"""AOT lowering: JAX tile programs -> HLO *text* artifacts for the Rust
PJRT runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is one (benchmark, tile size) specialization of an L2 tile
program; tile position / grid size stay runtime scalars, so one artifact
serves every tile of a run. A ``manifest.json`` records shapes and
parameters for the Rust side.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True: the Rust
    side unwraps with ``to_tuple``)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32():
    return jax.ShapeDtypeStruct((), jnp.int32)


def stencil_artifact(name, weights, tt, ti, tj):
    """Lower one stencil tile program; returns (hlo_text, manifest entry)."""
    w = np.asarray(weights)
    r = (w.shape[0] - 1) // 2
    h = 2 * r
    fn = model.make_stencil_tile(tt, ti, tj, w)
    args = (
        i32(), i32(), i32(), i32(), i32(),
        f32((ti + h, tj + h)),
        f32((max(tt - 1, 1), h, tj + h)),
        f32((max(tt - 1, 1), ti, h)),
    )
    lowered = jax.jit(fn).lower(*args)
    entry = {
        "kind": "stencil",
        "name": name,
        "radius": r,
        "tile": [tt, ti, tj],
        "inputs": {
            "scalars": ["t0", "u0", "v0", "n", "m"],
            "prev_plane": [ti + h, tj + h],
            "halo_u": [max(tt - 1, 1), h, tj + h],
            "halo_v": [max(tt - 1, 1), ti, h],
        },
        "outputs": {
            "facet_t": [ti, tj],
            "facet_u": [tt, h, tj],
            "facet_v": [tt, ti, h],
        },
    }
    return to_hlo_text(lowered), entry


def sw3_artifact(si, sj, sk):
    fn = model.make_sw3_tile(si, sj, sk)
    args = (
        f32((si,)), f32((sj,)), f32((sk,)),
        f32((sj + 1, sk + 1)), f32((si, sk + 1)), f32((si, sj)),
    )
    lowered = jax.jit(fn).lower(*args)
    entry = {
        "kind": "sw3",
        "name": "smith-waterman-3seq",
        "tile": [si, sj, sk],
        "inputs": {
            "a": [si], "b": [sj], "c": [sk],
            "halo_i": [sj + 1, sk + 1],
            "halo_j": [si, sk + 1],
            "halo_k": [si, sj],
        },
        "outputs": {
            "facet_i": [sj, sk],
            "facet_j": [si, sk],
            "facet_k": [si, sj],
        },
    }
    return to_hlo_text(lowered), entry


#: artifact set built by ``make artifacts`` (e2e examples + tests use these)
DEFAULT_CONFIGS = [
    ("jacobi2d5p_t4x16x16", "jacobi5p", (4, 16, 16)),
    ("jacobi2d5p_t8x32x32", "jacobi5p", (8, 32, 32)),
    ("jacobi2d9p_t4x16x16", "jacobi9p", (4, 16, 16)),
    ("gaussian_t4x16x16", "gaussian", (4, 16, 16)),
    ("sw3_t16x16x16", "sw3", (16, 16, 16)),
]

WEIGHTS = {
    "jacobi5p": ref.jacobi5p_weights,
    "jacobi9p": ref.jacobi9p_weights,
    "gaussian": ref.gaussian5x5_weights,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(legacy) single-file mode")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for fname, kind, tile in DEFAULT_CONFIGS:
        if kind == "sw3":
            hlo, entry = sw3_artifact(*tile)
        else:
            hlo, entry = stencil_artifact(fname, WEIGHTS[kind](), *tile)
        path = os.path.join(out_dir, f"{fname}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        entry["file"] = f"{fname}.hlo.txt"
        manifest[fname] = entry
        print(f"wrote {path} ({len(hlo)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    # `make artifacts` stamps on model.hlo.txt: keep it a real artifact
    # (copy of the e2e default) so loaders can open it directly.
    import shutil
    shutil.copyfile(
        os.path.join(out_dir, "jacobi2d5p_t8x32x32.hlo.txt"),
        os.path.join(out_dir, "model.hlo.txt"),
    )
    print(f"wrote {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
