"""L1 Pallas kernel: one stencil time-step over a one-sided-padded plane.

This is the compute hot-spot of the stencil benchmarks (jacobi2d5p,
jacobi2d9p, gaussian). The kernel is written for TPU-style execution:

* the output plane is blocked on a grid; each program instance computes one
  (BH, BW) block in VMEM -- the BlockSpec plays the role of the paper's
  on-chip scratchpad buffers (DESIGN.md section Hardware-Adaptation);
* the input stays unblocked (one-sided halo of 2r makes neighbor blocks
  overlap); each instance dynamically slices its (BH+2r, BW+2r) window,
  which expresses the HBM->VMEM halo schedule the paper expresses with
  copy loops;
* taps are unrolled at trace time (weights are static), so the inner body
  is 2D vector arithmetic -- VPU-friendly, no gather.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated through the interpreter and the
pure-jnp oracle (ref.py), per the repo's AOT recipe.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stencil_block_kernel(in_ref, out_ref, *, weights, r, bh, bw):
    """Compute one (bh, bw) output block from its (bh+2r, bw+2r) window."""
    h = 2 * r
    i = pl.program_id(0)
    j = pl.program_id(1)
    window = pl.load(
        in_ref,
        (pl.dslice(i * bh, bh + h), pl.dslice(j * bw, bw + h)),
    )
    acc = jnp.zeros((bh, bw), window.dtype)
    k = weights.shape[0]
    for a in range(k):
        for b in range(k):
            w = float(weights[a, b])
            if w == 0.0:
                continue
            acc = acc + w * jax.lax.dynamic_slice(window, (a, b), (bh, bw))
    out_ref[...] = acc


def _pick_block(n, preferred):
    """Largest divisor of n that is <= preferred (block must tile evenly)."""
    b = min(preferred, n)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("r", "weights_key"))
def _noop(*a, **k):  # pragma: no cover - placeholder to keep jit imports used
    raise NotImplementedError


def stencil_step(padded, weights, *, block=(32, 128)):
    """One stencil step: (H+2r, W+2r) padded plane -> (H, W) plane.

    ``weights`` must be a concrete (2r+1, 2r+1) array (static taps).
    Blocks default to (32, 128): 8-lane-sublane friendly shapes; a 32x128
    f32 block is 16 KiB -- two input/output blocks fit VMEM with room for
    double buffering.
    """
    import numpy as np

    w = np.asarray(weights)
    k = w.shape[0]
    r = (k - 1) // 2
    h = 2 * r
    out_h = padded.shape[0] - h
    out_w = padded.shape[1] - h
    bh = _pick_block(out_h, block[0])
    bw = _pick_block(out_w, block[1])
    grid = (out_h // bh, out_w // bw)
    kernel = functools.partial(
        _stencil_block_kernel, weights=w, r=r, bh=bh, bw=bw
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(padded.shape, lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((out_h, out_w), padded.dtype),
        interpret=True,
    )(padded)


def vmem_report(out_h, out_w, r, block=(32, 128), elem_bytes=4):
    """Static VMEM/MXU structure estimate for DESIGN.md section Perf.

    Returns a dict with the per-instance VMEM footprint (input window +
    output block, double-buffered) and the arithmetic intensity of the
    unrolled tap loop. interpret=True wall-clock is not a TPU proxy; this
    is the quantity we optimize instead.
    """
    bh = _pick_block(out_h, block[0])
    bw = _pick_block(out_w, block[1])
    h = 2 * r
    window = (bh + h) * (bw + h) * elem_bytes
    out = bh * bw * elem_bytes
    taps = (2 * r + 1) ** 2
    return {
        "block": (bh, bw),
        "vmem_bytes_single": window + out,
        "vmem_bytes_double_buffered": 2 * (window + out),
        "flops_per_elem": 2 * taps,
        "bytes_per_elem_hbm": 2 * elem_bytes,  # read + write, halo amortized
        "arith_intensity": (2 * taps) / (2 * elem_bytes),
    }
