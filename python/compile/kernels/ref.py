"""Pure-jnp correctness oracles for the L1 Pallas kernels and L2 tile models.

Everything here is written in the most obvious way possible (shifted-slice
sums, explicit loops) so it can serve as ground truth for:

* the Pallas stencil kernel (``stencil.py``) -- ``stencil_step_ref``;
* the tile model's facet dataflow (``model.py``) -- ``run_stencil_global``
  executes the *whole* iteration space one plane at a time, no tiling,
  which is what a correct tile decomposition must reproduce;
* the Smith-Waterman wavefront kernel (``sw.py``) -- ``sw3_ref`` is a
  dynamic-programming triple loop in numpy.
"""

import jax.numpy as jnp
import numpy as np


def stencil_step_ref(padded, weights):
    """One stencil step on a one-sided-padded plane.

    ``padded``  : (H + 2r, W + 2r) -- covers [u0-2r, u0+H) x [v0-2r, v0+W).
    ``weights`` : (2r+1, 2r+1) tap weights in *original* (di, dj) order.

    Returns the (H, W) updated interior. In skew-normalized coordinates the
    original-space tap (di, dj) reads padded[x + di + r, y + dj + r], i.e. a
    plain "valid" correlation.
    """
    k = weights.shape[0]
    r = (k - 1) // 2
    h = 2 * r
    out_h = padded.shape[0] - h
    out_w = padded.shape[1] - h
    acc = jnp.zeros((out_h, out_w), padded.dtype)
    for a in range(k):
        for b in range(k):
            acc = acc + weights[a, b] * padded[a : a + out_h, b : b + out_w]
    return acc


def jacobi5p_weights(dtype=jnp.float32):
    """Heat-equation 5-point stencil: c*center + (1-c)/4 * cross."""
    c = 0.5
    w = np.zeros((3, 3), dtype=np.float64)
    w[1, 1] = c
    w[0, 1] = w[2, 1] = w[1, 0] = w[1, 2] = (1.0 - c) / 4.0
    return jnp.asarray(w, dtype=dtype)


def jacobi9p_weights(dtype=jnp.float32):
    """9-point smoothing stencil (3x3 convolution, normalized)."""
    w = np.array(
        [[1.0, 2.0, 1.0], [2.0, 4.0, 2.0], [1.0, 2.0, 1.0]], dtype=np.float64
    )
    w /= w.sum()
    return jnp.asarray(w, dtype=dtype)


def gaussian5x5_weights(dtype=jnp.float32):
    """5x5 Gaussian blur kernel (binomial approximation)."""
    b = np.array([1.0, 4.0, 6.0, 4.0, 1.0])
    w = np.outer(b, b)
    w /= w.sum()
    return jnp.asarray(w, dtype=dtype)


def run_stencil_global(grid0, weights, steps):
    """Reference run of ``steps`` stencil updates over a full grid with a
    zero (Dirichlet) boundary, in ORIGINAL (unskewed) coordinates.

    ``grid0``: (N, M) initial state. Returns (N, M) after ``steps`` updates.
    """
    k = weights.shape[0]
    r = (k - 1) // 2
    g = grid0
    for _ in range(steps):
        padded = jnp.pad(g, r)  # zero boundary
        acc = jnp.zeros_like(g)
        for a in range(k):
            for b in range(k):
                acc = acc + weights[a, b] * padded[a : a + g.shape[0], b : b + g.shape[1]]
        g = acc
    return g


# ---------------------------------------------------------------------------
# Smith-Waterman, three sequences (Table I: smith-waterman-3seq).
# ---------------------------------------------------------------------------

#: gap penalty per unmatched axis step (max-plus DP)
SW_GAP = -1.0
#: triple-match reward / mismatch penalty
SW_MATCH = 2.0
SW_MISMATCH = -1.0


def sw3_score(a, b, c):
    """Score of aligning symbols a, b, c (numpy broadcasting semantics)."""
    return np.where((a == b) & (b == c), SW_MATCH, SW_MISMATCH)


def sw3_ref(A, B, C):
    """Full-table 3-sequence alignment DP, numpy triple loop.

    H[i,j,k] = max over the 7 backward neighbors of H[..] + move cost
    (global-style, no clamping, zero boundary). Out-of-table neighbors
    read 0. Returns the (len(A), len(B), len(C)) table.
    """
    A = np.asarray(A)
    B = np.asarray(B)
    C = np.asarray(C)
    ni, nj, nk = len(A), len(B), len(C)
    H = np.zeros((ni + 1, nj + 1, nk + 1), dtype=np.float32)
    for i in range(1, ni + 1):
        for j in range(1, nj + 1):
            for k in range(1, nk + 1):
                s = sw3_score(A[i - 1], B[j - 1], C[k - 1])
                cands = [
                    H[i - 1, j - 1, k - 1] + s,
                    H[i - 1, j, k] + SW_GAP,
                    H[i, j - 1, k] + SW_GAP,
                    H[i, j, k - 1] + SW_GAP,
                    H[i - 1, j - 1, k] + 2 * SW_GAP,
                    H[i - 1, j, k - 1] + 2 * SW_GAP,
                    H[i, j - 1, k - 1] + 2 * SW_GAP,
                ]
                H[i, j, k] = max(cands)
    return H[1:, 1:, 1:]
