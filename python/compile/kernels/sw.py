"""L1 kernel for smith-waterman-3seq: the plane-combine hot-spot.

The 3-sequence alignment DP has seven uniform dependencies ({0,-1}^3 \\ 0).
Splitting them per plane i:

* three reach the previous i-plane -- ``sw_base_kernel`` (Pallas) computes
  ``base[j,k] = max(Hprev[j-1,k-1] + s[j,k], Hprev[j,k] + g, Hprev[j,k-1] + 2g,
  Hprev[j-1,k] + 2g)`` for a whole (sj, sk) plane at once: elementwise max
  over shifted windows, fully vectorizable;
* four stay in-plane; rows are combined with a max-plus *scan*: with linear
  gap ``g``, ``x[k] = max(c[k], x[k-1] + g)`` solves to
  ``x = cummax(c - k*g) + k*g`` -- an associative scan, no sequential loop
  over k (model.py uses this).

This is the paper's "rethink for the hardware" step: the wavefront DP's
inner dependence becomes a parallel prefix instead of a serial chain.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _sw_base_body(hprev_ref, score_ref, out_ref, *, gap):
    """base[j,k] over one padded previous plane.

    hprev_ref: (sj+1, sk+1) plane i-1, padded low by 1 in j and k
               (hprev[j+1, k+1] is the in-tile point (j, k)).
    score_ref: (sj, sk) triple-match scores for plane i.
    out_ref:   (sj, sk).
    """
    hp = hprev_ref[...]
    s = score_ref[...]
    sj, sk = s.shape
    diag = jax.lax.dynamic_slice(hp, (0, 0), (sj, sk)) + s        # (i-1,j-1,k-1)
    up = jax.lax.dynamic_slice(hp, (1, 1), (sj, sk)) + gap        # (i-1,j,k)
    upk = jax.lax.dynamic_slice(hp, (1, 0), (sj, sk)) + 2.0 * gap  # (i-1,j,k-1)
    upj = jax.lax.dynamic_slice(hp, (0, 1), (sj, sk)) + 2.0 * gap  # (i-1,j-1,k)
    out_ref[...] = jnp.maximum(jnp.maximum(diag, up), jnp.maximum(upk, upj))


def sw_base(hprev_padded, scores, gap=ref.SW_GAP):
    """Pallas call computing the previous-plane contribution for a plane."""
    sj, sk = scores.shape
    body = functools.partial(_sw_base_body, gap=float(gap))
    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((sj, sk), scores.dtype),
        interpret=True,
    )(hprev_padded, scores)


def sw_base_ref(hprev_padded, scores, gap=ref.SW_GAP):
    """jnp oracle for sw_base."""
    sj, sk = scores.shape
    hp = hprev_padded
    diag = hp[0:sj, 0:sk] + scores
    up = hp[1 : sj + 1, 1 : sk + 1] + gap
    upk = hp[1 : sj + 1, 0:sk] + 2.0 * gap
    upj = hp[0:sj, 1 : sk + 1] + 2.0 * gap
    return jnp.maximum(jnp.maximum(diag, up), jnp.maximum(upk, upj))


def maxplus_row_scan(c, x_left, gap=ref.SW_GAP):
    """Solve x[k] = max(c[k], x[k-1] + gap) with x[-1] = x_left.

    Associative-scan closed form: x[k] = max_{m<=k} (c'[m] + (k-m) gap)
    where c'[-1] = x_left; computed as cummax(c' - idx*gap) + idx*gap.
    """
    sk = c.shape[0]
    x0 = jnp.reshape(x_left, (1,)).astype(c.dtype)
    cext = jnp.concatenate([x0, c])
    idx = jnp.arange(sk + 1, dtype=c.dtype)
    shifted = cext - idx * gap
    run = jax.lax.cummax(shifted)
    x = run + idx * gap
    return x[1:]


def maxplus_row_scan_ref(c, x_left, gap=ref.SW_GAP):
    """Sequential oracle for maxplus_row_scan."""
    out = []
    x = x_left
    for k in range(c.shape[0]):
        x = jnp.maximum(c[k], x + gap)
        out.append(x)
    return jnp.stack(out)
