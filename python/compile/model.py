"""L2: JAX tile programs over CFA facets, calling the L1 Pallas kernels.

A tile program is the ``execute`` stage of the paper's read-execute-write
template (Fig 13) expressed over exactly the CFA data sets:

* **inputs** are the tile's flow-in pieces -- the previous-time plane padded
  with one-sided halos, plus per-step halo slabs read from the neighbor
  tiles' facets;
* **outputs** are the tile's flow-out **facets** (the last w_k planes along
  each axis), which L3 writes to global memory with single-burst stores.

Programs are shape-specialized per (benchmark, tile size) and AOT-lowered
by ``aot.py``; tile position and grid size are *runtime scalars* so one
artifact serves every tile, including boundary masking.

Coordinate convention for stencils (skew-normalized space, DESIGN.md):
iteration point (t, u, v) carries original grid cell (i, j) = (u - t, v - t)
at time t; points with (i, j) outside the grid are masked to zero, which
implements the Dirichlet boundary of the reference (ref.run_stencil_global).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels import sw as swk
from .kernels.stencil import stencil_step


def make_stencil_tile(tt, ti, tj, weights):
    """Build the tile program for a stencil benchmark.

    Static: tt, ti, tj (tile size), weights ((2r+1)^2 taps).
    Runtime inputs:
      t0, u0, v0 : i32 scalars -- tile origin in the skewed space;
      n, m       : i32 scalars -- original grid size (N rows, M cols);
      prev_plane : (ti+h, tj+h) f32 -- plane t0-1 over
                   [u0-h, u0+ti) x [v0-h, v0+tj);
      halo_u     : (tt-1, h, tj+h) f32 -- u-halo rows for local steps >= 1;
      halo_v     : (tt-1, ti, h) f32 -- v-halo cols for local steps >= 1.
    Outputs (flow-out facets):
      facet_t (ti, tj), facet_u (tt, h, tj), facet_v (tt, ti, h)
    with h = 2r.
    """
    w = np.asarray(weights)
    r = (w.shape[0] - 1) // 2
    h = 2 * r
    assert ti >= h and tj >= h, "tile too small for the halo"

    def mask_plane(plane, s, t0, u0, v0, n, m):
        # skew u = i + r*t (the factor that normalizes radius-r deps)
        t = t0 + s
        uu = u0 + jnp.arange(ti, dtype=jnp.int32)[:, None]
        vv = v0 + jnp.arange(tj, dtype=jnp.int32)[None, :]
        i = uu - r * t
        j = vv - r * t
        valid = (i >= 0) & (i < n) & (j >= 0) & (j < m)
        return jnp.where(valid, plane, jnp.zeros_like(plane))

    def tile_fn(t0, u0, v0, n, m, prev_plane, halo_u, halo_v):
        interior0 = mask_plane(
            stencil_step(prev_plane, w), 0, t0, u0, v0, n, m
        )
        fac_u0 = jnp.zeros((tt, h, tj), prev_plane.dtype)
        fac_v0 = jnp.zeros((tt, ti, h), prev_plane.dtype)
        fac_u0 = fac_u0.at[0].set(interior0[ti - h :, :])
        fac_v0 = fac_v0.at[0].set(interior0[:, tj - h :])

        def body(s, carry):
            interior, fac_u, fac_v = carry
            hu = jax.lax.dynamic_index_in_dim(halo_u, s - 1, 0, keepdims=False)
            hv = jax.lax.dynamic_index_in_dim(halo_v, s - 1, 0, keepdims=False)
            padded = jnp.concatenate(
                [hu, jnp.concatenate([hv, interior], axis=1)], axis=0
            )
            nxt = mask_plane(stencil_step(padded, w), s, t0, u0, v0, n, m)
            fac_u = jax.lax.dynamic_update_index_in_dim(
                fac_u, nxt[ti - h :, :], s, 0
            )
            fac_v = jax.lax.dynamic_update_index_in_dim(
                fac_v, nxt[:, tj - h :], s, 0
            )
            return nxt, fac_u, fac_v

        if tt > 1:
            interior, fac_u, fac_v = jax.lax.fori_loop(
                1, tt, body, (interior0, fac_u0, fac_v0)
            )
        else:
            interior, fac_u, fac_v = interior0, fac_u0, fac_v0
        return interior, fac_u, fac_v

    return tile_fn


def make_sw3_tile(si, sj, sk):
    """Build the tile program for smith-waterman-3seq.

    Runtime inputs:
      a (si,), b (sj,), c (sk,) : f32 symbol chunks for this tile;
      halo_i : (sj+1, sk+1) -- plane i0-1 over [j0-1, ..) x [k0-1, ..);
      halo_j : (si, sk+1)   -- H[i, j0-1, k] rows, k from k0-1;
      halo_k : (si, sj)     -- H[i, j, k0-1] columns.
    Outputs (facets, w = 1 on every axis):
      facet_i (sj, sk), facet_j (si, sk), facet_k (si, sj).
    """
    gap = ref.SW_GAP

    def plane(prev_padded, a_i, b, c, hj_row, hk_col):
        # scores s[j,k] for this i-plane
        s = jnp.where(
            (a_i == b[:, None]) & (b[:, None] == c[None, :]),
            jnp.float32(ref.SW_MATCH),
            jnp.float32(ref.SW_MISMATCH),
        )
        base = swk.sw_base(prev_padded, s)  # (sj, sk) pallas kernel

        def row_step(prev_row_padded, inputs):
            base_row, hk = inputs  # (sk,), scalar H[i, j, k0-1]
            c_row = jnp.maximum(
                base_row,
                jnp.maximum(
                    prev_row_padded[1:] + gap, prev_row_padded[:-1] + 2.0 * gap
                ),
            )
            row = swk.maxplus_row_scan(c_row, hk, gap)
            # next row's padded predecessor: [H[i, j, k0-1], row]
            nxt = jnp.concatenate([jnp.reshape(hk, (1,)).astype(row.dtype), row])
            return nxt, row

        # row j0-1 of this plane, padded from k0-1: hj_row is (sk+1,)
        _, rows = jax.lax.scan(row_step, hj_row, (base, hk_col))
        pl_ = rows  # (sj, sk)
        # assemble next prev_padded for plane i+1
        top = hj_row[None, :]  # will be replaced by caller; see scan below
        del top
        return pl_

    def tile_fn(a, b, c, halo_i, halo_j, halo_k):
        def i_step(prev_padded, inputs):
            a_i, hj_row, hk_col = inputs
            pl_ = plane(prev_padded, a_i, b, c, hj_row, hk_col)
            nxt = jnp.concatenate(
                [hj_row[None, :],
                 jnp.concatenate([hk_col[:, None], pl_], axis=1)],
                axis=0,
            )
            return nxt, (pl_[-1, :], pl_[:, -1], pl_)

        # NB: the padded predecessor of plane i+1 uses HALO rows of plane i
        # (H[i, j0-1, *] and H[i, *, k0-1]) -- exactly halo_j[i] / halo_k[i].
        last, (fj, fk, planes) = jax.lax.scan(
            i_step, halo_i, (a, halo_j, halo_k)
        )
        del last
        facet_i = planes[-1]  # (sj, sk)
        return facet_i, fj, fk

    return tile_fn


# ---------------------------------------------------------------------------
# Python-level tile orchestration (build-time validation of the dataflow the
# Rust coordinator implements; pytest drives this against the global refs).
# ---------------------------------------------------------------------------

def run_stencil_tiled(grid0, weights, steps, tt, ti, tj):
    """Execute the full stencil with the tile program, assembling halos the
    way the Rust coordinator does (from neighbor facets), and compare-ready
    against ref.run_stencil_global.

    Uses a dense skewed-space scratch array as stand-in for global memory
    (the point here is the tile dataflow, not the allocation).
    """
    w = np.asarray(weights)
    r = (w.shape[0] - 1) // 2
    h = 2 * r
    n, m = grid0.shape
    T = steps
    U, V = n + r * T, m + r * T  # skewed extents (padded up; masked anyway)
    assert T % tt == 0 and U % ti == 0 and V % tj == 0, "tiles must divide"
    tile = make_stencil_tile(tt, ti, tj, w)

    # value[t, u, v] for t in [-1, T); t=-1 holds the initial grid
    val = np.zeros((T + 1, U + h, V + h), dtype=np.float32)  # +h: low pads

    def get(t, u, v):
        # value of skewed point; zero outside grid (mask semantics).
        # u, v may dip into [-h, 0): the initial plane (t = -1) lives at
        # skewed coordinates u = i - r, which start at -r.
        i, j = u - r * t, v - r * t
        if t < -1 or u < -h or v < -h:
            return 0.0
        if 0 <= i < n and 0 <= j < m:
            return val[t + 1, u + h, v + h]
        return 0.0

    # seed the initial plane t = -1: u = i - r may be negative -> the +h pad
    for i in range(n):
        for j in range(m):
            u, v = i - r, j - r
            val[0, u + h, v + h] = float(grid0[i, j])

    for bt in range(T // tt):
        for bu in range(U // ti):
            for bv in range(V // tj):
                t0, u0, v0 = bt * tt, bu * ti, bv * tj
                prev = np.zeros((ti + h, tj + h), np.float32)
                for x in range(ti + h):
                    for y in range(tj + h):
                        prev[x, y] = get(t0 - 1, u0 - h + x, v0 - h + y)
                hu = np.zeros((max(tt - 1, 1), h, tj + h), np.float32)
                hv = np.zeros((max(tt - 1, 1), ti, h), np.float32)
                for s in range(1, tt):
                    for x in range(h):
                        for y in range(tj + h):
                            hu[s - 1, x, y] = get(t0 + s - 1, u0 - h + x, v0 - h + y)
                    for x in range(ti):
                        for y in range(h):
                            hv[s - 1, x, y] = get(t0 + s - 1, u0 + x, v0 - h + y)
                fac_t, fac_u, fac_v = tile(
                    jnp.int32(t0), jnp.int32(u0), jnp.int32(v0),
                    jnp.int32(n), jnp.int32(m),
                    jnp.asarray(prev), jnp.asarray(hu), jnp.asarray(hv),
                )
                fac_t = np.asarray(fac_t)
                fac_u = np.asarray(fac_u)
                fac_v = np.asarray(fac_v)
                # write facets back (facets overlap on corners; identical
                # values, so order does not matter)
                for s in range(tt):
                    t = t0 + s
                    for x in range(h):
                        for y in range(tj):
                            val[t + 1, u0 + ti - h + x + h, v0 + y + h] = fac_u[s, x, y]
                    for x in range(ti):
                        for y in range(h):
                            val[t + 1, u0 + x + h, v0 + tj - h + y + h] = fac_v[s, x, y]
                val[t0 + tt - 1 + 1, u0 + h : u0 + ti + h, v0 + h : v0 + tj + h] = fac_t

    # extract the final grid from plane T-1: i = u - r*(T-1)
    out = np.zeros((n, m), np.float32)
    for i in range(n):
        for j in range(m):
            out[i, j] = get(T - 1, i + r * (T - 1), j + r * (T - 1))
    return out


def run_sw3_tiled(A, B, C, si, sj, sk):
    """Execute the full 3-seq DP with the tile program (halo assembly in
    numpy), producing the final facets; compare against ref.sw3_ref."""
    A = np.asarray(A, np.float32)
    B = np.asarray(B, np.float32)
    C = np.asarray(C, np.float32)
    ni, nj, nk = len(A), len(B), len(C)
    assert ni % si == 0 and nj % sj == 0 and nk % sk == 0
    tile = make_sw3_tile(si, sj, sk)
    H = np.zeros((ni + 1, nj + 1, nk + 1), np.float32)  # +1: zero boundary
    for bi in range(ni // si):
        for bj in range(nj // sj):
            for bk in range(nk // sk):
                i0, j0, k0 = bi * si, bj * sj, bk * sk
                halo_i = H[i0, j0 : j0 + sj + 1, k0 : k0 + sk + 1]
                halo_j = H[i0 + 1 : i0 + si + 1, j0, k0 : k0 + sk + 1]
                halo_k = H[i0 + 1 : i0 + si + 1, j0 + 1 : j0 + sj + 1, k0]
                fi, fj, fk = tile(
                    jnp.asarray(A[i0 : i0 + si]),
                    jnp.asarray(B[j0 : j0 + sj]),
                    jnp.asarray(C[k0 : k0 + sk]),
                    jnp.asarray(halo_i),
                    jnp.asarray(halo_j),
                    jnp.asarray(halo_k),
                )
                # facets are the tile's boundary planes; the DP needs the
                # full tile interior for verification, so recompute it the
                # slow way is avoided by storing facets only -- sufficient
                # because downstream tiles read only facets. For the final
                # comparison we also need interiors, so store what we have:
                H[i0 + si, j0 + 1 : j0 + sj + 1, k0 + 1 : k0 + sk + 1] = np.asarray(fi)
                H[i0 + 1 : i0 + si + 1, j0 + sj, k0 + 1 : k0 + sk + 1] = np.asarray(fj)
                H[i0 + 1 : i0 + si + 1, j0 + 1 : j0 + sj + 1, k0 + sk] = np.asarray(fk)
    return H
