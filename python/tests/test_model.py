"""L2 tile-program correctness: the facet dataflow reproduces the global
references exactly (this is the contract the Rust coordinator builds on)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import model
from compile.kernels import ref


class TestStencilTiled:
    @pytest.mark.parametrize(
        "weights_fn,T,tt,tile",
        [
            (ref.jacobi5p_weights, 4, 2, 6),
            (ref.jacobi5p_weights, 4, 4, 4),
            (ref.jacobi9p_weights, 4, 2, 6),
            (ref.gaussian5x5_weights, 4, 2, 8),
            (ref.gaussian5x5_weights, 4, 4, 4),
        ],
    )
    def test_tiled_equals_global(self, weights_fn, T, tt, tile):
        w = weights_fn()
        r = (np.asarray(w).shape[0] - 1) // 2
        n = m = 8
        grid0 = np.random.RandomState(0).rand(n, m).astype(np.float32)
        U = n + r * T
        assert U % tile == 0
        exp = np.asarray(ref.run_stencil_global(jnp.asarray(grid0), w, T))
        got = model.run_stencil_tiled(grid0, w, T, tt=tt, ti=tile, tj=tile)
        np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-5)

    def test_single_tile_degenerate(self):
        w = ref.jacobi5p_weights()
        n = m = 4
        grid0 = np.eye(4, dtype=np.float32)
        exp = np.asarray(ref.run_stencil_global(jnp.asarray(grid0), w, 1))
        got = model.run_stencil_tiled(grid0, w, 1, tt=1, ti=5, tj=5)
        np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-6)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_random_grids(self, seed):
        w = ref.jacobi5p_weights()
        n = m = 6
        T, tt, tile = 2, 2, 4
        grid0 = np.random.RandomState(seed).randn(n, m).astype(np.float32)
        exp = np.asarray(ref.run_stencil_global(jnp.asarray(grid0), w, T))
        got = model.run_stencil_tiled(grid0, w, T, tt=tt, ti=tile, tj=tile)
        np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-4)

    def test_boundary_masking_zeroes_outside(self):
        # all-ones grid: after one averaging step interior stays 1.0 but the
        # grid border drops (zero Dirichlet halo) -- sensitive to masking
        w = ref.jacobi5p_weights()
        n = m = 6
        grid0 = np.ones((n, m), np.float32)
        exp = np.asarray(ref.run_stencil_global(jnp.asarray(grid0), w, 2))
        got = model.run_stencil_tiled(grid0, w, 2, tt=2, ti=4, tj=4)
        np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-6)
        assert got[0, 0] < 1.0
        assert got[3, 3] == pytest.approx(1.0, abs=1e-6)


class TestSw3Tiled:
    def sequences(self, seed, n):
        rng = np.random.RandomState(seed)
        return (rng.randint(0, 4, n), rng.randint(0, 4, n), rng.randint(0, 4, n))

    @pytest.mark.parametrize("n,s", [(8, 4), (8, 8), (12, 4)])
    def test_facets_match_reference(self, n, s):
        A, B, C = self.sequences(7, n)
        Href = ref.sw3_ref(A, B, C)
        H = model.run_sw3_tiled(A, B, C, s, s, s)
        for i in range(n):
            for j in range(n):
                for k in range(n):
                    if (i % s == s - 1) or (j % s == s - 1) or (k % s == s - 1):
                        assert H[i + 1, j + 1, k + 1] == pytest.approx(
                            Href[i, j, k], abs=1e-4
                        ), (i, j, k)

    def test_identical_sequences_score_matches(self):
        A = np.arange(8) % 4
        Href = ref.sw3_ref(A, A, A)
        # perfect diagonal: H[i,i,i] = (i+1) * match
        for i in range(8):
            assert Href[i, i, i] == pytest.approx((i + 1) * ref.SW_MATCH)
        H = model.run_sw3_tiled(A, A, A, 4, 4, 4)
        assert H[8, 8, 8] == pytest.approx(8 * ref.SW_MATCH)
