"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes, dtypes and weights; assert_allclose against
ref.py. This is the core build-time correctness signal for the compute
layer (the Rust runtime then loads bit-identical HLO).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.kernels import ref
from compile.kernels import sw as swk
from compile.kernels.stencil import stencil_step, vmem_report


def rand(shape, seed, dtype=np.float32):
    return jnp.asarray(np.random.RandomState(seed).rand(*shape).astype(dtype))


class TestStencilKernel:
    @pytest.mark.parametrize(
        "weights_fn,r",
        [
            (ref.jacobi5p_weights, 1),
            (ref.jacobi9p_weights, 1),
            (ref.gaussian5x5_weights, 2),
        ],
    )
    def test_named_benchmarks_match_ref(self, weights_fn, r):
        w = weights_fn()
        P = rand((16 + 2 * r, 32 + 2 * r), seed=r)
        got = stencil_step(P, w)
        exp = ref.stencil_step_ref(P, w)
        np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        h=st.integers(1, 24),
        wd=st.integers(1, 48),
        r=st.integers(1, 2),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_random_shapes_and_weights(self, h, wd, r, seed):
        rng = np.random.RandomState(seed)
        w = jnp.asarray(rng.randn(2 * r + 1, 2 * r + 1).astype(np.float32))
        P = jnp.asarray(rng.randn(h + 2 * r, wd + 2 * r).astype(np.float32))
        got = stencil_step(P, w)
        exp = ref.stencil_step_ref(P, w)
        assert got.shape == (h, wd)
        np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-4)

    def test_float64(self):
        w = np.asarray(ref.jacobi5p_weights(), dtype=np.float32)
        P = rand((10, 10), seed=3)
        got = stencil_step(P, w)
        exp = ref.stencil_step_ref(P, jnp.asarray(w))
        np.testing.assert_allclose(got, exp, rtol=1e-6)

    def test_impulse_response_is_weights(self):
        # a centered impulse reproduces the flipped tap pattern exactly
        r = 1
        w = ref.jacobi5p_weights()
        P = np.zeros((5, 5), np.float32)
        P[2, 2] = 1.0
        got = np.asarray(stencil_step(jnp.asarray(P), w))
        # out[x,y] = sum w[a,b] P[x+a, y+b] -> impulse at (2,2) spreads w
        # reversed around (2-r... ) == w by symmetry of our kernels
        exp = np.asarray(ref.stencil_step_ref(jnp.asarray(P), w))
        np.testing.assert_allclose(got, exp)
        assert got[1, 1] == pytest.approx(float(np.asarray(w)[1, 1]))

    def test_block_divisor_logic(self):
        # odd sizes must still tile exactly (block picked as a divisor)
        w = ref.jacobi5p_weights()
        P = rand((7 + 2, 13 + 2), seed=9)
        got = stencil_step(P, w)
        exp = ref.stencil_step_ref(P, w)
        np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-6)

    def test_vmem_report_structure(self):
        rep = vmem_report(32, 128, r=1)
        assert rep["vmem_bytes_double_buffered"] == 2 * rep["vmem_bytes_single"]
        assert rep["block"] == (32, 128)
        assert rep["flops_per_elem"] == 18
        # double buffering must fit comfortably in 16 MiB VMEM
        assert rep["vmem_bytes_double_buffered"] < 16 * 1024 * 1024


class TestSwKernels:
    @settings(max_examples=25, deadline=None)
    @given(
        sj=st.integers(1, 24),
        sk=st.integers(1, 24),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_sw_base_matches_ref(self, sj, sk, seed):
        rng = np.random.RandomState(seed)
        hp = jnp.asarray(rng.randn(sj + 1, sk + 1).astype(np.float32))
        sc = jnp.asarray(rng.randn(sj, sk).astype(np.float32))
        np.testing.assert_allclose(
            swk.sw_base(hp, sc), swk.sw_base_ref(hp, sc), rtol=1e-5, atol=1e-5
        )

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
        left=st.floats(-5, 5),
    )
    def test_maxplus_scan_matches_sequential(self, n, seed, left):
        c = jnp.asarray(np.random.RandomState(seed).randn(n).astype(np.float32))
        got = swk.maxplus_row_scan(c, jnp.float32(left))
        exp = swk.maxplus_row_scan_ref(c, jnp.float32(left))
        np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-5)

    def test_scan_gap_semantics(self):
        # with c = [-inf-ish ...] the scan is pure gap decay from x_left
        c = jnp.full((4,), -1e9, jnp.float32)
        got = np.asarray(swk.maxplus_row_scan(c, jnp.float32(10.0), gap=-1.0))
        np.testing.assert_allclose(got, [9.0, 8.0, 7.0, 6.0])
