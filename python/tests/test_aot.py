"""AOT path: tile programs lower to valid HLO text with stable signatures."""

import json
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import aot
from compile.kernels import ref


class TestLowering:
    def test_stencil_artifact_text(self):
        hlo, entry = aot.stencil_artifact(
            "t", ref.jacobi5p_weights(), 2, 8, 8
        )
        assert hlo.startswith("HloModule")
        assert "f32[10,10]" in hlo  # prev_plane (8+2, 8+2)
        assert entry["outputs"]["facet_t"] == [8, 8]
        assert entry["radius"] == 1

    def test_gaussian_artifact_halo_width(self):
        hlo, entry = aot.stencil_artifact(
            "g", ref.gaussian5x5_weights(), 2, 8, 8
        )
        assert entry["radius"] == 2
        assert entry["inputs"]["prev_plane"] == [12, 12]  # h = 4
        assert "f32[12,12]" in hlo

    def test_sw3_artifact_text(self):
        hlo, entry = aot.sw3_artifact(4, 4, 4)
        assert hlo.startswith("HloModule")
        assert entry["outputs"]["facet_i"] == [4, 4]

    def test_default_configs_cover_table1(self):
        kinds = {k for _, k, _ in aot.DEFAULT_CONFIGS}
        assert {"jacobi5p", "jacobi9p", "gaussian", "sw3"} <= kinds


class TestArtifactsOnDisk:
    """Validate the artifacts `make artifacts` produced (skip if absent)."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    @pytest.fixture()
    def manifest(self):
        path = os.path.join(self.ART, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            return json.load(f)

    def test_manifest_files_exist(self, manifest):
        assert len(manifest) >= 5
        for name, entry in manifest.items():
            p = os.path.join(self.ART, entry["file"])
            assert os.path.exists(p), p
            with open(p) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), name

    def test_manifest_shapes_consistent(self, manifest):
        for name, entry in manifest.items():
            if entry["kind"] == "stencil":
                tt, ti, tj = entry["tile"]
                h = 2 * entry["radius"]
                assert entry["inputs"]["prev_plane"] == [ti + h, tj + h]
                assert entry["outputs"]["facet_u"] == [tt, h, tj]
