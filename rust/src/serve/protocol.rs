//! Wire grammar for `cfa serve`.
//!
//! Requests and responses are both line-delimited compact JSON: one
//! object per line, no intra-object newlines. Every request carries an
//! `id` chosen by the client; every response line echoes it, so a client
//! can multiplex requests over one connection and correlate the replies.
//! `Json` objects render with sorted keys, so response lines are
//! byte-deterministic — CI greps for exact substrings like
//! `"event":"done","id":"a"`.
//!
//! Request grammar (`cmd` selects the variant; unknown keys are ignored
//! so clients can annotate freely):
//!
//! ```text
//! {"cmd":"tune","id":ID, "space":"tiny"|{...}, "strategy":"exhaustive"
//!  |"random"|"hill"|"model-guided", "seed":0, "budget":0, "parallel":1,
//!  "out":PATH?, "resume":PATH?, "retry_failed":true, "deadline_secs":0,
//!  "trace_cache":true, "prune":false, "shard":"I/N"?, "stream":false,
//!  "profile":PATH?}
//! {"cmd":"run","id":ID, "workload":"jacobi2d5p", "tile":[16,16,16],
//!  "tiles_per_dim":3, "layout":"cfa", "mode":"timing"|"sweep",
//!  "channels":1, "striping":"address:4096"?, "threads":1,
//!  "profile":PATH?}
//! {"cmd":"plan","id":ID, "workload":..., "tile":[...],
//!  "tiles_per_dim":3, "layout":"cfa"}
//! {"cmd":"stats","id":ID}
//! {"cmd":"shutdown","id":ID}
//! ```
//!
//! Response events: `accepted` (queued), `rejected` (queue full —
//! explicit backpressure, resend later), `row` (one streamed journal
//! row, only when `stream` is on), `done` (terminal success, payload in
//! `data`), `error` (terminal failure, message in `error`).

use crate::dse::Space;
use crate::memsim::Striping;
use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{self, Write};
use std::sync::{Arc, Mutex, PoisonError};

/// A parsed request (the variant behind `cmd`).
pub enum Request {
    Run(RunRequest),
    Tune(Box<TuneRequest>),
    Plan(PlanRequest),
    Stats,
    Shutdown,
}

impl Request {
    /// `stats` and `shutdown` are answered synchronously on the
    /// connection thread; everything else goes through the worker pool.
    pub fn is_inline(&self) -> bool {
        matches!(self, Request::Stats | Request::Shutdown)
    }
}

/// `{"cmd":"tune",...}` — one explorer run, same knobs as `cfa tune`.
pub struct TuneRequest {
    pub space: Space,
    pub strategy: String,
    pub seed: u64,
    pub budget: usize,
    pub parallel: usize,
    pub out: Option<String>,
    pub resume: Option<String>,
    pub retry_failed: bool,
    pub deadline_secs: u64,
    pub trace_cache: bool,
    /// Early-abort replay: prune points whose bandwidth upper bound the
    /// front already dominates (same semantics as `cfa tune --prune`).
    pub prune: bool,
    /// `"I/N"` — own only shard I of N (see `cfa tune --shard`).
    pub shard: Option<(usize, usize)>,
    pub stream: bool,
    /// Server-side span-trace output path: the job runs under a span
    /// capture and writes Chrome trace-event JSON here. Advisory wall
    /// time only — journal bytes are unaffected.
    pub profile: Option<String>,
}

/// `{"cmd":"run",...}` — one experiment session, timing or sweep mode
/// (the data-verified PJRT path needs artifacts and stays on the CLI).
pub struct RunRequest {
    pub workload: String,
    pub tile: Vec<i64>,
    pub tiles_per_dim: i64,
    pub layout: String,
    pub mode: String,
    pub channels: usize,
    pub striping: Option<Striping>,
    pub threads: usize,
    /// Server-side span-trace output path (see [`TuneRequest::profile`]).
    pub profile: Option<String>,
}

/// `{"cmd":"plan",...}` — layout facts for one geometry, no simulation.
pub struct PlanRequest {
    pub workload: String,
    pub tile: Vec<i64>,
    pub tiles_per_dim: i64,
    pub layout: String,
}

fn field_str(j: &Json, key: &str) -> Option<String> {
    j.get(key).and_then(Json::as_str).map(str::to_string)
}

fn field_u64(j: &Json, key: &str, default: u64) -> Result<u64> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => {
            let n = v
                .as_f64()
                .ok_or_else(|| anyhow!("'{key}' must be a number"))?;
            if n < 0.0 || n.fract() != 0.0 {
                bail!("'{key}' must be a non-negative integer, got {n}");
            }
            Ok(n as u64)
        }
    }
}

fn field_bool(j: &Json, key: &str, default: bool) -> Result<bool> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| anyhow!("'{key}' must be a boolean")),
    }
}

fn field_tile(j: &Json, key: &str) -> Result<Vec<i64>> {
    let arr = j
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("'{key}' must be an array of tile sizes"))?;
    if arr.is_empty() {
        bail!("'{key}' must not be empty");
    }
    arr.iter()
        .map(|v| {
            let n = v
                .as_f64()
                .ok_or_else(|| anyhow!("'{key}' entries must be numbers"))?;
            if n < 1.0 || n.fract() != 0.0 {
                bail!("'{key}' entries must be positive integers, got {n}");
            }
            Ok(n as i64)
        })
        .collect()
}

/// The `space` field: a builtin name string or an inline space object
/// (the `--space PATH` JSON grammar, passed by value — the daemon never
/// reads client-side files for it).
fn parse_space(j: &Json) -> Result<Space> {
    let v = j
        .get("space")
        .ok_or_else(|| anyhow!("tune request needs 'space' (builtin name or inline object)"))?;
    match v.as_str() {
        Some(name) => Space::builtin(name).ok_or_else(|| {
            anyhow!("unknown builtin space '{name}' (pass an inline space object for custom spaces)")
        }),
        None => Space::from_json(v).context("inline 'space' object"),
    }
}

fn parse_tune(j: &Json) -> Result<TuneRequest> {
    let shard = match field_str(j, "shard") {
        None => None,
        Some(spec) => {
            let parts = spec
                .split_once('/')
                .and_then(|(i, n)| Some((i.parse::<usize>().ok()?, n.parse::<usize>().ok()?)));
            let (i, n) =
                parts.ok_or_else(|| anyhow!("'shard' must be \"I/N\" (e.g. \"0/4\"), got '{spec}'"))?;
            if n == 0 || i >= n {
                bail!("'shard' index must be < shards, shards >= 1 (got {i}/{n})");
            }
            Some((i, n))
        }
    };
    Ok(TuneRequest {
        space: parse_space(j)?,
        strategy: field_str(j, "strategy").unwrap_or_else(|| "exhaustive".to_string()),
        seed: field_u64(j, "seed", 0)?,
        budget: field_u64(j, "budget", 0)? as usize,
        parallel: field_u64(j, "parallel", 1)?.max(1) as usize,
        out: field_str(j, "out"),
        resume: field_str(j, "resume"),
        retry_failed: field_bool(j, "retry_failed", true)?,
        deadline_secs: field_u64(j, "deadline_secs", 0)?,
        trace_cache: field_bool(j, "trace_cache", true)?,
        prune: field_bool(j, "prune", false)?,
        shard,
        stream: field_bool(j, "stream", false)?,
        profile: field_str(j, "profile"),
    })
}

fn parse_run(j: &Json) -> Result<RunRequest> {
    let mode = field_str(j, "mode").unwrap_or_else(|| "timing".to_string());
    if mode != "timing" && mode != "sweep" {
        bail!("'mode' must be 'timing' or 'sweep', got '{mode}'");
    }
    let striping = match field_str(j, "striping") {
        Some(s) => Some(Striping::parse(&s).context("'striping'")?),
        None => None,
    };
    Ok(RunRequest {
        workload: field_str(j, "workload")
            .ok_or_else(|| anyhow!("run request needs 'workload'"))?,
        tile: field_tile(j, "tile")?,
        tiles_per_dim: field_u64(j, "tiles_per_dim", 3)?.max(1) as i64,
        layout: field_str(j, "layout").unwrap_or_else(|| "cfa".to_string()),
        mode,
        channels: field_u64(j, "channels", 1)?.max(1) as usize,
        striping,
        threads: field_u64(j, "threads", 1)?.max(1) as usize,
        profile: field_str(j, "profile"),
    })
}

fn parse_plan(j: &Json) -> Result<PlanRequest> {
    Ok(PlanRequest {
        workload: field_str(j, "workload")
            .ok_or_else(|| anyhow!("plan request needs 'workload'"))?,
        tile: field_tile(j, "tile")?,
        tiles_per_dim: field_u64(j, "tiles_per_dim", 3)?.max(1) as i64,
        layout: field_str(j, "layout").unwrap_or_else(|| "cfa".to_string()),
    })
}

/// Parse one request line. The `id` is extracted leniently *first* so an
/// `error` reply for a bad request still carries the client's id; only a
/// line that is not JSON at all falls back to the empty id.
pub fn parse_line(line: &str) -> (String, Result<Request>) {
    let j = match json::parse(line) {
        Ok(j) => j,
        Err(e) => return (String::new(), Err(anyhow!("request is not JSON: {e}"))),
    };
    let id = field_str(&j, "id").unwrap_or_default();
    let req = (|| -> Result<Request> {
        let cmd = field_str(&j, "cmd")
            .ok_or_else(|| anyhow!("request needs 'cmd' (run|tune|plan|stats|shutdown)"))?;
        match cmd.as_str() {
            "run" => Ok(Request::Run(parse_run(&j)?)),
            "tune" => Ok(Request::Tune(Box::new(parse_tune(&j)?))),
            "plan" => Ok(Request::Plan(parse_plan(&j)?)),
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            c => bail!("unknown cmd '{c}' (run|tune|plan|stats|shutdown)"),
        }
    })();
    (id, req)
}

/// The shared, line-atomic response writer for one connection. Cloned
/// into every job spawned from the connection so workers stream rows and
/// terminal replies directly, without going back through the connection
/// thread. Each send holds the lock across one `writeln!` + flush, so
/// concurrent senders interleave whole lines, never bytes.
#[derive(Clone)]
pub struct Reply {
    writer: Arc<Mutex<dyn Write + Send>>,
}

impl Reply {
    pub fn new(writer: Arc<Mutex<dyn Write + Send>>) -> Reply {
        Reply { writer }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, dyn Write + Send> {
        // a panicked sender mid-writeln leaves at worst a torn line;
        // poisoning must not silence every later reply on the connection
        self.writer.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Write one response line. Fault site: `serve::respond`.
    pub fn send(&self, j: &Json) -> io::Result<()> {
        crate::util::faults::check_io("serve::respond")?;
        let mut w = self.lock();
        writeln!(w, "{}", j.to_string_compact())?;
        w.flush()
    }

    /// Run `action` and write the line it returns as one atomic step:
    /// the writer lock is held across both, so a worker that picks a
    /// just-queued job up instantly still cannot emit its first row
    /// ahead of the `accepted` line.
    pub fn send_atomically(&self, action: impl FnOnce() -> Json) -> io::Result<()> {
        crate::util::faults::check_io("serve::respond")?;
        let mut w = self.lock();
        let j = action();
        writeln!(w, "{}", j.to_string_compact())?;
        w.flush()
    }
}

/// `{"event":"accepted","id":ID}` — the request is queued.
pub fn accepted(id: &str) -> Json {
    Json::obj(vec![("event", Json::str("accepted")), ("id", Json::str(id))])
}

/// `{"error":REASON,"event":"rejected","id":ID}` — backpressure.
pub fn rejected(id: &str, reason: &str) -> Json {
    Json::obj(vec![
        ("error", Json::str(reason)),
        ("event", Json::str("rejected")),
        ("id", Json::str(id)),
    ])
}

/// `{"data":ROW,"event":"row","id":ID}` — one streamed journal row.
pub fn row(id: &str, data: Json) -> Json {
    Json::obj(vec![
        ("data", data),
        ("event", Json::str("row")),
        ("id", Json::str(id)),
    ])
}

/// `{"data":PAYLOAD,"event":"done","id":ID}` — terminal success.
pub fn done(id: &str, data: Json) -> Json {
    Json::obj(vec![
        ("data", data),
        ("event", Json::str("done")),
        ("id", Json::str(id)),
    ])
}

/// `{"error":MSG,"event":"error","id":ID}` — terminal failure.
pub fn error_event(id: &str, msg: &str) -> Json {
    Json::obj(vec![
        ("error", Json::str(msg)),
        ("event", Json::str("error")),
        ("id", Json::str(id)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tune_line_parses_with_defaults() {
        let (id, req) = parse_line(r#"{"cmd":"tune","id":"a","space":"tiny"}"#);
        assert_eq!(id, "a");
        match req.unwrap() {
            Request::Tune(t) => {
                assert_eq!(t.strategy, "exhaustive");
                assert_eq!(t.seed, 0);
                assert_eq!(t.budget, 0);
                assert_eq!(t.parallel, 1);
                assert!(t.retry_failed);
                assert!(t.trace_cache);
                assert!(!t.prune);
                assert!(t.shard.is_none());
                assert!(!t.stream);
                assert!(t.out.is_none());
                assert!(t.profile.is_none());
                let reg = crate::layout::registry::global();
                assert_eq!(
                    t.space.enumerate(&reg).unwrap().len(),
                    8,
                    "tiny space is 8 points"
                );
            }
            _ => panic!("expected tune"),
        }
    }

    #[test]
    fn tune_shard_and_prune_parse_and_validate() {
        let (_, req) = parse_line(
            r#"{"cmd":"tune","id":"s","space":"tiny","prune":true,"shard":"1/4"}"#,
        );
        match req.unwrap() {
            Request::Tune(t) => {
                assert!(t.prune);
                assert_eq!(t.shard, Some((1, 4)));
            }
            _ => panic!("expected tune"),
        }
        // malformed specs are rejected with the field name in the error
        for bad in [r#""shard":"4""#, r#""shard":"4/4""#, r#""shard":"0/0""#, r#""shard":"a/b""#] {
            let line = format!(r#"{{"cmd":"tune","id":"s","space":"tiny",{bad}}}"#);
            let (_, req) = parse_line(&line);
            assert!(
                req.unwrap_err().to_string().contains("shard"),
                "{bad} should fail mentioning shard"
            );
        }
    }

    #[test]
    fn inline_space_objects_parse() {
        let (_, req) = parse_line(
            r#"{"cmd":"tune","id":"x","space":{"workloads":["jacobi2d5p"],"quick":true,"tiles":[[8,8,8]]}}"#,
        );
        match req.unwrap() {
            Request::Tune(t) => {
                let reg = crate::layout::registry::global();
                assert!(!t.space.enumerate(&reg).unwrap().is_empty());
            }
            _ => panic!("expected tune"),
        }
    }

    #[test]
    fn bad_lines_keep_their_id_when_json_parses() {
        // not JSON at all: empty id
        let (id, req) = parse_line("this is not json");
        assert_eq!(id, "");
        assert!(req.is_err());
        // JSON but bad cmd: id survives into the error path
        let (id, req) = parse_line(r#"{"cmd":"frobnicate","id":"k7"}"#);
        assert_eq!(id, "k7");
        assert!(req.unwrap_err().to_string().contains("unknown cmd"));
        // tune without a space names the missing field
        let (id, req) = parse_line(r#"{"cmd":"tune","id":"k8"}"#);
        assert_eq!(id, "k8");
        assert!(req.unwrap_err().to_string().contains("space"));
    }

    #[test]
    fn run_request_validates_mode_and_striping() {
        let (_, req) = parse_line(
            r#"{"cmd":"run","id":"r","workload":"jacobi2d5p","tile":[8,8,8],"mode":"sweep","channels":4,"striping":"facet"}"#,
        );
        match req.unwrap() {
            Request::Run(r) => {
                assert_eq!(r.mode, "sweep");
                assert_eq!(r.channels, 4);
                assert_eq!(r.tile, vec![8, 8, 8]);
                assert!(r.striping.is_some());
                assert!(r.profile.is_none());
            }
            _ => panic!("expected run"),
        }
        let (_, req) = parse_line(
            r#"{"cmd":"run","id":"r","workload":"jacobi2d5p","tile":[8,8,8],"mode":"data"}"#,
        );
        assert!(req.unwrap_err().to_string().contains("mode"));
    }

    #[test]
    fn response_lines_are_sorted_key_compact_json() {
        // pinned byte-for-byte: CI greps these exact substrings
        assert_eq!(
            done("a", Json::Bool(true)).to_string_compact(),
            r#"{"data":true,"event":"done","id":"a"}"#
        );
        assert_eq!(
            error_event("b", "boom").to_string_compact(),
            r#"{"error":"boom","event":"error","id":"b"}"#
        );
        assert_eq!(
            accepted("c").to_string_compact(),
            r#"{"event":"accepted","id":"c"}"#
        );
    }

    #[test]
    fn reply_interleaves_whole_lines() {
        let buf: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        let reply = Reply::new(buf.clone() as Arc<Mutex<dyn Write + Send>>);
        let mut handles = Vec::new();
        for i in 0..8 {
            let r = reply.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    r.send(&accepted(&format!("t{i}"))).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 8 * 50);
        for line in lines {
            let j = json::parse(line).expect("every line is whole JSON");
            assert_eq!(j.get("event").and_then(Json::as_str), Some("accepted"));
        }
    }
}
