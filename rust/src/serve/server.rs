//! The daemon itself: shared state, connection loops, request execution.
//!
//! Ownership diagram (one process, N connections, W workers):
//!
//! ```text
//!             TcpListener / stdin            ┌── worker 0 ──┐
//!   client ──► connection thread ──► queue ──┤   worker 1   ├─► Reply ─► client
//!               (parse, stats,     (bounded) └── worker W ──┘  (line-atomic,
//!                shutdown inline)                               per-connection)
//!                      │                            │
//!                      ▼                            ▼
//!               Arc<ServeState> ◄───────────────────┘
//!        registry · SessionCache · Batcher(TraceCache) · counters
//! ```
//!
//! Everything compiled is process-wide: the [`SessionCache`] (compiled
//! allocation + schedule per geometry) and the [`Batcher`]'s
//! [`TraceCache`](crate::memsim::TraceCache) outlive every request, so
//! tenant N+1 of a geometry pays zero compiles. Execution state is
//! per-request: each job runs under its own quarantine
//! ([`try_parallel_map`] with one item) and its connection's
//! [`CancelToken`].
//!
//! Shutdown matrix:
//!
//! * `shutdown` request → reply, stop reading, **drain** the pool
//!   (in-flight tunes finish; their journals complete).
//! * SIGINT / SIGTERM → drain **and cancel** every token (tunes stop
//!   cooperatively at the next point boundary; journals stay resumable).
//! * client disconnect (TCP EOF) → cancel that connection's token only.
//! * stdio EOF → drain without cancelling (a pipe's EOF is the end of
//!   the request script, not an abandoned client).

use crate::dse::{CancelToken, Exhaustive, Explorer, HillClimb, ModelGuided, RandomSearch, Strategy};
use crate::experiment::{ExperimentSpec, Mode, Session, SessionCache};
use crate::harness::workloads;
use crate::layout::{Allocation as _, LayoutRegistry};
use crate::memsim::TraceProvider;
use crate::poly::deps::DepPattern;
use crate::poly::tiling::Tiling;
use crate::serve::batcher::Batcher;
use crate::serve::protocol::{self, parse_line, Reply, Request, RunRequest, TuneRequest};
use crate::serve::queue::{Job, WorkerPool};
use crate::obs::metrics::{Counter, Gauge, Histogram};
use crate::util::json::Json;
use crate::util::par::try_parallel_map;
use crate::util::{faults, signals};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{self, BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

/// Process-wide shared state: the compiled-state caches every tenant
/// shares, plus the daemon's counters and shutdown machinery.
///
/// The counters are registry-backed handles ([`crate::obs::metrics`])
/// named `cfa.serve.{requests,rejected,errors}` (counters),
/// `cfa.serve.active` (gauge), and `cfa.serve.request_micros`
/// (histogram); the `stats` reply reads the same handles the registry
/// snapshot sums.
pub struct ServeState {
    registry: LayoutRegistry,
    sessions: Arc<SessionCache>,
    traces: Arc<Batcher>,
    requests: Counter,
    rejected: Counter,
    errors: Counter,
    active: Gauge,
    request_micros: Histogram,
    shutdown: AtomicBool,
    tokens: Mutex<Vec<CancelToken>>,
}

impl ServeState {
    fn new() -> ServeState {
        let m = crate::obs::registry();
        ServeState {
            registry: crate::layout::registry::global(),
            sessions: Arc::new(SessionCache::new()),
            traces: Arc::new(Batcher::new()),
            requests: m.counter("cfa.serve.requests"),
            rejected: m.counter("cfa.serve.rejected"),
            errors: m.counter("cfa.serve.errors"),
            active: m.gauge("cfa.serve.active"),
            request_micros: m.histogram("cfa.serve.request_micros"),
            shutdown: AtomicBool::new(false),
            tokens: Mutex::new(Vec::new()),
        }
    }

    /// The shared session cache (tests read its counters).
    pub fn sessions(&self) -> &Arc<SessionCache> {
        &self.sessions
    }

    /// The shared single-flight trace provider (tests read its counters).
    pub fn traces(&self) -> &Arc<Batcher> {
        &self.traces
    }

    /// Request lines seen (including malformed ones).
    pub fn requests(&self) -> u64 {
        self.requests.get()
    }

    /// Requests bounced by backpressure.
    pub fn rejected(&self) -> u64 {
        self.rejected.get()
    }

    /// Requests that ended in an `error` reply.
    pub fn errors(&self) -> u64 {
        self.errors.get()
    }

    /// Jobs currently executing on workers.
    pub fn active(&self) -> u64 {
        self.active.get()
    }

    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Cancel every connection's token (signal-driven drain).
    pub fn cancel_all(&self) {
        let tokens = self.tokens.lock().unwrap_or_else(PoisonError::into_inner);
        for t in tokens.iter() {
            t.cancel();
        }
    }

    fn register_token(&self) -> CancelToken {
        let token = CancelToken::new();
        self.tokens
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(token.clone());
        token
    }

    /// The `stats` payload: daemon counters plus every shared cache's.
    pub fn stats_json(&self) -> Json {
        let (rebases, fresh) = self.sessions.plan_counters();
        Json::obj(vec![
            ("active", Json::num(self.active() as f64)),
            ("errors", Json::num(self.errors() as f64)),
            (
                "plans",
                Json::obj(vec![
                    ("fresh", Json::num(fresh as f64)),
                    ("rebase_hits", Json::num(rebases as f64)),
                ]),
            ),
            ("rejected", Json::num(self.rejected() as f64)),
            ("requests", Json::num(self.requests() as f64)),
            ("sessions", self.sessions.stats().to_json()),
            ("traces", self.traces.stats().to_json()),
        ])
    }
}

/// The daemon: shared state plus the worker pool. The pool sits behind
/// `Mutex<Option<..>>` so [`Server::shutdown_and_join`] can drain it
/// through `&self` while detached connection threads still hold the
/// `Arc<Server>`.
pub struct Server {
    state: Arc<ServeState>,
    pool: Mutex<Option<WorkerPool>>,
}

impl Server {
    pub fn new(workers: usize, depth: usize) -> Server {
        let state = Arc::new(ServeState::new());
        let st = state.clone();
        let pool = WorkerPool::new(workers, depth, move |job| run_job(&st, job));
        Server {
            state,
            pool: Mutex::new(Some(pool)),
        }
    }

    pub fn state(&self) -> &Arc<ServeState> {
        &self.state
    }

    fn submit(&self, job: Job) -> std::result::Result<(), Job> {
        match &*self.pool.lock().unwrap_or_else(PoisonError::into_inner) {
            Some(p) => p.submit(job),
            None => Err(job),
        }
    }

    /// Serve one client: read request lines until EOF, error, or a
    /// `shutdown` request. `stats`/`shutdown` are answered inline;
    /// `run`/`tune`/`plan` go through the pool. A malformed or panicking
    /// line costs an `error` reply, never the loop. `cancel_on_eof`
    /// decides what an input EOF means: an abandoned tenant (TCP — cancel
    /// its in-flight work) or the end of a request script (stdio — let
    /// queued work drain).
    pub fn serve_connection<R: BufRead>(
        &self,
        mut reader: R,
        writer: Arc<Mutex<dyn Write + Send>>,
        cancel_on_eof: bool,
    ) {
        let reply = Reply::new(writer);
        let token = self.state.register_token();
        let mut graceful = false;
        let mut line = String::new();
        loop {
            if self.state.shutdown_requested() {
                graceful = true;
                break;
            }
            line.clear();
            match reader.read_line(&mut line) {
                Err(_) | Ok(0) => break,
                Ok(_) => {}
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            self.state.requests.inc();
            // parse under quarantine: a panic (incl. CFA_FAULTS at
            // serve::parse) errors this line only
            let parsed = {
                let _span = crate::obs::span("serve::parse");
                try_parallel_map(std::slice::from_ref(&trimmed), 1, |l: &&str| {
                    faults::check("serve::parse");
                    parse_line(l)
                })
                .pop()
                .expect("one item in, one result out")
            };
            let (id, req) = match parsed {
                Err(p) => {
                    self.state.errors.inc();
                    let _ = reply.send(&protocol::error_event("", &p.message()));
                    continue;
                }
                Ok((id, Err(e))) => {
                    self.state.errors.inc();
                    let _ = reply.send(&protocol::error_event(&id, &format!("{e:#}")));
                    continue;
                }
                Ok((id, Ok(req))) => (id, req),
            };
            match req {
                Request::Stats => {
                    let _ = reply.send(&protocol::done(&id, self.state.stats_json()));
                }
                Request::Shutdown => {
                    let _ = reply.send(&protocol::done(
                        &id,
                        Json::obj(vec![("shutting_down", Json::Bool(true))]),
                    ));
                    self.state.request_shutdown();
                    graceful = true;
                    break;
                }
                req => {
                    let _span = crate::obs::span("serve::enqueue");
                    // the enqueue fault site, quarantined the same way
                    let fault = try_parallel_map(&[()], 1, |_: &()| {
                        faults::check("serve::enqueue");
                    })
                    .pop()
                    .expect("one item in, one result out");
                    if let Err(p) = fault {
                        self.state.errors.inc();
                        let _ = reply.send(&protocol::error_event(&id, &p.message()));
                        continue;
                    }
                    let job = Job {
                        id: id.clone(),
                        req,
                        reply: reply.clone(),
                        cancel: token.clone(),
                    };
                    // accept/reject is written under the same writer lock
                    // as the submit, so a worker that grabs the job
                    // instantly still emits its rows after the accept
                    let _ = reply.send_atomically(|| match self.submit(job) {
                        Ok(()) => protocol::accepted(&id),
                        Err(_) => {
                            self.state.rejected.inc();
                            protocol::rejected(&id, "queue full; resend when earlier requests finish")
                        }
                    });
                }
            }
        }
        if !graceful && cancel_on_eof {
            token.cancel();
        }
    }

    /// Stop accepting, drain the pool (queued + in-flight jobs run to
    /// completion), join the workers.
    pub fn shutdown_and_join(&self) {
        self.state.request_shutdown();
        let pool = self
            .pool
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .take();
        if let Some(p) = pool {
            p.join();
        }
    }
}

/// The request's server-side profile path, when the client asked for a
/// span trace of this job.
fn profile_path(req: &Request) -> Option<String> {
    match req {
        Request::Tune(t) => t.profile.clone(),
        Request::Run(r) => r.profile.clone(),
        _ => None,
    }
}

/// One worker iteration: execute under per-request quarantine, then send
/// the terminal reply. With a `profile` path on the request, the whole
/// execution runs under a span capture whose Chrome trace-event JSON is
/// written server-side (concurrent jobs profiling at once each see the
/// union window — advisory wall time, never journal input).
fn run_job(state: &Arc<ServeState>, job: Job) {
    let Job {
        id,
        req,
        reply,
        cancel,
    } = job;
    state.active.inc();
    let started = std::time::Instant::now();
    let capture = profile_path(&req).map(|p| (crate::obs::begin_capture(), p));
    let result = {
        let _span = crate::obs::span("serve::run");
        try_parallel_map(std::slice::from_ref(&req), 1, |r: &Request| {
            execute(state, &id, r, &reply, &cancel)
        })
        .pop()
        .expect("one item in, one result out")
    };
    let profile_err = capture.and_then(|(cap, path)| cap.export(&path).err().map(|e| (path, e)));
    let _span = crate::obs::span("serve::respond");
    match result {
        Ok(Ok(data)) => match profile_err {
            None => {
                let _ = reply.send(&protocol::done(&id, data));
            }
            Some((path, e)) => {
                state.errors.inc();
                let _ = reply.send(&protocol::error_event(
                    &id,
                    &format!("writing profile '{path}': {e}"),
                ));
            }
        },
        Ok(Err(e)) => {
            state.errors.inc();
            let _ = reply.send(&protocol::error_event(&id, &format!("{e:#}")));
        }
        Err(p) => {
            state.errors.inc();
            let _ = reply.send(&protocol::error_event(&id, &p.message()));
        }
    }
    state.request_micros.record(started.elapsed().as_micros() as u64);
    state.active.dec();
}

fn execute(
    state: &Arc<ServeState>,
    id: &str,
    req: &Request,
    reply: &Reply,
    cancel: &CancelToken,
) -> Result<Json> {
    match req {
        Request::Tune(t) => execute_tune(state, id, t, reply, cancel),
        Request::Run(r) => execute_run(state, r),
        Request::Plan(p) => execute_plan(state, p),
        // handled inline on the connection thread; answered here too so
        // a future dispatch change cannot drop them silently
        Request::Stats | Request::Shutdown => Ok(state.stats_json()),
    }
}

fn make_strategy(name: &str, seed: u64) -> Result<Box<dyn Strategy>> {
    Ok(match name {
        "exhaustive" => Box::new(Exhaustive::new()),
        "random" => Box::new(RandomSearch::new(seed)),
        "hill" | "hillclimb" => Box::new(HillClimb::new(seed)),
        "model-guided" | "model" => Box::new(ModelGuided::new(seed)),
        s => bail!("unknown strategy '{s}' (exhaustive | random | hill | model-guided)"),
    })
}

/// A tune request is exactly a `cfa tune` run wired into the shared
/// caches: the explorer gets the daemon's [`Batcher`] as its trace
/// provider and the process-wide [`SessionCache`], so its journal bytes
/// are identical to a standalone run while its compiles are shared.
fn execute_tune(
    state: &Arc<ServeState>,
    id: &str,
    t: &TuneRequest,
    reply: &Reply,
    cancel: &CancelToken,
) -> Result<Json> {
    let strategy = make_strategy(&t.strategy, t.seed)?;
    let mut ex = Explorer::new(t.space.clone(), strategy)
        .registry(state.registry.clone())
        .parallel(t.parallel)
        .retry_failed(t.retry_failed)
        .prune(t.prune)
        .cancel_token(cancel.clone());
    if let Some((i, n)) = t.shard {
        ex = ex.shard(i, n);
    }
    if t.trace_cache {
        ex = ex
            .trace_provider(state.traces.clone() as Arc<dyn TraceProvider>)
            .session_cache(state.sessions.clone());
    } else {
        ex = ex.trace_cache(false);
    }
    if let Some(out) = &t.out {
        ex = ex.journal(out);
    }
    if let Some(resume) = &t.resume {
        ex = ex.resume(resume);
    }
    if t.budget > 0 {
        ex = ex.budget(t.budget);
    }
    if t.deadline_secs > 0 {
        ex = ex.deadline_secs(t.deadline_secs);
    }
    if t.stream {
        let reply = reply.clone();
        let id = id.to_string();
        ex = ex.on_evaluation(move |e| {
            let _ = reply.send(&protocol::row(&id, e.to_json()));
        });
    }
    let out = ex.explore()?;
    Ok(Json::obj(vec![
        ("evaluated", Json::num(out.evaluated as f64)),
        ("failed", Json::num(out.failed as f64)),
        (
            "front",
            Json::arr(out.front.iter().map(|e| Json::str(e.fingerprint()))),
        ),
        ("interrupted", Json::Bool(out.interrupted)),
        ("points_total", Json::num(out.points_total as f64)),
        ("pruned", Json::num(out.pruned as f64)),
        ("resumed", Json::num(out.resumed as f64)),
        ("sharded_out", Json::num(out.sharded_out as f64)),
        ("summary", Json::str(out.summary())),
        (
            "trace_cache",
            match &out.trace_cache {
                Some(cs) => cs.to_json(),
                None => Json::Null,
            },
        ),
    ]))
}

fn execute_run(state: &Arc<ServeState>, r: &RunRequest) -> Result<Json> {
    let mut b = ExperimentSpec::builder()
        .named(&r.workload, r.tile.clone(), r.tiles_per_dim)
        .layout(&r.layout)
        .threads(r.threads)
        .channels(r.channels);
    if let Some(s) = &r.striping {
        b = b.striping(s.clone());
    }
    let spec = b.spec()?;
    // through the shared cache: a repeat geometry reuses the compiled core
    let session = Session::compile_with_cache(spec, &state.registry, &state.sessions)?;
    let mode = if r.mode == "sweep" {
        Mode::Sweep
    } else {
        Mode::Timing
    };
    let report = session.run(mode)?;
    Ok(Json::obj(vec![
        ("report", report.to_json()),
        ("summary", Json::str(report.summary())),
    ]))
}

fn execute_plan(state: &Arc<ServeState>, p: &crate::serve::protocol::PlanRequest) -> Result<Json> {
    let w = workloads::by_name(&p.workload)
        .ok_or_else(|| anyhow!("unknown benchmark '{}' (see `cfa list`)", p.workload))?;
    if p.tile.len() != w.dims {
        bail!(
            "tile {:?} has {} dims but '{}' is {}-d",
            p.tile,
            p.tile.len(),
            p.workload,
            w.dims
        );
    }
    let deps = DepPattern::new(w.deps.clone())?;
    let tiling = Tiling::new(w.space_for(&p.tile, p.tiles_per_dim), p.tile.clone());
    let alloc = state.registry.build(&p.layout, &tiling, &deps)?;
    let counts = tiling.tile_counts();
    let mid: Vec<i64> = counts.iter().map(|&c| (c - 1).min(1)).collect();
    let plan = alloc.plan(&mid);
    Ok(Json::obj(vec![
        ("arrays", Json::num(alloc.num_arrays() as f64)),
        ("footprint_elems", Json::num(alloc.footprint() as f64)),
        ("layout", Json::str(alloc.name())),
        ("read_bursts", Json::num(plan.read_runs.len() as f64)),
        ("read_raw", Json::num(plan.read_raw() as f64)),
        ("read_useful", Json::num(plan.read_useful as f64)),
        ("write_bursts", Json::num(plan.write_runs.len() as f64)),
        ("write_raw", Json::num(plan.write_raw() as f64)),
        ("write_useful", Json::num(plan.write_useful as f64)),
    ]))
}

/// On SIGINT/SIGTERM: stop accepting, cancel every tenant, give
/// in-flight requests a bounded window to land their (resumable)
/// journals, then exit — even if a connection thread is still parked in
/// a blocking read.
fn spawn_signal_monitor(state: Arc<ServeState>) {
    signals::install();
    std::thread::spawn(move || loop {
        if signals::triggered() {
            state.request_shutdown();
            state.cancel_all();
            let deadline = std::time::Instant::now() + Duration::from_secs(10);
            while state.active() > 0 && std::time::Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(25));
            }
            std::process::exit(130);
        }
        std::thread::sleep(Duration::from_millis(50));
    });
}

/// `cfa serve --stdio`: one connection over stdin/stdout, then drain.
/// This is the tests/CI transport — a fixed request script piped in, the
/// response lines on stdout.
pub fn serve_stdio(workers: usize, depth: usize) -> Result<()> {
    let server = Server::new(workers, depth);
    spawn_signal_monitor(server.state.clone());
    let stdin = io::stdin();
    let writer: Arc<Mutex<dyn Write + Send>> = Arc::new(Mutex::new(io::stdout()));
    server.serve_connection(stdin.lock(), writer, false);
    server.shutdown_and_join();
    Ok(())
}

/// `cfa serve --addr HOST:PORT`: accept loop with one thread per
/// connection. The listener polls non-blocking so it can notice shutdown
/// (a client's `shutdown` request or a signal) within ~25 ms.
pub fn serve_tcp(addr: &str, workers: usize, depth: usize) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener
        .set_nonblocking(true)
        .context("listener non-blocking mode")?;
    let server = Arc::new(Server::new(workers, depth));
    spawn_signal_monitor(server.state.clone());
    println!("cfa serve: listening on {addr} ({} workers)", {
        let pool = server.pool.lock().unwrap_or_else(PoisonError::into_inner);
        pool.as_ref().map(|p| p.workers()).unwrap_or(0)
    });
    loop {
        if server.state.shutdown_requested() || signals::triggered() {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let server = server.clone();
                // detached: a connection thread may sit in a blocking
                // read for the client's lifetime; workers are what get
                // joined, and process exit reaps the readers
                std::thread::spawn(move || {
                    let _ = stream.set_nonblocking(false);
                    let writer: Arc<Mutex<dyn Write + Send>> = match stream.try_clone() {
                        Ok(w) => Arc::new(Mutex::new(w)),
                        Err(_) => return,
                    };
                    let reader = BufReader::new(stream);
                    // a dropped socket is an abandoned tenant
                    server.serve_connection(reader, writer, true);
                });
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => return Err(e).context("accepting a connection"),
        }
    }
    if signals::triggered() {
        server.state.cancel_all();
    }
    server.shutdown_and_join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn sink() -> (Arc<Mutex<Vec<u8>>>, Arc<Mutex<dyn Write + Send>>) {
        let buf: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        (buf.clone(), buf as Arc<Mutex<dyn Write + Send>>)
    }

    fn lines(buf: &Arc<Mutex<Vec<u8>>>) -> Vec<Json> {
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        text.lines()
            .map(|l| crate::util::json::parse(l).expect("reply lines are JSON"))
            .collect()
    }

    fn event_of<'a>(replies: &'a [Json], id: &str, event: &str) -> Option<&'a Json> {
        replies.iter().find(|j| {
            j.get("id").and_then(Json::as_str) == Some(id)
                && j.get("event").and_then(Json::as_str) == Some(event)
        })
    }

    #[test]
    fn malformed_lines_error_without_killing_the_connection() {
        let server = Server::new(2, 8);
        let (buf, writer) = sink();
        let script = concat!(
            "not json at all\n",
            "{\"cmd\":\"frobnicate\",\"id\":\"bad\"}\n",
            "\n",
            "{\"cmd\":\"stats\",\"id\":\"s1\"}\n",
            "{\"cmd\":\"plan\",\"id\":\"p1\",\"workload\":\"jacobi2d5p\",\"tile\":[8,8,8]}\n",
            "{\"cmd\":\"shutdown\",\"id\":\"z\"}\n",
        );
        server.serve_connection(Cursor::new(script), writer, false);
        server.shutdown_and_join();
        let replies = lines(&buf);
        // both garbage lines errored, with the id preserved when extractable
        assert!(event_of(&replies, "", "error").is_some(), "non-JSON line");
        let bad = event_of(&replies, "bad", "error").expect("unknown cmd");
        assert!(bad
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("unknown cmd"));
        // ... and the connection kept serving everything after them
        assert!(event_of(&replies, "s1", "done").is_some(), "stats answered");
        assert!(event_of(&replies, "p1", "accepted").is_some());
        let plan = event_of(&replies, "p1", "done").expect("plan answered");
        let data = plan.get("data").unwrap();
        assert!(data.get("read_bursts").and_then(Json::as_f64).unwrap() > 0.0);
        let z = event_of(&replies, "z", "done").expect("shutdown acknowledged");
        assert_eq!(
            z.get("data").and_then(|d| d.get("shutting_down")),
            Some(&Json::Bool(true))
        );
        assert_eq!(server.state().errors(), 2);
    }

    #[test]
    fn run_request_executes_through_the_shared_session_cache() {
        let server = Server::new(1, 4);
        let (buf, writer) = sink();
        // same geometry twice: the second compile must be a cache hit
        let script = concat!(
            "{\"cmd\":\"run\",\"id\":\"r1\",\"workload\":\"jacobi2d5p\",\"tile\":[8,8,8],\"tiles_per_dim\":2}\n",
            "{\"cmd\":\"run\",\"id\":\"r2\",\"workload\":\"jacobi2d5p\",\"tile\":[8,8,8],\"tiles_per_dim\":2}\n",
            "{\"cmd\":\"shutdown\",\"id\":\"z\"}\n",
        );
        server.serve_connection(Cursor::new(script), writer, false);
        server.shutdown_and_join();
        let replies = lines(&buf);
        let r1 = event_of(&replies, "r1", "done").expect("first run");
        let r2 = event_of(&replies, "r2", "done").expect("second run");
        let cyc = |j: &Json| {
            j.get("data")
                .and_then(|d| d.get("report"))
                .and_then(|r| r.get("makespan_cycles"))
                .and_then(Json::as_f64)
        };
        assert_eq!(cyc(r1), cyc(r2), "shared core replays identically");
        assert_eq!(server.state().sessions().misses(), 1);
        assert_eq!(server.state().sessions().hits(), 1);
    }

    #[test]
    fn stats_payload_has_sorted_cache_sections() {
        let state = ServeState::new();
        let j = state.stats_json();
        let s = j.to_string_compact();
        // sorted keys pin the grep-able shape
        assert!(s.starts_with(r#"{"active":0,"errors":0,"plans":"#), "{s}");
        assert!(s.contains(r#""sessions":{"entries":0,"hits":0,"misses":0}"#));
        assert!(s.contains(r#""traces":{"entries":0,"hits":0,"misses":0}"#));
    }
}
