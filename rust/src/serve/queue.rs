//! Bounded worker pool with explicit backpressure.
//!
//! One `std::sync::mpsc::sync_channel` of depth `depth` feeds `workers`
//! threads that share the receiver behind a mutex (dispatch is handed
//! out one job at a time; execution is fully parallel). A full queue is
//! a *visible* condition — [`WorkerPool::submit`] hands the job back and
//! the connection loop turns it into a `rejected` reply — never a silent
//! unbounded backlog, which is what an `mpsc::channel` would give a
//! daemon under a misbehaving client.
//!
//! Shutdown is drain-by-disconnect: [`WorkerPool::join`] drops the
//! sender, workers keep pulling until the channel is both disconnected
//! *and* empty, so every accepted job still runs to completion (its
//! [`CancelToken`] decides whether "completion" means finishing or
//! cooperatively stopping with a resumable journal).

use crate::dse::CancelToken;
use crate::obs::metrics::Gauge;
use crate::serve::protocol::{Reply, Request};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;

/// One accepted request, carrying everything a worker needs to answer
/// the client directly: the reply writer and the tenant's cancel token.
pub struct Job {
    pub id: String,
    pub req: Request,
    pub reply: Reply,
    pub cancel: CancelToken,
}

/// The bounded pool. `run` is the job executor (the server's dispatch);
/// workers own nothing else. Queue occupancy is published as the
/// registry gauge `cfa.serve.queue_depth` (incremented on a successful
/// submit, decremented when a worker takes the job).
pub struct WorkerPool {
    tx: Option<SyncSender<Job>>,
    handles: Vec<JoinHandle<()>>,
    depth: Gauge,
}

impl WorkerPool {
    pub fn new<F>(workers: usize, depth: usize, run: F) -> WorkerPool
    where
        F: Fn(Job) + Send + Sync + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<Job>(depth.max(1));
        let rx: Arc<Mutex<Receiver<Job>>> = Arc::new(Mutex::new(rx));
        let run = Arc::new(run);
        let queue_depth = crate::obs::registry().gauge("cfa.serve.queue_depth");
        let handles = (0..workers.max(1))
            .map(|i| {
                let rx = rx.clone();
                let run = run.clone();
                let queue_depth = queue_depth.clone();
                std::thread::Builder::new()
                    .name(format!("cfa-serve-worker-{i}"))
                    .spawn(move || loop {
                        // take the receiver lock only to pull one job;
                        // blocking in recv while holding it is fine — the
                        // holder is by definition the only idle worker
                        // that could have gotten the next job anyway
                        let job = {
                            let guard = rx.lock().unwrap_or_else(PoisonError::into_inner);
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                queue_depth.dec();
                                run(job)
                            }
                            // disconnected AND drained: the pool is done
                            Err(_) => break,
                        }
                    })
                    .expect("spawn serve worker")
            })
            .collect();
        WorkerPool {
            tx: Some(tx),
            handles,
            depth: queue_depth,
        }
    }

    /// Try to queue a job. `Err(job)` means the queue is full (or the
    /// pool is already draining) — the caller owns the job again and
    /// replies `rejected`.
    pub fn submit(&self, job: Job) -> Result<(), Job> {
        match self.tx.as_ref() {
            None => Err(job),
            Some(tx) => match tx.try_send(job) {
                Ok(()) => {
                    self.depth.inc();
                    Ok(())
                }
                Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => Err(j),
            },
        }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Drain and stop: drop the sender, then join every worker. Queued
    /// jobs all execute before the workers see the disconnect.
    pub fn join(mut self) {
        self.tx = None;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn job(id: &str) -> Job {
        let sink: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        Job {
            id: id.to_string(),
            req: Request::Stats,
            reply: Reply::new(sink as Arc<Mutex<dyn Write + Send>>),
            cancel: CancelToken::new(),
        }
    }

    #[test]
    fn join_drains_every_accepted_job() {
        let ran = Arc::new(AtomicU64::new(0));
        let r = ran.clone();
        let pool = WorkerPool::new(2, 16, move |_job| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            r.fetch_add(1, Ordering::SeqCst);
        });
        for i in 0..10 {
            pool.submit(job(&format!("j{i}"))).map_err(|_| ()).unwrap();
        }
        // the depth gauge is registered while the pool is alive (other
        // pools in this binary may contribute cells to the same name)
        assert!(crate::obs::registry()
            .snapshot()
            .contains_key("cfa.serve.queue_depth"));
        let depth = pool.depth.clone();
        pool.join();
        assert_eq!(ran.load(Ordering::SeqCst), 10, "queued jobs ran before exit");
        assert_eq!(depth.get(), 0, "every queued job was taken off the gauge");
    }

    #[test]
    fn full_queue_hands_the_job_back() {
        // one worker parked on a slow job + depth 1 → the third submit
        // must bounce instead of queueing invisibly
        let gate = Arc::new(Mutex::new(()));
        let hold = gate.lock().unwrap();
        let g = gate.clone();
        let pool = WorkerPool::new(1, 1, move |_job| {
            let _wait = g.lock().unwrap_or_else(PoisonError::into_inner);
        });
        pool.submit(job("running")).map_err(|_| ()).unwrap();
        // the worker may not have picked the first job up yet; the queue
        // slot is full once two jobs are in flight
        let mut bounced = None;
        for i in 0..50 {
            match pool.submit(job(&format!("q{i}"))) {
                Ok(()) => std::thread::sleep(std::time::Duration::from_millis(2)),
                Err(j) => {
                    bounced = Some(j.id.clone());
                    break;
                }
            }
        }
        let bounced = bounced.expect("a submit must eventually bounce on a stuffed queue");
        assert!(bounced.starts_with('q'));
        drop(hold);
        pool.join();
    }
}
