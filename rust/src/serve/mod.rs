//! `cfa serve` — a persistent, multi-tenant autotuning service.
//!
//! One long-running daemon accepts concurrent `run` / `tune` / `plan` /
//! `stats` / `shutdown` requests over a line-delimited JSON protocol
//! (one compact-JSON object per line, each way) and executes them on a
//! bounded worker pool. The point of the daemon over one-shot `cfa tune`
//! processes is *shared compiled state*: every tenant's requests go
//! through one process-wide [`SessionCache`](crate::experiment::SessionCache)
//! (compiled allocation + schedule + plan cache per geometry) and one
//! process-wide [`TraceCache`](crate::memsim::TraceCache) fronted by a
//! same-geometry single-flight [`Batcher`] — so a geometry is compiled
//! once, ever, no matter how many tenants ask for it or how concurrently
//! they ask.
//!
//! Layering (everything here is std-only — `TcpListener`, threads, one
//! bounded `sync_channel`):
//!
//! * [`protocol`] — the wire grammar: request parsing and the atomic
//!   line-writer ([`Reply`]) every response goes through.
//! * [`batcher`] — the single-flight trace provider shared by tenants.
//! * [`queue`] — the bounded worker pool with explicit backpressure
//!   (`rejected` replies, never silent queueing).
//! * [`server`] — connection loops (TCP and `--stdio`), request
//!   execution, shared-state plumbing, graceful drain.
//!
//! Safety properties, in the same spirit as the explorer's quarantine:
//! a malformed line gets an `error` reply and the connection keeps
//! serving; a request that panics (including injected `CFA_FAULTS`
//! panics at `serve::parse` / `serve::enqueue` / `serve::respond`)
//! errors that request only; a client disconnect cancels that tenant's
//! work through its [`CancelToken`](crate::dse::CancelToken); SIGINT /
//! SIGTERM cancel every tenant cooperatively so journals stay
//! resumable. Tune journals written by the daemon are byte-identical to
//! the ones plain `cfa tune` writes, cache sharing or not.
//!
//! See `DESIGN.md` §"Tune-as-a-service" for the protocol grammar and
//! ownership diagram.

pub mod batcher;
pub mod protocol;
pub mod queue;
pub mod server;

pub use batcher::Batcher;
pub use protocol::{parse_line, PlanRequest, Reply, Request, RunRequest, TuneRequest};
pub use queue::{Job, WorkerPool};
pub use server::{serve_stdio, serve_tcp, ServeState, Server};
