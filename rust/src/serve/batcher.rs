//! Same-geometry request batching: a single-flight front for the shared
//! [`TraceCache`].
//!
//! The cache alone already deduplicates *storage* (first insert wins),
//! but under concurrency it does not deduplicate *work*: two tenants
//! hitting the same cold geometry at the same instant would both miss
//! and both compile, and the second compile is thrown away. The batcher
//! closes that window with per-key flights — the first requester of a
//! cold key becomes the leader and compiles through
//! [`TraceCache::get_or_compile`]; every concurrent requester of the
//! same key waits on the flight and then reads the cache (a guaranteed
//! hit, because the leader inserts before it lands the flight).
//!
//! The payoff is an exact accounting identity the serve tests lean on:
//! `misses == distinct geometries actually compiled`, no matter how many
//! tenants raced. Note the batcher never calls [`TraceCache::get`] —
//! that method counts a miss just for *peeking* at an absent key, which
//! would break the identity.
//!
//! A leader that panics mid-compile (injected faults at
//! `trace::compile`) lands its flight on unwind, so waiters wake, find
//! the cache still cold, and the first of them becomes the new leader —
//! a poisoned flight never wedges the key.

use crate::memsim::{CacheStats, TraceCache, TraceProvider, TxnTrace};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex, PoisonError};

/// One in-progress compile; waiters block on the condvar.
struct Flight {
    done: Mutex<bool>,
    cv: Condvar,
}

impl Flight {
    fn new() -> Flight {
        Flight {
            done: Mutex::new(false),
            cv: Condvar::new(),
        }
    }

    fn wait(&self) {
        let mut done = self.done.lock().unwrap_or_else(PoisonError::into_inner);
        while !*done {
            done = self
                .cv
                .wait(done)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    fn finish(&self) {
        *self.done.lock().unwrap_or_else(PoisonError::into_inner) = true;
        self.cv.notify_all();
    }
}

/// Single-flight [`TraceProvider`] wrapping one shared [`TraceCache`].
pub struct Batcher {
    cache: Arc<TraceCache>,
    inflight: Mutex<HashMap<String, Arc<Flight>>>,
}

/// Lands the leader's flight even when `compile` unwinds.
struct FlightGuard<'a> {
    batcher: &'a Batcher,
    key: &'a str,
    flight: Arc<Flight>,
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        self.batcher
            .inflight
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .remove(self.key);
        self.flight.finish();
    }
}

impl Batcher {
    pub fn new() -> Batcher {
        Batcher::with_cache(Arc::new(TraceCache::new()))
    }

    /// Wrap an existing cache (tests hand in a pre-warmed one).
    pub fn with_cache(cache: Arc<TraceCache>) -> Batcher {
        Batcher {
            cache,
            inflight: Mutex::new(HashMap::new()),
        }
    }

    /// The wrapped cache (its counters are the batcher's counters).
    pub fn cache(&self) -> &Arc<TraceCache> {
        &self.cache
    }

    /// Snapshot of the wrapped cache's hit/miss/entry counters. With
    /// single-flight in front, `misses` equals the number of distinct
    /// geometries actually compiled.
    pub fn stats(&self) -> crate::memsim::CacheStats {
        self.cache.stats()
    }

    /// Keys currently being compiled (observability; racy by nature).
    pub fn inflight_len(&self) -> usize {
        self.inflight
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }

    fn get_or_compile_impl(
        &self,
        key: &str,
        compile: &mut dyn FnMut() -> TxnTrace,
    ) -> Arc<TxnTrace> {
        loop {
            let flight = {
                let mut inflight = self
                    .inflight
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner);
                match inflight.get(key) {
                    Some(f) => f.clone(),
                    None => {
                        let f = Arc::new(Flight::new());
                        inflight.insert(key.to_string(), f.clone());
                        // leader: compile (or hit, after a prior leader
                        // landed) and release the flight either way
                        drop(inflight);
                        let guard = FlightGuard {
                            batcher: self,
                            key,
                            flight: f,
                        };
                        let trace = self.cache.get_or_compile(key, || compile());
                        drop(guard);
                        return trace;
                    }
                }
            };
            // follower: wait the leader out, then loop. The re-check
            // either finds no flight and becomes a (cache-hitting)
            // leader, or — if the old leader panicked cold — elects
            // exactly one new compiling leader.
            flight.wait();
        }
    }
}

impl Default for Batcher {
    fn default() -> Batcher {
        Batcher::new()
    }
}

impl TraceProvider for Batcher {
    fn get_or_compile_with(
        &self,
        key: &str,
        compile: &mut dyn FnMut() -> TxnTrace,
    ) -> Arc<TxnTrace> {
        self.get_or_compile_impl(key, compile)
    }

    fn stats(&self) -> CacheStats {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn trace_with_len(n: usize) -> TxnTrace {
        let mut t = TxnTrace::new();
        for i in 0..n {
            t.push(crate::memsim::Dir::Read, i as u64 * 64, 16);
        }
        t
    }

    #[test]
    fn racing_requesters_compile_once() {
        let batcher = Arc::new(Batcher::new());
        let compiles = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let b = batcher.clone();
            let c = compiles.clone();
            handles.push(std::thread::spawn(move || {
                let t = b.get_or_compile_with(
                    "geom-a",
                    &mut || {
                        c.fetch_add(1, Ordering::SeqCst);
                        // hold the flight open long enough that the other
                        // threads genuinely arrive while it is in progress
                        std::thread::sleep(std::time::Duration::from_millis(50));
                        trace_with_len(3)
                    },
                );
                t.len()
            }));
        }
        let lens: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(lens.iter().all(|&l| l == 3), "all tenants share one trace");
        assert_eq!(compiles.load(Ordering::SeqCst), 1, "exactly one compile");
        let s = batcher.stats();
        assert_eq!(s.misses, 1, "misses == compiles, even under a race");
        assert_eq!(s.hits, 7);
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn distinct_keys_do_not_serialize_on_each_other() {
        let batcher = Arc::new(Batcher::new());
        let mut handles = Vec::new();
        for k in 0..4 {
            let b = batcher.clone();
            handles.push(std::thread::spawn(move || {
                let key = format!("geom-{k}");
                b.get_or_compile_with(&key, &mut || trace_with_len(k + 1)).len()
            }));
        }
        let mut lens: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        lens.sort_unstable();
        assert_eq!(lens, vec![1, 2, 3, 4]);
        let s = batcher.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 4, 4));
        assert_eq!(batcher.inflight_len(), 0, "all flights landed");
    }

    #[test]
    fn leader_panic_elects_a_new_leader_instead_of_wedging() {
        let batcher = Arc::new(Batcher::new());
        let b = batcher.clone();
        let bomb = std::thread::spawn(move || {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                b.get_or_compile_with("geom-p", &mut || panic!("compile bomb"))
            }));
            assert!(result.is_err());
        });
        bomb.join().unwrap();
        // the flight landed on unwind; the key must be compilable again
        let t = batcher.get_or_compile_with("geom-p", &mut || trace_with_len(2));
        assert_eq!(t.len(), 2);
        assert_eq!(batcher.inflight_len(), 0);
    }
}
