//! The `experiment` subsystem: one typed front door for the whole stack.
//!
//! The paper's pipeline — choose a layout, plan burst runs per tile,
//! marshal, replay timing — is one conceptual flow, and this module
//! exposes it as one API instead of four disjoint entry points:
//!
//! 1. **Spec.** An [`ExperimentSpec`] names a workload
//!    ([`WorkloadSpec`]), a layout by registry name ([`LayoutSpec`] —
//!    resolved through the open [`LayoutRegistry`], so custom layouts are
//!    reachable by name), an execution shape ([`ExecSpec`]) and a memory
//!    interface ([`MemConfig`]). Build one with [`ExperimentSpec::builder`].
//! 2. **Session.** [`ExperimentSpec::compile`] resolves the spec once into
//!    a [`Session`] that owns the allocation, the tile [`Schedule`] and the
//!    plan-memoization state ([`PlanCacheState`]); compiling is where all
//!    name resolution and divisibility validation happens.
//! 3. **Run.** [`Session::run`] executes polymorphically over [`Mode`]:
//!    `Timing` (replay the session schedule through the memory simulator),
//!    `Data { seed }` (full data path: the synthetic kernel for offline
//!    workloads, the verified PJRT end-to-end drivers for
//!    [`WorkloadSpec::Stencil`] / [`WorkloadSpec::Sw3`]), or `Sweep` (the
//!    paper's memory-bound rig: flat lexicographic replay, Fig-15
//!    semantics). Every mode returns the same unified [`Report`] — a
//!    superset of the legacy `RunReport`/`BatchReport` with JSON
//!    serialization.
//!
//! The legacy free functions (`run_stencil`, `run_sw`, the
//! `measure_bandwidth`/`build_alloc` family) have been removed; every
//! driver, sweep, bench and test builds sessions. The [`crate::dse`]
//! explorer builds on sessions too, one per candidate point.
//!
//! ```no_run
//! use cfa::experiment::{ExperimentSpec, Mode, ScheduleKind};
//!
//! let session = ExperimentSpec::builder()
//!     .named("jacobi2d5p", vec![16, 16, 16], 3)
//!     .layout("cfa")
//!     .schedule(ScheduleKind::Wavefront)
//!     .threads(4)
//!     .compile()?;
//! let report = session.run(Mode::Timing)?;
//! println!("{}", report.summary());
//! # Ok::<(), anyhow::Error>(())
//! ```

mod e2e;

use crate::coordinator::batch::{self, BatchCoordinator, BatchReport, Schedule};
use crate::coordinator::reference::{sw3_deps, StencilKind};
use crate::coordinator::HostMemory;
use crate::harness::workloads;
use crate::layout::registry::{self, LayoutRegistry};
use crate::layout::{Allocation, PlanCache, PlanCacheState};
use crate::memsim::{MemConfig, MemSim, MultiPortSim, Striping, Timing, TxnTrace};
use crate::poly::deps::DepPattern;
use crate::poly::tiling::Tiling;
use crate::poly::vec::IVec;
use crate::runtime::Runtime;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::Arc;
use std::time::Instant;

/// What program the experiment runs.
#[derive(Clone, Debug)]
pub enum WorkloadSpec {
    /// A named registry workload (Table I via `harness::workloads::by_name`,
    /// plus `heat3d`) at one tile size, `tiles_per_dim` tiles per axis.
    Named {
        name: String,
        tile: IVec,
        tiles_per_dim: i64,
    },
    /// An explicit iteration space, tiling and dependence pattern.
    Custom {
        label: String,
        space: IVec,
        tile: IVec,
        deps: Vec<IVec>,
    },
    /// End-to-end stencil through the PJRT runtime (`Mode::Data`): the
    /// skew-normalized (steps, n + r·steps, m + r·steps) box, verified
    /// against the native reference. `tile` must match the artifact.
    Stencil {
        artifact: String,
        kind: StencilKind,
        tile: IVec,
        n: i64,
        m: i64,
        steps: i64,
    },
    /// End-to-end Smith-Waterman-3seq through the PJRT runtime.
    Sw3 {
        artifact: String,
        tile: IVec,
        ni: i64,
        nj: i64,
        nk: i64,
    },
}

impl WorkloadSpec {
    /// Report label (matches the legacy drivers' `benchmark` strings).
    pub fn label(&self) -> String {
        match self {
            WorkloadSpec::Named { name, .. } => name.clone(),
            WorkloadSpec::Custom { label, .. } => label.clone(),
            WorkloadSpec::Stencil { kind, n, m, steps, .. } => {
                format!("{kind:?}/{steps}x{n}x{m}").to_lowercase()
            }
            WorkloadSpec::Sw3 { ni, nj, nk, .. } => format!("sw3/{ni}x{nj}x{nk}"),
        }
    }

    /// True for the workloads whose data path runs on the PJRT runtime.
    pub fn is_e2e(&self) -> bool {
        matches!(self, WorkloadSpec::Stencil { .. } | WorkloadSpec::Sw3 { .. })
    }
}

/// Tile schedule shape for the session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    /// One lexicographic wave (timing/planning only — the Fig-15 rig).
    Flat,
    /// Exact dependence-depth wavefront (required for `Mode::Data`).
    Wavefront,
}

/// How the session executes: schedule shape, worker threads for the pure
/// plan/marshal phase, modeled compute parallelism, artifacts location.
#[derive(Clone, Debug)]
pub struct ExecSpec {
    pub schedule: ScheduleKind,
    /// Worker threads for burst planning / marshalling (1 = serial;
    /// timing and numerics are bit-identical for any count).
    pub threads: usize,
    /// Modeled compute parallelism (ops/cycle) for the exec stage.
    pub pe_ops_per_cycle: u64,
    /// Artifacts directory for the PJRT end-to-end workloads.
    pub artifacts_dir: String,
    /// Memory channels. 1 replays through the single-port [`MemSim`]
    /// exactly as before; >1 routes timing replays through a
    /// [`MultiPortSim`] of independent per-channel controllers.
    pub channels: usize,
    /// How element addresses interleave over channels (ignored when
    /// `channels == 1`).
    pub striping: Striping,
}

impl Default for ExecSpec {
    fn default() -> ExecSpec {
        ExecSpec {
            schedule: ScheduleKind::Wavefront,
            threads: 1,
            pe_ops_per_cycle: 64,
            artifacts_dir: "artifacts".to_string(),
            channels: 1,
            striping: Striping::default(),
        }
    }
}

/// Which layout to run with, by registry name (canonical or alias).
#[derive(Clone, Debug)]
pub struct LayoutSpec {
    pub name: String,
}

impl LayoutSpec {
    pub fn new(name: impl Into<String>) -> LayoutSpec {
        LayoutSpec { name: name.into() }
    }
}

/// A fully-specified experiment: workload × layout × execution × memory.
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    pub workload: WorkloadSpec,
    pub layout: LayoutSpec,
    pub exec: ExecSpec,
    pub mem: MemConfig,
}

impl ExperimentSpec {
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder::new()
    }

    /// Compile against the process-global layout registry.
    pub fn compile(self) -> Result<Session> {
        Session::compile_with(self, &registry::global())
    }

    /// Compile against an explicit registry (custom layouts without
    /// touching global state).
    pub fn compile_with(self, registry: &LayoutRegistry) -> Result<Session> {
        Session::compile_with(self, registry)
    }
}

/// Builder for [`ExperimentSpec`] (and, via [`compile`](Self::compile),
/// directly for [`Session`]).
#[derive(Clone, Debug, Default)]
pub struct ExperimentBuilder {
    workload: Option<WorkloadSpec>,
    layout: Option<String>,
    exec: ExecSpec,
    mem: Option<MemConfig>,
    registry: Option<LayoutRegistry>,
}

impl ExperimentBuilder {
    pub fn new() -> ExperimentBuilder {
        ExperimentBuilder::default()
    }

    /// Any workload, verbatim.
    pub fn workload(mut self, w: WorkloadSpec) -> Self {
        self.workload = Some(w);
        self
    }

    /// Named registry workload (`cfa list` names, plus `heat3d`).
    pub fn named(self, name: impl Into<String>, tile: IVec, tiles_per_dim: i64) -> Self {
        self.workload(WorkloadSpec::Named {
            name: name.into(),
            tile,
            tiles_per_dim,
        })
    }

    /// Explicit space/tile/dependence-pattern workload.
    pub fn custom(
        self,
        label: impl Into<String>,
        space: IVec,
        tile: IVec,
        deps: Vec<IVec>,
    ) -> Self {
        self.workload(WorkloadSpec::Custom {
            label: label.into(),
            space,
            tile,
            deps,
        })
    }

    /// End-to-end stencil workload (PJRT data path).
    pub fn stencil(
        self,
        artifact: impl Into<String>,
        kind: StencilKind,
        tile: IVec,
        n: i64,
        m: i64,
        steps: i64,
    ) -> Self {
        self.workload(WorkloadSpec::Stencil {
            artifact: artifact.into(),
            kind,
            tile,
            n,
            m,
            steps,
        })
    }

    /// End-to-end Smith-Waterman-3seq workload (PJRT data path).
    pub fn sw3(
        self,
        artifact: impl Into<String>,
        tile: IVec,
        ni: i64,
        nj: i64,
        nk: i64,
    ) -> Self {
        self.workload(WorkloadSpec::Sw3 {
            artifact: artifact.into(),
            tile,
            ni,
            nj,
            nk,
        })
    }

    /// Layout by registry name (canonical or alias). Default: `cfa`.
    pub fn layout(mut self, name: impl Into<String>) -> Self {
        self.layout = Some(name.into());
        self
    }

    pub fn schedule(mut self, kind: ScheduleKind) -> Self {
        self.exec.schedule = kind;
        self
    }

    pub fn threads(mut self, n: usize) -> Self {
        self.exec.threads = n.max(1);
        self
    }

    pub fn pe_ops_per_cycle(mut self, ops: u64) -> Self {
        self.exec.pe_ops_per_cycle = ops;
        self
    }

    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.exec.artifacts_dir = dir.into();
        self
    }

    /// Memory channels (>= 1; validated at compile).
    pub fn channels(mut self, n: usize) -> Self {
        self.exec.channels = n;
        self
    }

    /// Channel interleaving policy (only meaningful with `channels > 1`).
    pub fn striping(mut self, s: Striping) -> Self {
        self.exec.striping = s;
        self
    }

    pub fn mem(mut self, cfg: MemConfig) -> Self {
        self.mem = Some(cfg);
        self
    }

    /// Resolve layout names against this registry instead of the global
    /// one (lets tests and embedders use custom layouts without mutating
    /// process state).
    pub fn registry(mut self, registry: LayoutRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// The spec, unvalidated (validation happens at compile).
    pub fn spec(self) -> Result<ExperimentSpec> {
        Ok(ExperimentSpec {
            workload: self
                .workload
                .ok_or_else(|| anyhow!("experiment spec has no workload"))?,
            layout: LayoutSpec::new(self.layout.unwrap_or_else(|| registry::names::CFA.into())),
            exec: self.exec,
            mem: self.mem.unwrap_or_default(),
        })
    }

    /// Compile straight to a [`Session`].
    pub fn compile(self) -> Result<Session> {
        let registry = match self.registry.clone() {
            Some(r) => r,
            None => registry::global(),
        };
        let spec = self.spec()?;
        Session::compile_with(spec, &registry)
    }
}

/// Outcome of [`Session::run_trace_bounded`]: either a report
/// bit-identical to [`Session::run_trace`]'s, or an abort carrying the
/// monotone effective-bandwidth upper bound at the abort point.
#[derive(Clone, Debug)]
pub enum BoundedRun {
    Completed(Report),
    Pruned { bound_mb_s: f64 },
}

/// How to run a compiled session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Replay the session's schedule through the memory simulator
    /// (plan-only: no data is marshalled).
    Timing,
    /// Full data path. Offline workloads run the deterministic synthetic
    /// kernel (requires a wavefront schedule); `Stencil`/`Sw3` run the
    /// verified PJRT end-to-end drivers.
    Data { seed: u64 },
    /// The paper's memory-bound rig: every tile's bursts replayed
    /// back-to-back in lexicographic order (Fig-15 semantics), regardless
    /// of the session schedule.
    Sweep,
}

/// Unified outcome of any [`Session::run`] — superset of the legacy
/// `RunReport` (serial e2e drivers) and `BatchReport` (batched
/// coordinator), with JSON serialization for machine-readable records.
#[derive(Clone, Debug)]
pub struct Report {
    /// Workload label (e.g. `jacobi2d5p`, `jacobi5p/32x96x96`).
    pub benchmark: String,
    /// Canonical layout name (registry spelling).
    pub layout: String,
    /// Mode label: `timing` | `data` | `sweep`.
    pub mode: String,
    pub tiles: u64,
    pub waves: usize,
    /// Pipeline/replay makespan in bus cycles.
    pub makespan_cycles: u64,
    /// Cycles the memory port was busy moving data.
    pub mem_busy_cycles: u64,
    /// Raw / useful bytes moved.
    pub raw_bytes: u64,
    pub useful_bytes: u64,
    /// Total burst transactions issued.
    pub transactions: u64,
    /// Raw bandwidth over the makespan, MB/s.
    pub raw_mb_s: f64,
    /// Effective bandwidth over the makespan, MB/s (Fig-15 color).
    pub effective_mb_s: f64,
    /// Bus roofline of the memory config the run used, MB/s.
    pub peak_mb_s: f64,
    /// Full simulator counters, when the run replays through the memory
    /// simulator (`crate::memsim::MemSim`).
    pub timing: Option<Timing>,
    /// Verification error (end-to-end data runs only).
    pub max_abs_err: Option<f64>,
    /// Host wall time of the run, seconds.
    pub wall_secs: f64,
}

impl Report {
    /// Effective bandwidth as a percentage of the bus roofline.
    pub fn bus_pct(&self) -> f64 {
        if self.peak_mb_s == 0.0 {
            0.0
        } else {
            100.0 * self.effective_mb_s / self.peak_mb_s
        }
    }

    /// One-line human summary (same shape as the legacy `RunReport`).
    pub fn summary(&self) -> String {
        let err = match self.max_abs_err {
            Some(e) => format!(" err={e:.2e}"),
            None => String::new(),
        };
        format!(
            "{:<22} {:<9} {:<6} tiles={:<5} txns={:<6} raw={:>7.1} MB/s eff={:>7.1} MB/s ({:>5.1}% of bus){err}",
            self.benchmark,
            self.layout,
            self.mode,
            self.tiles,
            self.transactions,
            self.raw_mb_s,
            self.effective_mb_s,
            self.bus_pct(),
        )
    }

    /// Machine-readable record.
    pub fn to_json(&self) -> Json {
        let timing = match &self.timing {
            Some(t) => Json::obj(vec![
                ("cycles", Json::num(t.cycles as f64)),
                ("data_cycles", Json::num(t.data_cycles as f64)),
                ("axi_bursts", Json::num(t.axi_bursts as f64)),
                ("row_hits", Json::num(t.row_hits as f64)),
                ("row_misses", Json::num(t.row_misses as f64)),
                ("row_switches", Json::num(t.row_switches as f64)),
                ("turnarounds", Json::num(t.turnarounds as f64)),
            ]),
            None => Json::Null,
        };
        Json::obj(vec![
            ("benchmark", Json::str(self.benchmark.clone())),
            ("layout", Json::str(self.layout.clone())),
            ("mode", Json::str(self.mode.clone())),
            ("tiles", Json::num(self.tiles as f64)),
            ("waves", Json::num(self.waves as f64)),
            ("makespan_cycles", Json::num(self.makespan_cycles as f64)),
            ("mem_busy_cycles", Json::num(self.mem_busy_cycles as f64)),
            ("raw_bytes", Json::num(self.raw_bytes as f64)),
            ("useful_bytes", Json::num(self.useful_bytes as f64)),
            ("transactions", Json::num(self.transactions as f64)),
            ("raw_mb_s", Json::num(self.raw_mb_s)),
            ("effective_mb_s", Json::num(self.effective_mb_s)),
            ("peak_mb_s", Json::num(self.peak_mb_s)),
            (
                "max_abs_err",
                match self.max_abs_err {
                    Some(e) => Json::num(e),
                    None => Json::Null,
                },
            ),
            ("wall_secs", Json::num(self.wall_secs)),
            ("timing", timing),
        ])
    }

    /// Parse a record produced by [`Report::to_json`].
    pub fn from_json(j: &Json) -> Result<Report> {
        let text = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("report json: missing string '{k}'"))
        };
        let num = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("report json: missing number '{k}'"))
        };
        let timing = match j.get("timing") {
            None | Some(Json::Null) => None,
            Some(t) => {
                let f = |k: &str| -> Result<u64> {
                    t.get(k)
                        .and_then(Json::as_f64)
                        .map(|x| x as u64)
                        .ok_or_else(|| anyhow!("report json: missing timing '{k}'"))
                };
                Some(Timing {
                    cycles: f("cycles")?,
                    data_cycles: f("data_cycles")?,
                    axi_bursts: f("axi_bursts")?,
                    row_hits: f("row_hits")?,
                    row_misses: f("row_misses")?,
                    row_switches: f("row_switches")?,
                    turnarounds: f("turnarounds")?,
                })
            }
        };
        Ok(Report {
            benchmark: text("benchmark")?,
            layout: text("layout")?,
            mode: text("mode")?,
            tiles: num("tiles")? as u64,
            waves: num("waves")? as usize,
            makespan_cycles: num("makespan_cycles")? as u64,
            mem_busy_cycles: num("mem_busy_cycles")? as u64,
            raw_bytes: num("raw_bytes")? as u64,
            useful_bytes: num("useful_bytes")? as u64,
            transactions: num("transactions")? as u64,
            raw_mb_s: num("raw_mb_s")?,
            effective_mb_s: num("effective_mb_s")?,
            peak_mb_s: num("peak_mb_s")?,
            timing,
            max_abs_err: j.get("max_abs_err").and_then(Json::as_f64),
            wall_secs: num("wall_secs")?,
        })
    }
}

/// The compiled, immutable half of a [`Session`]: everything derived from
/// the *geometry* (workload × iteration space × tile × layout × schedule
/// kind) and nothing from the memory configuration or PE throughput. Built
/// once, then shared behind an `Arc` — two sessions that differ only in
/// `MemConfig`/channels/striping/PE can (and, through [`SessionCache`], do)
/// point at the same core, so one geometry pays the allocation build and
/// the canonical-plan derivation exactly once no matter how many tenants
/// ask for it.
pub struct SessionCore {
    benchmark: String,
    layout: String,
    tiling: Tiling,
    deps: DepPattern,
    alloc: Box<dyn Allocation>,
    schedule: Schedule,
    cache: PlanCacheState,
}

impl SessionCore {
    /// Build a core from already-resolved geometry inputs (the expensive
    /// step: allocation build + schedule construction + plan-cache
    /// fingerprinting).
    fn build(
        benchmark: String,
        tiling: Tiling,
        deps: DepPattern,
        entry: &crate::layout::LayoutEntry,
        schedule_kind: ScheduleKind,
    ) -> Result<SessionCore> {
        let alloc = entry.build(&tiling, &deps)?;
        let layout = entry.name().to_string();
        let schedule = match schedule_kind {
            ScheduleKind::Flat => Schedule::flat(&tiling),
            ScheduleKind::Wavefront => Schedule::wavefront(&tiling, &deps),
        };
        let cache = PlanCacheState::new(alloc.as_ref());
        Ok(SessionCore {
            benchmark,
            layout,
            tiling,
            deps,
            alloc,
            schedule,
            cache,
        })
    }

    /// The geometry fingerprint this core was built from (see
    /// [`Session::compile_trace`] for what it does and does not include).
    fn trace_geometry(&self, schedule_kind: ScheduleKind) -> String {
        format!(
            "{}|d{:?}|{}|s{:?}|t{:?}|{:?}",
            self.benchmark,
            self.deps.vecs(),
            self.layout,
            self.tiling.space,
            self.tiling.tile,
            schedule_kind
        )
    }

    /// The plan-memoization state (counter readout for `stats`).
    pub fn plan_cache_state(&self) -> &PlanCacheState {
        &self.cache
    }
}

/// A process-wide cache of compiled [`SessionCore`]s, keyed by geometry
/// fingerprint. The serve daemon owns one so concurrent tenants asking for
/// the same geometry share one allocation and one canonical plan; the
/// explorer can ride the same cache. Compilation runs outside the lock
/// (same policy as [`TraceCache`]: racing compiles build identical cores,
/// first insert wins), and a poisoned map is recovered by taking the inner
/// value — the map itself is never left mid-mutation by `HashMap` ops.
pub struct SessionCache {
    cores: std::sync::Mutex<std::collections::HashMap<String, Arc<SessionCore>>>,
    /// Registry-backed (`cfa.session_cache.{hits,misses}`); one cell per
    /// cache instance, summed by the process-wide registry snapshot.
    hits: crate::obs::metrics::Counter,
    misses: crate::obs::metrics::Counter,
}

impl Default for SessionCache {
    fn default() -> SessionCache {
        SessionCache::new()
    }
}

impl SessionCache {
    pub fn new() -> SessionCache {
        SessionCache {
            cores: std::sync::Mutex::new(std::collections::HashMap::new()),
            hits: crate::obs::registry().counter("cfa.session_cache.hits"),
            misses: crate::obs::registry().counter("cfa.session_cache.misses"),
        }
    }

    fn lock(
        &self,
    ) -> std::sync::MutexGuard<'_, std::collections::HashMap<String, Arc<SessionCore>>> {
        self.cores
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Cores served from the cache so far.
    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    /// Core compilations so far.
    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    /// Number of cached cores.
    pub fn len(&self) -> usize {
        self.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the hit/miss/entry counters.
    pub fn stats(&self) -> crate::memsim::CacheStats {
        crate::memsim::CacheStats {
            hits: self.hits(),
            misses: self.misses(),
            entries: self.len(),
        }
    }

    /// Summed plan-cache counters across every cached core:
    /// `(rebase_hits, fresh_plans)`.
    pub fn plan_counters(&self) -> (u64, u64) {
        self.lock().values().fold((0, 0), |(r, f), core| {
            (
                r + core.cache.rebase_hits(),
                f + core.cache.fresh_plans(),
            )
        })
    }
}

/// A compiled experiment: the allocation, schedule and plan cache built
/// once from an [`ExperimentSpec`], runnable any number of times. The
/// compiled state lives in an [`Arc<SessionCore>`], so cloning a session —
/// or compiling a second spec with the same geometry through a
/// [`SessionCache`] — shares it rather than rebuilding it.
#[derive(Clone)]
pub struct Session {
    spec: ExperimentSpec,
    core: Arc<SessionCore>,
}

impl Session {
    /// Validate the non-geometry half of `spec` (memory config, channels,
    /// striping) — runs on every compile, cached core or not.
    fn validate_spec(spec: &ExperimentSpec) -> Result<()> {
        spec.mem
            .validate()
            .context("experiment spec has an invalid memory configuration")?;
        if spec.exec.channels == 0 {
            bail!("experiment spec needs at least one memory channel (channels >= 1)");
        }
        spec.exec
            .striping
            .validate(spec.mem.elem_bytes)
            .context("experiment spec has an invalid striping")?;
        Ok(())
    }

    /// Resolve and validate `spec` against `registry`.
    pub fn compile_with(spec: ExperimentSpec, registry: &LayoutRegistry) -> Result<Session> {
        Session::validate_spec(&spec)?;
        let (benchmark, tiling, deps) = resolve_workload(&spec.workload)?;
        let entry = registry.resolve_or_err(&spec.layout.name)?;
        let core = SessionCore::build(benchmark, tiling, deps, entry, spec.exec.schedule)?;
        Ok(Session {
            spec,
            core: Arc::new(core),
        })
    }

    /// [`Session::compile_with`], sharing compiled cores through `cache`:
    /// a geometry seen before skips the allocation build entirely and the
    /// new session points at the cached core. Spec validation and workload
    /// resolution still run per call — a cache hit never launders an
    /// invalid spec.
    pub fn compile_with_cache(
        spec: ExperimentSpec,
        registry: &LayoutRegistry,
        cache: &SessionCache,
    ) -> Result<Session> {
        Session::validate_spec(&spec)?;
        let (benchmark, tiling, deps) = resolve_workload(&spec.workload)?;
        let entry = registry.resolve_or_err(&spec.layout.name)?;
        // key on the same fingerprint compiled traces carry; compute it
        // from the resolved inputs without building the allocation
        let key = format!(
            "{}|d{:?}|{}|s{:?}|t{:?}|{:?}",
            benchmark,
            deps.vecs(),
            entry.name(),
            tiling.space,
            tiling.tile,
            spec.exec.schedule
        );
        if let Some(core) = cache.lock().get(&key) {
            cache.hits.inc();
            return Ok(Session {
                spec,
                core: core.clone(),
            });
        }
        // compile outside the lock; identical racers are resolved by
        // first-insert-wins, so results do not depend on the race
        let built = Arc::new(SessionCore::build(
            benchmark,
            tiling,
            deps,
            entry,
            spec.exec.schedule,
        )?);
        cache.misses.inc();
        let core = cache.lock().entry(key).or_insert(built).clone();
        Ok(Session { spec, core })
    }

    /// The shared compiled core (tests assert sharing via `Arc::ptr_eq`).
    pub fn core(&self) -> &Arc<SessionCore> {
        &self.core
    }

    pub fn spec(&self) -> &ExperimentSpec {
        &self.spec
    }

    pub fn workload(&self) -> &WorkloadSpec {
        &self.spec.workload
    }

    /// Report label of the workload.
    pub fn benchmark(&self) -> &str {
        &self.core.benchmark
    }

    /// Canonical layout name.
    pub fn layout(&self) -> &str {
        &self.core.layout
    }

    pub fn tiling(&self) -> &Tiling {
        &self.core.tiling
    }

    pub fn deps(&self) -> &DepPattern {
        &self.core.deps
    }

    /// The allocation this session shares with its core.
    pub fn allocation(&self) -> &dyn Allocation {
        self.core.alloc.as_ref()
    }

    pub fn schedule(&self) -> &Schedule {
        &self.core.schedule
    }

    /// A plan-cache view over the core-owned memoization state (the
    /// canonical interior plan is derived once per core, however many
    /// sessions share it).
    pub fn cache(&self) -> PlanCache<'_> {
        PlanCache::with_state(self.core.alloc.as_ref(), &self.core.cache)
    }

    /// Compile this session's schedule into a flat, config-independent
    /// [`TxnTrace`] — the exact transaction stream `run(Mode::Timing)`
    /// submits, fed from the session-owned [`PlanCacheState`] so interior
    /// tiles rebase the canonical plan rather than re-deriving it. The
    /// trace depends only on the session's *geometry* (workload × space ×
    /// tile × layout × schedule), never on [`MemConfig`] or PE throughput,
    /// so sessions sharing a geometry can share one compiled trace (the
    /// `dse` trace cache does exactly this).
    pub fn compile_trace(&self) -> TxnTrace {
        let cache = self.cache();
        let mut trace = batch::compile_trace(&cache, &self.core.schedule, self.spec.exec.threads);
        trace.geometry = self.trace_geometry();
        trace
    }

    /// The geometry fingerprint stamped on compiled traces: everything the
    /// transaction stream depends on (workload label, dependence pattern,
    /// layout, iteration space, tile, schedule shape) and nothing it does
    /// not (`MemConfig`, PE throughput) — so sessions differing only in
    /// mem/PE accept each other's traces, and a trace from a different
    /// layout (or a same-named workload with different deps) is rejected.
    fn trace_geometry(&self) -> String {
        self.core.trace_geometry(self.spec.exec.schedule)
    }

    /// `Mode::Timing` over a pre-compiled trace: replay `trace` through the
    /// memory simulator's coalesced fast path and report exactly what
    /// `run(Mode::Timing)` would — same `Timing` counters, same cycles,
    /// same derived bandwidth, bit for bit. The trace must carry this
    /// session's geometry stamp ([`Session::compile_trace`] from a session
    /// that differs at most in `MemConfig`/PE): tile/wave counts alone
    /// cannot distinguish two layouts over the same tiling, and a foreign
    /// trace would replay silently wrong numbers.
    pub fn run_trace(&self, trace: &TxnTrace) -> Result<Report> {
        self.validate_trace(trace)?;
        let wall0 = Instant::now();
        let (rep, _) = self.replay_trace(trace, None)?;
        Ok(self.report_from_batch("timing", &rep, wall0.elapsed().as_secs_f64()))
    }

    /// Early-abort variant of [`Session::run_trace`]: before every trace
    /// entry, `dominated` is consulted with the current **monotone upper
    /// bound** on the final effective bandwidth (MB/s), derived from
    /// [`MemSim::min_final_cycles`] — the data bus moves at most one beat
    /// per cycle, so `final_cycles >= bus_free + remaining_beats` at every
    /// prefix, and dividing the (known) useful bytes by that lower bound
    /// gives a bandwidth figure the finished replay can never exceed.
    /// Returning `true` aborts the replay ([`BoundedRun::Pruned`] with the
    /// bound); a completed replay returns a report **bit-identical** to
    /// [`Session::run_trace`]'s. Multi-channel sessions have no bounded
    /// mode and always run to completion (identical results, never pruned).
    pub fn run_trace_bounded(
        &self,
        trace: &TxnTrace,
        dominated: &mut dyn FnMut(f64) -> bool,
    ) -> Result<BoundedRun> {
        self.validate_trace(trace)?;
        if self.spec.exec.channels > 1 {
            return Ok(BoundedRun::Completed(self.run_trace(trace)?));
        }
        let wall0 = Instant::now();
        let mem = &self.spec.mem;
        let useful_b = trace.useful_elems * mem.elem_bytes;
        let mut sim = MemSim::new(mem.clone());
        let mut last_bound = f64::INFINITY;
        let completed = sim.run_trace_bounded(trace, &mut |lb_cycles| {
            let bound = if lb_cycles == 0 {
                f64::INFINITY
            } else {
                useful_b as f64 / 1e6 / mem.secs(lb_cycles)
            };
            last_bound = bound;
            dominated(bound)
        });
        match completed {
            None => Ok(BoundedRun::Pruned {
                bound_mb_s: last_bound,
            }),
            Some(cycles) => {
                let rep = BatchReport {
                    tiles: trace.tiles,
                    waves: trace.waves,
                    cycles,
                    timing: sim.timing().clone(),
                    raw_elems: trace.raw_elems,
                    useful_elems: trace.useful_elems,
                    transactions: trace.transactions(),
                };
                Ok(BoundedRun::Completed(self.report_from_batch(
                    "timing",
                    &rep,
                    wall0.elapsed().as_secs_f64(),
                )))
            }
        }
    }

    /// [`Session::run_trace`] plus a cycle-domain bandwidth
    /// [`Timeline`](crate::obs::Timeline) sampled every `epoch_cycles`
    /// simulated cycles (one channel list per memory channel). The
    /// report is bit-identical to the unsampled [`Session::run_trace`]
    /// — sampling is passive — and the timeline's epoch sums equal the
    /// report's aggregate `Timing` counters exactly.
    pub fn run_trace_with_timeline(
        &self,
        trace: &TxnTrace,
        epoch_cycles: u64,
    ) -> Result<(Report, crate::obs::Timeline)> {
        self.validate_trace(trace)?;
        let wall0 = Instant::now();
        let (rep, tl) = self.replay_trace(trace, Some(epoch_cycles))?;
        let tl = tl.expect("a sampler was attached");
        anyhow::ensure!(
            tl.matches(&rep.timing),
            "timeline epochs do not sum to the aggregate Timing counters"
        );
        let report = self.report_from_batch("timing", &rep, wall0.elapsed().as_secs_f64());
        Ok((report, tl))
    }

    /// The geometry/shape guard shared by the trace-replay entry points.
    fn validate_trace(&self, trace: &TxnTrace) -> Result<()> {
        let expected = self.trace_geometry();
        if trace.geometry != expected {
            let got = if trace.geometry.is_empty() {
                "<unstamped>"
            } else {
                trace.geometry.as_str()
            };
            bail!("trace geometry mismatch: got '{got}', session expects '{expected}'");
        }
        if trace.tiles != self.core.schedule.num_tiles()
            || trace.waves != self.core.schedule.num_waves()
        {
            bail!(
                "trace shape mismatch: trace has {} tiles / {} waves, session schedule has {} / {}",
                trace.tiles,
                trace.waves,
                self.core.schedule.num_tiles(),
                self.core.schedule.num_waves()
            );
        }
        Ok(())
    }

    /// Replay a trace through the session's memory interface: the
    /// single-port [`MemSim`] when `channels == 1` (bit-identical to the
    /// pre-multichannel path), a [`MultiPortSim`] with the striping
    /// resolved against this session's allocation otherwise (one routing
    /// pass, then parallel per-channel replay).
    fn replay_trace(
        &self,
        trace: &TxnTrace,
        sample_epoch: Option<u64>,
    ) -> Result<(BatchReport, Option<crate::obs::Timeline>)> {
        let exec = &self.spec.exec;
        let (cycles, timing, timeline) = if exec.channels > 1 {
            let map = exec.striping.resolve(
                self.core.alloc.as_ref(),
                self.spec.mem.elem_bytes,
                exec.channels,
            )?;
            let mut mp = MultiPortSim::new(self.spec.mem.clone(), exec.channels, map);
            if let Some(epoch) = sample_epoch {
                mp.set_sampler(epoch);
            }
            mp.run_trace_parallel(trace, exec.threads);
            (mp.now(), mp.aggregate_timing(), mp.timeline())
        } else {
            let mut sim = MemSim::new(self.spec.mem.clone());
            if let Some(epoch) = sample_epoch {
                sim.set_sampler(epoch);
            }
            sim.run_trace(trace);
            let tl = sim.take_sampler().map(|s| crate::obs::Timeline {
                epoch_cycles: s.epoch_cycles(),
                channels: vec![s.into_epochs()],
            });
            (sim.now(), sim.timing().clone(), tl)
        };
        Ok((
            BatchReport {
                tiles: trace.tiles,
                waves: trace.waves,
                cycles,
                timing,
                raw_elems: trace.raw_elems,
                useful_elems: trace.useful_elems,
                transactions: trace.transactions(),
            },
            timeline,
        ))
    }

    /// Execute the session. End-to-end workloads in `Mode::Data` open the
    /// PJRT runtime from `exec.artifacts_dir`; use
    /// [`Session::run_with_runtime`] to reuse an already-open runtime.
    pub fn run(&self, mode: Mode) -> Result<Report> {
        match (&self.spec.workload, mode) {
            (w, Mode::Data { seed }) if w.is_e2e() => {
                let rt = Runtime::open(&self.spec.exec.artifacts_dir)?;
                self.run_with_runtime(&rt, Mode::Data { seed })
            }
            (_, mode) => self.run_offline(mode),
        }
    }

    /// [`Session::run`] against a caller-owned runtime (used by the CLI
    /// and the legacy driver shims, which open the runtime once).
    pub fn run_with_runtime(&self, rt: &Runtime, mode: Mode) -> Result<Report> {
        if self.spec.workload.is_e2e()
            && matches!(mode, Mode::Data { .. })
            && self.spec.exec.channels > 1
        {
            bail!(
                "Mode::Data drives the single-channel data path; a {}-channel session \
                 supports Mode::Timing and Mode::Sweep",
                self.spec.exec.channels
            );
        }
        match (&self.spec.workload, mode) {
            (WorkloadSpec::Stencil { .. }, Mode::Data { seed }) => e2e::run_stencil(self, rt, seed),
            (WorkloadSpec::Sw3 { .. }, Mode::Data { seed }) => e2e::run_sw3(self, rt, seed),
            (_, mode) => self.run_offline(mode),
        }
    }

    /// `Mode::Data` for offline workloads, returning the final host buffer
    /// alongside the report (the bit-identity tests compare buffers).
    /// End-to-end workloads are rejected: their data path is the verified
    /// PJRT driver ([`Session::run`] / [`Session::run_with_runtime`]), not
    /// the synthetic kernel, and silently substituting the latter would
    /// yield a report indistinguishable from a verified run.
    pub fn run_data_buffered(&self, seed: u64) -> Result<(Report, HostMemory)> {
        if self.spec.workload.is_e2e() {
            bail!(
                "run_data_buffered drives the offline synthetic kernel; run this \
                 end-to-end workload through Session::run(Mode::Data) instead"
            );
        }
        if self.spec.exec.channels > 1 {
            bail!(
                "Mode::Data drives the single-channel data path; a {}-channel session \
                 supports Mode::Timing and Mode::Sweep",
                self.spec.exec.channels
            );
        }
        if !self.core.schedule.is_dependence_safe() {
            bail!(
                "Mode::Data needs a dependence-respecting schedule: compile the session \
                 with ScheduleKind::Wavefront (ScheduleKind::Flat is timing-only)"
            );
        }
        let wall0 = Instant::now();
        let (rep, host) = self.coordinator(&self.core.schedule).run_data(seed);
        let report = self.report_from_batch("data", &rep, wall0.elapsed().as_secs_f64());
        Ok((report, host))
    }

    fn run_offline(&self, mode: Mode) -> Result<Report> {
        let wall0 = Instant::now();
        let multi = self.spec.exec.channels > 1;
        match mode {
            Mode::Timing if multi => {
                // multi-channel timing goes through the compiled trace —
                // the coordinator stays single-port and untouched
                let trace = self.compile_trace();
                let (rep, _) = self.replay_trace(&trace, None)?;
                Ok(self.report_from_batch("timing", &rep, wall0.elapsed().as_secs_f64()))
            }
            Mode::Timing => {
                let rep = self.coordinator(&self.core.schedule).run_timing();
                Ok(self.report_from_batch("timing", &rep, wall0.elapsed().as_secs_f64()))
            }
            Mode::Sweep if multi => {
                // flat replay order regardless of the session schedule
                let flat;
                let schedule = if self.spec.exec.schedule == ScheduleKind::Flat {
                    &self.core.schedule
                } else {
                    flat = Schedule::flat(&self.core.tiling);
                    &flat
                };
                let cache = self.cache();
                let trace = batch::compile_trace(&cache, schedule, self.spec.exec.threads);
                let (rep, _) = self.replay_trace(&trace, None)?;
                Ok(self.report_from_batch("sweep", &rep, wall0.elapsed().as_secs_f64()))
            }
            Mode::Sweep => {
                // the memory-bound rig always replays flat, back-to-back
                if self.spec.exec.schedule == ScheduleKind::Flat {
                    let rep = self.coordinator(&self.core.schedule).run_timing();
                    Ok(self.report_from_batch("sweep", &rep, wall0.elapsed().as_secs_f64()))
                } else {
                    let flat = Schedule::flat(&self.core.tiling);
                    let rep = self.coordinator(&flat).run_timing();
                    Ok(self.report_from_batch("sweep", &rep, wall0.elapsed().as_secs_f64()))
                }
            }
            Mode::Data { seed } => {
                let (report, _host) = self.run_data_buffered(seed)?;
                Ok(report)
            }
        }
    }

    fn coordinator<'a>(&'a self, schedule: &'a Schedule) -> BatchCoordinator<'a> {
        BatchCoordinator::new(self.core.alloc.as_ref(), schedule, self.spec.mem.clone())
            .threads(self.spec.exec.threads)
            .cache_state(&self.core.cache)
    }

    fn report_from_batch(
        &self,
        mode: &str,
        rep: &crate::coordinator::batch::BatchReport,
        wall_secs: f64,
    ) -> Report {
        let mem = &self.spec.mem;
        let secs = mem.secs(rep.cycles.max(1));
        let raw_bytes = rep.raw_elems * mem.elem_bytes;
        let useful_bytes = rep.useful_elems * mem.elem_bytes;
        Report {
            benchmark: self.core.benchmark.clone(),
            layout: self.core.layout.clone(),
            mode: mode.to_string(),
            tiles: rep.tiles,
            waves: rep.waves,
            makespan_cycles: rep.cycles,
            mem_busy_cycles: rep.timing.data_cycles,
            raw_bytes,
            useful_bytes,
            transactions: rep.transactions,
            raw_mb_s: raw_bytes as f64 / 1e6 / secs,
            effective_mb_s: useful_bytes as f64 / 1e6 / secs,
            // the roofline of the whole interface: one bus per channel
            peak_mb_s: mem.peak_mb_s() * self.spec.exec.channels.max(1) as f64,
            timing: Some(rep.timing.clone()),
            max_abs_err: None,
            wall_secs,
        }
    }
}

/// Resolve a workload spec into (report label, tiling, deps), validating
/// dimensions and divisibility — the checks the legacy drivers did at run
/// time now happen once at compile.
fn resolve_workload(w: &WorkloadSpec) -> Result<(String, Tiling, DepPattern)> {
    let label = w.label();
    match w {
        WorkloadSpec::Named {
            name,
            tile,
            tiles_per_dim,
        } => {
            let wl = workloads::by_name(name)
                .or_else(|| (name == "heat3d").then(workloads::heat3d))
                .ok_or_else(|| anyhow!("unknown workload '{name}' (see `cfa list`)"))?;
            if tile.len() != wl.dims {
                bail!(
                    "workload '{name}' is {}-d but the tile has {} dims",
                    wl.dims,
                    tile.len()
                );
            }
            let deps = DepPattern::new(wl.deps.clone()).context("building deps")?;
            let tiling = Tiling::new(wl.space_for(tile, *tiles_per_dim), tile.clone());
            Ok((label, tiling, deps))
        }
        WorkloadSpec::Custom {
            space, tile, deps, ..
        } => {
            if space.len() != tile.len() {
                bail!(
                    "space has {} dims but the tile has {}",
                    space.len(),
                    tile.len()
                );
            }
            let deps = DepPattern::new(deps.clone()).context("building deps")?;
            let tiling = Tiling::new(space.clone(), tile.clone());
            Ok((label, tiling, deps))
        }
        WorkloadSpec::Stencil {
            kind,
            tile,
            n,
            m,
            steps,
            ..
        } => {
            let [tt, ti, tj] = tile[..] else {
                bail!("stencil tile must be 3-d (tt, ti, tj), got {tile:?}");
            };
            let r = kind.radius();
            let (uu, vv) = (n + r * steps, m + r * steps);
            if steps % tt != 0 || uu % ti != 0 || vv % tj != 0 {
                bail!(
                    "tile ({tt},{ti},{tj}) must divide the skewed space ({steps},{uu},{vv}); \
                     pick n,m,steps accordingly"
                );
            }
            let deps = DepPattern::new(kind.skewed_deps()).context("building deps")?;
            let tiling = Tiling::new(vec![*steps, uu, vv], tile.clone());
            Ok((label, tiling, deps))
        }
        WorkloadSpec::Sw3 {
            tile, ni, nj, nk, ..
        } => {
            let [si, sj, sk] = tile[..] else {
                bail!("sw3 tile must be 3-d (si, sj, sk), got {tile:?}");
            };
            if ni % si != 0 || nj % sj != 0 || nk % sk != 0 {
                bail!("tile ({si},{sj},{sk}) must divide ({ni},{nj},{nk})");
            }
            let deps = DepPattern::new(sw3_deps()).context("building deps")?;
            let tiling = Tiling::new(vec![*ni, *nj, *nk], tile.clone());
            Ok((label, tiling, deps))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn quick_session(layout: &str) -> Session {
        ExperimentSpec::builder()
            .named("jacobi2d5p", vec![8, 8, 8], 3)
            .layout(layout)
            .schedule(ScheduleKind::Wavefront)
            .compile()
            .expect("compile")
    }

    #[test]
    fn builder_defaults_and_compile() {
        let s = quick_session("cfa");
        assert_eq!(s.benchmark(), "jacobi2d5p");
        assert_eq!(s.layout(), registry::names::CFA);
        assert_eq!(s.tiling().num_tiles(), 27);
        assert_eq!(s.schedule().num_tiles(), 27);
    }

    #[test]
    fn alias_resolves_to_canonical_layout() {
        let s = quick_session("bounding-box");
        assert_eq!(s.layout(), registry::names::BBOX);
    }

    #[test]
    fn session_cache_shares_cores_and_counts() {
        let reg = LayoutRegistry::with_builtins();
        let cache = SessionCache::new();
        let spec = || {
            ExperimentSpec::builder()
                .named("jacobi2d5p", vec![8, 8, 8], 3)
                .layout("cfa")
                .schedule(ScheduleKind::Wavefront)
                .spec()
                .expect("spec")
        };
        let a = Session::compile_with_cache(spec(), &reg, &cache).expect("compile a");
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (0, 1, 1));
        // same geometry, different memory interface: the core is shared
        let mut spec_b = spec();
        spec_b.exec.threads = 4;
        let b = Session::compile_with_cache(spec_b, &reg, &cache).expect("compile b");
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (1, 1, 1));
        assert!(Arc::ptr_eq(a.core(), b.core()));
        // a clone shares too, without touching the cache
        let c = a.clone();
        assert!(Arc::ptr_eq(a.core(), c.core()));
        assert_eq!(cache.hits(), 1);
        // a different geometry compiles its own core
        let d = Session::compile_with_cache(
            ExperimentSpec::builder()
                .named("jacobi2d5p", vec![8, 8, 8], 3)
                .layout("original")
                .schedule(ScheduleKind::Wavefront)
                .spec()
                .expect("spec"),
            &reg,
            &cache,
        )
        .expect("compile d");
        assert!(!Arc::ptr_eq(a.core(), d.core()));
        assert_eq!((cache.misses(), cache.len()), (2, 2));
        // shared cores replay identically to privately compiled ones
        let solo = quick_session("cfa");
        let ra = a.run(Mode::Timing).expect("run a");
        let rb = b.run(Mode::Timing).expect("run b");
        let rs = solo.run(Mode::Timing).expect("run solo");
        assert_eq!(ra.makespan_cycles, rs.makespan_cycles);
        assert_eq!(rb.makespan_cycles, rs.makespan_cycles);
        assert_eq!(ra.timing, rs.timing);
        // a cache hit never launders an invalid spec
        let mut bad = spec();
        bad.exec.channels = 0;
        assert!(Session::compile_with_cache(bad, &reg, &cache).is_err());
    }

    #[test]
    fn plan_cache_counters_cover_every_tile() {
        let s = quick_session("cfa");
        let state = s.core().plan_cache_state();
        assert_eq!((state.rebase_hits(), state.fresh_plans()), (0, 0));
        s.run(Mode::Timing).expect("run");
        // 3x3x3 exact tiling: exactly one interior tile rebases, the other
        // 26 boundary tiles plan fresh (plus the canonical derivation,
        // which goes through alloc.plan directly and is not counted)
        assert_eq!(state.rebase_hits(), 1);
        assert_eq!(state.fresh_plans(), 26);
    }

    #[test]
    fn missing_workload_and_unknown_names_error() {
        assert!(ExperimentSpec::builder().compile().is_err());
        let err = ExperimentSpec::builder()
            .named("jacobi2d5p", vec![8, 8, 8], 3)
            .layout("nope")
            .compile()
            .unwrap_err()
            .to_string();
        assert!(err.contains("nope") && err.contains("cfa"), "{err}");
        assert!(ExperimentSpec::builder()
            .named("not-a-workload", vec![8, 8, 8], 3)
            .compile()
            .is_err());
    }

    #[test]
    fn stencil_divisibility_checked_at_compile() {
        let bad = ExperimentSpec::builder()
            .stencil("jacobi2d5p_t4x16x16", StencilKind::Jacobi5p, vec![4, 16, 16], 23, 24, 8)
            .compile();
        assert!(bad.is_err());
        let good = ExperimentSpec::builder()
            .stencil("jacobi2d5p_t4x16x16", StencilKind::Jacobi5p, vec![4, 16, 16], 24, 24, 8)
            .compile()
            .unwrap();
        assert_eq!(good.benchmark(), "jacobi5p/8x24x24");
        // timing mode works offline even for e2e workloads (plans only)
        let rep = good.run(Mode::Timing).unwrap();
        assert_eq!(rep.tiles, good.tiling().num_tiles());
        assert!(rep.transactions > 0);
    }

    #[test]
    fn data_mode_rejects_flat_schedules() {
        let s = ExperimentSpec::builder()
            .named("jacobi2d5p", vec![8, 8, 8], 3)
            .schedule(ScheduleKind::Flat)
            .compile()
            .unwrap();
        let err = s.run(Mode::Data { seed: 1 }).unwrap_err().to_string();
        assert!(err.contains("Wavefront"), "{err}");
    }

    #[test]
    fn invalid_mem_config_rejected_at_compile() {
        let err = ExperimentSpec::builder()
            .named("jacobi2d5p", vec![8, 8, 8], 3)
            .mem(MemConfig {
                max_outstanding: 0,
                ..MemConfig::default()
            })
            .compile()
            .unwrap_err();
        assert!(format!("{err:#}").contains("max_outstanding"), "{err:#}");
    }

    #[test]
    fn compiled_trace_timing_matches_mode_timing() {
        for layout in registry::global().names() {
            let s = quick_session(layout);
            let direct = s.run(Mode::Timing).unwrap();
            let trace = s.compile_trace();
            let via_trace = s.run_trace(&trace).unwrap();
            assert_eq!(via_trace.mode, "timing");
            assert_eq!(via_trace.makespan_cycles, direct.makespan_cycles, "{layout}");
            assert_eq!(via_trace.timing, direct.timing, "{layout}");
            assert_eq!(via_trace.transactions, direct.transactions);
            assert_eq!(via_trace.raw_bytes, direct.raw_bytes);
            assert_eq!(via_trace.useful_bytes, direct.useful_bytes);
            assert_eq!(via_trace.tiles, direct.tiles);
            assert_eq!(via_trace.waves, direct.waves);
            assert_eq!(
                via_trace.effective_mb_s.to_bits(),
                direct.effective_mb_s.to_bits()
            );
        }
    }

    #[test]
    fn mismatched_trace_is_rejected() {
        let s = quick_session("cfa");
        let other = ExperimentSpec::builder()
            .named("jacobi2d5p", vec![8, 8, 8], 2)
            .layout("cfa")
            .compile()
            .unwrap();
        let err = s.run_trace(&other.compile_trace()).unwrap_err().to_string();
        assert!(err.contains("mismatch"), "{err}");
        // same tiling and schedule shape, different layout: tile/wave
        // counts are identical, so only the geometry stamp can catch it
        let orig = quick_session("original");
        let err = orig.run_trace(&s.compile_trace()).unwrap_err().to_string();
        assert!(err.contains("geometry"), "{err}");
        // an unstamped (hand-built) trace is rejected too
        let err = s.run_trace(&TxnTrace::new()).unwrap_err().to_string();
        assert!(err.contains("unstamped"), "{err}");
    }

    #[test]
    fn report_json_round_trips() {
        let s = quick_session("cfa");
        let rep = s.run(Mode::Sweep).unwrap();
        assert_eq!(rep.mode, "sweep");
        let text = rep.to_json().to_string_pretty();
        let back = Report::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.benchmark, rep.benchmark);
        assert_eq!(back.layout, rep.layout);
        assert_eq!(back.tiles, rep.tiles);
        assert_eq!(back.transactions, rep.transactions);
        assert_eq!(back.raw_bytes, rep.raw_bytes);
        assert_eq!(back.raw_mb_s.to_bits(), rep.raw_mb_s.to_bits());
        assert_eq!(back.timing, rep.timing);
        assert_eq!(back.max_abs_err, rep.max_abs_err);
    }

    #[test]
    fn invalid_striping_and_channels_rejected_at_compile() {
        let err = ExperimentSpec::builder()
            .named("jacobi2d5p", vec![8, 8, 8], 3)
            .channels(2)
            .striping(Striping::Address { stripe_bytes: 12 })
            .compile()
            .unwrap_err();
        assert!(format!("{err:#}").contains("stripe_bytes"), "{err:#}");
        let err = ExperimentSpec::builder()
            .named("jacobi2d5p", vec![8, 8, 8], 3)
            .channels(0)
            .compile()
            .unwrap_err();
        assert!(format!("{err:#}").contains("channel"), "{err:#}");
    }

    #[test]
    fn multichannel_timing_matches_trace_replay_for_every_striping() {
        for striping in [
            Striping::Address { stripe_bytes: 4096 },
            Striping::Facet,
            Striping::Tile,
        ] {
            let s = ExperimentSpec::builder()
                .named("jacobi2d5p", vec![8, 8, 8], 3)
                .schedule(ScheduleKind::Flat)
                .channels(4)
                .striping(striping.clone())
                .compile()
                .unwrap();
            let direct = s.run(Mode::Timing).unwrap();
            // the roofline is the whole interface: one bus per channel
            assert!(
                (direct.peak_mb_s - 4.0 * MemConfig::default().peak_mb_s()).abs() < 1e-9,
                "{striping:?}"
            );
            let trace = s.compile_trace();
            let replayed = s.run_trace(&trace).unwrap();
            assert_eq!(replayed.makespan_cycles, direct.makespan_cycles, "{striping:?}");
            assert_eq!(replayed.timing, direct.timing, "{striping:?}");
            assert_eq!(replayed.raw_bytes, direct.raw_bytes);
            assert_eq!(replayed.transactions, direct.transactions);
        }
    }

    #[test]
    fn data_mode_refuses_multichannel_sessions() {
        let s = ExperimentSpec::builder()
            .named("jacobi2d5p", vec![8, 8, 8], 3)
            .schedule(ScheduleKind::Wavefront)
            .channels(2)
            .compile()
            .unwrap();
        let err = s.run(Mode::Data { seed: 1 }).unwrap_err().to_string();
        assert!(err.contains("single-channel"), "{err}");
    }

    #[test]
    fn sweep_mode_matches_flat_timing() {
        // Mode::Sweep ignores the session schedule: a wavefront session's
        // sweep equals a flat session's timing run, counter for counter
        let wavy = quick_session("cfa").run(Mode::Sweep).unwrap();
        let flat = ExperimentSpec::builder()
            .named("jacobi2d5p", vec![8, 8, 8], 3)
            .schedule(ScheduleKind::Flat)
            .compile()
            .unwrap()
            .run(Mode::Timing)
            .unwrap();
        assert_eq!(wavy.makespan_cycles, flat.makespan_cycles);
        assert_eq!(wavy.timing, flat.timing);
        assert_eq!(wavy.transactions, flat.transactions);
        assert_eq!(wavy.raw_bytes, flat.raw_bytes);
    }
}
