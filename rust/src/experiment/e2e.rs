//! End-to-end data-path drivers for [`Session`](super::Session): the
//! paper's read–execute–write accelerator (Fig 2/13) with the FPGA
//! replaced by the simulated memory interface (timing) plus AOT-compiled
//! PJRT tile programs (numerics), verified against native references.
//!
//! Ported verbatim from the legacy `coordinator::stencil` /
//! `coordinator::sw` free functions (since removed), so the verification
//! semantics (sampling convention, store order, reference comparison) are
//! unchanged — the e2e numeric tests pin them down.

use crate::accel::{Pipeline, TileCost};
use crate::coordinator::batch::PlanStream;
use crate::coordinator::reference::{stencil_reference, sw3_reference};
use crate::coordinator::HostMemory;
use crate::experiment::{Report, Session, WorkloadSpec};
use crate::memsim::MemSim;
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::time::Instant;

/// Execute a [`WorkloadSpec::Stencil`] session end to end.
pub(super) fn run_stencil(session: &Session, rt: &Runtime, seed: u64) -> Result<Report> {
    let WorkloadSpec::Stencil {
        artifact,
        kind,
        tile,
        n,
        m,
        steps,
    } = session.workload()
    else {
        bail!("run_stencil needs a WorkloadSpec::Stencil session");
    };
    let (kind, n, m, steps) = (*kind, *n, *m, *steps);
    let wall0 = Instant::now();
    let exe = rt.load(artifact)?;
    if &exe.info.tile != tile {
        bail!(
            "artifact {artifact} tile {:?} does not match the spec tile {tile:?}",
            exe.info.tile
        );
    }
    let [tt, ti, tj] = tile[..] else {
        bail!("artifact {artifact} has no 3-d tile");
    };
    let r = exe.info.radius;
    if r != kind.radius() {
        bail!("artifact radius {r} does not match benchmark {kind:?}");
    }
    let h = 2 * r;
    let (uu, vv) = (n + r * steps, m + r * steps);

    let alloc = session.allocation();
    let tiling = session.tiling();
    let mem_cfg = &session.spec().mem;
    let mut host = HostMemory::new(alloc.footprint());

    // program input: the initial grid (not a read-write array, kept as-is)
    let mut rng = Rng::new(seed);
    let init: Vec<f32> = (0..(n * m) as usize)
        .map(|_| rng.gen_f64() as f32)
        .collect();

    let sample = |host: &HostMemory, t: i64, u: i64, v: i64| -> f32 {
        if t < 0 {
            // initial plane t = -1 in skewed coords: i = u - r*t = u + r
            let (i, j) = (u + r, v + r);
            if (0..n).contains(&i) && (0..m).contains(&j) {
                init[(i * m + j) as usize]
            } else {
                0.0
            }
        } else if (0..steps).contains(&t) && (0..uu).contains(&u) && (0..vv).contains(&v) {
            let (_, addr) = alloc.read_loc(&[t, u, v]);
            host.read(addr)
        } else {
            0.0
        }
    };

    let mut sim = MemSim::new(mem_cfg.clone());
    let mut pipe = Pipeline::new();
    let mut raw_elems = 0u64;
    let mut useful_elems = 0u64;
    let mut transactions = 0u64;
    let pe_ops = session.spec().exec.pe_ops_per_cycle;
    let flops_per_point = 2 * ((2 * r + 1) * (2 * r + 1)) as u64;

    let halo_t = (tt - 1).max(1);
    // burst planning streams ahead of the tile loop through the session's
    // plan cache: one plan at a time when serial, a bounded window planned
    // in parallel with more threads. consumption stays in lexicographic
    // order either way, so simulator state and Timing counters are
    // unchanged
    let tiles: Vec<Vec<i64>> = tiling.tiles().collect();
    let cache = session.cache();
    let plans = PlanStream::with_cache(&cache, &tiles, session.spec().exec.threads);
    for (coords, plan) in tiles.iter().zip(plans) {
        let (bt, bu, bv) = (coords[0], coords[1], coords[2]);
        let (t0, u0, v0) = (bt * tt, bu * ti, bv * tj);

        // ---- assemble flow-in (the read stage's result)
        let mut prev = vec![0f32; ((ti + h) * (tj + h)) as usize];
        for x in 0..ti + h {
            for y in 0..tj + h {
                prev[(x * (tj + h) + y) as usize] =
                    sample(&host, t0 - 1, u0 - h + x, v0 - h + y);
            }
        }
        let mut halo_u = vec![0f32; (halo_t * h * (tj + h)) as usize];
        let mut halo_v = vec![0f32; (halo_t * ti * h) as usize];
        for s in 1..tt {
            for x in 0..h {
                for y in 0..tj + h {
                    halo_u[(((s - 1) * h + x) * (tj + h) + y) as usize] =
                        sample(&host, t0 + s - 1, u0 - h + x, v0 - h + y);
                }
            }
            for x in 0..ti {
                for y in 0..h {
                    halo_v[(((s - 1) * ti + x) * h + y) as usize] =
                        sample(&host, t0 + s - 1, u0 + x, v0 - h + y);
                }
            }
        }

        // ---- execute on PJRT
        let out = exe.execute(
            &[t0 as i32, u0 as i32, v0 as i32, n as i32, m as i32],
            &[
                (&prev, &[ti + h, tj + h]),
                (&halo_u, &[halo_t, h, tj + h]),
                (&halo_v, &[halo_t, ti, h]),
            ],
        )?;
        let (facet_t, facet_u, facet_v) = (&out[0], &out[1], &out[2]);

        // ---- write flow-out facets to global memory (no per-point Vec:
        // the allocation streams the replicated locations directly)
        let store = |host: &mut HostMemory, p: &[i64], v: f32| {
            alloc.for_each_write_loc(p, &mut |_, addr| host.write(addr, v));
        };
        for x in 0..ti {
            for y in 0..tj {
                store(
                    &mut host,
                    &[t0 + tt - 1, u0 + x, v0 + y],
                    facet_t[(x * tj + y) as usize],
                );
            }
        }
        for s in 0..tt {
            for x in 0..h {
                for y in 0..tj {
                    store(
                        &mut host,
                        &[t0 + s, u0 + ti - h + x, v0 + y],
                        facet_u[((s * h + x) * tj + y) as usize],
                    );
                }
            }
            for x in 0..ti {
                for y in 0..h {
                    store(
                        &mut host,
                        &[t0 + s, u0 + x, v0 + tj - h + y],
                        facet_v[((s * ti + x) * h + y) as usize],
                    );
                }
            }
        }

        // ---- timing through the memory simulator + task pipeline
        let (rd, wr) = crate::accel::tile_mem_cycles(&mut sim, &plan.read_runs, &plan.write_runs);
        let vol = tiling.tile_rect(coords).volume();
        pipe.push(TileCost {
            read: rd,
            exec: vol * flops_per_point / pe_ops.max(1),
            write: wr,
        });
        raw_elems += plan.read_raw() + plan.write_raw();
        useful_elems += plan.read_useful + plan.write_useful;
        transactions += plan.transactions() as u64;
    }
    let stats = pipe.finish();

    // ---- verification against the native reference
    let reference = stencil_reference(&init, n as usize, m as usize, kind, steps as usize);
    let mut max_err = 0f64;
    for i in 0..n {
        for j in 0..m {
            let (u, v) = (i + r * (steps - 1), j + r * (steps - 1));
            let (_, addr) = alloc.read_loc(&[steps - 1, u, v]);
            let got = host.read(addr);
            let want = reference[(i * m + j) as usize];
            max_err = max_err.max((got - want).abs() as f64);
        }
    }

    Ok(finish_report(
        session,
        stats,
        raw_elems,
        useful_elems,
        transactions,
        sim,
        max_err,
        wall0,
    ))
}

/// Execute a [`WorkloadSpec::Sw3`] session end to end, verifying every
/// facet value against the native DP reference.
pub(super) fn run_sw3(session: &Session, rt: &Runtime, seed: u64) -> Result<Report> {
    let WorkloadSpec::Sw3 {
        artifact,
        tile,
        ni,
        nj,
        nk,
    } = session.workload()
    else {
        bail!("run_sw3 needs a WorkloadSpec::Sw3 session");
    };
    let (ni, nj, nk) = (*ni, *nj, *nk);
    let wall0 = Instant::now();
    let exe = rt.load(artifact)?;
    if &exe.info.tile != tile {
        bail!(
            "artifact {artifact} tile {:?} does not match the spec tile {tile:?}",
            exe.info.tile
        );
    }
    let [si, sj, sk] = tile[..] else {
        bail!("artifact {artifact} has no 3-d tile");
    };

    let alloc = session.allocation();
    let tiling = session.tiling();
    let mem_cfg = &session.spec().mem;
    let mut host = HostMemory::new(alloc.footprint());

    // program inputs: three symbol sequences over a 4-letter alphabet
    let mut rng = Rng::new(seed);
    let mut seq = |len: i64| -> Vec<f32> {
        (0..len).map(|_| rng.gen_range(4) as f32).collect()
    };
    let a = seq(ni);
    let b = seq(nj);
    let c = seq(nk);

    let sample = |host: &HostMemory, i: i64, j: i64, k: i64| -> f32 {
        if i < 0 || j < 0 || k < 0 {
            0.0 // zero boundary of the DP
        } else {
            let (_, addr) = alloc.read_loc(&[i, j, k]);
            host.read(addr)
        }
    };

    let mut sim = MemSim::new(mem_cfg.clone());
    let mut pipe = Pipeline::new();
    let (mut raw_elems, mut useful_elems, mut transactions) = (0u64, 0u64, 0u64);
    let pe_ops = session.spec().exec.pe_ops_per_cycle;

    // burst planning streams ahead of the tile loop (see run_stencil)
    let tiles: Vec<Vec<i64>> = tiling.tiles().collect();
    let cache = session.cache();
    let plans = PlanStream::with_cache(&cache, &tiles, session.spec().exec.threads);
    for (coords, plan) in tiles.iter().zip(plans) {
        let (i0, j0, k0) = (coords[0] * si, coords[1] * sj, coords[2] * sk);
        // ---- flow-in: three halo planes (zero outside the lattice)
        let mut halo_i = vec![0f32; ((sj + 1) * (sk + 1)) as usize];
        for x in 0..sj + 1 {
            for y in 0..sk + 1 {
                halo_i[(x * (sk + 1) + y) as usize] =
                    sample(&host, i0 - 1, j0 - 1 + x, k0 - 1 + y);
            }
        }
        let mut halo_j = vec![0f32; (si * (sk + 1)) as usize];
        for x in 0..si {
            for y in 0..sk + 1 {
                halo_j[(x * (sk + 1) + y) as usize] = sample(&host, i0 + x, j0 - 1, k0 - 1 + y);
            }
        }
        let mut halo_k = vec![0f32; (si * sj) as usize];
        for x in 0..si {
            for y in 0..sj {
                halo_k[(x * sj + y) as usize] = sample(&host, i0 + x, j0 + y, k0 - 1);
            }
        }

        // ---- execute
        let out = exe.execute(
            &[],
            &[
                (&a[i0 as usize..(i0 + si) as usize], &[si]),
                (&b[j0 as usize..(j0 + sj) as usize], &[sj]),
                (&c[k0 as usize..(k0 + sk) as usize], &[sk]),
                (&halo_i, &[sj + 1, sk + 1]),
                (&halo_j, &[si, sk + 1]),
                (&halo_k, &[si, sj]),
            ],
        )?;
        let (facet_i, facet_j, facet_k) = (&out[0], &out[1], &out[2]);

        // ---- write facets (streamed locations, no per-point Vec)
        let store = |host: &mut HostMemory, p: &[i64], v: f32| {
            alloc.for_each_write_loc(p, &mut |_, addr| host.write(addr, v));
        };
        for x in 0..sj {
            for y in 0..sk {
                store(
                    &mut host,
                    &[i0 + si - 1, j0 + x, k0 + y],
                    facet_i[(x * sk + y) as usize],
                );
            }
        }
        for x in 0..si {
            for y in 0..sk {
                store(
                    &mut host,
                    &[i0 + x, j0 + sj - 1, k0 + y],
                    facet_j[(x * sk + y) as usize],
                );
            }
        }
        for x in 0..si {
            for y in 0..sj {
                store(
                    &mut host,
                    &[i0 + x, j0 + y, k0 + sk - 1],
                    facet_k[(x * sj + y) as usize],
                );
            }
        }

        // ---- timing
        let (rd, wr) = crate::accel::tile_mem_cycles(&mut sim, &plan.read_runs, &plan.write_runs);
        let vol = tiling.tile_rect(coords).volume();
        pipe.push(TileCost {
            read: rd,
            exec: vol * 14 / pe_ops.max(1), // 7 max-adds per cell
            write: wr,
        });
        raw_elems += plan.read_raw() + plan.write_raw();
        useful_elems += plan.read_useful + plan.write_useful;
        transactions += plan.transactions() as u64;
    }
    let stats = pipe.finish();

    // ---- verify all facet values against the reference DP
    let reference = sw3_reference(&a, &b, &c);
    let mut max_err = 0f64;
    for i in 0..ni {
        for j in 0..nj {
            for k in 0..nk {
                let on_facet =
                    (i % si == si - 1) || (j % sj == sj - 1) || (k % sk == sk - 1);
                if !on_facet {
                    continue;
                }
                let (_, addr) = alloc.read_loc(&[i, j, k]);
                let got = host.read(addr);
                let want = reference[((i * nj + j) * nk + k) as usize];
                max_err = max_err.max((got - want).abs() as f64);
            }
        }
    }

    Ok(finish_report(
        session,
        stats,
        raw_elems,
        useful_elems,
        transactions,
        sim,
        max_err,
        wall0,
    ))
}

/// Fold the pipeline stats and simulator counters into a unified
/// [`Report`] (mode `data`, verification error attached).
#[allow(clippy::too_many_arguments)]
fn finish_report(
    session: &Session,
    stats: crate::accel::PipelineStats,
    raw_elems: u64,
    useful_elems: u64,
    transactions: u64,
    sim: MemSim,
    max_err: f64,
    wall0: Instant,
) -> Report {
    let mem_cfg = &session.spec().mem;
    let raw_bytes = raw_elems * mem_cfg.elem_bytes;
    let useful_bytes = useful_elems * mem_cfg.elem_bytes;
    let secs = mem_cfg.secs(stats.makespan.max(1));
    Report {
        benchmark: session.benchmark().to_string(),
        layout: session.layout().to_string(),
        mode: "data".to_string(),
        tiles: session.tiling().num_tiles(),
        waves: session.schedule().num_waves(),
        makespan_cycles: stats.makespan,
        mem_busy_cycles: stats.mem_busy,
        raw_bytes,
        useful_bytes,
        transactions,
        raw_mb_s: raw_bytes as f64 / 1e6 / secs,
        effective_mb_s: useful_bytes as f64 / 1e6 / secs,
        peak_mb_s: mem_cfg.peak_mb_s(),
        timing: Some(sim.timing().clone()),
        max_abs_err: Some(max_err),
        wall_secs: wall0.elapsed().as_secs_f64(),
    }
}
