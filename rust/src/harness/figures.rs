//! Figure regeneration: the sweeps behind Fig 15 (bandwidth), Fig 16
//! (slices/DSP) and Fig 17 (BRAM), with the paper's memory-bound rig
//! (Fig 14: read and write engines only, one AXI HP port, f64 elements).

use crate::area::{AreaEstimate, Device};
use crate::dse::{Evaluation, Exhaustive, Explorer, Space};
use crate::experiment::{ExperimentSpec, Mode, ScheduleKind};
use crate::harness::workloads::Workload;
use crate::layout::registry;
use crate::layout::{Allocation, LayoutRegistry};
use crate::memsim::MemConfig;
use crate::poly::deps::DepPattern;
use crate::poly::tiling::Tiling;
use crate::util::table::{stacked_bars, StackedBar};

/// One Fig-15 data point.
#[derive(Clone, Debug, PartialEq)]
pub struct BandwidthPoint {
    pub benchmark: String,
    pub tile: Vec<i64>,
    pub alloc: String,
    pub raw_mb_s: f64,
    pub effective_mb_s: f64,
    pub transactions: u64,
    pub raw_bytes: u64,
    pub useful_bytes: u64,
}

/// Build (tiling, deps, allocation) for a sweep point, resolving the
/// layout name through `layout_registry`.
pub fn build_alloc_named(
    w: &Workload,
    tile: &[i64],
    layout: &str,
    tiles_per_dim: i64,
    layout_registry: &LayoutRegistry,
) -> anyhow::Result<(Tiling, DepPattern, Box<dyn Allocation>)> {
    let deps = DepPattern::new(w.deps.clone())?;
    let space = w.space_for(tile, tiles_per_dim);
    let tiling = Tiling::new(space, tile.to_vec());
    let a = layout_registry.build(layout, &tiling, &deps)?;
    Ok((tiling, deps, a))
}

/// Simulate the paper's memory-bound rig for one sweep point: all tiles'
/// planned bursts played back-to-back through the AXI/DRAM model, via an
/// experiment [`Session`](crate::experiment::Session) in `Mode::Sweep`.
/// `threads` workers burst-plan the tiles; replay stays serial in
/// lexicographic order, so the point is bit-identical for any worker
/// count (planning flows through the session's plan cache: interior tiles
/// rebase one canonical plan, which is what keeps the dense sweeps cheap
/// at 128³-tile scale).
pub fn measure_bandwidth_named(
    w: &Workload,
    tile: &[i64],
    layout: &str,
    mem_cfg: &MemConfig,
    tiles_per_dim: i64,
    threads: usize,
    layout_registry: &LayoutRegistry,
) -> anyhow::Result<BandwidthPoint> {
    let session = ExperimentSpec::builder()
        .custom(
            w.name,
            w.space_for(tile, tiles_per_dim),
            tile.to_vec(),
            w.deps.clone(),
        )
        .layout(layout)
        .schedule(ScheduleKind::Flat)
        .threads(threads)
        .mem(mem_cfg.clone())
        .registry(layout_registry.clone())
        .compile()?;
    let rep = session.run(Mode::Sweep)?;
    Ok(BandwidthPoint {
        benchmark: w.name.to_string(),
        tile: tile.to_vec(),
        alloc: rep.layout,
        raw_mb_s: rep.raw_mb_s,
        effective_mb_s: rep.effective_mb_s,
        transactions: rep.transactions,
        raw_bytes: rep.raw_bytes,
        useful_bytes: rep.useful_bytes,
    })
}

/// Project one dse [`Evaluation`] onto a Fig-15 data point. Sweeps run
/// exhaustively over known-good spaces, so every record is a success.
pub fn bandwidth_point_of(e: &Evaluation) -> BandwidthPoint {
    let report = e.report().expect("figure sweeps journal successes only");
    BandwidthPoint {
        benchmark: e.point().workload.clone(),
        tile: e.point().tile.clone(),
        alloc: report.layout.clone(),
        raw_mb_s: report.raw_mb_s,
        effective_mb_s: report.effective_mb_s,
        transactions: report.transactions,
        raw_bytes: report.raw_bytes,
        useful_bytes: report.useful_bytes,
    }
}

/// Full Fig-15 sweep over every layout in the global registry.
pub fn fig15_sweep(
    workloads: &[Workload],
    mem_cfg: &MemConfig,
    tiles_per_dim: i64,
) -> Vec<BandwidthPoint> {
    fig15_sweep_parallel(workloads, mem_cfg, tiles_per_dim, 1)
}

/// [`fig15_sweep`] with the sweep points fanned out across `threads`
/// workers. Every point owns its simulator, so the result is the serial
/// sweep's output bit-for-bit, in the same order (a point that errors is
/// skipped in both).
pub fn fig15_sweep_parallel(
    workloads: &[Workload],
    mem_cfg: &MemConfig,
    tiles_per_dim: i64,
    threads: usize,
) -> Vec<BandwidthPoint> {
    fig15_sweep_registry(&registry::global(), workloads, mem_cfg, tiles_per_dim, threads)
}

/// The Fig-15 sweep against an explicit layout registry: benchmarks ×
/// tile sizes × every registered layout, in registration order. Adding a
/// layout to the registry adds its bars to every figure — no edits here.
///
/// Since the `dse` subsystem landed, this is a thin wrapper: the sweep is
/// an [`Exhaustive`] exploration of [`Space::fig15`], point for point and
/// bit for bit the serial measurement loop (a point that errors is
/// skipped, as before).
pub fn fig15_sweep_registry(
    layout_registry: &LayoutRegistry,
    workloads: &[Workload],
    mem_cfg: &MemConfig,
    tiles_per_dim: i64,
    threads: usize,
) -> Vec<BandwidthPoint> {
    let space = Space::fig15(workloads, mem_cfg, tiles_per_dim);
    let outcome = Explorer::new(space, Box::new(Exhaustive::new()))
        .registry(layout_registry.clone())
        .parallel(threads)
        .explore()
        .expect("fig15 sweep exploration");
    outcome.all.iter().map(bandwidth_point_of).collect()
}

/// Render one benchmark's Fig-15 panel as stacked ASCII bars.
pub fn render_fig15(points: &[BandwidthPoint], benchmark: &str, mem_cfg: &MemConfig) -> String {
    let mut out = String::new();
    let mut tiles: Vec<Vec<i64>> = Vec::new();
    for p in points.iter().filter(|p| p.benchmark == benchmark) {
        if !tiles.contains(&p.tile) {
            tiles.push(p.tile.clone());
        }
    }
    for tile in tiles {
        let bars: Vec<StackedBar> = points
            .iter()
            .filter(|p| p.benchmark == benchmark && p.tile == tile)
            .map(|p| StackedBar {
                label: p.alloc.clone(),
                effective: p.effective_mb_s,
                raw: p.raw_mb_s,
            })
            .collect();
        let title = format!(
            "{} tile {}",
            benchmark,
            tile.iter()
                .map(|x| x.to_string())
                .collect::<Vec<_>>()
                .join("x")
        );
        out.push_str(&stacked_bars(&title, &bars, mem_cfg.peak_mb_s(), 48, "MB/s"));
        out.push('\n');
    }
    out
}

/// One Fig-16/17 data point.
#[derive(Clone, Debug, PartialEq)]
pub struct AreaPoint {
    pub benchmark: String,
    pub tile: Vec<i64>,
    pub alloc: String,
    pub est: AreaEstimate,
}

/// Area sweep (drives both Fig 16 and Fig 17).
pub fn area_sweep(
    workloads: &[Workload],
    elem_bytes: u64,
    tiles_per_dim: i64,
) -> Vec<AreaPoint> {
    area_sweep_parallel(workloads, elem_bytes, tiles_per_dim, 1)
}

/// [`area_sweep`] with the sweep points fanned out across `threads`
/// workers; output is identical to the serial sweep, in the same order.
pub fn area_sweep_parallel(
    workloads: &[Workload],
    elem_bytes: u64,
    tiles_per_dim: i64,
    threads: usize,
) -> Vec<AreaPoint> {
    area_sweep_registry(
        &registry::global(),
        workloads,
        elem_bytes,
        tiles_per_dim,
        threads,
    )
}

/// Project one dse [`Evaluation`] onto a Fig-16/17 data point. Sweeps run
/// exhaustively over known-good spaces, so every record is a success.
pub fn area_point_of(e: &Evaluation) -> AreaPoint {
    AreaPoint {
        benchmark: e.point().workload.clone(),
        tile: e.point().tile.clone(),
        alloc: e.point().layout.clone(),
        est: *e.area().expect("figure sweeps journal successes only"),
    }
}

/// The area sweep against an explicit layout registry (benchmarks × tile
/// sizes × every registered layout, registration order). A thin wrapper
/// over an [`Exhaustive`] exploration of [`Space::area`] — the dse
/// evaluator scores every point on bandwidth *and* area, and this view
/// keeps the area columns.
pub fn area_sweep_registry(
    layout_registry: &LayoutRegistry,
    workloads: &[Workload],
    elem_bytes: u64,
    tiles_per_dim: i64,
    threads: usize,
) -> Vec<AreaPoint> {
    let space = Space::area(workloads, elem_bytes, tiles_per_dim);
    let outcome = Explorer::new(space, Box::new(Exhaustive::new()))
        .registry(layout_registry.clone())
        .parallel(threads)
        .explore()
        .expect("area sweep exploration");
    outcome.all.iter().map(area_point_of).collect()
}

/// Aggregate CFA vs all-other-baselines min/max, Fig-16 style.
pub fn fig16_aggregate(points: &[AreaPoint], metric: impl Fn(&AreaEstimate, &Device) -> f64) -> Vec<(String, f64, f64, f64, f64)> {
    // returns (benchmark, cfa_min, cfa_max, base_min, base_max)
    let dev = Device::default();
    let mut benches: Vec<String> = Vec::new();
    for p in points {
        if !benches.contains(&p.benchmark) {
            benches.push(p.benchmark.clone());
        }
    }
    benches
        .into_iter()
        .map(|b| {
            let vals = |is_cfa: bool| -> (f64, f64) {
                let xs: Vec<f64> = points
                    .iter()
                    .filter(|p| {
                        p.benchmark == b && ((p.alloc == registry::names::CFA) == is_cfa)
                    })
                    .map(|p| metric(&p.est, &dev))
                    .collect();
                (
                    xs.iter().cloned().fold(f64::INFINITY, f64::min),
                    xs.iter().cloned().fold(0.0, f64::max),
                )
            };
            let (cmin, cmax) = vals(true);
            let (bmin, bmax) = vals(false);
            (b, cmin, cmax, bmin, bmax)
        })
        .collect()
}

/// JSON export of a bandwidth sweep (machine-readable experiment record).
pub fn fig15_json(points: &[BandwidthPoint], mem_cfg: &MemConfig) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("figure", Json::str("fig15")),
        ("roofline_mb_s", Json::num(mem_cfg.peak_mb_s())),
        (
            "points",
            Json::arr(points.iter().map(|p| {
                Json::obj(vec![
                    ("benchmark", Json::str(p.benchmark.clone())),
                    (
                        "tile",
                        Json::arr(p.tile.iter().map(|&x| Json::num(x as f64))),
                    ),
                    ("alloc", Json::str(p.alloc.clone())),
                    ("raw_mb_s", Json::num(p.raw_mb_s)),
                    ("effective_mb_s", Json::num(p.effective_mb_s)),
                    ("transactions", Json::num(p.transactions as f64)),
                ])
            })),
        ),
    ])
}

/// CSV export of a bandwidth sweep.
pub fn fig15_csv(points: &[BandwidthPoint]) -> String {
    let mut t = crate::util::table::Table::new(&[
        "benchmark",
        "tile",
        "alloc",
        "raw_mb_s",
        "effective_mb_s",
        "transactions",
        "raw_bytes",
        "useful_bytes",
    ]);
    for p in points {
        t.row(&[
            p.benchmark.clone(),
            p.tile
                .iter()
                .map(|x| x.to_string())
                .collect::<Vec<_>>()
                .join("x"),
            p.alloc.clone(),
            format!("{:.2}", p.raw_mb_s),
            format!("{:.2}", p.effective_mb_s),
            p.transactions.to_string(),
            p.raw_bytes.to_string(),
            p.useful_bytes.to_string(),
        ]);
    }
    t.to_csv()
}

/// JSON export of an area sweep (machine-readable experiment record for
/// Fig 16/17).
pub fn area_json(points: &[AreaPoint]) -> crate::util::json::Json {
    use crate::util::json::Json;
    let dev = Device::default();
    Json::obj(vec![
        ("figure", Json::str("fig16_17")),
        (
            "points",
            Json::arr(points.iter().map(|p| {
                Json::obj(vec![
                    ("benchmark", Json::str(p.benchmark.clone())),
                    (
                        "tile",
                        Json::arr(p.tile.iter().map(|&x| Json::num(x as f64))),
                    ),
                    ("alloc", Json::str(p.alloc.clone())),
                    ("slices", Json::num(p.est.slices as f64)),
                    ("dsp", Json::num(p.est.dsp as f64)),
                    ("bram36", Json::num(p.est.bram36 as f64)),
                    ("slice_pct", Json::num(p.est.slice_pct(&dev))),
                    ("dsp_pct", Json::num(p.est.dsp_pct(&dev))),
                    ("bram_pct", Json::num(p.est.bram_pct(&dev))),
                ])
            })),
        ),
    ])
}

/// CSV export of an area sweep.
pub fn area_csv(points: &[AreaPoint]) -> String {
    let dev = Device::default();
    let mut t = crate::util::table::Table::new(&[
        "benchmark", "tile", "alloc", "slices", "slice_pct", "dsp", "dsp_pct", "bram36",
        "bram_pct",
    ]);
    for p in points {
        t.row(&[
            p.benchmark.clone(),
            p.tile
                .iter()
                .map(|x| x.to_string())
                .collect::<Vec<_>>()
                .join("x"),
            p.alloc.clone(),
            p.est.slices.to_string(),
            format!("{:.2}", p.est.slice_pct(&dev)),
            p.est.dsp.to_string(),
            format!("{:.2}", p.est.dsp_pct(&dev)),
            p.est.bram36.to_string(),
            format!("{:.2}", p.est.bram_pct(&dev)),
        ]);
    }
    t.to_csv()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::workloads::table1;
    use crate::layout::registry::names;
    use crate::memsim::{Dir, MemSim, Txn};

    #[test]
    fn batched_measure_matches_manual_serial_loop() {
        // refactor guard: the batch-coordinator path must reproduce the
        // classic tile-by-tile submit loop exactly
        let w = &table1(true)[0];
        let tile = vec![16, 16, 16];
        let cfg = MemConfig::default();
        let reg = registry::global();
        for name in reg.names() {
            let (tiling, _d, a) = build_alloc_named(w, &tile, name, 3, &reg).unwrap();
            let mut sim = MemSim::new(cfg.clone());
            let (mut raw, mut useful, mut txns) = (0u64, 0u64, 0u64);
            for coords in tiling.tiles() {
                let plan = a.plan(&coords);
                for r in &plan.read_runs {
                    sim.submit(&Txn {
                        dir: Dir::Read,
                        addr: r.addr,
                        len: r.len,
                    });
                }
                for r in &plan.write_runs {
                    sim.submit(&Txn {
                        dir: Dir::Write,
                        addr: r.addr,
                        len: r.len,
                    });
                }
                raw += plan.read_raw() + plan.write_raw();
                useful += plan.read_useful + plan.write_useful;
                txns += plan.transactions() as u64;
            }
            let p = measure_bandwidth_named(w, &tile, name, &cfg, 3, 1, &reg).unwrap();
            assert_eq!(p.transactions, txns, "{name}");
            assert_eq!(p.raw_bytes, raw * cfg.elem_bytes);
            assert_eq!(p.useful_bytes, useful * cfg.elem_bytes);
            let secs = cfg.secs(sim.now().max(1));
            let raw_mb = raw as f64 * cfg.elem_bytes as f64 / 1e6 / secs;
            assert_eq!(p.raw_mb_s.to_bits(), raw_mb.to_bits(), "{name}");
            // the within-point threaded path is bit-identical too
            let batched = measure_bandwidth_named(w, &tile, name, &cfg, 3, 4, &reg).unwrap();
            assert_eq!(p, batched, "{name}");
        }
    }

    #[test]
    fn parallel_sweep_is_bit_identical_to_serial() {
        let wl = table1(true);
        let cfg = MemConfig::default();
        let serial = fig15_sweep(&wl[..2], &cfg, 2);
        for threads in [1, 4] {
            let par = fig15_sweep_parallel(&wl[..2], &cfg, 2, threads);
            assert_eq!(serial.len(), par.len());
            for (s, p) in serial.iter().zip(&par) {
                assert_eq!(s, p, "threads={threads}");
                assert_eq!(s.raw_mb_s.to_bits(), p.raw_mb_s.to_bits());
                assert_eq!(s.effective_mb_s.to_bits(), p.effective_mb_s.to_bits());
            }
        }
    }

    #[test]
    fn quick_sweep_has_paper_shape() {
        // CFA reaches near-roofline effective bandwidth; original has zero
        // redundancy but lower raw; bbox has raw >> effective.
        let w = &table1(true)[0]; // jacobi2d5p
        let cfg = MemConfig::default();
        let reg = registry::global();
        let mut by_alloc = std::collections::BTreeMap::new();
        for name in reg.names() {
            let p = measure_bandwidth_named(w, &[16, 16, 16], name, &cfg, 3, 1, &reg).unwrap();
            by_alloc.insert(p.alloc.clone(), p);
        }
        let cfa = &by_alloc[names::CFA];
        let orig = &by_alloc[names::ORIGINAL];
        let bbox = &by_alloc[names::BBOX];
        assert!(
            cfa.effective_mb_s > 0.8 * cfg.peak_mb_s(),
            "CFA effective {:.1} not near roofline",
            cfa.effective_mb_s
        );
        assert!(cfa.effective_mb_s > orig.effective_mb_s);
        assert!(cfa.effective_mb_s > bbox.effective_mb_s);
        assert!(bbox.raw_mb_s > bbox.effective_mb_s * 1.2, "bbox should be redundant");
        assert_eq!(orig.raw_bytes, orig.useful_bytes);
        // CFA uses far fewer transactions than the original layout
        assert!(cfa.transactions * 4 < orig.transactions);
    }

    #[test]
    fn fig15_render_contains_all_allocs() {
        let w = &table1(true)[0];
        let cfg = MemConfig::default();
        let reg = crate::layout::LayoutRegistry::with_builtins();
        let pts: Vec<BandwidthPoint> = reg
            .names()
            .iter()
            .map(|&a| measure_bandwidth_named(w, &[16, 16, 16], a, &cfg, 2, 1, &reg).unwrap())
            .collect();
        let s = render_fig15(&pts, "jacobi2d5p", &cfg);
        for a in reg.names() {
            assert!(s.contains(a), "{s}");
        }
    }

    #[test]
    fn fig15_json_round_trips() {
        let w = &table1(true)[0];
        let cfg = MemConfig::default();
        let reg = registry::global();
        let pts =
            vec![measure_bandwidth_named(w, &[16, 16, 16], names::CFA, &cfg, 2, 1, &reg).unwrap()];
        let j = fig15_json(&pts, &cfg);
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("figure").unwrap().as_str(), Some("fig15"));
        let p0 = back.get("points").unwrap().idx(0).unwrap();
        assert_eq!(p0.get("alloc").unwrap().as_str(), Some("cfa"));
        assert!(p0.get("effective_mb_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn area_sweep_produces_all_points() {
        let wl = table1(true);
        let pts = area_sweep(&wl[..1], 8, 2);
        assert_eq!(pts.len(), wl[0].tile_sizes.len() * 4);
        let csv = area_csv(&pts);
        assert!(csv.lines().count() == pts.len() + 1);
        // the parallel sweep is the serial sweep, in order
        let par = area_sweep_parallel(&wl[..1], 8, 2, 4);
        assert_eq!(pts, par);
    }

    #[test]
    fn fig16_aggregate_shapes() {
        let wl = table1(true);
        let pts = area_sweep(&wl[..2], 8, 2);
        let agg = fig16_aggregate(&pts, |e, d| e.slice_pct(d));
        assert_eq!(agg.len(), 2);
        for (b, cmin, cmax, bmin, bmax) in agg {
            assert!(cmin <= cmax && bmin <= bmax, "{b}");
            assert!(cmin > 0.0);
        }
    }
}
