//! Benchmark registry — Table I of the paper.
//!
//! Each workload is a uniform dependence pattern in skew-normalized form
//! (every vector non-positive; `poly::skew` documents the basis change)
//! plus the tile-size sweep the paper uses: 16³ → 128³, with 1:1, 1.5:1
//! and 2:1 aspect ratios (gaussian: 4×16² → 4×128², time-tile fixed at 4).

use crate::poly::vec::IVec;

/// One Table-I benchmark.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: &'static str,
    /// "Equivalent application" column of Table I.
    pub equivalent: &'static str,
    pub dims: usize,
    /// Skew-normalized dependence vectors.
    pub deps: Vec<IVec>,
    /// Tile-size sweep (already ratio-expanded).
    pub tile_sizes: Vec<IVec>,
}

impl Workload {
    /// Iteration-space sizes for a tile: `tiles_per_dim` tiles per axis.
    pub fn space_for(&self, tile: &[i64], tiles_per_dim: i64) -> IVec {
        tile.iter().map(|t| t * tiles_per_dim).collect()
    }

    /// Dependence count (the "Nb of deps" column).
    pub fn n_deps(&self) -> usize {
        self.deps.len()
    }
}

/// 3x3 stencil support at t-1, skewed by r=1: (-1, di-1, dj-1).
fn skewed_taps(support: &[(i64, i64)], r: i64) -> Vec<IVec> {
    support
        .iter()
        .map(|&(di, dj)| vec![-1, di - r, dj - r])
        .collect()
}

fn cube_sizes(bases: &[i64], ratios: bool) -> Vec<IVec> {
    let mut out = Vec::new();
    for &b in bases {
        out.push(vec![b, b, b]);
        if ratios {
            out.push(vec![b, 3 * b / 2, b]); // 1.5:1
            out.push(vec![b, 2 * b, b]); // 2:1
        }
    }
    out
}

fn gaussian_sizes(bases: &[i64], ratios: bool) -> Vec<IVec> {
    let mut out = Vec::new();
    for &b in bases {
        out.push(vec![4, b, b]);
        if ratios {
            out.push(vec![4, 3 * b / 2, b]);
            out.push(vec![4, 2 * b, b]);
        }
    }
    out
}

/// Build the full Table-I registry. `quick` restricts the tile sweep to two
/// sizes without ratio variants (used by tests and `--quick` benches).
pub fn table1(quick: bool) -> Vec<Workload> {
    let bases: &[i64] = if quick { &[16, 32] } else { &[16, 32, 64, 128] };
    let ratios = !quick;

    // jacobi2d5p: 5-point cross at t-1 (Laplace equation)
    let cross = [(0, 0), (-1, 0), (1, 0), (0, -1), (0, 1)];
    // jacobi2d9p: full 3x3 at t-1 (3x3 convolution)
    let full3: Vec<(i64, i64)> = (-1..=1)
        .flat_map(|a| (-1..=1).map(move |b| (a, b)))
        .collect();
    // jacobi2d9p-gol: 2nd-order finite difference — 8-neighborhood at t-1
    // plus the center at t-2 (wave-equation style); reaches two time planes,
    // so w = (2, 2, 2).
    let mut gol = skewed_taps(
        &full3
            .iter()
            .copied()
            .filter(|&(a, b)| (a, b) != (0, 0))
            .collect::<Vec<_>>(),
        1,
    );
    gol.push(vec![-2, -2, -2]); // center at t-2, skewed by r=1
    // gaussian: 5x5 at t-1, r=2
    let full5: Vec<(i64, i64)> = (-2..=2)
        .flat_map(|a| (-2..=2).map(move |b| (a, b)))
        .collect();
    // smith-waterman 3 sequences: {0,-1}^3 \ 0, naturally backwards
    let mut sw = Vec::new();
    for di in [-1i64, 0] {
        for dj in [-1i64, 0] {
            for dk in [-1i64, 0] {
                if (di, dj, dk) != (0, 0, 0) {
                    sw.push(vec![di, dj, dk]);
                }
            }
        }
    }

    vec![
        Workload {
            name: "jacobi2d5p",
            equivalent: "Laplace equation",
            dims: 3,
            deps: skewed_taps(&cross, 1),
            tile_sizes: cube_sizes(bases, ratios),
        },
        Workload {
            name: "jacobi2d9p",
            equivalent: "3x3 convolution",
            dims: 3,
            deps: skewed_taps(&full3, 1),
            tile_sizes: cube_sizes(bases, ratios),
        },
        Workload {
            name: "jacobi2d9p-gol",
            equivalent: "2nd-order finite difference",
            dims: 3,
            deps: gol,
            tile_sizes: cube_sizes(bases, ratios),
        },
        Workload {
            name: "gaussian",
            equivalent: "5x5 Gaussian Blur",
            dims: 3,
            deps: skewed_taps(&full5, 2),
            tile_sizes: gaussian_sizes(bases, ratios),
        },
        Workload {
            name: "smith-waterman-3seq",
            equivalent: "Alignment of 3 sequences",
            dims: 3,
            deps: sw,
            tile_sizes: cube_sizes(bases, ratios),
        },
    ]
}

/// Extension workload beyond Table I: a 3-D heat stencil over time — a
/// 4-D iteration space, which exercises the paper's §IV.J observation that
/// k-th-level neighbors with k >= d of contiguity directions cannot all be
/// served contiguously (C(4,2) = 6 pairs > 4 facets). Not part of the
/// paper's sweep; used by the 4-D tests and available to `layout_explorer`.
pub fn heat3d() -> Workload {
    // 7-point 3-D stencil at t-1, skewed by 1 in each spatial dim.
    let mut deps = Vec::new();
    for (di, dj, dk) in [
        (0, 0, 0),
        (-1, 0, 0),
        (1, 0, 0),
        (0, -1, 0),
        (0, 1, 0),
        (0, 0, -1),
        (0, 0, 1),
    ] {
        deps.push(vec![-1, di - 1, dj - 1, dk - 1]);
    }
    Workload {
        name: "heat3d",
        equivalent: "3-D heat equation (4-D space, beyond Table I)",
        dims: 4,
        deps,
        tile_sizes: vec![vec![4, 8, 8, 8], vec![4, 16, 16, 16]],
    }
}

/// Find a workload by name.
pub fn by_name(name: &str) -> Option<Workload> {
    table1(false).into_iter().find(|w| w.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::deps::DepPattern;

    #[test]
    fn dep_counts_match_table1() {
        let t = table1(false);
        let counts: Vec<(&str, usize)> = t.iter().map(|w| (w.name, w.n_deps())).collect();
        assert_eq!(
            counts,
            vec![
                ("jacobi2d5p", 5),
                ("jacobi2d9p", 9),
                ("jacobi2d9p-gol", 9),
                ("gaussian", 25),
                ("smith-waterman-3seq", 7),
            ]
        );
    }

    #[test]
    fn all_patterns_are_backwards_and_valid() {
        for w in table1(false) {
            let deps = DepPattern::new(w.deps.clone())
                .unwrap_or_else(|e| panic!("{}: {e}", w.name));
            assert_eq!(deps.dims(), 3, "{}", w.name);
            assert!(!deps.active_axes().is_empty());
        }
    }

    #[test]
    fn facet_widths_match_design_doc() {
        let widths: Vec<Vec<i64>> = table1(false)
            .iter()
            .map(|w| DepPattern::new(w.deps.clone()).unwrap().widths())
            .collect();
        assert_eq!(widths[0], vec![1, 2, 2]); // jacobi2d5p
        assert_eq!(widths[1], vec![1, 2, 2]); // jacobi2d9p
        assert_eq!(widths[2], vec![2, 2, 2]); // gol: reaches t-2
        assert_eq!(widths[3], vec![1, 4, 4]); // gaussian
        assert_eq!(widths[4], vec![1, 1, 1]); // sw3
    }

    #[test]
    fn tile_sweeps_cover_paper_range() {
        let t = table1(false);
        let jac = &t[0];
        assert!(jac.tile_sizes.contains(&vec![16, 16, 16]));
        assert!(jac.tile_sizes.contains(&vec![128, 128, 128]));
        assert!(jac.tile_sizes.contains(&vec![16, 24, 16])); // 1.5:1
        let g = &t[3];
        assert!(g.tile_sizes.iter().all(|s| s[0] == 4));
        assert!(g.tile_sizes.contains(&vec![4, 128, 128]));
    }

    #[test]
    fn quick_mode_is_smaller() {
        assert!(table1(true)[0].tile_sizes.len() < table1(false)[0].tile_sizes.len());
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("gaussian").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn heat3d_is_4d_and_backwards() {
        let w = heat3d();
        let deps = DepPattern::new(w.deps.clone()).unwrap();
        assert_eq!(deps.dims(), 4);
        assert_eq!(deps.widths(), vec![1, 2, 2, 2]);
    }
}
