//! Benchmark harness: Table-I workload registry and the sweeps that
//! regenerate every figure of the paper's evaluation (§VI).

pub mod figures;
pub mod workloads;

pub use figures::{
    area_sweep, area_sweep_parallel, area_sweep_registry, fig15_sweep, fig15_sweep_parallel,
    fig15_sweep_registry, measure_bandwidth_named, render_fig15,
};
pub use workloads::{by_name, table1, Workload};
