//! Benchmark harness: Table-I workload registry and the sweeps that
//! regenerate every figure of the paper's evaluation (§VI).

pub mod figures;
pub mod workloads;

pub use figures::{area_sweep, fig15_sweep, measure_bandwidth, render_fig15};
pub use workloads::{by_name, table1, Workload};
