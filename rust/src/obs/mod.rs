//! Observability layer: one registry, two time domains.
//!
//! Everything the crate reports about itself flows through this module,
//! split along the one distinction that matters for reproducibility —
//! which *clock* a fact lives on:
//!
//! * [`metrics`] — clockless monotonic counters/gauges/histograms in a
//!   process-wide registry (`cfa.<subsystem>.<metric>`). The cache
//!   hit/miss counters, serve queue depth and request counts live here;
//!   the serve `stats` reply and the tune summary read these handles.
//! * [`span`] — **wall-time** phase tracing (compile / plan / marshal /
//!   replay / evaluate / serve lifecycle) exported as Chrome
//!   trace-event JSON for Perfetto. Advisory by contract: span data can
//!   never flow into a journal, report or any other deterministic
//!   artifact, so `--profile` on/off leaves journals byte-identical.
//! * [`timeline`] — **cycle-time** bandwidth evolution sampled inside
//!   the memory simulator. Deterministic by contract: a pure function
//!   of the replay's counter evolution, byte-identical across
//!   serial/parallel replay and cache on/off.
//!
//! The determinism line between the two time domains is the load-
//! bearing design decision; DESIGN.md §Observability spells out the
//! full contract and the span/metric taxonomies.

pub mod metrics;
pub mod span;
pub mod timeline;

pub use metrics::{registry, Counter, Gauge, Histogram, Registry};
pub use span::{begin_capture, enabled, span, Capture, Span, SpanEvent};
pub use timeline::{EpochSample, Timeline, TimelineSampler};
