//! Cycle-domain bandwidth timelines for the memory simulator.
//!
//! The spans in [`crate::obs::span`] answer "where did the *wall time*
//! go"; timelines answer the paper's question — "what did the memory
//! interface *do* over the run": effective bandwidth, row-hit rate and
//! bus utilization per epoch of simulated cycles, per channel.
//!
//! Determinism contract (the load-bearing property): a timeline is a
//! pure function of the replay's counter evolution, which is itself
//! bit-identical across the scalar/streamed kernels, serial/parallel
//! multi-channel replay, and trace-cache on/off. [`TimelineSampler`]
//! reads [`Timing`] *deltas* and the simulated clock — never the wall
//! clock, never allocation addresses — so sampled runs are byte-stable
//! and sampling cannot perturb the run (`record` only reads state;
//! `tests/obs_api.rs` pins sampled ≡ unsampled final `Timing`).
//!
//! Granularity: the engine calls [`TimelineSampler::record`] once per
//! submitted span (after the span completes), and the whole delta is
//! attributed to the epoch containing the span's completion cycle.
//! A closed-form `bulk_advance` that jumps many epochs therefore lands
//! its counters in the completion epoch — attribution-at-completion,
//! the standard trade for not simulating beat-by-beat. Epochs with no
//! completions are omitted (sparse representation).

use crate::memsim::{MemConfig, Timing};
use crate::util::json::Json;

/// Counter deltas attributed to one epoch (sparse: all-zero epochs are
/// never stored). `epoch` is the index; epoch `e` covers simulated
/// cycles `[e * epoch_cycles + 1, (e+1) * epoch_cycles]`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EpochSample {
    pub epoch: u64,
    pub data_cycles: u64,
    pub axi_bursts: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_switches: u64,
    pub turnarounds: u64,
}

impl EpochSample {
    fn is_zero(&self) -> bool {
        self.data_cycles == 0
            && self.axi_bursts == 0
            && self.row_hits == 0
            && self.row_misses == 0
            && self.row_switches == 0
            && self.turnarounds == 0
    }

    fn absorb(&mut self, d: &EpochSample) {
        self.data_cycles += d.data_cycles;
        self.axi_bursts += d.axi_bursts;
        self.row_hits += d.row_hits;
        self.row_misses += d.row_misses;
        self.row_switches += d.row_switches;
        self.turnarounds += d.turnarounds;
    }
}

/// Per-channel sampler owned by a `MemSim`. Records counter deltas at
/// span completion; clones with the simulator, so the pre-split
/// parallel multi-channel replay (which clones each channel, replays,
/// and keeps the mutated clone) carries its samples back for free.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TimelineSampler {
    epoch_cycles: u64,
    last: Timing,
    epochs: Vec<EpochSample>,
}

impl TimelineSampler {
    /// A sampler with `epoch_cycles`-cycle epochs (clamped to >= 1).
    pub fn new(epoch_cycles: u64) -> TimelineSampler {
        TimelineSampler {
            epoch_cycles: epoch_cycles.max(1),
            last: Timing::default(),
            epochs: Vec::new(),
        }
    }

    pub fn epoch_cycles(&self) -> u64 {
        self.epoch_cycles
    }

    /// Record the counter movement since the previous call, attributed
    /// to the epoch containing simulated cycle `now`. Read-only with
    /// respect to the simulation: the engine's state never depends on
    /// whether this ran. Saturating deltas make a `record` after an
    /// engine `reset`/`restore` harmless (the sampler is reset alongside
    /// the engine on `reset`; `restore` rewinds are not resampled).
    pub fn record(&mut self, t: &Timing, now: u64) {
        let d = EpochSample {
            epoch: if now == 0 {
                0
            } else {
                (now - 1) / self.epoch_cycles
            },
            data_cycles: t.data_cycles.saturating_sub(self.last.data_cycles),
            axi_bursts: t.axi_bursts.saturating_sub(self.last.axi_bursts),
            row_hits: t.row_hits.saturating_sub(self.last.row_hits),
            row_misses: t.row_misses.saturating_sub(self.last.row_misses),
            row_switches: t.row_switches.saturating_sub(self.last.row_switches),
            turnarounds: t.turnarounds.saturating_sub(self.last.turnarounds),
        };
        self.last = t.clone();
        if d.is_zero() {
            return;
        }
        match self.epochs.last_mut() {
            Some(e) if e.epoch == d.epoch => e.absorb(&d),
            _ => self.epochs.push(d),
        }
    }

    /// The recorded epochs (sparse, ascending by construction: `now` is
    /// monotone within a replay).
    pub fn epochs(&self) -> &[EpochSample] {
        &self.epochs
    }

    /// Consume the sampler into its epoch list.
    pub fn into_epochs(self) -> Vec<EpochSample> {
        self.epochs
    }
}

/// A finished multi-channel timeline: one sparse epoch list per channel
/// (a single-channel run is one list).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Timeline {
    pub epoch_cycles: u64,
    pub channels: Vec<Vec<EpochSample>>,
}

impl Timeline {
    /// Sum of every epoch across every channel. By construction this
    /// equals the run's aggregate [`Timing`] counters exactly — the
    /// identity [`Timeline::matches`] checks and CI asserts.
    pub fn totals(&self) -> EpochSample {
        let mut out = EpochSample::default();
        for ch in &self.channels {
            for e in ch {
                out.absorb(e);
                out.epoch = out.epoch.max(e.epoch);
            }
        }
        out
    }

    /// Per-channel total data beats (imbalance input).
    pub fn channel_data_cycles(&self) -> Vec<u64> {
        self.channels
            .iter()
            .map(|ch| ch.iter().map(|e| e.data_cycles).sum())
            .collect()
    }

    /// Traffic imbalance over channels that saw any traffic:
    /// max data beats / mean data beats, 1.0 = perfectly balanced.
    pub fn imbalance(&self) -> f64 {
        let busy: Vec<u64> = self
            .channel_data_cycles()
            .into_iter()
            .filter(|&d| d > 0)
            .collect();
        if busy.is_empty() {
            return 1.0;
        }
        let max = *busy.iter().max().unwrap() as f64;
        let mean = busy.iter().sum::<u64>() as f64 / busy.len() as f64;
        max / mean
    }

    /// True iff the epoch sums reproduce `t`'s additive counters
    /// exactly (`cycles` is a makespan, not additive, so it is not
    /// part of the identity).
    pub fn matches(&self, t: &Timing) -> bool {
        let s = self.totals();
        s.data_cycles == t.data_cycles
            && s.axi_bursts == t.axi_bursts
            && s.row_hits == t.row_hits
            && s.row_misses == t.row_misses
            && s.row_switches == t.row_switches
            && s.turnarounds == t.turnarounds
    }

    /// JSON artifact for `cfa run --timeline`. Integer counters come
    /// straight from the epochs; the derived floats (`bus_util`,
    /// `row_hit_rate`, `raw_mb_s`, `eff_mb_s`) are pure functions of
    /// those integers and the config, so the whole document is
    /// byte-deterministic. `useful_ratio` is the run-level useful/raw
    /// traffic ratio from the layout plans (epoch-resolved usefulness
    /// would require tagging every burst; the ratio is constant per
    /// layout anyway).
    pub fn to_json(&self, cfg: &MemConfig, useful_ratio: f64) -> Json {
        let epoch_json = |e: &EpochSample| {
            let first_beats = e.row_hits + e.row_misses;
            let hit_rate = if first_beats == 0 {
                0.0
            } else {
                e.row_hits as f64 / first_beats as f64
            };
            let bus_util = e.data_cycles as f64 / self.epoch_cycles as f64;
            // beats/epoch × bytes/beat × cycles/sec ÷ cycles/epoch = B/s
            let raw_mb_s = e.data_cycles as f64 * cfg.bus_bytes as f64 * cfg.clock_mhz
                / self.epoch_cycles as f64;
            Json::obj(vec![
                ("axi_bursts", Json::num(e.axi_bursts as f64)),
                ("bus_util", Json::num(bus_util)),
                ("data_cycles", Json::num(e.data_cycles as f64)),
                ("eff_mb_s", Json::num(raw_mb_s * useful_ratio)),
                ("epoch", Json::num(e.epoch as f64)),
                ("raw_mb_s", Json::num(raw_mb_s)),
                ("row_hit_rate", Json::num(hit_rate)),
                ("row_hits", Json::num(e.row_hits as f64)),
                ("row_misses", Json::num(e.row_misses as f64)),
                ("row_switches", Json::num(e.row_switches as f64)),
                ("turnarounds", Json::num(e.turnarounds as f64)),
            ])
        };
        let t = self.totals();
        Json::obj(vec![
            (
                "channels",
                Json::arr(
                    self.channels
                        .iter()
                        .map(|ch| Json::arr(ch.iter().map(epoch_json))),
                ),
            ),
            ("epoch_cycles", Json::num(self.epoch_cycles as f64)),
            ("imbalance", Json::num(self.imbalance())),
            (
                "totals",
                Json::obj(vec![
                    ("axi_bursts", Json::num(t.axi_bursts as f64)),
                    ("data_cycles", Json::num(t.data_cycles as f64)),
                    ("row_hits", Json::num(t.row_hits as f64)),
                    ("row_misses", Json::num(t.row_misses as f64)),
                    ("row_switches", Json::num(t.row_switches as f64)),
                    ("turnarounds", Json::num(t.turnarounds as f64)),
                ]),
            ),
            ("useful_ratio", Json::num(useful_ratio)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timing(data: u64, bursts: u64, hits: u64, misses: u64) -> Timing {
        Timing {
            cycles: 0,
            data_cycles: data,
            axi_bursts: bursts,
            row_hits: hits,
            row_misses: misses,
            row_switches: 0,
            turnarounds: 0,
        }
    }

    #[test]
    fn deltas_accumulate_into_completion_epochs() {
        let mut s = TimelineSampler::new(100);
        s.record(&timing(10, 1, 0, 1), 50); // epoch 0
        s.record(&timing(30, 2, 1, 1), 100); // cycle 100 is still epoch 0
        s.record(&timing(60, 3, 2, 1), 101); // cycle 101 opens epoch 1
        s.record(&timing(60, 3, 2, 1), 150); // zero delta: skipped
        s.record(&timing(100, 4, 2, 2), 505); // jump to epoch 5
        let e = s.epochs();
        assert_eq!(e.len(), 3, "sparse: only epochs with traffic");
        assert_eq!((e[0].epoch, e[0].data_cycles, e[0].axi_bursts), (0, 30, 2));
        assert_eq!((e[1].epoch, e[1].data_cycles), (1, 30));
        assert_eq!((e[2].epoch, e[2].data_cycles), (5, 40));
        let tl = Timeline {
            epoch_cycles: 100,
            channels: vec![s.into_epochs()],
        };
        assert!(tl.matches(&timing(100, 4, 2, 2)), "sums reproduce the final counters");
        assert!(!tl.matches(&timing(101, 4, 2, 2)));
    }

    #[test]
    fn epoch_zero_cycles_clamp() {
        let mut s = TimelineSampler::new(0); // clamped to 1-cycle epochs
        assert_eq!(s.epoch_cycles(), 1);
        s.record(&timing(1, 1, 0, 1), 0); // now=0 lands in epoch 0
        assert_eq!(s.epochs()[0].epoch, 0);
    }

    #[test]
    fn records_after_a_counter_rewind_saturate() {
        let mut s = TimelineSampler::new(10);
        s.record(&timing(50, 5, 0, 5), 9);
        // a restore rewound the engine; deltas clamp to zero, no panic
        s.record(&timing(20, 2, 0, 2), 5);
        assert_eq!(s.epochs().len(), 1);
    }

    #[test]
    fn imbalance_ignores_idle_channels() {
        let busy = vec![EpochSample {
            epoch: 0,
            data_cycles: 100,
            ..EpochSample::default()
        }];
        let busier = vec![EpochSample {
            epoch: 0,
            data_cycles: 300,
            ..EpochSample::default()
        }];
        let tl = Timeline {
            epoch_cycles: 64,
            channels: vec![busy, busier, Vec::new()],
        };
        assert_eq!(tl.channel_data_cycles(), vec![100, 300, 0]);
        assert!((tl.imbalance() - 1.5).abs() < 1e-12, "{}", tl.imbalance());
        let idle = Timeline {
            epoch_cycles: 64,
            channels: vec![Vec::new()],
        };
        assert_eq!(idle.imbalance(), 1.0);
    }

    #[test]
    fn json_shape_and_derived_rates() {
        let tl = Timeline {
            epoch_cycles: 100,
            channels: vec![vec![EpochSample {
                epoch: 2,
                data_cycles: 50,
                axi_bursts: 4,
                row_hits: 3,
                row_misses: 1,
                row_switches: 0,
                turnarounds: 1,
            }]],
        };
        let cfg = MemConfig::default(); // 8 B/beat, 100 MHz
        let j = tl.to_json(&cfg, 0.5);
        let ch = j.get("channels").and_then(Json::as_arr).unwrap();
        let e = ch[0].idx(0).unwrap();
        assert_eq!(e.get("epoch").and_then(Json::as_f64), Some(2.0));
        assert_eq!(e.get("bus_util").and_then(Json::as_f64), Some(0.5));
        assert_eq!(e.get("row_hit_rate").and_then(Json::as_f64), Some(0.75));
        // 50 beats × 8 B × 100 MHz / 100 cycles = 400 MB/s raw
        assert_eq!(e.get("raw_mb_s").and_then(Json::as_f64), Some(400.0));
        assert_eq!(e.get("eff_mb_s").and_then(Json::as_f64), Some(200.0));
        assert_eq!(
            j.get("totals").and_then(|t| t.get("data_cycles")).and_then(Json::as_f64),
            Some(50.0)
        );
        // byte-determinism: same integers → same bytes
        assert_eq!(
            j.to_string_pretty(),
            tl.to_json(&cfg, 0.5).to_string_pretty()
        );
    }
}
