//! Process-wide metrics registry: named counters, gauges, and histograms.
//!
//! The design problem this solves: the crate grew one ad-hoc pair of
//! `AtomicU64`s per cache (`TraceCache`, `SessionCache`, `PlanCache`)
//! plus hand-rolled depth/rejected counts in `serve`, and every surface
//! that wanted a number (the tune summary, the serve `stats` reply, the
//! benches) collected fields by hand. The registry replaces the
//! *plumbing*, not the *semantics*:
//!
//! - A metric handle ([`Counter`], [`Gauge`], [`Histogram`]) is a cheap
//!   clonable `Arc` around one relaxed `AtomicU64` cell. The owning
//!   struct keeps the handle exactly where its bare atomic used to
//!   live, so **per-instance counts are preserved** — two `TraceCache`s
//!   still count independently, which the cache tests pin.
//! - Creating a handle registers a [`Weak`] reference under a dotted
//!   name (`cfa.trace_cache.hits`). [`Registry::snapshot`] sums every
//!   live cell per name, so the process-wide view is the sum of the
//!   instance views, and dropping an instance removes its contribution.
//! - Reads and writes are `Ordering::Relaxed` — identical cost to the
//!   bare atomics these replace. There is no enable/disable knob here
//!   because the counters *are* the product (they feed `stats` replies
//!   and tune summaries); the disable fast path lives in
//!   [`crate::obs::span`], which records wall time.
//!
//! Naming scheme: `cfa.<subsystem>.<metric>`, all lowercase,
//! underscores inside segments. The scheme is documented in DESIGN.md
//! §Observability and asserted by `snapshot_names_are_sorted` below.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError, Weak};

use crate::util::json::Json;

/// One named atomic cell; the payload shared by [`Counter`] and
/// [`Gauge`].
#[derive(Debug)]
struct Cell {
    name: &'static str,
    value: AtomicU64,
}

impl Cell {
    fn new(name: &'static str) -> Arc<Cell> {
        Arc::new(Cell {
            name,
            value: AtomicU64::new(0),
        })
    }
}

/// Monotonically increasing counter handle.
///
/// Clones share the same cell, so a struct can hand out views of its
/// own counter (the caches do this for their `hits()` accessors).
#[derive(Clone, Debug)]
pub struct Counter(Arc<Cell>);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.0.value.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.value.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.value.load(Ordering::Relaxed)
    }
}

/// Up/down gauge handle (queue depth, active jobs).
///
/// `dec` saturates at zero rather than wrapping, so a stray unpaired
/// decrement shows up as a floor, not a number near `u64::MAX`.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<Cell>);

impl Gauge {
    #[inline]
    pub fn inc(&self) {
        self.0.value.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn dec(&self) {
        let _ = self
            .0
            .value
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.value.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.value.load(Ordering::Relaxed)
    }
}

/// Power-of-two bucketed histogram (count, sum, 32 log2 buckets).
///
/// Bucket `i` holds values whose bit length is `i` (bucket 0 is the
/// value zero); values with more than 31 significant bits land in the
/// last bucket. Good enough for latency-in-micros distributions without
/// any float math on the record path.
#[derive(Debug)]
struct HistCell {
    name: &'static str,
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; 32],
}

#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistCell>);

impl Histogram {
    #[inline]
    pub fn record(&self, v: u64) {
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
        let idx = (64 - v.leading_zeros() as usize).min(31);
        self.0.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    #[inline]
    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    /// Bucket counts (index = bit length of the recorded value).
    pub fn buckets(&self) -> [u64; 32] {
        let mut out = [0u64; 32];
        for (o, b) in out.iter_mut().zip(self.0.buckets.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }
}

/// The process-wide registry. Obtain it with [`registry`]; there is
/// exactly one per process.
pub struct Registry {
    counters: Mutex<Vec<Weak<Cell>>>,
    gauges: Mutex<Vec<Weak<Cell>>>,
    histograms: Mutex<Vec<Weak<HistCell>>>,
}

/// The process-wide registry singleton.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        counters: Mutex::new(Vec::new()),
        gauges: Mutex::new(Vec::new()),
        histograms: Mutex::new(Vec::new()),
    })
}

fn register<T>(slot: &Mutex<Vec<Weak<T>>>, cell: &Arc<T>) {
    let mut v = slot.lock().unwrap_or_else(PoisonError::into_inner);
    // prune cells whose owners dropped, so the registry does not grow
    // without bound across short-lived cache instances
    v.retain(|w| w.strong_count() > 0);
    v.push(Arc::downgrade(cell));
}

impl Registry {
    /// A fresh counter cell registered under `name`. Every call makes a
    /// new cell: instances count independently and `snapshot` sums.
    pub fn counter(&self, name: &'static str) -> Counter {
        let cell = Cell::new(name);
        register(&self.counters, &cell);
        Counter(cell)
    }

    /// A fresh gauge cell registered under `name`.
    pub fn gauge(&self, name: &'static str) -> Gauge {
        let cell = Cell::new(name);
        register(&self.gauges, &cell);
        Gauge(cell)
    }

    /// A fresh histogram registered under `name`.
    pub fn histogram(&self, name: &'static str) -> Histogram {
        let cell = Arc::new(HistCell {
            name,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        });
        register(&self.histograms, &cell);
        Histogram(cell)
    }

    /// Process-wide totals: every live cell summed per name, plus
    /// `<name>.count` / `<name>.sum` entries for histograms. Sorted by
    /// name (BTreeMap), so iteration order is deterministic.
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        let mut out: BTreeMap<String, u64> = BTreeMap::new();
        for kind in [&self.counters, &self.gauges] {
            let v = kind.lock().unwrap_or_else(PoisonError::into_inner);
            for cell in v.iter().filter_map(Weak::upgrade) {
                *out.entry(cell.name.to_string()).or_insert(0) +=
                    cell.value.load(Ordering::Relaxed);
            }
        }
        let v = self
            .histograms
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        for cell in v.iter().filter_map(Weak::upgrade) {
            *out.entry(format!("{}.count", cell.name)).or_insert(0) +=
                cell.count.load(Ordering::Relaxed);
            *out.entry(format!("{}.sum", cell.name)).or_insert(0) +=
                cell.sum.load(Ordering::Relaxed);
        }
        out
    }

    /// The snapshot as a flat JSON object (sorted keys, integer
    /// values) — the debugging/export face of the registry.
    pub fn to_json(&self) -> Json {
        let snap = self.snapshot();
        Json::obj(
            snap.iter()
                .map(|(k, v)| (k.as_str(), Json::num(*v as f64)))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instances_count_independently_and_snapshot_sums() {
        let a = registry().counter("cfa.test.metrics.independent");
        let b = registry().counter("cfa.test.metrics.independent");
        a.inc();
        a.inc();
        b.add(5);
        assert_eq!(a.get(), 2);
        assert_eq!(b.get(), 5);
        let snap = registry().snapshot();
        assert_eq!(snap["cfa.test.metrics.independent"], 7);
    }

    #[test]
    fn dropped_instances_leave_the_snapshot() {
        let a = registry().counter("cfa.test.metrics.dropped");
        a.add(3);
        assert_eq!(registry().snapshot()["cfa.test.metrics.dropped"], 3);
        drop(a);
        // a fresh registration triggers the prune sweep
        let _keep = registry().counter("cfa.test.metrics.dropped2");
        assert!(!registry()
            .snapshot()
            .contains_key("cfa.test.metrics.dropped"));
    }

    #[test]
    fn clones_share_one_cell() {
        let a = registry().counter("cfa.test.metrics.clone");
        let b = a.clone();
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2);
        assert_eq!(registry().snapshot()["cfa.test.metrics.clone"], 2);
    }

    #[test]
    fn gauge_saturates_at_zero() {
        let g = registry().gauge("cfa.test.metrics.gauge");
        g.inc();
        g.dec();
        g.dec();
        assert_eq!(g.get(), 0, "dec below zero floors instead of wrapping");
        g.set(9);
        assert_eq!(g.get(), 9);
    }

    #[test]
    fn histogram_counts_sum_and_buckets() {
        let h = registry().histogram("cfa.test.metrics.hist");
        h.record(0);
        h.record(1);
        h.record(2);
        h.record(3);
        h.record(u64::MAX);
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 0u64.wrapping_add(1 + 2 + 3).wrapping_add(u64::MAX));
        let b = h.buckets();
        assert_eq!(b[0], 1, "zero lands in bucket 0");
        assert_eq!(b[1], 1, "1 has bit length 1");
        assert_eq!(b[2], 2, "2 and 3 have bit length 2");
        assert_eq!(b[31], 1, "huge values clamp to the last bucket");
        let snap = registry().snapshot();
        assert_eq!(snap["cfa.test.metrics.hist.count"], 5);
    }

    #[test]
    fn snapshot_names_are_sorted() {
        let _a = registry().counter("cfa.test.metrics.z_last");
        let _b = registry().counter("cfa.test.metrics.a_first");
        let snap = registry().snapshot();
        let keys: Vec<&String> = snap.keys().collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
        // and the JSON face is an object, compact-printable
        let j = registry().to_json();
        assert!(j.to_string_compact().starts_with('{'));
    }
}
