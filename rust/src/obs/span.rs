//! Scoped, nestable span tracing with Chrome trace-event export.
//!
//! Spans are the *wall-time* half of the observability layer (the
//! cycle-time half is [`crate::obs::timeline`]). They answer "where did
//! this tune run spend its seconds" — compile vs plan vs marshal vs
//! replay vs per-point evaluate — which no aggregate counter can.
//!
//! The cost contract, in order of importance:
//!
//! 1. **Disabled is free.** [`span`] with no active capture is one
//!    relaxed atomic load and a two-word stack return — no allocation,
//!    no clock read, no lock (asserted by `tests/obs_alloc.rs`). Hot
//!    paths keep their instrumentation permanently; nobody pays until a
//!    `--profile` flag turns a capture on.
//! 2. **Enabled is honest but advisory.** Events carry wall-clock
//!    micros and go through one global mutex. Wall time is *never*
//!    allowed to feed back into anything deterministic: spans have no
//!    accessors that reports or journals could read, so a journal
//!    written under `--profile` is byte-identical to one without
//!    (pinned in `tests/trace_replay.rs`).
//!
//! Span ids are logical (a process-global monotonic counter), not
//! derived from time, so id assignment order is stable for a serial
//! run. Thread ids are small dense logical ids in first-use order.
//!
//! Capture model: [`begin_capture`] bumps a refcount that enables
//! recording and remembers the sink high-water mark; finishing drains
//! the events recorded since. Captures are designed to *enclose* the
//! spans they observe (the CLI wraps a whole tune; serve wraps a whole
//! job). Overlapping captures from concurrent serve requests each see
//! the union window — advisory by design, documented in DESIGN.md.
//!
//! Export is the Chrome trace-event JSON array format (`ph: "B"/"E"`
//! duration events), loadable in Perfetto or `chrome://tracing`.

use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};
use std::time::Instant;

use crate::util::{fsx, json::Json};

/// Number of active captures; recording is on while non-zero. The
/// relaxed load of this counter is the entire disabled fast path.
static ENABLED: AtomicUsize = AtomicUsize::new(0);

/// Next span id; ids are logical and process-monotonic, never reused.
/// Id 0 is reserved for "span recorded while disabled" (a no-op span).
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);

/// Next logical thread id, assigned densely in first-use order.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// One begin or end event, as exported.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    /// Logical span id shared by the B/E pair.
    pub id: u64,
    /// Static taxonomy name, e.g. `trace::compile` (see DESIGN.md).
    pub name: &'static str,
    /// `true` for the begin ("B") event, `false` for end ("E").
    pub begin: bool,
    /// Wall-clock microseconds since the process sink's origin.
    /// Advisory: feeds profiles only, never journals.
    pub ts_us: u64,
    /// Logical thread id (dense, first-use order).
    pub tid: u64,
}

struct Sink {
    origin: Instant,
    events: Vec<SpanEvent>,
}

fn sink() -> &'static Mutex<Sink> {
    static SINK: OnceLock<Mutex<Sink>> = OnceLock::new();
    SINK.get_or_init(|| {
        Mutex::new(Sink {
            origin: Instant::now(),
            events: Vec::new(),
        })
    })
}

/// Whether any capture is active. One relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed) != 0
}

/// The calling thread's logical tid (as stamped on its events).
pub fn current_tid() -> u64 {
    TID.with(|t| *t)
}

fn push_event(id: u64, name: &'static str, begin: bool) {
    let tid = TID.with(|t| *t);
    let mut s = sink().lock().unwrap_or_else(PoisonError::into_inner);
    let ts_us = s.origin.elapsed().as_micros() as u64;
    s.events.push(SpanEvent {
        id,
        name,
        begin,
        ts_us,
        tid,
    });
}

/// RAII guard for one span; dropping it records the end event. Close
/// order is LIFO by construction — the guard is a stack value.
pub struct Span {
    id: u64,
    name: &'static str,
}

/// Open a span named `name`. When no capture is active this returns an
/// inert guard without touching the clock, the sink, or the allocator.
#[inline]
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span { id: 0, name };
    }
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    push_event(id, name, true);
    Span { id, name }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.id != 0 {
            // record the end even if the capture just finished, so a
            // B inside a capture window is never left unbalanced by a
            // racing finish; the stray E lands before any later
            // capture's start mark and is dropped with the sink reset
            push_event(self.id, self.name, false);
        }
    }
}

/// An active capture window. Obtain with [`begin_capture`]; consume
/// with [`Capture::finish`] (events) or [`Capture::export`] (file).
/// Dropping without finishing discards the window's events.
pub struct Capture {
    start: usize,
    done: bool,
}

/// Start capturing spans. Enables recording process-wide (refcounted)
/// and marks the current sink position as this capture's start.
pub fn begin_capture() -> Capture {
    // hold the sink lock while enabling so no event can slip in
    // between reading the high-water mark and the enable becoming
    // visible — the mark is exact
    let s = sink().lock().unwrap_or_else(PoisonError::into_inner);
    let start = s.events.len();
    ENABLED.fetch_add(1, Ordering::Relaxed);
    drop(s);
    Capture { start, done: false }
}

fn end_capture(start: usize, want_events: bool) -> Vec<SpanEvent> {
    let mut s = sink().lock().unwrap_or_else(PoisonError::into_inner);
    let start = start.min(s.events.len());
    let out = if want_events {
        s.events[start..].to_vec()
    } else {
        Vec::new()
    };
    if ENABLED.fetch_sub(1, Ordering::Relaxed) == 1 {
        // last capture out resets the sink so the buffer never grows
        // across profiling sessions
        s.events.clear();
    }
    out
}

impl Capture {
    /// Stop capturing and return every event recorded in the window.
    pub fn finish(mut self) -> Vec<SpanEvent> {
        self.done = true;
        end_capture(self.start, true)
    }

    /// Stop capturing and write the window as Chrome trace-event JSON
    /// (Perfetto-loadable) via an atomic rename.
    pub fn export(self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let events = self.finish();
        fsx::write_atomic(path, trace_json(&events).to_string_pretty())
    }
}

impl Drop for Capture {
    fn drop(&mut self) {
        if !self.done {
            end_capture(self.start, false);
        }
    }
}

/// Chrome trace-event JSON for a slice of events:
/// `{"displayTimeUnit":"ms","traceEvents":[{"ph":"B",...},...]}`.
pub fn trace_json(events: &[SpanEvent]) -> Json {
    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        (
            "traceEvents",
            Json::arr(events.iter().map(|e| {
                Json::obj(vec![
                    (
                        "args",
                        Json::obj(vec![("span_id", Json::num(e.id as f64))]),
                    ),
                    ("cat", Json::str("cfa")),
                    ("name", Json::str(e.name)),
                    ("ph", Json::str(if e.begin { "B" } else { "E" })),
                    ("pid", Json::num(1)),
                    ("tid", Json::num(e.tid as f64)),
                    ("ts", Json::num(e.ts_us as f64)),
                ])
            })),
        ),
    ])
}

/// True when every begin has a matching end and, per thread, spans
/// close LIFO (properly nested). Used by tests and the CI smoke.
pub fn events_balanced(events: &[SpanEvent]) -> bool {
    use std::collections::HashMap;
    let mut stacks: HashMap<u64, Vec<u64>> = HashMap::new();
    for e in events {
        let stack = stacks.entry(e.tid).or_default();
        if e.begin {
            stack.push(e.id);
        } else {
            match stack.pop() {
                Some(top) if top == e.id => {}
                _ => return false,
            }
        }
    }
    stacks.values().all(Vec::is_empty)
}

#[cfg(test)]
mod tests {
    use super::*;

    // span tests share the process-global sink with every other test
    // in this binary (some of which hit instrumented code paths), so
    // they serialize on one mutex AND filter captured events down to
    // their own thread before asserting shapes
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn mine(events: Vec<SpanEvent>) -> Vec<SpanEvent> {
        let tid = current_tid();
        events.into_iter().filter(|e| e.tid == tid).collect()
    }

    #[test]
    fn disabled_spans_are_inert() {
        let _g = serial();
        let before = NEXT_SPAN_ID.load(Ordering::Relaxed);
        {
            let _s = span("test::inert");
        }
        // another test's capture may be active concurrently, in which
        // case the span above legitimately consumed an id; only assert
        // the strict no-id property when we observed disabled
        if !enabled() {
            assert_eq!(
                NEXT_SPAN_ID.load(Ordering::Relaxed),
                before,
                "no id is consumed while disabled"
            );
        }
        let cap = begin_capture();
        assert!(mine(cap.finish()).is_empty(), "nothing was recorded");
    }

    #[test]
    fn nested_spans_close_lifo_and_balance() {
        let _g = serial();
        let cap = begin_capture();
        {
            let _outer = span("test::outer");
            {
                let _inner = span("test::inner");
            }
            let _sibling = span("test::sibling");
        }
        let events = mine(cap.finish());
        assert_eq!(events.len(), 6, "three spans, B+E each");
        assert!(events_balanced(&events));
        let names: Vec<(&str, bool)> =
            events.iter().map(|e| (e.name, e.begin)).collect();
        assert_eq!(
            names,
            vec![
                ("test::outer", true),
                ("test::inner", true),
                ("test::inner", false),
                ("test::sibling", true),
                // sibling opened after inner closed, and closes before
                // outer: strict LIFO on one thread
                ("test::sibling", false),
                ("test::outer", false),
            ]
        );
    }

    #[test]
    fn span_ids_are_monotonic_within_a_capture() {
        let _g = serial();
        let cap = begin_capture();
        {
            let _a = span("test::a");
            let _b = span("test::b");
        }
        let events = mine(cap.finish());
        let begins: Vec<u64> =
            events.iter().filter(|e| e.begin).map(|e| e.id).collect();
        let mut sorted = begins.clone();
        sorted.sort_unstable();
        assert_eq!(begins, sorted, "begin order is id order on one thread");
    }

    #[test]
    fn capture_windows_do_not_leak_between_sessions() {
        let _g = serial();
        {
            let cap = begin_capture();
            let _s = span("test::first");
            drop(_s);
            let _ = cap.finish();
        }
        let cap = begin_capture();
        {
            let _s = span("test::second");
        }
        let events = mine(cap.finish());
        assert_eq!(events.len(), 2);
        assert!(events.iter().all(|e| e.name == "test::second"));
    }

    #[test]
    fn trace_json_shape_round_trips() {
        let _g = serial();
        let cap = begin_capture();
        {
            let _s = span("test::json");
        }
        let events = mine(cap.finish());
        let text = trace_json(&events).to_string_pretty();
        let parsed = crate::util::json::parse(&text).expect("valid JSON");
        let arr = parsed
            .get("traceEvents")
            .and_then(Json::as_arr)
            .expect("traceEvents array");
        assert_eq!(arr.len(), 2);
        let b = &arr[0];
        assert_eq!(b.get("ph").and_then(Json::as_str), Some("B"));
        assert_eq!(b.get("name").and_then(Json::as_str), Some("test::json"));
        assert_eq!(b.get("cat").and_then(Json::as_str), Some("cfa"));
        assert!(b.get("ts").and_then(Json::as_f64).is_some());
        assert_eq!(arr[1].get("ph").and_then(Json::as_str), Some("E"));
    }

    #[test]
    fn unbalanced_event_streams_are_rejected() {
        let b = |id, tid| SpanEvent {
            id,
            name: "x",
            begin: true,
            ts_us: 0,
            tid,
        };
        let e = |id, tid| SpanEvent {
            id,
            name: "x",
            begin: false,
            ts_us: 0,
            tid,
        };
        assert!(events_balanced(&[b(1, 1), b(2, 1), e(2, 1), e(1, 1)]));
        assert!(!events_balanced(&[b(1, 1), b(2, 1), e(1, 1), e(2, 1)]), "crossed close order");
        assert!(!events_balanced(&[b(1, 1)]), "dangling begin");
        assert!(!events_balanced(&[e(1, 1)]), "dangling end");
        assert!(
            events_balanced(&[b(1, 1), b(2, 2), e(2, 2), e(1, 1)]),
            "per-thread stacks are independent"
        );
    }
}
