//! Flow-in / flow-out sets and facets (§II.F, §IV.F, Appendix).
//!
//! For a tile T under a backwards uniform dependence pattern:
//!
//! * **flow-in(T)**  = iterations *outside* T whose results T reads
//!   (`{ y ∉ T : ∃j, ∃x ∈ T : x + B_j = y }` = ∪_j (T + B_j) \ T, clipped
//!   to the iteration space);
//! * **flow-out(T)** = iterations *of* T read by some other tile
//!   (`{ x ∈ T : ∃j : x - B_j ∈ E \ T }`);
//! * **facet S_k(T)** = the last `w_k` planes of T along axis k; the
//!   appendix proves flow-in(T) ⊆ ∪ facets of producer tiles, which is the
//!   correctness basis of CFA.

use crate::poly::deps::DepPattern;
use crate::poly::rect::{Rect, Region};
use crate::poly::tiling::Tiling;
use crate::poly::vec::{neg, IVec};

/// Flow-in region of tile `coords` (exact, disjoint union of rects).
pub fn flow_in(tiling: &Tiling, deps: &DepPattern, coords: &[i64]) -> Region {
    let t = tiling.tile_rect(coords);
    let space = tiling.space_rect();
    let mut out = Region::empty();
    for b in deps.vecs() {
        // producers read by T: T shifted by B, minus T itself.
        let shifted = t.shift(b).intersect(&space);
        for piece in shifted.subtract(&t) {
            out.add(piece);
        }
    }
    out
}

/// Flow-out region of tile `coords` (exact).
pub fn flow_out(tiling: &Tiling, deps: &DepPattern, coords: &[i64]) -> Region {
    let t = tiling.tile_rect(coords);
    let space = tiling.space_rect();
    let mut out = Region::empty();
    for b in deps.vecs() {
        // consumers of x ∈ T live at x - B; x is flow-out iff x - B is a
        // valid iteration outside T.
        let consumers_outside = t.shift(&neg(b)).intersect(&space);
        for piece in consumers_outside.subtract(&t) {
            out.add(piece.shift(b).intersect(&t));
        }
    }
    out
}

/// Facet S_k(T): the last `w_k` planes of tile T along axis k (§Appendix:
/// `S_k(T) = { x ∈ T : x_k mod t_k >= t_k - w_k }`). For boundary-clamped
/// tiles the facet is the last `w_k` planes of the *actual* tile extent.
pub fn facet(tiling: &Tiling, deps: &DepPattern, coords: &[i64], k: usize) -> Rect {
    let t = tiling.tile_rect(coords);
    let w = deps.width(k);
    let mut lo = t.lo.clone();
    lo[k] = (t.hi[k] - w).max(t.lo[k]);
    Rect::new(lo, t.hi)
}

/// All facets of a tile, one per active axis, in axis order.
pub fn facets(tiling: &Tiling, deps: &DepPattern, coords: &[i64]) -> Vec<(usize, Rect)> {
    deps.active_axes()
        .into_iter()
        .map(|k| (k, facet(tiling, deps, coords, k)))
        .collect()
}

/// Union of all facets of a tile.
pub fn facet_union(tiling: &Tiling, deps: &DepPattern, coords: &[i64]) -> Region {
    let mut out = Region::empty();
    for (_, f) in facets(tiling, deps, coords) {
        out.add(f);
    }
    out
}

/// The appendix theorem, checked pointwise: every flow-in point of `coords`
/// lies in a facet of the tile that produced it. Returns the offending point
/// if the property fails (used by property tests; `None` = holds).
pub fn coverage_violation(
    tiling: &Tiling,
    deps: &DepPattern,
    coords: &[i64],
) -> Option<IVec> {
    let fin = flow_in(tiling, deps, coords);
    for y in fin.all_points() {
        let producer = tiling.tile_of(&y);
        let in_some_facet = deps
            .active_axes()
            .iter()
            .any(|&k| facet(tiling, deps, &producer, k).contains(&y));
        if !in_some_facet {
            return Some(y);
        }
    }
    None
}

/// Neighbor tiles a tile reads from: the producer-tile coordinates of its
/// flow-in, with the neighbor level (number of differing coordinates).
/// For backwards patterns with w_k <= t_k these are exactly the tiles at
/// offsets δ ∈ {0,-1}^d \ {0} that actually carry flow (§IV.G–I).
pub fn producer_tiles(
    tiling: &Tiling,
    deps: &DepPattern,
    coords: &[i64],
) -> Vec<(IVec, usize)> {
    let fin = flow_in(tiling, deps, coords);
    let mut seen: Vec<IVec> = Vec::new();
    for r in fin.rects() {
        // a rect can span several producer tiles; enumerate the tile range
        // it covers.
        let lo_t = tiling.tile_of(&r.lo);
        let hi_pt: IVec = r.hi.iter().map(|h| h - 1).collect();
        let hi_t = tiling.tile_of(&hi_pt);
        let range = Rect::new(lo_t, hi_t.iter().map(|c| c + 1).collect());
        range.for_each_point(&mut |c| seen.push(c.to_vec()));
    }
    seen.sort();
    seen.dedup();
    seen.into_iter()
        .map(|c| {
            let lvl = crate::poly::vec::neighbor_level(&c, coords);
            (c, lvl)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run, Config};

    fn fig5_setup() -> (Tiling, DepPattern) {
        // 3D space tiled 5x5x5 like the paper's Figure 5; pattern with
        // w = (1, 1, 2).
        let tiling = Tiling::new(vec![15, 15, 15], vec![5, 5, 5]);
        let deps = DepPattern::new(vec![
            vec![-1, 0, 0],
            vec![0, -1, -1],
            vec![0, 0, -2],
            vec![-1, -1, -1],
        ])
        .unwrap();
        (tiling, deps)
    }

    #[test]
    fn facet_shapes_match_paper() {
        let (tiling, deps) = fig5_setup();
        assert_eq!(deps.widths(), vec![1, 1, 2]);
        // facet along i: rightmost plane, 5x5x... wait — last w_0=1 plane
        let f0 = facet(&tiling, &deps, &[1, 1, 1], 0);
        assert_eq!(f0, Rect::new(vec![9, 5, 5], vec![10, 10, 10]));
        assert_eq!(f0.volume(), 25);
        // facet along k: two last planes
        let f2 = facet(&tiling, &deps, &[1, 1, 1], 2);
        assert_eq!(f2, Rect::new(vec![5, 5, 8], vec![10, 10, 10]));
        assert_eq!(f2.volume(), 50);
    }

    #[test]
    fn flow_in_of_interior_tile() {
        let (tiling, deps) = fig5_setup();
        let fin = flow_in(&tiling, &deps, &[1, 1, 1]);
        // flow-in must be outside the tile and inside the space
        let t = tiling.tile_rect(&[1, 1, 1]);
        for p in fin.all_points() {
            assert!(!t.contains(&p));
            assert!(tiling.space_rect().contains(&p));
        }
        assert!(fin.volume() > 0);
    }

    #[test]
    fn corner_tile_has_no_flow_in() {
        let (tiling, deps) = fig5_setup();
        let fin = flow_in(&tiling, &deps, &[0, 0, 0]);
        assert_eq!(fin.volume(), 0);
    }

    #[test]
    fn last_tile_has_no_flow_out() {
        let (tiling, deps) = fig5_setup();
        let fout = flow_out(&tiling, &deps, &[2, 2, 2]);
        assert_eq!(fout.volume(), 0);
    }

    #[test]
    fn flow_out_is_inside_facets() {
        let (tiling, deps) = fig5_setup();
        let coords = vec![1, 1, 1];
        let fout = flow_out(&tiling, &deps, &coords);
        let fu = facet_union(&tiling, &deps, &coords);
        for p in fout.all_points() {
            assert!(fu.contains(&p), "flow-out point {p:?} outside facets");
        }
        // facets over-approximate: their union is at least the flow-out
        assert!(fu.volume() >= fout.volume());
    }

    #[test]
    fn coverage_theorem_on_fig5() {
        let (tiling, deps) = fig5_setup();
        for c in tiling.tiles() {
            assert_eq!(coverage_violation(&tiling, &deps, &c), None, "tile {c:?}");
        }
    }

    #[test]
    fn flow_in_out_duality() {
        // Duality: every flow-in point of a tile is a flow-out point of its
        // producer tile, and total flow-in >= total flow-out (a point at a
        // tile corner is read by several consumer tiles but counted once as
        // flow-out).
        let tiling = Tiling::new(vec![8, 8], vec![4, 4]);
        let deps = DepPattern::new(vec![vec![-1, 0], vec![0, -1]]).unwrap();
        let mut total_in = 0u64;
        for c in tiling.tiles() {
            let fin = flow_in(&tiling, &deps, &c);
            total_in += fin.volume();
            for p in fin.all_points() {
                let producer = tiling.tile_of(&p);
                assert!(
                    flow_out(&tiling, &deps, &producer).contains(&p),
                    "flow-in point {p:?} of tile {c:?} not flow-out of {producer:?}"
                );
            }
        }
        let total_out: u64 = tiling
            .tiles()
            .map(|c| flow_out(&tiling, &deps, &c).volume())
            .sum();
        assert!(total_in >= total_out);
        assert!(total_out > 0);
    }

    #[test]
    fn producer_tiles_are_backward_neighbors() {
        let (tiling, deps) = fig5_setup();
        let prods = producer_tiles(&tiling, &deps, &[1, 1, 1]);
        assert!(!prods.is_empty());
        for (c, lvl) in &prods {
            assert!(*lvl >= 1 && *lvl <= 3);
            for k in 0..3 {
                assert!(c[k] == 1 || c[k] == 0, "producer {c:?}");
            }
        }
        // includes the third-level corner neighbor (Fig 9)
        assert!(prods.iter().any(|(c, l)| *l == 3 && c == &vec![0, 0, 0]));
    }

    #[test]
    fn prop_coverage_theorem_random() {
        // The appendix proof, instantiated on random spaces/patterns/tiles.
        run("flow-in covered by producer facets", Config::small(40), |g| {
            let d = g.usize(2, 3);
            let tile: IVec = (0..d).map(|_| g.i64(2, 5)).collect();
            let space: IVec = tile.iter().map(|t| t * g.i64(2, 3)).collect();
            let tiling = Tiling::new(space, tile.clone());
            let nv = g.usize(1, 4);
            let vecs: Vec<IVec> = (0..nv)
                .map(|_| {
                    (0..d)
                        .map(|k| g.i64(-(tile[k].min(3)), 0))
                        .collect::<IVec>()
                })
                .filter(|v| !crate::poly::vec::is_zero(v))
                .collect();
            if vecs.is_empty() {
                return;
            }
            let deps = DepPattern::new(vecs).unwrap();
            for c in tiling.tiles() {
                assert_eq!(
                    coverage_violation(&tiling, &deps, &c),
                    None,
                    "tiling {tile:?} deps {deps} tile {c:?}"
                );
            }
        });
    }

    #[test]
    fn prop_flow_sets_disjoint_from_tile_interior_complement() {
        run("flow-out ⊆ T, flow-in ∩ T = ∅", Config::small(40), |g| {
            let d = g.usize(1, 3);
            let tile: IVec = (0..d).map(|_| g.i64(2, 4)).collect();
            let space: IVec = tile.iter().map(|t| t * 2).collect();
            let tiling = Tiling::new(space, tile);
            let v: IVec = (0..d).map(|_| g.i64(-2, 0)).collect();
            if crate::poly::vec::is_zero(&v) {
                return;
            }
            let deps = DepPattern::new(vec![v]).unwrap();
            for c in tiling.tiles() {
                let t = tiling.tile_rect(&c);
                for p in flow_out(&tiling, &deps, &c).all_points() {
                    assert!(t.contains(&p));
                }
                for p in flow_in(&tiling, &deps, &c).all_points() {
                    assert!(!t.contains(&p));
                }
            }
        });
    }
}
