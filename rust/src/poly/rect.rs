//! Hyperrectangles and disjoint rectangle unions.
//!
//! Under the paper's hypotheses (rectangular iteration space, rectangular
//! tiles, uniform dependences) every set we manipulate — tiles, facets,
//! flow-in / flow-out sets, bounding boxes — is a finite union of integer
//! hyperrectangles. This module is the project's "mini-ISL": exact set
//! algebra on half-open boxes.

use crate::poly::vec::IVec;

/// A half-open integer hyperrectangle `{ x : lo <= x < hi }`.
///
/// Empty iff `hi[k] <= lo[k]` for some k.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Rect {
    pub lo: IVec,
    pub hi: IVec,
}

impl Rect {
    pub fn new(lo: IVec, hi: IVec) -> Rect {
        assert_eq!(lo.len(), hi.len(), "Rect: dimension mismatch");
        Rect { lo, hi }
    }

    /// The box `[0, sizes)`.
    pub fn from_sizes(sizes: &[i64]) -> Rect {
        Rect::new(vec![0; sizes.len()], sizes.to_vec())
    }

    pub fn dims(&self) -> usize {
        self.lo.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lo.iter().zip(&self.hi).any(|(l, h)| h <= l)
    }

    /// Extent along dimension k (0 if empty along k).
    pub fn extent(&self, k: usize) -> i64 {
        (self.hi[k] - self.lo[k]).max(0)
    }

    /// Number of lattice points.
    pub fn volume(&self) -> u64 {
        if self.is_empty() {
            return 0;
        }
        (0..self.dims()).map(|k| self.extent(k) as u64).product()
    }

    pub fn contains(&self, p: &[i64]) -> bool {
        assert_eq!(p.len(), self.dims());
        p.iter()
            .zip(self.lo.iter().zip(&self.hi))
            .all(|(x, (l, h))| l <= x && x < h)
    }

    /// Intersection (possibly empty).
    pub fn intersect(&self, other: &Rect) -> Rect {
        assert_eq!(self.dims(), other.dims());
        Rect::new(
            self.lo
                .iter()
                .zip(&other.lo)
                .map(|(a, b)| *a.max(b))
                .collect(),
            self.hi
                .iter()
                .zip(&other.hi)
                .map(|(a, b)| *a.min(b))
                .collect(),
        )
    }

    /// Translate by `off`.
    pub fn shift(&self, off: &[i64]) -> Rect {
        Rect::new(
            self.lo.iter().zip(off).map(|(a, b)| a + b).collect(),
            self.hi.iter().zip(off).map(|(a, b)| a + b).collect(),
        )
    }

    /// Smallest rect containing both (empty operands ignored).
    pub fn hull(&self, other: &Rect) -> Rect {
        if self.is_empty() {
            return other.clone();
        }
        if other.is_empty() {
            return self.clone();
        }
        Rect::new(
            self.lo
                .iter()
                .zip(&other.lo)
                .map(|(a, b)| *a.min(b))
                .collect(),
            self.hi
                .iter()
                .zip(&other.hi)
                .map(|(a, b)| *a.max(b))
                .collect(),
        )
    }

    /// `self \ other` as disjoint rects (slab decomposition, axis by axis).
    pub fn subtract(&self, other: &Rect) -> Vec<Rect> {
        let inter = self.intersect(other);
        if inter.is_empty() {
            return if self.is_empty() {
                vec![]
            } else {
                vec![self.clone()]
            };
        }
        let mut out = Vec::new();
        // Peel slabs around the intersection, dimension by dimension;
        // `core` shrinks toward the intersection.
        let mut core = self.clone();
        for k in 0..self.dims() {
            if core.lo[k] < inter.lo[k] {
                let mut below = core.clone();
                below.hi[k] = inter.lo[k];
                out.push(below);
            }
            if inter.hi[k] < core.hi[k] {
                let mut above = core.clone();
                above.lo[k] = inter.hi[k];
                out.push(above);
            }
            core.lo[k] = inter.lo[k];
            core.hi[k] = inter.hi[k];
        }
        out.retain(|r| !r.is_empty());
        out
    }

    /// Row-major iterator over lattice points. Allocates one point per step;
    /// use only off the hot path (tests, planning — not the simulator loop).
    pub fn points(&self) -> PointIter {
        PointIter {
            rect: self.clone(),
            cur: if self.is_empty() {
                None
            } else {
                Some(self.lo.clone())
            },
        }
    }

    /// Visit every lattice point in row-major order through one reusable
    /// coordinate buffer — the hot-path replacement for [`Rect::points`]:
    /// no per-point heap allocation, same order, same set.
    pub fn for_each_point(&self, f: &mut dyn FnMut(&[i64])) {
        if self.is_empty() {
            return;
        }
        let d = self.dims();
        let mut p = self.lo.clone();
        loop {
            f(&p);
            // advance row-major (last dim fastest) with carry
            let mut k = d;
            loop {
                if k == 0 {
                    return;
                }
                k -= 1;
                p[k] += 1;
                if p[k] < self.hi[k] {
                    break;
                }
                p[k] = self.lo[k];
            }
        }
    }
}

/// Iterator over a rect's lattice points in row-major (last dim fastest) order.
pub struct PointIter {
    rect: Rect,
    cur: Option<IVec>,
}

impl Iterator for PointIter {
    type Item = IVec;

    fn next(&mut self) -> Option<IVec> {
        let cur = self.cur.as_mut()?;
        let out = cur.clone();
        // advance
        let d = self.rect.dims();
        let mut k = d;
        loop {
            if k == 0 {
                self.cur = None;
                break;
            }
            k -= 1;
            cur[k] += 1;
            if cur[k] < self.rect.hi[k] {
                break;
            }
            cur[k] = self.rect.lo[k];
        }
        Some(out)
    }
}

/// A finite union of **disjoint** rects. Insertion maintains disjointness by
/// subtracting existing members from every new rect.
#[derive(Clone, Debug, Default)]
pub struct Region {
    rects: Vec<Rect>,
}

impl Region {
    pub fn empty() -> Region {
        Region { rects: Vec::new() }
    }

    pub fn of(rect: Rect) -> Region {
        let mut r = Region::empty();
        r.add(rect);
        r
    }

    pub fn rects(&self) -> &[Rect] {
        &self.rects
    }

    pub fn is_empty(&self) -> bool {
        self.rects.is_empty()
    }

    /// Insert a rect, keeping the union disjoint.
    pub fn add(&mut self, rect: Rect) {
        if rect.is_empty() {
            return;
        }
        let mut pieces = vec![rect];
        for existing in &self.rects {
            let mut next = Vec::new();
            for p in pieces {
                next.extend(p.subtract(existing));
            }
            pieces = next;
            if pieces.is_empty() {
                return;
            }
        }
        self.rects.extend(pieces);
    }

    /// Union in another region.
    pub fn add_region(&mut self, other: &Region) {
        for r in &other.rects {
            self.add(r.clone());
        }
    }

    /// Remove all points of `rect` from the region.
    pub fn subtract_rect(&mut self, rect: &Rect) {
        let mut next = Vec::new();
        for r in &self.rects {
            next.extend(r.subtract(rect));
        }
        self.rects = next;
    }

    /// Total number of lattice points (exact: members are disjoint).
    pub fn volume(&self) -> u64 {
        self.rects.iter().map(|r| r.volume()).sum()
    }

    pub fn contains(&self, p: &[i64]) -> bool {
        self.rects.iter().any(|r| r.contains(p))
    }

    /// Bounding box of the union (empty rect of dim 0 if region is empty).
    pub fn bbox(&self) -> Option<Rect> {
        let mut it = self.rects.iter();
        let first = it.next()?.clone();
        Some(it.fold(first, |acc, r| acc.hull(r)))
    }

    /// All lattice points (testing / planning only).
    pub fn all_points(&self) -> Vec<IVec> {
        self.rects.iter().flat_map(|r| r.points()).collect()
    }

    /// Clip every member to `window`.
    pub fn intersect_rect(&self, window: &Rect) -> Region {
        let mut out = Region::empty();
        for r in &self.rects {
            out.add(r.intersect(window));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run, Config};

    fn r2(lo: [i64; 2], hi: [i64; 2]) -> Rect {
        Rect::new(lo.to_vec(), hi.to_vec())
    }

    #[test]
    fn basic_geometry() {
        let r = r2([0, 0], [4, 3]);
        assert_eq!(r.volume(), 12);
        assert!(r.contains(&[0, 0]));
        assert!(r.contains(&[3, 2]));
        assert!(!r.contains(&[4, 0]));
        assert!(!r.is_empty());
        assert!(r2([2, 2], [2, 5]).is_empty());
    }

    #[test]
    fn intersect_shift_hull() {
        let a = r2([0, 0], [4, 4]);
        let b = r2([2, 1], [6, 3]);
        let i = a.intersect(&b);
        assert_eq!(i, r2([2, 1], [4, 3]));
        assert_eq!(a.shift(&[1, -1]), r2([1, -1], [5, 3]));
        assert_eq!(a.hull(&b), r2([0, 0], [6, 4]));
    }

    #[test]
    fn subtract_produces_disjoint_exact_cover() {
        let a = r2([0, 0], [5, 5]);
        let b = r2([1, 1], [3, 4]);
        let parts = a.subtract(&b);
        let vol: u64 = parts.iter().map(|p| p.volume()).sum();
        assert_eq!(vol, 25 - 6);
        // each point of a is in exactly one of parts ∪ {a∩b}
        for p in a.points() {
            let in_parts = parts.iter().filter(|r| r.contains(&p)).count();
            let in_b = b.contains(&p) as usize;
            assert_eq!(in_parts + in_b, 1, "point {p:?}");
        }
    }

    #[test]
    fn subtract_disjoint_and_containing() {
        let a = r2([0, 0], [2, 2]);
        assert_eq!(a.subtract(&r2([5, 5], [6, 6])), vec![a.clone()]);
        assert!(a.subtract(&r2([-1, -1], [3, 3])).is_empty());
    }

    #[test]
    fn point_iteration_row_major() {
        let r = r2([1, 1], [3, 3]);
        let pts: Vec<IVec> = r.points().collect();
        assert_eq!(
            pts,
            vec![vec![1, 1], vec![1, 2], vec![2, 1], vec![2, 2]]
        );
        assert_eq!(r2([0, 0], [0, 5]).points().count(), 0);
    }

    #[test]
    fn for_each_point_matches_points() {
        run("for_each_point ≡ points()", Config::small(60), |g| {
            let d = g.usize(0, 3);
            let lo: IVec = (0..d).map(|_| g.i64(-3, 3)).collect();
            let ext: IVec = (0..d).map(|_| g.i64(0, 4)).collect();
            let hi: IVec = lo.iter().zip(&ext).map(|(l, e)| l + e).collect();
            let r = Rect::new(lo, hi);
            let mut seen: Vec<IVec> = Vec::new();
            r.for_each_point(&mut |p| seen.push(p.to_vec()));
            let boxed: Vec<IVec> = r.points().collect();
            assert_eq!(seen, boxed);
        });
    }

    #[test]
    fn region_union_dedupes_overlap() {
        let mut reg = Region::empty();
        reg.add(r2([0, 0], [4, 4]));
        reg.add(r2([2, 2], [6, 6]));
        assert_eq!(reg.volume(), 16 + 16 - 4);
        assert!(reg.contains(&[5, 5]));
        assert!(!reg.contains(&[5, 0]));
    }

    #[test]
    fn region_bbox_and_subtract() {
        let mut reg = Region::empty();
        reg.add(r2([0, 0], [2, 2]));
        reg.add(r2([4, 4], [6, 6]));
        assert_eq!(reg.bbox().unwrap(), r2([0, 0], [6, 6]));
        reg.subtract_rect(&r2([0, 0], [6, 5]));
        assert_eq!(reg.volume(), 2);
    }

    #[test]
    fn prop_region_volume_equals_point_count() {
        run("region volume == |points|", Config::small(60), |g| {
            let d = g.usize(1, 3);
            let mut reg = Region::empty();
            let mut naive: Vec<IVec> = Vec::new();
            for _ in 0..g.usize(1, 4) {
                let lo: IVec = (0..d).map(|_| g.i64(-3, 3)).collect();
                let ext: IVec = (0..d).map(|_| g.i64(0, 4)).collect();
                let hi: IVec = lo.iter().zip(&ext).map(|(l, e)| l + e).collect();
                let r = Rect::new(lo, hi);
                for p in r.points() {
                    if !naive.contains(&p) {
                        naive.push(p);
                    }
                }
                reg.add(r);
            }
            assert_eq!(reg.volume(), naive.len() as u64);
            for p in &naive {
                assert!(reg.contains(p));
            }
        });
    }

    #[test]
    fn prop_subtract_partition() {
        run("a\\b ⊎ a∩b partitions a", Config::small(60), |g| {
            let d = g.usize(1, 3);
            let mk = |g: &crate::util::prop::Gen| {
                let lo: IVec = (0..d).map(|_| g.i64(-4, 4)).collect();
                let ext: IVec = (0..d).map(|_| g.i64(0, 5)).collect();
                let hi: IVec = lo.iter().zip(&ext).map(|(l, e)| l + e).collect();
                Rect::new(lo, hi)
            };
            let a = mk(g);
            let b = mk(g);
            let parts = a.subtract(&b);
            // disjointness of parts
            let vol: u64 = parts.iter().map(|r| r.volume()).sum();
            assert_eq!(vol + a.intersect(&b).volume(), a.volume());
            for p in a.points() {
                let n = parts.iter().filter(|r| r.contains(&p)).count()
                    + b.contains(&p) as usize;
                assert_eq!(n, 1);
            }
        });
    }
}
