//! Uniform dependence patterns and skew normalization.
//!
//! A dependence vector `B` means iteration `x` reads the value produced by
//! iteration `x + B` (§II.G). CFA's construction (§IV.E) assumes every
//! vector is *backwards* in every dimension (`B·e_k <= 0` for all k); the
//! paper expects a pre-processing basis change when that does not hold
//! (e.g. raw Jacobi has `(-1, +1)` components). [`Skew`] implements that
//! change of basis for the common outer-sequential case.

use crate::poly::vec::{all_non_positive, ceil_div, is_zero, IVec};
use std::fmt;

/// Errors from pattern construction / normalization.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum DepError {
    #[error("dependence vector {0:?} is zero")]
    ZeroVector(Vec<i64>),
    #[error("dependence vectors have inconsistent dimensions")]
    DimMismatch,
    #[error("dependence vector {0:?} is not backwards (some component > 0)")]
    NotBackwards(Vec<i64>),
    #[error("cannot skew-normalize: vector {0:?} has a positive component but a zero leading component")]
    NotSkewable(Vec<i64>),
}

/// A set of uniform dependence vectors, all backwards in all dimensions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DepPattern {
    vecs: Vec<IVec>,
    dims: usize,
}

impl DepPattern {
    /// Build a validated backwards pattern.
    pub fn new(vecs: Vec<IVec>) -> Result<DepPattern, DepError> {
        let dims = vecs.first().map(|v| v.len()).unwrap_or(0);
        for v in &vecs {
            if v.len() != dims {
                return Err(DepError::DimMismatch);
            }
            if is_zero(v) {
                return Err(DepError::ZeroVector(v.clone()));
            }
            if !all_non_positive(v) {
                return Err(DepError::NotBackwards(v.clone()));
            }
        }
        Ok(DepPattern { vecs, dims })
    }

    pub fn dims(&self) -> usize {
        self.dims
    }

    pub fn vecs(&self) -> &[IVec] {
        &self.vecs
    }

    pub fn len(&self) -> usize {
        self.vecs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vecs.is_empty()
    }

    /// Facet thickness along axis k (§IV.F.3):
    /// `w_k = max_q | e_k · B_q |`.
    pub fn width(&self, k: usize) -> i64 {
        self.vecs.iter().map(|v| v[k].abs()).max().unwrap_or(0)
    }

    /// All facet thicknesses.
    pub fn widths(&self) -> IVec {
        (0..self.dims).map(|k| self.width(k)).collect()
    }

    /// Axes with non-zero thickness (axes that actually carry flow).
    pub fn active_axes(&self) -> Vec<usize> {
        (0..self.dims).filter(|&k| self.width(k) > 0).collect()
    }
}

impl fmt::Display for DepPattern {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let parts: Vec<String> = self
            .vecs
            .iter()
            .map(|v| crate::poly::vec::fmt_vec(v))
            .collect();
        write!(f, "{{{}}}", parts.join(", "))
    }
}

/// A skewing basis change `x'_k = x_k + f_k * x_0` (f_0 = 0), the standard
/// normalization that makes stencil-like patterns backwards when the outer
/// (time) dimension is strictly sequential.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Skew {
    pub factors: IVec,
}

impl Skew {
    /// Identity skew for `dims` dimensions.
    pub fn identity(dims: usize) -> Skew {
        Skew {
            factors: vec![0; dims],
        }
    }

    pub fn is_identity(&self) -> bool {
        self.factors.iter().all(|&f| f == 0)
    }

    /// Apply to a point.
    pub fn apply(&self, x: &[i64]) -> IVec {
        let mut out = x.to_vec();
        for k in 1..x.len() {
            out[k] += self.factors[k] * x[0];
        }
        out
    }

    /// Inverse transform.
    pub fn unapply(&self, x: &[i64]) -> IVec {
        let mut out = x.to_vec();
        for k in 1..x.len() {
            out[k] -= self.factors[k] * x[0];
        }
        out
    }

    /// Apply to a dependence vector (dependence vectors transform like
    /// points because the map is linear).
    pub fn apply_dep(&self, b: &[i64]) -> IVec {
        self.apply(b)
    }
}

/// Normalize an arbitrary uniform pattern into a backwards one using a skew.
///
/// Requires: every vector with a positive component somewhere has a strictly
/// negative leading component (outer-sequential programs: stencils over
/// time, wavefront DP…). Returns the skew and the normalized pattern.
pub fn normalize(vecs: &[IVec]) -> Result<(Skew, DepPattern), DepError> {
    let dims = vecs.first().map(|v| v.len()).unwrap_or(0);
    for v in vecs {
        if v.len() != dims {
            return Err(DepError::DimMismatch);
        }
        if is_zero(v) {
            return Err(DepError::ZeroVector(v.clone()));
        }
    }
    let mut factors = vec![0i64; dims];
    for k in 1..dims {
        let mut f = 0i64;
        for v in vecs {
            if v[k] > 0 {
                if v[0] >= 0 {
                    return Err(DepError::NotSkewable(v.clone()));
                }
                // need v[k] + f * v[0] <= 0  =>  f >= v[k] / -v[0]
                f = f.max(ceil_div(v[k], -v[0]));
            }
        }
        factors[k] = f;
    }
    let skew = Skew { factors };
    let skewed: Vec<IVec> = vecs.iter().map(|v| skew.apply_dep(v)).collect();
    let pat = DepPattern::new(skewed)?;
    Ok((skew, pat))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run, Config};

    #[test]
    fn widths_of_figure5_pattern() {
        // Fig 5a-like pattern: thickness 1 along i, 2 along k.
        let p = DepPattern::new(vec![vec![-1, 0, -1], vec![0, -1, -2], vec![0, 0, -1]])
            .unwrap();
        assert_eq!(p.widths(), vec![1, 1, 2]);
        assert_eq!(p.active_axes(), vec![0, 1, 2]);
    }

    #[test]
    fn rejects_zero_and_forward() {
        assert_eq!(
            DepPattern::new(vec![vec![0, 0]]),
            Err(DepError::ZeroVector(vec![0, 0]))
        );
        assert_eq!(
            DepPattern::new(vec![vec![-1, 1]]),
            Err(DepError::NotBackwards(vec![-1, 1]))
        );
        assert_eq!(
            DepPattern::new(vec![vec![-1], vec![-1, 0]]),
            Err(DepError::DimMismatch)
        );
    }

    #[test]
    fn jacobi_5p_normalizes_with_unit_skew() {
        // A_t[i,j] uses A_{t-1}[i+di, j+dj], di/dj in cross pattern.
        let raw = vec![
            vec![-1, 0, 0],
            vec![-1, 1, 0],
            vec![-1, -1, 0],
            vec![-1, 0, 1],
            vec![-1, 0, -1],
        ];
        let (skew, pat) = normalize(&raw).unwrap();
        assert_eq!(skew.factors, vec![0, 1, 1]);
        assert_eq!(pat.widths(), vec![1, 2, 2]);
        // skew round-trips points
        let x = vec![3, 5, 7];
        assert_eq!(skew.unapply(&skew.apply(&x)), x);
    }

    #[test]
    fn gaussian_5x5_normalizes_with_skew_two() {
        let mut raw = Vec::new();
        for di in -2..=2 {
            for dj in -2..=2 {
                raw.push(vec![-1, di, dj]);
            }
        }
        let (skew, pat) = normalize(&raw).unwrap();
        assert_eq!(skew.factors, vec![0, 2, 2]);
        assert_eq!(pat.widths(), vec![1, 4, 4]);
    }

    #[test]
    fn already_backwards_needs_no_skew() {
        let raw = vec![vec![0, -1, 0], vec![-1, -1, -1], vec![0, 0, -1]];
        let (skew, pat) = normalize(&raw).unwrap();
        assert!(skew.is_identity());
        assert_eq!(pat.vecs().len(), 3);
    }

    #[test]
    fn unskewable_is_an_error() {
        // positive component with zero leading component
        let raw = vec![vec![0, 1]];
        assert!(matches!(normalize(&raw), Err(DepError::NotSkewable(_))));
    }

    #[test]
    fn prop_normalize_yields_backwards() {
        run("normalize => all non-positive", Config::small(120), |g| {
            let d = g.usize(2, 4);
            let n = g.usize(1, 6);
            let vecs: Vec<IVec> = (0..n)
                .map(|_| {
                    let mut v: IVec = (0..d).map(|_| g.i64(-3, 3)).collect();
                    v[0] = g.i64(-3, -1); // outer-sequential
                    v
                })
                .collect();
            let (skew, pat) = normalize(&vecs).expect("skewable");
            for v in pat.vecs() {
                assert!(all_non_positive(v), "{v:?}");
            }
            // skew is a bijection on points
            let p: IVec = (0..d).map(|_| g.i64(-10, 10)).collect();
            assert_eq!(skew.unapply(&skew.apply(&p)), p);
        });
    }
}
