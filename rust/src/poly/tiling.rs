//! Rectangular iteration spaces and rectangular tilings (§II.C, §IV.D).

use crate::poly::rect::Rect;
use crate::poly::vec::{ceil_div, ediv, IVec};

/// A rectangular iteration space `[0, N_1) x ... x [0, N_d)` partitioned
/// into hyperrectangular tiles of size `t_1 x ... x t_d`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tiling {
    /// Iteration space sizes N_k.
    pub space: IVec,
    /// Tile sizes t_k.
    pub tile: IVec,
}

impl Tiling {
    /// Create a tiling. Panics on inconsistent dimensions or non-positive
    /// sizes; tile sizes are clamped to the space (a tile larger than the
    /// space along an axis means "no tiling on that axis", §Appendix).
    pub fn new(space: IVec, tile: IVec) -> Tiling {
        assert_eq!(space.len(), tile.len(), "Tiling: dimension mismatch");
        assert!(space.iter().all(|&n| n > 0), "space sizes must be positive");
        assert!(tile.iter().all(|&t| t > 0), "tile sizes must be positive");
        let tile = tile
            .iter()
            .zip(&space)
            .map(|(t, n)| (*t).min(*n))
            .collect();
        Tiling { space, tile }
    }

    pub fn dims(&self) -> usize {
        self.space.len()
    }

    /// The full iteration space as a rect.
    pub fn space_rect(&self) -> Rect {
        Rect::from_sizes(&self.space)
    }

    /// True iff `p` is an iteration point of the space. Equivalent to
    /// `space_rect().contains(p)` but allocation-free (the address-generation
    /// fast path calls this per point). Panics on a wrong-arity point, like
    /// `Rect::contains` does — a truncated point must never pass silently.
    #[inline]
    pub fn in_space(&self, p: &[i64]) -> bool {
        assert_eq!(p.len(), self.dims(), "in_space: dimension mismatch");
        p.iter().zip(&self.space).all(|(x, n)| 0 <= *x && x < n)
    }

    /// Number of tiles along each axis (ceil — boundary tiles may be
    /// partial when sizes do not divide).
    pub fn tile_counts(&self) -> IVec {
        self.space
            .iter()
            .zip(&self.tile)
            .map(|(n, t)| ceil_div(*n, *t))
            .collect()
    }

    /// Total number of tiles.
    pub fn num_tiles(&self) -> u64 {
        self.tile_counts().iter().map(|&c| c as u64).product()
    }

    /// True iff every tile size divides its space size (the experiments use
    /// divisible configurations, as the paper does).
    pub fn is_exact(&self) -> bool {
        self.space
            .iter()
            .zip(&self.tile)
            .all(|(n, t)| n % t == 0)
    }

    /// The iteration rect of tile `coords` (clamped at the space boundary).
    pub fn tile_rect(&self, coords: &[i64]) -> Rect {
        assert_eq!(coords.len(), self.dims());
        let lo: IVec = coords
            .iter()
            .zip(&self.tile)
            .map(|(c, t)| c * t)
            .collect();
        let hi: IVec = lo
            .iter()
            .zip(self.tile.iter().zip(&self.space))
            .map(|(l, (t, n))| (l + t).min(*n))
            .collect();
        Rect::new(lo, hi)
    }

    /// Tile coordinates containing iteration point `p` (valid for any
    /// integer point, including outside the space).
    pub fn tile_of(&self, p: &[i64]) -> IVec {
        assert_eq!(p.len(), self.dims());
        p.iter().zip(&self.tile).map(|(x, t)| ediv(*x, *t)).collect()
    }

    /// True iff `coords` is a valid tile of this tiling.
    pub fn tile_in_range(&self, coords: &[i64]) -> bool {
        coords
            .iter()
            .zip(&self.tile_counts())
            .all(|(c, n)| (0..*n).contains(c))
    }

    /// Iterate all tile coordinates in lexicographic order — a legal
    /// schedule for backwards dependence patterns (§II.D: tiles are atomic;
    /// lexicographic order respects every non-positive dependence).
    pub fn tiles(&self) -> impl Iterator<Item = IVec> {
        Rect::from_sizes(&self.tile_counts()).points()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run, Config};

    #[test]
    fn exact_tiling_counts() {
        let t = Tiling::new(vec![32, 64], vec![16, 16]);
        assert_eq!(t.tile_counts(), vec![2, 4]);
        assert_eq!(t.num_tiles(), 8);
        assert!(t.is_exact());
    }

    #[test]
    fn partial_boundary_tiles_are_clamped() {
        let t = Tiling::new(vec![10], vec![4]);
        assert_eq!(t.tile_counts(), vec![3]);
        assert!(!t.is_exact());
        assert_eq!(t.tile_rect(&[2]), Rect::new(vec![8], vec![10]));
    }

    #[test]
    fn oversized_tile_clamps_to_space() {
        let t = Tiling::new(vec![8, 8], vec![100, 4]);
        assert_eq!(t.tile, vec![8, 4]);
        assert_eq!(t.tile_counts(), vec![1, 2]);
    }

    #[test]
    fn in_space_matches_space_rect() {
        let t = Tiling::new(vec![6, 4], vec![3, 2]);
        for p in [[0, 0], [5, 3], [6, 0], [0, 4], [-1, 1], [3, 2]] {
            assert_eq!(t.in_space(&p), t.space_rect().contains(&p), "{p:?}");
        }
    }

    #[test]
    fn tile_of_points() {
        let t = Tiling::new(vec![20, 20], vec![5, 5]);
        assert_eq!(t.tile_of(&[0, 0]), vec![0, 0]);
        assert_eq!(t.tile_of(&[4, 5]), vec![0, 1]);
        assert_eq!(t.tile_of(&[-1, 0]), vec![-1, 0]); // outside the space
        assert!(t.tile_in_range(&[3, 3]));
        assert!(!t.tile_in_range(&[4, 0]));
    }

    #[test]
    fn tiles_iterator_is_lexicographic_and_complete() {
        let t = Tiling::new(vec![4, 6], vec![2, 3]);
        let tiles: Vec<IVec> = t.tiles().collect();
        assert_eq!(tiles.len(), 4);
        assert_eq!(tiles[0], vec![0, 0]);
        assert_eq!(tiles[3], vec![1, 1]);
        let mut sorted = tiles.clone();
        sorted.sort();
        assert_eq!(sorted, tiles);
    }

    #[test]
    fn prop_tiles_partition_space() {
        run("tiles partition the space", Config::small(60), |g| {
            let d = g.usize(1, 3);
            let space: IVec = (0..d).map(|_| g.i64(1, 12)).collect();
            let tile: IVec = (0..d).map(|_| g.i64(1, 6)).collect();
            let t = Tiling::new(space.clone(), tile);
            // every point belongs to exactly one tile rect
            for p in Rect::from_sizes(&space).points() {
                let c = t.tile_of(&p);
                assert!(t.tile_in_range(&c), "{p:?} -> {c:?}");
                assert!(t.tile_rect(&c).contains(&p));
            }
            // total volume matches
            let vol: u64 = t.tiles().map(|c| t.tile_rect(&c).volume()).sum();
            assert_eq!(vol, Rect::from_sizes(&space).volume());
        });
    }
}
