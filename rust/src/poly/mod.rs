//! Rectangular-polyhedral substrate (the project's "mini-ISL").
//!
//! The paper's hypotheses (§IV.E: uniform dependences, rectangular tiling,
//! dense data) close the polyhedral model over hyperrectangles: iteration
//! spaces, tiles, facets and flow sets are all finite unions of integer
//! boxes, and every transformation CFA needs (modulo projection, data
//! tiling, dimension permutation) is closed-form. This module implements
//! that exact algebra; no general ILP/Presburger machinery is required.

pub mod deps;
pub mod flow;
pub mod rect;
pub mod tiling;
pub mod vec;

pub use deps::{normalize, DepError, DepPattern, Skew};
pub use flow::{coverage_violation, facet, facet_union, facets, flow_in, flow_out, producer_tiles};
pub use rect::{Rect, Region};
pub use tiling::Tiling;
pub use vec::IVec;
