//! Integer vectors (points, dependence vectors, offsets).
//!
//! Dimensions are tiny (2–4 in every benchmark) so a plain `Vec<i64>` with
//! free functions is the representation; no SIMD or smallvec tricks needed
//! outside the simulator hot path (which never allocates per point).

/// An integer vector / lattice point.
pub type IVec = Vec<i64>;

/// Dot product. Panics on dimension mismatch.
pub fn dot(a: &[i64], b: &[i64]) -> i64 {
    assert_eq!(a.len(), b.len(), "dot: dimension mismatch");
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Component-wise sum.
pub fn add(a: &[i64], b: &[i64]) -> IVec {
    assert_eq!(a.len(), b.len(), "add: dimension mismatch");
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

/// Component-wise difference `a - b`.
pub fn sub(a: &[i64], b: &[i64]) -> IVec {
    assert_eq!(a.len(), b.len(), "sub: dimension mismatch");
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Negation.
pub fn neg(a: &[i64]) -> IVec {
    a.iter().map(|x| -x).collect()
}

/// Scalar multiple.
pub fn scale(a: &[i64], k: i64) -> IVec {
    a.iter().map(|x| x * k).collect()
}

/// True iff every component is `<= 0` (the paper's "backwards in all
/// dimensions" hypothesis for dependence vectors).
pub fn all_non_positive(a: &[i64]) -> bool {
    a.iter().all(|&x| x <= 0)
}

/// True iff the vector is all zeros.
pub fn is_zero(a: &[i64]) -> bool {
    a.iter().all(|&x| x == 0)
}

/// Euclidean-style modulo with non-negative result (`x mod m`, m > 0).
pub fn emod(x: i64, m: i64) -> i64 {
    debug_assert!(m > 0);
    ((x % m) + m) % m
}

/// Floor division (`⌊x / m⌋`, m > 0) — tile coordinate of a point coordinate.
pub fn ediv(x: i64, m: i64) -> i64 {
    debug_assert!(m > 0);
    let q = x / m;
    if x % m < 0 {
        q - 1
    } else {
        q
    }
}

/// Ceiling division for non-negative operands.
pub fn ceil_div(x: i64, m: i64) -> i64 {
    debug_assert!(m > 0);
    ediv(x + m - 1, m)
}

/// Number of coordinates in which `a` and `b` differ — the *neighbor level*
/// between two tiles (§IV.D: first-level neighbors differ along exactly one
/// canonical axis, k-th level along exactly k).
pub fn neighbor_level(a: &[i64], b: &[i64]) -> usize {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| x != y).count()
}

/// Render as `(a, b, c)`.
pub fn fmt_vec(a: &[i64]) -> String {
    let inner: Vec<String> = a.iter().map(|x| x.to_string()).collect();
    format!("({})", inner.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_arith() {
        assert_eq!(dot(&[1, 2, 3], &[4, 5, 6]), 32);
        assert_eq!(add(&[1, 2], &[3, -1]), vec![4, 1]);
        assert_eq!(sub(&[1, 2], &[3, -1]), vec![-2, 3]);
        assert_eq!(neg(&[1, -2]), vec![-1, 2]);
        assert_eq!(scale(&[1, -2], 3), vec![3, -6]);
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn dot_rejects_mismatch() {
        dot(&[1], &[1, 2]);
    }

    #[test]
    fn predicates() {
        assert!(all_non_positive(&[0, -1, -3]));
        assert!(!all_non_positive(&[0, 1]));
        assert!(is_zero(&[0, 0]));
        assert!(!is_zero(&[0, 1]));
    }

    #[test]
    fn euclidean_mod_div() {
        assert_eq!(emod(7, 5), 2);
        assert_eq!(emod(-1, 5), 4);
        assert_eq!(emod(-5, 5), 0);
        assert_eq!(ediv(7, 5), 1);
        assert_eq!(ediv(-1, 5), -1);
        assert_eq!(ediv(-5, 5), -1);
        assert_eq!(ediv(-6, 5), -2);
        // invariant: x == ediv(x,m)*m + emod(x,m)
        for x in -20..20 {
            assert_eq!(x, ediv(x, 5) * 5 + emod(x, 5));
        }
    }

    #[test]
    fn ceil_division() {
        assert_eq!(ceil_div(10, 5), 2);
        assert_eq!(ceil_div(11, 5), 3);
        assert_eq!(ceil_div(0, 5), 0);
    }

    #[test]
    fn neighbor_levels() {
        assert_eq!(neighbor_level(&[1, 1, 1], &[1, 1, 1]), 0);
        assert_eq!(neighbor_level(&[1, 1, 1], &[1, 2, 1]), 1);
        assert_eq!(neighbor_level(&[1, 1, 1], &[0, 2, 1]), 2);
        assert_eq!(neighbor_level(&[1, 1, 1], &[0, 2, 0]), 3);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_vec(&[1, -2, 3]), "(1, -2, 3)");
    }
}
