//! Declarative exploration spaces: what the tuner is allowed to try.
//!
//! A [`Space`] is the cross product of workloads (each with its own tile
//! candidates), layouts (registry names; empty = every registered layout),
//! memory-interface variants (named [`MemConfig`] overrides — burst width,
//! element width, outstanding window, …), channel counts × striping
//! policies (the multi-channel "memory wall" axes) and modeled PE
//! throughputs. [`Space::enumerate`] materializes the product in a
//! deterministic nesting order (workload → tile → layout → mem →
//! channels → striping → PE, the same order the figure sweeps use),
//! together with the structured coordinates hill-climb neighborhoods are
//! defined over.
//!
//! Spaces are either built programmatically ([`Space::fig15`],
//! [`Space::area`], [`Space::builtin`]) or parsed from a JSON description
//! (the `--space PATH` grammar; see `DESIGN.md` §"Design-space
//! exploration").

use std::collections::BTreeMap;

use crate::harness::workloads::{self, Workload};
use crate::layout::LayoutRegistry;
use crate::memsim::{MemConfig, Striping};
use crate::poly::vec::IVec;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// One workload of a space: a name (the report label), its dependence
/// pattern, and the tile shapes the tuner may pick for it.
#[derive(Clone, Debug)]
pub struct SpaceWorkload {
    pub name: String,
    pub deps: Vec<IVec>,
    pub tiles: TileSet,
}

/// Tile-shape candidates for one workload.
#[derive(Clone, Debug)]
pub enum TileSet {
    /// An explicit ordered list (e.g. a Table-I sweep column).
    List(Vec<IVec>),
    /// Per-axis candidate values; the set is their cartesian product
    /// (last axis fastest). Hill-climb steps move one axis one position.
    Axes(Vec<Vec<i64>>),
}

impl TileSet {
    /// All tiles with their structured coordinates, deterministic order.
    pub fn enumerate(&self) -> Vec<(Vec<usize>, IVec)> {
        match self {
            TileSet::List(ts) => ts
                .iter()
                .enumerate()
                .map(|(i, t)| (vec![i], t.clone()))
                .collect(),
            TileSet::Axes(axes) => {
                let mut out = Vec::new();
                if axes.is_empty() || axes.iter().any(|a| a.is_empty()) {
                    return out;
                }
                let mut idx = vec![0usize; axes.len()];
                'outer: loop {
                    let tile: IVec = idx.iter().zip(axes).map(|(&i, a)| a[i]).collect();
                    out.push((idx.clone(), tile));
                    for d in (0..axes.len()).rev() {
                        idx[d] += 1;
                        if idx[d] < axes[d].len() {
                            continue 'outer;
                        }
                        idx[d] = 0;
                    }
                    break;
                }
                out
            }
        }
    }
}

/// A named memory-interface variant.
#[derive(Clone, Debug)]
pub struct MemVariant {
    pub name: String,
    pub cfg: MemConfig,
}

impl MemVariant {
    pub fn new(name: impl Into<String>, cfg: MemConfig) -> MemVariant {
        MemVariant {
            name: name.into(),
            cfg,
        }
    }

    /// The paper's ZC706 HP-port defaults under the name `default`.
    pub fn paper_default() -> MemVariant {
        MemVariant::new("default", MemConfig::default())
    }
}

/// A declarative exploration space.
#[derive(Clone, Debug)]
pub struct Space {
    pub workloads: Vec<SpaceWorkload>,
    /// Tiles per axis of the iteration space (`space = tile * this`).
    pub tiles_per_dim: i64,
    /// Layout names (canonical or alias); empty = every registered layout.
    pub layouts: Vec<String>,
    pub mems: Vec<MemVariant>,
    /// Memory channel counts to sweep (each >= 1; `[1]` = single-port).
    pub channels: Vec<usize>,
    /// Channel interleaving policies to sweep (paired with every channel
    /// count; with `channels == [1]` the policy is inert).
    pub stripings: Vec<Striping>,
    /// Modeled PE throughputs (ops/cycle) for the exec stage.
    pub pe: Vec<u64>,
}

/// One fully-resolved candidate configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Point {
    pub workload: String,
    pub tile: IVec,
    /// Canonical layout name (resolved at enumeration).
    pub layout: String,
    /// Memory-variant name (resolved against [`Space::mems`]).
    pub mem: String,
    /// Memory channels (1 = the single-port [`crate::memsim::MemSim`]).
    pub channels: usize,
    /// Channel interleaving policy.
    pub striping: Striping,
    pub pe: u64,
}

fn fmt_tile(tile: &[i64]) -> String {
    tile.iter()
        .map(|x| x.to_string())
        .collect::<Vec<_>>()
        .join("x")
}

impl Point {
    /// Stable identity of the point — the journal's dedup key.
    pub fn fingerprint(&self) -> String {
        format!(
            "{}|t{}|{}|{}|c{}|{}|pe{}",
            self.workload,
            fmt_tile(&self.tile),
            self.layout,
            self.mem,
            self.channels,
            self.striping.label(),
            self.pe
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workload", Json::str(self.workload.clone())),
            (
                "tile",
                Json::arr(self.tile.iter().map(|&x| Json::num(x as f64))),
            ),
            ("layout", Json::str(self.layout.clone())),
            ("mem", Json::str(self.mem.clone())),
            ("channels", Json::num(self.channels as f64)),
            ("striping", Json::str(self.striping.label())),
            ("pe", Json::num(self.pe as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Point> {
        let text = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("point json: missing string '{k}'"))
        };
        let tile = j
            .get("tile")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("point json: missing array 'tile'"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|x| x as i64)
                    .ok_or_else(|| anyhow!("point json: non-numeric tile entry"))
            })
            .collect::<Result<IVec>>()?;
        let pe = j
            .get("pe")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("point json: missing number 'pe'"))? as u64;
        // channels/striping default for journals written before the
        // multi-channel axes existed (their points were all single-port)
        let channels = match j.get("channels").and_then(Json::as_f64) {
            Some(c) if c >= 1.0 => c as usize,
            Some(c) => bail!("point json: channels must be >= 1, got {c}"),
            None => 1,
        };
        let striping = match j.get("striping").and_then(Json::as_str) {
            Some(s) => Striping::parse(s).map_err(|e| anyhow!("point json: {e}"))?,
            None => Striping::default(),
        };
        Ok(Point {
            workload: text("workload")?,
            tile,
            layout: text("layout")?,
            mem: text("mem")?,
            channels,
            striping,
            pe,
        })
    }
}

/// A materialized space: points in deterministic nesting order, plus the
/// coordinate structure strategies navigate.
#[derive(Clone, Debug)]
pub struct Enumerated {
    points: Vec<Point>,
    /// Flattened coordinates per point:
    /// `[workload, tile..., layout, mem, channels, striping, pe]`.
    coords: Vec<Vec<usize>>,
    by_coords: BTreeMap<Vec<usize>, usize>,
}

impl Enumerated {
    pub fn points(&self) -> &[Point] {
        &self.points
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Hill-climb neighborhood of point `i`: every point whose structured
    /// coordinates differ by exactly one step in exactly one non-workload
    /// dimension — ±1 along a tile axis (or tile-list position), the
    /// adjacent layout, memory variant or PE setting.
    pub fn neighbors(&self, i: usize) -> Vec<usize> {
        let c = &self.coords[i];
        let mut out = Vec::new();
        for d in 1..c.len() {
            for delta in [-1i64, 1] {
                let v = c[d] as i64 + delta;
                if v < 0 {
                    continue;
                }
                let mut n = c.clone();
                n[d] = v as usize;
                if let Some(&j) = self.by_coords.get(&n) {
                    out.push(j);
                }
            }
        }
        out
    }
}

impl Space {
    /// Look a workload up by name.
    pub fn workload(&self, name: &str) -> Option<&SpaceWorkload> {
        self.workloads.iter().find(|w| w.name == name)
    }

    /// Look a memory variant up by name.
    pub fn mem(&self, name: &str) -> Option<&MemVariant> {
        self.mems.iter().find(|m| m.name == name)
    }

    /// Materialize every point. Layout names resolve (and canonicalize)
    /// against `registry`; an empty layout list means every registered
    /// layout, in registration order. Duplicate configurations (same
    /// fingerprint, e.g. a tile listed twice) keep their first occurrence
    /// only, so a fingerprint names exactly one point.
    pub fn enumerate(&self, registry: &LayoutRegistry) -> Result<Enumerated> {
        if self.mems.is_empty() {
            bail!("space has no memory variants");
        }
        if self.pe.is_empty() {
            bail!("space has no PE settings");
        }
        if self.channels.is_empty() {
            bail!("space has no channel counts (use [1] for a single port)");
        }
        if let Some(c) = self.channels.iter().find(|&&c| c == 0) {
            bail!("space channel counts must be >= 1, got {c}");
        }
        if self.stripings.is_empty() {
            bail!("space has no striping policies (use [\"address:4096\"])");
        }
        // an unaligned byte stripe cannot be honored against any variant's
        // element size — reject the space at its front door
        for s in &self.stripings {
            for mv in &self.mems {
                s.validate(mv.cfg.elem_bytes).map_err(|e| {
                    anyhow!("space striping '{}' vs mem variant '{}': {e}", s.label(), mv.name)
                })?;
            }
        }
        let layouts: Vec<String> = if self.layouts.is_empty() {
            registry.names().iter().map(|s| s.to_string()).collect()
        } else {
            self.layouts
                .iter()
                .map(|l| {
                    registry
                        .resolve_or_err(l)
                        .map(|e| e.name().to_string())
                })
                .collect::<Result<_>>()?
        };
        let mut points = Vec::new();
        let mut coords = Vec::new();
        let mut by_coords = BTreeMap::new();
        let mut seen = std::collections::BTreeSet::new();
        for (wi, w) in self.workloads.iter().enumerate() {
            for (tc, tile) in w.tiles.enumerate() {
                for (li, layout) in layouts.iter().enumerate() {
                    for (mi, mv) in self.mems.iter().enumerate() {
                        for (ci, &channels) in self.channels.iter().enumerate() {
                            for (si, striping) in self.stripings.iter().enumerate() {
                                for (pi, &pe) in self.pe.iter().enumerate() {
                                    let point = Point {
                                        workload: w.name.clone(),
                                        tile: tile.clone(),
                                        layout: layout.clone(),
                                        mem: mv.name.clone(),
                                        channels,
                                        striping: striping.clone(),
                                        pe,
                                    };
                                    if !seen.insert(point.fingerprint()) {
                                        continue;
                                    }
                                    let mut c = Vec::with_capacity(tc.len() + 6);
                                    c.push(wi);
                                    c.extend_from_slice(&tc);
                                    c.push(li);
                                    c.push(mi);
                                    c.push(ci);
                                    c.push(si);
                                    c.push(pi);
                                    by_coords.insert(c.clone(), points.len());
                                    coords.push(c);
                                    points.push(point);
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(Enumerated {
            points,
            coords,
            by_coords,
        })
    }

    /// The Fig-15 bandwidth-sweep space: the given workloads with their
    /// own tile sweeps, every registered layout, one memory config.
    pub fn fig15(wl: &[Workload], mem_cfg: &MemConfig, tiles_per_dim: i64) -> Space {
        Space {
            workloads: wl
                .iter()
                .map(|w| SpaceWorkload {
                    name: w.name.to_string(),
                    deps: w.deps.clone(),
                    tiles: TileSet::List(w.tile_sizes.clone()),
                })
                .collect(),
            tiles_per_dim,
            layouts: Vec::new(),
            mems: vec![MemVariant::new("default", mem_cfg.clone())],
            channels: vec![1],
            stripings: vec![Striping::default()],
            pe: vec![64],
        }
    }

    /// The Fig-16/17 area-sweep space: same shape as [`Space::fig15`] with
    /// the element width pinned to `elem_bytes`.
    pub fn area(wl: &[Workload], elem_bytes: u64, tiles_per_dim: i64) -> Space {
        let cfg = MemConfig {
            elem_bytes,
            ..MemConfig::default()
        };
        let mut s = Space::fig15(wl, &cfg, tiles_per_dim);
        s.mems = vec![MemVariant::new(format!("b{elem_bytes}"), cfg)];
        s
    }

    /// Named built-in spaces for `cfa tune --space`.
    pub fn builtin(name: &str) -> Option<Space> {
        match name {
            "fig15" => Some(Space::fig15(&workloads::table1(false), &MemConfig::default(), 3)),
            "fig15-quick" => {
                Some(Space::fig15(&workloads::table1(true), &MemConfig::default(), 3))
            }
            "fig17" | "area" => Some(Space::area(&workloads::table1(false), 8, 3)),
            "fig17-quick" | "area-quick" => Some(Space::area(&workloads::table1(true), 8, 3)),
            // 1 workload x 2 tiles x 4 layouts = 8 points: the CI smoke space
            "tiny" => {
                let wl = workloads::table1(true);
                Some(Space::fig15(&wl[..1], &MemConfig::default(), 2))
            }
            _ => None,
        }
    }

    /// Parse the `--space PATH` JSON grammar (see `DESIGN.md`).
    pub fn parse(text: &str) -> Result<Space> {
        let j = crate::util::json::parse(text).map_err(|e| anyhow!("space json: {e}"))?;
        Space::from_json(&j)
    }

    /// Build a space from its JSON description.
    pub fn from_json(j: &Json) -> Result<Space> {
        let quick = j.get("quick").and_then(Json::as_bool).unwrap_or(false);
        let names = j
            .get("workloads")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("space json: missing 'workloads' array"))?;
        if names.is_empty() {
            bail!("space json: 'workloads' is empty");
        }
        let tiles = parse_tile_list(j.get("tiles"), "tiles")?;
        let tile_axes = parse_tile_list(j.get("tile_axes"), "tile_axes")?;
        if tiles.is_some() && tile_axes.is_some() {
            bail!("space json: 'tiles' and 'tile_axes' are mutually exclusive");
        }
        let mut sws = Vec::new();
        for n in names {
            let name = n
                .as_str()
                .ok_or_else(|| anyhow!("space json: workload names must be strings"))?;
            let w = resolve_workload(name, quick)
                .ok_or_else(|| anyhow!("space json: unknown workload '{name}' (see `cfa list`)"))?;
            let tiles = match (&tiles, &tile_axes) {
                (Some(ts), _) => {
                    for t in ts {
                        if t.len() != w.dims {
                            bail!(
                                "space json: tile {t:?} has {} dims but '{name}' is {}-d",
                                t.len(),
                                w.dims
                            );
                        }
                    }
                    TileSet::List(ts.clone())
                }
                (None, Some(axes)) => {
                    if axes.len() != w.dims {
                        bail!(
                            "space json: 'tile_axes' has {} axes but '{name}' is {}-d",
                            axes.len(),
                            w.dims
                        );
                    }
                    TileSet::Axes(axes.clone())
                }
                (None, None) => TileSet::List(w.tile_sizes.clone()),
            };
            sws.push(SpaceWorkload {
                name: w.name.to_string(),
                deps: w.deps.clone(),
                tiles,
            });
        }
        let layouts = match j.get("layouts").and_then(Json::as_arr) {
            None => Vec::new(),
            Some(ls) => ls
                .iter()
                .map(|l| {
                    l.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("space json: layout names must be strings"))
                })
                .collect::<Result<_>>()?,
        };
        let tiles_per_dim = j
            .get("tiles_per_dim")
            .and_then(Json::as_f64)
            .map(|x| x as i64)
            .unwrap_or(3);
        if tiles_per_dim < 1 {
            bail!("space json: tiles_per_dim must be >= 1");
        }
        let pe = match j.get("pe").and_then(Json::as_arr) {
            None => vec![64],
            Some(ps) => ps
                .iter()
                .map(|p| {
                    p.as_f64()
                        .map(|x| x as u64)
                        .ok_or_else(|| anyhow!("space json: 'pe' entries must be numbers"))
                })
                .collect::<Result<_>>()?,
        };
        let mems: Vec<MemVariant> = match j.get("mem").and_then(Json::as_arr) {
            None => vec![MemVariant::paper_default()],
            Some(ms) => ms
                .iter()
                .enumerate()
                .map(|(i, m)| mem_variant_from_json(m, i))
                .collect::<Result<_>>()?,
        };
        let channels = match j.get("channels").and_then(Json::as_arr) {
            None => vec![1],
            Some(cs) => {
                let mut out = Vec::new();
                for c in cs {
                    let n = c
                        .as_f64()
                        .ok_or_else(|| anyhow!("space json: 'channels' entries must be numbers"))?;
                    if n < 1.0 {
                        bail!("space json: 'channels' entries must be >= 1, got {n}");
                    }
                    out.push(n as usize);
                }
                if out.is_empty() {
                    bail!("space json: 'channels' is empty");
                }
                out
            }
        };
        let stripings = match j.get("striping").and_then(Json::as_arr) {
            None => vec![Striping::default()],
            Some(ss) => {
                let mut out = Vec::new();
                for s in ss {
                    let name = s
                        .as_str()
                        .ok_or_else(|| anyhow!("space json: 'striping' entries must be strings"))?;
                    out.push(Striping::parse(name).map_err(|e| anyhow!("space json: {e}"))?);
                }
                if out.is_empty() {
                    bail!("space json: 'striping' is empty");
                }
                out
            }
        };
        // reject unaligned byte stripes at the parse front door, with the
        // mem variant they collide with named in the error
        for s in &stripings {
            for mv in &mems {
                s.validate(mv.cfg.elem_bytes).map_err(|e| {
                    anyhow!(
                        "space json: striping '{}' vs mem variant '{}': {e}",
                        s.label(),
                        mv.name
                    )
                })?;
            }
        }
        Ok(Space {
            workloads: sws,
            tiles_per_dim,
            layouts,
            mems,
            channels,
            stripings,
            pe,
        })
    }
}

fn resolve_workload(name: &str, quick: bool) -> Option<Workload> {
    if name == "heat3d" {
        return Some(workloads::heat3d());
    }
    workloads::table1(quick).into_iter().find(|w| w.name == name)
}

fn parse_tile_list(j: Option<&Json>, key: &str) -> Result<Option<Vec<IVec>>> {
    let Some(arr) = j else { return Ok(None) };
    let rows = arr
        .as_arr()
        .ok_or_else(|| anyhow!("space json: '{key}' must be an array of arrays"))?;
    let mut out = Vec::new();
    for row in rows {
        let vals = row
            .as_arr()
            .ok_or_else(|| anyhow!("space json: '{key}' must be an array of arrays"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|x| x as i64)
                    .ok_or_else(|| anyhow!("space json: '{key}' entries must be numbers"))
            })
            .collect::<Result<IVec>>()?;
        out.push(vals);
    }
    if out.is_empty() {
        bail!("space json: '{key}' is empty");
    }
    Ok(Some(out))
}

/// One `mem` entry: `{"name": ..., "preset": ..., "<MemConfig field>":
/// value, ...}`, starting from the paper's defaults — or from a named
/// geometry preset ([`MemConfig::preset`]: `zc706`, `hbm`, `hbm-flat`),
/// which explicit fields then override. Covers the burst/width knobs the
/// paper varies plus the rest of [`MemConfig`]. An unnamed entry takes
/// its preset's name when it has one.
fn mem_variant_from_json(j: &Json, idx: usize) -> Result<MemVariant> {
    let Json::Obj(m) = j else {
        bail!("space json: 'mem' entries must be objects");
    };
    // a named preset seeds the config first — field order must not matter,
    // so this is a separate pass — and explicit fields then override it
    let mut cfg = MemConfig::default();
    let mut preset_name = None;
    for (k, v) in m {
        if k.as_str() == "preset" {
            let p = v
                .as_str()
                .ok_or_else(|| anyhow!("space json: mem 'preset' must be a string"))?;
            cfg = MemConfig::preset(p).ok_or_else(|| {
                anyhow!(
                    "space json: unknown mem preset '{p}' (known: {})",
                    MemConfig::preset_names().join(", ")
                )
            })?;
            preset_name = Some(p.to_string());
        }
    }
    let mut name = preset_name.unwrap_or_else(|| format!("mem{idx}"));
    for (k, v) in m {
        let num = || -> Result<f64> {
            v.as_f64()
                .ok_or_else(|| anyhow!("space json: mem field '{k}' must be a number"))
        };
        match k.as_str() {
            "preset" => {} // consumed above
            "name" => {
                name = v
                    .as_str()
                    .ok_or_else(|| anyhow!("space json: mem 'name' must be a string"))?
                    .to_string();
            }
            "elem_bytes" => cfg.elem_bytes = num()? as u64,
            "bus_bytes" => cfg.bus_bytes = num()? as u64,
            "clock_mhz" => cfg.clock_mhz = num()?,
            "max_burst_beats" => cfg.max_burst_beats = num()? as u64,
            "boundary_bytes" => cfg.boundary_bytes = num()? as u64,
            "issue_cycles" => cfg.issue_cycles = num()? as u64,
            "row_hit_cycles" => cfg.row_hit_cycles = num()? as u64,
            "row_miss_cycles" => cfg.row_miss_cycles = num()? as u64,
            "row_bytes" => cfg.row_bytes = num()? as u64,
            "banks" => cfg.banks = num()? as u64,
            "max_outstanding" => cfg.max_outstanding = num()? as usize,
            "turnaround_cycles" => cfg.turnaround_cycles = num()? as u64,
            "cmd_shared_cycles" => cfg.cmd_shared_cycles = num()? as u64,
            _ => bail!("space json: unknown mem field '{k}'"),
        }
    }
    // a degenerate config (max_outstanding 0, zero bus/boundary/banks, a
    // boundary that is not a multiple of the bus width, …) must fail here
    // with a message, not panic later inside the simulator's burst loop
    cfg.validate()
        .map_err(|e| anyhow!("space json: mem variant '{name}': {e}"))?;
    Ok(MemVariant { name, cfg })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::registry::names;

    fn quick2() -> Space {
        Space::fig15(&workloads::table1(true)[..2], &MemConfig::default(), 2)
    }

    #[test]
    fn enumeration_matches_the_sweep_nesting_order() {
        let reg = LayoutRegistry::with_builtins();
        let space = quick2();
        let e = space.enumerate(&reg).unwrap();
        let wl = workloads::table1(true);
        let mut expect = Vec::new();
        for w in &wl[..2] {
            for tile in &w.tile_sizes {
                for name in reg.names() {
                    expect.push((w.name.to_string(), tile.clone(), name.to_string()));
                }
            }
        }
        assert_eq!(e.len(), expect.len());
        for (p, (w, t, l)) in e.points().iter().zip(&expect) {
            assert_eq!(&p.workload, w);
            assert_eq!(&p.tile, t);
            assert_eq!(&p.layout, l);
            assert_eq!(p.mem, "default");
            assert_eq!(p.pe, 64);
        }
    }

    #[test]
    fn fingerprints_are_unique() {
        let reg = LayoutRegistry::with_builtins();
        let e = quick2().enumerate(&reg).unwrap();
        let mut fps: Vec<String> = e.points().iter().map(Point::fingerprint).collect();
        fps.sort();
        fps.dedup();
        assert_eq!(fps.len(), e.len());
    }

    #[test]
    fn neighbors_step_one_dimension_at_a_time() {
        let reg = LayoutRegistry::with_builtins();
        let space = quick2();
        let e = space.enumerate(&reg).unwrap();
        // first point: workload 0, tile 0, layout 0 -> neighbors are tile 1
        // and layout 1 (mem/pe have a single value)
        let ns = e.neighbors(0);
        assert_eq!(ns.len(), 2);
        for &n in &ns {
            let p = &e.points()[n];
            assert_eq!(p.workload, e.points()[0].workload);
            let tile_step = (p.tile != e.points()[0].tile) as usize;
            let layout_step = (p.layout != e.points()[0].layout) as usize;
            assert_eq!(tile_step + layout_step, 1, "{p:?}");
        }
    }

    #[test]
    fn axes_tiles_enumerate_cartesian_product_last_axis_fastest() {
        let ts = TileSet::Axes(vec![vec![4, 8], vec![16, 32]]);
        let tiles: Vec<IVec> = ts.enumerate().into_iter().map(|(_, t)| t).collect();
        assert_eq!(
            tiles,
            vec![vec![4, 16], vec![4, 32], vec![8, 16], vec![8, 32]]
        );
    }

    #[test]
    fn builtin_spaces_resolve() {
        let reg = LayoutRegistry::with_builtins();
        let tiny = Space::builtin("tiny").unwrap();
        assert_eq!(tiny.enumerate(&reg).unwrap().len(), 8);
        assert!(Space::builtin("fig15").is_some());
        assert!(Space::builtin("fig17-quick").is_some());
        assert!(Space::builtin("nope").is_none());
    }

    #[test]
    fn mem_presets_parse_seed_and_override() {
        let space = Space::parse(
            r#"{"workloads": ["jacobi2d5p"],
                "mem": [{"preset": "hbm"},
                        {"preset": "hbm", "name": "hbm-wide", "bus_bytes": 8},
                        {"bus_bytes": 16}]}"#,
        )
        .unwrap();
        // an unnamed preset entry takes the preset's name
        assert_eq!(space.mems[0].name, "hbm");
        let hbm = MemConfig::preset("hbm").unwrap();
        assert_eq!(space.mems[0].cfg, hbm);
        // explicit fields override the preset seed, order-independently
        assert_eq!(space.mems[1].name, "hbm-wide");
        assert_eq!(space.mems[1].cfg.bus_bytes, 8);
        assert_eq!(space.mems[1].cfg.banks, hbm.banks);
        // no preset: paper defaults, positional name
        assert_eq!(space.mems[2].name, "mem2");
        assert_eq!(space.mems[2].cfg.row_bytes, MemConfig::default().row_bytes);
        // unknown presets fail with the known names in the message
        let err = Space::parse(
            r#"{"workloads": ["jacobi2d5p"], "mem": [{"preset": "hbm9"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown mem preset") && err.contains("hbm"), "{err}");
    }

    #[test]
    fn json_space_round_trips_through_enumerate() {
        let text = r#"{
            "workloads": ["jacobi2d5p"],
            "tiles": [[16, 16, 16], [32, 32, 32]],
            "layouts": ["cfa", "bounding-box"],
            "tiles_per_dim": 2,
            "pe": [64, 128],
            "mem": [{"name": "default"}, {"name": "burst64", "max_burst_beats": 64}]
        }"#;
        let space = Space::parse(text).unwrap();
        assert_eq!(space.tiles_per_dim, 2);
        assert_eq!(space.mems[1].cfg.max_burst_beats, 64);
        let reg = LayoutRegistry::with_builtins();
        let e = space.enumerate(&reg).unwrap();
        // 2 tiles x 2 layouts x 2 mems x 2 pe
        assert_eq!(e.len(), 16);
        // aliases canonicalize at enumeration
        assert!(e.points().iter().any(|p| p.layout == names::BBOX));
        // a point's fingerprint distinguishes the mem variant and PE count
        assert!(e.points().iter().any(|p| p.fingerprint().contains("burst64")));
        assert!(e.points().iter().any(|p| p.fingerprint().ends_with("pe128")));
    }

    #[test]
    fn json_errors_are_specific() {
        assert!(Space::parse("{}").is_err());
        assert!(Space::parse(r#"{"workloads": ["nope"]}"#).is_err());
        let err = Space::parse(
            r#"{"workloads": ["jacobi2d5p"], "mem": [{"name": "x", "bogus": 1}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("bogus"), "{err}");
        assert!(Space::parse(
            r#"{"workloads": ["jacobi2d5p"], "tiles": [[16, 16]]}"#
        )
        .is_err());
    }

    #[test]
    fn degenerate_mem_variants_error_instead_of_panicking() {
        // used to panic later, inside submit_axi's window pop
        let err = Space::parse(
            r#"{"workloads": ["jacobi2d5p"],
                "mem": [{"name": "broken", "max_outstanding": 0}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(
            err.contains("broken") && err.contains("max_outstanding"),
            "{err}"
        );
        let err = Space::parse(
            r#"{"workloads": ["jacobi2d5p"],
                "mem": [{"name": "odd", "boundary_bytes": 4100}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("multiple of bus_bytes"), "{err}");
        // zero bus width / banks are equally construction-time errors
        for field in ["bus_bytes", "banks", "boundary_bytes"] {
            let text = format!(
                r#"{{"workloads": ["jacobi2d5p"], "mem": [{{"name": "z", "{field}": 0}}]}}"#
            );
            let err = Space::parse(&text).unwrap_err().to_string();
            assert!(err.contains(field), "{field}: {err}");
        }
    }

    #[test]
    fn point_json_round_trips() {
        let p = Point {
            workload: "jacobi2d5p".into(),
            tile: vec![16, 24, 16],
            layout: "cfa".into(),
            mem: "default".into(),
            channels: 4,
            striping: Striping::Facet,
            pe: 64,
        };
        let back = Point::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.fingerprint(), p.fingerprint());
        // journals written before the channel axes existed still parse,
        // defaulting to the single-port interface they were measured on
        let legacy = crate::util::json::parse(
            r#"{"workload": "jacobi2d5p", "tile": [16, 24, 16],
                "layout": "cfa", "mem": "default", "pe": 64}"#,
        )
        .unwrap();
        let old = Point::from_json(&legacy).unwrap();
        assert_eq!(old.channels, 1);
        assert_eq!(old.striping, Striping::default());
    }

    #[test]
    fn channel_axes_enumerate_and_neighbor_like_any_dimension() {
        let mut space = Space::builtin("tiny").unwrap();
        space.channels = vec![1, 4];
        space.stripings = vec![
            Striping::Address { stripe_bytes: 4096 },
            Striping::Facet,
        ];
        let reg = LayoutRegistry::with_builtins();
        let e = space.enumerate(&reg).unwrap();
        assert_eq!(e.len(), 8 * 4, "tiny (8) x channels (2) x striping (2)");
        let mut fps: Vec<String> = e.points().iter().map(Point::fingerprint).collect();
        fps.sort();
        fps.dedup();
        assert_eq!(fps.len(), e.len(), "channel axes must not alias fingerprints");
        // the first point's neighborhood now includes a channel step and a
        // striping step (plus tile and layout as before)
        let p0 = &e.points()[0];
        assert_eq!((p0.channels, &p0.striping), (1, &space.stripings[0]));
        let ns = e.neighbors(0);
        assert_eq!(ns.len(), 4, "{ns:?}");
        let channel_steps = ns
            .iter()
            .filter(|&&n| {
                let p = &e.points()[n];
                p.channels != p0.channels && p.striping == p0.striping && p.tile == p0.tile
            })
            .count();
        let striping_steps = ns
            .iter()
            .filter(|&&n| {
                let p = &e.points()[n];
                p.striping != p0.striping && p.channels == p0.channels && p.tile == p0.tile
            })
            .count();
        assert_eq!((channel_steps, striping_steps), (1, 1));
    }

    #[test]
    fn unaligned_stripes_rejected_at_both_front_doors() {
        // JSON parser
        let err = Space::parse(
            r#"{"workloads": ["jacobi2d5p"],
                "channels": [2],
                "striping": ["address:12"]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("stripe_bytes"), "{err}");
        // programmatic spaces are caught at enumerate
        let mut space = Space::builtin("tiny").unwrap();
        space.stripings = vec![Striping::Address { stripe_bytes: 12 }];
        let reg = LayoutRegistry::with_builtins();
        let err = space.enumerate(&reg).unwrap_err().to_string();
        assert!(err.contains("stripe_bytes"), "{err}");
        // zero channels are equally structural errors
        assert!(Space::parse(
            r#"{"workloads": ["jacobi2d5p"], "channels": [0]}"#
        )
        .is_err());
        let mut space = Space::builtin("tiny").unwrap();
        space.channels = vec![0];
        assert!(space.enumerate(&reg).is_err());
    }

    #[test]
    fn channels_and_striping_parse_from_json_grammar() {
        let space = Space::parse(
            r#"{"workloads": ["jacobi2d5p"],
                "channels": [1, 4],
                "striping": ["address:4096", "facet", "tile"],
                "mem": [{"name": "walled", "cmd_shared_cycles": 6}]}"#,
        )
        .unwrap();
        assert_eq!(space.channels, vec![1, 4]);
        assert_eq!(
            space.stripings,
            vec![
                Striping::Address { stripe_bytes: 4096 },
                Striping::Facet,
                Striping::Tile
            ]
        );
        assert_eq!(space.mems[0].cfg.cmd_shared_cycles, 6);
    }
}
