//! `dse` — design-space exploration: autotune tiling × layout × memory
//! configuration for bandwidth and area.
//!
//! The paper hand-sweeps the tile-shape/layout space and reports that
//! burst-friendly layouts only pay off for the right configurations
//! (Figs. 15–17, Table I). This subsystem makes that search a first-class,
//! resumable optimizer on top of the experiment API:
//!
//! * [`Space`] — a declarative exploration space (per-workload tile
//!   candidates, registry layouts by name, memory-interface variants
//!   including burst widths, PE throughputs) with deterministic
//!   enumeration and structured hill-climb coordinates;
//! * [`Strategy`] — deterministic proposal streams: [`Exhaustive`],
//!   seeded [`RandomSearch`], [`HillClimb`] (±1 step per tile axis /
//!   adjacent layout, random restarts that avoid journaled ground), and
//!   [`ModelGuided`] (rank unexplored points by a cheap analytic cost
//!   model fitted on the scores so far — [`model`] — refit periodically,
//!   optionally warm-started from a prior tune journal);
//! * [`Evaluator`] — every point compiles an
//!   [`ExperimentSpec`](crate::experiment::ExperimentSpec) and runs
//!   `Session::run(Mode::Timing)` over a flat schedule (the memory-bound
//!   rig), scoring effective bandwidth from the simulator and BRAM/slice
//!   cost from the [`area`](crate::area) model;
//! * [`Explorer`] — batched, [`parallel_map`](crate::util::par)-fanned
//!   evaluation with fingerprint dedup, a flushed JSONL journal
//!   ([`journal`]) and resume (`--resume` skips journaled points), and an
//!   incrementally maintained Pareto front ([`ParetoFront`], oracle
//!   [`pareto_indices`]) over (bandwidth ↑, BRAM ↓). Points sharing a
//!   (workload × space × tile × layout) geometry reuse one compiled
//!   transaction trace through a shared
//!   [`TraceCache`](crate::memsim::TraceCache) and replay it through the
//!   memory simulator's coalesced fast path — bit-identical to the
//!   plan-walk path, just without re-deriving the stream per point.
//!
//! Exploration is fault-isolated and crash-safe: a failing or panicking
//! point becomes a journaled [`Evaluation::Failed`] quarantine record
//! (retried once on resume), a torn journal tail from a killed run is
//! salvaged, and a wall-clock deadline / [`CancelToken`] stops the run
//! cooperatively with a flushed, resumable journal (see `explore`).
//!
//! Three scaling features push past exhaustive sweeps (verification
//! tier 12): early-abort replay (`Explorer::prune`) cuts off a point's
//! replay the moment its monotone bandwidth upper bound is dominated by
//! the Pareto front, journaling an [`Evaluation::Pruned`] record while
//! leaving the surviving front byte-identical; sharded exploration
//! (`Explorer::shard`, [`explore::shard_of`]) deterministically partitions
//! any strategy's proposal stream by fingerprint hash so shards run on
//! disjoint machines; and `cfa merge` folds shard journals back into one
//! whose front equals the unsharded run's.
//!
//! The figure sweeps are thin wrappers over `Exhaustive` spaces
//! ([`Space::fig15`] / [`Space::area`]; see `harness::figures`), and the
//! CLI exposes the tuner as `cfa tune`.
//!
//! ```no_run
//! use cfa::dse::{Explorer, HillClimb, Space};
//!
//! let space = Space::builtin("fig15-quick").unwrap();
//! let outcome = Explorer::new(space, Box::new(HillClimb::new(42)))
//!     .parallel(4)
//!     .budget(64)
//!     .journal("tune.jsonl")
//!     .explore()?;
//! println!("{}", outcome.summary());
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod evaluate;
pub mod explore;
pub mod journal;
pub mod model;
pub mod space;
pub mod strategy;

pub use crate::util::par::CancelToken;
pub use evaluate::{
    dominates, geometry_key, pareto_front, pareto_indices, Evaluation, Evaluator, ParetoFront,
};
pub use explore::{shard_of, Explorer, Outcome};
pub use model::{CostModel, FeatureMap};
pub use space::{Enumerated, MemVariant, Point, Space, SpaceWorkload, TileSet};
pub use strategy::{Ctx, Exhaustive, HillClimb, ModelGuided, RandomSearch, Strategy};
