//! `dse::model` — a cheap analytic cost model over tune-journal rows.
//!
//! The model predicts a point's effective bandwidth from *derived
//! features* that are pure functions of the point and its memory variant
//! — burst-length and row-switch estimates, footprint, channel count, PE
//! throughput, plus a per-layout intercept — so it can score **unexplored**
//! proposals without planning or replaying anything. Fitting is ridge
//! least-squares via hand-rolled normal equations (the offline crate set
//! has no linear algebra), which keeps a refit at O(rows·d²+d³) for a
//! feature dimension `d` of a dozen or so.
//!
//! Determinism contract: [`FeatureMap::for_space`] derives the layout
//! one-hot ordering from enumeration order, training rows are consumed in
//! `BTreeMap` (index) order, and the solver is straight-line f64
//! arithmetic — the same rows always produce bit-identical weights, which
//! is what makes [`ModelGuided`](crate::dse::ModelGuided) a *deterministic*
//! proposal stream (verification tier 12).

use crate::dse::space::Point;
use crate::memsim::{MemConfig, Striping};

/// Maps a [`Point`] to a feature vector. Owns the layout one-hot
/// dictionary so every fit/predict pair agrees on the encoding.
#[derive(Clone, Debug)]
pub struct FeatureMap {
    layouts: Vec<String>,
}

/// Number of numeric (non-one-hot) features, intercept included.
const NUMERIC: usize = 8;

impl FeatureMap {
    /// Build the layout dictionary from a set of points (first-seen order;
    /// for an enumerated space this is enumeration order, so the encoding
    /// is deterministic).
    pub fn for_space(points: &[Point]) -> FeatureMap {
        let mut layouts: Vec<String> = Vec::new();
        for p in points {
            if !layouts.iter().any(|l| l == &p.layout) {
                layouts.push(p.layout.clone());
            }
        }
        FeatureMap { layouts }
    }

    /// Feature dimension (numeric features + one layout indicator each).
    pub fn dim(&self) -> usize {
        NUMERIC + self.layouts.len()
    }

    /// Derive the feature vector of a point under its memory variant.
    /// Every feature is finite for any validated [`MemConfig`].
    pub fn features(&self, p: &Point, mem: &MemConfig) -> Vec<f64> {
        let eb = mem.elem_bytes.max(1) as f64;
        let volume: f64 = p.tile.iter().map(|&d| d.max(1) as f64).product();
        let inner = p.tile.last().copied().unwrap_or(1).max(1) as f64;
        // burst-length proxy: the innermost contiguous run, capped by what
        // one AXI burst can carry
        let burst_cap = (mem.max_burst_beats.max(1) * mem.bus_bytes.max(1)) as f64;
        let burst = (inner * eb).min(burst_cap);
        // row-switch estimate: how many DRAM rows the tile footprint spans
        let rows = volume * eb / mem.row_bytes.max(1) as f64;
        let striping = match p.striping {
            Striping::Address { .. } => 0.0,
            Striping::Facet => 1.0,
            Striping::Tile => 2.0,
        };
        let mut x = Vec::with_capacity(self.dim());
        x.push(1.0); // intercept
        x.push(burst.ln());
        x.push((1.0 + volume).ln());
        x.push((1.0 + rows).ln());
        x.push(p.channels.max(1) as f64);
        x.push(mem.peak_mb_s().max(1.0).ln());
        x.push((1 + p.pe) as f64);
        x.push(striping);
        for l in &self.layouts {
            x.push(if l == &p.layout { 1.0 } else { 0.0 });
        }
        x
    }
}

/// A fitted linear model: predicted bandwidth = `weights · features`.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub weights: Vec<f64>,
}

impl CostModel {
    /// Ridge least-squares fit of `ys ≈ X·w` via the normal equations
    /// `(XᵀX + λI)·w = Xᵀy`. The ridge term keeps the system
    /// well-conditioned when rows are few or features collinear (one-hot
    /// columns with an intercept always are). Deterministic: the result
    /// is a pure function of the rows in the order given.
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], ridge: f64) -> CostModel {
        assert_eq!(xs.len(), ys.len(), "row/target count mismatch");
        let d = xs.first().map(|x| x.len()).unwrap_or(0);
        if d == 0 {
            return CostModel { weights: Vec::new() };
        }
        let mut a = vec![vec![0.0f64; d]; d];
        let mut b = vec![0.0f64; d];
        for (x, &y) in xs.iter().zip(ys) {
            assert_eq!(x.len(), d, "ragged feature row");
            for i in 0..d {
                for j in 0..d {
                    a[i][j] += x[i] * x[j];
                }
                b[i] += x[i] * y;
            }
        }
        for (i, row) in a.iter_mut().enumerate() {
            row[i] += ridge.max(f64::MIN_POSITIVE);
        }
        CostModel {
            weights: solve(a, b),
        }
    }

    /// Predicted bandwidth for a feature vector.
    pub fn predict(&self, x: &[f64]) -> f64 {
        self.weights.iter().zip(x).map(|(w, v)| w * v).sum()
    }

    /// Root-mean-square prediction error over a row set (0 for empty).
    pub fn rms_error(&self, xs: &[Vec<f64>], ys: &[f64]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        let sq: f64 = xs
            .iter()
            .zip(ys)
            .map(|(x, &y)| {
                let e = self.predict(x) - y;
                e * e
            })
            .sum();
        (sq / xs.len() as f64).sqrt()
    }
}

/// Gaussian elimination with partial pivoting on the (symmetric
/// positive-definite, thanks to the ridge) normal system. A degenerate
/// pivot — impossible for `ridge > 0`, kept as a guard — zeroes that
/// weight instead of dividing by ~0.
fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let d = b.len();
    for col in 0..d {
        let pivot = (col..d)
            .max_by(|&i, &j| {
                a[i][col]
                    .abs()
                    .partial_cmp(&a[j][col].abs())
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .expect("non-empty pivot range");
        a.swap(col, pivot);
        b.swap(col, pivot);
        let p = a[col][col];
        if p.abs() < 1e-300 {
            continue;
        }
        for row in col + 1..d {
            let f = a[row][col] / p;
            if f == 0.0 {
                continue;
            }
            for k in col..d {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut w = vec![0.0f64; d];
    for col in (0..d).rev() {
        let mut acc = b[col];
        for k in col + 1..d {
            acc -= a[col][k] * w[k];
        }
        w[col] = if a[col][col].abs() < 1e-300 {
            0.0
        } else {
            acc / a[col][col]
        };
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::MemConfig;

    fn point(layout: &str, tile: Vec<i64>, pe: u64) -> Point {
        Point {
            workload: "w".into(),
            tile,
            layout: layout.into(),
            mem: "default".into(),
            channels: 1,
            striping: Striping::default(),
            pe,
        }
    }

    #[test]
    fn features_are_finite_and_fixed_dim() {
        let pts = vec![
            point("cfa", vec![32, 32, 32], 64),
            point("original", vec![8, 8, 8], 128),
        ];
        let fm = FeatureMap::for_space(&pts);
        assert_eq!(fm.dim(), NUMERIC + 2);
        for p in &pts {
            let x = fm.features(p, &MemConfig::default());
            assert_eq!(x.len(), fm.dim());
            assert!(x.iter().all(|v| v.is_finite()), "{x:?}");
        }
    }

    #[test]
    fn fit_recovers_an_exact_linear_relation() {
        // y = 3·x1 + 0.5·x2 over a full-rank synthetic design
        let xs: Vec<Vec<f64>> = (0..12)
            .map(|i| vec![1.0, i as f64, ((i * 7) % 5) as f64])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x[1] + 0.5 * x[2]).collect();
        let m = CostModel::fit(&xs, &ys, 1e-9);
        assert!(m.rms_error(&xs, &ys) < 1e-6, "{}", m.rms_error(&xs, &ys));
        for (x, &y) in xs.iter().zip(&ys) {
            assert!((m.predict(x) - y).abs() < 1e-6);
        }
    }

    #[test]
    fn fit_is_deterministic_bit_for_bit() {
        let pts = vec![
            point("cfa", vec![32, 32, 32], 64),
            point("original", vec![8, 16, 64], 64),
            point("bbox", vec![16, 16, 16], 128),
        ];
        let fm = FeatureMap::for_space(&pts);
        let cfg = MemConfig::default();
        let xs: Vec<Vec<f64>> = pts.iter().map(|p| fm.features(p, &cfg)).collect();
        let ys = vec![900.0, 220.0, 410.0];
        let a = CostModel::fit(&xs, &ys, 1e-6);
        let b = CostModel::fit(&xs, &ys, 1e-6);
        let bits = |m: &CostModel| m.weights.iter().map(|w| w.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b));
        assert!(a.rms_error(&xs, &ys).is_finite());
    }

    #[test]
    fn degenerate_rows_do_not_panic() {
        // identical rows: rank-1 design, the ridge keeps it solvable
        let xs = vec![vec![1.0, 2.0]; 4];
        let ys = vec![5.0; 4];
        let m = CostModel::fit(&xs, &ys, 1e-6);
        assert!(m.predict(&[1.0, 2.0]).is_finite());
        assert!(m.rms_error(&xs, &ys) < 1.0);
    }
}
