//! Point evaluation and Pareto bookkeeping.
//!
//! Every candidate runs through the production front door — an
//! [`ExperimentSpec`] compiled to a [`Session`](crate::experiment::Session)
//! and executed in `Mode::Timing` over a flat schedule (the paper's
//! memory-bound rig), so each point's tiles share the session's memoized
//! `PlanCacheState` and its timing replay is bit-identical to a serial
//! figure-sweep measurement. Area comes from the analytic model
//! ([`AreaModel`]) over the very allocation the session ran.
//!
//! **Trace reuse.** Points sharing a (workload × space box × tile ×
//! layout) *geometry* submit byte-identical transaction streams — they
//! differ only in [`MemConfig`](crate::memsim::MemConfig) and PE
//! throughput, which matter at replay, not at plan time. An [`Evaluator`]
//! holding a shared [`TraceCache`] therefore compiles each geometry's
//! [`TxnTrace`](crate::memsim::TxnTrace) once
//! (through the session's plan cache) and replays every mem/PE variant
//! through the simulator's coalesced fast path
//! ([`Session::run_trace`](crate::experiment::Session::run_trace)) — turning
//! the explorer's cost from O(points × plan-walk × burst-split) into
//! O(geometries × compile + points × stream-replay), bit-identically.
//!
//! **Determinism.** Evaluations normalize `wall_secs` to `0.0`: journal
//! records must be byte-deterministic (serial ≡ parallel, cache on ≡ cache
//! off, run ≡ re-run), and host wall time is the one report field that is
//! not a pure function of the point. Throughput is measured by the benches
//! (`benches/replay_throughput.rs`), not by journal records.

use crate::area::{AreaEstimate, AreaModel};
use crate::dse::space::{Point, Space};
use crate::experiment::{
    BoundedRun, ExperimentSpec, Mode, Report, ScheduleKind, Session, SessionCache,
};
use crate::layout::LayoutRegistry;
use crate::memsim::{TraceCache, TraceProvider};
use crate::poly::vec::IVec;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;

/// One journaled record: a successfully evaluated point — the timing
/// report plus its area estimate — or a quarantined failure. Failures are
/// first-class records so a resumed run knows what already broke (and
/// retries it exactly once, unless told not to) instead of losing the
/// information with the process.
#[derive(Clone, Debug)]
pub enum Evaluation {
    /// The point compiled and ran; objectives are valid.
    Success {
        point: Point,
        report: Report,
        area: AreaEstimate,
    },
    /// The point failed to compile/run (or its evaluation panicked); the
    /// rendered error is all that survives.
    Failed { point: Point, error: String },
    /// The point's replay was early-aborted because its monotone
    /// effective-bandwidth upper bound was already dominated by the Pareto
    /// front (see `Explorer::prune`). The bound proves the point could
    /// never have joined the front, so skipping it leaves the surviving
    /// front byte-identical to a no-abort run. Resumable like a failure:
    /// a resumed run retries pruned points (the front that dominated them
    /// is not an input of a fresh exploration).
    Pruned { point: Point, bound_mb_s: f64 },
}

impl Evaluation {
    /// A successful evaluation record.
    pub fn success(point: Point, report: Report, area: AreaEstimate) -> Evaluation {
        Evaluation::Success {
            point,
            report,
            area,
        }
    }

    /// A quarantined-failure record.
    pub fn failed(point: Point, error: impl Into<String>) -> Evaluation {
        Evaluation::Failed {
            point,
            error: error.into(),
        }
    }

    /// An early-abort (bound-dominated) record.
    pub fn pruned(point: Point, bound_mb_s: f64) -> Evaluation {
        Evaluation::Pruned {
            point,
            bound_mb_s,
        }
    }

    /// The evaluated point (every variant carries one).
    pub fn point(&self) -> &Point {
        match self {
            Evaluation::Success { point, .. }
            | Evaluation::Failed { point, .. }
            | Evaluation::Pruned { point, .. } => point,
        }
    }

    /// The point's journal identity.
    pub fn fingerprint(&self) -> String {
        self.point().fingerprint()
    }

    pub fn is_failed(&self) -> bool {
        matches!(self, Evaluation::Failed { .. })
    }

    pub fn is_pruned(&self) -> bool {
        matches!(self, Evaluation::Pruned { .. })
    }

    /// The quarantined error, for [`Evaluation::Failed`] records.
    pub fn error(&self) -> Option<&str> {
        match self {
            Evaluation::Failed { error, .. } => Some(error),
            Evaluation::Success { .. } | Evaluation::Pruned { .. } => None,
        }
    }

    /// The abort-time bandwidth upper bound, for [`Evaluation::Pruned`]
    /// records.
    pub fn bound_mb_s(&self) -> Option<f64> {
        match self {
            Evaluation::Pruned { bound_mb_s, .. } => Some(*bound_mb_s),
            _ => None,
        }
    }

    /// The timing report, for successful records.
    pub fn report(&self) -> Option<&Report> {
        match self {
            Evaluation::Success { report, .. } => Some(report),
            Evaluation::Failed { .. } | Evaluation::Pruned { .. } => None,
        }
    }

    /// The area estimate, for successful records.
    pub fn area(&self) -> Option<&AreaEstimate> {
        match self {
            Evaluation::Success { area, .. } => Some(area),
            Evaluation::Failed { .. } | Evaluation::Pruned { .. } => None,
        }
    }

    /// Bandwidth objective (maximize): effective MB/s over the makespan.
    /// Failures and pruned points score `-inf` — never on the front,
    /// dominated by anything.
    pub fn effective_mb_s(&self) -> f64 {
        match self {
            Evaluation::Success { report, .. } => report.effective_mb_s,
            Evaluation::Failed { .. } | Evaluation::Pruned { .. } => f64::NEG_INFINITY,
        }
    }

    /// Area objective (minimize): BRAM-36 blocks of the on-chip buffers.
    /// Failures and pruned points cost `u64::MAX` for the same reason.
    pub fn bram36(&self) -> u64 {
        match self {
            Evaluation::Success { area, .. } => area.bram36,
            Evaluation::Failed { .. } | Evaluation::Pruned { .. } => u64::MAX,
        }
    }

    /// One journal line's JSON record. Success records keep the exact
    /// pre-quarantine shape (clean-run journals are byte-identical across
    /// versions); failures carry `error` instead of `report`/`area`, which
    /// is also how [`Evaluation::from_json`] tells them apart.
    pub fn to_json(&self) -> Json {
        match self {
            Evaluation::Success {
                point,
                report,
                area,
            } => Json::obj(vec![
                ("fingerprint", Json::str(self.fingerprint())),
                ("point", point.to_json()),
                ("report", report.to_json()),
                (
                    "area",
                    Json::obj(vec![
                        ("slices", Json::num(area.slices as f64)),
                        ("dsp", Json::num(area.dsp as f64)),
                        ("bram36", Json::num(area.bram36 as f64)),
                    ]),
                ),
            ]),
            Evaluation::Failed { point, error } => Json::obj(vec![
                ("fingerprint", Json::str(self.fingerprint())),
                ("point", point.to_json()),
                ("error", Json::str(error)),
            ]),
            Evaluation::Pruned { point, bound_mb_s } => Json::obj(vec![
                ("fingerprint", Json::str(self.fingerprint())),
                ("point", point.to_json()),
                ("pruned", Json::Bool(true)),
                ("bound_mb_s", Json::num(*bound_mb_s)),
            ]),
        }
    }

    /// Parse a record produced by [`Evaluation::to_json`]; the stored
    /// fingerprint must match the point (journal corruption check).
    pub fn from_json(j: &Json) -> Result<Evaluation> {
        let point = Point::from_json(
            j.get("point")
                .ok_or_else(|| anyhow!("evaluation json: missing 'point'"))?,
        )?;
        if let Some(fp) = j.get("fingerprint").and_then(Json::as_str) {
            if fp != point.fingerprint() {
                anyhow::bail!(
                    "evaluation json: fingerprint '{fp}' does not match point '{}'",
                    point.fingerprint()
                );
            }
        }
        if j.get("pruned").is_some() {
            let bound = j
                .get("bound_mb_s")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("evaluation json: pruned record missing 'bound_mb_s'"))?;
            return Ok(Evaluation::pruned(point, bound));
        }
        if let Some(error) = j.get("error").and_then(Json::as_str) {
            return Ok(Evaluation::failed(point, error));
        }
        let report = Report::from_json(
            j.get("report")
                .ok_or_else(|| anyhow!("evaluation json: missing 'report'"))?,
        )?;
        let area = j
            .get("area")
            .ok_or_else(|| anyhow!("evaluation json: missing 'area'"))?;
        let field = |k: &str| -> Result<u64> {
            area.get(k)
                .and_then(Json::as_f64)
                .map(|x| x as u64)
                .ok_or_else(|| anyhow!("evaluation json: missing area '{k}'"))
        };
        Ok(Evaluation::success(
            point,
            report,
            AreaEstimate {
                slices: field("slices")?,
                dsp: field("dsp")?,
                bram36: field("bram36")?,
            },
        ))
    }

    /// One-line summary: the report line plus the area objectives, or the
    /// quarantined error.
    pub fn summary(&self) -> String {
        match self {
            Evaluation::Success { report, area, .. } => format!(
                "{}  area: {} slices, {} dsp, {} bram36",
                report.summary(),
                area.slices,
                area.dsp,
                area.bram36
            ),
            Evaluation::Failed { error, .. } => {
                format!("{}  FAILED: {error}", self.fingerprint())
            }
            Evaluation::Pruned { bound_mb_s, .. } => format!(
                "{}  PRUNED: bound {bound_mb_s:.1} MB/s dominated by the front",
                self.fingerprint()
            ),
        }
    }
}

/// Evaluates points of one space against one layout registry, optionally
/// reusing compiled transaction traces across the mem/PE variants of a
/// geometry (see the module docs) and compiled session cores across
/// evaluations sharing a geometry. The trace source is any
/// [`TraceProvider`] — a plain [`TraceCache`] for a private exploration,
/// or the serve daemon's coalescing batcher so concurrent tenants share
/// one process-wide cache.
pub struct Evaluator<'a> {
    space: &'a Space,
    registry: LayoutRegistry,
    traces: Option<Arc<dyn TraceProvider>>,
    sessions: Option<Arc<SessionCache>>,
}

/// The trace-cache key of a point's transaction-stream geometry: every
/// (mem, PE) variant of the same (workload + deps, space box, tile,
/// layout) replays the identical stream. The dependence pattern is part
/// of the key so that even caches shared across spaces whose same-named
/// workloads carry different deps can never alias. Channel count and
/// striping are deliberately *not* part of the key: the compiled trace is
/// routing-agnostic (splitting across channels happens at replay), so all
/// channel/striping variants of a geometry share one compiled trace.
pub fn geometry_key(p: &Point, space_box: &[i64], deps: &[IVec]) -> String {
    let fmt = |xs: &[i64]| {
        xs.iter()
            .map(|x| x.to_string())
            .collect::<Vec<_>>()
            .join("x")
    };
    format!(
        "{}|d{:?}|s{}|t{}|{}",
        p.workload,
        deps,
        fmt(space_box),
        fmt(&p.tile),
        p.layout
    )
}

impl<'a> Evaluator<'a> {
    pub fn new(space: &'a Space, registry: LayoutRegistry) -> Evaluator<'a> {
        Evaluator {
            space,
            registry,
            traces: None,
            sessions: None,
        }
    }

    /// Share a trace cache across evaluations (and, via `Arc`, across the
    /// explorer's `parallel_map` workers). Cache hits replay bit-identically
    /// to cold compiles, so this changes throughput only, never results.
    pub fn with_trace_cache(self, traces: Arc<TraceCache>) -> Evaluator<'a> {
        self.with_trace_provider(traces)
    }

    /// [`Evaluator::with_trace_cache`] over any [`TraceProvider`] — the
    /// serve daemon injects its single-flight batcher here.
    pub fn with_trace_provider(mut self, traces: Arc<dyn TraceProvider>) -> Evaluator<'a> {
        self.traces = Some(traces);
        self
    }

    /// Share compiled session cores across evaluations: points that differ
    /// only in mem/channels/striping/PE reuse one allocation and one
    /// canonical plan. Results are unchanged (cores are immutable).
    pub fn with_session_cache(mut self, sessions: Arc<SessionCache>) -> Evaluator<'a> {
        self.sessions = Some(sessions);
        self
    }

    /// The shared trace provider, when one was attached.
    pub fn trace_provider(&self) -> Option<&Arc<dyn TraceProvider>> {
        self.traces.as_ref()
    }

    /// Compile and run one point; see the module docs for the semantics.
    pub fn evaluate(&self, p: &Point) -> Result<Evaluation> {
        let _span = crate::obs::span("dse::evaluate");
        let w = self
            .space
            .workload(&p.workload)
            .ok_or_else(|| anyhow!("point references unknown workload '{}'", p.workload))?;
        let mv = self
            .space
            .mem(&p.mem)
            .ok_or_else(|| anyhow!("point references unknown mem variant '{}'", p.mem))?;
        let space_box: IVec = p.tile.iter().map(|t| t * self.space.tiles_per_dim).collect();
        let key = geometry_key(p, &space_box, &w.deps);
        let spec = ExperimentSpec::builder()
            .custom(p.workload.clone(), space_box, p.tile.clone(), w.deps.clone())
            .layout(p.layout.clone())
            .schedule(ScheduleKind::Flat)
            .threads(1)
            .pe_ops_per_cycle(p.pe)
            .mem(mv.cfg.clone())
            .channels(p.channels)
            .striping(p.striping.clone())
            .spec()
            .with_context(|| format!("compiling {}", p.fingerprint()))?;
        let session = match &self.sessions {
            Some(cache) => Session::compile_with_cache(spec, &self.registry, cache),
            None => Session::compile_with(spec, &self.registry),
        }
        .with_context(|| format!("compiling {}", p.fingerprint()))?;
        let mut report = match &self.traces {
            Some(cache) => {
                let trace = cache.get_or_compile_with(&key, &mut || session.compile_trace());
                session.run_trace(&trace)?
            }
            None => session.run(Mode::Timing)?,
        };
        // journal determinism: wall time is the one field that is not a
        // pure function of the point (see the module docs)
        report.wall_secs = 0.0;
        let area = AreaModel::default().estimate(session.allocation(), mv.cfg.elem_bytes);
        Ok(Evaluation::success(p.clone(), report, area))
    }

    /// [`Evaluator::evaluate`] with early-abort: replay through
    /// [`Session::run_trace_bounded`], aborting the moment the point's
    /// monotone bandwidth upper bound — paired with its (replay-free) area
    /// estimate — is dominated by any member of `front`, a snapshot of the
    /// explorer's Pareto front keys ([`ParetoFront::keys`]).
    ///
    /// Points that run to completion produce records byte-identical to
    /// [`Evaluator::evaluate`]'s. Multi-channel sessions have no bounded
    /// replay mode (arbitration order makes a cheap per-entry bound loose
    /// to the point of uselessness), so they always run to completion;
    /// correctness is unaffected, only how much work pruning saves.
    pub fn evaluate_pruned(&self, p: &Point, front: &[(f64, u64)]) -> Result<Evaluation> {
        let _span = crate::obs::span("dse::evaluate");
        let w = self
            .space
            .workload(&p.workload)
            .ok_or_else(|| anyhow!("point references unknown workload '{}'", p.workload))?;
        let mv = self
            .space
            .mem(&p.mem)
            .ok_or_else(|| anyhow!("point references unknown mem variant '{}'", p.mem))?;
        let space_box: IVec = p.tile.iter().map(|t| t * self.space.tiles_per_dim).collect();
        let key = geometry_key(p, &space_box, &w.deps);
        let spec = ExperimentSpec::builder()
            .custom(p.workload.clone(), space_box, p.tile.clone(), w.deps.clone())
            .layout(p.layout.clone())
            .schedule(ScheduleKind::Flat)
            .threads(1)
            .pe_ops_per_cycle(p.pe)
            .mem(mv.cfg.clone())
            .channels(p.channels)
            .striping(p.striping.clone())
            .spec()
            .with_context(|| format!("compiling {}", p.fingerprint()))?;
        let session = match &self.sessions {
            Some(cache) => Session::compile_with_cache(spec, &self.registry, cache),
            None => Session::compile_with(spec, &self.registry),
        }
        .with_context(|| format!("compiling {}", p.fingerprint()))?;
        // area is a pure function of the allocation — known before replay,
        // which is what lets a *bandwidth* bound decide domination
        let area = AreaModel::default().estimate(session.allocation(), mv.cfg.elem_bytes);
        // bounded replay needs the trace path; compile one privately when
        // no shared cache was attached
        let trace = match &self.traces {
            Some(cache) => cache.get_or_compile_with(&key, &mut || session.compile_trace()),
            None => Arc::new(session.compile_trace()),
        };
        let bounded = session.run_trace_bounded(&trace, &mut |bound_mb_s| {
            front.iter().any(|&k| dominates(k, (bound_mb_s, area.bram36)))
        })?;
        match bounded {
            BoundedRun::Completed(mut report) => {
                report.wall_secs = 0.0;
                Ok(Evaluation::success(p.clone(), report, area))
            }
            BoundedRun::Pruned { bound_mb_s } => Ok(Evaluation::pruned(p.clone(), bound_mb_s)),
        }
    }
}

/// `a` dominates `b`: at least as good on both objectives (bandwidth up,
/// BRAM down), strictly better on at least one.
pub fn dominates(a: (f64, u64), b: (f64, u64)) -> bool {
    a.0 >= b.0 && a.1 <= b.1 && (a.0 > b.0 || a.1 < b.1)
}

/// Indices of the non-dominated items under `key` = (effective MB/s to
/// maximize, BRAM-36 blocks to minimize), preserving input order.
pub fn pareto_indices<T>(items: &[T], key: impl Fn(&T) -> (f64, u64)) -> Vec<usize> {
    let objs: Vec<(f64, u64)> = items.iter().map(&key).collect();
    (0..items.len())
        .filter(|&i| {
            !objs
                .iter()
                .enumerate()
                .any(|(j, &b)| j != i && dominates(b, objs[i]))
        })
        .collect()
}

/// The non-dominated subset of `evals`, in evaluation order.
pub fn pareto_front(evals: &[Evaluation]) -> Vec<Evaluation> {
    pareto_indices(evals, |e| (e.effective_mb_s(), e.bram36()))
        .into_iter()
        .map(|i| evals[i].clone())
        .collect()
}

/// An incrementally maintained Pareto front over (bandwidth ↑, BRAM ↓).
///
/// [`ParetoFront::offer`] keeps the non-domination invariant on every
/// insertion — O(front) per evaluation instead of the O(n²) full recompute
/// [`pareto_indices`] performs — while reporting exactly the same surviving
/// indices in the same (insertion) order. `pareto_indices` stays as the
/// property-test oracle for this structure (the unit tests below check the
/// equivalence on random objective sets), and a debug assertion in the
/// explorer cross-checks them at the end of every exploration.
#[derive(Clone, Debug, Default)]
pub struct ParetoFront {
    /// Surviving (insertion index, objectives), insertion order.
    members: Vec<(usize, (f64, u64))>,
}

impl ParetoFront {
    pub fn new() -> ParetoFront {
        ParetoFront::default()
    }

    /// Offer a point; evicts every member it dominates. Returns true iff
    /// the point joined the front. Equal-objective members coexist (neither
    /// dominates), matching [`pareto_indices`] exactly.
    pub fn offer(&mut self, index: usize, key: (f64, u64)) -> bool {
        if self.members.iter().any(|&(_, k)| dominates(k, key)) {
            return false;
        }
        self.members.retain(|&(_, k)| !dominates(key, k));
        self.members.push((index, key));
        true
    }

    /// Indices of the surviving members, in insertion order — identical to
    /// `pareto_indices` over the full insertion sequence.
    pub fn indices(&self) -> Vec<usize> {
        self.members.iter().map(|&(i, _)| i).collect()
    }

    /// Objective keys of the surviving members, insertion order. This is
    /// the snapshot the explorer hands to [`Evaluator::evaluate_pruned`]:
    /// a candidate whose bandwidth *upper bound* is dominated by any of
    /// these keys can never join the front.
    pub fn keys(&self) -> Vec<(f64, u64)> {
        self.members.iter().map(|&(_, k)| k).collect()
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_is_strict_somewhere() {
        assert!(dominates((10.0, 5), (9.0, 5)));
        assert!(dominates((10.0, 4), (10.0, 5)));
        assert!(!dominates((10.0, 5), (10.0, 5)), "equal points never dominate");
        assert!(!dominates((10.0, 6), (9.0, 5)), "trade-offs do not dominate");
    }

    #[test]
    fn pareto_keeps_trade_offs_and_drops_dominated() {
        let pts = [(10.0, 10u64), (12.0, 20), (8.0, 5), (9.0, 10), (12.0, 20)];
        let front = pareto_indices(&pts, |&p| p);
        // (9.0, 10) is dominated by (10.0, 10); the duplicate optimum stays
        assert_eq!(front, vec![0, 1, 2, 4]);
    }

    #[test]
    fn incremental_front_matches_batch_recompute() {
        let pts = [(10.0, 10u64), (12.0, 20), (8.0, 5), (9.0, 10), (12.0, 20)];
        let mut front = ParetoFront::new();
        for (i, &p) in pts.iter().enumerate() {
            front.offer(i, p);
        }
        assert_eq!(front.indices(), pareto_indices(&pts, |&p| p));
        assert_eq!(front.len(), 4);
    }

    #[test]
    fn prop_incremental_front_equals_oracle() {
        use crate::util::prop::{run, Config};
        run("ParetoFront == pareto_indices", Config::default(), |g| {
            let n = g.usize(0, 40);
            let pts: Vec<(f64, u64)> = (0..n)
                .map(|_| (g.i64(0, 20) as f64 * 0.5, g.i64(0, 12) as u64))
                .collect();
            let mut front = ParetoFront::new();
            for (i, &p) in pts.iter().enumerate() {
                let joined = front.offer(i, p);
                // a point joins iff nothing before it dominates it
                let expect = !pts[..i].iter().any(|&q| dominates(q, p));
                assert_eq!(joined, expect, "offer({i}) on {pts:?}");
            }
            assert_eq!(
                front.indices(),
                pareto_indices(&pts, |&p| p),
                "front diverged from the oracle on {pts:?}"
            );
            assert_eq!(front.is_empty(), pts.is_empty());
        });
    }
}
