//! Point evaluation and Pareto bookkeeping.
//!
//! Every candidate runs through the production front door — an
//! [`ExperimentSpec`] compiled to a [`Session`](crate::experiment::Session)
//! and executed in `Mode::Timing` over a flat schedule (the paper's
//! memory-bound rig), so each point's tiles share the session's memoized
//! `PlanCacheState` and its timing replay is bit-identical to a serial
//! figure-sweep measurement. Area comes from the analytic model
//! ([`AreaModel`]) over the very allocation the session ran.

use crate::area::{AreaEstimate, AreaModel};
use crate::dse::space::{Point, Space};
use crate::experiment::{ExperimentSpec, Mode, Report, ScheduleKind};
use crate::layout::LayoutRegistry;
use crate::poly::vec::IVec;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

/// One evaluated point: the timing report plus its area estimate.
#[derive(Clone, Debug)]
pub struct Evaluation {
    pub point: Point,
    pub report: Report,
    pub area: AreaEstimate,
}

impl Evaluation {
    /// The point's journal identity.
    pub fn fingerprint(&self) -> String {
        self.point.fingerprint()
    }

    /// Bandwidth objective (maximize): effective MB/s over the makespan.
    pub fn effective_mb_s(&self) -> f64 {
        self.report.effective_mb_s
    }

    /// Area objective (minimize): BRAM-36 blocks of the on-chip buffers.
    pub fn bram36(&self) -> u64 {
        self.area.bram36
    }

    /// One journal line's JSON record.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("fingerprint", Json::str(self.fingerprint())),
            ("point", self.point.to_json()),
            ("report", self.report.to_json()),
            (
                "area",
                Json::obj(vec![
                    ("slices", Json::num(self.area.slices as f64)),
                    ("dsp", Json::num(self.area.dsp as f64)),
                    ("bram36", Json::num(self.area.bram36 as f64)),
                ]),
            ),
        ])
    }

    /// Parse a record produced by [`Evaluation::to_json`]; the stored
    /// fingerprint must match the point (journal corruption check).
    pub fn from_json(j: &Json) -> Result<Evaluation> {
        let point = Point::from_json(
            j.get("point")
                .ok_or_else(|| anyhow!("evaluation json: missing 'point'"))?,
        )?;
        if let Some(fp) = j.get("fingerprint").and_then(Json::as_str) {
            if fp != point.fingerprint() {
                anyhow::bail!(
                    "evaluation json: fingerprint '{fp}' does not match point '{}'",
                    point.fingerprint()
                );
            }
        }
        let report = Report::from_json(
            j.get("report")
                .ok_or_else(|| anyhow!("evaluation json: missing 'report'"))?,
        )?;
        let area = j
            .get("area")
            .ok_or_else(|| anyhow!("evaluation json: missing 'area'"))?;
        let field = |k: &str| -> Result<u64> {
            area.get(k)
                .and_then(Json::as_f64)
                .map(|x| x as u64)
                .ok_or_else(|| anyhow!("evaluation json: missing area '{k}'"))
        };
        Ok(Evaluation {
            point,
            report,
            area: AreaEstimate {
                slices: field("slices")?,
                dsp: field("dsp")?,
                bram36: field("bram36")?,
            },
        })
    }

    /// One-line summary: the report line plus the area objectives.
    pub fn summary(&self) -> String {
        format!(
            "{}  area: {} slices, {} dsp, {} bram36",
            self.report.summary(),
            self.area.slices,
            self.area.dsp,
            self.area.bram36
        )
    }
}

/// Evaluates points of one space against one layout registry.
pub struct Evaluator<'a> {
    space: &'a Space,
    registry: LayoutRegistry,
}

impl<'a> Evaluator<'a> {
    pub fn new(space: &'a Space, registry: LayoutRegistry) -> Evaluator<'a> {
        Evaluator { space, registry }
    }

    /// Compile and run one point; see the module docs for the semantics.
    pub fn evaluate(&self, p: &Point) -> Result<Evaluation> {
        let w = self
            .space
            .workload(&p.workload)
            .ok_or_else(|| anyhow!("point references unknown workload '{}'", p.workload))?;
        let mv = self
            .space
            .mem(&p.mem)
            .ok_or_else(|| anyhow!("point references unknown mem variant '{}'", p.mem))?;
        let space_box: IVec = p.tile.iter().map(|t| t * self.space.tiles_per_dim).collect();
        let session = ExperimentSpec::builder()
            .custom(p.workload.clone(), space_box, p.tile.clone(), w.deps.clone())
            .layout(p.layout.clone())
            .schedule(ScheduleKind::Flat)
            .threads(1)
            .pe_ops_per_cycle(p.pe)
            .mem(mv.cfg.clone())
            .registry(self.registry.clone())
            .compile()
            .with_context(|| format!("compiling {}", p.fingerprint()))?;
        let report = session.run(Mode::Timing)?;
        let area = AreaModel::default().estimate(session.allocation(), mv.cfg.elem_bytes);
        Ok(Evaluation {
            point: p.clone(),
            report,
            area,
        })
    }
}

/// `a` dominates `b`: at least as good on both objectives (bandwidth up,
/// BRAM down), strictly better on at least one.
pub fn dominates(a: (f64, u64), b: (f64, u64)) -> bool {
    a.0 >= b.0 && a.1 <= b.1 && (a.0 > b.0 || a.1 < b.1)
}

/// Indices of the non-dominated items under `key` = (effective MB/s to
/// maximize, BRAM-36 blocks to minimize), preserving input order.
pub fn pareto_indices<T>(items: &[T], key: impl Fn(&T) -> (f64, u64)) -> Vec<usize> {
    let objs: Vec<(f64, u64)> = items.iter().map(&key).collect();
    (0..items.len())
        .filter(|&i| {
            !objs
                .iter()
                .enumerate()
                .any(|(j, &b)| j != i && dominates(b, objs[i]))
        })
        .collect()
}

/// The non-dominated subset of `evals`, in evaluation order.
pub fn pareto_front(evals: &[Evaluation]) -> Vec<Evaluation> {
    pareto_indices(evals, |e| (e.effective_mb_s(), e.bram36()))
        .into_iter()
        .map(|i| evals[i].clone())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_is_strict_somewhere() {
        assert!(dominates((10.0, 5), (9.0, 5)));
        assert!(dominates((10.0, 4), (10.0, 5)));
        assert!(!dominates((10.0, 5), (10.0, 5)), "equal points never dominate");
        assert!(!dominates((10.0, 6), (9.0, 5)), "trade-offs do not dominate");
    }

    #[test]
    fn pareto_keeps_trade_offs_and_drops_dominated() {
        let pts = [(10.0, 10u64), (12.0, 20), (8.0, 5), (9.0, 10), (12.0, 20)];
        let front = pareto_indices(&pts, |&p| p);
        // (9.0, 10) is dominated by (10.0, 10); the duplicate optimum stays
        assert_eq!(front, vec![0, 1, 2, 4]);
    }
}
