//! The exploration driver: strategy → batched evaluation → journal →
//! Pareto front.
//!
//! [`Explorer::explore`] enumerates the space once, seeds the attempted
//! set from a resume journal (skipping every already-journaled
//! fingerprint), then loops: ask the [`Strategy`] for a batch, fan the
//! batch out over [`try_parallel_map`] workers (each point owns its
//! session and simulator, so per-point timing is bit-identical to a
//! serial run), journal each result in batch order, feed the scores back
//! to the strategy. Batches are composed from results only — never from
//! worker timing — so the journal sequence and the front are identical
//! for any `--parallel` setting.
//!
//! **Fault isolation.** A failing point — compile error, runtime error,
//! or a panic caught by `try_parallel_map` — costs exactly itself: it is
//! journaled as an [`Evaluation::Failed`] quarantine record and the run
//! continues. Resume retries journaled failures exactly once (a success
//! supersedes them); [`Explorer::retry_failed`]`(false)` keeps them
//! skipped instead. A wall-clock deadline or an external [`CancelToken`]
//! interrupts the run *cooperatively* — workers finish or skip their
//! current item, the journal stays flushed and resumable, and the
//! [`Outcome`] is marked interrupted.
//!
//! Two hot-loop mechanisms keep large explorations cheap without touching
//! results: a shared [`TraceCache`] compiles each geometry's transaction
//! stream once and replays every mem/PE variant through the simulator's
//! coalesced fast path (`--trace-cache off` disables it; journals are
//! byte-identical either way), and the Pareto front is maintained
//! incrementally per evaluation ([`ParetoFront`]) instead of recomputed
//! O(n²) at the end.
//!
//! **Early-abort replay** ([`Explorer::prune`], off by default): each
//! point's replay runs through
//! [`Session::run_trace_bounded`](crate::experiment::Session::run_trace_bounded),
//! which aborts the moment the point's monotone effective-bandwidth upper
//! bound — paired with its replay-free area estimate — is dominated by a
//! snapshot of the Pareto front taken *before the batch fanned out* (so
//! the decision is a pure function of prior results, not of worker
//! timing). A dominated bound proves the point could never have joined
//! the front, so the surviving front and every success record are
//! byte-identical to a no-abort run; the aborted point is journaled as a
//! resumable [`Evaluation::Pruned`] record carrying the bound.
//!
//! **Sharded exploration** ([`Explorer::shard`]): shard `i/N` pre-marks
//! every point whose fingerprint does not hash to `i` ([`shard_of`],
//! FNV-1a — a pure function of the fingerprint, stable across runs and
//! machines) as attempted, deterministically partitioning any strategy's
//! proposal stream. Disjoint shards union to exactly the unsharded point
//! set; `cfa merge` folds their journals back into one whose front equals
//! the unsharded run's.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::dse::evaluate::{Evaluation, Evaluator, ParetoFront};
use crate::dse::journal::{self, Journal};
use crate::dse::space::Space;
use crate::dse::strategy::{Ctx, Strategy};
use crate::experiment::SessionCache;
use crate::layout::registry;
use crate::layout::LayoutRegistry;
use crate::memsim::{CacheStats, TraceCache, TraceProvider};
use crate::util::faults;
use crate::util::par::{try_parallel_map, CancelToken};
use anyhow::{anyhow, Result};

/// Which shard of `shards` owns a fingerprint: FNV-1a over the
/// fingerprint bytes, mod the shard count. Hand-rolled (not
/// `DefaultHasher`, whose algorithm is unspecified) so the partition is
/// stable across runs, machines, and toolchains — the property that lets
/// `cfa tune --shard i/N` instances run anywhere and still union to
/// exactly the unsharded point set.
pub fn shard_of(fingerprint: &str, shards: usize) -> usize {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x1_0000_0000_01b3;
    let mut h = FNV_OFFSET;
    for &b in fingerprint.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    (h % shards.max(1) as u64) as usize
}

/// Configured exploration run; build with [`Explorer::new`] + setters,
/// execute with [`Explorer::explore`].
pub struct Explorer {
    space: Space,
    strategy: Box<dyn Strategy>,
    registry: LayoutRegistry,
    parallel: usize,
    budget: Option<usize>,
    out: Option<PathBuf>,
    resume: Option<PathBuf>,
    trace_cache: bool,
    traces_ext: Option<Arc<dyn TraceProvider>>,
    sessions: Option<Arc<SessionCache>>,
    on_evaluation: Option<Box<dyn Fn(&Evaluation) + Send + Sync>>,
    retry_failed: bool,
    cancel: CancelToken,
    deadline: Option<Duration>,
    prune: bool,
    shard: Option<(usize, usize)>,
}

/// What an exploration produced.
#[derive(Clone, Debug)]
pub struct Outcome {
    /// Strategy name (for the summary line).
    pub strategy: String,
    /// Size of the enumerated space.
    pub points_total: usize,
    /// Evaluations resumed from the journal (no work performed) —
    /// successes, plus kept failures when retry is disabled.
    pub resumed: usize,
    /// Fresh evaluations performed by this run.
    pub evaluated: usize,
    /// Points attempted this run that failed (quarantined, journaled).
    pub failed: usize,
    /// Journaled failures this run re-attempted instead of skipping.
    pub retried: usize,
    /// Replays early-aborted because the point's bandwidth upper bound was
    /// dominated by the front (journaled as resumable `Pruned` records;
    /// they consume no budget — they are exactly the replays *not* run).
    pub pruned: usize,
    /// Points owned by other shards (`--shard i/N`): excluded from this
    /// run's proposal stream, never attempted or journaled here.
    pub sharded_out: usize,
    /// True iff the run stopped at the deadline / cancellation token
    /// rather than exhausting its strategy or budget.
    pub interrupted: bool,
    /// Every successful evaluation, journal order: resumed first, then
    /// fresh. Quarantined failures are *not* listed here.
    pub all: Vec<Evaluation>,
    /// Quarantine records freshly journaled by this run.
    pub quarantined: Vec<Evaluation>,
    /// The non-dominated subset of `all` (bandwidth up, BRAM down).
    pub front: Vec<Evaluation>,
    /// Trace-cache counters for this run, when a cache (internal or an
    /// injected provider) was active; `None` with `--trace-cache off`.
    pub trace_cache: Option<CacheStats>,
}

impl Outcome {
    /// Human summary: one status line plus the front, one line per point;
    /// quarantine and interruption notes only when there is something to
    /// say (clean-run output is unchanged).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "dse[{}]: {} points in space; evaluated {} new points \
             ({} resumed from journal, {} failed); pareto front: {} points\n",
            self.strategy,
            self.points_total,
            self.evaluated,
            self.resumed,
            self.failed,
            self.front.len()
        );
        for e in &self.front {
            s.push_str("  ");
            s.push_str(&e.summary());
            s.push('\n');
        }
        if let Some(cs) = &self.trace_cache {
            s.push_str(&format!(
                "  trace cache: {} hits, {} compiles, {} entries\n",
                cs.hits, cs.misses, cs.entries
            ));
        }
        if self.pruned > 0 {
            s.push_str(&format!(
                "  pruned: {} replays early-aborted (bandwidth bound dominated by the front)\n",
                self.pruned
            ));
        }
        if self.sharded_out > 0 {
            s.push_str(&format!(
                "  shard: owns {} of {} points ({} owned by other shards)\n",
                self.points_total - self.sharded_out,
                self.points_total,
                self.sharded_out
            ));
        }
        if self.failed > 0 || self.retried > 0 {
            s.push_str(&format!(
                "  quarantine: {} new failures journaled, {} journaled failures retried\n",
                self.failed, self.retried
            ));
            for e in &self.quarantined {
                s.push_str(&format!(
                    "    {}: {}\n",
                    e.fingerprint(),
                    e.error().unwrap_or("?")
                ));
            }
        }
        if self.interrupted {
            s.push_str("  interrupted: deadline/cancellation reached; journal is resumable\n");
        }
        s
    }
}

impl Explorer {
    pub fn new(space: Space, strategy: Box<dyn Strategy>) -> Explorer {
        Explorer {
            space,
            strategy,
            registry: registry::global(),
            parallel: 1,
            budget: None,
            out: None,
            resume: None,
            trace_cache: true,
            traces_ext: None,
            sessions: None,
            on_evaluation: None,
            retry_failed: true,
            cancel: CancelToken::new(),
            deadline: None,
            prune: false,
            shard: None,
        }
    }

    /// Early-abort replay (default: off): abort a point's replay the
    /// moment its monotone bandwidth upper bound is dominated by the
    /// Pareto front, journaling a resumable [`Evaluation::Pruned`] record
    /// instead of a score. The surviving front and every success record
    /// stay byte-identical to a no-abort run (the bound is a true upper
    /// bound; see the module docs), only the work changes. Score-guided
    /// strategies see no score for a pruned point — with pruning on, a
    /// hill climb may walk a different (equally valid) path than without.
    pub fn prune(mut self, enabled: bool) -> Explorer {
        self.prune = enabled;
        self
    }

    /// Own only shard `index` of `shards` (both 0-based index and total):
    /// points whose fingerprint hashes elsewhere ([`shard_of`]) are
    /// pre-marked attempted, so any strategy's stream covers exactly this
    /// shard. Errors at [`Explorer::explore`] if `index >= shards`.
    pub fn shard(mut self, index: usize, shards: usize) -> Explorer {
        self.shard = Some((index, shards));
        self
    }

    /// Reuse compiled transaction traces across the mem/PE variants of a
    /// geometry (default: on). Off forces every point through the plan-walk
    /// path; results are bit-identical either way — this knob exists for
    /// benchmarking and for the identity tests that prove it.
    pub fn trace_cache(mut self, enabled: bool) -> Explorer {
        self.trace_cache = enabled;
        self
    }

    /// Compile traces through an external [`TraceProvider`] instead of a
    /// run-private [`TraceCache`] — the serve daemon injects its
    /// process-wide single-flight batcher here, so concurrent tenants
    /// exploring the same geometries share one compile. Implies the trace
    /// cache is on; results are bit-identical to every other cache mode.
    pub fn trace_provider(mut self, traces: Arc<dyn TraceProvider>) -> Explorer {
        self.traces_ext = Some(traces);
        self.trace_cache = true;
        self
    }

    /// Share compiled session cores (allocation + canonical plan) through
    /// an external [`SessionCache`]. Results are unchanged; geometry
    /// compiles collapse across points and across tenants.
    pub fn session_cache(mut self, sessions: Arc<SessionCache>) -> Explorer {
        self.sessions = Some(sessions);
        self
    }

    /// Observe every freshly journaled record (successes and quarantined
    /// failures, journal order) as it lands — the daemon streams these to
    /// the requesting client. Resumed records are not replayed through the
    /// callback.
    pub fn on_evaluation(
        mut self,
        f: impl Fn(&Evaluation) + Send + Sync + 'static,
    ) -> Explorer {
        self.on_evaluation = Some(Box::new(f));
        self
    }

    /// Resolve layouts against this registry instead of the global one.
    pub fn registry(mut self, registry: LayoutRegistry) -> Explorer {
        self.registry = registry;
        self
    }

    /// Worker threads fanning out across points (1 = serial). The journal
    /// sequence and front are identical for any value.
    pub fn parallel(mut self, n: usize) -> Explorer {
        self.parallel = n.max(1);
        self
    }

    /// Maximum fresh evaluations this run (resumed points are free).
    pub fn budget(mut self, n: usize) -> Explorer {
        self.budget = Some(n);
        self
    }

    /// Journal every evaluation to this JSONL path.
    pub fn journal(mut self, path: impl Into<PathBuf>) -> Explorer {
        self.out = Some(path.into());
        self
    }

    /// Skip every point already journaled in this JSONL file. A torn
    /// trailing line (killed writer) is salvaged, not an error; journaled
    /// failures are retried once unless [`Explorer::retry_failed`]`(false)`.
    pub fn resume(mut self, path: impl Into<PathBuf>) -> Explorer {
        self.resume = Some(path.into());
        self
    }

    /// Whether resumed quarantine records are re-attempted (default: true).
    /// `false` treats a journaled failure like a journaled success: the
    /// point is skipped and counted as resumed.
    pub fn retry_failed(mut self, enabled: bool) -> Explorer {
        self.retry_failed = enabled;
        self
    }

    /// Cooperative cancellation: the run checks this token between items
    /// and between batches, finishing with a flushed, resumable journal
    /// and `interrupted = true`.
    pub fn cancel_token(mut self, token: CancelToken) -> Explorer {
        self.cancel = token;
        self
    }

    /// Wall-clock deadline for the whole exploration, observed at the
    /// same cooperative points as the cancellation token.
    pub fn deadline_secs(mut self, secs: u64) -> Explorer {
        self.deadline = Some(Duration::from_secs(secs));
        self
    }

    /// Run the exploration; see the module docs.
    pub fn explore(mut self) -> Result<Outcome> {
        let enumerated = self.space.enumerate(&self.registry)?;
        let fp_to_idx: BTreeMap<String, usize> = enumerated
            .points()
            .iter()
            .enumerate()
            .map(|(i, p)| (p.fingerprint(), i))
            .collect();

        let mut attempted: BTreeSet<usize> = BTreeSet::new();
        let mut scores: BTreeMap<usize, f64> = BTreeMap::new();
        let mut all: Vec<Evaluation> = Vec::new();
        // the front is maintained incrementally as evaluations arrive —
        // O(front) per point instead of an O(n²) recompute at the end
        let mut front = ParetoFront::new();
        let offer = |front: &mut ParetoFront, all: &mut Vec<Evaluation>, eval: Evaluation| {
            front.offer(all.len(), (eval.effective_mb_s(), eval.bram36()));
            all.push(eval);
        };
        let mut resumed = 0usize;
        let mut retried = 0usize;
        // every in-space index the resume journal mentioned (successes,
        // failures, pruned) — strategies use it to steer fresh work away
        // from known ground (e.g. hill-climb restarts)
        let mut journaled: BTreeSet<usize> = BTreeSet::new();
        // failures kept skipped (retry disabled); rewritten into a fresh
        // out-journal so it stays complete
        let mut kept_failures: Vec<Evaluation> = Vec::new();
        if let Some(path) = &self.resume {
            let (records, torn) = journal::read_salvage(path)?;
            if torn > 0 {
                eprintln!(
                    "dse: resume journal {}: ignored a torn trailing line ({torn} bytes); \
                     the lost point will be re-evaluated",
                    path.display()
                );
            }
            // first per index wins among failures/pruned; successes
            // supersede both regardless of line order. A pruned record
            // resumes like a failure: the front that dominated its bound
            // is not an input of this run, so the point is re-attempted.
            let mut failed_first: BTreeMap<usize, Evaluation> = BTreeMap::new();
            for eval in records {
                let Some(&i) = fp_to_idx.get(&eval.fingerprint()) else {
                    // a journal may span a larger space than this run's;
                    // foreign points are ignored, not errors
                    continue;
                };
                journaled.insert(i);
                if eval.is_failed() || eval.is_pruned() {
                    failed_first.entry(i).or_insert(eval);
                } else if attempted.insert(i) {
                    scores.insert(i, eval.effective_mb_s());
                    offer(&mut front, &mut all, eval);
                    resumed += 1;
                }
            }
            for (i, eval) in failed_first {
                if attempted.contains(&i) {
                    continue; // a journaled success supersedes the failure
                }
                if self.retry_failed {
                    // leave unattempted: the strategy proposes it again and
                    // the fresh outcome lands in the journal
                    retried += 1;
                } else {
                    attempted.insert(i);
                    resumed += 1;
                    kept_failures.push(eval);
                }
            }
        }

        // shard partition: pre-mark every point another shard owns as
        // attempted, so any strategy's propose/filter loop skips it and
        // still terminates (a strategy never distinguishes "attempted" from
        // "not mine"). Applied after resume so a merged journal's foreign
        // successes still count as resumed, not sharded out.
        let mut sharded_out = 0usize;
        if let Some((index, shards)) = self.shard {
            if shards == 0 || index >= shards {
                return Err(anyhow!(
                    "invalid shard {index}/{shards}: index must be < shards, shards >= 1"
                ));
            }
            for (i, p) in enumerated.points().iter().enumerate() {
                if shard_of(&p.fingerprint(), shards) != index && attempted.insert(i) {
                    sharded_out += 1;
                }
            }
        }

        // Keep the out-journal complete: when resuming in place, append;
        // otherwise write the resumed records first, then the fresh ones.
        let mut writer = match &self.out {
            None => None,
            Some(path) => {
                let in_place = self.resume.as_deref() == Some(path.as_path());
                let mut w = if in_place {
                    Journal::append_to(path)?
                } else {
                    Journal::create(path)?
                };
                if !in_place {
                    for e in &all {
                        w.push(e)?;
                    }
                    for e in &kept_failures {
                        w.push(e)?;
                    }
                }
                Some(w)
            }
        };

        let mut evaluator = Evaluator::new(&self.space, self.registry.clone());
        if let Some(traces) = &self.traces_ext {
            evaluator = evaluator.with_trace_provider(traces.clone());
        } else if self.trace_cache {
            // one cache for the whole run, shared by reference across the
            // parallel workers below (sharded internally)
            evaluator = evaluator.with_trace_cache(Arc::new(TraceCache::new()));
        }
        if let Some(sessions) = &self.sessions {
            evaluator = evaluator.with_session_cache(sessions.clone());
        }
        // the cooperative stop signal: an external token or the deadline,
        // checked between batches and before each item
        let cancel = self.cancel.clone();
        let deadline = self.deadline.map(|d| Instant::now() + d);
        let cancelled =
            move || cancel.is_cancelled() || deadline.is_some_and(|t| Instant::now() >= t);
        let mut evaluated = 0usize;
        let mut failed = 0usize;
        let mut pruned = 0usize;
        let mut quarantined: Vec<Evaluation> = Vec::new();
        let mut interrupted = false;
        loop {
            if cancelled() {
                interrupted = true;
                break;
            }
            let remaining = match self.budget {
                Some(b) => b.saturating_sub(evaluated),
                None => usize::MAX,
            };
            if remaining == 0 {
                break;
            }
            let mut batch = {
                let ctx = Ctx {
                    space: &enumerated,
                    attempted: &attempted,
                    scores: &scores,
                    mems: &self.space.mems,
                    journaled: &journaled,
                };
                self.strategy.propose(&ctx, remaining)
            };
            batch.truncate(remaining);
            batch.retain(|i| !attempted.contains(i));
            if batch.is_empty() {
                break;
            }
            // The prune decision compares against a front snapshot taken
            // BEFORE the batch fans out: every worker sees the same front
            // regardless of interleaving, so which points get pruned — and
            // hence the journal — is identical for any `--parallel`.
            let front_keys = if self.prune { front.keys() } else { Vec::new() };
            // panic-isolated fan-out: one panicking point costs exactly
            // itself; items claimed after cancellation are skipped (None)
            // so an expired deadline ends the batch within one item
            let prune = self.prune;
            let results = try_parallel_map(&batch, self.parallel, |&i| {
                if cancelled() {
                    return None;
                }
                faults::check("dse::evaluate");
                Some(if prune {
                    evaluator.evaluate_pruned(&enumerated.points()[i], &front_keys)
                } else {
                    evaluator.evaluate(&enumerated.points()[i])
                })
            });
            for (&i, result) in batch.iter().zip(results) {
                let outcome = match result {
                    Ok(Some(r)) => r,
                    Ok(None) => {
                        // skipped at cancellation: not attempted, so a
                        // resume re-proposes it
                        interrupted = true;
                        continue;
                    }
                    Err(p) => Err(anyhow!("evaluation panicked: {}", p.message())),
                };
                attempted.insert(i);
                match outcome {
                    Ok(eval) if eval.is_pruned() => {
                        // attempted but unscored: no front offer, no score
                        // for the strategy, no budget consumed — this is
                        // exactly the full replay that was *not* run
                        if let Some(w) = writer.as_mut() {
                            w.push(&eval)?;
                        }
                        if let Some(cb) = &self.on_evaluation {
                            cb(&eval);
                        }
                        crate::obs::registry().counter("cfa.dse.pruned").inc();
                        pruned += 1;
                    }
                    Ok(eval) => {
                        if let Some(w) = writer.as_mut() {
                            w.push(&eval)?;
                        }
                        if let Some(cb) = &self.on_evaluation {
                            cb(&eval);
                        }
                        scores.insert(i, eval.effective_mb_s());
                        offer(&mut front, &mut all, eval);
                        evaluated += 1;
                    }
                    Err(e) => {
                        let fp = enumerated.points()[i].fingerprint();
                        eprintln!("dse: quarantine {fp}: {e:#}");
                        let record =
                            Evaluation::failed(enumerated.points()[i].clone(), format!("{e:#}"));
                        if let Some(w) = writer.as_mut() {
                            w.push(&record)?;
                        }
                        if let Some(cb) = &self.on_evaluation {
                            cb(&record);
                        }
                        quarantined.push(record);
                        failed += 1;
                    }
                }
            }
            if interrupted {
                break;
            }
        }

        // pareto_indices is the oracle the incremental front is checked
        // against (cheap at exploration sizes, compiled out in release)
        debug_assert_eq!(
            front.indices(),
            crate::dse::evaluate::pareto_indices(&all, |e| (e.effective_mb_s(), e.bram36())),
            "incremental Pareto front diverged from the batch oracle"
        );
        let front: Vec<Evaluation> =
            front.indices().into_iter().map(|i| all[i].clone()).collect();
        let trace_cache = evaluator.trace_provider().map(|p| p.stats());
        Ok(Outcome {
            strategy: self.strategy.name().to_string(),
            points_total: enumerated.len(),
            resumed,
            evaluated,
            failed,
            retried,
            pruned,
            sharded_out,
            interrupted,
            all,
            quarantined,
            front,
            trace_cache,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::strategy::{Exhaustive, RandomSearch};
    use crate::harness::workloads::table1;
    use crate::memsim::MemConfig;

    fn tiny() -> Space {
        Space::fig15(&table1(true)[..1], &MemConfig::default(), 2)
    }

    #[test]
    fn exhaustive_covers_the_space_once() {
        let out = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .explore()
            .unwrap();
        assert_eq!(out.points_total, 8);
        assert_eq!(out.evaluated, 8);
        assert_eq!(out.resumed, 0);
        assert_eq!(out.failed, 0);
        assert_eq!(out.retried, 0);
        assert!(!out.interrupted);
        assert!(out.quarantined.is_empty());
        assert!(!out.front.is_empty());
        assert!(out.summary().contains("evaluated 8 new points"));
        // a clean run's summary carries no quarantine/interruption noise
        assert!(!out.summary().contains("quarantine"));
        assert!(!out.summary().contains("interrupted"));
    }

    #[test]
    fn budget_caps_fresh_evaluations() {
        let out = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .budget(3)
            .explore()
            .unwrap();
        assert_eq!(out.evaluated, 3);
        assert_eq!(out.all.len(), 3);
    }

    #[test]
    fn trace_cache_changes_nothing_but_work() {
        let cached = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .trace_cache(true)
            .explore()
            .unwrap();
        let cold = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .trace_cache(false)
            .explore()
            .unwrap();
        assert_eq!(cached.all.len(), cold.all.len());
        for (a, b) in cached.all.iter().zip(&cold.all) {
            assert_eq!(a.fingerprint(), b.fingerprint());
            assert_eq!(
                a.to_json().to_string_compact(),
                b.to_json().to_string_compact(),
                "{}",
                a.fingerprint()
            );
        }
        assert_eq!(cached.front.len(), cold.front.len());
    }

    #[test]
    fn summary_reports_cache_counters_only_when_on() {
        let cached = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .explore()
            .unwrap();
        let cs = cached.trace_cache.expect("default cache is on");
        assert_eq!(cs.hits + cs.misses, 8);
        assert!(cached.summary().contains("trace cache: "), "{}", cached.summary());
        let cold = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .trace_cache(false)
            .explore()
            .unwrap();
        assert!(cold.trace_cache.is_none());
        assert!(!cold.summary().contains("trace cache"));
    }

    #[test]
    fn streaming_callback_sees_fresh_records_in_journal_order() {
        use std::sync::Mutex;
        let seen = Arc::new(Mutex::new(Vec::new()));
        let sink = seen.clone();
        let out = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .parallel(4)
            .on_evaluation(move |e| sink.lock().unwrap().push(e.fingerprint()))
            .explore()
            .unwrap();
        let fps: Vec<String> = out.all.iter().map(Evaluation::fingerprint).collect();
        assert_eq!(*seen.lock().unwrap(), fps);
    }

    #[test]
    fn injected_provider_and_session_cache_share_without_changing_results() {
        let provider = Arc::new(TraceCache::new());
        let sessions = Arc::new(SessionCache::new());
        let a = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .trace_provider(provider.clone())
            .session_cache(sessions.clone())
            .explore()
            .unwrap();
        let (compiles, cores) = (provider.misses(), sessions.misses());
        assert!(compiles > 0 && cores > 0);
        // a second run over the same space recompiles nothing
        let b = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .trace_provider(provider.clone())
            .session_cache(sessions.clone())
            .explore()
            .unwrap();
        assert_eq!(provider.misses(), compiles, "second tenant must not recompile");
        assert_eq!(sessions.misses(), cores);
        // ... and both runs are byte-identical to a fully private one
        let cold = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .trace_cache(false)
            .explore()
            .unwrap();
        for (x, y) in a.all.iter().zip(&cold.all).chain(b.all.iter().zip(&cold.all)) {
            assert_eq!(
                x.to_json().to_string_compact(),
                y.to_json().to_string_compact()
            );
        }
        // the injected provider's process-wide stats land in the outcome
        assert_eq!(b.trace_cache.unwrap().misses, compiles);
    }

    #[test]
    fn random_search_finds_the_same_point_set() {
        let a = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .explore()
            .unwrap();
        let b = Explorer::new(tiny(), Box::new(RandomSearch::new(5)))
            .explore()
            .unwrap();
        let mut fa: Vec<String> = a.all.iter().map(Evaluation::fingerprint).collect();
        let mut fb: Vec<String> = b.all.iter().map(Evaluation::fingerprint).collect();
        fa.sort();
        fb.sort();
        assert_eq!(fa, fb);
    }

    #[test]
    fn pre_cancelled_run_is_interrupted_with_zero_evaluations() {
        let token = CancelToken::new();
        token.cancel();
        let out = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .cancel_token(token)
            .explore()
            .unwrap();
        assert_eq!(out.evaluated, 0);
        assert!(out.interrupted);
        assert!(out.summary().contains("interrupted"));
    }

    #[test]
    fn expired_deadline_interrupts_between_items() {
        let out = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .deadline_secs(0)
            .explore()
            .unwrap();
        assert_eq!(out.evaluated, 0);
        assert!(out.interrupted);
    }

    #[test]
    fn shard_of_is_a_stable_total_partition() {
        let fps = ["a|t4x4|cfa|default|c1|addr4096|pe64", "b", "c|x", ""];
        for fp in fps {
            let s = shard_of(fp, 3);
            assert!(s < 3);
            assert_eq!(s, shard_of(fp, 3), "stable across calls");
        }
        assert_eq!(shard_of("anything", 1), 0, "one shard owns everything");
        // known FNV-1a vector: hash("") = offset basis
        assert_eq!(shard_of("", usize::MAX >> 1), (0xcbf2_9ce4_8422_2325u64 % ((usize::MAX >> 1) as u64)) as usize);
    }

    #[test]
    fn pruned_run_keeps_the_front_byte_identical() {
        // Exhaustive proposes the whole (unbudgeted) space as one batch,
        // and the prune snapshot predates the batch — so a multi-batch
        // strategy is what exercises pruning. ModelGuided batches at its
        // refit interval; the front it ends with must still equal the
        // exhaustive reference, record for record.
        let plain = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .explore()
            .unwrap();
        let pruned = Explorer::new(tiny(), Box::new(crate::dse::ModelGuided::new(42)))
            .prune(true)
            .explore()
            .unwrap();
        let render = |f: &[Evaluation]| {
            let mut v: Vec<String> =
                f.iter().map(|e| e.to_json().to_string_compact()).collect();
            v.sort();
            v
        };
        assert_eq!(render(&plain.front), render(&pruned.front));
        // every point was either fully replayed or pruned, and completed
        // records are byte-identical to the exhaustive run's (records are
        // pure functions of the point)
        assert_eq!(pruned.evaluated + pruned.pruned, plain.evaluated);
        let plain_json = render(&plain.all);
        for e in &pruned.all {
            assert!(
                plain_json.contains(&e.to_json().to_string_compact()),
                "completed record diverged: {}",
                e.fingerprint()
            );
        }
        if pruned.pruned > 0 {
            assert!(pruned.summary().contains("pruned: "), "{}", pruned.summary());
        }
    }

    #[test]
    fn shards_partition_the_space_and_union_to_it() {
        let full = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .explore()
            .unwrap();
        let mut union: Vec<String> = Vec::new();
        let mut total_sharded_out = 0;
        for index in 0..2 {
            let out = Explorer::new(tiny(), Box::new(Exhaustive::new()))
                .shard(index, 2)
                .explore()
                .unwrap();
            assert_eq!(out.evaluated + out.sharded_out, full.evaluated);
            total_sharded_out += out.sharded_out;
            union.extend(out.all.iter().map(Evaluation::fingerprint));
        }
        assert_eq!(total_sharded_out, full.evaluated, "each point has exactly one owner");
        union.sort();
        let mut expect: Vec<String> = full.all.iter().map(Evaluation::fingerprint).collect();
        expect.sort();
        assert_eq!(union, expect);
    }

    #[test]
    fn invalid_shard_spec_is_an_error() {
        assert!(Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .shard(2, 2)
            .explore()
            .is_err());
        assert!(Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .shard(0, 0)
            .explore()
            .is_err());
    }
}
