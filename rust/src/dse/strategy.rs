//! Exploration strategies: which points to evaluate next.
//!
//! A [`Strategy`] is a deterministic proposal stream over an enumerated
//! space. The explorer calls [`Strategy::propose`] with the evaluations so
//! far; the strategy returns a batch of unattempted point indices, and the
//! explorer fans the whole batch out over its workers. Because a batch's
//! composition depends only on *prior results* (never on wall-clock or
//! worker interleaving), the sequence of evaluated points — and with it
//! the journal and the Pareto front — is identical for any `--parallel`
//! setting.

use std::collections::{BTreeMap, BTreeSet};

use crate::dse::model::{CostModel, FeatureMap};
use crate::dse::space::{Enumerated, MemVariant, Point};
use crate::memsim::MemConfig;
use crate::util::rng::Rng;

/// What a strategy sees when proposing: the space, which points were
/// already attempted (evaluated or failed), the scalar climb score
/// (effective bandwidth, MB/s) of every successful evaluation, the
/// space's memory variants (for feature derivation), and which indices
/// arrived pre-attempted from a resumed journal (as opposed to being
/// evaluated in this run).
pub struct Ctx<'a> {
    pub space: &'a Enumerated,
    pub attempted: &'a BTreeSet<usize>,
    pub scores: &'a BTreeMap<usize, f64>,
    pub mems: &'a [MemVariant],
    pub journaled: &'a BTreeSet<usize>,
}

impl Ctx<'_> {
    /// The [`MemConfig`] a point replays under, resolved by name against
    /// the space's variants (enumerated points always resolve; the default
    /// config is a never-taken fallback that keeps this total).
    fn mem_cfg(&self, p: &Point) -> MemConfig {
        self.mems
            .iter()
            .find(|m| m.name == p.mem)
            .map(|m| m.cfg.clone())
            .unwrap_or_default()
    }
}

/// A deterministic proposal stream; see the module docs.
pub trait Strategy {
    fn name(&self) -> &'static str;

    /// Propose up to `max` unattempted point indices to evaluate next.
    /// An empty batch ends the exploration.
    fn propose(&mut self, ctx: &Ctx<'_>, max: usize) -> Vec<usize>;
}

/// Every point, in enumeration order (the figure sweeps' strategy).
#[derive(Clone, Debug, Default)]
pub struct Exhaustive {
    cursor: usize,
}

impl Exhaustive {
    pub fn new() -> Exhaustive {
        Exhaustive::default()
    }
}

impl Strategy for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn propose(&mut self, ctx: &Ctx<'_>, max: usize) -> Vec<usize> {
        let mut out = Vec::new();
        while self.cursor < ctx.space.len() && out.len() < max {
            if !ctx.attempted.contains(&self.cursor) {
                out.push(self.cursor);
            }
            self.cursor += 1;
        }
        out
    }
}

/// Every point, in a seeded random order (uniform without replacement).
#[derive(Clone, Debug)]
pub struct RandomSearch {
    rng: Rng,
    order: Option<Vec<usize>>,
    cursor: usize,
}

impl RandomSearch {
    pub fn new(seed: u64) -> RandomSearch {
        RandomSearch {
            rng: Rng::new(seed),
            order: None,
            cursor: 0,
        }
    }
}

impl Strategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn propose(&mut self, ctx: &Ctx<'_>, max: usize) -> Vec<usize> {
        if self.order.is_none() {
            let mut order: Vec<usize> = (0..ctx.space.len()).collect();
            self.rng.shuffle(&mut order);
            self.order = Some(order);
        }
        let order = self.order.as_ref().expect("order initialized above");
        let mut out = Vec::new();
        while self.cursor < order.len() && out.len() < max {
            let i = order[self.cursor];
            if !ctx.attempted.contains(&i) {
                out.push(i);
            }
            self.cursor += 1;
        }
        out
    }
}

/// Greedy local search on effective bandwidth with random restarts.
///
/// Seeds at a random unattempted point, then repeatedly proposes the
/// unattempted neighborhood of the current point ([`Enumerated::neighbors`]:
/// ±1 step per tile axis, adjacent layout/mem/PE). Once the whole
/// neighborhood is evaluated it moves to the best strictly-improving
/// neighbor; at a local optimum it restarts at a fresh random point, until
/// the space (or the budget) is exhausted.
#[derive(Clone, Debug)]
pub struct HillClimb {
    rng: Rng,
    current: Option<usize>,
}

impl HillClimb {
    pub fn new(seed: u64) -> HillClimb {
        HillClimb {
            rng: Rng::new(seed),
            current: None,
        }
    }
}

impl Strategy for HillClimb {
    fn name(&self) -> &'static str {
        "hill"
    }

    fn propose(&mut self, ctx: &Ctx<'_>, max: usize) -> Vec<usize> {
        loop {
            let Some(cur) = self.current else {
                // Random restart among the unattempted points. Prefer
                // territory the journal has never seen: a resumed run used
                // to restart onto journaled fingerprints (they are "free"
                // until re-proposed, since resume only pre-marks failures'
                // retries), burning restarts on known ground. Skip them —
                // counted, so a resumed tune can report it — unless they
                // are all that is left (preserving full coverage and the
                // retry-failures-exactly-once contract).
                let free: Vec<usize> = (0..ctx.space.len())
                    .filter(|i| !ctx.attempted.contains(i))
                    .collect();
                if free.is_empty() {
                    return Vec::new();
                }
                let unjournaled: Vec<usize> = free
                    .iter()
                    .copied()
                    .filter(|i| !ctx.journaled.contains(i))
                    .collect();
                let pool = if unjournaled.is_empty() {
                    &free
                } else {
                    if unjournaled.len() < free.len() {
                        crate::obs::registry()
                            .counter("cfa.dse.hill_restart_skips")
                            .add((free.len() - unjournaled.len()) as u64);
                    }
                    &unjournaled
                };
                let pick = pool[self.rng.gen_usize(pool.len())];
                self.current = Some(pick);
                return vec![pick];
            };
            let Some(&cur_score) = ctx.scores.get(&cur) else {
                // the seed (or move target) failed to evaluate: restart
                self.current = None;
                continue;
            };
            let neighbors = ctx.space.neighbors(cur);
            let mut fresh: Vec<usize> = neighbors
                .iter()
                .copied()
                .filter(|i| !ctx.attempted.contains(i))
                .collect();
            if !fresh.is_empty() {
                fresh.truncate(max);
                return fresh;
            }
            // neighborhood fully explored: climb or restart
            let mut best: Option<(usize, f64)> = None;
            for i in neighbors {
                if let Some(&s) = ctx.scores.get(&i) {
                    if best.map(|(_, bs)| s > bs).unwrap_or(true) {
                        best = Some((i, s));
                    }
                }
            }
            match best {
                Some((i, s)) if s > cur_score => self.current = Some(i),
                _ => self.current = None,
            }
        }
    }
}

/// Model-guided best-first search: fit the cheap analytic cost model
/// ([`dse::model`](crate::dse::model)) on every score so far, rank the
/// unexplored points by predicted bandwidth, and evaluate best-first,
/// refitting every [`ModelGuided::refit_every`] fresh scores.
///
/// Bootstraps with seeded random probes until [`ModelGuided::min_train`]
/// scores exist (a model fitted on nothing ranks nothing). A warm-start
/// journal ([`ModelGuided::with_warm_start`]) substitutes for bootstrap
/// probes: its (point, score) rows join the training set even though the
/// points may lie outside this space.
///
/// Deterministic: training rows are consumed in `BTreeMap` (index) order
/// after the warm rows, the fit is straight-line arithmetic, and ranking
/// ties break by enumeration index — the same prior results always produce
/// the same next batch, preserving the journal's serial ≡ parallel
/// contract. With an unbounded budget it still visits every point (ranking
/// proposes all free points, worst-last), so coverage matches the other
/// strategies.
pub struct ModelGuided {
    rng: Rng,
    /// Scores required before the first fit.
    min_train: usize,
    /// Refit after this many fresh training rows (also the ranked batch
    /// cap, so stale models never steer more than one refit interval).
    refit_every: usize,
    ridge: f64,
    warm: Vec<(Point, f64)>,
    /// Fitted state: feature map, weights, and how many training rows the
    /// weights were fitted on (for the refit trigger).
    fitted: Option<(FeatureMap, CostModel, usize)>,
}

impl ModelGuided {
    pub fn new(seed: u64) -> ModelGuided {
        // small defaults on purpose: even the 8-point CI smoke space gets a
        // bootstrap batch and then ranked batches (a min_train the size of
        // the space would degenerate to random search in one batch)
        ModelGuided {
            rng: Rng::new(seed),
            min_train: 4,
            refit_every: 4,
            ridge: 1e-3,
            warm: Vec::new(),
            fitted: None,
        }
    }

    /// Seed the training set with (point, effective MB/s) rows salvaged
    /// from a prior tune journal — typically of a *different* space, which
    /// is the point: the feature map only needs each row's mem name to
    /// resolve against this space's variants (rows that do not resolve are
    /// dropped; their features would be fiction).
    pub fn with_warm_start(mut self, rows: Vec<(Point, f64)>) -> ModelGuided {
        self.warm = rows;
        self
    }

    /// Training rows visible right now: warm rows (space-filtered), then
    /// this run's scores in index order.
    fn training_rows<'c>(&self, ctx: &Ctx<'c>) -> Vec<(Point, MemConfig, f64)> {
        let mut rows: Vec<(Point, MemConfig, f64)> = self
            .warm
            .iter()
            .filter(|(p, _)| ctx.mems.iter().any(|m| m.name == p.mem))
            .map(|(p, y)| (p.clone(), ctx.mem_cfg(p), *y))
            .collect();
        for (&i, &y) in ctx.scores {
            let p = &ctx.space.points()[i];
            rows.push((p.clone(), ctx.mem_cfg(p), y));
        }
        rows
    }
}

impl Strategy for ModelGuided {
    fn name(&self) -> &'static str {
        "model-guided"
    }

    fn propose(&mut self, ctx: &Ctx<'_>, max: usize) -> Vec<usize> {
        let mut free: Vec<usize> = (0..ctx.space.len())
            .filter(|i| !ctx.attempted.contains(i))
            .collect();
        if free.is_empty() || max == 0 {
            return Vec::new();
        }
        let rows = self.training_rows(ctx);
        if rows.len() < self.min_train {
            // bootstrap: seeded random probes (without replacement) until
            // enough scores exist to fit on
            let need = (self.min_train - rows.len()).min(max).min(free.len());
            let mut out = Vec::with_capacity(need);
            while out.len() < need {
                let k = self.rng.gen_usize(free.len());
                out.push(free.swap_remove(k));
            }
            out.sort_unstable();
            return out;
        }
        let stale = match &self.fitted {
            None => true,
            Some((_, _, trained_on)) => rows.len() >= trained_on + self.refit_every,
        };
        if stale {
            let _span = crate::obs::span("dse::model::fit");
            let fm = FeatureMap::for_space(ctx.space.points());
            let xs: Vec<Vec<f64>> = rows.iter().map(|(p, m, _)| fm.features(p, m)).collect();
            let ys: Vec<f64> = rows.iter().map(|(_, _, y)| *y).collect();
            let model = CostModel::fit(&xs, &ys, self.ridge);
            crate::obs::registry().counter("cfa.dse.model_refits").inc();
            self.fitted = Some((fm, model, rows.len()));
        }
        let (fm, model, _) = self.fitted.as_ref().expect("fitted above");
        let mut ranked: Vec<(f64, usize)> = free
            .iter()
            .map(|&i| {
                let p = &ctx.space.points()[i];
                (model.predict(&fm.features(p, &ctx.mem_cfg(p))), i)
            })
            .collect();
        // best predicted first; ties (and NaN-free f64s generally) break
        // by enumeration index so the stream is a pure function of scores
        ranked.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1)));
        ranked
            .into_iter()
            .take(max.min(self.refit_every.max(1)))
            .map(|(_, i)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::workloads::table1;
    use crate::layout::LayoutRegistry;
    use crate::memsim::MemConfig;

    fn tiny_space() -> Enumerated {
        let reg = LayoutRegistry::with_builtins();
        crate::dse::Space::fig15(&table1(true)[..1], &MemConfig::default(), 2)
            .enumerate(&reg)
            .unwrap()
    }

    fn drain(
        strategy: &mut dyn Strategy,
        space: &Enumerated,
        score: impl Fn(usize) -> f64,
    ) -> Vec<usize> {
        drain_journaled(strategy, space, score, &BTreeSet::new())
    }

    fn drain_journaled(
        strategy: &mut dyn Strategy,
        space: &Enumerated,
        score: impl Fn(usize) -> f64,
        journaled: &BTreeSet<usize>,
    ) -> Vec<usize> {
        let mems = [MemVariant::new("default", MemConfig::default())];
        let mut attempted = BTreeSet::new();
        let mut scores = BTreeMap::new();
        let mut order = Vec::new();
        loop {
            let batch = {
                let ctx = Ctx {
                    space,
                    attempted: &attempted,
                    scores: &scores,
                    mems: &mems,
                    journaled,
                };
                strategy.propose(&ctx, usize::MAX)
            };
            if batch.is_empty() {
                break;
            }
            for i in batch {
                assert!(attempted.insert(i), "point {i} proposed twice");
                scores.insert(i, score(i));
                order.push(i);
            }
        }
        order
    }

    #[test]
    fn exhaustive_visits_everything_in_enumeration_order() {
        let space = tiny_space();
        let order = drain(&mut Exhaustive::new(), &space, |_| 0.0);
        assert_eq!(order, (0..space.len()).collect::<Vec<_>>());
    }

    #[test]
    fn random_search_is_a_seeded_permutation() {
        let space = tiny_space();
        let a = drain(&mut RandomSearch::new(7), &space, |_| 0.0);
        let b = drain(&mut RandomSearch::new(7), &space, |_| 0.0);
        let c = drain(&mut RandomSearch::new(8), &space, |_| 0.0);
        assert_eq!(a, b, "same seed, same order");
        assert_ne!(a, c, "different seed, different order");
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..space.len()).collect::<Vec<_>>());
    }

    #[test]
    fn hill_climb_terminates_and_covers_with_unbounded_budget() {
        let space = tiny_space();
        // score favoring high indices: the climb walks up, restarts fill in
        let order = drain(&mut HillClimb::new(3), &space, |i| i as f64);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..space.len()).collect::<Vec<_>>());
    }

    #[test]
    fn hill_climb_is_deterministic_for_a_seed() {
        let space = tiny_space();
        let a = drain(&mut HillClimb::new(11), &space, |i| (i % 5) as f64);
        let b = drain(&mut HillClimb::new(11), &space, |i| (i % 5) as f64);
        assert_eq!(a, b);
    }

    #[test]
    fn hill_climb_restarts_prefer_unjournaled_points() {
        let space = tiny_space();
        // mark the first half journaled: every restart must land in the
        // second half until only journaled ground remains
        let journaled: BTreeSet<usize> = (0..space.len() / 2).collect();
        let order = drain_journaled(&mut HillClimb::new(3), &space, |i| i as f64, &journaled);
        let first_restart = order[0];
        assert!(
            !journaled.contains(&first_restart),
            "restart {first_restart} landed on journaled ground"
        );
        // coverage is preserved: once unjournaled ground is exhausted the
        // fallback still visits everything
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..space.len()).collect::<Vec<_>>());
    }

    #[test]
    fn model_guided_covers_the_space_and_is_deterministic() {
        let space = tiny_space();
        let score = |i: usize| ((i * 37) % 11) as f64;
        let a = drain(&mut ModelGuided::new(5), &space, score);
        let b = drain(&mut ModelGuided::new(5), &space, score);
        assert_eq!(a, b, "same seed and scores, same proposal stream");
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(
            sorted,
            (0..space.len()).collect::<Vec<_>>(),
            "unbounded budget still visits every point exactly once"
        );
    }

    #[test]
    fn model_guided_ranks_after_bootstrap() {
        let space = tiny_space();
        let mems = [MemVariant::new("default", MemConfig::default())];
        let mut s = ModelGuided::new(9);
        let mut attempted = BTreeSet::new();
        let mut scores = BTreeMap::new();
        let journaled = BTreeSet::new();
        // first batch is bootstrap-sized, not the whole space
        let batch = {
            let ctx = Ctx {
                space: &space,
                attempted: &attempted,
                scores: &scores,
                mems: &mems,
                journaled: &journaled,
            };
            s.propose(&ctx, usize::MAX)
        };
        assert_eq!(batch.len(), 4.min(space.len()), "bootstrap probes");
        for i in batch {
            attempted.insert(i);
            scores.insert(i, (i % 7) as f64);
        }
        // once trained, batches are capped at the refit interval so the
        // model is refreshed periodically
        let ranked = {
            let ctx = Ctx {
                space: &space,
                attempted: &attempted,
                scores: &scores,
                mems: &mems,
                journaled: &journaled,
            };
            s.propose(&ctx, usize::MAX)
        };
        assert!(!ranked.is_empty());
        assert!(ranked.len() <= 4, "ranked batch respects the refit cap");
        assert!(ranked.iter().all(|i| !attempted.contains(i)));
    }

    #[test]
    fn model_guided_warm_start_skips_unresolvable_rows() {
        let space = tiny_space();
        let mut alien = space.points()[0].clone();
        alien.mem = "no-such-mem".into();
        let warm = vec![
            (space.points()[0].clone(), 100.0),
            (alien, 900.0), // dropped: mem does not resolve in this space
        ];
        let mut s = ModelGuided::new(5).with_warm_start(warm);
        let order = drain(&mut s, &space, |i| i as f64);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..space.len()).collect::<Vec<_>>());
    }
}
