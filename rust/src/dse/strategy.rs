//! Exploration strategies: which points to evaluate next.
//!
//! A [`Strategy`] is a deterministic proposal stream over an enumerated
//! space. The explorer calls [`Strategy::propose`] with the evaluations so
//! far; the strategy returns a batch of unattempted point indices, and the
//! explorer fans the whole batch out over its workers. Because a batch's
//! composition depends only on *prior results* (never on wall-clock or
//! worker interleaving), the sequence of evaluated points — and with it
//! the journal and the Pareto front — is identical for any `--parallel`
//! setting.

use std::collections::{BTreeMap, BTreeSet};

use crate::dse::space::Enumerated;
use crate::util::rng::Rng;

/// What a strategy sees when proposing: the space, which points were
/// already attempted (evaluated or failed), and the scalar climb score
/// (effective bandwidth, MB/s) of every successful evaluation.
pub struct Ctx<'a> {
    pub space: &'a Enumerated,
    pub attempted: &'a BTreeSet<usize>,
    pub scores: &'a BTreeMap<usize, f64>,
}

/// A deterministic proposal stream; see the module docs.
pub trait Strategy {
    fn name(&self) -> &'static str;

    /// Propose up to `max` unattempted point indices to evaluate next.
    /// An empty batch ends the exploration.
    fn propose(&mut self, ctx: &Ctx<'_>, max: usize) -> Vec<usize>;
}

/// Every point, in enumeration order (the figure sweeps' strategy).
#[derive(Clone, Debug, Default)]
pub struct Exhaustive {
    cursor: usize,
}

impl Exhaustive {
    pub fn new() -> Exhaustive {
        Exhaustive::default()
    }
}

impl Strategy for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn propose(&mut self, ctx: &Ctx<'_>, max: usize) -> Vec<usize> {
        let mut out = Vec::new();
        while self.cursor < ctx.space.len() && out.len() < max {
            if !ctx.attempted.contains(&self.cursor) {
                out.push(self.cursor);
            }
            self.cursor += 1;
        }
        out
    }
}

/// Every point, in a seeded random order (uniform without replacement).
#[derive(Clone, Debug)]
pub struct RandomSearch {
    rng: Rng,
    order: Option<Vec<usize>>,
    cursor: usize,
}

impl RandomSearch {
    pub fn new(seed: u64) -> RandomSearch {
        RandomSearch {
            rng: Rng::new(seed),
            order: None,
            cursor: 0,
        }
    }
}

impl Strategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn propose(&mut self, ctx: &Ctx<'_>, max: usize) -> Vec<usize> {
        if self.order.is_none() {
            let mut order: Vec<usize> = (0..ctx.space.len()).collect();
            self.rng.shuffle(&mut order);
            self.order = Some(order);
        }
        let order = self.order.as_ref().expect("order initialized above");
        let mut out = Vec::new();
        while self.cursor < order.len() && out.len() < max {
            let i = order[self.cursor];
            if !ctx.attempted.contains(&i) {
                out.push(i);
            }
            self.cursor += 1;
        }
        out
    }
}

/// Greedy local search on effective bandwidth with random restarts.
///
/// Seeds at a random unattempted point, then repeatedly proposes the
/// unattempted neighborhood of the current point ([`Enumerated::neighbors`]:
/// ±1 step per tile axis, adjacent layout/mem/PE). Once the whole
/// neighborhood is evaluated it moves to the best strictly-improving
/// neighbor; at a local optimum it restarts at a fresh random point, until
/// the space (or the budget) is exhausted.
#[derive(Clone, Debug)]
pub struct HillClimb {
    rng: Rng,
    current: Option<usize>,
}

impl HillClimb {
    pub fn new(seed: u64) -> HillClimb {
        HillClimb {
            rng: Rng::new(seed),
            current: None,
        }
    }
}

impl Strategy for HillClimb {
    fn name(&self) -> &'static str {
        "hill"
    }

    fn propose(&mut self, ctx: &Ctx<'_>, max: usize) -> Vec<usize> {
        loop {
            let Some(cur) = self.current else {
                // random restart among the unattempted points
                let free: Vec<usize> = (0..ctx.space.len())
                    .filter(|i| !ctx.attempted.contains(i))
                    .collect();
                if free.is_empty() {
                    return Vec::new();
                }
                let pick = free[self.rng.gen_usize(free.len())];
                self.current = Some(pick);
                return vec![pick];
            };
            let Some(&cur_score) = ctx.scores.get(&cur) else {
                // the seed (or move target) failed to evaluate: restart
                self.current = None;
                continue;
            };
            let neighbors = ctx.space.neighbors(cur);
            let mut fresh: Vec<usize> = neighbors
                .iter()
                .copied()
                .filter(|i| !ctx.attempted.contains(i))
                .collect();
            if !fresh.is_empty() {
                fresh.truncate(max);
                return fresh;
            }
            // neighborhood fully explored: climb or restart
            let mut best: Option<(usize, f64)> = None;
            for i in neighbors {
                if let Some(&s) = ctx.scores.get(&i) {
                    if best.map(|(_, bs)| s > bs).unwrap_or(true) {
                        best = Some((i, s));
                    }
                }
            }
            match best {
                Some((i, s)) if s > cur_score => self.current = Some(i),
                _ => self.current = None,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::workloads::table1;
    use crate::layout::LayoutRegistry;
    use crate::memsim::MemConfig;

    fn tiny_space() -> Enumerated {
        let reg = LayoutRegistry::with_builtins();
        crate::dse::Space::fig15(&table1(true)[..1], &MemConfig::default(), 2)
            .enumerate(&reg)
            .unwrap()
    }

    fn drain(
        strategy: &mut dyn Strategy,
        space: &Enumerated,
        score: impl Fn(usize) -> f64,
    ) -> Vec<usize> {
        let mut attempted = BTreeSet::new();
        let mut scores = BTreeMap::new();
        let mut order = Vec::new();
        loop {
            let batch = {
                let ctx = Ctx {
                    space,
                    attempted: &attempted,
                    scores: &scores,
                };
                strategy.propose(&ctx, usize::MAX)
            };
            if batch.is_empty() {
                break;
            }
            for i in batch {
                assert!(attempted.insert(i), "point {i} proposed twice");
                scores.insert(i, score(i));
                order.push(i);
            }
        }
        order
    }

    #[test]
    fn exhaustive_visits_everything_in_enumeration_order() {
        let space = tiny_space();
        let order = drain(&mut Exhaustive::new(), &space, |_| 0.0);
        assert_eq!(order, (0..space.len()).collect::<Vec<_>>());
    }

    #[test]
    fn random_search_is_a_seeded_permutation() {
        let space = tiny_space();
        let a = drain(&mut RandomSearch::new(7), &space, |_| 0.0);
        let b = drain(&mut RandomSearch::new(7), &space, |_| 0.0);
        let c = drain(&mut RandomSearch::new(8), &space, |_| 0.0);
        assert_eq!(a, b, "same seed, same order");
        assert_ne!(a, c, "different seed, different order");
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..space.len()).collect::<Vec<_>>());
    }

    #[test]
    fn hill_climb_terminates_and_covers_with_unbounded_budget() {
        let space = tiny_space();
        // score favoring high indices: the climb walks up, restarts fill in
        let order = drain(&mut HillClimb::new(3), &space, |i| i as f64);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..space.len()).collect::<Vec<_>>());
    }

    #[test]
    fn hill_climb_is_deterministic_for_a_seed() {
        let space = tiny_space();
        let a = drain(&mut HillClimb::new(11), &space, |i| (i % 5) as f64);
        let b = drain(&mut HillClimb::new(11), &space, |i| (i % 5) as f64);
        assert_eq!(a, b);
    }
}
