//! JSONL results journal: one [`Evaluation`] per line.
//!
//! The journal is the explorer's durability story: every evaluation is
//! appended (and flushed) the moment it completes, so a killed run leaves
//! a valid prefix behind. `--resume PATH` reads that prefix back and the
//! explorer skips every journaled fingerprint — a resume with a full
//! journal performs zero evaluations and reproduces the front from the
//! parsed records alone (the JSON encoding round-trips `f64` exactly).

use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::dse::evaluate::Evaluation;
use crate::util::json;
use anyhow::{anyhow, Context, Result};

/// Read every evaluation of a JSONL journal (blank lines ignored).
pub fn read(path: &Path) -> Result<Vec<Evaluation>> {
    let f = File::open(path).with_context(|| format!("opening journal {}", path.display()))?;
    let mut out = Vec::new();
    for (ln, line) in BufReader::new(f).lines().enumerate() {
        let line = line.with_context(|| format!("reading journal {}", path.display()))?;
        if line.trim().is_empty() {
            continue;
        }
        let j = json::parse(&line)
            .map_err(|e| anyhow!("{}:{}: {e}", path.display(), ln + 1))?;
        let eval = Evaluation::from_json(&j)
            .with_context(|| format!("{}:{}", path.display(), ln + 1))?;
        out.push(eval);
    }
    Ok(out)
}

/// Flushing JSONL writer.
pub struct Journal {
    path: PathBuf,
    out: BufWriter<File>,
}

impl Journal {
    /// Create (truncating any existing file).
    pub fn create(path: &Path) -> Result<Journal> {
        let f = File::create(path)
            .with_context(|| format!("creating journal {}", path.display()))?;
        Ok(Journal {
            path: path.to_path_buf(),
            out: BufWriter::new(f),
        })
    }

    /// Open for appending (the resume-in-place case).
    pub fn append_to(path: &Path) -> Result<Journal> {
        let f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        Ok(Journal {
            path: path.to_path_buf(),
            out: BufWriter::new(f),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record and flush it to disk.
    pub fn push(&mut self, eval: &Evaluation) -> Result<()> {
        writeln!(self.out, "{}", eval.to_json().to_string_compact())
            .and_then(|()| self.out.flush())
            .with_context(|| format!("writing journal {}", self.path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{Evaluator, Space};
    use crate::harness::workloads::table1;
    use crate::layout::LayoutRegistry;
    use crate::memsim::MemConfig;

    fn sample_evals(n: usize) -> Vec<Evaluation> {
        let space = Space::fig15(&table1(true)[..1], &MemConfig::default(), 2);
        let reg = LayoutRegistry::with_builtins();
        let points = space.enumerate(&reg).unwrap();
        let ev = Evaluator::new(&space, reg);
        points
            .points()
            .iter()
            .take(n)
            .map(|p| ev.evaluate(p).unwrap())
            .collect()
    }

    #[test]
    fn journal_round_trips_bit_exactly() {
        let evals = sample_evals(3);
        let path = std::env::temp_dir().join("cfa_dse_journal_roundtrip.jsonl");
        let mut j = Journal::create(&path).unwrap();
        for e in &evals {
            j.push(e).unwrap();
        }
        drop(j);
        let back = read(&path).unwrap();
        assert_eq!(back.len(), evals.len());
        for (a, b) in back.iter().zip(&evals) {
            assert_eq!(a.fingerprint(), b.fingerprint());
            assert_eq!(a.effective_mb_s().to_bits(), b.effective_mb_s().to_bits());
            assert_eq!(a.report.timing, b.report.timing);
            assert_eq!(a.area, b.area);
        }
        // appending extends without clobbering
        let more = sample_evals(4);
        let mut j = Journal::append_to(&path).unwrap();
        j.push(&more[3]).unwrap();
        drop(j);
        assert_eq!(read(&path).unwrap().len(), 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_lines_are_rejected_with_position() {
        let path = std::env::temp_dir().join("cfa_dse_journal_corrupt.jsonl");
        std::fs::write(&path, "{\"point\": 3}\n").unwrap();
        let err = format!("{:#}", read(&path).unwrap_err());
        assert!(err.contains(":1"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
