//! JSONL results journal: one [`Evaluation`] per line.
//!
//! The journal is the explorer's durability story: every evaluation is
//! appended (and flushed) the moment it completes, so a killed run leaves
//! a valid prefix behind. `--resume PATH` reads that prefix back and the
//! explorer skips every journaled fingerprint — a resume with a full
//! journal performs zero evaluations and reproduces the front from the
//! parsed records alone (the JSON encoding round-trips `f64` exactly).
//!
//! **Crash salvage.** A `kill -9` (or power cut) can land mid-`write`,
//! leaving a torn final line with no trailing newline. [`read_salvage`]
//! treats exactly the newline-terminated prefix as authoritative and
//! reports how many torn bytes it ignored; [`Journal::append_to`]
//! truncates that torn tail (with a logged warning) before appending, so
//! an in-place resume never concatenates a fresh record onto half of an
//! old one. Corruption *inside* the terminated prefix is still a hard
//! error — salvage recovers from interrupted writes, not from bit rot.

use std::collections::{BTreeMap, BTreeSet};
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::dse::evaluate::Evaluation;
use crate::dse::space::Enumerated;
use crate::util::json;
use anyhow::{anyhow, Context, Result};

fn parse_line(path: &Path, ln: usize, line: &str) -> Result<Evaluation> {
    let j = json::parse(line).map_err(|e| anyhow!("{}:{}: {e}", path.display(), ln + 1))?;
    Evaluation::from_json(&j).with_context(|| format!("{}:{}", path.display(), ln + 1))
}

/// Read every evaluation of a JSONL journal (blank lines ignored). Strict:
/// any unparsable line — including a torn final line — is an error. Resume
/// paths that must survive a crash use [`read_salvage`] instead.
pub fn read(path: &Path) -> Result<Vec<Evaluation>> {
    let f = File::open(path).with_context(|| format!("opening journal {}", path.display()))?;
    let mut out = Vec::new();
    for (ln, line) in BufReader::new(f).lines().enumerate() {
        let line = line.with_context(|| format!("reading journal {}", path.display()))?;
        if line.trim().is_empty() {
            continue;
        }
        out.push(parse_line(path, ln, &line)?);
    }
    Ok(out)
}

/// Read the newline-terminated prefix of a journal, ignoring a torn
/// (unterminated) trailing line. Returns the parsed records plus the
/// number of torn tail bytes that were ignored — `0` for a clean file.
/// Lines *within* the terminated prefix still parse strictly: an
/// interrupted append only ever tears the final line, so anything else
/// is real corruption and stays an error.
pub fn read_salvage(path: &Path) -> Result<(Vec<Evaluation>, usize)> {
    let bytes =
        std::fs::read(path).with_context(|| format!("opening journal {}", path.display()))?;
    let clean_len = match bytes.iter().rposition(|&b| b == b'\n') {
        Some(i) => i + 1,
        None => 0,
    };
    let torn = bytes.len() - clean_len;
    let text = std::str::from_utf8(&bytes[..clean_len])
        .with_context(|| format!("journal {} is not UTF-8", path.display()))?;
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        out.push(parse_line(path, ln, line)?);
    }
    Ok((out, torn))
}

/// Truncate a torn (newline-less) trailing line off `path`, logging what
/// was dropped. No-op when the file is absent, empty, or cleanly
/// terminated. Returns the number of bytes truncated.
pub fn truncate_torn_tail(path: &Path) -> Result<usize> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        // nothing to salvage; let the subsequent open surface real errors
        Err(_) => return Ok(0),
    };
    if bytes.is_empty() || bytes.ends_with(b"\n") {
        return Ok(0);
    }
    let keep = bytes
        .iter()
        .rposition(|&b| b == b'\n')
        .map(|i| i + 1)
        .unwrap_or(0);
    let torn = bytes.len() - keep;
    let f = OpenOptions::new()
        .write(true)
        .open(path)
        .with_context(|| format!("salvaging journal {}", path.display()))?;
    f.set_len(keep as u64)
        .with_context(|| format!("salvaging journal {}", path.display()))?;
    eprintln!(
        "dse: journal {}: truncated a torn trailing line ({torn} bytes); \
         the lost point will be re-evaluated",
        path.display()
    );
    Ok(torn)
}

/// Counters from a [`merge`] run, for the CLI summary line.
#[derive(Clone, Copy, Debug, Default)]
pub struct MergeStats {
    /// Input journals read.
    pub inputs: usize,
    /// Records parsed across every input (pre-dedup).
    pub read: usize,
    /// Torn tail bytes ignored across the inputs.
    pub torn_bytes: usize,
    /// Records written to the output.
    pub written: usize,
    /// Input records dropped by fingerprint dedup.
    pub duplicates: usize,
    /// Written records whose fingerprint is not in the ordering space
    /// (always 0 without one).
    pub out_of_space: usize,
}

/// Fold shard journals (or any set of tune journals) into one:
/// fingerprint-dedup across every input — a success supersedes a
/// failure/pruned record regardless of file order, the first success wins
/// otherwise (success records for one fingerprint are byte-identical by
/// the journal determinism contract, so "first" is cosmetic) — then write
/// the survivors to `out`.
///
/// With `order` (an enumerated space), in-space records are emitted in
/// enumeration order, out-of-space records after them in first-seen
/// order. Because a clean unsharded exhaustive run journals exactly the
/// space's success records in enumeration order, merging the shards of
/// such a run under its space reproduces the unsharded journal *file*
/// byte for byte. Without `order`, records keep first-seen order.
///
/// Inputs are salvaged, not strictly read: a shard killed mid-append
/// merges its clean prefix (the torn byte count is reported).
pub fn merge(out: &Path, inputs: &[PathBuf], order: Option<&Enumerated>) -> Result<MergeStats> {
    let mut stats = MergeStats {
        inputs: inputs.len(),
        ..MergeStats::default()
    };
    let mut best: BTreeMap<String, Evaluation> = BTreeMap::new();
    let mut seen_order: Vec<String> = Vec::new();
    for path in inputs {
        let (records, torn) = read_salvage(path)?;
        stats.read += records.len();
        stats.torn_bytes += torn;
        for eval in records {
            let fp = eval.fingerprint();
            match best.get(&fp) {
                None => {
                    seen_order.push(fp.clone());
                    best.insert(fp, eval);
                }
                Some(prev) => {
                    stats.duplicates += 1;
                    let prev_success = !prev.is_failed() && !prev.is_pruned();
                    let new_success = !eval.is_failed() && !eval.is_pruned();
                    if new_success && !prev_success {
                        best.insert(fp, eval);
                    }
                }
            }
        }
    }
    let mut w = Journal::create(out)?;
    match order {
        Some(space) => {
            let mut rest: BTreeSet<&str> = best.keys().map(String::as_str).collect();
            for p in space.points() {
                let fp = p.fingerprint();
                if let Some(eval) = best.get(&fp) {
                    w.push(eval)?;
                    stats.written += 1;
                    rest.remove(fp.as_str());
                }
            }
            for fp in &seen_order {
                if rest.contains(fp.as_str()) {
                    w.push(&best[fp])?;
                    stats.written += 1;
                    stats.out_of_space += 1;
                }
            }
        }
        None => {
            for fp in &seen_order {
                w.push(&best[fp])?;
                stats.written += 1;
            }
        }
    }
    Ok(stats)
}

/// Flushing JSONL writer.
pub struct Journal {
    path: PathBuf,
    out: BufWriter<File>,
}

impl Journal {
    /// Create (truncating any existing file).
    pub fn create(path: &Path) -> Result<Journal> {
        let f = File::create(path).with_context(|| format!("creating journal {}", path.display()))?;
        Ok(Journal {
            path: path.to_path_buf(),
            out: BufWriter::new(f),
        })
    }

    /// Open for appending (the resume-in-place case). A torn trailing line
    /// left by a killed writer is truncated first, so appended records
    /// always start at a line boundary.
    pub fn append_to(path: &Path) -> Result<Journal> {
        truncate_torn_tail(path)?;
        let f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        Ok(Journal {
            path: path.to_path_buf(),
            out: BufWriter::new(f),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record and flush it to disk. Fault site:
    /// `dse::journal::push`.
    pub fn push(&mut self, eval: &Evaluation) -> Result<()> {
        crate::util::faults::check_io("dse::journal::push")
            .and_then(|()| writeln!(self.out, "{}", eval.to_json().to_string_compact()))
            .and_then(|()| self.out.flush())
            .with_context(|| format!("writing journal {}", self.path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{Evaluator, Space};
    use crate::harness::workloads::table1;
    use crate::layout::LayoutRegistry;
    use crate::memsim::MemConfig;

    fn sample_evals(n: usize) -> Vec<Evaluation> {
        let space = Space::fig15(&table1(true)[..1], &MemConfig::default(), 2);
        let reg = LayoutRegistry::with_builtins();
        let points = space.enumerate(&reg).unwrap();
        let ev = Evaluator::new(&space, reg);
        points
            .points()
            .iter()
            .take(n)
            .map(|p| ev.evaluate(p).unwrap())
            .collect()
    }

    #[test]
    fn journal_round_trips_bit_exactly() {
        let evals = sample_evals(3);
        let path = std::env::temp_dir().join("cfa_dse_journal_roundtrip.jsonl");
        let mut j = Journal::create(&path).unwrap();
        for e in &evals {
            j.push(e).unwrap();
        }
        drop(j);
        let back = read(&path).unwrap();
        assert_eq!(back.len(), evals.len());
        for (a, b) in back.iter().zip(&evals) {
            assert_eq!(a.fingerprint(), b.fingerprint());
            assert_eq!(a.effective_mb_s().to_bits(), b.effective_mb_s().to_bits());
            assert_eq!(a.report().unwrap().timing, b.report().unwrap().timing);
            assert_eq!(a.area().unwrap(), b.area().unwrap());
        }
        // appending extends without clobbering
        let more = sample_evals(4);
        let mut j = Journal::append_to(&path).unwrap();
        j.push(&more[3]).unwrap();
        drop(j);
        assert_eq!(read(&path).unwrap().len(), 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn failed_records_round_trip() {
        let evals = sample_evals(1);
        let failed = Evaluation::failed(evals[0].point().clone(), "synthetic: boom");
        let path = std::env::temp_dir().join("cfa_dse_journal_failed.jsonl");
        let mut j = Journal::create(&path).unwrap();
        j.push(&evals[0]).unwrap();
        j.push(&failed).unwrap();
        drop(j);
        let back = read(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert!(!back[0].is_failed());
        assert!(back[1].is_failed());
        assert_eq!(back[1].fingerprint(), failed.fingerprint());
        assert_eq!(back[1].error(), Some("synthetic: boom"));
        assert!(back[1].report().is_none() && back[1].area().is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_lines_are_rejected_with_position() {
        let path = std::env::temp_dir().join("cfa_dse_journal_corrupt.jsonl");
        std::fs::write(&path, "{\"point\": 3}\n").unwrap();
        let err = format!("{:#}", read(&path).unwrap_err());
        assert!(err.contains(":1"), "{err}");
        // the line is newline-terminated, so salvage rejects it too:
        // torn-tail recovery is not a license to skip corrupt records
        let err = format!("{:#}", read_salvage(&path).unwrap_err());
        assert!(err.contains(":1"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn salvage_ignores_exactly_the_torn_tail() {
        let evals = sample_evals(2);
        let path = std::env::temp_dir().join("cfa_dse_journal_salvage.jsonl");
        let mut j = Journal::create(&path).unwrap();
        for e in &evals {
            j.push(e).unwrap();
        }
        drop(j);
        let clean = std::fs::read(&path).unwrap();
        // every truncation point mid-final-line salvages the first record
        // and reports the rest as torn; line boundaries salvage cleanly
        let first_line_end = clean.iter().position(|&b| b == b'\n').unwrap() + 1;
        for cut in first_line_end..=clean.len() {
            std::fs::write(&path, &clean[..cut]).unwrap();
            let (records, torn) = read_salvage(&path).unwrap();
            if cut == clean.len() {
                assert_eq!((records.len(), torn), (2, 0));
            } else {
                assert_eq!(records.len(), 1, "cut={cut}");
                assert_eq!(torn, cut - first_line_end, "cut={cut}");
            }
            assert_eq!(records[0].fingerprint(), evals[0].fingerprint());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn merge_dedups_and_success_supersedes_failure() {
        let evals = sample_evals(3);
        let dir = std::env::temp_dir();
        let (a, b, out) = (
            dir.join("cfa_dse_merge_a.jsonl"),
            dir.join("cfa_dse_merge_b.jsonl"),
            dir.join("cfa_dse_merge_out.jsonl"),
        );
        // shard A: a failure for point 0, a success for point 1
        let mut j = Journal::create(&a).unwrap();
        j.push(&Evaluation::failed(evals[0].point().clone(), "boom")).unwrap();
        j.push(&evals[1]).unwrap();
        drop(j);
        // shard B: the success for point 0, a pruned duplicate of point 1,
        // a pruned record for point 2
        let mut j = Journal::create(&b).unwrap();
        j.push(&evals[0]).unwrap();
        j.push(&Evaluation::pruned(evals[1].point().clone(), 123.0)).unwrap();
        j.push(&Evaluation::pruned(evals[2].point().clone(), 456.0)).unwrap();
        drop(j);
        let stats = merge(&out, &[a.clone(), b.clone()], None).unwrap();
        assert_eq!((stats.inputs, stats.read), (2, 5));
        assert_eq!((stats.written, stats.duplicates, stats.out_of_space), (3, 2, 0));
        let back = read(&out).unwrap();
        assert_eq!(back.len(), 3);
        // first-seen order without a space; successes superseded both
        // non-success duplicates
        assert_eq!(back[0].fingerprint(), evals[0].fingerprint());
        assert!(!back[0].is_failed(), "success supersedes the failure");
        assert!(!back[1].is_failed() && !back[1].is_pruned());
        assert!(back[2].is_pruned(), "unsuperseded pruned records survive");
        for p in [&a, &b, &out] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn append_to_truncates_a_torn_tail_before_appending() {
        let evals = sample_evals(2);
        let path = std::env::temp_dir().join("cfa_dse_journal_torn_append.jsonl");
        let mut j = Journal::create(&path).unwrap();
        j.push(&evals[0]).unwrap();
        drop(j);
        // simulate a kill mid-append: half a second record, no newline
        let torn_half = &evals[1].to_json().to_string_compact()[..20];
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(torn_half.as_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(read(&path).is_err(), "strict read must reject the torn file");
        // append_to salvages: the torn bytes vanish, the append lands clean
        let mut j = Journal::append_to(&path).unwrap();
        j.push(&evals[1]).unwrap();
        drop(j);
        let back = read(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[1].fingerprint(), evals[1].fingerprint());
        // a clean file is untouched by the salvage pass
        let before = std::fs::read(&path).unwrap();
        assert_eq!(truncate_torn_tail(&path).unwrap(), 0);
        assert_eq!(std::fs::read(&path).unwrap(), before);
        std::fs::remove_file(&path).ok();
    }
}
