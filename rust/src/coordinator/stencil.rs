//! End-to-end stencil driver: heat diffusion & friends through the full
//! stack (CFA/baseline layout → burst plans → AXI/DRAM timing → PJRT tile
//! compute → verification).
//!
//! Coordinate convention matches `python/compile/model.py`: the iteration
//! space is the skew-normalized (t, u, v) box with u = i + r·t; the initial
//! grid is the program input (CFA only re-allocates read-write arrays,
//! §IV.E) and is served from its own buffer at t = -1.

use crate::accel::{Pipeline, TileCost};
use crate::coordinator::reference::{stencil_reference, StencilKind};
use crate::coordinator::{AllocKind, HostMemory, RunReport};
use crate::memsim::{MemConfig, MemSim};
use crate::poly::deps::DepPattern;
use crate::poly::tiling::Tiling;
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// Configuration of one end-to-end stencil run.
#[derive(Clone, Debug)]
pub struct StencilRun {
    /// Artifact name in `artifacts/manifest.json`.
    pub artifact: String,
    pub kind: StencilKind,
    /// Original grid size.
    pub n: i64,
    pub m: i64,
    /// Time steps.
    pub steps: i64,
    pub alloc: AllocKind,
    /// Modeled compute parallelism (ops/cycle) for the exec stage.
    pub pe_ops_per_cycle: u64,
    pub seed: u64,
    /// Worker threads for burst planning (`coordinator::batch::PlanStream`).
    /// Planning is pure, so this never changes timing or numerics; the
    /// PJRT compute itself stays on the driver thread.
    pub parallel: usize,
}

impl StencilRun {
    /// Heat-diffusion default sized for the 8x32x32 jacobi artifact.
    pub fn heat_default(alloc: AllocKind) -> StencilRun {
        StencilRun {
            artifact: "jacobi2d5p_t8x32x32".into(),
            kind: StencilKind::Jacobi5p,
            n: 96,
            m: 96,
            steps: 32,
            alloc,
            pe_ops_per_cycle: 64,
            seed: 42,
            parallel: 1,
        }
    }
}

/// Execute the run; returns the report (verification included).
pub fn run_stencil(rt: &Runtime, cfg: &StencilRun, mem_cfg: &MemConfig) -> Result<RunReport> {
    let wall0 = Instant::now();
    let exe = rt.load(&cfg.artifact)?;
    let (tt, ti, tj) = match exe.info.tile[..] {
        [a, b, c] => (a, b, c),
        _ => bail!("artifact {} has no 3-d tile", cfg.artifact),
    };
    let r = exe.info.radius;
    if r != cfg.kind.radius() {
        bail!(
            "artifact radius {r} does not match benchmark {:?}",
            cfg.kind
        );
    }
    let h = 2 * r;
    let (n, m, steps) = (cfg.n, cfg.m, cfg.steps);
    let (uu, vv) = (n + r * steps, m + r * steps);
    if steps % tt != 0 || uu % ti != 0 || vv % tj != 0 {
        bail!(
            "tile ({tt},{ti},{tj}) must divide the skewed space ({steps},{uu},{vv}); \
             pick n,m,steps accordingly"
        );
    }

    let deps = DepPattern::new(cfg.kind.skewed_deps()).context("building deps")?;
    let tiling = Tiling::new(vec![steps, uu, vv], vec![tt, ti, tj]);
    let alloc = cfg.alloc.build(&tiling, &deps)?;
    let mut host = HostMemory::new(alloc.footprint());

    // program input: the initial grid (not a read-write array, kept as-is)
    let mut rng = Rng::new(cfg.seed);
    let init: Vec<f32> = (0..(n * m) as usize)
        .map(|_| rng.gen_f64() as f32)
        .collect();

    let sample = |host: &HostMemory, t: i64, u: i64, v: i64| -> f32 {
        if t < 0 {
            // initial plane t = -1 in skewed coords: i = u - r*t = u + r
            let (i, j) = (u + r, v + r);
            if (0..n).contains(&i) && (0..m).contains(&j) {
                init[(i * m + j) as usize]
            } else {
                0.0
            }
        } else if (0..steps).contains(&t) && (0..uu).contains(&u) && (0..vv).contains(&v) {
            let (_, addr) = alloc.read_loc(&[t, u, v]);
            host.read(addr)
        } else {
            0.0
        }
    };

    let mut sim = MemSim::new(mem_cfg.clone());
    let mut pipe = Pipeline::new();
    let mut raw_elems = 0u64;
    let mut useful_elems = 0u64;
    let mut transactions = 0u64;
    let flops_per_point = 2 * ((2 * r + 1) * (2 * r + 1)) as u64;

    let halo_t = (tt - 1).max(1);
    // burst planning streams ahead of the tile loop: one plan at a time
    // when serial (the old behavior), a bounded window planned in parallel
    // with --parallel N. consumption stays in lexicographic order either
    // way, so simulator state and Timing counters are unchanged
    let tiles: Vec<Vec<i64>> = tiling.tiles().collect();
    let plans = crate::coordinator::batch::PlanStream::new(alloc.as_ref(), &tiles, cfg.parallel);
    for (coords, plan) in tiles.iter().zip(plans) {
        let (bt, bu, bv) = (coords[0], coords[1], coords[2]);
        let (t0, u0, v0) = (bt * tt, bu * ti, bv * tj);

        // ---- assemble flow-in (the read stage's result)
        let mut prev = vec![0f32; ((ti + h) * (tj + h)) as usize];
        for x in 0..ti + h {
            for y in 0..tj + h {
                prev[(x * (tj + h) + y) as usize] =
                    sample(&host, t0 - 1, u0 - h + x, v0 - h + y);
            }
        }
        let mut halo_u = vec![0f32; (halo_t * h * (tj + h)) as usize];
        let mut halo_v = vec![0f32; (halo_t * ti * h) as usize];
        for s in 1..tt {
            for x in 0..h {
                for y in 0..tj + h {
                    halo_u[(((s - 1) * h + x) * (tj + h) + y) as usize] =
                        sample(&host, t0 + s - 1, u0 - h + x, v0 - h + y);
                }
            }
            for x in 0..ti {
                for y in 0..h {
                    halo_v[(((s - 1) * ti + x) * h + y) as usize] =
                        sample(&host, t0 + s - 1, u0 + x, v0 - h + y);
                }
            }
        }

        // ---- execute on PJRT
        let out = exe.execute(
            &[t0 as i32, u0 as i32, v0 as i32, n as i32, m as i32],
            &[
                (&prev, &[ti + h, tj + h]),
                (&halo_u, &[halo_t, h, tj + h]),
                (&halo_v, &[halo_t, ti, h]),
            ],
        )?;
        let (facet_t, facet_u, facet_v) = (&out[0], &out[1], &out[2]);

        // ---- write flow-out facets to global memory (no per-point Vec:
        // the allocation streams the replicated locations directly)
        let store = |host: &mut HostMemory, p: &[i64], v: f32| {
            alloc.for_each_write_loc(p, &mut |_, addr| host.write(addr, v));
        };
        for x in 0..ti {
            for y in 0..tj {
                store(
                    &mut host,
                    &[t0 + tt - 1, u0 + x, v0 + y],
                    facet_t[(x * tj + y) as usize],
                );
            }
        }
        for s in 0..tt {
            for x in 0..h {
                for y in 0..tj {
                    store(
                        &mut host,
                        &[t0 + s, u0 + ti - h + x, v0 + y],
                        facet_u[((s * h + x) * tj + y) as usize],
                    );
                }
            }
            for x in 0..ti {
                for y in 0..h {
                    store(
                        &mut host,
                        &[t0 + s, u0 + x, v0 + tj - h + y],
                        facet_v[((s * ti + x) * h + y) as usize],
                    );
                }
            }
        }

        // ---- timing through the memory simulator + task pipeline
        let (rd, wr) = crate::accel::tile_mem_cycles(&mut sim, &plan.read_runs, &plan.write_runs);
        let vol = tiling.tile_rect(coords).volume();
        pipe.push(TileCost {
            read: rd,
            exec: vol * flops_per_point / cfg.pe_ops_per_cycle.max(1),
            write: wr,
        });
        raw_elems += plan.read_raw() + plan.write_raw();
        useful_elems += plan.read_useful + plan.write_useful;
        transactions += plan.transactions() as u64;
    }
    let stats = pipe.finish();

    // ---- verification against the native reference
    let reference = stencil_reference(&init, n as usize, m as usize, cfg.kind, steps as usize);
    let mut max_err = 0f64;
    for i in 0..n {
        for j in 0..m {
            let (u, v) = (i + r * (steps - 1), j + r * (steps - 1));
            let (_, addr) = alloc.read_loc(&[steps - 1, u, v]);
            let got = host.read(addr);
            let want = reference[(i * m + j) as usize];
            max_err = max_err.max((got - want).abs() as f64);
        }
    }

    Ok(RunReport {
        benchmark: format!("{:?}/{}x{}x{}", cfg.kind, steps, n, m).to_lowercase(),
        alloc: cfg.alloc.name().to_string(),
        tiles: tiling.num_tiles(),
        makespan_cycles: stats.makespan,
        mem_busy_cycles: stats.mem_busy,
        raw_bytes: raw_elems * mem_cfg.elem_bytes,
        useful_bytes: useful_elems * mem_cfg.elem_bytes,
        transactions,
        max_abs_err: max_err,
        wall_secs: wall0.elapsed().as_secs_f64(),
    })
}
