//! End-to-end stencil driver — **deprecated shim, kept for one PR**.
//!
//! The driver itself lives in the experiment subsystem
//! ([`crate::experiment`]): a [`StencilRun`] is translated into a
//! [`WorkloadSpec::Stencil`](crate::experiment::WorkloadSpec) session and
//! executed in `Mode::Data`, which runs the identical read–execute–write
//! loop (layout → burst plans → AXI/DRAM timing → PJRT tile compute →
//! verification). New code should build the session directly:
//!
//! ```no_run
//! use cfa::coordinator::reference::StencilKind;
//! use cfa::experiment::{ExperimentSpec, Mode};
//!
//! let session = ExperimentSpec::builder()
//!     .stencil("jacobi2d5p_t8x32x32", StencilKind::Jacobi5p, vec![8, 32, 32], 96, 96, 32)
//!     .layout("cfa")
//!     .compile()?;
//! let report = session.run(Mode::Data { seed: 42 })?;
//! # Ok::<(), anyhow::Error>(())
//! ```

use crate::coordinator::reference::StencilKind;
use crate::coordinator::{AllocKind, RunReport};
use crate::experiment::{ExperimentSpec, Mode};
use crate::memsim::MemConfig;
use crate::runtime::Runtime;
use anyhow::Result;

/// Configuration of one end-to-end stencil run (legacy shape; the session
/// builder covers the same fields).
#[derive(Clone, Debug)]
pub struct StencilRun {
    /// Artifact name in `artifacts/manifest.json`.
    pub artifact: String,
    pub kind: StencilKind,
    /// Original grid size.
    pub n: i64,
    pub m: i64,
    /// Time steps.
    pub steps: i64,
    pub alloc: AllocKind,
    /// Modeled compute parallelism (ops/cycle) for the exec stage.
    pub pe_ops_per_cycle: u64,
    pub seed: u64,
    /// Worker threads for burst planning (`coordinator::batch::PlanStream`).
    /// Planning is pure, so this never changes timing or numerics; the
    /// PJRT compute itself stays on the driver thread.
    pub parallel: usize,
}

impl StencilRun {
    /// Heat-diffusion default sized for the 8x32x32 jacobi artifact.
    pub fn heat_default(alloc: AllocKind) -> StencilRun {
        StencilRun {
            artifact: "jacobi2d5p_t8x32x32".into(),
            kind: StencilKind::Jacobi5p,
            n: 96,
            m: 96,
            steps: 32,
            alloc,
            pe_ops_per_cycle: 64,
            seed: 42,
            parallel: 1,
        }
    }
}

/// Execute the run; returns the report (verification included).
/// Deprecated shim over [`crate::experiment::Session::run_with_runtime`].
pub fn run_stencil(rt: &Runtime, cfg: &StencilRun, mem_cfg: &MemConfig) -> Result<RunReport> {
    // the artifact's tile shape defines the tiling, exactly as before
    let exe = rt.load(&cfg.artifact)?;
    let session = ExperimentSpec::builder()
        .stencil(
            cfg.artifact.clone(),
            cfg.kind,
            exe.info.tile.clone(),
            cfg.n,
            cfg.m,
            cfg.steps,
        )
        .layout(cfg.alloc.name())
        .threads(cfg.parallel)
        .pe_ops_per_cycle(cfg.pe_ops_per_cycle)
        .mem(mem_cfg.clone())
        .compile()?;
    Ok(session
        .run_with_runtime(rt, Mode::Data { seed: cfg.seed })?
        .into_run_report())
}
