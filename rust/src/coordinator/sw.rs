//! End-to-end Smith-Waterman-3seq driver (Table I's wavefront benchmark):
//! 3-D dynamic-programming lattice, naturally backwards dependencies, no
//! skewing needed.

use crate::accel::{Pipeline, TileCost};
use crate::coordinator::reference::{sw3_deps, sw3_reference};
use crate::coordinator::{AllocKind, HostMemory, RunReport};
use crate::memsim::MemConfig;
use crate::memsim::MemSim;
use crate::poly::deps::DepPattern;
use crate::poly::tiling::Tiling;
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::time::Instant;

/// Configuration for one 3-seq alignment run.
#[derive(Clone, Debug)]
pub struct SwRun {
    pub artifact: String,
    /// Sequence lengths (iteration space (ni, nj, nk)).
    pub ni: i64,
    pub nj: i64,
    pub nk: i64,
    pub alloc: AllocKind,
    pub pe_ops_per_cycle: u64,
    pub seed: u64,
    /// Worker threads for burst planning (pure; timing/numerics unchanged).
    pub parallel: usize,
}

impl SwRun {
    pub fn default_run(alloc: AllocKind) -> SwRun {
        SwRun {
            artifact: "sw3_t16x16x16".into(),
            ni: 48,
            nj: 48,
            nk: 48,
            alloc,
            pe_ops_per_cycle: 64,
            seed: 7,
            parallel: 1,
        }
    }
}

/// Execute the alignment through the full stack; verify every facet value
/// against the native DP reference.
pub fn run_sw(rt: &Runtime, cfg: &SwRun, mem_cfg: &MemConfig) -> Result<RunReport> {
    let wall0 = Instant::now();
    let exe = rt.load(&cfg.artifact)?;
    let (si, sj, sk) = match exe.info.tile[..] {
        [a, b, c] => (a, b, c),
        _ => bail!("artifact {} has no 3-d tile", cfg.artifact),
    };
    let (ni, nj, nk) = (cfg.ni, cfg.nj, cfg.nk);
    if ni % si != 0 || nj % sj != 0 || nk % sk != 0 {
        bail!("tile ({si},{sj},{sk}) must divide ({ni},{nj},{nk})");
    }
    let deps = DepPattern::new(sw3_deps())?;
    let tiling = Tiling::new(vec![ni, nj, nk], vec![si, sj, sk]);
    let alloc = cfg.alloc.build(&tiling, &deps)?;
    let mut host = HostMemory::new(alloc.footprint());

    // program inputs: three symbol sequences over a 4-letter alphabet
    let mut rng = Rng::new(cfg.seed);
    let mut seq = |len: i64| -> Vec<f32> {
        (0..len).map(|_| rng.gen_range(4) as f32).collect()
    };
    let a = seq(ni);
    let b = seq(nj);
    let c = seq(nk);

    let sample = |host: &HostMemory, i: i64, j: i64, k: i64| -> f32 {
        if i < 0 || j < 0 || k < 0 {
            0.0 // zero boundary of the DP
        } else {
            let (_, addr) = alloc.read_loc(&[i, j, k]);
            host.read(addr)
        }
    };

    let mut sim = MemSim::new(mem_cfg.clone());
    let mut pipe = Pipeline::new();
    let (mut raw_elems, mut useful_elems, mut transactions) = (0u64, 0u64, 0u64);

    // burst planning streams ahead of the tile loop: one plan at a time
    // when serial (the old behavior), a bounded window planned in parallel
    // with --parallel N. consumption order is unchanged either way, so
    // timing is bit-identical
    let tiles: Vec<Vec<i64>> = tiling.tiles().collect();
    let plans = crate::coordinator::batch::PlanStream::new(alloc.as_ref(), &tiles, cfg.parallel);
    for (coords, plan) in tiles.iter().zip(plans) {
        let (i0, j0, k0) = (coords[0] * si, coords[1] * sj, coords[2] * sk);
        // ---- flow-in: three halo planes (zero outside the lattice)
        let mut halo_i = vec![0f32; ((sj + 1) * (sk + 1)) as usize];
        for x in 0..sj + 1 {
            for y in 0..sk + 1 {
                halo_i[(x * (sk + 1) + y) as usize] =
                    sample(&host, i0 - 1, j0 - 1 + x, k0 - 1 + y);
            }
        }
        let mut halo_j = vec![0f32; (si * (sk + 1)) as usize];
        for x in 0..si {
            for y in 0..sk + 1 {
                halo_j[(x * (sk + 1) + y) as usize] = sample(&host, i0 + x, j0 - 1, k0 - 1 + y);
            }
        }
        let mut halo_k = vec![0f32; (si * sj) as usize];
        for x in 0..si {
            for y in 0..sj {
                halo_k[(x * sj + y) as usize] = sample(&host, i0 + x, j0 + y, k0 - 1);
            }
        }

        // ---- execute
        let out = exe.execute(
            &[],
            &[
                (&a[i0 as usize..(i0 + si) as usize], &[si]),
                (&b[j0 as usize..(j0 + sj) as usize], &[sj]),
                (&c[k0 as usize..(k0 + sk) as usize], &[sk]),
                (&halo_i, &[sj + 1, sk + 1]),
                (&halo_j, &[si, sk + 1]),
                (&halo_k, &[si, sj]),
            ],
        )?;
        let (facet_i, facet_j, facet_k) = (&out[0], &out[1], &out[2]);

        // ---- write facets (streamed locations, no per-point Vec)
        let store = |host: &mut HostMemory, p: &[i64], v: f32| {
            alloc.for_each_write_loc(p, &mut |_, addr| host.write(addr, v));
        };
        for x in 0..sj {
            for y in 0..sk {
                store(
                    &mut host,
                    &[i0 + si - 1, j0 + x, k0 + y],
                    facet_i[(x * sk + y) as usize],
                );
            }
        }
        for x in 0..si {
            for y in 0..sk {
                store(
                    &mut host,
                    &[i0 + x, j0 + sj - 1, k0 + y],
                    facet_j[(x * sk + y) as usize],
                );
            }
        }
        for x in 0..si {
            for y in 0..sj {
                store(
                    &mut host,
                    &[i0 + x, j0 + y, k0 + sk - 1],
                    facet_k[(x * sj + y) as usize],
                );
            }
        }

        // ---- timing
        let (rd, wr) = crate::accel::tile_mem_cycles(&mut sim, &plan.read_runs, &plan.write_runs);
        let vol = tiling.tile_rect(coords).volume();
        pipe.push(TileCost {
            read: rd,
            exec: vol * 14 / cfg.pe_ops_per_cycle.max(1), // 7 max-adds per cell
            write: wr,
        });
        raw_elems += plan.read_raw() + plan.write_raw();
        useful_elems += plan.read_useful + plan.write_useful;
        transactions += plan.transactions() as u64;
    }
    let stats = pipe.finish();

    // ---- verify all facet values against the reference DP
    let reference = sw3_reference(&a, &b, &c);
    let mut max_err = 0f64;
    for i in 0..ni {
        for j in 0..nj {
            for k in 0..nk {
                let on_facet =
                    (i % si == si - 1) || (j % sj == sj - 1) || (k % sk == sk - 1);
                if !on_facet {
                    continue;
                }
                let (_, addr) = alloc.read_loc(&[i, j, k]);
                let got = host.read(addr);
                let want = reference[((i * nj + j) * nk + k) as usize];
                max_err = max_err.max((got - want).abs() as f64);
            }
        }
    }

    Ok(RunReport {
        benchmark: format!("sw3/{ni}x{nj}x{nk}"),
        alloc: cfg.alloc.name().to_string(),
        tiles: tiling.num_tiles(),
        makespan_cycles: stats.makespan,
        mem_busy_cycles: stats.mem_busy,
        raw_bytes: raw_elems * mem_cfg.elem_bytes,
        useful_bytes: useful_elems * mem_cfg.elem_bytes,
        transactions,
        max_abs_err: max_err,
        wall_secs: wall0.elapsed().as_secs_f64(),
    })
}
