//! End-to-end Smith-Waterman-3seq driver — **deprecated shim, kept for
//! one PR**. The driver body lives in [`crate::experiment`]; a [`SwRun`]
//! is translated into a [`WorkloadSpec::Sw3`](crate::experiment::WorkloadSpec)
//! session and executed in `Mode::Data` (3-D dynamic-programming lattice,
//! naturally backwards dependencies, no skewing needed).

use crate::coordinator::{AllocKind, RunReport};
use crate::experiment::{ExperimentSpec, Mode};
use crate::memsim::MemConfig;
use crate::runtime::Runtime;
use anyhow::Result;

/// Configuration for one 3-seq alignment run (legacy shape).
#[derive(Clone, Debug)]
pub struct SwRun {
    pub artifact: String,
    /// Sequence lengths (iteration space (ni, nj, nk)).
    pub ni: i64,
    pub nj: i64,
    pub nk: i64,
    pub alloc: AllocKind,
    pub pe_ops_per_cycle: u64,
    pub seed: u64,
    /// Worker threads for burst planning (pure; timing/numerics unchanged).
    pub parallel: usize,
}

impl SwRun {
    pub fn default_run(alloc: AllocKind) -> SwRun {
        SwRun {
            artifact: "sw3_t16x16x16".into(),
            ni: 48,
            nj: 48,
            nk: 48,
            alloc,
            pe_ops_per_cycle: 64,
            seed: 7,
            parallel: 1,
        }
    }
}

/// Execute the alignment through the full stack; verify every facet value
/// against the native DP reference. Deprecated shim over
/// [`crate::experiment::Session::run_with_runtime`].
pub fn run_sw(rt: &Runtime, cfg: &SwRun, mem_cfg: &MemConfig) -> Result<RunReport> {
    let exe = rt.load(&cfg.artifact)?;
    let session = ExperimentSpec::builder()
        .sw3(
            cfg.artifact.clone(),
            exe.info.tile.clone(),
            cfg.ni,
            cfg.nj,
            cfg.nk,
        )
        .layout(cfg.alloc.name())
        .threads(cfg.parallel)
        .pe_ops_per_cycle(cfg.pe_ops_per_cycle)
        .mem(mem_cfg.clone())
        .compile()?;
    Ok(session
        .run_with_runtime(rt, Mode::Data { seed: cfg.seed })?
        .into_run_report())
}
