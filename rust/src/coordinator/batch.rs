//! Batched tile coordination: a wavefront scheduler plus a parallel,
//! deterministically-replayed executor.
//!
//! The serial coordinators drive tiles one by one: plan bursts, marshal
//! data, account timing, repeat. At sweep scale (Table I × tile sizes ×
//! allocations, or a 128³-tile space) the pure parts of that loop — burst
//! planning against the [`Allocation`] and host-memory marshalling —
//! dominate wall time, yet nothing about them is order-dependent. This
//! module splits the loop into the two phases the memory simulator's
//! [`ReplayState`](crate::memsim::ReplayState) separation enables:
//!
//! 1. **Plan phase (parallel).** Tiles are grouped into *waves* by
//!    dependence depth over the tile graph (every producer tile sits in a
//!    strictly earlier wave). Within a wave, burst planning and data
//!    marshalling run concurrently on [`crate::util::par`] workers; both
//!    are pure functions of the allocation and the pre-wave host memory.
//! 2. **Replay phase (serial, deterministic).** Each wave's plans are
//!    replayed through the single shared [`MemSim`] in lexicographic tile
//!    order — the same order a serial run uses — so `Timing` counters,
//!    cycle totals and host-memory contents are **bit-identical** to
//!    serial execution regardless of worker count. `tests/batch_parallel.rs`
//!    asserts this across all four allocations and random Table-I tilings.
//!
//! The wave structure is not just a parallelism vehicle: it is the tile
//! schedule a multi-accelerator deployment would use (tiles of one wave
//! have no mutual flow), so `Schedule::wavefront` doubles as the answer to
//! "how many tiles can legally be in flight at once" (`max_width`).

use crate::coordinator::HostMemory;
use crate::layout::{linearize, Allocation, PlanCache, PlanCacheState, TilePlan};
use crate::memsim::{Dir, MemConfig, MemSim, Timing, Txn, TxnTrace};
use crate::poly::deps::DepPattern;
use crate::poly::flow::producer_tiles;
use crate::poly::tiling::Tiling;
use crate::poly::vec::IVec;
use crate::util::par::parallel_map;

/// A tile execution schedule: waves of tiles, each wave internally in
/// lexicographic order, with all inter-tile flow pointing to earlier waves.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schedule {
    waves: Vec<Vec<IVec>>,
    /// True iff the wave grouping respects inter-tile dependences
    /// (producers strictly earlier). Only such schedules may drive the
    /// data path; [`Schedule::flat`] is timing-only.
    dependence_safe: bool,
}

impl Schedule {
    /// The degenerate schedule: one wave holding every tile in
    /// lexicographic order. Replaying it reproduces the classic serial
    /// sweep exactly (it is what `harness::figures::measure_bandwidth_named`
    /// uses); it carries no dependence information, so only use it for
    /// timing/planning work, never for data-path execution.
    pub fn flat(tiling: &Tiling) -> Schedule {
        Schedule {
            waves: vec![tiling.tiles().collect()],
            dependence_safe: false,
        }
    }

    /// Group tiles by dependence depth over the tile graph derived from
    /// `deps`: depth 0 tiles have no flow-in, and every producer of a
    /// depth-d tile has depth < d. Backwards dependence patterns make all
    /// producers lexicographic predecessors, so one lexicographic pass
    /// computes exact depths (longest chain, not the coarser diagonal
    /// heuristic — a pattern active along one axis only yields as many
    /// waves as tiles along that axis, with full planes running wide).
    pub fn wavefront(tiling: &Tiling, deps: &DepPattern) -> Schedule {
        let counts = tiling.tile_counts();
        let mut depth_of: Vec<usize> = Vec::with_capacity(tiling.num_tiles() as usize);
        let mut waves: Vec<Vec<IVec>> = Vec::new();
        for coords in tiling.tiles() {
            // tiles() is lexicographic and linearize(coords, counts) is the
            // running index, so every producer's depth is already known
            debug_assert_eq!(linearize(&coords, &counts) as usize, depth_of.len());
            let d = producer_tiles(tiling, deps, &coords)
                .iter()
                .map(|(p, _)| depth_of[linearize(p, &counts) as usize] + 1)
                .max()
                .unwrap_or(0);
            depth_of.push(d);
            if waves.len() <= d {
                waves.resize_with(d + 1, Vec::new);
            }
            waves[d].push(coords);
        }
        Schedule {
            waves,
            dependence_safe: true,
        }
    }

    /// Whether this schedule may drive the data path (see [`Schedule`]).
    pub fn is_dependence_safe(&self) -> bool {
        self.dependence_safe
    }

    pub fn waves(&self) -> &[Vec<IVec>] {
        &self.waves
    }

    pub fn num_waves(&self) -> usize {
        self.waves.len()
    }

    pub fn num_tiles(&self) -> u64 {
        self.waves.iter().map(|w| w.len() as u64).sum()
    }

    /// Widest wave — the schedule's available tile-level parallelism.
    pub fn max_width(&self) -> usize {
        self.waves.iter().map(|w| w.len()).max().unwrap_or(0)
    }
}

/// Aggregate outcome of one batched run. All fields are exact counters, so
/// `PartialEq` compares two runs bit-for-bit (the parallel-equals-serial
/// tests rely on it).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BatchReport {
    pub tiles: u64,
    pub waves: usize,
    /// Memory-interface makespan of the whole replay, in bus cycles.
    pub cycles: u64,
    /// Full simulator counters at the end of the replay.
    pub timing: Timing,
    pub raw_elems: u64,
    pub useful_elems: u64,
    pub transactions: u64,
}

/// Burst-plan `tiles` against `alloc` with `threads` workers; results are
/// in input order. The workhorse behind both the batch coordinator and the
/// serial drivers' `--parallel` mode (planning is pure, so the serial
/// drivers can fan it out even though their PJRT compute stays on one
/// thread). Plans through a private [`PlanCache`], so interior tiles of an
/// exact tiling rebase one canonical plan instead of re-deriving it — the
/// output is still `alloc.plan(tile)` bit for bit. Holds all plans at once;
/// for bounded memory over long tile streams use [`PlanStream`].
pub fn plan_tiles(alloc: &dyn Allocation, tiles: &[IVec], threads: usize) -> Vec<TilePlan> {
    let cache = PlanCache::new(alloc);
    plan_tiles_cached(&cache, tiles, threads)
}

/// [`plan_tiles`] against a caller-owned [`PlanCache`] (share one cache
/// across waves/chunks so the canonical interior plan is derived once).
pub fn plan_tiles_cached(cache: &PlanCache, tiles: &[IVec], threads: usize) -> Vec<TilePlan> {
    let _span = crate::obs::span("batch::plan");
    parallel_map(tiles, threads, |coords| cache.plan(coords))
}

/// Compile a schedule's burst plans into a flat [`TxnTrace`]: every tile's
/// read runs then write runs, tiles in lexicographic order within each
/// wave, waves in schedule order — **exactly** the submit order
/// [`BatchCoordinator::run_timing`] replays, so replaying the trace through
/// [`MemSim::run_trace`](crate::memsim::MemSim::run_trace) is bit-identical
/// to a coordinator timing run. The trace also accumulates the aggregate
/// counters a [`BatchReport`] carries (tiles, waves, raw/useful elements),
/// making it self-contained for report construction.
///
/// The trace is **config-independent**: entries are element-unit runs, so
/// one compilation serves every `MemConfig`/PE variant of the same
/// geometry (the premise of the `dse` trace cache).
pub fn compile_trace<'a>(
    cache: &'a PlanCache<'a>,
    schedule: &'a Schedule,
    threads: usize,
) -> TxnTrace {
    let _span = crate::obs::span("batch::compile_trace");
    let mut trace = TxnTrace::new();
    trace.waves = schedule.num_waves();
    for wave in schedule.waves() {
        for plan in PlanStream::with_cache(cache, wave, threads) {
            for r in &plan.read_runs {
                trace.push(Dir::Read, r.addr, r.len);
            }
            for r in &plan.write_runs {
                trace.push(Dir::Write, r.addr, r.len);
            }
            trace.raw_elems += plan.read_raw() + plan.write_raw();
            trace.useful_elems += plan.read_useful + plan.write_useful;
            trace.tiles += 1;
        }
    }
    trace
}

/// Upper bound on plans a batched executor keeps live at once; chunks of
/// this size are planned ahead in schedule order and consumed in order.
const PLAN_CHUNK: usize = 256;

/// The plan source a [`PlanStream`] draws from: its own cache, or one
/// shared by the caller (the batch coordinator reuses a single cache
/// across all waves of a schedule).
enum PlanSource<'a> {
    Owned(PlanCache<'a>),
    Shared(&'a PlanCache<'a>),
}

impl<'a> PlanSource<'a> {
    fn cache(&self) -> &PlanCache<'a> {
        match self {
            PlanSource::Owned(c) => c,
            PlanSource::Shared(c) => c,
        }
    }
}

/// Streaming wrapper around [`plan_tiles`]: yields each tile's plan in
/// input order while keeping at most one chunk of plans in memory — one
/// plan at a time when serial (`threads <= 1`, exactly the classic
/// plan-per-tile loop), a bounded multiple of the worker count otherwise.
/// Both serial coordinators drive their tile loops through this; interior
/// tiles come out of the memoized fast path either way.
pub struct PlanStream<'a> {
    source: PlanSource<'a>,
    tiles: &'a [IVec],
    threads: usize,
    chunk: usize,
    next: usize,
    buffered: std::collections::VecDeque<TilePlan>,
}

impl<'a> PlanStream<'a> {
    pub fn new(alloc: &'a dyn Allocation, tiles: &'a [IVec], threads: usize) -> PlanStream<'a> {
        PlanStream::build(PlanSource::Owned(PlanCache::new(alloc)), tiles, threads)
    }

    /// Stream over `tiles` drawing plans from a shared cache.
    pub fn with_cache(
        cache: &'a PlanCache<'a>,
        tiles: &'a [IVec],
        threads: usize,
    ) -> PlanStream<'a> {
        PlanStream::build(PlanSource::Shared(cache), tiles, threads)
    }

    fn build(source: PlanSource<'a>, tiles: &'a [IVec], threads: usize) -> PlanStream<'a> {
        let chunk = if threads > 1 {
            (threads * 8).min(PLAN_CHUNK)
        } else {
            1
        };
        PlanStream {
            source,
            tiles,
            threads,
            chunk,
            next: 0,
            buffered: std::collections::VecDeque::new(),
        }
    }
}

impl Iterator for PlanStream<'_> {
    type Item = TilePlan;

    fn next(&mut self) -> Option<TilePlan> {
        if self.buffered.is_empty() {
            if self.next >= self.tiles.len() {
                return None;
            }
            let end = (self.next + self.chunk).min(self.tiles.len());
            self.buffered.extend(plan_tiles_cached(
                self.source.cache(),
                &self.tiles[self.next..end],
                self.threads,
            ));
            self.next = end;
        }
        self.buffered.pop_front()
    }
}

/// The deterministic synthetic tile kernel of the data path: gathers the
/// tile's flow-in from host memory through the allocation's canonical read
/// addresses, then writes every flow-out point (all its replicated
/// locations) a value mixing the point's coordinates with the gathered
/// mean. Pure in `(plan, pre-wave host, seed)` — the property the parallel
/// data path needs — while still making every output value depend on real
/// upstream data, so a scheduling bug (a tile running before its producer)
/// corrupts the final buffer instead of going unnoticed.
pub fn execute_tile(
    alloc: &dyn Allocation,
    plan: &TilePlan,
    host: &HostMemory,
    seed: u64,
) -> Vec<(u64, f32)> {
    // Gather through the run cursor: contiguous host slices instead of one
    // addr_of per point. The cursor enumerates addresses in row-major point
    // order, so this f32 fold adds the same values in the same order as the
    // old pointwise loop — bit-identical bias.
    let mut acc = 0f32;
    let mut n = 0u64;
    let mem = host.as_slice();
    for pc in &plan.read_pieces {
        alloc.for_each_run(pc.array, &pc.iter_box, &mut |addr, len| {
            for &v in &mem[addr as usize..(addr + len) as usize] {
                acc += v;
            }
            n += len;
        });
    }
    let bias = if n == 0 { 0.0 } else { acc / n as f32 };
    let mut writes = Vec::new();
    for pc in &plan.write_pieces {
        pc.iter_box.for_each_point(&mut |p| {
            let v = 0.5 * bias + point_hash(seed, p);
            alloc.for_each_write_loc(p, &mut |_, addr| writes.push((addr, v)));
        });
    }
    writes
}

/// Deterministic coordinate hash in [0, 1) (splitmix-style mixing).
fn point_hash(seed: u64, p: &[i64]) -> f32 {
    let mut h = seed ^ 0x9E37_79B9_7F4A_7C15;
    for &x in p {
        h ^= (x as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = h.rotate_left(27).wrapping_mul(0x94D0_49BB_1331_11EB);
    }
    (h >> 40) as f32 / (1u64 << 24) as f32
}

/// Batched coordinator over one allocation and schedule.
pub struct BatchCoordinator<'a> {
    alloc: &'a dyn Allocation,
    schedule: &'a Schedule,
    mem_cfg: MemConfig,
    threads: usize,
    cache: Option<&'a PlanCacheState>,
}

impl<'a> BatchCoordinator<'a> {
    pub fn new(
        alloc: &'a dyn Allocation,
        schedule: &'a Schedule,
        mem_cfg: MemConfig,
    ) -> BatchCoordinator<'a> {
        BatchCoordinator {
            alloc,
            schedule,
            mem_cfg,
            threads: 1,
            cache: None,
        }
    }

    /// Worker threads for the plan/marshal phase (1 = serial).
    pub fn threads(mut self, n: usize) -> BatchCoordinator<'a> {
        self.threads = n.max(1);
        self
    }

    /// Plan through caller-owned cache state (must have been created for
    /// this coordinator's allocation). A [`Session`](crate::experiment)
    /// passes its own state here so the canonical interior plan is derived
    /// once per session rather than once per run; planning output is
    /// unchanged either way (`cache.plan ≡ alloc.plan`).
    pub fn cache_state(mut self, state: &'a PlanCacheState) -> BatchCoordinator<'a> {
        self.cache = Some(state);
        self
    }

    /// The plan cache this run will draw from: a view over the shared
    /// state when one was provided, a private cache otherwise.
    fn plan_cache(&self) -> PlanCache<'a> {
        match self.cache {
            Some(state) => PlanCache::with_state(self.alloc, state),
            None => PlanCache::new(self.alloc),
        }
    }

    /// Serially replay one wave's plans (lexicographic tile order: reads
    /// then writes per tile, exactly as the serial sweep submits them) and
    /// fold the accounting into `report`.
    fn replay_wave(&self, sim: &mut MemSim, plans: &[TilePlan], report: &mut BatchReport) {
        for plan in plans {
            for r in &plan.read_runs {
                sim.submit(&Txn {
                    dir: Dir::Read,
                    addr: r.addr,
                    len: r.len,
                });
            }
            for r in &plan.write_runs {
                sim.submit(&Txn {
                    dir: Dir::Write,
                    addr: r.addr,
                    len: r.len,
                });
            }
            report.raw_elems += plan.read_raw() + plan.write_raw();
            report.useful_elems += plan.read_useful + plan.write_useful;
            report.transactions += plan.transactions() as u64;
            report.tiles += 1;
        }
    }

    /// Timing-only run (the Fig-15 memory-bound rig): burst-plan each wave
    /// in parallel, replay serially. Bit-identical to `threads = 1`, and
    /// bounded-memory: plans stream through a [`PlanStream`] window rather
    /// than materializing a whole wave (a flat schedule is one wave holding
    /// every tile).
    pub fn run_timing(&self) -> BatchReport {
        let mut sim = MemSim::new(self.mem_cfg.clone());
        let mut report = BatchReport {
            waves: self.schedule.num_waves(),
            ..BatchReport::default()
        };
        // one plan cache across every wave: the canonical interior plan is
        // derived once and rebased per interior tile
        let cache = self.plan_cache();
        for wave in self.schedule.waves() {
            for plan in PlanStream::with_cache(&cache, wave, self.threads) {
                self.replay_wave(&mut sim, std::slice::from_ref(&plan), &mut report);
            }
        }
        report.cycles = sim.now();
        report.timing = sim.timing().clone();
        report
    }

    /// Full data-path run with the synthetic kernel: per wave, plan +
    /// gather + compute in parallel against the pre-wave memory, then
    /// apply writebacks and replay timing serially in lexicographic order.
    /// Requires a dependence-respecting schedule ([`Schedule::wavefront`]);
    /// panics on a timing-only schedule such as [`Schedule::flat`], whose
    /// waves would gather flow-in from unwritten memory and return a
    /// plausible-looking but wrong buffer. Returns the report plus the
    /// final host memory.
    pub fn run_data(&self, seed: u64) -> (BatchReport, HostMemory) {
        assert!(
            self.schedule.is_dependence_safe(),
            "run_data needs a dependence-respecting schedule (Schedule::wavefront); \
             Schedule::flat is timing-only"
        );
        let mut host = HostMemory::new(self.alloc.footprint());
        let mut sim = MemSim::new(self.mem_cfg.clone());
        let mut report = BatchReport {
            waves: self.schedule.num_waves(),
            ..BatchReport::default()
        };
        let cache = self.plan_cache();
        for wave in self.schedule.waves() {
            // chunked for bounded memory. applying a chunk's writes before
            // the next chunk's gathers is safe: a gather address is the
            // canonical location of a flow-in point, which lives in a
            // producer tile — always in an *earlier wave* — and per-array
            // addressing is injective, so no same-wave tile can write it.
            // chunk size is fixed, so the grouping (and with it every
            // buffer and counter) is identical for any worker count.
            for chunk in wave.chunks(PLAN_CHUNK) {
                let host_ref = &host;
                let results: Vec<(TilePlan, Vec<(u64, f32)>)> = {
                    let _span = crate::obs::span("batch::marshal");
                    parallel_map(chunk, self.threads, |coords| {
                        let plan = cache.plan(coords);
                        let writes = execute_tile(self.alloc, &plan, host_ref, seed);
                        (plan, writes)
                    })
                };
                for (_, writes) in &results {
                    for &(addr, v) in writes {
                        host.write(addr, v);
                    }
                }
                for (plan, _) in &results {
                    self.replay_wave(&mut sim, std::slice::from_ref(plan), &mut report);
                }
            }
        }
        report.cycles = sim.now();
        report.timing = sim.timing().clone();
        (report, host)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::AllocKind;
    use crate::poly::deps::DepPattern;

    fn setup() -> (Tiling, DepPattern) {
        let tiling = Tiling::new(vec![12, 12, 12], vec![4, 4, 4]);
        let deps = DepPattern::new(vec![
            vec![-1, 0, 0],
            vec![0, -1, 0],
            vec![0, 0, -1],
            vec![-1, -1, -1],
        ])
        .unwrap();
        (tiling, deps)
    }

    #[test]
    fn wavefront_covers_every_tile_once() {
        let (tiling, deps) = setup();
        let sched = Schedule::wavefront(&tiling, &deps);
        assert_eq!(sched.num_tiles(), tiling.num_tiles());
        let mut seen: Vec<IVec> = sched.waves().iter().flatten().cloned().collect();
        seen.sort();
        let mut all: Vec<IVec> = tiling.tiles().collect();
        all.sort();
        assert_eq!(seen, all);
    }

    #[test]
    fn wavefront_producers_precede_consumers() {
        let (tiling, deps) = setup();
        let sched = Schedule::wavefront(&tiling, &deps);
        let wave_of = |c: &IVec| {
            sched
                .waves()
                .iter()
                .position(|w| w.contains(c))
                .expect("tile scheduled")
        };
        for coords in tiling.tiles() {
            let wc = wave_of(&coords);
            for (p, _) in producer_tiles(&tiling, &deps, &coords) {
                assert!(
                    wave_of(&p) < wc,
                    "producer {p:?} not before {coords:?} (wave {wc})"
                );
            }
        }
    }

    #[test]
    fn wavefront_depth_matches_diagonal_for_full_pattern() {
        // with flow along every axis and the diagonal, exact depth equals
        // the coordinate sum (the classic wavefront diagonals)
        let (tiling, deps) = setup();
        let sched = Schedule::wavefront(&tiling, &deps);
        assert_eq!(sched.num_waves(), 7); // 3 tiles per axis: depths 0..=6
        for (d, wave) in sched.waves().iter().enumerate() {
            for c in wave {
                assert_eq!(c.iter().sum::<i64>() as usize, d, "{c:?}");
            }
        }
    }

    #[test]
    fn axis_only_pattern_runs_full_planes_per_wave() {
        let tiling = Tiling::new(vec![12, 12, 12], vec![4, 4, 4]);
        let deps = DepPattern::new(vec![vec![-1, 0, 0]]).unwrap();
        let sched = Schedule::wavefront(&tiling, &deps);
        assert_eq!(sched.num_waves(), 3);
        assert_eq!(sched.max_width(), 9); // a full 3x3 plane per wave
    }

    #[test]
    fn flat_schedule_is_one_lexicographic_wave() {
        let (tiling, _) = setup();
        let sched = Schedule::flat(&tiling);
        assert_eq!(sched.num_waves(), 1);
        assert_eq!(sched.waves()[0], tiling.tiles().collect::<Vec<IVec>>());
    }

    #[test]
    fn parallel_timing_equals_serial_all_allocations() {
        let (tiling, deps) = setup();
        let sched = Schedule::wavefront(&tiling, &deps);
        let mem = MemConfig::default();
        for kind in AllocKind::ALL {
            let alloc = kind.build(&tiling, &deps).unwrap();
            let serial = BatchCoordinator::new(alloc.as_ref(), &sched, mem.clone()).run_timing();
            let par = BatchCoordinator::new(alloc.as_ref(), &sched, mem.clone())
                .threads(4)
                .run_timing();
            assert_eq!(serial, par, "{}", kind.name());
            assert_eq!(serial.tiles, tiling.num_tiles());
            assert_eq!(
                serial.timing.row_hits + serial.timing.row_misses,
                serial.timing.axi_bursts
            );
        }
    }

    #[test]
    fn plan_cache_is_bit_identical_to_fresh_planning() {
        // interior tiles come out of the rebase fast path; every tile's
        // cached plan must equal alloc.plan(tile) exactly, for all four
        // allocations
        let (tiling, deps) = setup();
        for kind in AllocKind::ALL {
            let alloc = kind.build(&tiling, &deps).unwrap();
            let cache = PlanCache::new(alloc.as_ref());
            assert!(cache.is_interior(&[1, 1, 1]), "{}", kind.name());
            assert!(!cache.is_interior(&[0, 1, 1]));
            assert!(!cache.is_interior(&[1, 2, 1]));
            for coords in tiling.tiles() {
                assert_eq!(
                    cache.plan(&coords),
                    alloc.plan(&coords),
                    "{} tile {coords:?}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn plan_stream_yields_every_plan_in_order() {
        let (tiling, deps) = setup();
        let alloc = AllocKind::Cfa.build(&tiling, &deps).unwrap();
        let tiles: Vec<IVec> = tiling.tiles().collect();
        for threads in [1, 3] {
            let streamed: Vec<TilePlan> =
                PlanStream::new(alloc.as_ref(), &tiles, threads).collect();
            assert_eq!(streamed.len(), tiles.len(), "threads={threads}");
            for (coords, plan) in tiles.iter().zip(&streamed) {
                let direct = alloc.plan(coords);
                assert_eq!(direct.read_runs, plan.read_runs, "{coords:?}");
                assert_eq!(direct.write_runs, plan.write_runs, "{coords:?}");
            }
        }
    }

    #[test]
    fn compiled_trace_replays_bit_identically_to_run_timing() {
        // the trace is the coordinator's submit stream, flattened: replaying
        // it must reproduce the timing run's counters exactly, for every
        // allocation and both schedule shapes, and for any compile threads
        let (tiling, deps) = setup();
        for sched in [Schedule::wavefront(&tiling, &deps), Schedule::flat(&tiling)] {
            for kind in AllocKind::ALL {
                let alloc = kind.build(&tiling, &deps).unwrap();
                let coord = BatchCoordinator::new(alloc.as_ref(), &sched, MemConfig::default());
                let report = coord.run_timing();
                let cache = PlanCache::new(alloc.as_ref());
                let trace = compile_trace(&cache, &sched, 1);
                assert_eq!(compile_trace(&cache, &sched, 3), trace, "{}", kind.name());
                assert_eq!(trace.tiles, report.tiles, "{}", kind.name());
                assert_eq!(trace.waves, report.waves);
                assert_eq!(trace.transactions(), report.transactions);
                assert_eq!(trace.raw_elems, report.raw_elems);
                assert_eq!(trace.useful_elems, report.useful_elems);
                let mut sim = MemSim::new(MemConfig::default());
                sim.run_trace(&trace);
                assert_eq!(sim.now(), report.cycles, "{}", kind.name());
                assert_eq!(*sim.timing(), report.timing, "{}", kind.name());
            }
        }
    }

    #[test]
    #[should_panic(expected = "dependence-respecting")]
    fn data_path_rejects_timing_only_schedules() {
        let (tiling, deps) = setup();
        let sched = Schedule::flat(&tiling);
        assert!(!sched.is_dependence_safe());
        let alloc = AllocKind::Cfa.build(&tiling, &deps).unwrap();
        let _ = BatchCoordinator::new(alloc.as_ref(), &sched, MemConfig::default()).run_data(1);
    }

    #[test]
    fn data_path_depends_on_schedule_correctness() {
        // the synthetic kernel mixes upstream values into every write, so
        // interior-tile outputs must differ from a run with zeroed inputs
        let (tiling, deps) = setup();
        let sched = Schedule::wavefront(&tiling, &deps);
        let alloc = AllocKind::Cfa.build(&tiling, &deps).unwrap();
        let (report, host) =
            BatchCoordinator::new(alloc.as_ref(), &sched, MemConfig::default()).run_data(42);
        assert_eq!(report.tiles, tiling.num_tiles());
        assert!(host.as_slice().iter().any(|&v| v != 0.0));
        // an interior flow point carries its producer's bias: recompute its
        // pure hash part and check the stored value is not just the hash
        let p = vec![7, 7, 7];
        let (_, addr) = alloc.read_loc(&p);
        let stored = host.read(addr);
        assert!(
            (stored - point_hash(42, &p)).abs() > 1e-9,
            "gathered bias missing from {stored}"
        );
    }
}
