//! Native Rust references for end-to-end verification.
//!
//! These mirror `python/compile/kernels/ref.py` exactly (same weights, same
//! zero-Dirichlet boundary, f32 arithmetic) so the coordinator can check
//! the full simulated pipeline against an independent implementation.

/// Stencil tap sets, matching ref.py.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StencilKind {
    Jacobi5p,
    Jacobi9p,
    Gaussian,
}

impl StencilKind {
    pub fn parse(s: &str) -> Option<StencilKind> {
        match s {
            "jacobi5p" | "jacobi2d5p" => Some(StencilKind::Jacobi5p),
            "jacobi9p" | "jacobi2d9p" => Some(StencilKind::Jacobi9p),
            "gaussian" => Some(StencilKind::Gaussian),
            _ => None,
        }
    }

    /// Stencil radius r (halo h = 2r in skewed space).
    pub fn radius(&self) -> i64 {
        match self {
            StencilKind::Jacobi5p | StencilKind::Jacobi9p => 1,
            StencilKind::Gaussian => 2,
        }
    }

    /// Tap weights, (2r+1)^2 row-major — identical to ref.py.
    pub fn weights(&self) -> Vec<Vec<f32>> {
        match self {
            StencilKind::Jacobi5p => {
                let c = 0.5f64;
                let e = (1.0 - c) / 4.0;
                vec![
                    vec![0.0, e as f32, 0.0],
                    vec![e as f32, c as f32, e as f32],
                    vec![0.0, e as f32, 0.0],
                ]
            }
            StencilKind::Jacobi9p => {
                let raw = [[1.0, 2.0, 1.0], [2.0, 4.0, 2.0], [1.0, 2.0, 1.0]];
                let sum: f64 = raw.iter().flatten().sum();
                raw.iter()
                    .map(|row| row.iter().map(|x| (x / sum) as f32).collect())
                    .collect()
            }
            StencilKind::Gaussian => {
                let b = [1.0f64, 4.0, 6.0, 4.0, 1.0];
                let sum: f64 = 256.0;
                b.iter()
                    .map(|x| b.iter().map(|y| ((x * y) / sum) as f32).collect())
                    .collect()
            }
        }
    }

    /// Uniform dependence vectors in the *skewed* space (t, u, v): every
    /// stencil tap (di, dj) becomes (-1, di - r, dj - r).
    pub fn skewed_deps(&self) -> Vec<Vec<i64>> {
        let r = self.radius();
        let w = self.weights();
        let mut out = Vec::new();
        for (a, row) in w.iter().enumerate() {
            for (b, &tap) in row.iter().enumerate() {
                if tap != 0.0 {
                    let di = a as i64 - r;
                    let dj = b as i64 - r;
                    out.push(vec![-1, di - r, dj - r]);
                }
            }
        }
        out
    }
}

/// Run `steps` stencil updates on a grid with zero boundary (f32, matching
/// ref.run_stencil_global).
pub fn stencil_reference(grid0: &[f32], n: usize, m: usize, kind: StencilKind, steps: usize) -> Vec<f32> {
    let w = kind.weights();
    let r = kind.radius() as isize;
    let k = w.len() as isize;
    let mut cur = grid0.to_vec();
    let mut next = vec![0.0f32; n * m];
    for _ in 0..steps {
        for i in 0..n as isize {
            for j in 0..m as isize {
                let mut acc = 0.0f32;
                for a in 0..k {
                    for b in 0..k {
                        let ii = i + a - r;
                        let jj = j + b - r;
                        if ii >= 0 && ii < n as isize && jj >= 0 && jj < m as isize {
                            acc += w[a as usize][b as usize]
                                * cur[ii as usize * m + jj as usize];
                        }
                    }
                }
                next[i as usize * m + j as usize] = acc;
            }
        }
        std::mem::swap(&mut cur, &mut next);
    }
    cur
}

/// Smith-Waterman-3seq scoring constants (must match ref.py).
pub const SW_GAP: f32 = -1.0;
pub const SW_MATCH: f32 = 2.0;
pub const SW_MISMATCH: f32 = -1.0;

/// Full-table 3-seq DP (zero boundary). Returns H of shape (ni, nj, nk).
pub fn sw3_reference(a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
    let (ni, nj, nk) = (a.len(), b.len(), c.len());
    // padded table with zero boundary at index 0
    let (pj, pk) = (nj + 1, nk + 1);
    let mut h = vec![0.0f32; (ni + 1) * pj * pk];
    let idx = |i: usize, j: usize, k: usize| (i * pj + j) * pk + k;
    for i in 1..=ni {
        for j in 1..=nj {
            for k in 1..=nk {
                let s = if a[i - 1] == b[j - 1] && b[j - 1] == c[k - 1] {
                    SW_MATCH
                } else {
                    SW_MISMATCH
                };
                let mut best = h[idx(i - 1, j - 1, k - 1)] + s;
                best = best.max(h[idx(i - 1, j, k)] + SW_GAP);
                best = best.max(h[idx(i, j - 1, k)] + SW_GAP);
                best = best.max(h[idx(i, j, k - 1)] + SW_GAP);
                best = best.max(h[idx(i - 1, j - 1, k)] + 2.0 * SW_GAP);
                best = best.max(h[idx(i - 1, j, k - 1)] + 2.0 * SW_GAP);
                best = best.max(h[idx(i, j - 1, k - 1)] + 2.0 * SW_GAP);
                h[idx(i, j, k)] = best;
            }
        }
    }
    // strip the boundary
    let mut out = vec![0.0f32; ni * nj * nk];
    for i in 0..ni {
        for j in 0..nj {
            for k in 0..nk {
                out[(i * nj + j) * nk + k] = h[idx(i + 1, j + 1, k + 1)];
            }
        }
    }
    out
}

/// SW-3seq dependence pattern: the 7 backwards vectors of {0,-1}^3 \ {0}.
pub fn sw3_deps() -> Vec<Vec<i64>> {
    let mut out = Vec::new();
    for di in [-1i64, 0] {
        for dj in [-1i64, 0] {
            for dk in [-1i64, 0] {
                if (di, dj, dk) != (0, 0, 0) {
                    out.push(vec![di, dj, dk]);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_sum_to_one() {
        for kind in [StencilKind::Jacobi5p, StencilKind::Jacobi9p, StencilKind::Gaussian] {
            let s: f32 = kind.weights().iter().flatten().sum();
            assert!((s - 1.0).abs() < 1e-6, "{kind:?}: {s}");
        }
    }

    #[test]
    fn skewed_deps_are_backwards_with_right_widths() {
        use crate::poly::deps::DepPattern;
        for (kind, ndeps, w) in [
            (StencilKind::Jacobi5p, 5, vec![1, 2, 2]),
            (StencilKind::Jacobi9p, 9, vec![1, 2, 2]),
            (StencilKind::Gaussian, 25, vec![1, 4, 4]),
        ] {
            let deps = DepPattern::new(kind.skewed_deps()).expect("backwards");
            assert_eq!(deps.len(), ndeps, "{kind:?}");
            assert_eq!(deps.widths(), w, "{kind:?}");
        }
    }

    #[test]
    fn stencil_reference_conserves_constant_interior() {
        // all-ones grid: the center cell of a big grid stays 1.0 after one
        // averaging step
        let n = 9;
        let g = vec![1.0f32; n * n];
        let out = stencil_reference(&g, n, n, StencilKind::Jacobi5p, 1);
        assert!((out[4 * n + 4] - 1.0).abs() < 1e-6);
        assert!(out[0] < 1.0); // boundary decays
    }

    #[test]
    fn sw3_reference_diagonal_identity() {
        let a: Vec<f32> = (0..6).map(|x| (x % 3) as f32).collect();
        let h = sw3_reference(&a, &a, &a);
        let n = 6;
        // perfect triple alignment: H[i,i,i] = (i+1)*match
        for i in 0..n {
            let v = h[(i * n + i) * n + i];
            assert!((v - (i as f32 + 1.0) * SW_MATCH).abs() < 1e-5, "i={i} v={v}");
        }
    }

    #[test]
    fn sw3_deps_shape() {
        let d = sw3_deps();
        assert_eq!(d.len(), 7);
        assert!(d.iter().all(|v| v.iter().all(|&x| x <= 0)));
    }
}
