//! L3 coordinator: drives tile-by-tile execution of a benchmark through a
//! chosen off-chip allocation, the AXI/DRAM simulator and the PJRT runtime.
//!
//! This is the paper's read–execute–write accelerator (Fig 2/13) with the
//! FPGA replaced by the simulated memory interface (timing) plus the
//! AOT-compiled tile programs (numerics). One run proves the whole stack:
//! if any facet address function, burst plan or halo assembly were wrong,
//! the final grid would not match the native Rust reference.
//!
//! [`batch`] adds the scale path: a wavefront scheduler over the tile
//! dependence graph and a parallel executor whose timing and buffers stay
//! bit-identical to serial execution. The end-to-end drivers themselves
//! live in [`crate::experiment`] (`Session::run(Mode::Data)`); the old
//! `stencil`/`sw` free-function shims are gone.

pub mod batch;
pub mod reference;

use crate::layout::registry::{self, names};
use crate::layout::Allocation;
use crate::poly::deps::DepPattern;
use crate::poly::tiling::Tiling;

/// The four built-in allocations (§VI.A.1 baselines + CFA) as a closed
/// enum. **Deprecated**: the open
/// [`LayoutRegistry`](crate::layout::LayoutRegistry) is the source of
/// truth for names, aliases and constructors — this enum merely mirrors
/// its built-in entries as a convenience for tests that iterate the
/// built-ins. New code should name layouts through the registry / the
/// [`experiment`](crate::experiment) API.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocKind {
    Cfa,
    Original,
    BoundingBox,
    DataTiling,
}

impl AllocKind {
    pub const ALL: [AllocKind; 4] = [
        AllocKind::Cfa,
        AllocKind::Original,
        AllocKind::BoundingBox,
        AllocKind::DataTiling,
    ];

    /// Parse a canonical name or alias, via the global registry (the
    /// registry owns every spelling; no string literals live here).
    pub fn parse(s: &str) -> Option<AllocKind> {
        let reg = registry::global();
        let canon = reg.canonical(s)?;
        AllocKind::ALL.iter().copied().find(|k| k.name() == canon)
    }

    /// Canonical registry name of this built-in.
    pub fn name(&self) -> &'static str {
        match self {
            AllocKind::Cfa => names::CFA,
            AllocKind::Original => names::ORIGINAL,
            AllocKind::BoundingBox => names::BBOX,
            AllocKind::DataTiling => names::DATATILE,
        }
    }

    /// Instantiate the allocation for a tiling + pattern through the
    /// registry's constructor (data tiling uses the paper's best-size
    /// sweep).
    pub fn build(&self, tiling: &Tiling, deps: &DepPattern) -> anyhow::Result<Box<dyn Allocation>> {
        registry::global().build(self.name(), tiling, deps)
    }
}

/// Simulated host "global memory": one flat f32 store per allocation array.
#[derive(Clone, Debug, PartialEq)]
pub struct HostMemory {
    data: Vec<f32>,
}

impl HostMemory {
    pub fn new(elems: u64) -> HostMemory {
        HostMemory {
            data: vec![0.0; elems as usize],
        }
    }

    #[inline]
    pub fn read(&self, addr: u64) -> f32 {
        self.data[addr as usize]
    }

    #[inline]
    pub fn write(&mut self, addr: u64, v: f32) {
        self.data[addr as usize] = v;
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The whole store (verification: bit-compare two runs' buffers).
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::deps::DepPattern;

    #[test]
    fn alloc_kind_round_trip() {
        for k in AllocKind::ALL {
            assert_eq!(AllocKind::parse(k.name()), Some(k));
        }
        assert_eq!(AllocKind::parse("nope"), None);
        // aliases route through the registry
        assert_eq!(AllocKind::parse("bounding-box"), Some(AllocKind::BoundingBox));
        assert_eq!(AllocKind::parse("data-tiling"), Some(AllocKind::DataTiling));
    }

    #[test]
    fn build_all_allocations() {
        let tiling = Tiling::new(vec![8, 8], vec![4, 4]);
        let deps = DepPattern::new(vec![vec![-1, 0], vec![0, -1]]).unwrap();
        for k in AllocKind::ALL {
            let a = k.build(&tiling, &deps).unwrap();
            assert_eq!(a.name(), k.name());
            assert!(a.footprint() > 0);
        }
    }

    #[test]
    fn host_memory_rw() {
        let mut h = HostMemory::new(16);
        h.write(3, 1.5);
        assert_eq!(h.read(3), 1.5);
        assert_eq!(h.read(0), 0.0);
        assert_eq!(h.len(), 16);
    }
}
