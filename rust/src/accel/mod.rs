//! Accelerator task-level pipeline and scratchpad model (§II.D, §V.D).
//!
//! The paper's accelerators follow the read–execute–write template of
//! Fig 2 / Fig 13: three coarse-grain stages connected by double buffers
//! (`#pragma HLS DATAFLOW`), each stage processing a different tile. The
//! read and write engines share the single AXI HP port; compute runs on
//! its own resource. [`Pipeline`] computes the steady-state makespan of a
//! tile stream under those constraints, and [`Scratchpad`] models the
//! on-chip BRAM buffers whose capacity bounds the tile size (§VI.B.3.b:
//! "BRAM was, indeed, the factor limiting tile size").

use crate::memsim::{Dir, MemSim, Txn};

/// Per-tile stage costs, in bus cycles.
#[derive(Clone, Copy, Debug, Default)]
pub struct TileCost {
    pub read: u64,
    pub exec: u64,
    pub write: u64,
}

/// Result of a pipeline simulation.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineStats {
    /// Total cycles from first read to last write.
    pub makespan: u64,
    /// Cycles the memory port was busy.
    pub mem_busy: u64,
    /// Cycles the compute engine was busy.
    pub exec_busy: u64,
    /// Tiles processed.
    pub tiles: u64,
}

impl PipelineStats {
    /// Fraction of the makespan the memory port was active.
    pub fn mem_utilization(&self) -> f64 {
        if self.makespan == 0 {
            0.0
        } else {
            self.mem_busy as f64 / self.makespan as f64
        }
    }
}

/// Coarse-grain read–execute–write pipeline with double buffering and a
/// shared memory port.
///
/// Per tile i (one buffer pair per stage boundary, DATAFLOW-style):
/// * `read(i)` — read engine is serial (after `read(i-1)`), needs the port;
/// * `exec(i)` — after `read(i)` and `exec(i-1)`;
/// * `write(i)` — becomes *ready* at `exec(i)` end; write engine is serial.
///
/// The port arbitrates between the read prefetch stream and pending
/// writebacks FIFO-by-ready-time, which is how an AXI interconnect services
/// two masters: a write that became ready before the next read request gets
/// the port first, otherwise the prefetch proceeds and the write drains
/// later.
#[derive(Clone, Debug, Default)]
pub struct Pipeline {
    stats: PipelineStats,
    read_done: u64,
    exec_done: u64,
    last_end: u64,
    port_free: u64,
    /// Writebacks waiting for the port: (ready_cycle, beats).
    pending_writes: std::collections::VecDeque<(u64, u64)>,
}

impl Pipeline {
    pub fn new() -> Pipeline {
        Pipeline::default()
    }

    fn issue_write(&mut self, ready: u64, len: u64) {
        let start = self.port_free.max(ready);
        self.port_free = start + len;
        self.last_end = self.last_end.max(self.port_free);
    }

    /// Feed one tile through the pipeline.
    pub fn push(&mut self, cost: TileCost) {
        let read_ready = self.read_done;
        // writes already ready get the port before this read
        while let Some(&(ready, len)) = self.pending_writes.front() {
            if ready <= read_ready.max(self.port_free) {
                self.pending_writes.pop_front();
                self.issue_write(ready, len);
            } else {
                break;
            }
        }
        let read_start = self.port_free.max(read_ready);
        let read_end = read_start + cost.read;
        self.port_free = read_end;
        self.read_done = read_end;

        let exec_start = read_end.max(self.exec_done);
        let exec_end = exec_start + cost.exec;
        self.exec_done = exec_end;
        self.last_end = self.last_end.max(exec_end);

        if cost.write > 0 {
            self.pending_writes.push_back((exec_end, cost.write));
        }
        self.stats.mem_busy += cost.read + cost.write;
        self.stats.exec_busy += cost.exec;
        self.stats.tiles += 1;
    }

    /// Drain pending writebacks and return the statistics.
    pub fn finish(&mut self) -> PipelineStats {
        while let Some((ready, len)) = self.pending_writes.pop_front() {
            self.issue_write(ready, len);
        }
        self.stats.makespan = self.last_end.max(self.port_free).max(self.exec_done);
        self.stats
    }

    /// Run a whole tile stream.
    pub fn run(costs: impl IntoIterator<Item = TileCost>) -> PipelineStats {
        let mut p = Pipeline::new();
        for c in costs {
            p.push(c);
        }
        p.finish()
    }
}

/// Measure the memory-port cycles of a tile's transfer plan on the shared
/// AXI model (reads then writes, as Fig 13's dataflow stages issue them).
pub fn tile_mem_cycles(
    sim: &mut MemSim,
    reads: &[crate::layout::Run],
    writes: &[crate::layout::Run],
) -> (u64, u64) {
    sim.reset();
    let rtxn: Vec<Txn> = reads
        .iter()
        .map(|r| Txn {
            dir: Dir::Read,
            addr: r.addr,
            len: r.len,
        })
        .collect();
    let read_cycles = sim.run(&rtxn);
    let wtxn: Vec<Txn> = writes
        .iter()
        .map(|r| Txn {
            dir: Dir::Write,
            addr: r.addr,
            len: r.len,
        })
        .collect();
    let total = sim.run(&wtxn);
    (read_cycles, total - read_cycles)
}

/// On-chip scratchpad (BRAM) model.
///
/// Xilinx 7-series block RAM: 36 Kib blocks, usable as two independent
/// 18 Kib halves; a buffer of W-bit words consumes
/// `ceil(bits / 18Kib)` half-blocks (port width ≤ 36 bits per half).
#[derive(Clone, Copy, Debug)]
pub struct Scratchpad {
    /// Available BRAM36 blocks on the device (xc7z045: 545).
    pub bram36_available: u64,
}

impl Default for Scratchpad {
    fn default() -> Self {
        Scratchpad {
            bram36_available: 545,
        }
    }
}

impl Scratchpad {
    /// BRAM36 blocks needed for a buffer of `elems` elements of
    /// `elem_bytes` bytes (double-buffered if `double`).
    pub fn bram36_for(&self, elems: u64, elem_bytes: u64, double: bool) -> u64 {
        if elems == 0 {
            return 0;
        }
        let bits = elems * elem_bytes * 8;
        let half_blocks = bits.div_ceil(18 * 1024);
        let blocks = half_blocks.div_ceil(2);
        if double {
            blocks * 2
        } else {
            blocks
        }
    }

    /// Utilization fraction for a set of buffers.
    pub fn utilization(&self, blocks: u64) -> f64 {
        blocks as f64 / self.bram36_available as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run, Config};

    #[test]
    fn pipeline_overlaps_stages() {
        // 10 tiles, read=exec=write=100: perfect pipeline bounded by the
        // shared port (read+write = 200/tile) rather than the 300 serial.
        let stats = Pipeline::run((0..10).map(|_| TileCost {
            read: 100,
            exec: 100,
            write: 100,
        }));
        assert!(stats.makespan < 10 * 300, "no overlap: {}", stats.makespan);
        assert!(stats.makespan >= 10 * 200, "port is shared: {}", stats.makespan);
        assert_eq!(stats.tiles, 10);
    }

    #[test]
    fn compute_bound_pipeline_hides_memory() {
        let stats = Pipeline::run((0..20).map(|_| TileCost {
            read: 10,
            exec: 500,
            write: 10,
        }));
        // makespan ≈ exec total + fill
        assert!(stats.makespan < 20 * 500 + 100);
        assert!(stats.makespan >= 20 * 500);
        assert!(stats.mem_utilization() < 0.1);
    }

    #[test]
    fn memory_bound_pipeline_saturates_port() {
        let stats = Pipeline::run((0..20).map(|_| TileCost {
            read: 500,
            exec: 10,
            write: 500,
        }));
        assert!(stats.mem_utilization() > 0.95);
    }

    #[test]
    fn empty_pipeline() {
        let stats = Pipeline::run(std::iter::empty());
        assert_eq!(stats.makespan, 0);
        assert_eq!(stats.tiles, 0);
    }

    #[test]
    fn bram_sizing() {
        let sp = Scratchpad::default();
        // one 18Kib half-block holds 288 f64 elements
        assert_eq!(sp.bram36_for(288, 8, false), 1);
        assert_eq!(sp.bram36_for(0, 8, false), 0);
        // 16^3 tile of f64 = 32 KiB = 262144 bits -> 15 halves -> 8 blocks
        assert_eq!(sp.bram36_for(4096, 8, false), 8);
        assert_eq!(sp.bram36_for(4096, 8, true), 16);
        assert!((sp.utilization(109) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn prop_pipeline_bounds() {
        run("pipeline makespan bounds", Config::small(80), |g| {
            let n = g.usize(1, 12);
            let costs: Vec<TileCost> = (0..n)
                .map(|_| TileCost {
                    read: g.i64(0, 200) as u64,
                    exec: g.i64(0, 200) as u64,
                    write: g.i64(0, 200) as u64,
                })
                .collect();
            let stats = Pipeline::run(costs.iter().copied());
            let mem: u64 = costs.iter().map(|c| c.read + c.write).sum();
            let exec: u64 = costs.iter().map(|c| c.exec).sum();
            let serial: u64 = costs.iter().map(|c| c.read + c.exec + c.write).sum();
            // lower bounds: each resource's busy time
            assert!(stats.makespan >= mem);
            assert!(stats.makespan >= exec);
            // upper bound: fully serial execution
            assert!(stats.makespan <= serial);
            assert_eq!(stats.mem_busy, mem);
            assert_eq!(stats.exec_busy, exec);
        });
    }
}
