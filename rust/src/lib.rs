//! # CFA — Canonical Facet Allocation
//!
//! Production-grade reproduction of *"Increasing FPGA Accelerators Memory
//! Bandwidth with a Burst-Friendly Memory Layout"* (Ferry, Yuki, Derrien,
//! Rajopadhye — CS.AR 2022).
//!
//! The crate implements the paper's full system as a three-layer stack:
//!
//! * **L3 (this crate)** — the polyhedral layout engine (CFA + the three
//!   baseline allocations of §VI, behind the open
//!   [`layout::registry::LayoutRegistry`]), a cycle-approximate AXI/DRAM
//!   memory simulator standing in for the Zynq testbed, the
//!   read-execute-write accelerator pipeline, an FPGA area model, an HLS
//!   code generator (Fig 12/13), and the coordinators that drive tile
//!   execution — serial drivers plus the batched wavefront coordinator
//!   ([`coordinator::batch`]) that plans and marshals tiles in parallel
//!   while keeping timing bit-identical to serial replay. The
//!   [`experiment`] module is the front door: a typed spec compiles once
//!   into a session (allocation + schedule + plan cache) and runs in any
//!   mode, returning one unified report. [`dse`] builds on it: a parallel,
//!   resumable design-space explorer that autotunes tiling × layout ×
//!   memory configuration for bandwidth and area (`cfa tune`).
//! * **L2/L1 (build-time Python)** — JAX tile programs calling Pallas
//!   stencil kernels, AOT-lowered to HLO text in `artifacts/`.
//! * **runtime** — a PJRT CPU client (the `xla` crate) that loads those
//!   artifacts so tile compute runs from Rust with Python never on the
//!   request path. Gated behind the off-by-default `pjrt` feature so the
//!   tier-1 build needs neither the crate nor `artifacts/`.
//!
//! See `DESIGN.md` (repo root) for the system inventory.

pub mod accel;
pub mod area;
pub mod coordinator;
pub mod dse;
pub mod experiment;
pub mod harness;
pub mod hlsgen;
pub mod layout;
pub mod memsim;
pub mod obs;
pub mod poly;
pub mod runtime;
pub mod serve;
pub mod util;
