//! Cycle-approximate AXI + DRAM memory-interface simulator.
//!
//! Stands in for the paper's testbed (§VI.A): a Xilinx Zynq ZC706 with one
//! AXI high-performance port (HP0) to DDR3 — 64-bit bus at 100 MHz, i.e. a
//! **800 MB/s roofline**. The paper's bandwidth results are a function of
//! *transaction structure* — how many bursts, how long, how contiguous, how
//! much of each is useful — and this simulator models exactly those
//! first-order mechanisms:
//!
//! * per-transaction issue/address-phase overhead (AR/AW handshake),
//! * AXI burst segmentation (≤256 beats, no 4 KiB boundary crossing),
//! * DRAM open-row policy: row hits stream at bus rate, row misses pay an
//!   activate+precharge penalty (per bank),
//! * outstanding-transaction overlap — Vitis HLS issues multiple reads in
//!   flight, hiding latency behind the data phase of earlier bursts
//!   (§VI.B.1: "burst access overlapping, which hides latency for long
//!   bursts even when they are decomposed into smaller burst accesses"),
//! * read/write turnaround penalty on the shared port.

pub mod engine;
pub mod multiport;
pub mod trace;

pub use engine::{MemSim, ReplayState, Timing};
pub use multiport::{cfa_port_map, MultiPortSim, PortMap, Striping};
pub use trace::{CacheStats, TraceCache, TraceProvider, TxnTrace};

/// Transfer direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Read,
    Write,
}

/// One burst transaction handed to the memory interface (element units).
#[derive(Clone, Copy, Debug)]
pub struct Txn {
    pub dir: Dir,
    /// Element address.
    pub addr: u64,
    /// Elements transferred.
    pub len: u64,
}

/// Memory interface configuration. Defaults model the ZC706 HP0 port.
#[derive(Clone, Debug, PartialEq)]
pub struct MemConfig {
    /// Bytes per element (the paper transfers f64: 8).
    pub elem_bytes: u64,
    /// Bus width in bytes per cycle (64-bit AXI: 8).
    pub bus_bytes: u64,
    /// Bus clock in MHz (100.0 on the paper's designs).
    pub clock_mhz: f64,
    /// Max beats per AXI burst (AXI4: 256).
    pub max_burst_beats: u64,
    /// AXI bursts may not cross this boundary (4096 bytes).
    pub boundary_bytes: u64,
    /// Cycles for the AR/AW address handshake per AXI burst.
    pub issue_cycles: u64,
    /// First-data latency on a DRAM row hit.
    pub row_hit_cycles: u64,
    /// First-data latency on a DRAM row miss (precharge + activate + CAS).
    pub row_miss_cycles: u64,
    /// DRAM row size in bytes (8 KiB rows on the ZC706 DDR3).
    pub row_bytes: u64,
    /// Number of DRAM banks.
    pub banks: u64,
    /// Maximum outstanding transactions (latency overlap window). Vitis
    /// m_axi adapters pipeline requests *within* an inferred burst, but a
    /// copy-loop FSM keeps only a couple of independent requests in flight
    /// across bursts — which is exactly why the paper's short-burst
    /// baselines lose bandwidth.
    pub max_outstanding: usize,
    /// Bus turnaround penalty when switching read<->write.
    pub turnaround_cycles: u64,
    /// Shared-command-path contention (multi-channel interfaces only):
    /// every channel beyond the first adds this many cycles to each
    /// burst's address phase, modeling the arbitration the channels'
    /// common command path serializes — the "memory controller wall"
    /// effect that keeps N channels from buying N× bandwidth. A
    /// single-channel interface ignores it entirely.
    pub cmd_shared_cycles: u64,
}

impl Default for MemConfig {
    fn default() -> Self {
        MemConfig {
            elem_bytes: 8,
            bus_bytes: 8,
            clock_mhz: 100.0,
            max_burst_beats: 256,
            boundary_bytes: 4096,
            issue_cycles: 4,
            row_hit_cycles: 22,
            row_miss_cycles: 48,
            row_bytes: 8192,
            banks: 8,
            max_outstanding: 2,
            turnaround_cycles: 7,
            cmd_shared_cycles: 0,
        }
    }
}

impl MemConfig {
    /// Check the structural invariants the queuing model relies on.
    ///
    /// The simulator divides by `bus_bytes`, `boundary_bytes`, `row_bytes`
    /// and `banks`, and pops the in-flight window whenever it holds
    /// `max_outstanding` entries — a zero in any of those fields used to
    /// surface as a panic (or an infinite split loop) deep inside
    /// `submit_axi`. [`MemSim::new`] enforces this at construction, and the
    /// `dse` space parser surfaces it as a JSON error, so a bad
    /// `--space` file fails with a message instead of a backtrace.
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::bail;
        if self.max_outstanding == 0 {
            bail!("max_outstanding must be >= 1 (the command path needs an in-flight window)");
        }
        if self.bus_bytes == 0 {
            bail!("bus_bytes must be nonzero");
        }
        if self.elem_bytes == 0 {
            bail!("elem_bytes must be nonzero");
        }
        if self.boundary_bytes == 0 {
            bail!("boundary_bytes must be nonzero");
        }
        if self.max_burst_beats == 0 {
            bail!("max_burst_beats must be nonzero (bursts could never make progress)");
        }
        if self.row_bytes == 0 {
            bail!("row_bytes must be nonzero");
        }
        if self.banks == 0 {
            bail!("banks must be nonzero");
        }
        if self.boundary_bytes % self.bus_bytes != 0 {
            bail!(
                "boundary_bytes ({}) must be a multiple of bus_bytes ({})",
                self.boundary_bytes,
                self.bus_bytes
            );
        }
        Ok(())
    }

    /// Named configuration presets, reachable from the `dse` space JSON
    /// (`{"preset": "hbm", ...}`) and `cfa tune --mem`.
    ///
    /// * `zc706` / `ddr` — the paper's testbed (the [`Default`] config):
    ///   64-bit HP port, 8 KiB DDR3 rows, 8 banks.
    /// * `hbm` — an HBM-like *pseudo-channel*: the geometry §VII points
    ///   at. Narrower bus per channel but faster, many more banks, much
    ///   shorter rows (1 KiB pages), a deeper outstanding window, and a
    ///   nonzero shared-command-path cost — so multi-channel sweeps see
    ///   the controller-wall effect out of the box. Row-friendly layouts
    ///   gain less per burst (rows are short) but bank-level parallelism
    ///   forgives scattered traffic more; that tradeoff is exactly what
    ///   the preset exists to let `cfa tune` explore.
    /// * `hbm-flat` — the same geometry with `cmd_shared_cycles: 0`, the
    ///   idealized no-contention variant (useful as an ablation baseline).
    pub fn preset(name: &str) -> Option<MemConfig> {
        match name {
            "zc706" | "ddr" | "default" => Some(MemConfig::default()),
            "hbm" => Some(MemConfig {
                elem_bytes: 8,
                bus_bytes: 4,
                clock_mhz: 450.0,
                max_burst_beats: 64,
                boundary_bytes: 4096,
                issue_cycles: 4,
                row_hit_cycles: 16,
                row_miss_cycles: 36,
                row_bytes: 1024,
                banks: 16,
                max_outstanding: 4,
                turnaround_cycles: 4,
                cmd_shared_cycles: 1,
            }),
            "hbm-flat" => Some(MemConfig {
                cmd_shared_cycles: 0,
                ..MemConfig::preset("hbm").expect("hbm preset exists")
            }),
            _ => None,
        }
    }

    /// The canonical preset names (`preset` accepts a few aliases too).
    pub fn preset_names() -> &'static [&'static str] {
        &["zc706", "hbm", "hbm-flat"]
    }

    /// Peak bandwidth in MB/s (the roofline of Fig 15).
    pub fn peak_mb_s(&self) -> f64 {
        self.bus_bytes as f64 * self.clock_mhz
    }

    /// Cycles → seconds.
    pub fn secs(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.clock_mhz * 1e6)
    }

    /// Beats needed for `len` elements.
    pub fn beats(&self, len: u64) -> u64 {
        (len * self.elem_bytes).div_ceil(self.bus_bytes)
    }
}

/// Aggregated bandwidth numbers for a simulated run.
#[derive(Clone, Copy, Debug, Default)]
pub struct Bandwidth {
    /// Bytes moved on the bus (redundancy included).
    pub raw_bytes: u64,
    /// Application-useful bytes.
    pub useful_bytes: u64,
    /// Total cycles.
    pub cycles: u64,
    /// AXI bursts issued.
    pub bursts: u64,
    /// DRAM row misses observed.
    pub row_misses: u64,
}

impl Bandwidth {
    /// Raw bandwidth in MB/s.
    pub fn raw_mb_s(&self, cfg: &MemConfig) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.raw_bytes as f64 / 1e6 / cfg.secs(self.cycles)
    }

    /// Effective bandwidth in MB/s (§VI.B.2: only useful data counts).
    pub fn effective_mb_s(&self, cfg: &MemConfig) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.useful_bytes as f64 / 1e6 / cfg.secs(self.cycles)
    }

    /// Fraction of the bus roofline actually used for useful data.
    pub fn efficiency(&self, cfg: &MemConfig) -> f64 {
        self.effective_mb_s(cfg) / cfg.peak_mb_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_matches_paper_platform() {
        let cfg = MemConfig::default();
        assert!((cfg.peak_mb_s() - 800.0).abs() < 1e-9);
    }

    #[test]
    fn beats_round_up() {
        let cfg = MemConfig::default();
        assert_eq!(cfg.beats(1), 1);
        assert_eq!(cfg.beats(10), 10);
        let cfg4 = MemConfig {
            elem_bytes: 4,
            ..MemConfig::default()
        };
        assert_eq!(cfg4.beats(3), 2); // 12 bytes on an 8-byte bus
    }

    #[test]
    fn validate_rejects_degenerate_configs() {
        assert!(MemConfig::default().validate().is_ok());
        let cases: Vec<(&str, MemConfig)> = vec![
            (
                "max_outstanding",
                MemConfig {
                    max_outstanding: 0,
                    ..MemConfig::default()
                },
            ),
            (
                "bus_bytes",
                MemConfig {
                    bus_bytes: 0,
                    ..MemConfig::default()
                },
            ),
            (
                "boundary_bytes",
                MemConfig {
                    boundary_bytes: 0,
                    ..MemConfig::default()
                },
            ),
            (
                "banks",
                MemConfig {
                    banks: 0,
                    ..MemConfig::default()
                },
            ),
            (
                "row_bytes",
                MemConfig {
                    row_bytes: 0,
                    ..MemConfig::default()
                },
            ),
            (
                "multiple of bus_bytes",
                MemConfig {
                    boundary_bytes: 4100,
                    ..MemConfig::default()
                },
            ),
        ];
        for (needle, cfg) in cases {
            let err = cfg.validate().expect_err(needle).to_string();
            assert!(err.contains(needle), "'{err}' should mention {needle}");
        }
    }

    #[test]
    fn presets_validate_and_resolve() {
        for &name in MemConfig::preset_names() {
            let cfg = MemConfig::preset(name).expect(name);
            cfg.validate().expect(name);
        }
        // aliases and the unknown-name contract
        assert!(MemConfig::preset("ddr").is_some());
        assert!(MemConfig::preset("default").is_some());
        assert!(MemConfig::preset("nope").is_none());
        // the HBM-like geometry is narrower, faster, more banked, shorter-rowed
        let hbm = MemConfig::preset("hbm").unwrap();
        let ddr = MemConfig::default();
        assert!(hbm.bus_bytes < ddr.bus_bytes);
        assert!(hbm.clock_mhz > ddr.clock_mhz);
        assert!(hbm.banks > ddr.banks);
        assert!(hbm.row_bytes < ddr.row_bytes);
        assert_eq!(
            MemConfig::preset("hbm-flat").unwrap().cmd_shared_cycles,
            0
        );
    }

    #[test]
    fn bandwidth_math() {
        let cfg = MemConfig::default();
        let bw = Bandwidth {
            raw_bytes: 8_000,
            useful_bytes: 4_000,
            cycles: 1_000,
            bursts: 1,
            row_misses: 0,
        };
        // 8000 bytes / 10us = 800 MB/s raw
        assert!((bw.raw_mb_s(&cfg) - 800.0).abs() < 1e-6);
        assert!((bw.effective_mb_s(&cfg) - 400.0).abs() < 1e-6);
        assert!((bw.efficiency(&cfg) - 0.5).abs() < 1e-9);
    }
}
