//! The transaction-level timing engine.
//!
//! A small queuing model with two resources: the **command path** (accepts
//! one AXI burst per `issue_cycles`, at most `max_outstanding` in flight)
//! and the **data bus** (one beat per cycle). Each burst's first data beat
//! additionally waits for the DRAM latency (row hit or miss, per bank,
//! open-row policy); long bursts crossing row boundaries pay the row-switch
//! penalty inline. Latency of burst *i+1* overlaps the data phase of burst
//! *i* — exactly the "burst access overlapping" Vitis relies on — so long
//! back-to-back bursts stream at the bus rate while scattered short bursts
//! pay latency on every transaction.

use crate::memsim::{Bandwidth, Dir, MemConfig, Txn, TxnTrace};
use crate::obs::timeline::TimelineSampler;
use std::collections::VecDeque;

/// Detailed timing of one simulated run.
///
/// Accounting identities (checked by `tests/memsim_identities.rs`):
/// every AXI burst's first beat is classified as exactly one row hit or
/// row miss (`row_hits + row_misses == axi_bursts`); rows crossed *inside*
/// a streaming burst are counted separately in `row_switches`;
/// `data_cycles` equals the total beats transferred; `turnarounds` equals
/// the number of read↔write direction changes in the burst stream.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Timing {
    pub cycles: u64,
    pub data_cycles: u64,
    pub axi_bursts: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    /// Row activations forced mid-burst by streaming across a row
    /// boundary (charged a reduced, prefetch-overlapped penalty).
    pub row_switches: u64,
    pub turnarounds: u64,
}

impl Timing {
    /// Cross-channel aggregate of independent controllers: counters sum,
    /// `cycles` is the max (channels run concurrently, so the makespan is
    /// the slowest one's clock).
    pub fn merge<'a>(parts: impl IntoIterator<Item = &'a Timing>) -> Timing {
        let mut out = Timing::default();
        for t in parts {
            out.cycles = out.cycles.max(t.cycles);
            out.data_cycles += t.data_cycles;
            out.axi_bursts += t.axi_bursts;
            out.row_hits += t.row_hits;
            out.row_misses += t.row_misses;
            out.row_switches += t.row_switches;
            out.turnarounds += t.turnarounds;
        }
        out
    }
}

/// **Replay-time** state of the memory interface: DRAM bank rows, the
/// in-flight window, resource clocks and the running counters.
///
/// Split out of [`MemSim`] so batched coordinators can treat burst
/// *planning* (pure, parallelizable) and timing *replay* (stateful,
/// order-dependent) as separate phases: plans are computed concurrently,
/// then replayed through one `ReplayState` in a deterministic order —
/// that fixed replay order is what makes batched runs bit-identical to
/// serial ones. [`MemSim::snapshot`] / [`MemSim::restore`] additionally
/// let callers checkpoint and re-run a stretch of the replay (e.g. one
/// wave) in isolation; the batch coordinator itself replays straight
/// through and does not need them.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ReplayState {
    /// Open row per bank.
    open_rows: Vec<Option<u64>>,
    /// Completion times of in-flight bursts, oldest first — a ring buffer
    /// bounded by `max_outstanding`, so retiring the oldest burst is O(1)
    /// (`pop_front`) instead of the O(window) shift a `Vec::remove(0)`
    /// would pay on every burst.
    inflight: VecDeque<u64>,
    /// Next cycle the command path is free.
    cmd_free: u64,
    /// Next cycle the data bus is free.
    bus_free: u64,
    /// Direction of the previous burst (turnaround tracking).
    last_dir: Option<Dir>,
    /// Running counters.
    timing: Timing,
}

impl ReplayState {
    fn for_banks(banks: usize) -> ReplayState {
        ReplayState {
            open_rows: vec![None; banks],
            ..ReplayState::default()
        }
    }

    /// Current simulated time (cycle when everything issued so far drains).
    pub fn now(&self) -> u64 {
        self.bus_free.max(self.cmd_free)
    }

    pub fn timing(&self) -> &Timing {
        &self.timing
    }
}

/// Precomputed parameters of the **coalesced streaming kernel** (see
/// [`MemSim::run_trace`]): when a config's burst split falls on a uniform,
/// self-aligned chunk grid, long contiguous spans decompose into identical
/// full-chunk bursts whose queuing-model evolution has a closed form.
/// `None` (config does not meet the conditions) falls back to the scalar
/// per-burst path everywhere — the fast path only ever engages when it is
/// provably bit-identical.
#[derive(Clone, Copy, Debug)]
struct StreamCfg {
    /// Uniform chunk size in bytes: `min(boundary_bytes, max_burst_beats
    /// * bus_bytes)`, required to divide both the AXI boundary and the
    /// DRAM row (so aligned chunks never cross either).
    chunk: u64,
    /// Data beats per uniform chunk.
    beats: u64,
    /// Worst-case first-beat latency (`row_miss_cycles`); the bus-bound
    /// conditions are checked against it so hit/miss classification can
    /// never change the closed-form state evolution.
    lat_max: u64,
    /// The outstanding window size (`max_outstanding`), as u64.
    window: u64,
}

/// Derive the streaming parameters for `cfg`, or `None` when any of the
/// static coalescing conditions fails (see `DESIGN.md` §"Trace compilation
/// & replay fast path" for the derivation):
///
/// * the chunk grid is uniform and self-aligned: `chunk | boundary_bytes`;
/// * aligned chunks never cross a DRAM row: `chunk | row_bytes`;
/// * `row_hit_cycles <= row_miss_cycles` (so `lat_max` really bounds both);
/// * the window overlaps enough to keep the bus the bottleneck once it is:
///   `beats >= issue_cycles`, `window >= 2`,
///   `2*issue + lat_max <= window*beats` and
///   `issue + lat_max <= (window-1)*beats`.
fn stream_cfg(cfg: &MemConfig) -> Option<StreamCfg> {
    let chunk = cfg.boundary_bytes.min(cfg.max_burst_beats * cfg.bus_bytes);
    if chunk == 0 || cfg.boundary_bytes % chunk != 0 || chunk % cfg.bus_bytes != 0 {
        return None;
    }
    if cfg.row_bytes % chunk != 0 || cfg.row_hit_cycles > cfg.row_miss_cycles {
        return None;
    }
    let beats = chunk / cfg.bus_bytes;
    let window = cfg.max_outstanding as u64;
    let lat_max = cfg.row_miss_cycles;
    if window < 2 || beats < cfg.issue_cycles {
        return None;
    }
    if 2 * cfg.issue_cycles + lat_max > window * beats {
        return None;
    }
    if cfg.issue_cycles + lat_max > (window - 1) * beats {
        return None;
    }
    Some(StreamCfg {
        chunk,
        beats,
        lat_max,
        window,
    })
}

/// Memory interface simulator: plan-time configuration ([`MemConfig`])
/// plus [`ReplayState`]. Holds DRAM bank state across calls so a
/// tile-by-tile driver observes realistic row locality.
#[derive(Clone, Debug)]
pub struct MemSim {
    cfg: MemConfig,
    stream: Option<StreamCfg>,
    state: ReplayState,
    /// Optional cycle-domain bandwidth sampler ([`crate::obs::timeline`]).
    /// Deliberately *not* part of [`ReplayState`]: snapshots/restores and
    /// the state-equality identity tests see the simulation, not the
    /// observer. The sampler only ever reads `state`, so a sampled run's
    /// `ReplayState` is bit-identical to an unsampled one.
    sampler: Option<TimelineSampler>,
}

impl MemSim {
    /// Build a simulator. Panics if the configuration violates
    /// [`MemConfig::validate`] — error-returning front doors (the `dse`
    /// space parser, `ExperimentSpec::compile`) validate before reaching
    /// here, so a panic marks a programming error, not bad user input.
    pub fn new(cfg: MemConfig) -> MemSim {
        if let Err(e) = cfg.validate() {
            panic!("invalid MemConfig: {e}");
        }
        let banks = cfg.banks as usize;
        let stream = stream_cfg(&cfg);
        MemSim {
            cfg,
            stream,
            state: ReplayState::for_banks(banks),
            sampler: None,
        }
    }

    /// True iff this simulator's config admits the coalesced streaming
    /// fast path (the paper's ZC706 defaults do).
    pub fn streaming_enabled(&self) -> bool {
        self.stream.is_some()
    }

    pub fn cfg(&self) -> &MemConfig {
        &self.cfg
    }

    /// Reset time and DRAM state (keeps the configuration). An attached
    /// sampler restarts with it (same epoch size, empty epochs), so the
    /// timeline always describes one run from t=0.
    pub fn reset(&mut self) {
        self.state = ReplayState::for_banks(self.cfg.banks as usize);
        if let Some(s) = &mut self.sampler {
            *s = TimelineSampler::new(s.epoch_cycles());
        }
    }

    /// Attach a bandwidth timeline sampler with `epoch_cycles`-cycle
    /// epochs (replacing any previous one). Sampling is passive: it
    /// cannot change the replay's state or timing.
    pub fn set_sampler(&mut self, epoch_cycles: u64) {
        self.sampler = Some(TimelineSampler::new(epoch_cycles));
    }

    /// The attached sampler, if any.
    pub fn sampler(&self) -> Option<&TimelineSampler> {
        self.sampler.as_ref()
    }

    /// Detach and return the sampler (e.g. to fold its epochs into a
    /// [`crate::obs::Timeline`]).
    pub fn take_sampler(&mut self) -> Option<TimelineSampler> {
        self.sampler.take()
    }

    /// Feed the attached sampler, if any. Called once per submitted
    /// span, after the span's bursts have completed.
    #[inline]
    fn sample(&mut self) {
        if let Some(s) = &mut self.sampler {
            s.record(&self.state.timing, self.state.now());
        }
    }

    /// Checkpoint the replay state (e.g. at a wave boundary).
    pub fn snapshot(&self) -> ReplayState {
        self.state.clone()
    }

    /// Restore a state previously taken with [`MemSim::snapshot`] from a
    /// simulator with the same configuration.
    pub fn restore(&mut self, state: ReplayState) {
        assert_eq!(
            state.open_rows.len(),
            self.cfg.banks as usize,
            "snapshot from a different bank configuration"
        );
        self.state = state;
    }

    /// Current simulated time (cycle when everything issued so far drains).
    pub fn now(&self) -> u64 {
        self.state.now()
    }

    pub fn timing(&self) -> &Timing {
        &self.state.timing
    }

    /// Split a transaction into AXI bursts (≤ max beats, no boundary
    /// crossing) and play them through the queuing model. Returns the
    /// completion cycle. This is the **scalar reference path**: every fast
    /// path ([`MemSim::run_trace`], [`MemSim::submit_streamed`]) is
    /// asserted bit-identical to it.
    pub fn submit(&mut self, txn: &Txn) -> u64 {
        self.submit_span(
            txn.dir,
            txn.addr * self.cfg.elem_bytes,
            txn.len * self.cfg.elem_bytes,
        )
    }

    /// [`MemSim::submit`] through the coalesced streaming kernel: the same
    /// AXI burst sequence and final state, with the uniform middle of long
    /// contiguous spans advanced in closed form.
    pub fn submit_streamed(&mut self, txn: &Txn) -> u64 {
        self.submit_span_streamed(
            txn.dir,
            txn.addr * self.cfg.elem_bytes,
            txn.len * self.cfg.elem_bytes,
        )
    }

    /// Play a whole transaction list; returns total cycles from t=0.
    pub fn run(&mut self, txns: &[Txn]) -> u64 {
        for t in txns {
            self.submit(t);
        }
        self.now()
    }

    /// Replay a compiled [`TxnTrace`] through the streaming kernel, without
    /// materializing `Txn` values or a transaction list. Bit-identical (the
    /// full [`ReplayState`], counters included) to [`MemSim::run`] over the
    /// trace's transactions — `tests/trace_replay.rs` pins this across
    /// random streams × random configs.
    pub fn run_trace(&mut self, trace: &TxnTrace) -> u64 {
        let _span = crate::obs::span("memsim::replay");
        let eb = self.cfg.elem_bytes;
        for i in 0..trace.len() {
            let (dir, addr, len) = trace.entry(i);
            self.submit_span_streamed(dir, addr * eb, len * eb);
        }
        self.now()
    }

    /// Lower bound on the cycle when a replay with `remaining_bytes` still
    /// to stream can possibly finish: the data bus moves at most one beat
    /// per cycle, so the remaining beats serialize after the current
    /// `bus_free`, and the command path never rolls back below `cmd_free`.
    ///
    /// This is the **monotone** bound the explorer's early-abort mode is
    /// built on: submitting a span advances `bus_free` by at least the
    /// beats it carried, while the remaining-beat term shrinks by at most
    /// that many (⌈(a+b)/w⌉ − ⌈a/w⌉ ≤ ⌈b/w⌉), so the bound never
    /// decreases as replay proceeds — and the final `now()` always
    /// satisfies it, so an effective-bandwidth figure derived from it is a
    /// true upper bound at every prefix (see DESIGN.md §"Scaling the
    /// explorer").
    pub fn min_final_cycles(&self, remaining_bytes: u64) -> u64 {
        let beats = remaining_bytes.div_ceil(self.cfg.bus_bytes);
        self.state.cmd_free.max(self.state.bus_free + beats)
    }

    /// Early-abort replay: identical to [`MemSim::run_trace`], except that
    /// before every entry `dominated` is consulted with the current
    /// [`MemSim::min_final_cycles`] bound. Returning `true` aborts the
    /// replay immediately (`None`); a run that completes returns
    /// `Some(now)` having evolved the state **bit-identically** to
    /// `run_trace` — the bound is read-only, so a predicate that never
    /// fires cannot perturb anything.
    pub fn run_trace_bounded(
        &mut self,
        trace: &TxnTrace,
        dominated: &mut dyn FnMut(u64) -> bool,
    ) -> Option<u64> {
        let _span = crate::obs::span("memsim::replay_bounded");
        let eb = self.cfg.elem_bytes;
        let mut remaining_b = trace.total_elems() * eb;
        for i in 0..trace.len() {
            if dominated(self.min_final_cycles(remaining_b)) {
                return None;
            }
            let (dir, addr, len) = trace.entry(i);
            self.submit_span_streamed(dir, addr * eb, len * eb);
            remaining_b -= len * eb;
        }
        Some(self.now())
    }

    /// Scalar replay of a compiled [`TxnTrace`]: the per-burst reference
    /// loop, just without a `Txn` list (bench baseline and property-test
    /// oracle for [`MemSim::run_trace`]).
    pub fn run_trace_scalar(&mut self, trace: &TxnTrace) -> u64 {
        let eb = self.cfg.elem_bytes;
        for i in 0..trace.len() {
            let (dir, addr, len) = trace.entry(i);
            self.submit_span(dir, addr * eb, len * eb);
        }
        self.now()
    }

    /// Scalar burst split of one byte span: the reference semantics.
    fn submit_span(&mut self, dir: Dir, mut addr_b: u64, mut remaining_b: u64) -> u64 {
        let mut done = self.now();
        while remaining_b > 0 {
            let to_boundary = self.cfg.boundary_bytes - (addr_b % self.cfg.boundary_bytes);
            let max_bytes = self.cfg.max_burst_beats * self.cfg.bus_bytes;
            let chunk = remaining_b.min(to_boundary).min(max_bytes);
            done = self.submit_axi(dir, addr_b, chunk);
            addr_b += chunk;
            remaining_b -= chunk;
        }
        self.sample();
        done
    }

    /// The coalesced streaming kernel. Burst boundaries are exactly those
    /// of [`MemSim::submit_span`]; only the *state evolution* of the
    /// uniform middle bursts is advanced in closed form, and only once the
    /// replay provably reaches the bus-bound steady state:
    ///
    /// 1. **Head** (scalar): boundary-clipped bursts until the cursor sits
    ///    on the uniform chunk grid.
    /// 2. **Uniform region**: full-`chunk`, chunk-aligned bursts. Processed
    ///    scalar while tracking consecutive *bus-bound* bursts (`complete ==
    ///    bus_free + beats` — equivalent to `data_start == bus_free`, which
    ///    also rules out a turnaround). After `window` consecutive bus-bound
    ///    bursts the in-flight ring is exactly the arithmetic tail of the
    ///    uniform sequence; if additionally `cmd_free + issue + lat_max <=
    ///    bus_free`, the static [`StreamCfg`] conditions guarantee every
    ///    remaining uniform burst stays bus-bound, and [`MemSim::bulk_advance`]
    ///    applies all of them at once.
    /// 3. **Tail** (scalar): the sub-chunk remainder.
    fn submit_span_streamed(&mut self, dir: Dir, mut addr: u64, mut remaining: u64) -> u64 {
        let Some(sc) = self.stream else {
            return self.submit_span(dir, addr, remaining);
        };
        let mut done = self.now();
        // head: at most boundary/chunk + 1 bursts (the boundary clip forces
        // boundary alignment, and chunk divides the boundary)
        while remaining > 0 && addr % sc.chunk != 0 {
            let to_boundary = self.cfg.boundary_bytes - (addr % self.cfg.boundary_bytes);
            let max_bytes = self.cfg.max_burst_beats * self.cfg.bus_bytes;
            let n = remaining.min(to_boundary).min(max_bytes);
            done = self.submit_axi(dir, addr, n);
            addr += n;
            remaining -= n;
        }
        // uniform region: aligned chunks never see a closer boundary (the
        // distance to the boundary is a positive multiple of chunk), so the
        // scalar split would emit exactly `chunk` bytes per burst here
        let mut full = remaining / sc.chunk;
        let mut streak = 0u64;
        while full > 0 {
            if streak >= sc.window
                && self.state.inflight.len() == self.cfg.max_outstanding
                && self.state.cmd_free + self.cfg.issue_cycles + sc.lat_max <= self.state.bus_free
            {
                done = self.bulk_advance(addr, full, &sc);
                addr += full * sc.chunk;
                remaining -= full * sc.chunk;
                full = 0;
            } else {
                let bus0 = self.state.bus_free;
                done = self.submit_axi(dir, addr, sc.chunk);
                streak = if done == bus0 + sc.beats { streak + 1 } else { 0 };
                addr += sc.chunk;
                remaining -= sc.chunk;
                full -= 1;
            }
        }
        // tail: chunk-aligned and sub-chunk, so it never crosses a boundary
        if remaining > 0 {
            done = self.submit_axi(dir, addr, remaining);
        }
        // one sample per span, the same granularity as the scalar path
        // (the no-streaming fallback returned above, sampling inside
        // submit_span), so scalar and streamed replays of one trace
        // produce identical timelines
        self.sample();
        done
    }

    /// Advance the replay state across `n` uniform chunk-aligned bursts in
    /// closed form. Preconditions (established by the caller): the last
    /// `window` bursts were uniform and bus-bound (so the in-flight ring is
    /// `{bus_free - (window-1)*beats, .., bus_free}`), the same direction
    /// continues (no turnaround), aligned chunks cross neither an AXI
    /// boundary nor a DRAM row, and `cmd_free + issue + lat_max <=
    /// bus_free`. Under the static [`StreamCfg`] conditions these make
    /// every one of the `n` bursts bus-bound, so:
    ///
    /// * the bus advances exactly `beats` per burst;
    /// * `cmd_free_k = max(cmd_free_0 + k*issue, bus_free_0 + (k-window)*
    ///   beats + issue)` (induction over `issue <= beats`);
    /// * first-beat classification: a burst entering DRAM row `r` is a hit
    ///   iff `open_rows[r % banks] == r` — only the first `banks` rows
    ///   entered can still see pre-span state; later entries re-enter a
    ///   bank opened `banks` rows earlier inside the span, always a miss.
    ///   Non-entering bursts stream inside an already-open row: hits.
    ///
    /// Latency never feeds the state (the conditions hold for
    /// `lat_max`), so hit/miss classification affects counters only —
    /// which is exactly why the bulk state is bit-identical to scalar.
    fn bulk_advance(&mut self, addr: u64, n: u64, sc: &StreamCfg) -> u64 {
        let i_cyc = self.cfg.issue_cycles;
        let row_bytes = self.cfg.row_bytes;
        let banks = self.cfg.banks;
        let (b, m) = (sc.beats, sc.window);
        let st = &mut self.state;
        let b0 = st.bus_free;
        let c0 = st.cmd_free;
        // bus: every burst is bus-bound
        st.bus_free = b0 + n * b;
        // command path closed form (see doc comment)
        let via_window = if n >= m {
            b0 + (n - m) * b + i_cyc
        } else {
            // ring entries are earlier uniform completes, all >= (m-n)*b
            b0 - (m - n) * b + i_cyc
        };
        st.cmd_free = (c0 + n * i_cyc).max(via_window);
        // in-flight ring: the last `window` completes of the uniform
        // sequence (reaching back into the pre-bulk streak when n < window)
        st.inflight.clear();
        for j in 0..m {
            let back = m - 1 - j; // window-1 .. 0
            let v = if n >= back {
                st.bus_free - back * b
            } else {
                b0 - (back - n) * b
            };
            st.inflight.push_back(v);
        }
        st.timing.axi_bursts += n;
        st.timing.data_cycles += n * b;
        // row accounting: rows whose start lies in [addr, end) are entered
        // at a chunk-aligned burst start (chunk divides row_bytes)
        let end = addr + n * sc.chunk;
        let first_row = addr.div_ceil(row_bytes);
        if first_row * row_bytes < end {
            let n_rows = (end - 1) / row_bytes - first_row + 1;
            let probe = n_rows.min(banks);
            let mut hits = 0u64;
            for r in first_row..first_row + probe {
                if st.open_rows[(r % banks) as usize] == Some(r) {
                    hits += 1;
                }
            }
            st.timing.row_hits += (n - n_rows) + hits;
            st.timing.row_misses += n_rows - hits;
            let last_row = first_row + n_rows - 1;
            for r in (last_row + 1 - probe)..=last_row {
                st.open_rows[(r % banks) as usize] = Some(r);
            }
        } else {
            // the whole bulk streams inside the already-open current row
            st.timing.row_hits += n;
        }
        st.timing.cycles = st.bus_free.max(st.cmd_free);
        st.bus_free
    }

    /// One AXI burst through the model.
    fn submit_axi(&mut self, dir: Dir, addr_b: u64, bytes: u64) -> u64 {
        let st = &mut self.state;
        let beats = bytes.div_ceil(self.cfg.bus_bytes);
        st.timing.axi_bursts += 1;

        // --- command path: serialized issue, bounded outstanding window.
        let mut issue = st.cmd_free;
        if st.inflight.len() >= self.cfg.max_outstanding {
            // must wait for the oldest in-flight burst to retire (O(1):
            // the window is a ring, not a shifted Vec)
            let oldest = st.inflight.pop_front().expect("window non-empty");
            issue = issue.max(oldest);
        }
        st.cmd_free = issue + self.cfg.issue_cycles;

        // --- DRAM latency for the first beat.
        let row = addr_b / self.cfg.row_bytes;
        let bank = (row % self.cfg.banks) as usize;
        let hit = st.open_rows[bank] == Some(row);
        let lat = if hit {
            st.timing.row_hits += 1;
            self.cfg.row_hit_cycles
        } else {
            st.timing.row_misses += 1;
            self.cfg.row_miss_cycles
        };
        st.open_rows[bank] = Some(row);

        // --- row switches inside the burst.
        let last_b = addr_b + bytes - 1;
        let rows_crossed = last_b / self.cfg.row_bytes - row;
        if rows_crossed > 0 {
            // every subsequent row in the stream is a fresh activate, but
            // DRAM-side prefetch overlaps most of it; charge a reduced
            // penalty and update the open row.
            let final_row = last_b / self.cfg.row_bytes;
            let bank2 = (final_row % self.cfg.banks) as usize;
            st.open_rows[bank2] = Some(final_row);
            st.timing.row_switches += rows_crossed;
        }
        let row_switch_pen = rows_crossed * (self.cfg.row_miss_cycles / 4);

        // --- turnaround.
        let turn = if st.last_dir.is_some() && st.last_dir != Some(dir) {
            st.timing.turnarounds += 1;
            self.cfg.turnaround_cycles
        } else {
            0
        };
        st.last_dir = Some(dir);

        // --- data phase: first beat after issue+latency, but the bus is a
        // single resource; latency overlaps earlier bursts' data phases.
        let data_start = (issue + self.cfg.issue_cycles + lat).max(st.bus_free + turn);
        let complete = data_start + beats + row_switch_pen;
        st.bus_free = complete;
        st.timing.data_cycles += beats;
        st.timing.cycles = st.now();
        st.inflight.push_back(complete);
        complete
    }

    /// Convenience: run transactions and fold into a [`Bandwidth`] record.
    /// `useful_elems` is supplied by the layout plans.
    pub fn measure(&mut self, txns: &[Txn], useful_elems: u64) -> Bandwidth {
        self.reset();
        let cycles = self.run(txns);
        let raw_elems: u64 = txns.iter().map(|t| t.len).sum();
        Bandwidth {
            raw_bytes: raw_elems * self.cfg.elem_bytes,
            useful_bytes: useful_elems * self.cfg.elem_bytes,
            cycles,
            bursts: self.state.timing.axi_bursts,
            // all activates observed: per-burst misses + mid-burst switches
            row_misses: self.state.timing.row_misses + self.state.timing.row_switches,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run as prop_run, Config};

    fn sim() -> MemSim {
        MemSim::new(MemConfig::default())
    }

    #[test]
    fn single_long_burst_approaches_bus_rate() {
        let mut s = sim();
        // 1 MiB contiguous read
        let txns = [Txn {
            dir: Dir::Read,
            addr: 0,
            len: 131_072,
        }];
        let bw = s.measure(&txns, 131_072);
        let eff = bw.efficiency(s.cfg());
        assert!(eff > 0.9, "long burst efficiency {eff}");
        assert!(eff <= 1.0 + 1e-9, "cannot beat the roofline: {eff}");
    }

    #[test]
    fn scattered_singletons_are_slow() {
        let mut s = sim();
        // 4096 single-element reads scattered across rows
        let txns: Vec<Txn> = (0..4096)
            .map(|i| Txn {
                dir: Dir::Read,
                addr: i * 1031, // stride past rows
                len: 1,
            })
            .collect();
        let bw = s.measure(&txns, 4096);
        let eff = bw.efficiency(s.cfg());
        assert!(eff < 0.3, "scattered reads should be slow, got {eff}");
    }

    #[test]
    fn longer_bursts_monotonically_better() {
        // same data volume, increasing burst length
        let total = 32_768u64;
        let mut prev = 0.0;
        for burst in [8u64, 64, 512, 4096] {
            let mut s = sim();
            let txns: Vec<Txn> = (0..total / burst)
                .map(|i| Txn {
                    dir: Dir::Read,
                    addr: i * burst * 3, // gaps → separate transactions
                    len: burst,
                })
                .collect();
            let bw = s.measure(&txns, total);
            let eff = bw.efficiency(s.cfg());
            assert!(
                eff >= prev - 0.02,
                "efficiency should improve with burst length: {burst} -> {eff} (prev {prev})"
            );
            prev = eff;
        }
        assert!(prev > 0.8);
    }

    #[test]
    fn boundary_and_length_segmentation() {
        let mut s = sim();
        // 600 elements * 8B = 4800B: crosses a 4KiB boundary → ≥2 bursts;
        // also > 256 beats → ≥3
        s.measure(
            &[Txn {
                dir: Dir::Read,
                addr: 0,
                len: 600,
            }],
            600,
        );
        assert!(s.timing().axi_bursts >= 3);
    }

    #[test]
    fn row_hits_tracked() {
        let mut s = sim();
        // two bursts in the same row: second is a hit
        s.run(&[
            Txn {
                dir: Dir::Read,
                addr: 0,
                len: 8,
            },
            Txn {
                dir: Dir::Read,
                addr: 16,
                len: 8,
            },
        ]);
        assert_eq!(s.timing().row_hits, 1);
        assert_eq!(s.timing().row_misses, 1);
    }

    #[test]
    fn turnaround_counted() {
        let mut s = sim();
        s.run(&[
            Txn {
                dir: Dir::Read,
                addr: 0,
                len: 8,
            },
            Txn {
                dir: Dir::Write,
                addr: 1024,
                len: 8,
            },
            Txn {
                dir: Dir::Write,
                addr: 2048,
                len: 8,
            },
        ]);
        assert_eq!(s.timing().turnarounds, 1);
    }

    #[test]
    fn mid_burst_row_crossings_are_switches_not_misses() {
        // AXI bursts never cross the 4 KiB boundary, so mid-burst row
        // crossings need rows smaller than the boundary
        let mut s = MemSim::new(MemConfig {
            row_bytes: 1024,
            ..MemConfig::default()
        });
        // 2 KiB contiguous read: one burst streaming across a 1 KiB row
        // boundary — exactly one first-beat classification (a miss), the
        // crossing counted as an in-burst switch
        s.run(&[Txn {
            dir: Dir::Read,
            addr: 0,
            len: 256,
        }]);
        let t = s.timing();
        assert_eq!(t.row_hits + t.row_misses, t.axi_bursts);
        assert!(t.row_switches > 0, "{t:?}");
    }

    #[test]
    fn snapshot_restore_replays_identically() {
        let mut s = sim();
        let wave1 = [
            Txn {
                dir: Dir::Read,
                addr: 0,
                len: 100,
            },
            Txn {
                dir: Dir::Write,
                addr: 5000,
                len: 40,
            },
        ];
        let wave2 = [Txn {
            dir: Dir::Read,
            addr: 123,
            len: 77,
        }];
        s.run(&wave1);
        let at_boundary = s.snapshot();
        s.run(&wave2);
        let first = (s.now(), s.timing().clone());
        // restore to the wave boundary and replay wave2: bit-identical
        s.restore(at_boundary);
        s.run(&wave2);
        assert_eq!((s.now(), s.timing().clone()), first);
    }

    #[test]
    fn reset_restores_time_zero() {
        let mut s = sim();
        s.run(&[Txn {
            dir: Dir::Read,
            addr: 0,
            len: 100,
        }]);
        assert!(s.now() > 0);
        s.reset();
        assert_eq!(s.now(), 0);
        assert_eq!(s.timing().axi_bursts, 0);
    }

    /// Verbatim reimplementation of the pre-ring engine: the in-flight
    /// window as a `Vec` shifted with `remove(0)`, all other rules
    /// identical. The ring-cursor window must reproduce it bit for bit.
    struct ShiftEngine {
        cfg: MemConfig,
        open_rows: Vec<Option<u64>>,
        inflight: Vec<u64>,
        cmd_free: u64,
        bus_free: u64,
        last_dir: Option<Dir>,
        timing: Timing,
    }

    impl ShiftEngine {
        fn new(cfg: MemConfig) -> ShiftEngine {
            let banks = cfg.banks as usize;
            ShiftEngine {
                cfg,
                open_rows: vec![None; banks],
                inflight: Vec::new(),
                cmd_free: 0,
                bus_free: 0,
                last_dir: None,
                timing: Timing::default(),
            }
        }

        fn now(&self) -> u64 {
            self.bus_free.max(self.cmd_free)
        }

        fn submit(&mut self, txn: &Txn) {
            let mut addr_b = txn.addr * self.cfg.elem_bytes;
            let mut remaining_b = txn.len * self.cfg.elem_bytes;
            while remaining_b > 0 {
                let to_boundary = self.cfg.boundary_bytes - (addr_b % self.cfg.boundary_bytes);
                let max_bytes = self.cfg.max_burst_beats * self.cfg.bus_bytes;
                let chunk = remaining_b.min(to_boundary).min(max_bytes);
                self.submit_axi(txn.dir, addr_b, chunk);
                addr_b += chunk;
                remaining_b -= chunk;
            }
        }

        fn submit_axi(&mut self, dir: Dir, addr_b: u64, bytes: u64) {
            let beats = bytes.div_ceil(self.cfg.bus_bytes);
            self.timing.axi_bursts += 1;
            let mut issue = self.cmd_free;
            if self.inflight.len() >= self.cfg.max_outstanding {
                let oldest = self.inflight.remove(0); // the old O(window) shift
                issue = issue.max(oldest);
            }
            self.cmd_free = issue + self.cfg.issue_cycles;
            let row = addr_b / self.cfg.row_bytes;
            let bank = (row % self.cfg.banks) as usize;
            let lat = if self.open_rows[bank] == Some(row) {
                self.timing.row_hits += 1;
                self.cfg.row_hit_cycles
            } else {
                self.timing.row_misses += 1;
                self.cfg.row_miss_cycles
            };
            self.open_rows[bank] = Some(row);
            let last_b = addr_b + bytes - 1;
            let rows_crossed = last_b / self.cfg.row_bytes - row;
            if rows_crossed > 0 {
                let final_row = last_b / self.cfg.row_bytes;
                let bank2 = (final_row % self.cfg.banks) as usize;
                self.open_rows[bank2] = Some(final_row);
                self.timing.row_switches += rows_crossed;
            }
            let row_switch_pen = rows_crossed * (self.cfg.row_miss_cycles / 4);
            let turn = if self.last_dir.is_some() && self.last_dir != Some(dir) {
                self.timing.turnarounds += 1;
                self.cfg.turnaround_cycles
            } else {
                0
            };
            self.last_dir = Some(dir);
            let data_start = (issue + self.cfg.issue_cycles + lat).max(self.bus_free + turn);
            let complete = data_start + beats + row_switch_pen;
            self.bus_free = complete;
            self.timing.data_cycles += beats;
            self.timing.cycles = self.now();
            self.inflight.push(complete);
        }
    }

    fn random_stream(g: &crate::util::prop::Gen, n: usize) -> Vec<Txn> {
        (0..n)
            .map(|_| Txn {
                dir: if g.bool() { Dir::Read } else { Dir::Write },
                addr: g.i64(0, 1 << 18) as u64,
                len: g.i64(1, 4096) as u64,
            })
            .collect()
    }

    #[test]
    fn prop_ring_window_matches_shift_reference() {
        // the satellite contract: the ring-cursor outstanding window is
        // bit-identical (Timing and now()) to the old Vec::remove(0) shift
        // on randomized burst streams, across window sizes
        prop_run("ring window == shifted window", Config::small(60), |g| {
            let cfg = MemConfig {
                max_outstanding: g.usize(1, 6),
                row_bytes: *g.choose(&[1024u64, 8192]),
                ..MemConfig::default()
            };
            let txns = random_stream(g, g.usize(1, 24));
            let mut ring = MemSim::new(cfg.clone());
            let mut shift = ShiftEngine::new(cfg);
            ring.run(&txns);
            for t in &txns {
                shift.submit(t);
            }
            assert_eq!(ring.now(), shift.now());
            assert_eq!(*ring.timing(), shift.timing);
        });
    }

    #[test]
    fn prop_streamed_submit_matches_scalar() {
        // streaming fast path vs the scalar reference: full state equality
        // on the default (streaming-enabled) config, including long
        // contiguous spans that trigger the closed-form bulk advance
        prop_run("streamed == scalar", Config::small(40), |g| {
            let cfg = MemConfig::default();
            let n = g.usize(1, 8);
            let txns: Vec<Txn> = (0..n)
                .map(|_| Txn {
                    dir: if g.bool() { Dir::Read } else { Dir::Write },
                    addr: g.i64(0, 1 << 16) as u64,
                    len: g.i64(1, 1 << 17) as u64, // up to 1 MiB spans
                })
                .collect();
            let mut scalar = MemSim::new(cfg.clone());
            let mut streamed = MemSim::new(cfg);
            assert!(streamed.streaming_enabled());
            for t in &txns {
                let a = scalar.submit(t);
                let b = streamed.submit_streamed(t);
                assert_eq!(a, b);
            }
            assert_eq!(scalar.snapshot(), streamed.snapshot());
        });
    }

    #[test]
    fn bulk_advance_engages_on_the_paper_config() {
        // a 4 MiB contiguous read on the ZC706 defaults reaches the
        // bus-bound steady state; the streamed path must agree exactly
        let txn = Txn {
            dir: Dir::Read,
            addr: 3, // misaligned start: head bursts before the uniform grid
            len: 1 << 19,
        };
        let mut scalar = sim();
        let mut streamed = sim();
        scalar.submit(&txn);
        streamed.submit_streamed(&txn);
        assert_eq!(scalar.snapshot(), streamed.snapshot());
        assert!(scalar.timing().axi_bursts > 100);
    }

    #[test]
    fn sampling_never_perturbs_the_replay_and_sums_exactly() {
        // the timeline contract: sampler on ≡ off for the full replay
        // state, and the epoch deltas sum to the aggregate counters —
        // on both the scalar and the streamed kernel
        let txns: Vec<Txn> = (0..40)
            .map(|i| Txn {
                dir: if i % 5 == 0 { Dir::Write } else { Dir::Read },
                addr: i * 977,
                len: 1 + (i * 131) % 3000,
            })
            .collect();
        let mut plain = sim();
        let mut sampled = sim();
        sampled.set_sampler(256);
        for t in &txns {
            plain.submit_streamed(t);
            sampled.submit_streamed(t);
        }
        assert_eq!(plain.snapshot(), sampled.snapshot(), "sampling is passive");
        let epochs = sampled.sampler().unwrap().epochs().to_vec();
        assert!(!epochs.is_empty());
        let tl = crate::obs::Timeline {
            epoch_cycles: 256,
            channels: vec![epochs.clone()],
        };
        assert!(tl.matches(sampled.timing()), "epochs sum to the aggregate");
        // and the scalar kernel records the identical timeline
        let mut scalar = sim();
        scalar.set_sampler(256);
        for t in &txns {
            scalar.submit(t);
        }
        assert_eq!(scalar.sampler().unwrap().epochs(), &epochs[..]);
        // reset restarts the sampler with the run
        scalar.reset();
        assert!(scalar.sampler().unwrap().epochs().is_empty());
    }

    #[test]
    #[should_panic(expected = "max_outstanding")]
    fn zero_outstanding_window_rejected_at_construction() {
        MemSim::new(MemConfig {
            max_outstanding: 0,
            ..MemConfig::default()
        });
    }

    #[test]
    fn prop_conservation_laws() {
        prop_run("memsim conservation", Config::small(60), |g| {
            let mut s = sim();
            let n = g.usize(1, 20);
            let txns: Vec<Txn> = (0..n)
                .map(|_| Txn {
                    dir: if g.bool() { Dir::Read } else { Dir::Write },
                    addr: g.i64(0, 1 << 20) as u64,
                    len: g.i64(1, 2048) as u64,
                })
                .collect();
            let total: u64 = txns.iter().map(|t| t.len).sum();
            let bw = s.measure(&txns, total);
            // the bus moves one beat per cycle at most
            assert!(bw.cycles >= s.cfg().beats(total));
            // effective <= raw <= roofline
            assert!(bw.effective_mb_s(s.cfg()) <= bw.raw_mb_s(s.cfg()) + 1e-9);
            assert!(bw.raw_mb_s(s.cfg()) <= s.cfg().peak_mb_s() + 1e-9);
            // monotonic time
            assert_eq!(bw.cycles, s.now());
        });
    }

    #[test]
    fn prop_splitting_a_txn_never_helps() {
        prop_run("merged txn at least as fast", Config::small(40), |g| {
            let len = g.i64(2, 4096) as u64;
            let addr = g.i64(0, 1 << 16) as u64;
            let cut = g.i64(1, len as i64 - 1) as u64;
            let merged = [Txn {
                dir: Dir::Read,
                addr,
                len,
            }];
            let split = [
                Txn {
                    dir: Dir::Read,
                    addr,
                    len: cut,
                },
                Txn {
                    dir: Dir::Read,
                    addr: addr + cut,
                    len: len - cut,
                },
            ];
            let mut s1 = sim();
            let mut s2 = sim();
            let t_merged = s1.run(&merged);
            let t_split = s2.run(&split);
            assert!(
                t_merged <= t_split,
                "merged {t_merged} > split {t_split} (len {len}, cut {cut})"
            );
        });
    }

    fn random_trace(g: &crate::util::prop::Gen) -> TxnTrace {
        let mut t = TxnTrace::new();
        let n = g.i64(1, 24) as usize;
        for _ in 0..n {
            let dir = if g.bool() { Dir::Read } else { Dir::Write };
            let addr = g.i64(0, 1 << 14) as u64;
            let len = g.i64(1, 1024) as u64;
            t.push(dir, addr, len);
        }
        t
    }

    #[test]
    fn prop_bounded_replay_completion_is_bit_identical() {
        // a predicate that never fires must leave the state exactly as
        // run_trace does, and the bound it saw must be monotone and never
        // exceed the final completion cycle
        prop_run("bounded replay identity", Config::small(40), |g| {
            let trace = random_trace(g);
            let mut plain = sim();
            let t_plain = plain.run_trace(&trace);
            let mut bounded = sim();
            let mut bounds: Vec<u64> = Vec::new();
            let t_bounded = bounded
                .run_trace_bounded(&trace, &mut |lb| {
                    bounds.push(lb);
                    false
                })
                .expect("never aborted");
            assert_eq!(t_plain, t_bounded);
            assert_eq!(plain.snapshot(), bounded.snapshot());
            assert!(
                bounds.windows(2).all(|w| w[0] <= w[1]),
                "bound not monotone: {bounds:?}"
            );
            assert!(
                bounds.iter().all(|&lb| lb <= t_plain),
                "bound above final cycles {t_plain}: {bounds:?}"
            );
        });
    }

    #[test]
    fn bounded_replay_aborts_at_the_first_dominated_entry() {
        let mut t = TxnTrace::new();
        for i in 0..8u64 {
            t.push(Dir::Read, i * 4096, 256);
        }
        let mut s = sim();
        let mut calls = 0usize;
        let aborted = s.run_trace_bounded(&t, &mut |_| {
            calls += 1;
            calls == 3
        });
        assert!(aborted.is_none());
        assert_eq!(calls, 3, "stops probing after the abort");
        // the first probe happens before any entry is submitted, so an
        // immediately-dominated point costs zero replay work
        let mut s2 = sim();
        let zero = s2.run_trace_bounded(&t, &mut |_| true);
        assert!(zero.is_none());
        assert_eq!(s2.timing().axi_bursts, 0);
    }
}
