//! The transaction-level timing engine.
//!
//! A small queuing model with two resources: the **command path** (accepts
//! one AXI burst per `issue_cycles`, at most `max_outstanding` in flight)
//! and the **data bus** (one beat per cycle). Each burst's first data beat
//! additionally waits for the DRAM latency (row hit or miss, per bank,
//! open-row policy); long bursts crossing row boundaries pay the row-switch
//! penalty inline. Latency of burst *i+1* overlaps the data phase of burst
//! *i* — exactly the "burst access overlapping" Vitis relies on — so long
//! back-to-back bursts stream at the bus rate while scattered short bursts
//! pay latency on every transaction.

use crate::memsim::{Bandwidth, Dir, MemConfig, Txn};

/// Detailed timing of one simulated run.
///
/// Accounting identities (checked by `tests/memsim_identities.rs`):
/// every AXI burst's first beat is classified as exactly one row hit or
/// row miss (`row_hits + row_misses == axi_bursts`); rows crossed *inside*
/// a streaming burst are counted separately in `row_switches`;
/// `data_cycles` equals the total beats transferred; `turnarounds` equals
/// the number of read↔write direction changes in the burst stream.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Timing {
    pub cycles: u64,
    pub data_cycles: u64,
    pub axi_bursts: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    /// Row activations forced mid-burst by streaming across a row
    /// boundary (charged a reduced, prefetch-overlapped penalty).
    pub row_switches: u64,
    pub turnarounds: u64,
}

/// **Replay-time** state of the memory interface: DRAM bank rows, the
/// in-flight window, resource clocks and the running counters.
///
/// Split out of [`MemSim`] so batched coordinators can treat burst
/// *planning* (pure, parallelizable) and timing *replay* (stateful,
/// order-dependent) as separate phases: plans are computed concurrently,
/// then replayed through one `ReplayState` in a deterministic order —
/// that fixed replay order is what makes batched runs bit-identical to
/// serial ones. [`MemSim::snapshot`] / [`MemSim::restore`] additionally
/// let callers checkpoint and re-run a stretch of the replay (e.g. one
/// wave) in isolation; the batch coordinator itself replays straight
/// through and does not need them.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ReplayState {
    /// Open row per bank.
    open_rows: Vec<Option<u64>>,
    /// Completion times of in-flight bursts (ring, max_outstanding).
    inflight: Vec<u64>,
    /// Next cycle the command path is free.
    cmd_free: u64,
    /// Next cycle the data bus is free.
    bus_free: u64,
    /// Direction of the previous burst (turnaround tracking).
    last_dir: Option<Dir>,
    /// Running counters.
    timing: Timing,
}

impl ReplayState {
    fn for_banks(banks: usize) -> ReplayState {
        ReplayState {
            open_rows: vec![None; banks],
            ..ReplayState::default()
        }
    }

    /// Current simulated time (cycle when everything issued so far drains).
    pub fn now(&self) -> u64 {
        self.bus_free.max(self.cmd_free)
    }

    pub fn timing(&self) -> &Timing {
        &self.timing
    }
}

/// Memory interface simulator: plan-time configuration ([`MemConfig`])
/// plus [`ReplayState`]. Holds DRAM bank state across calls so a
/// tile-by-tile driver observes realistic row locality.
#[derive(Clone, Debug)]
pub struct MemSim {
    cfg: MemConfig,
    state: ReplayState,
}

impl MemSim {
    pub fn new(cfg: MemConfig) -> MemSim {
        let banks = cfg.banks as usize;
        MemSim {
            cfg,
            state: ReplayState::for_banks(banks),
        }
    }

    pub fn cfg(&self) -> &MemConfig {
        &self.cfg
    }

    /// Reset time and DRAM state (keeps the configuration).
    pub fn reset(&mut self) {
        self.state = ReplayState::for_banks(self.cfg.banks as usize);
    }

    /// Checkpoint the replay state (e.g. at a wave boundary).
    pub fn snapshot(&self) -> ReplayState {
        self.state.clone()
    }

    /// Restore a state previously taken with [`MemSim::snapshot`] from a
    /// simulator with the same configuration.
    pub fn restore(&mut self, state: ReplayState) {
        assert_eq!(
            state.open_rows.len(),
            self.cfg.banks as usize,
            "snapshot from a different bank configuration"
        );
        self.state = state;
    }

    /// Current simulated time (cycle when everything issued so far drains).
    pub fn now(&self) -> u64 {
        self.state.now()
    }

    pub fn timing(&self) -> &Timing {
        &self.state.timing
    }

    /// Split a transaction into AXI bursts (≤ max beats, no boundary
    /// crossing) and play them through the queuing model. Returns the
    /// completion cycle.
    pub fn submit(&mut self, txn: &Txn) -> u64 {
        let mut addr_b = txn.addr * self.cfg.elem_bytes;
        let mut remaining_b = txn.len * self.cfg.elem_bytes;
        let mut done = self.now();
        while remaining_b > 0 {
            let to_boundary = self.cfg.boundary_bytes - (addr_b % self.cfg.boundary_bytes);
            let max_bytes = self.cfg.max_burst_beats * self.cfg.bus_bytes;
            let chunk = remaining_b.min(to_boundary).min(max_bytes);
            done = self.submit_axi(txn.dir, addr_b, chunk);
            addr_b += chunk;
            remaining_b -= chunk;
        }
        done
    }

    /// Play a whole transaction list; returns total cycles from t=0.
    pub fn run(&mut self, txns: &[Txn]) -> u64 {
        for t in txns {
            self.submit(t);
        }
        self.now()
    }

    /// One AXI burst through the model.
    fn submit_axi(&mut self, dir: Dir, addr_b: u64, bytes: u64) -> u64 {
        let st = &mut self.state;
        let beats = bytes.div_ceil(self.cfg.bus_bytes);
        st.timing.axi_bursts += 1;

        // --- command path: serialized issue, bounded outstanding window.
        let mut issue = st.cmd_free;
        if st.inflight.len() >= self.cfg.max_outstanding {
            // must wait for the oldest in-flight burst to retire
            let oldest = st.inflight.remove(0);
            issue = issue.max(oldest);
        }
        st.cmd_free = issue + self.cfg.issue_cycles;

        // --- DRAM latency for the first beat.
        let row = addr_b / self.cfg.row_bytes;
        let bank = (row % self.cfg.banks) as usize;
        let hit = st.open_rows[bank] == Some(row);
        let lat = if hit {
            st.timing.row_hits += 1;
            self.cfg.row_hit_cycles
        } else {
            st.timing.row_misses += 1;
            self.cfg.row_miss_cycles
        };
        st.open_rows[bank] = Some(row);

        // --- row switches inside the burst.
        let last_b = addr_b + bytes - 1;
        let rows_crossed = last_b / self.cfg.row_bytes - row;
        if rows_crossed > 0 {
            // every subsequent row in the stream is a fresh activate, but
            // DRAM-side prefetch overlaps most of it; charge a reduced
            // penalty and update the open row.
            let final_row = last_b / self.cfg.row_bytes;
            let bank2 = (final_row % self.cfg.banks) as usize;
            st.open_rows[bank2] = Some(final_row);
            st.timing.row_switches += rows_crossed;
        }
        let row_switch_pen = rows_crossed * (self.cfg.row_miss_cycles / 4);

        // --- turnaround.
        let turn = if st.last_dir.is_some() && st.last_dir != Some(dir) {
            st.timing.turnarounds += 1;
            self.cfg.turnaround_cycles
        } else {
            0
        };
        st.last_dir = Some(dir);

        // --- data phase: first beat after issue+latency, but the bus is a
        // single resource; latency overlaps earlier bursts' data phases.
        let data_start = (issue + self.cfg.issue_cycles + lat).max(st.bus_free + turn);
        let complete = data_start + beats + row_switch_pen;
        st.bus_free = complete;
        st.timing.data_cycles += beats;
        st.timing.cycles = st.now();
        st.inflight.push(complete);
        complete
    }

    /// Convenience: run transactions and fold into a [`Bandwidth`] record.
    /// `useful_elems` is supplied by the layout plans.
    pub fn measure(&mut self, txns: &[Txn], useful_elems: u64) -> Bandwidth {
        self.reset();
        let cycles = self.run(txns);
        let raw_elems: u64 = txns.iter().map(|t| t.len).sum();
        Bandwidth {
            raw_bytes: raw_elems * self.cfg.elem_bytes,
            useful_bytes: useful_elems * self.cfg.elem_bytes,
            cycles,
            bursts: self.state.timing.axi_bursts,
            // all activates observed: per-burst misses + mid-burst switches
            row_misses: self.state.timing.row_misses + self.state.timing.row_switches,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run as prop_run, Config};

    fn sim() -> MemSim {
        MemSim::new(MemConfig::default())
    }

    #[test]
    fn single_long_burst_approaches_bus_rate() {
        let mut s = sim();
        // 1 MiB contiguous read
        let txns = [Txn {
            dir: Dir::Read,
            addr: 0,
            len: 131_072,
        }];
        let bw = s.measure(&txns, 131_072);
        let eff = bw.efficiency(s.cfg());
        assert!(eff > 0.9, "long burst efficiency {eff}");
        assert!(eff <= 1.0 + 1e-9, "cannot beat the roofline: {eff}");
    }

    #[test]
    fn scattered_singletons_are_slow() {
        let mut s = sim();
        // 4096 single-element reads scattered across rows
        let txns: Vec<Txn> = (0..4096)
            .map(|i| Txn {
                dir: Dir::Read,
                addr: i * 1031, // stride past rows
                len: 1,
            })
            .collect();
        let bw = s.measure(&txns, 4096);
        let eff = bw.efficiency(s.cfg());
        assert!(eff < 0.3, "scattered reads should be slow, got {eff}");
    }

    #[test]
    fn longer_bursts_monotonically_better() {
        // same data volume, increasing burst length
        let total = 32_768u64;
        let mut prev = 0.0;
        for burst in [8u64, 64, 512, 4096] {
            let mut s = sim();
            let txns: Vec<Txn> = (0..total / burst)
                .map(|i| Txn {
                    dir: Dir::Read,
                    addr: i * burst * 3, // gaps → separate transactions
                    len: burst,
                })
                .collect();
            let bw = s.measure(&txns, total);
            let eff = bw.efficiency(s.cfg());
            assert!(
                eff >= prev - 0.02,
                "efficiency should improve with burst length: {burst} -> {eff} (prev {prev})"
            );
            prev = eff;
        }
        assert!(prev > 0.8);
    }

    #[test]
    fn boundary_and_length_segmentation() {
        let mut s = sim();
        // 600 elements * 8B = 4800B: crosses a 4KiB boundary → ≥2 bursts;
        // also > 256 beats → ≥3
        s.measure(
            &[Txn {
                dir: Dir::Read,
                addr: 0,
                len: 600,
            }],
            600,
        );
        assert!(s.timing().axi_bursts >= 3);
    }

    #[test]
    fn row_hits_tracked() {
        let mut s = sim();
        // two bursts in the same row: second is a hit
        s.run(&[
            Txn {
                dir: Dir::Read,
                addr: 0,
                len: 8,
            },
            Txn {
                dir: Dir::Read,
                addr: 16,
                len: 8,
            },
        ]);
        assert_eq!(s.timing().row_hits, 1);
        assert_eq!(s.timing().row_misses, 1);
    }

    #[test]
    fn turnaround_counted() {
        let mut s = sim();
        s.run(&[
            Txn {
                dir: Dir::Read,
                addr: 0,
                len: 8,
            },
            Txn {
                dir: Dir::Write,
                addr: 1024,
                len: 8,
            },
            Txn {
                dir: Dir::Write,
                addr: 2048,
                len: 8,
            },
        ]);
        assert_eq!(s.timing().turnarounds, 1);
    }

    #[test]
    fn mid_burst_row_crossings_are_switches_not_misses() {
        // AXI bursts never cross the 4 KiB boundary, so mid-burst row
        // crossings need rows smaller than the boundary
        let mut s = MemSim::new(MemConfig {
            row_bytes: 1024,
            ..MemConfig::default()
        });
        // 2 KiB contiguous read: one burst streaming across a 1 KiB row
        // boundary — exactly one first-beat classification (a miss), the
        // crossing counted as an in-burst switch
        s.run(&[Txn {
            dir: Dir::Read,
            addr: 0,
            len: 256,
        }]);
        let t = s.timing();
        assert_eq!(t.row_hits + t.row_misses, t.axi_bursts);
        assert!(t.row_switches > 0, "{t:?}");
    }

    #[test]
    fn snapshot_restore_replays_identically() {
        let mut s = sim();
        let wave1 = [
            Txn {
                dir: Dir::Read,
                addr: 0,
                len: 100,
            },
            Txn {
                dir: Dir::Write,
                addr: 5000,
                len: 40,
            },
        ];
        let wave2 = [Txn {
            dir: Dir::Read,
            addr: 123,
            len: 77,
        }];
        s.run(&wave1);
        let at_boundary = s.snapshot();
        s.run(&wave2);
        let first = (s.now(), s.timing().clone());
        // restore to the wave boundary and replay wave2: bit-identical
        s.restore(at_boundary);
        s.run(&wave2);
        assert_eq!((s.now(), s.timing().clone()), first);
    }

    #[test]
    fn reset_restores_time_zero() {
        let mut s = sim();
        s.run(&[Txn {
            dir: Dir::Read,
            addr: 0,
            len: 100,
        }]);
        assert!(s.now() > 0);
        s.reset();
        assert_eq!(s.now(), 0);
        assert_eq!(s.timing().axi_bursts, 0);
    }

    #[test]
    fn prop_conservation_laws() {
        prop_run("memsim conservation", Config::small(60), |g| {
            let mut s = sim();
            let n = g.usize(1, 20);
            let txns: Vec<Txn> = (0..n)
                .map(|_| Txn {
                    dir: if g.bool() { Dir::Read } else { Dir::Write },
                    addr: g.i64(0, 1 << 20) as u64,
                    len: g.i64(1, 2048) as u64,
                })
                .collect();
            let total: u64 = txns.iter().map(|t| t.len).sum();
            let bw = s.measure(&txns, total);
            // the bus moves one beat per cycle at most
            assert!(bw.cycles >= s.cfg().beats(total));
            // effective <= raw <= roofline
            assert!(bw.effective_mb_s(s.cfg()) <= bw.raw_mb_s(s.cfg()) + 1e-9);
            assert!(bw.raw_mb_s(s.cfg()) <= s.cfg().peak_mb_s() + 1e-9);
            // monotonic time
            assert_eq!(bw.cycles, s.now());
        });
    }

    #[test]
    fn prop_splitting_a_txn_never_helps() {
        prop_run("merged txn at least as fast", Config::small(40), |g| {
            let len = g.i64(2, 4096) as u64;
            let addr = g.i64(0, 1 << 16) as u64;
            let cut = g.i64(1, len as i64 - 1) as u64;
            let merged = [Txn {
                dir: Dir::Read,
                addr,
                len,
            }];
            let split = [
                Txn {
                    dir: Dir::Read,
                    addr,
                    len: cut,
                },
                Txn {
                    dir: Dir::Read,
                    addr: addr + cut,
                    len: len - cut,
                },
            ];
            let mut s1 = sim();
            let mut s2 = sim();
            let t_merged = s1.run(&merged);
            let t_split = s2.run(&split);
            assert!(
                t_merged <= t_split,
                "merged {t_merged} > split {t_split} (len {len}, cut {cut})"
            );
        });
    }
}
