//! Compiled transaction traces and the cross-point trace cache.
//!
//! A `cfa tune` point replays the burst transactions its (workload × space
//! box × tile × layout) *geometry* induces — and that stream is entirely
//! independent of the memory configuration and PE throughput the point
//! varies: [`MemConfig`](crate::memsim::MemConfig) only decides how the
//! stream splits into AXI bursts and how long they take at **replay**.
//! The explorer used to pay the full plan walk (region algebra →
//! `runs_of_box` → `merge_runs` → `Txn` list) for every point anyway.
//!
//! [`TxnTrace`] is the compiled form of that stream: flat
//! structure-of-arrays columns (`dir` / element address / element length,
//! one entry per planned burst run) plus the aggregate counters a timing
//! report needs (tiles, waves, raw/useful elements), built **once** from a
//! schedule's plans (`coordinator::batch::compile_trace`) and replayed any
//! number of times through [`MemSim::run_trace`](crate::memsim::MemSim::run_trace)
//! without reconstructing `Txn` values.
//!
//! [`TraceCache`] shares compiled traces across the points of a design
//! space: keyed by the geometry fingerprint, sharded behind mutexes so the
//! `dse` explorer's `parallel_map` workers contend only per shard, with
//! hit/miss counters for observability. A cache hit replays bit-identically
//! to a cold compile — the contract `tests/trace_replay.rs` pins down.

use crate::memsim::{Dir, Txn};
use crate::obs::metrics::{registry, Counter};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// A compiled, config-independent transaction trace in SoA form.
///
/// Entries are element-unit burst runs in exact replay order (waves in
/// schedule order, tiles lexicographic within a wave, reads before writes
/// per tile — the order `BatchCoordinator::run_timing` submits). The
/// aggregate fields carry the geometry facts a
/// [`Report`](crate::experiment::Report) needs beyond simulator counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TxnTrace {
    dirs: Vec<Dir>,
    addrs: Vec<u64>,
    lens: Vec<u64>,
    /// Tiles whose plans the trace contains.
    pub tiles: u64,
    /// Waves of the schedule the trace was compiled from.
    pub waves: usize,
    /// Raw elements moved (burst lengths summed, redundancy included).
    pub raw_elems: u64,
    /// Application-useful elements moved.
    pub useful_elems: u64,
    /// Fingerprint of the geometry the trace was compiled from (stamped by
    /// `Session::compile_trace`; empty for hand-built traces). Two
    /// same-shaped schedules over *different layouts* submit different
    /// streams with identical tile/wave counts, so consumers that accept
    /// foreign traces (`Session::run_trace`) compare this, not the counts.
    pub geometry: String,
}

impl TxnTrace {
    pub fn new() -> TxnTrace {
        TxnTrace::default()
    }

    /// An empty trace with room for `n` entries (the multi-channel
    /// pre-split allocates one per channel).
    pub fn with_capacity(n: usize) -> TxnTrace {
        TxnTrace {
            dirs: Vec::with_capacity(n),
            addrs: Vec::with_capacity(n),
            lens: Vec::with_capacity(n),
            ..TxnTrace::default()
        }
    }

    /// Append one burst run (element units).
    pub fn push(&mut self, dir: Dir, addr: u64, len: u64) {
        self.dirs.push(dir);
        self.addrs.push(addr);
        self.lens.push(len);
    }

    /// Number of burst-run entries.
    pub fn len(&self) -> usize {
        self.dirs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dirs.is_empty()
    }

    /// Total transactions (what a `BatchReport` counts).
    pub fn transactions(&self) -> u64 {
        self.dirs.len() as u64
    }

    /// Entry `i` as `(dir, element address, element length)`.
    #[inline]
    pub fn entry(&self, i: usize) -> (Dir, u64, u64) {
        (self.dirs[i], self.addrs[i], self.lens[i])
    }

    /// Iterate entries in replay order.
    pub fn iter(&self) -> impl Iterator<Item = (Dir, u64, u64)> + '_ {
        (0..self.len()).map(|i| self.entry(i))
    }

    /// Materialize the trace as a `Txn` list (tests and benches comparing
    /// against the scalar [`MemSim::run`](crate::memsim::MemSim::run)).
    pub fn txns(&self) -> Vec<Txn> {
        self.iter()
            .map(|(dir, addr, len)| Txn { dir, addr, len })
            .collect()
    }

    /// Total elements across all entries.
    pub fn total_elems(&self) -> u64 {
        self.lens.iter().sum()
    }
}

/// Point-in-time counters of a trace cache (or any [`TraceProvider`]).
/// `misses` counts actual compilations, so a provider that coalesces
/// concurrent same-key requests (the serve batcher) reports exactly one
/// miss per distinct geometry — the number a "zero recompiles" assertion
/// wants to read.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that compiled (one per cached entry under coalescing).
    pub misses: u64,
    /// Entries currently cached.
    pub entries: usize,
}

impl CacheStats {
    /// `{"entries": N, "hits": N, "misses": N}` for the daemon's `stats`
    /// reply and bench records.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("hits", Json::num(self.hits as f64)),
            ("misses", Json::num(self.misses as f64)),
            ("entries", Json::num(self.entries as f64)),
        ])
    }
}

/// Anything that can serve compiled traces by geometry key. Implemented by
/// [`TraceCache`] itself and by the serve batcher, which wraps a cache with
/// single-flight coalescing; the `dse` evaluator only talks to this trait,
/// so the daemon can route explorer compiles through its shared cache
/// without a `dse` → `serve` dependency.
///
/// Object-safe on purpose (`&mut dyn FnMut` rather than `impl FnOnce`):
/// callers hold an `Arc<dyn TraceProvider>`.
pub trait TraceProvider: Send + Sync {
    /// The trace for `key`, compiling it with `compile` when absent.
    fn get_or_compile_with(&self, key: &str, compile: &mut dyn FnMut() -> TxnTrace)
        -> Arc<TxnTrace>;

    /// Current hit/miss/entry counters.
    fn stats(&self) -> CacheStats;
}

/// Shard count of the [`TraceCache`] (power of two; bounds lock contention
/// between `parallel_map` workers compiling different geometries).
const SHARDS: usize = 16;

/// One cache shard: a mutex-guarded slice of the key space.
///
/// Shards survive a panicking holder instead of propagating the poison to
/// every later caller (the explorer quarantines panicking evaluations, so
/// the process keeps running). Recovery policy: poisoned shard = cleared
/// shard — the cache is a cache, so dropping its entries is always safe,
/// and the first post-panic caller does exactly that. The `std` mutex has
/// no `clear_poison` at our MSRV, so the flag makes the clear one-shot and
/// later lock attempts simply read through the (permanently set) poison
/// marker.
struct Shard {
    map: Mutex<HashMap<String, Arc<TxnTrace>>>,
    recovered: AtomicBool,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            map: Mutex::new(HashMap::new()),
            recovered: AtomicBool::new(false),
        }
    }

    /// Lock the shard, recovering from poison (see the type docs).
    fn lock(&self) -> MutexGuard<'_, HashMap<String, Arc<TxnTrace>>> {
        match self.map.lock() {
            Ok(guard) => guard,
            Err(poisoned) => {
                let mut guard = poisoned.into_inner();
                if !self.recovered.swap(true, Ordering::Relaxed) {
                    guard.clear();
                }
                guard
            }
        }
    }
}

/// A shared cache of compiled traces, keyed by geometry fingerprint.
///
/// Scope matters: keys are geometry fingerprints *within one design space*
/// (workload names resolve to one dependence pattern per space), so share a
/// cache across the points of one exploration, not across unrelated spaces.
/// Compilation runs outside the shard lock — two workers racing on the same
/// cold key may both compile, but the traces are identical and the first
/// insert wins, so results are deterministic either way.
pub struct TraceCache {
    shards: Vec<Shard>,
    /// Registry-backed counters (`cfa.trace_cache.{hits,misses}`): one
    /// fresh cell per cache instance, so instances count independently
    /// (private explorer caches vs the daemon's shared one) while the
    /// process-wide registry snapshot sums them.
    hits: Counter,
    misses: Counter,
}

impl TraceCache {
    pub fn new() -> TraceCache {
        TraceCache {
            shards: (0..SHARDS).map(|_| Shard::new()).collect(),
            hits: registry().counter("cfa.trace_cache.hits"),
            misses: registry().counter("cfa.trace_cache.misses"),
        }
    }

    fn shard(&self, key: &str) -> &Shard {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % SHARDS]
    }

    /// The cached trace for `key`, if present (counts as a hit).
    pub fn get(&self, key: &str) -> Option<Arc<TxnTrace>> {
        let found = self.shard(key).lock().get(key).cloned();
        if found.is_some() {
            self.hits.inc();
        } else {
            self.misses.inc();
        }
        found
    }

    /// The trace for `key`, compiling it with `compile` on a miss.
    /// Fault site: `trace::compile`; span site: `trace::compile` (the
    /// miss path only — hits are lock-lookup cheap and stay unspanned).
    pub fn get_or_compile(
        &self,
        key: &str,
        compile: impl FnOnce() -> TxnTrace,
    ) -> Arc<TxnTrace> {
        if let Some(t) = self.shard(key).lock().get(key) {
            self.hits.inc();
            return t.clone();
        }
        // compile outside the lock: a cold geometry must not block other
        // geometries that hash to the same shard
        let _span = crate::obs::span("trace::compile");
        crate::util::faults::check("trace::compile");
        let built = Arc::new(compile());
        self.misses.inc();
        let mut shard = self.shard(key).lock();
        shard.entry(key.to_string()).or_insert(built).clone()
    }

    /// Cache hits observed so far.
    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    /// Cache misses (compilations) observed so far.
    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    /// Number of cached traces.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached trace (counters keep accumulating).
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().clear();
        }
    }

    /// Snapshot of the hit/miss/entry counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits(),
            misses: self.misses(),
            entries: self.len(),
        }
    }
}

impl TraceProvider for TraceCache {
    fn get_or_compile_with(
        &self,
        key: &str,
        compile: &mut dyn FnMut() -> TxnTrace,
    ) -> Arc<TxnTrace> {
        self.get_or_compile(key, || compile())
    }

    fn stats(&self) -> CacheStats {
        TraceCache::stats(self)
    }
}

impl Default for TraceCache {
    fn default() -> TraceCache {
        TraceCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::par::parallel_map;

    fn sample_trace(n: u64) -> TxnTrace {
        let mut t = TxnTrace::new();
        for i in 0..n {
            let dir = if i % 3 == 0 { Dir::Write } else { Dir::Read };
            t.push(dir, i * 100, i + 1);
        }
        t.tiles = n;
        t.waves = 1;
        t.raw_elems = t.total_elems();
        t.useful_elems = t.total_elems();
        t
    }

    #[test]
    fn soa_round_trips_entries_in_order() {
        let t = sample_trace(7);
        assert_eq!(t.len(), 7);
        assert_eq!(t.transactions(), 7);
        assert!(!t.is_empty());
        for (i, (dir, addr, len)) in t.iter().enumerate() {
            assert_eq!(t.entry(i), (dir, addr, len));
            assert_eq!(addr, i as u64 * 100);
            assert_eq!(len, i as u64 + 1);
        }
        let txns = t.txns();
        assert_eq!(txns.len(), 7);
        assert_eq!(txns[3].dir, Dir::Write);
        assert_eq!(t.total_elems(), (1..=7).sum::<u64>());
    }

    #[test]
    fn cache_hits_and_misses_are_counted() {
        let cache = TraceCache::new();
        assert!(cache.is_empty());
        assert!(cache.get("k").is_none());
        assert_eq!(cache.misses(), 1);
        let a = cache.get_or_compile("k", || sample_trace(4));
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 0);
        let b = cache.get_or_compile("k", || panic!("must not recompile"));
        assert_eq!(cache.hits(), 1);
        assert_eq!(*a, *b);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn stats_snapshot_and_trait_object_agree() {
        let cache = TraceCache::new();
        cache.get_or_compile("k", || sample_trace(3));
        let _ = cache.get_or_compile("k", || panic!("cached"));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        // the trait object path shares counters with the inherent API
        let p: &dyn TraceProvider = &cache;
        let t = p.get_or_compile_with("k2", &mut || sample_trace(2));
        assert_eq!(*t, sample_trace(2));
        let s = TraceProvider::stats(p);
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 2));
        assert_eq!(
            s.to_json().to_string_compact(),
            r#"{"entries":2,"hits":1,"misses":2}"#
        );
    }

    #[test]
    fn poisoned_shard_recovers_as_a_cleared_shard() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let cache = TraceCache::new();
        cache.get_or_compile("k", || sample_trace(4));
        // a key in a different shard, to prove poison stays local
        let not_with_k =
            |i: &u64| !std::ptr::eq(cache.shard(&format!("other{i}")), cache.shard("k"));
        let other = format!("other{}", (0..).find(not_with_k).unwrap());
        cache.get_or_compile(&other, || sample_trace(2));
        assert_eq!(cache.len(), 2);
        // poison the shard holding "k": panic while holding its guard
        let unwound = catch_unwind(AssertUnwindSafe(|| {
            let _guard = cache.shard("k").lock();
            panic!("poisoning panic");
        }));
        assert!(unwound.is_err());
        assert!(cache.shard("k").map.is_poisoned());
        // recovery: the poisoned shard comes back cleared and refills;
        // the sibling shard is untouched
        assert!(cache.get("k").is_none(), "poisoned shard must be cleared");
        let t = cache.get_or_compile("k", || sample_trace(4));
        assert_eq!(*t, sample_trace(4));
        let o = cache.get_or_compile(&other, || panic!("cached"));
        assert_eq!(*o, sample_trace(2));
        assert_eq!(cache.len(), 2);
        // repeated use of the once-poisoned shard keeps its contents now
        assert!(cache.get("k").is_some());
    }

    #[test]
    fn concurrent_get_or_compile_is_deterministic() {
        // many workers racing on few keys: every returned trace equals the
        // single-threaded compile, and the cache ends with one entry per key
        let cache = TraceCache::new();
        let items: Vec<u64> = (0..64).collect();
        let out = parallel_map(&items, 8, |&i| {
            let n = i % 4 + 1;
            let key = format!("geom{n}");
            cache.get_or_compile(&key, || sample_trace(n))
        });
        for (i, t) in items.iter().zip(&out) {
            assert_eq!(**t, sample_trace(i % 4 + 1));
        }
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.hits() + cache.misses(), 64);
        // every key misses at least once; racing workers may compile a cold
        // key more than once (first insert wins), but never after it lands
        assert!(cache.misses() >= 4, "misses {}", cache.misses());
    }
}
