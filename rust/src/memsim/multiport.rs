//! Multi-channel "memory wall" model (§VII future work): "the machine
//! model we have considered may be extended to multi-port memory accesses,
//! such as high-bandwidth memory … one has to find an adequate repartition
//! of data over each memory port to balance accesses."
//!
//! [`MultiPortSim`] aggregates N channels, each a full independent
//! [`MemSim`] controller with its own open rows, in-flight window,
//! turnaround state and clocks. Two knobs decide what N channels buy:
//!
//! * a [`PortMap`] routes each element run to a channel, derived from a
//!   first-class [`Striping`] policy —
//!   [`Striping::Address`] (fixed-granularity address interleave, what a
//!   controller does to an unmodified layout), [`Striping::Facet`] (one
//!   contiguous allocation region — for CFA, one facet array — per
//!   channel, the balanced repartition the paper anticipates) and
//!   [`Striping::Tile`] (per-tile chunks of every region round-robined
//!   across channels);
//! * [`MemConfig::cmd_shared_cycles`] models the *shared command path*:
//!   each extra channel adds that many arbitration cycles to every
//!   burst's address phase, so bandwidth stops scaling linearly — the
//!   "memory controller wall" effect.
//!
//! Compiled [`TxnTrace`]s replay across channels in parallel: one routing
//! pass pre-splits the SoA columns into per-channel sub-traces
//! ([`MultiPortSim::split_trace`]), then
//! [`parallel_map`](crate::util::par::parallel_map) replays each through
//! its channel's coalesced kernel — bit-identical to entry-wise
//! [`MultiPortSim::submit`] (pinned by `tests/multichannel.rs`).
//!
//! Stripes are defined in **element units** end-to-end: splitting and
//! routing use the same granularity, so a run chunk never straddles a
//! stripe it wasn't charged to. [`Striping::validate`] rejects byte
//! stripes that don't fall on element boundaries at every front door.

use crate::memsim::{Bandwidth, MemConfig, MemSim, ReplayState, Timing, Txn, TxnTrace};
use crate::obs::Timeline;
use crate::util::par::parallel_map;
use anyhow::bail;

/// Interleaving policy: how element addresses spread over channels.
///
/// `Facet` and `Tile` are computed from the *allocation* (via
/// [`Striping::resolve`]), generalizing [`cfa_port_map`] to every
/// registered layout: any allocation exposes its contiguous storage
/// regions through [`Allocation::regions`](crate::layout::Allocation::regions).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Striping {
    /// Fixed-granularity address interleave: stripe `s` lives on channel
    /// `s % channels`. `stripe_bytes` must be a positive multiple of the
    /// element size.
    Address { stripe_bytes: u64 },
    /// One allocation region (CFA: one facet array) per channel;
    /// consecutive regions share a channel when there are more regions
    /// than channels, and surplus channels stay idle (disengaged).
    Facet,
    /// Per-tile chunks of each allocation region round-robined across
    /// channels: tile `t` of a region lives on channel `t % channels`.
    Tile,
}

impl Default for Striping {
    fn default() -> Striping {
        Striping::Address { stripe_bytes: 4096 }
    }
}

impl Striping {
    /// Parse `"address[:BYTES]"` (alias `"addr"`; default 4096), `"facet"`
    /// or `"tile"`.
    pub fn parse(s: &str) -> anyhow::Result<Striping> {
        let s = s.trim();
        match s {
            "facet" => Ok(Striping::Facet),
            "tile" => Ok(Striping::Tile),
            _ => {
                let rest = s
                    .strip_prefix("address")
                    .or_else(|| s.strip_prefix("addr"))
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown striping '{s}' (expected address[:BYTES], facet or tile)"
                        )
                    })?;
                // bare "address" defaults to 4096; "address:N" and the
                // label form "addrN" both name an explicit stripe
                let stripe_bytes = if rest.is_empty() {
                    4096
                } else {
                    let n = rest.strip_prefix(':').unwrap_or(rest).trim();
                    n.parse::<u64>().map_err(|_| {
                        anyhow::anyhow!("striping '{s}': '{n}' is not a byte count")
                    })?
                };
                Ok(Striping::Address { stripe_bytes })
            }
        }
    }

    /// Short stable label (fingerprints, journals, reports).
    pub fn label(&self) -> String {
        match self {
            Striping::Address { stripe_bytes } => format!("addr{stripe_bytes}"),
            Striping::Facet => "facet".into(),
            Striping::Tile => "tile".into(),
        }
    }

    /// Reject stripes that don't fall on element boundaries. Splitting
    /// and routing both work in element units, so a byte stripe that is
    /// not a multiple of `elem_bytes` cannot be honored exactly — it is
    /// an error at every front door (space parser, CLI, `compile`), not a
    /// silently rounded approximation.
    pub fn validate(&self, elem_bytes: u64) -> anyhow::Result<()> {
        if let Striping::Address { stripe_bytes } = self {
            if *stripe_bytes == 0 {
                bail!("striping stripe_bytes must be nonzero");
            }
            if elem_bytes > 0 && stripe_bytes % elem_bytes != 0 {
                bail!(
                    "striping stripe_bytes ({stripe_bytes}) must be a multiple of \
                     elem_bytes ({elem_bytes}) so stripes fall on element boundaries"
                );
            }
        }
        Ok(())
    }

    /// Concretize the policy into a [`PortMap`] for one allocation.
    pub fn resolve(
        &self,
        alloc: &dyn crate::layout::Allocation,
        elem_bytes: u64,
        channels: usize,
    ) -> anyhow::Result<PortMap> {
        self.validate(elem_bytes)?;
        Ok(match self {
            Striping::Address { stripe_bytes } => PortMap::Interleaved {
                stripe_elems: (stripe_bytes / elem_bytes.max(1)).max(1),
            },
            Striping::Facet => {
                let bases: Vec<u64> = alloc.regions().iter().map(|&(b, _)| b).collect();
                PortMap::by_regions(&bases, channels)
            }
            Striping::Tile => {
                let tiles = alloc.tiling().num_tiles().max(1);
                let regions = alloc
                    .regions()
                    .iter()
                    .map(|&(base, elems)| (base, (elems / tiles).max(1)))
                    .collect();
                PortMap::TileStriped { regions }
            }
        })
    }
}

impl std::fmt::Display for Striping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// Transaction-to-port routing, in **element units** end-to-end: the same
/// granularity splits runs ([`PortMap::span_of`]) and routes the pieces
/// ([`PortMap::port_of`]), so every beat of a chunk is charged to the
/// channel that serves it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PortMap {
    /// `port = (elem_addr / stripe_elems) % ports`.
    Interleaved { stripe_elems: u64 },
    /// Half-open element-address ranges: port `p` serves
    /// `[bounds[p], bounds[p+1])`, the last bound extending to infinity.
    /// Bounds are **strictly increasing**; when a map engages fewer
    /// ranges than the interface has channels, trailing channels are
    /// disengaged (no traffic, excluded from [`MultiPortSim::imbalance`]).
    ByRange { bounds: Vec<u64> },
    /// Ascending `(base, chunk_elems)` regions; within the region starting
    /// at `base`, chunk `c = (addr - base) / chunk_elems` lives on port
    /// `c % ports`. The last region extends to infinity.
    TileStriped { regions: Vec<(u64, u64)> },
}

impl PortMap {
    /// Range map with one region per port, consecutive regions sharing a
    /// port when `bases.len() > ports`. Effective ports are clamped to
    /// the region count so the bounds are strictly increasing — trailing
    /// ports of a wider interface simply stay disengaged (the
    /// `ports > facets` duplicate-bounds bug made the last region's
    /// routing depend on `binary_search`'s unspecified choice).
    pub fn by_regions(bases: &[u64], ports: usize) -> PortMap {
        assert!(!bases.is_empty(), "by_regions needs at least one region");
        assert!(ports >= 1);
        let eff0 = ports.min(bases.len());
        let per = bases.len().div_ceil(eff0);
        // recompute: len=4, ports=3 gives per=2 and only 2 engaged ports
        let eff = bases.len().div_ceil(per);
        let bounds = (0..eff)
            .map(|p| if p == 0 { 0 } else { bases[p * per] })
            .collect();
        PortMap::ByRange { bounds }
    }

    /// Port index for an element address.
    pub fn port_of(&self, addr: u64, ports: usize) -> usize {
        match self {
            PortMap::Interleaved { stripe_elems } => {
                ((addr / (*stripe_elems).max(1)) % ports as u64) as usize
            }
            PortMap::ByRange { bounds } => {
                let last = bounds.len().min(ports).max(1) - 1;
                match bounds.binary_search(&addr) {
                    Ok(i) => i.min(last),
                    Err(0) => 0,
                    Err(i) => (i - 1).min(last),
                }
            }
            PortMap::TileStriped { regions } => {
                let (base, chunk) = Self::region_of(regions, addr);
                ((addr.saturating_sub(base) / chunk.max(1)) % ports as u64) as usize
            }
        }
    }

    /// Longest contiguous element span starting at `addr` that stays on
    /// one port (the split granularity of [`MultiPortSim::submit`]).
    /// Always >= 1.
    pub fn span_of(&self, addr: u64) -> u64 {
        match self {
            PortMap::Interleaved { stripe_elems } => {
                let s = (*stripe_elems).max(1);
                s - addr % s
            }
            PortMap::ByRange { bounds } => match bounds.iter().find(|&&b| b > addr) {
                Some(next) => next - addr,
                None => u64::MAX,
            },
            PortMap::TileStriped { regions } => {
                let (base, chunk) = Self::region_of(regions, addr);
                let chunk = chunk.max(1);
                let off = addr.saturating_sub(base);
                let in_chunk = chunk - off % chunk;
                match regions.iter().find(|&&(b, _)| b > addr) {
                    Some(&(next, _)) => in_chunk.min(next - addr),
                    None => in_chunk,
                }
            }
        }
    }

    /// Channels this map can ever route to, out of `ports`. Address and
    /// tile striping engage every channel; a range map engages one per
    /// bound.
    pub fn engaged(&self, ports: usize) -> usize {
        match self {
            PortMap::ByRange { bounds } => bounds.len().min(ports).max(1),
            _ => ports,
        }
    }

    fn region_of(regions: &[(u64, u64)], addr: u64) -> (u64, u64) {
        let i = regions.partition_point(|&(b, _)| b <= addr);
        regions[i.saturating_sub(1).min(regions.len() - 1)]
    }
}

/// N-channel memory interface: independent per-channel controllers behind
/// one routing map, with the shared-command-path contention of
/// [`MemConfig::cmd_shared_cycles`] folded into each channel's issue cost.
pub struct MultiPortSim {
    channels: Vec<MemSim>,
    map: PortMap,
    elem_bytes: u64,
    submitted_elems: u64,
}

impl MultiPortSim {
    /// `ports` channels of `cfg`. Each channel's address phase pays
    /// `cmd_shared_cycles` extra per additional channel (the shared
    /// command path serializes that much arbitration work per burst); a
    /// single-port interface is exactly [`MemSim`], whatever the knob.
    /// The adjustment happens **before** [`MemSim::new`] so the streaming
    /// kernel's closed form derives from the effective config.
    pub fn new(cfg: MemConfig, ports: usize, map: PortMap) -> MultiPortSim {
        assert!(ports >= 1, "a memory interface needs at least one port");
        let elem_bytes = cfg.elem_bytes;
        let mut chan_cfg = cfg;
        chan_cfg.issue_cycles += chan_cfg.cmd_shared_cycles * (ports as u64 - 1);
        MultiPortSim {
            channels: (0..ports).map(|_| MemSim::new(chan_cfg.clone())).collect(),
            map,
            elem_bytes,
            submitted_elems: 0,
        }
    }

    pub fn ports(&self) -> usize {
        self.channels.len()
    }

    pub fn map(&self) -> &PortMap {
        &self.map
    }

    /// Submit a transaction, splitting it at port boundaries
    /// ([`PortMap::span_of`]) so every piece lands whole on the channel
    /// that serves it. A single-port interface forwards unsplit.
    pub fn submit(&mut self, txn: &Txn) {
        self.submitted_elems += txn.len;
        let ports = self.channels.len();
        if ports == 1 {
            self.channels[0].submit(txn);
            return;
        }
        let mut addr = txn.addr;
        let mut remaining = txn.len;
        while remaining > 0 {
            let chunk = remaining.min(self.map.span_of(addr));
            let p = self.map.port_of(addr, ports);
            self.channels[p].submit(&Txn {
                dir: txn.dir,
                addr,
                len: chunk,
            });
            addr += chunk;
            remaining -= chunk;
        }
    }

    /// Route a compiled trace into per-channel sub-traces in one pass
    /// over the SoA columns — the same split [`MultiPortSim::submit`]
    /// performs, so replaying sub-trace `p` through channel `p` is
    /// bit-identical to entry-wise submission (order within a channel is
    /// preserved; cross-channel order is irrelevant, the controllers are
    /// independent).
    pub fn split_trace(&self, trace: &TxnTrace) -> Vec<TxnTrace> {
        let ports = self.channels.len();
        let mut subs: Vec<TxnTrace> = (0..ports)
            .map(|_| TxnTrace::with_capacity(trace.len() / ports + 1))
            .collect();
        for (dir, mut addr, mut remaining) in trace.iter() {
            if ports == 1 {
                subs[0].push(dir, addr, remaining);
                continue;
            }
            while remaining > 0 {
                let chunk = remaining.min(self.map.span_of(addr));
                subs[self.map.port_of(addr, ports)].push(dir, addr, chunk);
                addr += chunk;
                remaining -= chunk;
            }
        }
        subs
    }

    /// Replay a compiled [`TxnTrace`] entry by entry (the scalar
    /// reference path). Returns the completion time.
    pub fn run_trace(&mut self, trace: &TxnTrace) -> u64 {
        for (dir, addr, len) in trace.iter() {
            self.submit(&Txn { dir, addr, len });
        }
        self.now()
    }

    /// Replay a compiled trace with one routing pass and `threads`-way
    /// parallel per-channel replay (each sub-trace takes its channel's
    /// coalesced streaming kernel). Bit-identical to [`run_trace`]
    /// (`tests/multichannel.rs` pins the full per-channel `ReplayState`).
    ///
    /// [`run_trace`]: MultiPortSim::run_trace
    pub fn run_trace_parallel(&mut self, trace: &TxnTrace, threads: usize) -> u64 {
        let _span = crate::obs::span("memsim::replay_parallel");
        self.submitted_elems += trace.total_elems();
        let subs = self.split_trace(trace);
        let items: Vec<(MemSim, TxnTrace)> =
            std::mem::take(&mut self.channels).into_iter().zip(subs).collect();
        self.channels = parallel_map(&items, threads, |(sim, sub)| {
            let mut sim = sim.clone();
            sim.run_trace(sub);
            sim
        });
        self.now()
    }

    /// Completion time = the slowest channel (they run concurrently).
    pub fn now(&self) -> u64 {
        self.channels.iter().map(|c| c.now()).max().unwrap_or(0)
    }

    /// Per-channel busy report (balance diagnostics).
    pub fn channel_times(&self) -> Vec<u64> {
        self.channels.iter().map(|c| c.now()).collect()
    }

    /// Per-channel timing counters. The engine's accounting identities
    /// (`row_hits + row_misses == axi_bursts`, …) hold on every port
    /// independently — pinned by `tests/memsim_identities.rs`.
    pub fn timings(&self) -> Vec<&Timing> {
        self.channels.iter().map(|c| c.timing()).collect()
    }

    /// Per-channel replay state (bit-for-bit identity tests).
    pub fn channel_snapshots(&self) -> Vec<ReplayState> {
        self.channels.iter().map(|c| c.snapshot()).collect()
    }

    /// Attach a bandwidth timeline sampler to every channel (see
    /// [`MemSim::set_sampler`]). Samplers ride along the pre-split
    /// parallel replay because [`MultiPortSim::run_trace_parallel`]
    /// keeps the mutated per-channel clones — so the parallel timeline
    /// is bit-identical to the entry-wise serial one.
    pub fn set_sampler(&mut self, epoch_cycles: u64) {
        for c in &mut self.channels {
            c.set_sampler(epoch_cycles);
        }
    }

    /// Harvest the per-channel samplers into one [`Timeline`] (empty
    /// channel lists for channels that saw no traffic). `None` when no
    /// sampler was attached.
    pub fn timeline(&self) -> Option<Timeline> {
        let epoch_cycles = self.channels.first()?.sampler()?.epoch_cycles();
        Some(Timeline {
            epoch_cycles,
            channels: self
                .channels
                .iter()
                .map(|c| {
                    c.sampler()
                        .map(|s| s.epochs().to_vec())
                        .unwrap_or_default()
                })
                .collect(),
        })
    }

    /// Cross-channel aggregate: counters summed, `cycles` the slowest
    /// channel.
    pub fn aggregate_timing(&self) -> Timing {
        Timing::merge(self.channels.iter().map(|c| c.timing()))
    }

    /// Cross-channel [`Bandwidth`]: bytes summed over channels, cycles
    /// from the slowest — the number a multi-channel roofline compares
    /// against `channels * peak_mb_s`.
    pub fn bandwidth(&self, useful_elems: u64) -> Bandwidth {
        let t = self.aggregate_timing();
        Bandwidth {
            raw_bytes: self.submitted_elems * self.elem_bytes,
            useful_bytes: useful_elems * self.elem_bytes,
            cycles: self.now(),
            bursts: t.axi_bursts,
            row_misses: t.row_misses + t.row_switches,
        }
    }

    /// Load imbalance over **engaged** channels: max time / mean time
    /// (1.0 = ideal). Channels a range map cannot route to are excluded —
    /// counting structurally idle channels made a perfectly balanced
    /// facet map on a wide interface look pathological.
    pub fn imbalance(&self) -> f64 {
        let engaged = self.map.engaged(self.channels.len());
        let times = &self.channel_times()[..engaged];
        let max = *times.iter().max().unwrap_or(&0) as f64;
        let mean = times.iter().sum::<u64>() as f64 / times.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    pub fn reset(&mut self) {
        for c in &mut self.channels {
            c.reset();
        }
        self.submitted_elems = 0;
    }
}

/// The facet-per-port repartition for a CFA allocation: port boundaries
/// at the facet arrays' base addresses (see [`PortMap::by_regions`] for
/// the `ports != facets` semantics). Equivalent to resolving
/// [`Striping::Facet`] against the allocation.
pub fn cfa_port_map(cfa: &crate::layout::cfa::Cfa, ports: usize) -> PortMap {
    let bases: Vec<u64> = cfa.facet_arrays().iter().map(|f| f.base).collect();
    PortMap::by_regions(&bases, ports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::Dir;

    fn cfg() -> MemConfig {
        MemConfig::default()
    }

    fn test_cfa() -> crate::layout::cfa::Cfa {
        use crate::poly::deps::DepPattern;
        use crate::poly::tiling::Tiling;
        let tiling = Tiling::new(vec![24, 24, 24], vec![8, 8, 8]);
        let deps =
            DepPattern::new(vec![vec![-1, 0, 0], vec![0, -1, 0], vec![0, 0, -2]]).unwrap();
        crate::layout::cfa::Cfa::new(tiling, deps).unwrap()
    }

    #[test]
    fn single_port_equals_memsim() {
        let txns: Vec<Txn> = (0..32)
            .map(|i| Txn {
                dir: Dir::Read,
                addr: i * 100,
                len: 64,
            })
            .collect();
        let mut single = MemSim::new(cfg());
        let t_ref = single.run(&txns);
        let mut mp =
            MultiPortSim::new(cfg(), 1, PortMap::Interleaved { stripe_elems: 512 });
        for t in &txns {
            mp.submit(t);
        }
        assert_eq!(mp.now(), t_ref);
    }

    #[test]
    fn range_map_routes_and_scales() {
        // two disjoint streams on two ports finish in about half the time
        let stream = |base: u64| -> Vec<Txn> {
            (0..64)
                .map(|i| Txn {
                    dir: Dir::Read,
                    addr: base + i * 1024,
                    len: 1024,
                })
                .collect()
        };
        let all: Vec<Txn> = stream(0).into_iter().chain(stream(1 << 24)).collect();
        let mut one = MultiPortSim::new(cfg(), 1, PortMap::ByRange { bounds: vec![0] });
        for t in &all {
            one.submit(t);
        }
        let mut two = MultiPortSim::new(
            cfg(),
            2,
            PortMap::ByRange {
                bounds: vec![0, 1 << 24],
            },
        );
        for t in &all {
            two.submit(t);
        }
        let speedup = one.now() as f64 / two.now() as f64;
        assert!(speedup > 1.8, "speedup {speedup}");
        assert!(two.imbalance() < 1.1);
    }

    #[test]
    fn trace_replay_equals_txn_replay_per_port() {
        let txns: Vec<Txn> = (0..48)
            .map(|i| Txn {
                dir: if i % 4 == 0 { Dir::Write } else { Dir::Read },
                addr: i * 713,
                len: 96,
            })
            .collect();
        let mut trace = TxnTrace::new();
        for t in &txns {
            trace.push(t.dir, t.addr, t.len);
        }
        let map = || PortMap::Interleaved { stripe_elems: 64 };
        let mut by_txn = MultiPortSim::new(cfg(), 3, map());
        for t in &txns {
            by_txn.submit(t);
        }
        let mut by_trace = MultiPortSim::new(cfg(), 3, map());
        by_trace.run_trace(&trace);
        assert_eq!(by_txn.now(), by_trace.now());
        assert_eq!(by_txn.channel_times(), by_trace.channel_times());
        for (a, b) in by_txn.timings().iter().zip(by_trace.timings()) {
            assert_eq!(*a, b);
        }
        // and the pre-split parallel replay matches both, snapshots included
        let mut pre_split = MultiPortSim::new(cfg(), 3, map());
        pre_split.run_trace_parallel(&trace, 3);
        assert_eq!(pre_split.channel_snapshots(), by_trace.channel_snapshots());
        assert_eq!(pre_split.bandwidth(0).raw_bytes, by_trace.bandwidth(0).raw_bytes);
    }

    #[test]
    fn timelines_are_identical_across_serial_and_parallel_replay() {
        let mut trace = TxnTrace::new();
        for i in 0..96u64 {
            trace.push(
                if i % 3 == 0 { Dir::Write } else { Dir::Read },
                i * 511,
                1 + (i * 73) % 900,
            );
        }
        let map = || PortMap::Interleaved { stripe_elems: 128 };
        let mut serial = MultiPortSim::new(cfg(), 3, map());
        serial.set_sampler(512);
        serial.run_trace(&trace);
        let mut par = MultiPortSim::new(cfg(), 3, map());
        par.set_sampler(512);
        par.run_trace_parallel(&trace, 3);
        let tl_serial = serial.timeline().expect("sampler attached");
        let tl_par = par.timeline().expect("samplers survive parallel replay");
        assert_eq!(tl_serial, tl_par, "timeline is replay-path independent");
        assert!(
            tl_serial.matches(&serial.aggregate_timing()),
            "epochs sum to the aggregate counters"
        );
        assert_eq!(tl_serial.channels.len(), 3);
        assert!(tl_serial.imbalance() >= 1.0);
        // unsampled run: same channel states bit for bit
        let mut plain = MultiPortSim::new(cfg(), 3, map());
        plain.run_trace_parallel(&trace, 3);
        assert_eq!(plain.channel_snapshots(), par.channel_snapshots());
        assert!(plain.timeline().is_none());
    }

    #[test]
    fn interleaved_splits_at_stripes() {
        let mut mp =
            MultiPortSim::new(cfg(), 2, PortMap::Interleaved { stripe_elems: 32 });
        // 64 elems across two 32-element stripes -> both channels busy
        mp.submit(&Txn {
            dir: Dir::Read,
            addr: 0,
            len: 64,
        });
        let times = mp.channel_times();
        assert!(times.iter().all(|&t| t > 0), "{times:?}");
    }

    #[test]
    fn unaligned_byte_stripes_are_rejected() {
        // regression (routing bug 1): stripe_bytes 12 over 8-byte elements
        // used to split runs at 1-element stripes but route them at byte
        // granularity, charging straddling chunks to the wrong channel.
        // Now the config is refused wherever a striping enters.
        let s = Striping::Address { stripe_bytes: 12 };
        let err = s.validate(8).unwrap_err().to_string();
        assert!(err.contains("stripe_bytes"), "{err}");
        let cfa = test_cfa();
        assert!(s.resolve(&cfa, 8, 2).is_err());
        assert!(Striping::Address { stripe_bytes: 0 }.validate(8).is_err());
        // aligned stripes resolve to the element-unit interleave
        let map = Striping::Address { stripe_bytes: 4096 }.resolve(&cfa, 8, 2).unwrap();
        assert_eq!(map, PortMap::Interleaved { stripe_elems: 512 });
    }

    #[test]
    fn split_chunks_never_straddle_a_port() {
        // every chunk split_trace emits must live whole on its channel:
        // first and last element route identically
        let mut trace = TxnTrace::new();
        for i in 0..64u64 {
            trace.push(Dir::Read, i * 97, 1 + (i * 37) % 300);
        }
        for map in [
            PortMap::Interleaved { stripe_elems: 7 },
            PortMap::ByRange {
                bounds: vec![0, 500, 3000],
            },
            PortMap::TileStriped {
                regions: vec![(0, 64), (2048, 100)],
            },
        ] {
            let mp = MultiPortSim::new(cfg(), 3, map.clone());
            let subs = mp.split_trace(&trace);
            let mut elems = 0u64;
            for (p, sub) in subs.iter().enumerate() {
                for (_, addr, len) in sub.iter() {
                    assert_eq!(map.port_of(addr, 3), p, "{map:?}");
                    assert_eq!(map.port_of(addr + len - 1, 3), p, "{map:?}");
                    elems += len;
                }
            }
            assert_eq!(elems, trace.total_elems(), "{map:?}");
        }
    }

    #[test]
    fn port_of_range_boundaries() {
        let m = PortMap::ByRange {
            bounds: vec![0, 100, 200],
        };
        assert_eq!(m.port_of(0, 3), 0);
        assert_eq!(m.port_of(99, 3), 0);
        assert_eq!(m.port_of(100, 3), 1);
        assert_eq!(m.port_of(250, 3), 2);
        assert_eq!(m.span_of(40), 60);
        assert_eq!(m.span_of(100), 100);
        assert_eq!(m.span_of(250), u64::MAX);
    }

    #[test]
    fn tile_striping_round_robins_chunks() {
        let m = PortMap::TileStriped {
            regions: vec![(0, 10), (100, 25)],
        };
        assert_eq!(m.port_of(0, 2), 0);
        assert_eq!(m.port_of(10, 2), 1);
        assert_eq!(m.port_of(20, 2), 0);
        assert_eq!(m.span_of(5), 5);
        assert_eq!(m.span_of(95), 5); // clipped at the next region base
        assert_eq!(m.port_of(100, 2), 0);
        assert_eq!(m.port_of(125, 2), 1);
        assert_eq!(m.span_of(130), 20);
    }

    #[test]
    fn cfa_map_assigns_facets_to_ports() {
        let cfa = test_cfa();
        let map = cfa_port_map(&cfa, 3);
        let facets = cfa.facet_arrays();
        for (i, fa) in facets.iter().enumerate() {
            assert_eq!(map.port_of(fa.base, 3), i, "facet {i}");
            assert_eq!(map.port_of(fa.base + fa.size() - 1, 3), i);
        }
        // Striping::Facet resolves to the same map
        let resolved = Striping::Facet.resolve(&cfa, 8, 3).unwrap();
        assert_eq!(resolved, map);
    }

    #[test]
    fn more_ports_than_facets_keeps_bounds_strict_and_imbalance_engaged() {
        // regression (routing bug 2): ports > facets used to duplicate
        // bounds, making the last facet's port unspecified and the idle
        // trailing ports drag imbalance() down
        let cfa = test_cfa();
        let facets = cfa.facet_arrays();
        assert_eq!(facets.len(), 3);
        let map = cfa_port_map(&cfa, 5);
        let PortMap::ByRange { bounds } = &map else {
            panic!("cfa map must be ByRange")
        };
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "{bounds:?}");
        assert_eq!(bounds.len(), 3, "effective ports clamp to facet count");
        assert_eq!(map.engaged(5), 3);
        // every facet routes whole to its own engaged port
        let mut mp = MultiPortSim::new(cfg(), 5, map.clone());
        for (i, fa) in facets.iter().enumerate() {
            assert_eq!(map.port_of(fa.base, 5), i);
            assert_eq!(map.port_of(fa.base + fa.size() - 1, 5), i);
            mp.submit(&Txn {
                dir: Dir::Read,
                addr: fa.base,
                len: fa.size().min(4096),
            });
        }
        let times = mp.channel_times();
        assert!(times[..3].iter().all(|&t| t > 0), "{times:?}");
        assert!(times[3..].iter().all(|&t| t == 0), "{times:?}");
        // balanced over engaged channels despite two idle ones
        assert!(mp.imbalance() < 1.5, "imbalance {}", mp.imbalance());
    }

    #[test]
    fn by_regions_bounds_always_strictly_increase() {
        for len in 1..8usize {
            let bases: Vec<u64> = (0..len as u64).map(|i| i * 1000).collect();
            for ports in 1..10usize {
                let PortMap::ByRange { bounds } = PortMap::by_regions(&bases, ports)
                else {
                    unreachable!()
                };
                assert!(
                    bounds.windows(2).all(|w| w[0] < w[1]),
                    "len={len} ports={ports}: {bounds:?}"
                );
                assert!(bounds.len() <= ports.min(len));
                assert!(!bounds.is_empty());
            }
        }
    }

    #[test]
    fn shared_command_path_throttles_scaling_but_not_one_port() {
        let base = MemConfig::default();
        let contended = MemConfig {
            cmd_shared_cycles: 6,
            ..MemConfig::default()
        };
        let txns: Vec<Txn> = (0..64)
            .map(|i| Txn {
                dir: Dir::Read,
                addr: i * 40,
                len: 24,
            })
            .collect();
        // one port: the knob is inert (no other channel to arbitrate with)
        let mut serial = MemSim::new(base.clone());
        serial.run(&txns);
        let map = || PortMap::Interleaved { stripe_elems: 16 };
        let mut one = MultiPortSim::new(contended.clone(), 1, map());
        for t in &txns {
            one.submit(t);
        }
        assert_eq!(one.now(), serial.now());
        assert_eq!(one.timings()[0], serial.timing());
        // four ports: contention makes every burst's issue phase dearer
        let mut free = MultiPortSim::new(base, 4, map());
        let mut walled = MultiPortSim::new(contended, 4, map());
        for t in &txns {
            free.submit(t);
            walled.submit(t);
        }
        assert!(walled.now() > free.now(), "{} <= {}", walled.now(), free.now());
    }

    #[test]
    fn aggregate_timing_and_bandwidth_sum_channels() {
        let mut mp = MultiPortSim::new(cfg(), 2, PortMap::Interleaved { stripe_elems: 8 });
        mp.submit(&Txn {
            dir: Dir::Read,
            addr: 0,
            len: 100,
        });
        let agg = mp.aggregate_timing();
        let per = mp.timings();
        assert_eq!(agg.cycles, mp.now());
        assert_eq!(agg.data_cycles, per[0].data_cycles + per[1].data_cycles);
        assert_eq!(agg.axi_bursts, per[0].axi_bursts + per[1].axi_bursts);
        let bw = mp.bandwidth(100);
        assert_eq!(bw.raw_bytes, 100 * 8);
        assert_eq!(bw.useful_bytes, 100 * 8);
        assert_eq!(bw.cycles, mp.now());
        assert_eq!(bw.bursts, agg.axi_bursts);
        mp.reset();
        assert_eq!(mp.bandwidth(0).raw_bytes, 0);
        assert_eq!(mp.now(), 0);
    }

    #[test]
    fn striping_parse_label_round_trip() {
        for (s, want) in [
            ("address:4096", Striping::Address { stripe_bytes: 4096 }),
            ("addr:256", Striping::Address { stripe_bytes: 256 }),
            ("address", Striping::Address { stripe_bytes: 4096 }),
            ("addr256", Striping::Address { stripe_bytes: 256 }),
            ("facet", Striping::Facet),
            ("tile", Striping::Tile),
        ] {
            assert_eq!(Striping::parse(s).unwrap(), want, "{s}");
        }
        assert_eq!(Striping::parse("addr:256").unwrap().label(), "addr256");
        assert_eq!(Striping::parse("facet").unwrap().label(), "facet");
        assert!(Striping::parse("diagonal").is_err());
        assert!(Striping::parse("address:x").is_err());
    }
}
