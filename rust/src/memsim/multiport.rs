//! Multi-port memory extension (§VII future work): "the machine model we
//! have considered may be extended to multi-port memory accesses, such as
//! high-bandwidth memory … one has to find an adequate repartition of data
//! over each memory port to balance accesses."
//!
//! [`MultiPortSim`] aggregates N independent AXI channels (each its own
//! [`MemSim`]); a [`PortMap`] decides which channel serves each
//! transaction:
//!
//! * [`PortMap::Interleaved`] — address-striped at a fixed granularity
//!   (what a memory controller does to an unmodified layout);
//! * [`PortMap::ByRange`] — explicit address ranges per port. CFA's facet
//!   arrays are contiguous and independent, so mapping *one facet array
//!   per port* is the natural balanced repartition the paper anticipates —
//!   reads and writes of different facets then proceed concurrently.

use crate::memsim::{MemConfig, MemSim, Timing, Txn, TxnTrace};

/// Transaction-to-port routing policy.
#[derive(Clone, Debug)]
pub enum PortMap {
    /// `port = (byte_addr / stripe_bytes) % ports`.
    Interleaved { stripe_bytes: u64 },
    /// Half-open element-address ranges, one entry per port boundary:
    /// port p serves addresses in `[bounds[p], bounds[p+1])`; the last
    /// port serves everything above `bounds[ports-1]`.
    ByRange { bounds: Vec<u64> },
}

impl PortMap {
    /// Port index for an element address.
    pub fn port_of(&self, addr: u64, elem_bytes: u64, ports: usize) -> usize {
        match self {
            PortMap::Interleaved { stripe_bytes } => {
                ((addr * elem_bytes / (*stripe_bytes).max(1)) % ports as u64) as usize
            }
            PortMap::ByRange { bounds } => {
                debug_assert_eq!(bounds.len(), ports);
                match bounds.binary_search(&addr) {
                    Ok(i) => i.min(ports - 1),
                    Err(0) => 0,
                    Err(i) => (i - 1).min(ports - 1),
                }
            }
        }
    }
}

/// N-channel memory interface.
pub struct MultiPortSim {
    channels: Vec<MemSim>,
    map: PortMap,
    elem_bytes: u64,
}

impl MultiPortSim {
    pub fn new(cfg: MemConfig, ports: usize, map: PortMap) -> MultiPortSim {
        assert!(ports >= 1);
        let elem_bytes = cfg.elem_bytes;
        MultiPortSim {
            channels: (0..ports).map(|_| MemSim::new(cfg.clone())).collect(),
            map,
            elem_bytes,
        }
    }

    pub fn ports(&self) -> usize {
        self.channels.len()
    }

    /// Submit a transaction; interleaved maps may split it across ports.
    pub fn submit(&mut self, txn: &Txn) {
        let ports = self.channels.len();
        if ports == 1 {
            self.channels[0].submit(txn);
            return;
        }
        match &self.map {
            PortMap::ByRange { .. } => {
                let p = self.map.port_of(txn.addr, self.elem_bytes, ports);
                self.channels[p].submit(txn);
            }
            PortMap::Interleaved { stripe_bytes } => {
                // split the run at stripe boundaries; each piece goes to
                // its stripe's port.
                let stripe_elems = (stripe_bytes / self.elem_bytes).max(1);
                let mut addr = txn.addr;
                let mut remaining = txn.len;
                while remaining > 0 {
                    let in_stripe = stripe_elems - (addr % stripe_elems);
                    let chunk = remaining.min(in_stripe);
                    let p = self.map.port_of(addr, self.elem_bytes, ports);
                    self.channels[p].submit(&Txn {
                        dir: txn.dir,
                        addr,
                        len: chunk,
                    });
                    addr += chunk;
                    remaining -= chunk;
                }
            }
        }
    }

    /// Replay a compiled [`TxnTrace`] through the port map, entry by entry
    /// (no `Txn` list materialized). Returns the completion time.
    pub fn run_trace(&mut self, trace: &TxnTrace) -> u64 {
        for (dir, addr, len) in trace.iter() {
            self.submit(&Txn { dir, addr, len });
        }
        self.now()
    }

    /// Completion time = the slowest channel (they run concurrently).
    pub fn now(&self) -> u64 {
        self.channels.iter().map(|c| c.now()).max().unwrap_or(0)
    }

    /// Per-channel busy report (balance diagnostics).
    pub fn channel_times(&self) -> Vec<u64> {
        self.channels.iter().map(|c| c.now()).collect()
    }

    /// Per-channel timing counters. The engine's accounting identities
    /// (`row_hits + row_misses == axi_bursts`, …) hold on every port
    /// independently — pinned by `tests/memsim_identities.rs`.
    pub fn timings(&self) -> Vec<&Timing> {
        self.channels.iter().map(|c| c.timing()).collect()
    }

    /// Load imbalance: max channel time / mean channel time (1.0 = ideal).
    pub fn imbalance(&self) -> f64 {
        let times = self.channel_times();
        let max = *times.iter().max().unwrap_or(&0) as f64;
        let mean = times.iter().sum::<u64>() as f64 / times.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    pub fn reset(&mut self) {
        for c in &mut self.channels {
            c.reset();
        }
    }
}

/// The facet-per-port repartition for a CFA allocation: port boundaries at
/// the facet arrays' base addresses, round-robin when there are more facets
/// than ports.
pub fn cfa_port_map(cfa: &crate::layout::cfa::Cfa, ports: usize) -> PortMap {
    // With ports >= facets this is exactly one facet array per port; with
    // fewer ports, consecutive facet arrays share a port (they are still
    // contiguous ranges, preserving ByRange semantics).
    let facets = cfa.facet_arrays();
    let per_port = facets.len().div_ceil(ports);
    let mut bounds = Vec::with_capacity(ports);
    for p in 0..ports {
        let fi = (p * per_port).min(facets.len() - 1);
        bounds.push(if p == 0 { 0 } else { facets[fi].base });
    }
    PortMap::ByRange { bounds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::Dir;

    fn cfg() -> MemConfig {
        MemConfig::default()
    }

    #[test]
    fn single_port_equals_memsim() {
        let txns: Vec<Txn> = (0..32)
            .map(|i| Txn {
                dir: Dir::Read,
                addr: i * 100,
                len: 64,
            })
            .collect();
        let mut single = MemSim::new(cfg());
        let t_ref = single.run(&txns);
        let mut mp = MultiPortSim::new(cfg(), 1, PortMap::Interleaved { stripe_bytes: 4096 });
        for t in &txns {
            mp.submit(t);
        }
        assert_eq!(mp.now(), t_ref);
    }

    #[test]
    fn range_map_routes_and_scales() {
        // two disjoint streams on two ports finish in about half the time
        let stream = |base: u64| -> Vec<Txn> {
            (0..64)
                .map(|i| Txn {
                    dir: Dir::Read,
                    addr: base + i * 1024,
                    len: 1024,
                })
                .collect()
        };
        let all: Vec<Txn> = stream(0).into_iter().chain(stream(1 << 24)).collect();
        let mut one = MultiPortSim::new(cfg(), 1, PortMap::ByRange { bounds: vec![0] });
        for t in &all {
            one.submit(t);
        }
        let mut two = MultiPortSim::new(
            cfg(),
            2,
            PortMap::ByRange {
                bounds: vec![0, 1 << 24],
            },
        );
        for t in &all {
            two.submit(t);
        }
        let speedup = one.now() as f64 / two.now() as f64;
        assert!(speedup > 1.8, "speedup {speedup}");
        assert!(two.imbalance() < 1.1);
    }

    #[test]
    fn trace_replay_equals_txn_replay_per_port() {
        let txns: Vec<Txn> = (0..48)
            .map(|i| Txn {
                dir: if i % 4 == 0 { Dir::Write } else { Dir::Read },
                addr: i * 713,
                len: 96,
            })
            .collect();
        let mut trace = TxnTrace::new();
        for t in &txns {
            trace.push(t.dir, t.addr, t.len);
        }
        let map = || PortMap::Interleaved { stripe_bytes: 512 };
        let mut by_txn = MultiPortSim::new(cfg(), 3, map());
        for t in &txns {
            by_txn.submit(t);
        }
        let mut by_trace = MultiPortSim::new(cfg(), 3, map());
        by_trace.run_trace(&trace);
        assert_eq!(by_txn.now(), by_trace.now());
        assert_eq!(by_txn.channel_times(), by_trace.channel_times());
        for (a, b) in by_txn.timings().iter().zip(by_trace.timings()) {
            assert_eq!(*a, b);
        }
    }

    #[test]
    fn interleaved_splits_at_stripes() {
        let mut mp = MultiPortSim::new(cfg(), 2, PortMap::Interleaved { stripe_bytes: 256 });
        // 64 elems * 8B = 512B: spans 2 stripes → both channels busy
        mp.submit(&Txn {
            dir: Dir::Read,
            addr: 0,
            len: 64,
        });
        let times = mp.channel_times();
        assert!(times.iter().all(|&t| t > 0), "{times:?}");
    }

    #[test]
    fn port_of_range_boundaries() {
        let m = PortMap::ByRange {
            bounds: vec![0, 100, 200],
        };
        assert_eq!(m.port_of(0, 8, 3), 0);
        assert_eq!(m.port_of(99, 8, 3), 0);
        assert_eq!(m.port_of(100, 8, 3), 1);
        assert_eq!(m.port_of(250, 8, 3), 2);
    }

    #[test]
    fn cfa_map_assigns_facets_to_ports() {
        use crate::poly::deps::DepPattern;
        use crate::poly::tiling::Tiling;
        let tiling = Tiling::new(vec![24, 24, 24], vec![8, 8, 8]);
        let deps = DepPattern::new(vec![vec![-1, 0, 0], vec![0, -1, 0], vec![0, 0, -2]])
            .unwrap();
        let cfa = crate::layout::cfa::Cfa::new(tiling, deps).unwrap();
        let map = cfa_port_map(&cfa, 3);
        let facets = cfa.facet_arrays();
        for (i, fa) in facets.iter().enumerate() {
            assert_eq!(map.port_of(fa.base, 8, 3), i, "facet {i}");
            assert_eq!(map.port_of(fa.base + fa.size() - 1, 8, 3), i);
        }
    }
}
