//! Open layout registry: the single source of allocation names.
//!
//! Every place that used to hard-code the four-element allocation name
//! list — `AllocKind::parse`/`name`, the figure sweeps, the CLI, the
//! benches — now enumerates or resolves through a [`LayoutRegistry`]
//! instead. Canonical names and their aliases are defined exactly once
//! (in [`names`] and [`LayoutRegistry::with_builtins`]); adding a fifth
//! layout is one [`register`](LayoutRegistry::register) call (or
//! [`register_global`] for the process-wide registry the sweeps and the
//! CLI enumerate), with no edits to `coordinator/` or `harness/`.

use std::sync::{Arc, OnceLock, RwLock};

use crate::layout::Allocation;
use crate::poly::deps::DepPattern;
use crate::poly::tiling::Tiling;

/// Canonical built-in layout names — defined once, used by the registry,
/// `AllocKind`, the figures and the tests.
pub mod names {
    /// Canonical Facet Allocation (the paper's contribution).
    pub const CFA: &str = "cfa";
    /// Unchanged row-major layout, best-effort bursts (Bayliss et al.).
    pub const ORIGINAL: &str = "original";
    /// Rectangular over-approximation (Pouchet et al.).
    pub const BBOX: &str = "bbox";
    /// Whole-data-tile transfers (Ozturk et al.).
    pub const DATATILE: &str = "datatile";
}

/// Constructor of one layout: build an [`Allocation`] for a tiling and
/// dependence pattern. `Arc` so registries are cheap to clone/snapshot.
pub type LayoutCtor =
    Arc<dyn Fn(&Tiling, &DepPattern) -> anyhow::Result<Box<dyn Allocation>> + Send + Sync>;

/// One registered layout: canonical name, aliases, constructor.
#[derive(Clone)]
pub struct LayoutEntry {
    name: String,
    aliases: Vec<String>,
    ctor: LayoutCtor,
}

impl LayoutEntry {
    /// Canonical name (what reports and sweep points carry).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Accepted alternative spellings.
    pub fn aliases(&self) -> &[String] {
        &self.aliases
    }

    /// True iff `s` is the canonical name or one of the aliases.
    pub fn matches(&self, s: &str) -> bool {
        self.name == s || self.aliases.iter().any(|a| a == s)
    }

    /// Instantiate the layout.
    pub fn build(
        &self,
        tiling: &Tiling,
        deps: &DepPattern,
    ) -> anyhow::Result<Box<dyn Allocation>> {
        (self.ctor)(tiling, deps)
    }
}

impl std::fmt::Debug for LayoutEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LayoutEntry")
            .field("name", &self.name)
            .field("aliases", &self.aliases)
            .finish()
    }
}

/// An ordered, open set of layouts. Values are cheap to clone (entries
/// share their constructors), so the global registry hands out snapshots
/// and sweeps iterate without holding any lock.
#[derive(Clone, Debug, Default)]
pub struct LayoutRegistry {
    entries: Vec<LayoutEntry>,
}

impl LayoutRegistry {
    /// A registry with no layouts.
    pub fn empty() -> LayoutRegistry {
        LayoutRegistry::default()
    }

    /// The four built-in allocations of the paper's evaluation (§VI.A.1),
    /// in the order every figure lists them.
    pub fn with_builtins() -> LayoutRegistry {
        let mut r = LayoutRegistry::empty();
        r.register(names::CFA, &[], Arc::new(build_cfa))
            .expect("builtin");
        r.register(names::ORIGINAL, &[], Arc::new(build_original))
            .expect("builtin");
        r.register(names::BBOX, &["bounding-box"], Arc::new(build_bbox))
            .expect("builtin");
        r.register(names::DATATILE, &["data-tiling"], Arc::new(build_datatile))
            .expect("builtin");
        r
    }

    /// Register a layout. Errors if the canonical name or any alias
    /// collides with an already-registered spelling.
    pub fn register(
        &mut self,
        name: &str,
        aliases: &[&str],
        ctor: LayoutCtor,
    ) -> anyhow::Result<()> {
        for s in std::iter::once(name).chain(aliases.iter().copied()) {
            if s.is_empty() {
                anyhow::bail!("layout name must not be empty");
            }
            if let Some(e) = self.entries.iter().find(|e| e.matches(s)) {
                anyhow::bail!("layout name '{s}' already registered (by '{}')", e.name());
            }
        }
        self.entries.push(LayoutEntry {
            name: name.to_string(),
            aliases: aliases.iter().map(|s| s.to_string()).collect(),
            ctor,
        });
        Ok(())
    }

    /// Look an entry up by canonical name or alias.
    pub fn resolve(&self, name: &str) -> Option<&LayoutEntry> {
        self.entries.iter().find(|e| e.matches(name))
    }

    /// [`resolve`](Self::resolve), with an error naming the known layouts
    /// — the single source of the unknown-layout message.
    pub fn resolve_or_err(&self, name: &str) -> anyhow::Result<&LayoutEntry> {
        self.resolve(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown layout '{name}' (registered: {})",
                self.names().join(", ")
            )
        })
    }

    /// Canonical name for any accepted spelling.
    pub fn canonical(&self, name: &str) -> Option<&str> {
        self.resolve(name).map(|e| e.name())
    }

    /// Canonical names in registration order (what sweeps iterate).
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name()).collect()
    }

    /// All entries, registration order.
    pub fn iter(&self) -> impl Iterator<Item = &LayoutEntry> {
        self.entries.iter()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Build the layout `name` refers to; the error lists what is known.
    pub fn build(
        &self,
        name: &str,
        tiling: &Tiling,
        deps: &DepPattern,
    ) -> anyhow::Result<Box<dyn Allocation>> {
        self.resolve_or_err(name)?.build(tiling, deps)
    }
}

fn build_cfa(tiling: &Tiling, deps: &DepPattern) -> anyhow::Result<Box<dyn Allocation>> {
    Ok(Box::new(crate::layout::Cfa::new(
        tiling.clone(),
        deps.clone(),
    )?))
}

fn build_original(tiling: &Tiling, deps: &DepPattern) -> anyhow::Result<Box<dyn Allocation>> {
    Ok(Box::new(crate::layout::OriginalLayout::new(
        tiling.clone(),
        deps.clone(),
    )))
}

fn build_bbox(tiling: &Tiling, deps: &DepPattern) -> anyhow::Result<Box<dyn Allocation>> {
    Ok(Box::new(crate::layout::BoundingBox::new(
        tiling.clone(),
        deps.clone(),
    )))
}

fn build_datatile(tiling: &Tiling, deps: &DepPattern) -> anyhow::Result<Box<dyn Allocation>> {
    Ok(Box::new(crate::layout::datatile::best_data_tiling(
        tiling, deps,
    )))
}

static GLOBAL: OnceLock<RwLock<LayoutRegistry>> = OnceLock::new();

fn global_lock() -> &'static RwLock<LayoutRegistry> {
    GLOBAL.get_or_init(|| RwLock::new(LayoutRegistry::with_builtins()))
}

/// Snapshot of the process-global registry (built-ins pre-registered).
/// The snapshot is an independent value: later global registrations do not
/// retroactively appear in it, so sweeps see a consistent layout set.
///
/// A thread that panics while holding the lock poisons it, but never
/// leaves the registry itself inconsistent: entries are only pushed after
/// validation, and no layout constructor runs under the lock. Readers and
/// writers therefore recover by reading through the poison marker —
/// registry contents are kept, unlike the clear-on-recovery trace cache.
pub fn global() -> LayoutRegistry {
    match global_lock().read() {
        Ok(guard) => guard.clone(),
        Err(poisoned) => poisoned.into_inner().clone(),
    }
}

/// Register a layout in the process-global registry, making it visible to
/// every registry-enumerating consumer (figure sweeps, `cfa layouts`,
/// spec-by-name sessions that use the default registry). Recovers from a
/// poisoned lock the same way [`global`] does.
pub fn register_global(name: &str, aliases: &[&str], ctor: LayoutCtor) -> anyhow::Result<()> {
    match global_lock().write() {
        Ok(mut guard) => guard.register(name, aliases, ctor),
        Err(poisoned) => poisoned.into_inner().register(name, aliases, ctor),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Tiling, DepPattern) {
        let tiling = Tiling::new(vec![8, 8], vec![4, 4]);
        let deps = DepPattern::new(vec![vec![-1, 0], vec![0, -1]]).unwrap();
        (tiling, deps)
    }

    #[test]
    fn builtins_build_and_report_their_canonical_name() {
        let (tiling, deps) = setup();
        let r = LayoutRegistry::with_builtins();
        assert_eq!(
            r.names(),
            vec![names::CFA, names::ORIGINAL, names::BBOX, names::DATATILE]
        );
        for e in r.iter() {
            let a = e.build(&tiling, &deps).unwrap();
            assert_eq!(a.name(), e.name());
            assert!(a.footprint() > 0);
        }
    }

    #[test]
    fn alias_parsing_resolves_to_canonical_names() {
        // the satellite's dedicated alias test: both spellings of bbox and
        // datatile resolve, and resolve to the same entry as the canonical
        let r = LayoutRegistry::with_builtins();
        assert_eq!(r.canonical("bounding-box"), Some(names::BBOX));
        assert_eq!(r.canonical("data-tiling"), Some(names::DATATILE));
        assert_eq!(r.canonical(names::BBOX), Some(names::BBOX));
        assert_eq!(r.canonical(names::DATATILE), Some(names::DATATILE));
        assert_eq!(r.canonical(names::CFA), Some(names::CFA));
        assert_eq!(r.canonical(names::ORIGINAL), Some(names::ORIGINAL));
        assert_eq!(r.canonical("nope"), None);
        let (tiling, deps) = setup();
        let via_alias = r.build("bounding-box", &tiling, &deps).unwrap();
        assert_eq!(via_alias.name(), names::BBOX);
    }

    #[test]
    fn duplicate_names_and_aliases_are_rejected() {
        let mut r = LayoutRegistry::with_builtins();
        assert!(r
            .register(names::CFA, &[], Arc::new(build_cfa))
            .is_err());
        assert!(r
            .register("fresh", &["bounding-box"], Arc::new(build_bbox))
            .is_err());
        assert!(r.register("", &[], Arc::new(build_cfa)).is_err());
        assert!(r.register("fresh", &["f2"], Arc::new(build_bbox)).is_ok());
        assert_eq!(r.canonical("f2"), Some("fresh"));
    }

    #[test]
    fn unknown_layout_error_lists_known_names() {
        let (tiling, deps) = setup();
        let r = LayoutRegistry::with_builtins();
        let err = r.build("nope", &tiling, &deps).unwrap_err().to_string();
        assert!(err.contains("nope") && err.contains(names::CFA), "{err}");
    }

    #[test]
    fn global_snapshot_has_builtins() {
        let r = global();
        assert!(r.len() >= 4);
        assert_eq!(r.canonical("bounding-box"), Some(names::BBOX));
    }

    #[test]
    fn poisoned_global_lock_recovers_with_contents_intact() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        // poison the global lock: panic while holding the write guard
        let unwound = catch_unwind(AssertUnwindSafe(|| {
            let _guard = global_lock().write().unwrap_or_else(|p| p.into_inner());
            panic!("poisoning panic");
        }));
        assert!(unwound.is_err());
        // reads recover and keep every entry (nothing is cleared) ...
        let r = global();
        assert!(r.len() >= 4);
        assert_eq!(r.canonical("bounding-box"), Some(names::BBOX));
        // ... and writes recover too: this one reaches normal validation
        // (duplicate name) instead of dying on the poisoned lock. Note it
        // must NOT register a new name — other tests in this binary
        // enumerate the global registry and count its layouts.
        let err = register_global(names::CFA, &[], Arc::new(build_cfa)).unwrap_err();
        assert!(err.to_string().contains("already registered"), "{err}");
    }
}
