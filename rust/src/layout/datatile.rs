//! Data-tiling baseline (Ozturk et al. [19], §VI.A.1).
//!
//! The arrays are reorganized into **data tiles** of size `c_1 × … × c_d`
//! (≤ the iteration tile in every dimension, per §VI.A.1: "the best
//! performing tile size that is less or equal to the iteration tile size");
//! any data tile touched by a flow set is transferred **whole**, in one
//! burst per data tile (adjacent tiles merge). Long bursts, but every
//! partially-used data tile is redundancy — and unlike CFA the data-tile
//! grid is not aligned with the flow sets, so tile surfaces touch many
//! barely-used data tiles.

use crate::layout::{
    merge_runs, translate_plan_uniform, write_set, AddrGenProfile, Allocation, Piece, Run,
    TilePlan,
};
use crate::poly::deps::DepPattern;
use crate::poly::flow::flow_in;
use crate::poly::rect::{Rect, Region};
use crate::poly::tiling::Tiling;
use crate::poly::vec::IVec;

/// Data-tiled row-major allocation.
#[derive(Clone, Debug)]
pub struct DataTiling {
    tiling: Tiling,
    deps: DepPattern,
    /// Data-tile grid over the iteration space (sizes = `c`).
    grid: Tiling,
    /// Cached row-major strides of the grid's tile counts (data-tile index).
    gst: Vec<u64>,
    /// Cached row-major strides of one data tile (intra-tile offset).
    ist: Vec<u64>,
    /// Full volume of one (interior) data tile.
    vol: u64,
}

impl DataTiling {
    /// `c` is clamped to the iteration-tile size per dimension.
    pub fn new(tiling: Tiling, deps: DepPattern, c: IVec) -> DataTiling {
        assert_eq!(c.len(), tiling.dims());
        let c: IVec = c
            .iter()
            .zip(&tiling.tile)
            .map(|(ci, t)| (*ci).clamp(1, *t))
            .collect();
        let grid = Tiling::new(tiling.space.clone(), c);
        let gst = crate::layout::strides(&grid.tile_counts());
        let ist = crate::layout::strides(&grid.tile);
        let vol = grid.tile.iter().map(|&c| c as u64).product();
        DataTiling {
            tiling,
            deps,
            grid,
            gst,
            ist,
            vol,
        }
    }

    /// The data-tile edge sizes in use.
    pub fn data_tile(&self) -> &IVec {
        &self.grid.tile
    }

    /// Full volume of one (interior) data tile.
    fn dt_volume(&self) -> u64 {
        self.vol
    }

    /// Linear index of a data tile (row-major over the data-tile grid).
    fn dt_index(&self, dtc: &[i64]) -> u64 {
        dtc.iter().zip(&self.gst).map(|(c, s)| *c as u64 * s).sum()
    }

    /// Element address of `p`, allocation-free (two-level addressing:
    /// data-tile index × volume + intra-tile row-major offset).
    fn addr_at(&self, p: &[i64]) -> u64 {
        let mut idx = 0u64;
        let mut intra = 0u64;
        for (k, &x) in p.iter().enumerate() {
            let c = self.grid.tile[k];
            let dtc = x.div_euclid(c);
            idx += dtc as u64 * self.gst[k];
            intra += (x - dtc * c) as u64 * self.ist[k];
        }
        idx * self.vol + intra
    }

    /// Bursts transferring every data tile touched by `region`, whole.
    /// Dedup by linear tile index (sort + dedup — `Vec::contains` would be
    /// quadratic in the tens of thousands of tiles a 128³ surface touches;
    /// see EXPERIMENTS.md §Perf).
    fn region_bursts(&self, region: &Region) -> Vec<Run> {
        let mut idxs: Vec<u64> = Vec::new();
        for r in region.rects() {
            let lo_t = self.grid.tile_of(&r.lo);
            let hi_pt: IVec = r.hi.iter().map(|h| h - 1).collect();
            let hi_t = self.grid.tile_of(&hi_pt);
            let trange = Rect::new(lo_t, hi_t.iter().map(|c| c + 1).collect());
            trange.for_each_point(&mut |tc| idxs.push(self.dt_index(tc)));
        }
        idxs.sort_unstable();
        idxs.dedup();
        let vol = self.dt_volume();
        let mut runs: Vec<Run> = idxs
            .iter()
            .map(|i| Run {
                addr: i * vol,
                len: vol,
            })
            .collect();
        merge_runs(&mut runs);
        runs
    }
}

impl Allocation for DataTiling {
    fn name(&self) -> &str {
        "datatile"
    }

    fn tiling(&self) -> &Tiling {
        &self.tiling
    }

    fn footprint(&self) -> u64 {
        // allocation pads boundary data tiles to full size
        self.grid.num_tiles() * self.dt_volume()
    }

    fn num_arrays(&self) -> usize {
        1
    }

    fn holds(&self, array: usize, p: &[i64]) -> bool {
        array == 0 && self.tiling.in_space(p)
    }

    fn addr_of(&self, array: usize, p: &[i64]) -> u64 {
        assert!(self.holds(array, p));
        self.addr_at(p)
    }

    fn plan(&self, coords: &[i64]) -> TilePlan {
        let fin = flow_in(&self.tiling, &self.deps, coords);
        let fout = write_set(&self.tiling, &self.deps, coords);
        TilePlan {
            read_useful: fin.volume(),
            write_useful: fout.volume(),
            read_runs: self.region_bursts(&fin),
            write_runs: self.region_bursts(&fout),
            read_pieces: fin
                .rects()
                .iter()
                .map(|r| Piece {
                    array: 0,
                    iter_box: r.clone(),
                })
                .collect(),
            write_pieces: fout
                .rects()
                .iter()
                .map(|r| Piece {
                    array: 0,
                    iter_box: r.clone(),
                })
                .collect(),
        }
    }

    fn read_loc(&self, p: &[i64]) -> (usize, u64) {
        (0, self.addr_of(0, p))
    }

    fn write_locs(&self, p: &[i64]) -> Vec<(usize, u64)> {
        vec![(0, self.addr_of(0, p))]
    }

    fn for_each_write_loc(&self, p: &[i64], f: &mut dyn FnMut(usize, u64)) {
        f(0, self.addr_of(0, p));
    }

    fn for_each_run(&self, array: usize, bx: &Rect, f: &mut dyn FnMut(u64, u64)) {
        debug_assert_eq!(array, 0);
        if bx.is_empty() {
            return;
        }
        // The address map is affine only *within* a data tile, so walk the
        // box's rows (last axis fastest — point order) and split each row
        // at the data-tile boundaries along the last axis: inside a segment
        // the intra stride is 1, so the segment is one run.
        let d = bx.dims();
        if d == 0 {
            f(self.addr_at(&[]), 1);
            return;
        }
        let c_last = self.grid.tile[d - 1];
        let (row_lo, row_hi) = (bx.lo[d - 1], bx.hi[d - 1]);
        // address hop when the row crosses into the next data tile along
        // the last axis: grid index +1 there, intra offset back to zero
        let gstep = self.gst[d - 1] * self.vol;
        let mut emit_row = |row_start_addr: u64, f: &mut dyn FnMut(u64, u64)| {
            let mut x = row_lo;
            let mut addr = row_start_addr;
            while x < row_hi {
                let dtc = x.div_euclid(c_last);
                let seg_end = row_hi.min((dtc + 1) * c_last);
                f(addr, (seg_end - x) as u64);
                addr = addr + gstep - (x - dtc * c_last) as u64;
                x = seg_end;
            }
        };
        if d == 1 {
            emit_row(self.addr_at(&[row_lo]), f);
        } else {
            let outer = Rect::new(bx.lo[..d - 1].to_vec(), bx.hi[..d - 1].to_vec());
            let mut p = vec![0i64; d];
            p[d - 1] = row_lo;
            outer.for_each_point(&mut |op| {
                p[..d - 1].copy_from_slice(op);
                emit_row(self.addr_at(&p), &mut *f);
            });
        }
    }

    fn rebase_plan(&self, plan: &TilePlan, from: &[i64], to: &[i64]) -> Option<TilePlan> {
        // Translation-exact only when the data-tile grid divides the
        // iteration tile: then a tile shift moves whole data tiles and the
        // index arithmetic shifts uniformly. Otherwise the grid alignment
        // differs between interior tiles and the cache must not be used.
        let d = self.tiling.dims();
        if (0..d).any(|k| self.tiling.tile[k] % self.grid.tile[k] != 0) {
            return None;
        }
        // widths beyond the tile size break interior translation-exactness
        // (flow escapes the immediate neighbor ring; see
        // `layout::row_major_rebase`)
        if (0..d).any(|k| self.deps.width(k) > self.tiling.tile[k]) {
            return None;
        }
        let delta_idx: i64 = (0..d)
            .map(|k| {
                let dt_per_tile = self.tiling.tile[k] / self.grid.tile[k];
                (to[k] - from[k]) * dt_per_tile * self.gst[k] as i64
            })
            .sum();
        let delta = delta_idx * self.vol as i64;
        let shift: Vec<i64> = (0..d)
            .map(|k| (to[k] - from[k]) * self.tiling.tile[k])
            .collect();
        Some(translate_plan_uniform(plan, delta, &shift))
    }

    fn addrgen(&self) -> AddrGenProfile {
        let mut prof = AddrGenProfile {
            arrays: 1,
            ..AddrGenProfile::default()
        };
        // data-tile index + intra-tile linearization: two-level addressing
        let all_dims: Vec<i64> = self
            .grid
            .tile_counts()
            .into_iter()
            .chain(self.grid.tile.iter().copied())
            .collect();
        for &s in crate::layout::strides(&all_dims).iter() {
            if s > 1 {
                if s.is_power_of_two() {
                    prof.shift_ops += 1;
                } else {
                    prof.mul_ops += 1;
                }
                prof.add_ops += 1;
            }
        }
        // runtime div/mod to split point coords into (tile, intra)
        prof.div_mod_ops += self.tiling.dims();
        prof.counter_bits = 64 - self.footprint().leading_zeros() as usize;
        let counts = self.tiling.tile_counts();
        let mid: Vec<i64> = counts.iter().map(|&c| (c - 1).min(1)).collect();
        prof.bursts_per_tile = self.plan(&mid).transactions() as f64;
        prof
    }
}

/// Sweep data-tile sizes (powers of two per dim, ≤ iteration tile) and pick
/// "the best performing tile size" (§VI.A.1): each candidate's
/// representative-tile plan is timed on the AXI/DRAM model and the
/// configuration with the highest *effective bandwidth* wins — exactly the
/// trade the paper describes (longer bursts vs. redundant transfer).
pub fn best_data_tiling(tiling: &Tiling, deps: &DepPattern) -> DataTiling {
    use crate::memsim::{Dir, MemConfig, MemSim, Txn};
    let d = tiling.dims();
    // The paper applies data tiling to the *original arrays* (§VI.A.1,
    // Ozturk et al.), sweeping a single tile-size scalar. A strictly
    // sequential axis (every dependence negative there — the time axis of
    // an iterative stencil) is a version dimension introduced by the
    // single-assignment expansion, not an original array dimension, so the
    // data-tile size is pinned to 1 along it; the remaining axes get the
    // cubic sweep. Anything stronger would be an anisotropic oracle the
    // paper's baseline does not have.
    let sequential: Vec<bool> = (0..d)
        .map(|a| deps.vecs().iter().all(|v| v[a] < 0))
        .collect();
    let maxt = tiling.tile.iter().copied().max().unwrap_or(1);
    let mut cands: Vec<IVec> = Vec::new();
    let mut c = 1i64;
    while c <= maxt {
        cands.push(
            (0..d)
                .map(|k| if sequential[k] { 1 } else { c.min(tiling.tile[k]) })
                .collect(),
        );
        c *= 2;
    }
    cands.dedup();
    let counts = tiling.tile_counts();
    let mid: IVec = counts.iter().map(|&c| (c - 1).min(1)).collect();
    let cfg = MemConfig::default();
    let mut best: Option<(f64, DataTiling)> = None;
    for c in cands {
        let dt = DataTiling::new(tiling.clone(), deps.clone(), c);
        let plan = dt.plan(&mid);
        let mut sim = MemSim::new(cfg.clone());
        let txns: Vec<Txn> = plan
            .read_runs
            .iter()
            .map(|r| Txn { dir: Dir::Read, addr: r.addr, len: r.len })
            .chain(plan.write_runs.iter().map(|r| Txn {
                dir: Dir::Write,
                addr: r.addr,
                len: r.len,
            }))
            .collect();
        let cycles = sim.run(&txns).max(1);
        let useful = (plan.read_useful + plan.write_useful) as f64;
        let eff = useful / cycles as f64;
        let better = match &best {
            None => true,
            Some((be, _)) => eff > *be + 1e-12,
        };
        if better {
            best = Some((eff, dt));
        }
    }
    best.unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::deps::DepPattern;

    fn setup(c: IVec) -> DataTiling {
        let tiling = Tiling::new(vec![16, 16], vec![8, 8]);
        let deps = DepPattern::new(vec![vec![-1, 0], vec![0, -1], vec![-1, -1]]).unwrap();
        DataTiling::new(tiling, deps, c)
    }

    #[test]
    fn addressing_is_tiled_row_major() {
        let dt = setup(vec![4, 4]);
        assert_eq!(dt.addr_of(0, &[0, 0]), 0);
        assert_eq!(dt.addr_of(0, &[0, 3]), 3);
        // next data tile along the fast axis
        assert_eq!(dt.addr_of(0, &[0, 4]), 16);
        assert_eq!(dt.addr_of(0, &[1, 0]), 4);
    }

    #[test]
    fn addr_bijective() {
        let dt = setup(vec![4, 4]);
        let mut seen = std::collections::HashSet::new();
        for p in dt.tiling().space_rect().points() {
            assert!(seen.insert(dt.addr_of(0, &p)));
        }
    }

    #[test]
    fn whole_tiles_transferred() {
        let dt = setup(vec![4, 4]);
        let plan = dt.plan(&[1, 1]);
        // every burst length is a multiple of the data tile volume
        for r in plan.read_runs.iter().chain(&plan.write_runs) {
            assert_eq!(r.len % 16, 0, "{r:?}");
        }
        assert!(plan.read_raw() >= plan.read_useful);
        // flow-in is a thin halo; whole-tile transfer is heavily redundant
        assert!(plan.read_raw() > 2 * plan.read_useful);
    }

    #[test]
    fn run_cursor_splits_rows_at_grid_boundaries() {
        let dt = setup(vec![4, 4]);
        let bx = Rect::new(vec![1, 2], vec![3, 10]);
        let mut runs: Vec<(u64, u64)> = Vec::new();
        dt.for_each_run(0, &bx, &mut |a, l| runs.push((a, l)));
        let concat: Vec<u64> = runs.iter().flat_map(|&(a, l)| a..a + l).collect();
        let per_point: Vec<u64> = bx.points().map(|p| dt.addr_of(0, &p)).collect();
        assert_eq!(concat, per_point);
        // no run crosses a data-tile row segment (c_last = 4)
        assert!(runs.iter().all(|&(_, l)| l <= 4), "{runs:?}");
    }

    #[test]
    fn rebase_requires_divisible_grid() {
        let tiling = Tiling::new(vec![16, 16], vec![8, 8]);
        let deps = DepPattern::new(vec![vec![-1, 0], vec![0, -1]]).unwrap();
        let divisible = DataTiling::new(tiling.clone(), deps.clone(), vec![4, 4]);
        let plan = divisible.plan(&[1, 1]);
        assert!(divisible.rebase_plan(&plan, &[1, 1], &[1, 1]).is_some());
        // 8 % 3 != 0: grid alignment differs between interior tiles
        let skewed = DataTiling::new(tiling, deps, vec![3, 3]);
        let plan = skewed.plan(&[1, 1]);
        assert!(skewed.rebase_plan(&plan, &[1, 1], &[1, 1]).is_none());
    }

    #[test]
    fn unit_tiles_degenerate_to_exact() {
        let dt = setup(vec![1, 1]);
        let plan = dt.plan(&[1, 1]);
        assert_eq!(plan.read_raw(), plan.read_useful);
    }

    #[test]
    fn oversize_request_clamps_to_iteration_tile() {
        let dt = setup(vec![100, 100]);
        assert_eq!(dt.data_tile(), &vec![8, 8]);
    }

    #[test]
    fn best_sweep_beats_worst() {
        let tiling = Tiling::new(vec![16, 16], vec![8, 8]);
        let deps = DepPattern::new(vec![vec![-1, 0], vec![0, -1], vec![-1, -1]]).unwrap();
        let best = best_data_tiling(&tiling, &deps);
        let worst = DataTiling::new(tiling, deps, vec![8, 8]);
        let pb = best.plan(&[1, 1]);
        let pw = worst.plan(&[1, 1]);
        let ratio = |p: &TilePlan| {
            (p.read_raw() + p.write_raw()) as f64 / (p.read_useful + p.write_useful) as f64
        };
        assert!(ratio(&pb) <= ratio(&pw) + 1e-9);
    }

    #[test]
    fn plan_covers_flow_addresses() {
        let dt = setup(vec![4, 2]);
        for tc in dt.tiling().tiles() {
            let plan = dt.plan(&tc);
            for pc in &plan.read_pieces {
                for p in pc.iter_box.points() {
                    let a = dt.addr_of(0, &p);
                    assert!(
                        plan.read_runs.iter().any(|r| a >= r.addr && a < r.end()),
                        "uncovered {p:?}"
                    );
                }
            }
        }
    }
}
