//! Off-chip memory layouts and burst transfer planning (§IV–V).
//!
//! An [`Allocation`] decides *where* every iteration's result lives in
//! one-dimensional off-chip memory (§II.H: access function ∘ memory layout)
//! and derives, for each tile, a [`TilePlan`]: the burst transactions that
//! move its flow-in on chip and its flow-out off chip. Four allocations are
//! implemented, matching the paper's evaluation (§VI.A.1):
//!
//! * [`cfa::Cfa`] — Canonical Facet Allocation (the contribution),
//! * [`original::OriginalLayout`] — best-effort bursts on the unchanged
//!   layout (Bayliss et al.),
//! * [`bbox::BoundingBox`] — rectangular over-approximation (Pouchet et al.),
//! * [`datatile::DataTiling`] — whole-data-tile transfers (Ozturk et al.).
//!
//! Addresses are in **elements**; the memory simulator converts to bytes.

pub mod bbox;
pub mod cfa;
pub mod datatile;
pub mod original;

use crate::poly::rect::{Rect, Region};
use crate::poly::vec::IVec;

pub use bbox::BoundingBox;
pub use cfa::{Cfa, CfaOpts};
pub use datatile::DataTiling;
pub use original::OriginalLayout;

/// One contiguous burst transaction, in elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Run {
    pub addr: u64,
    pub len: u64,
}

impl Run {
    pub fn end(&self) -> u64 {
        self.addr + self.len
    }
}

/// A rectangular chunk of iteration points an array stores / a plan moves,
/// used by the coordinator to marshal values between host memory and the
/// on-chip buffers (the timing path uses the [`Run`]s instead).
#[derive(Clone, Debug)]
pub struct Piece {
    /// Index of the allocation-internal array holding the points.
    pub array: usize,
    /// Iteration-space box of points.
    pub iter_box: Rect,
}

/// Burst transfer plan of one tile (§V.C).
#[derive(Clone, Debug, Default)]
pub struct TilePlan {
    /// Flow-in bursts, issue order.
    pub read_runs: Vec<Run>,
    /// Flow-out bursts, issue order.
    pub write_runs: Vec<Run>,
    /// Iteration-point chunks behind the read bursts.
    pub read_pieces: Vec<Piece>,
    /// Iteration-point chunks behind the write bursts.
    pub write_pieces: Vec<Piece>,
    /// Application-useful elements read (= |flow-in|).
    pub read_useful: u64,
    /// Application-useful elements written (= |flow-out|).
    pub write_useful: u64,
}

impl TilePlan {
    /// Raw elements read (burst lengths summed, redundancy included).
    pub fn read_raw(&self) -> u64 {
        self.read_runs.iter().map(|r| r.len).sum()
    }

    /// Raw elements written.
    pub fn write_raw(&self) -> u64 {
        self.write_runs.iter().map(|r| r.len).sum()
    }

    /// Total transaction count.
    pub fn transactions(&self) -> usize {
        self.read_runs.len() + self.write_runs.len()
    }
}

/// Address-generator complexity profile, consumed by the area model
/// (§VI.B.3: "the cost of CFA itself in terms of hardware is the address
/// generators").
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AddrGenProfile {
    /// Distinct off-chip arrays addressed.
    pub arrays: usize,
    /// Multiplications by non-power-of-two strides (map to DSP blocks).
    pub mul_ops: usize,
    /// Power-of-two stride multiplications (map to wiring/LUT shifts).
    pub shift_ops: usize,
    /// Additions in address expressions.
    pub add_ops: usize,
    /// Runtime division/modulo units (none for loop-generated code).
    pub div_mod_ops: usize,
    /// Total counter bits across the copy loop nests.
    pub counter_bits: usize,
    /// Average burst transactions per tile (FSM complexity driver).
    pub bursts_per_tile: f64,
}

/// A memory layout for a tiled uniform-dependence program.
///
/// `Send + Sync` is part of the contract: every implementation is plain
/// data built once and then only read, so the batched coordinator
/// (`coordinator::batch`) can fan burst planning out across threads while
/// sharing one allocation by reference.
pub trait Allocation: Send + Sync {
    /// Short identifier (used in reports: "cfa", "original", …).
    fn name(&self) -> &str;

    /// The tiling this allocation was built for.
    fn tiling(&self) -> &crate::poly::tiling::Tiling;

    /// Total off-chip storage, in elements.
    fn footprint(&self) -> u64;

    /// Number of internal arrays (CFA: one facet array per active axis).
    fn num_arrays(&self) -> usize;

    /// True iff `array` stores the value of iteration point `p`.
    fn holds(&self, array: usize, p: &[i64]) -> bool;

    /// Element address of `p` within `array`. Panics if `!holds(array, p)`.
    fn addr_of(&self, array: usize, p: &[i64]) -> u64;

    /// Burst transfer plan for tile `coords`.
    fn plan(&self, coords: &[i64]) -> TilePlan;

    /// Canonical location a consumer reads `p` from.
    fn read_loc(&self, p: &[i64]) -> (usize, u64);

    /// All locations the producer tile writes `p` to (CFA duplicates
    /// tail-intersection points into several facet arrays).
    fn write_locs(&self, p: &[i64]) -> Vec<(usize, u64)>;

    /// Address-generator complexity (for the area model).
    fn addrgen(&self) -> AddrGenProfile;
}

/// The **write set** of a tile: the union of its facets (§IV.A: "all write
/// accesses are burst accesses"). This is what any scratchpad-recycling
/// implementation must evict — every facet point is either read by a later
/// tile (flow-out) or is live-out program state on a space-boundary tile —
/// so the whole union counts as application-useful; only the physical
/// duplication of corner points across CFA's facet arrays is redundancy.
/// All four allocations transfer this same logical set, which is what makes
/// the paper's bandwidth comparison apples-to-apples.
pub fn write_set(
    tiling: &crate::poly::tiling::Tiling,
    deps: &crate::poly::deps::DepPattern,
    coords: &[i64],
) -> Region {
    crate::poly::flow::facet_union(tiling, deps, coords)
}

/// Row-major strides for `dims` (last dim fastest). Empty dims → stride 1.
pub fn strides(dims: &[i64]) -> Vec<u64> {
    let mut s = vec![1u64; dims.len()];
    for k in (0..dims.len().saturating_sub(1)).rev() {
        s[k] = s[k + 1] * dims[k + 1] as u64;
    }
    s
}

/// Linearize `coords` under row-major `dims`.
pub fn linearize(coords: &[i64], dims: &[i64]) -> u64 {
    debug_assert_eq!(coords.len(), dims.len());
    let s = strides(dims);
    coords
        .iter()
        .zip(&s)
        .map(|(c, st)| {
            debug_assert!(*c >= 0);
            *c as u64 * st
        })
        .sum()
}

/// Maximal contiguous address runs of a box within a row-major array.
///
/// `bx` must satisfy `0 <= lo <= hi <= dims` per dimension. Runs are emitted
/// in ascending address order. A box that covers full trailing dimensions
/// collapses into fewer, longer runs — the formal core of "full-tile
/// contiguity" (§IV.G): a facet box covering its whole data tile is one run.
pub fn runs_of_box(bx: &Rect, dims: &[i64], base: u64) -> Vec<Run> {
    assert_eq!(bx.dims(), dims.len());
    if bx.is_empty() {
        return Vec::new();
    }
    for k in 0..dims.len() {
        assert!(
            bx.lo[k] >= 0 && bx.hi[k] <= dims[k],
            "box {bx:?} out of array bounds {dims:?}"
        );
    }
    let d = dims.len();
    if d == 0 {
        return vec![Run { addr: base, len: 1 }];
    }
    // Longest suffix of dims fully covered by the box.
    let mut m = d; // first index of the full suffix
    while m > 0 && bx.lo[m - 1] == 0 && bx.hi[m - 1] == dims[m - 1] {
        m -= 1;
    }
    if m == 0 {
        // whole array
        return vec![Run {
            addr: base,
            len: dims.iter().map(|&x| x as u64).product(),
        }];
    }
    // Runs vary over dims [0, m-1); the run dim is m-1; dims >= m are full.
    let st = strides(dims);
    let run_len = bx.extent(m - 1) as u64 * st[m - 1];
    let outer = Rect::new(bx.lo[..m - 1].to_vec(), bx.hi[..m - 1].to_vec());
    let mut out = Vec::with_capacity(outer.volume() as usize);
    let mut emit = |coords: &[i64]| {
        let mut addr = base + bx.lo[m - 1] as u64 * st[m - 1];
        for (k, c) in coords.iter().enumerate() {
            addr += *c as u64 * st[k];
        }
        out.push(Run {
            addr,
            len: run_len,
        });
    };
    if m == 1 {
        emit(&[]);
    } else {
        for coords in outer.points() {
            emit(&coords);
        }
    }
    out
}

/// Sort runs by address and merge overlapping / exactly-adjacent ones —
/// inter-tile contiguity in action (§IV.H): a facet read extending into the
/// neighboring data tile becomes a single burst here.
pub fn merge_runs(mut runs: Vec<Run>) -> Vec<Run> {
    if runs.is_empty() {
        return runs;
    }
    runs.sort_by_key(|r| r.addr);
    let mut out: Vec<Run> = Vec::with_capacity(runs.len());
    for r in runs {
        if r.len == 0 {
            continue;
        }
        match out.last_mut() {
            Some(last) if r.addr <= last.end() => {
                let new_end = last.end().max(r.end());
                last.len = new_end - last.addr;
            }
            _ => out.push(r),
        }
    }
    out
}

/// Runs of a whole region (used by the original-layout baseline: exact
/// accesses, merged where the layout happens to be contiguous).
pub fn runs_of_region(region: &Region, dims: &[i64], base: u64) -> Vec<Run> {
    let mut runs = Vec::new();
    for r in region.rects() {
        runs.extend(runs_of_box(r, dims, base));
    }
    merge_runs(runs)
}

/// Convenience: all iteration points behind a plan's pieces (tests only).
pub fn piece_points(pieces: &[Piece]) -> Vec<(usize, IVec)> {
    let mut out = Vec::new();
    for pc in pieces {
        for p in pc.iter_box.points() {
            out.push((pc.array, p));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run, Config};

    #[test]
    fn plan_types_are_send_sync() {
        // the batched coordinator moves plans between threads; keep the
        // whole planning vocabulary thread-safe by construction
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Run>();
        assert_send_sync::<Piece>();
        assert_send_sync::<TilePlan>();
        assert_send_sync::<AddrGenProfile>();
        assert_send_sync::<Box<dyn Allocation>>();
    }

    #[test]
    fn strides_row_major() {
        assert_eq!(strides(&[4, 3, 2]), vec![6, 2, 1]);
        assert_eq!(strides(&[5]), vec![1]);
        assert_eq!(strides(&[]), Vec::<u64>::new());
    }

    #[test]
    fn linearize_matches_manual() {
        assert_eq!(linearize(&[1, 2, 1], &[4, 3, 2]), 6 + 4 + 1);
        assert_eq!(linearize(&[0, 0, 0], &[4, 3, 2]), 0);
    }

    #[test]
    fn runs_full_array_is_one() {
        let bx = Rect::new(vec![0, 0], vec![3, 4]);
        let runs = runs_of_box(&bx, &[3, 4], 100);
        assert_eq!(runs, vec![Run { addr: 100, len: 12 }]);
    }

    #[test]
    fn runs_full_rows_merge() {
        // rows 1..3 of a 4x5 array: contiguous block of 10
        let bx = Rect::new(vec![1, 0], vec![3, 5]);
        assert_eq!(
            runs_of_box(&bx, &[4, 5], 0),
            vec![Run { addr: 5, len: 10 }]
        );
    }

    #[test]
    fn runs_partial_rows_fragment() {
        // columns 1..3 of a 4x5 array: one run per row
        let bx = Rect::new(vec![0, 1], vec![4, 3]);
        let runs = runs_of_box(&bx, &[4, 5], 0);
        assert_eq!(runs.len(), 4);
        assert_eq!(runs[0], Run { addr: 1, len: 2 });
        assert_eq!(runs[3], Run { addr: 16, len: 2 });
    }

    #[test]
    fn runs_3d_middle_full() {
        // box full in last dim only
        let bx = Rect::new(vec![0, 1, 0], vec![2, 2, 4]);
        let runs = runs_of_box(&bx, &[2, 3, 4], 0);
        assert_eq!(
            runs,
            vec![Run { addr: 4, len: 4 }, Run { addr: 16, len: 4 }]
        );
    }

    #[test]
    fn merge_adjacent_and_overlapping() {
        let merged = merge_runs(vec![
            Run { addr: 10, len: 5 },
            Run { addr: 0, len: 4 },
            Run { addr: 15, len: 5 },
            Run { addr: 4, len: 2 },
        ]);
        assert_eq!(
            merged,
            vec![Run { addr: 0, len: 6 }, Run { addr: 10, len: 10 }]
        );
    }

    #[test]
    fn prop_runs_cover_box_exactly() {
        run("runs_of_box covers exactly the box", Config::small(80), |g| {
            let d = g.usize(1, 3);
            let dims: Vec<i64> = (0..d).map(|_| g.i64(1, 5)).collect();
            let lo: Vec<i64> = dims.iter().map(|&n| g.i64(0, n - 1)).collect();
            let hi: Vec<i64> = lo
                .iter()
                .zip(&dims)
                .map(|(l, n)| g.i64(*l, *n))
                .collect();
            let bx = Rect::new(lo, hi);
            let runs = runs_of_box(&bx, &dims, 0);
            // build the address set from runs
            let mut from_runs: Vec<u64> = runs
                .iter()
                .flat_map(|r| (r.addr..r.end()).collect::<Vec<u64>>())
                .collect();
            from_runs.sort_unstable();
            // and from points
            let mut from_points: Vec<u64> =
                bx.points().map(|p| linearize(&p, &dims)).collect();
            from_points.sort_unstable();
            assert_eq!(from_runs, from_points);
            // runs are maximal: no two adjacent
            for w in runs.windows(2) {
                assert!(w[0].end() < w[1].addr);
            }
        });
    }

    #[test]
    fn prop_merge_preserves_address_set() {
        run("merge_runs preserves covered addresses", Config::small(80), |g| {
            let n = g.usize(0, 6);
            let runs: Vec<Run> = (0..n)
                .map(|_| Run {
                    addr: g.i64(0, 30) as u64,
                    len: g.i64(0, 8) as u64,
                })
                .collect();
            let merged = merge_runs(runs.clone());
            let covered = |rs: &[Run], a: u64| rs.iter().any(|r| a >= r.addr && a < r.end());
            for a in 0..50u64 {
                assert_eq!(covered(&runs, a), covered(&merged, a), "addr {a}");
            }
            for w in merged.windows(2) {
                assert!(w[0].end() < w[1].addr, "not maximal: {merged:?}");
            }
        });
    }
}
