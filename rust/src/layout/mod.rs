//! Off-chip memory layouts and burst transfer planning (§IV–V).
//!
//! An [`Allocation`] decides *where* every iteration's result lives in
//! one-dimensional off-chip memory (§II.H: access function ∘ memory layout)
//! and derives, for each tile, a [`TilePlan`]: the burst transactions that
//! move its flow-in on chip and its flow-out off chip. Four allocations are
//! implemented, matching the paper's evaluation (§VI.A.1):
//!
//! * [`cfa::Cfa`] — Canonical Facet Allocation (the contribution),
//! * [`original::OriginalLayout`] — best-effort bursts on the unchanged
//!   layout (Bayliss et al.),
//! * [`bbox::BoundingBox`] — rectangular over-approximation (Pouchet et al.),
//! * [`datatile::DataTiling`] — whole-data-tile transfers (Ozturk et al.).
//!
//! Addresses are in **elements**; the memory simulator converts to bytes.

pub mod bbox;
pub mod cfa;
pub mod datatile;
pub mod original;
pub mod registry;

use crate::poly::rect::{Rect, Region};
use crate::poly::vec::IVec;

pub use bbox::BoundingBox;
pub use cfa::{Cfa, CfaOpts};
pub use datatile::DataTiling;
pub use original::OriginalLayout;
pub use registry::{LayoutCtor, LayoutEntry, LayoutRegistry};

/// One contiguous burst transaction, in elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Run {
    pub addr: u64,
    pub len: u64,
}

impl Run {
    pub fn end(&self) -> u64 {
        self.addr + self.len
    }
}

/// A rectangular chunk of iteration points an array stores / a plan moves,
/// used by the coordinator to marshal values between host memory and the
/// on-chip buffers (the timing path uses the [`Run`]s instead).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Piece {
    /// Index of the allocation-internal array holding the points.
    pub array: usize,
    /// Iteration-space box of points.
    pub iter_box: Rect,
}

/// Burst transfer plan of one tile (§V.C). `PartialEq` compares every run,
/// piece and counter — the memoization identity tests rely on it.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TilePlan {
    /// Flow-in bursts, issue order.
    pub read_runs: Vec<Run>,
    /// Flow-out bursts, issue order.
    pub write_runs: Vec<Run>,
    /// Iteration-point chunks behind the read bursts.
    pub read_pieces: Vec<Piece>,
    /// Iteration-point chunks behind the write bursts.
    pub write_pieces: Vec<Piece>,
    /// Application-useful elements read (= |flow-in|).
    pub read_useful: u64,
    /// Application-useful elements written (= |flow-out|).
    pub write_useful: u64,
}

impl TilePlan {
    /// Raw elements read (burst lengths summed, redundancy included).
    pub fn read_raw(&self) -> u64 {
        self.read_runs.iter().map(|r| r.len).sum()
    }

    /// Raw elements written.
    pub fn write_raw(&self) -> u64 {
        self.write_runs.iter().map(|r| r.len).sum()
    }

    /// Total transaction count.
    pub fn transactions(&self) -> usize {
        self.read_runs.len() + self.write_runs.len()
    }
}

/// Address-generator complexity profile, consumed by the area model
/// (§VI.B.3: "the cost of CFA itself in terms of hardware is the address
/// generators").
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AddrGenProfile {
    /// Distinct off-chip arrays addressed.
    pub arrays: usize,
    /// Multiplications by non-power-of-two strides (map to DSP blocks).
    pub mul_ops: usize,
    /// Power-of-two stride multiplications (map to wiring/LUT shifts).
    pub shift_ops: usize,
    /// Additions in address expressions.
    pub add_ops: usize,
    /// Runtime division/modulo units (none for loop-generated code).
    pub div_mod_ops: usize,
    /// Total counter bits across the copy loop nests.
    pub counter_bits: usize,
    /// Average burst transactions per tile (FSM complexity driver).
    pub bursts_per_tile: f64,
}

/// A memory layout for a tiled uniform-dependence program.
///
/// `Send + Sync` is part of the contract: every implementation is plain
/// data built once and then only read, so the batched coordinator
/// (`coordinator::batch`) can fan burst planning out across threads while
/// sharing one allocation by reference.
pub trait Allocation: Send + Sync {
    /// Short identifier (used in reports: "cfa", "original", …).
    fn name(&self) -> &str;

    /// The tiling this allocation was built for.
    fn tiling(&self) -> &crate::poly::tiling::Tiling;

    /// Total off-chip storage, in elements.
    fn footprint(&self) -> u64;

    /// Contiguous storage regions as ascending `(base element address,
    /// elements)` pairs covering the footprint. Multi-channel striping
    /// policies ([`Striping::Facet`](crate::memsim::Striping) /
    /// [`Striping::Tile`](crate::memsim::Striping)) partition these over
    /// channels; the default is one region spanning the whole allocation,
    /// and CFA overrides it with one region per facet array.
    fn regions(&self) -> Vec<(u64, u64)> {
        vec![(0, self.footprint())]
    }

    /// Number of internal arrays (CFA: one facet array per active axis).
    fn num_arrays(&self) -> usize;

    /// True iff `array` stores the value of iteration point `p`.
    fn holds(&self, array: usize, p: &[i64]) -> bool;

    /// Element address of `p` within `array`. Panics if `!holds(array, p)`.
    fn addr_of(&self, array: usize, p: &[i64]) -> u64;

    /// Burst transfer plan for tile `coords`.
    fn plan(&self, coords: &[i64]) -> TilePlan;

    /// Canonical location a consumer reads `p` from.
    fn read_loc(&self, p: &[i64]) -> (usize, u64);

    /// All locations the producer tile writes `p` to (CFA duplicates
    /// tail-intersection points into several facet arrays).
    fn write_locs(&self, p: &[i64]) -> Vec<(usize, u64)>;

    /// Address-generator complexity (for the area model).
    fn addrgen(&self) -> AddrGenProfile;

    /// **Run cursor** — the burst-grained replacement for per-point
    /// [`Allocation::addr_of`] on the marshalling path. Visits `(addr, len)`
    /// address runs of `bx` in **row-major point order**: concatenating the
    /// visited intervals reproduces `[addr_of(array, p) for p in
    /// bx.points()]` element for element, so callers copy slices (or scan
    /// them) instead of linearizing every point, while any fold over the
    /// values stays bit-identical to the pointwise loop.
    ///
    /// Total for any box `array` holds. Plan pieces take the allocation's
    /// native fast path (for CFA: contained in one tile and held entirely
    /// by `array`, which `plan` guarantees — other boxes fall back to
    /// per-point coalescing). The default implementation
    /// (`coalesce_point_runs`) is the reference semantics; every in-tree
    /// allocation overrides it with an allocation-free native walker.
    fn for_each_run(&self, array: usize, bx: &Rect, f: &mut dyn FnMut(u64, u64)) {
        coalesce_point_runs(self, array, bx, f);
    }

    /// Visit every location the producer tile writes `p` to, in the same
    /// order [`Allocation::write_locs`] lists them, without materializing a
    /// `Vec` per point (the marshalling loops call this per flow-out point).
    fn for_each_write_loc(&self, p: &[i64], f: &mut dyn FnMut(usize, u64)) {
        for (array, addr) in self.write_locs(p) {
            f(array, addr);
        }
    }

    /// Rebase a plan computed for interior tile `from` onto interior tile
    /// `to`, in O(#runs + #pieces) — the engine behind [`PlanCache`].
    ///
    /// Contract: when both tiles are interior tiles of an **exact** tiling
    /// (every coordinate in `1..count-1`, tile sizes dividing the space),
    /// the result must be **bit-identical** to `self.plan(to)`. Allocations
    /// whose address function is not translation-equivariant under tile
    /// shifts return `None` (the default) and callers re-plan from scratch;
    /// `rebase_plan(plan, c, c)` doubles as the support probe.
    fn rebase_plan(&self, plan: &TilePlan, from: &[i64], to: &[i64]) -> Option<TilePlan> {
        let _ = (plan, from, to);
        None
    }
}

/// Reference run enumeration behind [`Allocation::for_each_run`]'s default:
/// walk the box in row-major point order and coalesce consecutive
/// addresses. Total for any box the allocation holds — no affine
/// precondition — so it is also CFA's fallback for boxes spanning tiles.
pub(crate) fn coalesce_point_runs<A: Allocation + ?Sized>(
    alloc: &A,
    array: usize,
    bx: &Rect,
    f: &mut dyn FnMut(u64, u64),
) {
    let mut cur: Option<(u64, u64)> = None;
    bx.for_each_point(&mut |p| {
        let a = alloc.addr_of(array, p);
        match &mut cur {
            Some((start, len)) if a == *start + *len => *len += 1,
            _ => {
                if let Some((s, l)) = cur.take() {
                    f(s, l);
                }
                cur = Some((a, 1));
            }
        }
    });
    if let Some((s, l)) = cur {
        f(s, l);
    }
}

/// Dot product of a point with cached row-major strides — the single
/// definition of the linear address map the fast paths share.
#[inline]
pub(crate) fn dot(p: &[i64], st: &[u64]) -> u64 {
    p.iter().zip(st).map(|(x, s)| *x as u64 * s).sum()
}

/// Translate a plan by a uniform address delta plus an iteration-space
/// shift — the [`Allocation::rebase_plan`] step shared by the single-array
/// row-major allocations (original, bbox, data tiling), whose address maps
/// are globally affine so every run moves by the same amount.
pub fn translate_plan_uniform(plan: &TilePlan, delta: i64, shift: &[i64]) -> TilePlan {
    let mv_runs = |runs: &[Run]| -> Vec<Run> {
        runs.iter()
            .map(|r| Run {
                addr: (r.addr as i64 + delta) as u64,
                len: r.len,
            })
            .collect()
    };
    let mv_pieces = |pieces: &[Piece]| -> Vec<Piece> {
        pieces
            .iter()
            .map(|pc| Piece {
                array: pc.array,
                iter_box: pc.iter_box.shift(shift),
            })
            .collect()
    };
    TilePlan {
        read_runs: mv_runs(&plan.read_runs),
        write_runs: mv_runs(&plan.write_runs),
        read_pieces: mv_pieces(&plan.read_pieces),
        write_pieces: mv_pieces(&plan.write_pieces),
        read_useful: plan.read_useful,
        write_useful: plan.write_useful,
    }
}

/// Run cursor of a globally row-major single-array layout (shared by the
/// original and bounding-box baselines): the whole space is one affine map,
/// so the walker anchors at the box origin's dot product with the strides.
pub(crate) fn row_major_runs(st: &[u64], bx: &Rect, f: &mut dyn FnMut(u64, u64)) {
    if bx.is_empty() {
        return;
    }
    affine_runs(bx, st, dot(&bx.lo, st), f);
}

/// [`Allocation::rebase_plan`] of a globally row-major single-array layout:
/// one uniform address delta per tile translation. Opts out (`None`) when a
/// dependence width exceeds the tile size — flow then escapes the immediate
/// neighbor ring, so even interior tiles' flow regions can be clipped by
/// the space boundary and translation-exactness breaks.
pub(crate) fn row_major_rebase(
    tiling: &crate::poly::tiling::Tiling,
    deps: &crate::poly::deps::DepPattern,
    st: &[u64],
    plan: &TilePlan,
    from: &[i64],
    to: &[i64],
) -> Option<TilePlan> {
    let d = tiling.dims();
    if (0..d).any(|k| deps.width(k) > tiling.tile[k]) {
        return None;
    }
    let delta: i64 = (0..d)
        .map(|k| (to[k] - from[k]) * tiling.tile[k] * st[k] as i64)
        .sum();
    let shift: Vec<i64> = (0..d).map(|k| (to[k] - from[k]) * tiling.tile[k]).collect();
    Some(translate_plan_uniform(plan, delta, &shift))
}

/// Memoized burst planning over one allocation (§IV read through a systems
/// lens): the interior tiles of an exact uniform tiling are translates of
/// one another, so their plans are translates too — one canonical interior
/// plan, derived once, rebases to any interior tile in O(#runs) instead of
/// re-running the full region algebra + `runs_of_box` + `merge_runs`
/// pipeline per tile. Boundary tiles (and tilings with no interior, partial
/// boundary tiles, or an allocation that opts out of
/// [`Allocation::rebase_plan`]) fall back to fresh planning, so
/// `cache.plan(c)` is **bit-identical** to `alloc.plan(c)` for every tile —
/// the identity the fast-path property tests pin down.
///
/// The canonical plan is derived lazily behind a [`std::sync::OnceLock`],
/// so a cache shared by reference across `util::par` workers stays `Sync`
/// and plans each tile exactly as the serial path would.
///
/// The memoization state itself lives in a [`PlanCacheState`], which does
/// **not** borrow the allocation: owners of a `Box<dyn Allocation>` (the
/// experiment [`Session`](crate::experiment::Session)) keep one state next
/// to the allocation and hand out short-lived `PlanCache` views, so the
/// canonical interior plan is derived once per session, not once per run.
pub struct PlanCacheState {
    counts: IVec,
    /// Interior class exists: exact tiling, ≥ 3 tiles per axis (coordinates
    /// `1..count-1` then see full-size neighbors on every side, so flow
    /// regions are never clipped by the space boundary — the precondition
    /// of translation-exactness).
    enabled: bool,
    /// Fingerprint of the allocation this state was created for (footprint
    /// + array count): a cached plan rebased against a *different*
    /// allocation would be silently wrong, so `plan` debug-asserts the
    /// pairing.
    fingerprint: (u64, usize),
    canon: std::sync::OnceLock<Option<(IVec, TilePlan)>>,
    /// Plans served by rebasing the canonical interior plan
    /// (registry-backed: `cfa.plan_cache.rebase_hits`).
    rebases: crate::obs::metrics::Counter,
    /// Plans derived fresh (boundary tiles, opted-out allocations;
    /// registry-backed: `cfa.plan_cache.fresh_plans`).
    fresh: crate::obs::metrics::Counter,
}

impl PlanCacheState {
    /// Derive the interior-class predicate for `alloc`'s tiling. Only the
    /// tiling is inspected; no reference to `alloc` is retained.
    pub fn new(alloc: &dyn Allocation) -> PlanCacheState {
        let tiling = alloc.tiling();
        let counts = tiling.tile_counts();
        let enabled = tiling.is_exact() && counts.iter().all(|&c| c >= 3);
        PlanCacheState {
            counts,
            enabled,
            fingerprint: (alloc.footprint(), alloc.num_arrays()),
            canon: std::sync::OnceLock::new(),
            rebases: crate::obs::registry().counter("cfa.plan_cache.rebase_hits"),
            fresh: crate::obs::registry().counter("cfa.plan_cache.fresh_plans"),
        }
    }

    /// Plans served by rebasing the memoized canonical interior plan.
    pub fn rebase_hits(&self) -> u64 {
        self.rebases.get()
    }

    /// Plans derived by the full per-tile pipeline.
    pub fn fresh_plans(&self) -> u64 {
        self.fresh.get()
    }

    /// True iff `coords` belongs to the memoizable interior class.
    pub fn is_interior(&self, coords: &[i64]) -> bool {
        self.enabled
            && coords
                .iter()
                .zip(&self.counts)
                .all(|(c, n)| *c >= 1 && *c < n - 1)
    }

    fn canon(&self, alloc: &dyn Allocation) -> Option<&(IVec, TilePlan)> {
        self.canon
            .get_or_init(|| {
                let c0: IVec = vec![1; self.counts.len()];
                let plan = alloc.plan(&c0);
                // probe: the allocation must support exact rebasing (data
                // tiling opts out when the grid does not divide the tile)
                alloc.rebase_plan(&plan, &c0, &c0)?;
                Some((c0, plan))
            })
            .as_ref()
    }

    /// Plan `coords` against `alloc`: rebased from the canonical interior
    /// plan when possible, freshly derived otherwise. Always equals
    /// `alloc.plan`. The caller must pass the same allocation the state was
    /// created for (the [`PlanCache`] wrapper enforces this pairing).
    pub fn plan(&self, alloc: &dyn Allocation, coords: &[i64]) -> TilePlan {
        debug_assert_eq!(
            self.fingerprint,
            (alloc.footprint(), alloc.num_arrays()),
            "PlanCacheState used with a different allocation than it was created for"
        );
        if self.is_interior(coords) {
            if let Some((c0, plan)) = self.canon(alloc) {
                if let Some(rebased) = alloc.rebase_plan(plan, c0, coords) {
                    self.rebases.inc();
                    return rebased;
                }
            }
        }
        self.fresh.inc();
        alloc.plan(coords)
    }
}

/// How a [`PlanCache`] holds its state: privately, or shared with an owner
/// that outlives individual runs (a `Session`).
enum CacheStateRef<'a> {
    Owned(PlanCacheState),
    Shared(&'a PlanCacheState),
}

/// A [`PlanCacheState`] paired with the allocation it plans against — the
/// planning front end every coordinator path uses.
pub struct PlanCache<'a> {
    alloc: &'a dyn Allocation,
    state: CacheStateRef<'a>,
}

impl<'a> PlanCache<'a> {
    pub fn new(alloc: &'a dyn Allocation) -> PlanCache<'a> {
        PlanCache {
            alloc,
            state: CacheStateRef::Owned(PlanCacheState::new(alloc)),
        }
    }

    /// A cache view over caller-owned state (must have been created for
    /// this same allocation), so the canonical plan survives this view.
    pub fn with_state(alloc: &'a dyn Allocation, state: &'a PlanCacheState) -> PlanCache<'a> {
        PlanCache {
            alloc,
            state: CacheStateRef::Shared(state),
        }
    }

    fn state(&self) -> &PlanCacheState {
        match &self.state {
            CacheStateRef::Owned(s) => s,
            CacheStateRef::Shared(s) => s,
        }
    }

    /// True iff `coords` belongs to the memoizable interior class.
    pub fn is_interior(&self, coords: &[i64]) -> bool {
        self.state().is_interior(coords)
    }

    /// Plan `coords`: rebased from the canonical interior plan when
    /// possible, freshly derived otherwise. Always equals `alloc.plan`.
    pub fn plan(&self, coords: &[i64]) -> TilePlan {
        self.state().plan(self.alloc, coords)
    }

    /// The allocation this cache plans against.
    pub fn alloc(&self) -> &'a dyn Allocation {
        self.alloc
    }
}

/// The **write set** of a tile: the union of its facets (§IV.A: "all write
/// accesses are burst accesses"). This is what any scratchpad-recycling
/// implementation must evict — every facet point is either read by a later
/// tile (flow-out) or is live-out program state on a space-boundary tile —
/// so the whole union counts as application-useful; only the physical
/// duplication of corner points across CFA's facet arrays is redundancy.
/// All four allocations transfer this same logical set, which is what makes
/// the paper's bandwidth comparison apples-to-apples.
pub fn write_set(
    tiling: &crate::poly::tiling::Tiling,
    deps: &crate::poly::deps::DepPattern,
    coords: &[i64],
) -> Region {
    crate::poly::flow::facet_union(tiling, deps, coords)
}

/// Row-major strides for `dims` (last dim fastest). Empty dims → stride 1.
pub fn strides(dims: &[i64]) -> Vec<u64> {
    let mut s = vec![1u64; dims.len()];
    for k in (0..dims.len().saturating_sub(1)).rev() {
        s[k] = s[k + 1] * dims[k + 1] as u64;
    }
    s
}

/// Linearize `coords` under row-major `dims`.
pub fn linearize(coords: &[i64], dims: &[i64]) -> u64 {
    debug_assert_eq!(coords.len(), dims.len());
    let s = strides(dims);
    coords
        .iter()
        .zip(&s)
        .map(|(c, st)| {
            debug_assert!(*c >= 0);
            *c as u64 * st
        })
        .sum()
}

/// Enumerate the contiguous address runs of a box under the affine map
/// `addr(p) = base + Σ_k s[k]·(p[k] − bx.lo[k])`, visiting them in
/// **row-major point order**: concatenating the visited intervals
/// reproduces `[addr(p) for p in bx.points()]` element for element. This is
/// the engine behind every [`Allocation::for_each_run`] implementation —
/// zero heap allocation beyond one small index buffer, addresses maintained
/// incrementally instead of re-linearized per point.
///
/// The longest *chained* trailing suffix of axes (unit stride innermost,
/// each next stride equal to the point count of the suffix inside it;
/// singleton axes chain for free) collapses into the run length; the
/// remaining outer axes are walked with carries.
pub fn affine_runs(bx: &Rect, s: &[u64], base: u64, f: &mut dyn FnMut(u64, u64)) {
    debug_assert_eq!(bx.dims(), s.len());
    if bx.is_empty() {
        return;
    }
    let d = bx.dims();
    // Longest chained suffix: iterating it row-major advances the address
    // by exactly 1 per point.
    let mut run_len = 1u64;
    let mut m = d;
    while m > 0 {
        let ext = bx.extent(m - 1) as u64;
        if ext == 1 {
            m -= 1; // degenerate axis: never advances, chains for free
            continue;
        }
        if s[m - 1] != run_len {
            break;
        }
        run_len *= ext;
        m -= 1;
    }
    if m == 0 {
        f(base, run_len);
        return;
    }
    // Walk the outer axes [0, m) row-major, maintaining the run address.
    let mut idx = vec![0i64; m];
    let mut addr = base;
    loop {
        f(addr, run_len);
        let mut k = m;
        loop {
            if k == 0 {
                return;
            }
            k -= 1;
            idx[k] += 1;
            addr += s[k];
            if idx[k] < bx.extent(k) {
                break;
            }
            addr -= s[k] * bx.extent(k) as u64;
            idx[k] = 0;
        }
    }
}

/// Maximal contiguous address runs of a box within a row-major array.
///
/// `bx` must satisfy `0 <= lo <= hi <= dims` per dimension. Runs are emitted
/// in ascending address order. A box that covers full trailing dimensions
/// collapses into fewer, longer runs — the formal core of "full-tile
/// contiguity" (§IV.G): a facet box covering its whole data tile is one run.
pub fn runs_of_box(bx: &Rect, dims: &[i64], base: u64) -> Vec<Run> {
    assert_eq!(bx.dims(), dims.len());
    if bx.is_empty() {
        return Vec::new();
    }
    for k in 0..dims.len() {
        assert!(
            bx.lo[k] >= 0 && bx.hi[k] <= dims[k],
            "box {bx:?} out of array bounds {dims:?}"
        );
    }
    if dims.is_empty() {
        return vec![Run { addr: base, len: 1 }];
    }
    // Row-major strides make point order == address order, so the affine
    // walker emits exactly the maximal ascending runs.
    let st = strides(dims);
    let base0 = base + dot(&bx.lo, &st);
    let mut out = Vec::new();
    affine_runs(bx, &st, base0, &mut |addr, len| {
        out.push(Run { addr, len });
    });
    out
}

/// Sort runs by address and merge overlapping / exactly-adjacent ones,
/// in place — inter-tile contiguity in action (§IV.H): a facet read
/// extending into the neighboring data tile becomes a single burst here.
/// Already-sorted input (the common case: [`runs_of_box`] emits ascending)
/// skips the sort entirely, and the compaction reuses the input buffer.
pub fn merge_runs(runs: &mut Vec<Run>) {
    if runs.len() > 1 && runs.windows(2).any(|w| w[0].addr > w[1].addr) {
        runs.sort_by_key(|r| r.addr);
    }
    let mut w = 0usize;
    let mut i = 0usize;
    while i < runs.len() {
        let r = runs[i];
        i += 1;
        if r.len == 0 {
            continue;
        }
        if w > 0 && r.addr <= runs[w - 1].end() {
            let new_end = runs[w - 1].end().max(r.end());
            runs[w - 1].len = new_end - runs[w - 1].addr;
        } else {
            runs[w] = r;
            w += 1;
        }
    }
    runs.truncate(w);
}

/// Runs of a whole region (used by the original-layout baseline: exact
/// accesses, merged where the layout happens to be contiguous).
pub fn runs_of_region(region: &Region, dims: &[i64], base: u64) -> Vec<Run> {
    let mut runs = Vec::new();
    for r in region.rects() {
        runs.extend(runs_of_box(r, dims, base));
    }
    merge_runs(&mut runs);
    runs
}

/// Convenience: all iteration points behind a plan's pieces (tests only).
pub fn piece_points(pieces: &[Piece]) -> Vec<(usize, IVec)> {
    let mut out = Vec::new();
    for pc in pieces {
        for p in pc.iter_box.points() {
            out.push((pc.array, p));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run, Config};

    #[test]
    fn plan_types_are_send_sync() {
        // the batched coordinator moves plans between threads; keep the
        // whole planning vocabulary thread-safe by construction
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Run>();
        assert_send_sync::<Piece>();
        assert_send_sync::<TilePlan>();
        assert_send_sync::<AddrGenProfile>();
        assert_send_sync::<Box<dyn Allocation>>();
    }

    #[test]
    fn strides_row_major() {
        assert_eq!(strides(&[4, 3, 2]), vec![6, 2, 1]);
        assert_eq!(strides(&[5]), vec![1]);
        assert_eq!(strides(&[]), Vec::<u64>::new());
    }

    #[test]
    fn linearize_matches_manual() {
        assert_eq!(linearize(&[1, 2, 1], &[4, 3, 2]), 6 + 4 + 1);
        assert_eq!(linearize(&[0, 0, 0], &[4, 3, 2]), 0);
    }

    #[test]
    fn runs_full_array_is_one() {
        let bx = Rect::new(vec![0, 0], vec![3, 4]);
        let runs = runs_of_box(&bx, &[3, 4], 100);
        assert_eq!(runs, vec![Run { addr: 100, len: 12 }]);
    }

    #[test]
    fn runs_full_rows_merge() {
        // rows 1..3 of a 4x5 array: contiguous block of 10
        let bx = Rect::new(vec![1, 0], vec![3, 5]);
        assert_eq!(
            runs_of_box(&bx, &[4, 5], 0),
            vec![Run { addr: 5, len: 10 }]
        );
    }

    #[test]
    fn runs_partial_rows_fragment() {
        // columns 1..3 of a 4x5 array: one run per row
        let bx = Rect::new(vec![0, 1], vec![4, 3]);
        let runs = runs_of_box(&bx, &[4, 5], 0);
        assert_eq!(runs.len(), 4);
        assert_eq!(runs[0], Run { addr: 1, len: 2 });
        assert_eq!(runs[3], Run { addr: 16, len: 2 });
    }

    #[test]
    fn runs_3d_middle_full() {
        // box full in last dim only
        let bx = Rect::new(vec![0, 1, 0], vec![2, 2, 4]);
        let runs = runs_of_box(&bx, &[2, 3, 4], 0);
        assert_eq!(
            runs,
            vec![Run { addr: 4, len: 4 }, Run { addr: 16, len: 4 }]
        );
    }

    #[test]
    fn merge_adjacent_and_overlapping() {
        let mut merged = vec![
            Run { addr: 10, len: 5 },
            Run { addr: 0, len: 4 },
            Run { addr: 15, len: 5 },
            Run { addr: 4, len: 2 },
        ];
        merge_runs(&mut merged);
        assert_eq!(
            merged,
            vec![Run { addr: 0, len: 6 }, Run { addr: 10, len: 10 }]
        );
    }

    #[test]
    fn merge_skips_sort_on_sorted_input_and_drops_empties() {
        let mut runs = vec![
            Run { addr: 0, len: 0 },
            Run { addr: 2, len: 3 },
            Run { addr: 5, len: 0 },
            Run { addr: 5, len: 1 },
            Run { addr: 9, len: 2 },
        ];
        merge_runs(&mut runs);
        assert_eq!(
            runs,
            vec![Run { addr: 2, len: 4 }, Run { addr: 9, len: 2 }]
        );
        let mut empty: Vec<Run> = Vec::new();
        merge_runs(&mut empty);
        assert!(empty.is_empty());
        let mut zero = vec![Run { addr: 7, len: 0 }];
        merge_runs(&mut zero);
        assert!(zero.is_empty());
    }

    #[test]
    fn prop_runs_cover_box_exactly() {
        run("runs_of_box covers exactly the box", Config::small(80), |g| {
            let d = g.usize(1, 3);
            let dims: Vec<i64> = (0..d).map(|_| g.i64(1, 5)).collect();
            let lo: Vec<i64> = dims.iter().map(|&n| g.i64(0, n - 1)).collect();
            let hi: Vec<i64> = lo
                .iter()
                .zip(&dims)
                .map(|(l, n)| g.i64(*l, *n))
                .collect();
            let bx = Rect::new(lo, hi);
            let runs = runs_of_box(&bx, &dims, 0);
            // build the address set from runs
            let mut from_runs: Vec<u64> = runs
                .iter()
                .flat_map(|r| (r.addr..r.end()).collect::<Vec<u64>>())
                .collect();
            from_runs.sort_unstable();
            // and from points
            let mut from_points: Vec<u64> =
                bx.points().map(|p| linearize(&p, &dims)).collect();
            from_points.sort_unstable();
            assert_eq!(from_runs, from_points);
            // runs are maximal: no two adjacent
            for w in runs.windows(2) {
                assert!(w[0].end() < w[1].addr);
            }
        });
    }

    #[test]
    fn prop_affine_runs_enumerate_points_in_order() {
        // the fast-path contract: concatenating the walker's runs yields
        // exactly [addr(p) for p in bx.points()], for arbitrary strides
        run("affine_runs ≡ per-point affine map", Config::small(80), |g| {
            let d = g.usize(1, 3);
            let lo: Vec<i64> = (0..d).map(|_| g.i64(0, 3)).collect();
            let ext: Vec<i64> = (0..d).map(|_| g.i64(0, 4)).collect();
            let hi: Vec<i64> = lo.iter().zip(&ext).map(|(l, e)| l + e).collect();
            let bx = Rect::new(lo, hi);
            let s: Vec<u64> = (0..d).map(|_| g.i64(1, 30) as u64).collect();
            let base = g.i64(0, 100) as u64;
            let mut from_runs: Vec<u64> = Vec::new();
            affine_runs(&bx, &s, base, &mut |addr, len| {
                from_runs.extend(addr..addr + len);
            });
            let per_point: Vec<u64> = bx
                .points()
                .map(|p| {
                    base + p
                        .iter()
                        .zip(&bx.lo)
                        .zip(&s)
                        .map(|((x, l), st)| (x - l) as u64 * st)
                        .sum::<u64>()
                })
                .collect();
            assert_eq!(from_runs, per_point, "box {bx:?} strides {s:?}");
        });
    }

    #[test]
    fn prop_merge_preserves_address_set() {
        run("merge_runs preserves covered addresses", Config::small(80), |g| {
            let n = g.usize(0, 6);
            let runs: Vec<Run> = (0..n)
                .map(|_| Run {
                    addr: g.i64(0, 30) as u64,
                    len: g.i64(0, 8) as u64,
                })
                .collect();
            let mut merged = runs.clone();
            merge_runs(&mut merged);
            let covered = |rs: &[Run], a: u64| rs.iter().any(|r| a >= r.addr && a < r.end());
            for a in 0..50u64 {
                assert_eq!(covered(&runs, a), covered(&merged, a), "addr {a}");
            }
            for w in merged.windows(2) {
                assert!(w[0].end() < w[1].addr, "not maximal: {merged:?}");
            }
        });
    }
}
