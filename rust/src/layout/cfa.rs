//! Canonical Facet Allocation (§IV) — the paper's contribution.
//!
//! For each active axis k (w_k > 0) CFA builds a **facet array** holding the
//! last `w_k` planes of every tile along k, combining:
//!
//! * **multi-projection** (§IV.F): one data space per canonical hyperplane,
//!   as thick as the dependence pattern plunges into neighbor tiles
//!   (`w_k = max_q |e_k · B_q|`);
//! * **single-assignment replication** (§IV.F.4): the tile coordinate along
//!   k is an extra array dimension, so no two tiles share storage;
//! * **data tiling** (§IV.G): the facet of one tile is one contiguous data
//!   tile → every flow-out facet is written in a single burst
//!   (*full-tile contiguity*);
//! * **dimension permutation** (§IV.H): each facet has an inter-tile
//!   contiguity axis `c_k`; its tile coordinate is the fastest outer
//!   dimension and its intra coordinate the slowest inner dimension, so a
//!   second-level-neighbor extension is a contiguous tail of the preceding
//!   data tile (*inter-tile contiguity*);
//! * **inner ordering** (§IV.I): tails nest as suffixes, so the third-level
//!   corner set S_3 is one contiguous chunk (*intra-tile contiguity*).
//!
//! The contiguity axes are assigned **cyclically over the active axes**
//! (c_i=j, c_j=k, c_k=i in 3D), which covers every second-level pair like
//! the paper's per-case choices do (the paper's printed 3D layouts contain
//! a typo — facet_k is missing its `[i]` dimension — the cyclic rule is the
//! consistent generalization of its §IV.H procedure).
//!
//! One deliberate deviation: the paper stores the thickness dimension as
//! `x_k mod w_k`. For tile sizes not divisible by w_k that map cyclically
//! rotates the tail (breaking monotonicity), so we store the equivalent
//! *offset-from-tail* index `x_k - (tile_end_k - w_k)` — same footprint,
//! identical when `w_k | t_k` up to rotation, and order-preserving, which
//! keeps partial facet reads contiguous.

use crate::layout::{
    affine_runs, merge_runs, runs_of_box, AddrGenProfile, Allocation, Piece, Run, TilePlan,
};
use crate::poly::deps::DepPattern;
use crate::poly::flow::flow_in;
use crate::poly::rect::Rect;
use crate::poly::tiling::Tiling;
use crate::poly::vec::IVec;

/// Feature toggles for the contiguity-level ablation
/// (`benches/ablation_contiguity.rs`).
#[derive(Clone, Copy, Debug)]
pub struct CfaOpts {
    /// Merge bursts across adjacent data tiles (§IV.H). Off → one burst
    /// set per facet piece.
    pub inter_tile: bool,
    /// Choose the facet serving a k≥3-level piece by measured contiguity
    /// (§IV.I). Off → always the lowest-numbered candidate axis.
    pub intra_tile: bool,
    /// Rectangular over-approximation of partial facet reads (Fig 11).
    /// Off → exact (possibly fragmented) reads.
    pub bbox_expand: bool,
}

impl Default for CfaOpts {
    fn default() -> Self {
        CfaOpts {
            inter_tile: true,
            intra_tile: true,
            bbox_expand: true,
        }
    }
}

/// One facet array (projection of the iteration space along `axis`).
#[derive(Clone, Debug)]
pub struct FacetArray {
    /// Axis k this facet projects along.
    pub axis: usize,
    /// Inter-tile contiguity axis c_k (None in 1-D spaces).
    pub contig: Option<usize>,
    /// Facet thickness w_k.
    pub w: i64,
    /// Tile-coordinate dimensions, storage order: `[k, others…, c_k]`.
    pub outer_order: Vec<usize>,
    /// Intra-tile dimensions (projected axes), storage order:
    /// `[c_k, others ascending]`.
    pub inner_order: Vec<usize>,
    /// Storage extents: outer tile counts, inner tile sizes, then w.
    pub dims: Vec<i64>,
    /// Base element offset of this array in global memory.
    pub base: u64,
    /// Cached row-major strides of `dims` (the address-generation fast path
    /// never re-derives them).
    pub strides: Vec<u64>,
    /// Per **iteration axis**: the storage stride of the intra-tile dim that
    /// axis maps to (`iter_stride[axis] == 1`, the thickness dim). Feeds the
    /// run cursor's affine walker directly.
    pub iter_stride: Vec<u64>,
}

impl FacetArray {
    /// Elements allocated.
    pub fn size(&self) -> u64 {
        self.dims.iter().map(|&d| d as u64).product()
    }

    /// Human-readable layout, e.g. `facet_1[jj][ii][kk][k][i][j:2]`.
    pub fn describe(&self, names: &[&str]) -> String {
        let nm = |a: usize| names.get(a).copied().unwrap_or("?");
        let mut s = format!("facet_{}", nm(self.axis));
        for &o in &self.outer_order {
            s.push_str(&format!("[{}{}]", nm(o), nm(o)));
        }
        for &i in &self.inner_order {
            s.push_str(&format!("[{}]", nm(i)));
        }
        s.push_str(&format!("[{}:{}]", nm(self.axis), self.w));
        s
    }
}

/// Canonical Facet Allocation over a tiling and a backwards pattern.
#[derive(Clone, Debug)]
pub struct Cfa {
    tiling: Tiling,
    deps: DepPattern,
    facets: Vec<FacetArray>,
    /// axis → index into `facets` (None for inactive axes). Replaces the
    /// linear `facets.iter().position(..)` scan on the planning hot path.
    facet_of_axis: Vec<Option<usize>>,
    opts: CfaOpts,
    total: u64,
}

/// Construction errors.
#[derive(Debug, thiserror::Error)]
pub enum CfaError {
    #[error("facet width {w} exceeds tile size {t} along axis {axis}: flow would reach beyond the adjacent tile")]
    WidthExceedsTile { axis: usize, w: i64, t: i64 },
    #[error("dependence pattern has no active axis (no inter-tile flow)")]
    NoActiveAxis,
}

impl Cfa {
    pub fn new(tiling: Tiling, deps: DepPattern) -> Result<Cfa, CfaError> {
        Cfa::with_opts(tiling, deps, CfaOpts::default())
    }

    pub fn with_opts(tiling: Tiling, deps: DepPattern, opts: CfaOpts) -> Result<Cfa, CfaError> {
        let d = tiling.dims();
        let active = deps.active_axes();
        if active.is_empty() {
            return Err(CfaError::NoActiveAxis);
        }
        for &k in &active {
            let (w, t) = (deps.width(k), tiling.tile[k]);
            if w > t {
                return Err(CfaError::WidthExceedsTile { axis: k, w, t });
            }
        }
        let counts = tiling.tile_counts();
        let mut facets = Vec::with_capacity(active.len());
        let mut base = 0u64;
        for (pos, &k) in active.iter().enumerate() {
            // Cyclic contiguity-axis assignment over the active axes; if the
            // next active axis is k itself (single active axis) fall back to
            // any projected axis.
            let contig = if d == 1 {
                None
            } else {
                let next = active[(pos + 1) % active.len()];
                Some(if next != k {
                    next
                } else {
                    (0..d).find(|&a| a != k).unwrap()
                })
            };
            // outer: k first (single-assignment dim), then the rest with the
            // contiguity axis last (fastest-varying).
            let mut outer: Vec<usize> = vec![k];
            let mut rest: Vec<usize> = (0..d).filter(|&a| a != k).collect();
            if let Some(c) = contig {
                rest.retain(|&a| a != c);
                outer.extend(rest.iter().copied());
                outer.push(c);
            } else {
                outer.extend(rest.iter().copied());
            }
            // inner: contiguity axis first (slowest intra), rest ascending.
            let mut inner: Vec<usize> = Vec::new();
            if let Some(c) = contig {
                inner.push(c);
                inner.extend((0..d).filter(|&a| a != k && a != c));
            }
            let w = deps.width(k);
            let mut dims: Vec<i64> = outer.iter().map(|&o| counts[o]).collect();
            dims.extend(inner.iter().map(|&i| tiling.tile[i]));
            dims.push(w);
            let strides = crate::layout::strides(&dims);
            // map every iteration axis to the stride of its intra storage
            // dim: inner axes in order, then the facet axis (stride 1, the
            // fastest dim). outer dims carry tile coordinates, not axes.
            let mut iter_stride = vec![0u64; d];
            for (i, &ax) in inner.iter().enumerate() {
                iter_stride[ax] = strides[outer.len() + i];
            }
            iter_stride[k] = 1;
            let fa = FacetArray {
                axis: k,
                contig,
                w,
                outer_order: outer,
                inner_order: inner,
                dims,
                base,
                strides,
                iter_stride,
            };
            base += fa.size();
            facets.push(fa);
        }
        let mut facet_of_axis = vec![None; d];
        for (fi, fa) in facets.iter().enumerate() {
            facet_of_axis[fa.axis] = Some(fi);
        }
        Ok(Cfa {
            tiling,
            deps,
            facets,
            facet_of_axis,
            opts,
            total: base,
        })
    }

    pub fn facet_arrays(&self) -> &[FacetArray] {
        &self.facets
    }

    pub fn deps(&self) -> &DepPattern {
        &self.deps
    }

    /// Index of the facet array for axis k (precomputed table, O(1)).
    fn facet_index(&self, axis: usize) -> Option<usize> {
        self.facet_of_axis[axis]
    }

    /// Start of the w-tail along `axis` of the tile with coordinate `tck`
    /// on that axis (clamped tiles keep a w-thick tail unless thinner than
    /// w). Allocation-free: only the one axis matters.
    fn tail_start_axis(&self, tck: i64, axis: usize) -> i64 {
        let t = self.tiling.tile[axis];
        let lo = tck * t;
        let hi = (lo + t).min(self.tiling.space[axis]);
        (hi - self.deps.width(axis)).max(lo)
    }

    /// Start of the w-tail of tile `tc` along `axis`.
    fn tail_start(&self, tc: &[i64], axis: usize) -> i64 {
        self.tail_start_axis(tc[axis], axis)
    }

    /// Map an iteration box contained in one tile's k-tail to the facet
    /// array's coordinate box (array dims order).
    fn box_to_array(&self, fi: usize, tc: &[i64], bx: &Rect) -> Rect {
        let fa = &self.facets[fi];
        let trect = self.tiling.tile_rect(tc);
        let tail0 = self.tail_start(tc, fa.axis);
        debug_assert!(bx.lo[fa.axis] >= tail0, "box not inside facet tail");
        let mut lo = Vec::with_capacity(fa.dims.len());
        let mut hi = Vec::with_capacity(fa.dims.len());
        for &o in &fa.outer_order {
            lo.push(tc[o]);
            hi.push(tc[o] + 1);
        }
        for &i in &fa.inner_order {
            lo.push(bx.lo[i] - trect.lo[i]);
            hi.push(bx.hi[i] - trect.lo[i]);
        }
        lo.push(bx.lo[fa.axis] - tail0);
        hi.push(bx.hi[fa.axis] - tail0);
        Rect::new(lo, hi)
    }

    /// The whole data tile of tile `tc` in facet `fi` (actual extents —
    /// boundary tiles underfill their allocation).
    fn data_tile_box(&self, fi: usize, tc: &[i64]) -> Rect {
        let fa = &self.facets[fi];
        let trect = self.tiling.tile_rect(tc);
        let mut facet_rect = trect.clone();
        facet_rect.lo[fa.axis] = self.tail_start(tc, fa.axis);
        self.box_to_array(fi, tc, &facet_rect)
    }

    /// Split a flow region into per-producer-tile boxes, each annotated
    /// with its *crossing axes*: the axes along which the producer tile
    /// differs from the consumer (the neighbor level of §IV.D). The box is
    /// guaranteed to sit in the producer's tail along every crossing axis
    /// (appendix theorem), so any of them selects a facet holding it —
    /// crossing axes, not incidental tail membership, are what determine
    /// the mergeable facet (§IV.H).
    fn split_by_producer(
        &self,
        region: &crate::poly::rect::Region,
        consumer: &[i64],
    ) -> Vec<(IVec, Rect, Vec<usize>)> {
        let mut out = Vec::new();
        for r in region.rects() {
            let lo_t = self.tiling.tile_of(&r.lo);
            let hi_pt: IVec = r.hi.iter().map(|h| h - 1).collect();
            let hi_t = self.tiling.tile_of(&hi_pt);
            let trange = Rect::new(lo_t, hi_t.iter().map(|c| c + 1).collect());
            trange.for_each_point(&mut |tc| {
                let sub = r.intersect(&self.tiling.tile_rect(tc));
                if sub.is_empty() {
                    return;
                }
                let crossing: Vec<usize> = (0..self.tiling.dims())
                    .filter(|&a| tc[a] != consumer[a])
                    .collect();
                debug_assert!(!crossing.is_empty(), "flow-in piece inside consumer");
                for &a in &crossing {
                    debug_assert!(
                        sub.lo[a] >= self.tail_start(tc, a),
                        "coverage violation: {sub:?} not in tail {a} of {tc:?}"
                    );
                }
                out.push((tc.to_vec(), sub, crossing));
            });
        }
        out
    }

    /// Choose which facet serves a flow-in piece (§IV.H–I).
    fn choose_facet(&self, tc: &[i64], bx: &Rect, tails: &[usize]) -> usize {
        let axis = match tails.len() {
            0 => unreachable!("piece outside all tails"),
            1 => tails[0],
            2 => {
                let (a, b) = (tails[0], tails[1]);
                let ca = self.facets[self.facet_index(a).unwrap()].contig;
                let cb = self.facets[self.facet_index(b).unwrap()].contig;
                if ca == Some(b) {
                    a
                } else if cb == Some(a) {
                    b
                } else {
                    a
                }
            }
            _ if !self.opts.intra_tile => tails[0],
            _ => {
                // k-th level piece: pick the facet whose layout yields the
                // fewest runs (in 3D this reproduces the S_3 suffix trick).
                *tails
                    .iter()
                    .min_by_key(|&&a| {
                        let fi = self.facet_index(a).unwrap();
                        let abox = self.box_to_array(fi, tc, bx);
                        runs_of_box(&abox, &self.facets[fi].dims, 0).len()
                    })
                    .unwrap()
            }
        };
        self.facet_index(axis).unwrap()
    }
}

impl Allocation for Cfa {
    fn name(&self) -> &str {
        "cfa"
    }

    fn tiling(&self) -> &Tiling {
        &self.tiling
    }

    fn footprint(&self) -> u64 {
        self.total
    }

    fn regions(&self) -> Vec<(u64, u64)> {
        // one contiguous region per facet array — the natural channel
        // repartition the paper's §VII anticipates
        self.facets.iter().map(|f| (f.base, f.size())).collect()
    }

    fn num_arrays(&self) -> usize {
        self.facets.len()
    }

    fn holds(&self, array: usize, p: &[i64]) -> bool {
        let fa = &self.facets[array];
        if !self.tiling.in_space(p) {
            return false;
        }
        let tck = p[fa.axis].div_euclid(self.tiling.tile[fa.axis]);
        p[fa.axis] >= self.tail_start_axis(tck, fa.axis)
    }

    fn addr_of(&self, array: usize, p: &[i64]) -> u64 {
        assert!(self.holds(array, p), "facet {array} does not hold {p:?}");
        let fa = &self.facets[array];
        let inner0 = fa.outer_order.len();
        let mut addr = fa.base;
        for (o, &ax) in fa.outer_order.iter().enumerate() {
            let tc = p[ax].div_euclid(self.tiling.tile[ax]);
            addr += tc as u64 * fa.strides[o];
        }
        for (i, &ax) in fa.inner_order.iter().enumerate() {
            let lo = p[ax].div_euclid(self.tiling.tile[ax]) * self.tiling.tile[ax];
            addr += (p[ax] - lo) as u64 * fa.strides[inner0 + i];
        }
        let k = fa.axis;
        let tck = p[k].div_euclid(self.tiling.tile[k]);
        // thickness dim is the fastest storage dim (stride 1)
        addr + (p[k] - self.tail_start_axis(tck, k)) as u64
    }

    fn plan(&self, coords: &[i64]) -> TilePlan {
        let fin = flow_in(&self.tiling, &self.deps, coords);
        // useful writes = the facet union (no double counting of corner
        // points duplicated across facet arrays; see layout::write_set).
        let wset = crate::layout::write_set(&self.tiling, &self.deps, coords);
        let mut plan = TilePlan {
            read_useful: fin.volume(),
            write_useful: wset.volume(),
            ..TilePlan::default()
        };

        // ---- reads: assign pieces to facets, over-approximate, linearize
        let pieces = self.split_by_producer(&fin, coords);
        // (facet, producer) -> hull of array boxes (Fig 11 rectangular
        // over-approximation), plus the exact pieces for marshaling.
        let mut groups: Vec<(usize, IVec, Rect)> = Vec::new();
        for (tc, bx, tails) in &pieces {
            let fi = self.choose_facet(tc, bx, tails);
            plan.read_pieces.push(Piece {
                array: fi,
                iter_box: bx.clone(),
            });
            let abox = self.box_to_array(fi, tc, bx);
            if self.opts.bbox_expand {
                if let Some(g) = groups
                    .iter_mut()
                    .find(|(gfi, gtc, _)| *gfi == fi && gtc == tc)
                {
                    g.2 = g.2.hull(&abox);
                    continue;
                }
            }
            groups.push((fi, tc.clone(), abox));
        }
        // Fig 11 rectangular over-approximation: widen each facet read to a
        // *single contiguous run* of its data tile. Scanning the intra
        // dimensions in storage order, every dimension after the first one
        // with extent > 1 is widened to the full tile extent; leading
        // singleton dimensions stay fixed. A few redundant elements are
        // transferred (counted in raw, not useful), and a read ending at
        // the tail of its data tile becomes address-adjacent to the next
        // data tile along the contiguity axis, so merge_runs fuses the
        // extension with the neighboring facet read (§IV.H) — this is what
        // keeps an interior 3-D tile at ~4 read transactions.
        if self.opts.bbox_expand {
            for (fi, _tc, abox) in groups.iter_mut() {
                let fa = &self.facets[*fi];
                let inner0 = fa.outer_order.len();
                let q = (inner0..abox.dims()).find(|&k| abox.extent(k) > 1);
                if let Some(q) = q {
                    // widen q to the end of the data tile (suffix form) and
                    // everything after it fully: the box becomes one run
                    // that terminates at the data-tile boundary, where it
                    // can fuse with the next data tile's read.
                    abox.hi[q] = fa.dims[q];
                    for k in q + 1..abox.dims() {
                        abox.lo[k] = 0;
                        abox.hi[k] = fa.dims[k];
                    }
                }
            }
        }
        let mut read_runs = Vec::new();
        for (fi, _, abox) in &groups {
            let fa = &self.facets[*fi];
            let mut rs = runs_of_box(abox, &fa.dims, fa.base);
            if self.opts.inter_tile {
                read_runs.append(&mut rs);
            } else {
                // no cross-tile merging: each group keeps its own bursts
                merge_runs(&mut rs);
                plan.read_runs.append(&mut rs);
            }
        }
        if self.opts.inter_tile {
            merge_runs(&mut read_runs);
            plan.read_runs = read_runs;
        }

        // ---- writes: every facet of this tile, one data tile each (§IV.A:
        // all write accesses are bursts).
        for (fi, fa) in self.facets.iter().enumerate() {
            let dt = self.data_tile_box(fi, coords);
            if dt.is_empty() {
                continue;
            }
            let mut rs = runs_of_box(&dt, &fa.dims, fa.base);
            merge_runs(&mut rs);
            plan.write_runs.append(&mut rs);
            let trect = self.tiling.tile_rect(coords);
            let mut facet_rect = trect.clone();
            facet_rect.lo[fa.axis] = self.tail_start(coords, fa.axis);
            plan.write_pieces.push(Piece {
                array: fi,
                iter_box: facet_rect,
            });
        }
        plan
    }

    fn read_loc(&self, p: &[i64]) -> (usize, u64) {
        for (fi, fa) in self.facets.iter().enumerate() {
            let tck = p[fa.axis].div_euclid(self.tiling.tile[fa.axis]);
            if p[fa.axis] >= self.tail_start_axis(tck, fa.axis) {
                return (fi, self.addr_of(fi, p));
            }
        }
        panic!("point {p:?} is in no facet (not a flow point)");
    }

    fn write_locs(&self, p: &[i64]) -> Vec<(usize, u64)> {
        let mut out = Vec::new();
        self.for_each_write_loc(p, &mut |array, addr| out.push((array, addr)));
        out
    }

    fn for_each_write_loc(&self, p: &[i64], f: &mut dyn FnMut(usize, u64)) {
        for (fi, fa) in self.facets.iter().enumerate() {
            let tck = p[fa.axis].div_euclid(self.tiling.tile[fa.axis]);
            if p[fa.axis] >= self.tail_start_axis(tck, fa.axis) {
                f(fi, self.addr_of(fi, p));
            }
        }
    }

    fn for_each_run(&self, array: usize, bx: &Rect, f: &mut dyn FnMut(u64, u64)) {
        if bx.is_empty() {
            return;
        }
        let one_tile = (0..bx.dims()).all(|a| {
            let t = self.tiling.tile[a];
            bx.lo[a].div_euclid(t) == (bx.hi[a] - 1).div_euclid(t)
        });
        if !one_tile {
            // valid per the trait contract but outside the affine fast path
            // (plan pieces never span tiles): coalesce per-point addresses
            // so the method stays total instead of emitting wrong runs
            crate::layout::coalesce_point_runs(self, array, bx, f);
            return;
        }
        let fa = &self.facets[array];
        // inside one tile the facet address map is affine in p, with the
        // cached per-axis strides; anchor at the box origin
        let base = self.addr_of(array, &bx.lo);
        affine_runs(bx, &fa.iter_stride, base, f);
    }

    fn rebase_plan(&self, plan: &TilePlan, from: &[i64], to: &[i64]) -> Option<TilePlan> {
        // Per-facet address delta: the outer storage dims hold tile
        // coordinates, so a tile translation moves every address of facet
        // fi by a constant — but a *different* constant per facet (their
        // outer orders differ). Runs carry no array tag, so attribute each
        // run to the unique facet whose address range contains it; interior
        // tiles never produce runs that straddle a facet boundary.
        let deltas: Vec<i64> = self
            .facets
            .iter()
            .map(|fa| {
                fa.outer_order
                    .iter()
                    .enumerate()
                    .map(|(o, &ax)| (to[ax] - from[ax]) * fa.strides[o] as i64)
                    .sum()
            })
            .collect();
        let mv_runs = |runs: &[Run]| -> Option<Vec<Run>> {
            let mut out = Vec::with_capacity(runs.len());
            for r in runs {
                let fi = self
                    .facets
                    .iter()
                    .position(|fa| r.addr >= fa.base && r.end() <= fa.base + fa.size())?;
                out.push(Run {
                    addr: (r.addr as i64 + deltas[fi]) as u64,
                    len: r.len,
                });
            }
            Some(out)
        };
        let shift: IVec = (0..self.tiling.dims())
            .map(|k| (to[k] - from[k]) * self.tiling.tile[k])
            .collect();
        let mv_pieces = |pieces: &[Piece]| -> Vec<Piece> {
            pieces
                .iter()
                .map(|pc| Piece {
                    array: pc.array,
                    iter_box: pc.iter_box.shift(&shift),
                })
                .collect()
        };
        Some(TilePlan {
            read_runs: mv_runs(&plan.read_runs)?,
            write_runs: mv_runs(&plan.write_runs)?,
            read_pieces: mv_pieces(&plan.read_pieces),
            write_pieces: mv_pieces(&plan.write_pieces),
            read_useful: plan.read_useful,
            write_useful: plan.write_useful,
        })
    }

    fn addrgen(&self) -> AddrGenProfile {
        let mut prof = AddrGenProfile {
            arrays: self.facets.len(),
            ..AddrGenProfile::default()
        };
        for fa in &self.facets {
            let st = &fa.strides;
            // off-chip base address: one multiply-add per outer dim
            for (k, _) in fa.outer_order.iter().enumerate() {
                let s = st[k];
                if s > 1 {
                    if s.is_power_of_two() {
                        prof.shift_ops += 1;
                    } else {
                        prof.mul_ops += 1;
                    }
                    prof.add_ops += 1;
                }
            }
            // on-chip address reconstruction per beat (Fig 12): the copy
            // loop divides the linear counter back into intra coordinates.
            prof.div_mod_ops += fa.inner_order.len();
            prof.add_ops += fa.inner_order.len() + 1;
            let vol: u64 = fa.dims[fa.outer_order.len()..]
                .iter()
                .map(|&x| x as u64)
                .product();
            prof.counter_bits += 64 - vol.leading_zeros() as usize;
        }
        // representative interior tile for the FSM burst count
        let counts = self.tiling.tile_counts();
        let mid: IVec = counts.iter().map(|&c| (c - 1).min(1)).collect();
        prof.bursts_per_tile = self.plan(&mid).transactions() as f64;
        prof
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::deps::DepPattern;
    use crate::util::prop::{run, Config};

    /// Fig-5-like configuration: 3D, 5^3 tiles, w = (1, 2, 2).
    fn fig5() -> Cfa {
        let tiling = Tiling::new(vec![15, 15, 15], vec![5, 5, 5]);
        let deps = DepPattern::new(vec![
            vec![-1, 0, 0],
            vec![0, -2, 0],
            vec![0, 0, -2],
            vec![-1, -1, -1],
        ])
        .unwrap();
        Cfa::new(tiling, deps).unwrap()
    }

    #[test]
    fn facet_arrays_have_paper_structure() {
        let cfa = fig5();
        let f = cfa.facet_arrays();
        assert_eq!(f.len(), 3);
        // facet_i: replication dim first, cyclic contiguity axis j
        assert_eq!(f[0].axis, 0);
        assert_eq!(f[0].contig, Some(1));
        assert_eq!(f[0].outer_order, vec![0, 2, 1]);
        assert_eq!(f[0].inner_order, vec![1, 2]);
        assert_eq!(f[0].w, 1);
        // dims: counts (3,3,3) then inner (5,5) then w=1
        assert_eq!(f[0].dims, vec![3, 3, 3, 5, 5, 1]);
        // facet_j: c_j = k
        assert_eq!(f[1].axis, 1);
        assert_eq!(f[1].contig, Some(2));
        assert_eq!(f[1].outer_order, vec![1, 0, 2]);
        assert_eq!(f[1].inner_order, vec![2, 0]);
        assert_eq!(f[1].dims, vec![3, 3, 3, 5, 5, 2]);
        // facet_k: c_k = i (cyclic wrap)
        assert_eq!(f[2].axis, 2);
        assert_eq!(f[2].contig, Some(0));
        assert_eq!(f[2].outer_order, vec![2, 1, 0]);
        assert_eq!(f[2].inner_order, vec![0, 1]);
    }

    #[test]
    fn footprint_is_sum_of_facets() {
        let cfa = fig5();
        let expect: u64 = 27 * 25 * 1 + 27 * 25 * 2 + 27 * 25 * 2;
        assert_eq!(cfa.footprint(), expect);
    }

    #[test]
    fn describe_is_readable() {
        let cfa = fig5();
        let d = cfa.facet_arrays()[1].describe(&["i", "j", "k"]);
        assert_eq!(d, "facet_j[jj][ii][kk][k][i][j:2]");
    }

    #[test]
    fn width_exceeding_tile_is_error() {
        let tiling = Tiling::new(vec![10], vec![2]);
        let deps = DepPattern::new(vec![vec![-3]]).unwrap();
        assert!(matches!(
            Cfa::new(tiling, deps),
            Err(CfaError::WidthExceedsTile { .. })
        ));
    }

    #[test]
    fn addr_bijective_within_each_facet() {
        let cfa = fig5();
        for fi in 0..cfa.num_arrays() {
            let mut seen = std::collections::HashSet::new();
            for p in cfa.tiling().space_rect().points() {
                if cfa.holds(fi, &p) {
                    let a = cfa.addr_of(fi, &p);
                    assert!(seen.insert(a), "address {a} reused (facet {fi}, {p:?})");
                    assert!(a < cfa.footprint());
                }
            }
        }
    }

    #[test]
    fn single_assignment_across_tiles() {
        // facet address ranges of distinct tiles are disjoint: collect the
        // write runs of every tile and check for overlap.
        let cfa = fig5();
        let mut intervals: Vec<(u64, u64)> = Vec::new();
        for tc in cfa.tiling().tiles() {
            for r in cfa.plan(&tc).write_runs {
                intervals.push((r.addr, r.end()));
            }
        }
        intervals.sort();
        for w in intervals.windows(2) {
            assert!(w[0].1 <= w[1].0, "write overlap: {w:?}");
        }
    }

    #[test]
    fn flow_out_facet_writes_are_single_bursts() {
        // full-tile contiguity (§IV.G): interior tiles write each facet in
        // exactly one transaction.
        let cfa = fig5();
        let plan = cfa.plan(&[1, 1, 1]);
        assert_eq!(plan.write_runs.len(), 3, "{:?}", plan.write_runs);
        // sizes: 25*w
        let mut lens: Vec<u64> = plan.write_runs.iter().map(|r| r.len).collect();
        lens.sort_unstable();
        assert_eq!(lens, vec![25, 50, 50]);
    }

    #[test]
    fn interior_tile_reads_are_few_long_bursts() {
        // the paper's "small number of burst transfers per tile (4 in the
        // case of 3-dimensional tiles)".
        let cfa = fig5();
        let plan = cfa.plan(&[1, 1, 1]);
        assert!(
            plan.read_runs.len() <= 4,
            "expected <=4 read bursts, got {:?}",
            plan.read_runs
        );
        assert!(plan.read_raw() >= plan.read_useful);
    }

    #[test]
    fn plan_reads_cover_flow_in() {
        // every flow-in point's canonical address is covered by a read run,
        // and every read piece's points are covered too.
        let cfa = fig5();
        for tc in cfa.tiling().tiles() {
            let plan = cfa.plan(&tc);
            let covered = |a: u64| plan.read_runs.iter().any(|r| a >= r.addr && a < r.end());
            for pc in &plan.read_pieces {
                for p in pc.iter_box.points() {
                    let a = cfa.addr_of(pc.array, &p);
                    assert!(covered(a), "tile {tc:?}: point {p:?} addr {a} uncovered");
                }
            }
            let fin = flow_in(cfa.tiling(), cfa.deps(), &tc);
            let piece_vol: u64 = plan.read_pieces.iter().map(|p| p.iter_box.volume()).sum();
            assert_eq!(piece_vol, fin.volume(), "pieces partition flow-in");
        }
    }

    #[test]
    fn write_pieces_cover_flow_out() {
        let cfa = fig5();
        for tc in cfa.tiling().tiles() {
            let plan = cfa.plan(&tc);
            let fout = crate::poly::flow::flow_out(cfa.tiling(), cfa.deps(), &tc);
            for p in fout.all_points() {
                let held = plan
                    .write_pieces
                    .iter()
                    .any(|pc| pc.iter_box.contains(&p));
                assert!(held, "flow-out point {p:?} of tile {tc:?} not written");
            }
        }
    }

    #[test]
    fn read_and_write_locs_agree() {
        // the canonical read location of a flow point is among its write
        // locations (the coordinator relies on this).
        let cfa = fig5();
        for p in cfa.tiling().space_rect().points() {
            let locs = cfa.write_locs(&p);
            if locs.is_empty() {
                continue; // interior point, never leaves chip
            }
            let rl = cfa.read_loc(&p);
            assert!(locs.contains(&rl), "{p:?}: {rl:?} not in {locs:?}");
        }
    }

    #[test]
    fn ablation_options_change_transaction_count() {
        let tiling = Tiling::new(vec![20, 20, 20], vec![5, 5, 5]);
        let deps = DepPattern::new(vec![
            vec![-1, 0, 0],
            vec![0, -2, 0],
            vec![0, 0, -2],
            vec![-1, -2, -2],
        ])
        .unwrap();
        let full = Cfa::with_opts(tiling.clone(), deps.clone(), CfaOpts::default()).unwrap();
        let no_inter = Cfa::with_opts(
            tiling.clone(),
            deps.clone(),
            CfaOpts {
                inter_tile: false,
                ..CfaOpts::default()
            },
        )
        .unwrap();
        let mid = vec![2, 2, 2];
        let t_full = full.plan(&mid).read_runs.len();
        let t_no_inter = no_inter.plan(&mid).read_runs.len();
        assert!(
            t_full <= t_no_inter,
            "inter-tile merging should not increase bursts ({t_full} vs {t_no_inter})"
        );
    }

    #[test]
    fn run_cursor_matches_pointwise_addr_of() {
        let cfa = fig5();
        for tc in cfa.tiling().tiles() {
            let plan = cfa.plan(&tc);
            for pc in plan.read_pieces.iter().chain(&plan.write_pieces) {
                let mut from_runs: Vec<u64> = Vec::new();
                cfa.for_each_run(pc.array, &pc.iter_box, &mut |a, l| {
                    from_runs.extend(a..a + l)
                });
                let per_point: Vec<u64> = pc
                    .iter_box
                    .points()
                    .map(|p| cfa.addr_of(pc.array, &p))
                    .collect();
                assert_eq!(from_runs, per_point, "tile {tc:?} piece {pc:?}");
            }
        }
    }

    #[test]
    fn rebase_matches_fresh_plan_on_interior_tiles() {
        let tiling = Tiling::new(vec![20, 20, 20], vec![5, 5, 5]);
        let deps = DepPattern::new(vec![
            vec![-1, 0, 0],
            vec![0, -2, 0],
            vec![0, 0, -2],
            vec![-1, -1, -1],
        ])
        .unwrap();
        let cfa = Cfa::new(tiling, deps).unwrap();
        let from = vec![1, 1, 1];
        let canon = cfa.plan(&from);
        for to in [vec![1, 1, 1], vec![1, 1, 2], vec![2, 2, 2], vec![2, 1, 1]] {
            let rebased = cfa.rebase_plan(&canon, &from, &to).unwrap();
            assert_eq!(rebased, cfa.plan(&to), "rebase {from:?} -> {to:?}");
        }
    }

    #[test]
    fn addrgen_profile_is_populated() {
        let prof = fig5().addrgen();
        assert_eq!(prof.arrays, 3);
        assert!(prof.add_ops > 0);
        assert!(prof.bursts_per_tile >= 1.0);
        assert!(prof.counter_bits > 0);
    }

    #[test]
    fn prop_cfa_invariants_random() {
        run("CFA invariants on random configs", Config::small(25), |g| {
            let d = g.usize(2, 3);
            let tile: IVec = (0..d).map(|_| g.i64(2, 4)).collect();
            let space: IVec = tile.iter().map(|t| t * g.i64(2, 3)).collect();
            let tiling = Tiling::new(space, tile.clone());
            let mut vecs = Vec::new();
            for _ in 0..g.usize(1, 3) {
                let v: IVec = (0..d).map(|k| g.i64(-tile[k].min(2), 0)).collect();
                if !crate::poly::vec::is_zero(&v) {
                    vecs.push(v);
                }
            }
            if vecs.is_empty() {
                return;
            }
            let deps = DepPattern::new(vecs).unwrap();
            let cfa = match Cfa::new(tiling.clone(), deps.clone()) {
                Ok(c) => c,
                Err(_) => return,
            };
            for tc in tiling.tiles() {
                let plan = cfa.plan(&tc);
                // raw >= useful on both directions
                assert!(plan.read_raw() >= plan.read_useful);
                assert!(plan.write_raw() >= plan.write_useful);
                // planned reads cover every piece point
                for pc in &plan.read_pieces {
                    for p in pc.iter_box.points() {
                        let a = cfa.addr_of(pc.array, &p);
                        assert!(
                            plan.read_runs.iter().any(|r| a >= r.addr && a < r.end()),
                            "uncovered read {p:?} tile {tc:?}"
                        );
                    }
                }
                // all runs within footprint
                for r in plan.read_runs.iter().chain(&plan.write_runs) {
                    assert!(r.end() <= cfa.footprint());
                }
            }
        });
    }
}
