//! Original-layout baseline (Bayliss et al. [16], §VI.A.1).
//!
//! The program's arrays keep their original row-major layout (the
//! single-assignment expanded iteration space, as polyhedral HLS flows
//! produce); a *best-effort* burst access pattern is derived: the exact
//! flow-in/flow-out sets are transferred with **no redundancy**, coalescing
//! only where the unchanged layout happens to be contiguous. This gives the
//! shortest bursts of all baselines but a perfect raw = effective ratio.

use crate::layout::{
    dot, row_major_rebase, row_major_runs, runs_of_region, write_set, AddrGenProfile,
    Allocation, Piece, TilePlan,
};
use crate::poly::deps::DepPattern;
use crate::poly::flow::flow_in;
use crate::poly::rect::Rect;
use crate::poly::tiling::Tiling;

/// Row-major allocation of the full iteration space.
#[derive(Clone, Debug)]
pub struct OriginalLayout {
    tiling: Tiling,
    deps: DepPattern,
    /// Cached row-major strides of the space (fast-path addressing).
    st: Vec<u64>,
}

impl OriginalLayout {
    pub fn new(tiling: Tiling, deps: DepPattern) -> OriginalLayout {
        let st = crate::layout::strides(&tiling.space);
        OriginalLayout { tiling, deps, st }
    }
}

impl Allocation for OriginalLayout {
    fn name(&self) -> &str {
        "original"
    }

    fn tiling(&self) -> &Tiling {
        &self.tiling
    }

    fn footprint(&self) -> u64 {
        self.tiling.space_rect().volume()
    }

    fn num_arrays(&self) -> usize {
        1
    }

    fn holds(&self, array: usize, p: &[i64]) -> bool {
        array == 0 && self.tiling.in_space(p)
    }

    fn addr_of(&self, array: usize, p: &[i64]) -> u64 {
        assert!(self.holds(array, p));
        dot(p, &self.st)
    }

    fn plan(&self, coords: &[i64]) -> TilePlan {
        let fin = flow_in(&self.tiling, &self.deps, coords);
        let fout = write_set(&self.tiling, &self.deps, coords);
        let read_runs = runs_of_region(&fin, &self.tiling.space, 0);
        let write_runs = runs_of_region(&fout, &self.tiling.space, 0);
        TilePlan {
            read_useful: fin.volume(),
            write_useful: fout.volume(),
            read_pieces: fin
                .rects()
                .iter()
                .map(|r| Piece {
                    array: 0,
                    iter_box: r.clone(),
                })
                .collect(),
            write_pieces: fout
                .rects()
                .iter()
                .map(|r| Piece {
                    array: 0,
                    iter_box: r.clone(),
                })
                .collect(),
            read_runs,
            write_runs,
        }
    }

    fn read_loc(&self, p: &[i64]) -> (usize, u64) {
        (0, self.addr_of(0, p))
    }

    fn write_locs(&self, p: &[i64]) -> Vec<(usize, u64)> {
        vec![(0, self.addr_of(0, p))]
    }

    fn for_each_write_loc(&self, p: &[i64], f: &mut dyn FnMut(usize, u64)) {
        f(0, self.addr_of(0, p));
    }

    fn for_each_run(&self, array: usize, bx: &Rect, f: &mut dyn FnMut(u64, u64)) {
        debug_assert_eq!(array, 0);
        row_major_runs(&self.st, bx, f);
    }

    fn rebase_plan(&self, plan: &TilePlan, from: &[i64], to: &[i64]) -> Option<TilePlan> {
        row_major_rebase(&self.tiling, &self.deps, &self.st, plan, from, to)
    }

    fn addrgen(&self) -> AddrGenProfile {
        let d = self.tiling.dims();
        let st = &self.st;
        let mut prof = AddrGenProfile {
            arrays: 1,
            ..AddrGenProfile::default()
        };
        // the scattered access pattern needs a full affine address
        // computation per burst start (one mul-add per dimension)
        for &s in st {
            if s > 1 {
                if s.is_power_of_two() {
                    prof.shift_ops += 1;
                } else {
                    prof.mul_ops += 1;
                }
                prof.add_ops += 1;
            }
        }
        prof.add_ops += d;
        prof.counter_bits = 64 - self.footprint().leading_zeros() as usize;
        let counts = self.tiling.tile_counts();
        let mid: Vec<i64> = counts.iter().map(|&c| (c - 1).min(1)).collect();
        prof.bursts_per_tile = self.plan(&mid).transactions() as f64;
        prof
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::deps::DepPattern;
    use crate::poly::vec::IVec;

    fn setup() -> OriginalLayout {
        let tiling = Tiling::new(vec![12, 12], vec![4, 4]);
        let deps = DepPattern::new(vec![vec![-1, 0], vec![0, -1], vec![-1, -1]]).unwrap();
        OriginalLayout::new(tiling, deps)
    }

    #[test]
    fn no_redundancy_ever() {
        let o = setup();
        for tc in o.tiling().tiles() {
            let plan = o.plan(&tc);
            assert_eq!(plan.read_raw(), plan.read_useful, "tile {tc:?}");
            assert_eq!(plan.write_raw(), plan.write_useful, "tile {tc:?}");
        }
    }

    #[test]
    fn bursts_are_short_rows() {
        // flow-in of an interior tile: a column piece (one element per row,
        // 4+1 rows) and a row piece (contiguous). Expect several short runs.
        let o = setup();
        let plan = o.plan(&[1, 1]);
        assert!(plan.read_runs.len() >= 4, "{:?}", plan.read_runs);
        // every run is within the footprint
        for r in &plan.read_runs {
            assert!(r.end() <= o.footprint());
        }
    }

    #[test]
    fn addresses_are_row_major() {
        let o = setup();
        assert_eq!(o.addr_of(0, &[0, 0]), 0);
        assert_eq!(o.addr_of(0, &[0, 11]), 11);
        assert_eq!(o.addr_of(0, &[1, 0]), 12);
        assert_eq!(o.read_loc(&[2, 3]), (0, 27));
        assert_eq!(o.write_locs(&[2, 3]), vec![(0, 27)]);
    }

    #[test]
    fn plan_covers_flow_in_addresses() {
        let o = setup();
        for tc in o.tiling().tiles() {
            let plan = o.plan(&tc);
            for pc in &plan.read_pieces {
                for p in pc.iter_box.points() {
                    let a = o.addr_of(0, &p);
                    assert!(plan.read_runs.iter().any(|r| a >= r.addr && a < r.end()));
                }
            }
        }
    }

    #[test]
    fn contiguous_flow_merges() {
        // 1-D space: flow-in along the only axis is contiguous → 1 burst.
        let tiling = Tiling::new(vec![12], vec![4]);
        let deps = DepPattern::new(vec![vec![-2]]).unwrap();
        let o = OriginalLayout::new(tiling, deps);
        let plan = o.plan(&[1]);
        assert_eq!(plan.read_runs.len(), 1);
        assert_eq!(plan.read_runs[0].len, 2);
    }

    #[test]
    fn footprint_is_space_volume() {
        let o = setup();
        assert_eq!(o.footprint(), 144);
        let mid: IVec = vec![1, 1];
        assert!(o.plan(&mid).transactions() > 0);
    }
}
