//! Bounding-box baseline (Pouchet et al. [8], §VI.A.1).
//!
//! The layout stays row-major, but each tile transfers the **rectangular
//! bounding box** of its flow-in / flow-out sets, trading redundant traffic
//! for long bursts: rows of the box are contiguous, and boxes covering full
//! trailing dimensions collapse into single transactions. The unused part
//! of the box is transferred and discarded (the grey area of Fig 15).

use crate::layout::{
    dot, merge_runs, row_major_rebase, row_major_runs, runs_of_box, write_set, AddrGenProfile,
    Allocation, Piece, TilePlan,
};
use crate::poly::deps::DepPattern;
use crate::poly::flow::flow_in;
use crate::poly::rect::Rect;
use crate::poly::tiling::Tiling;

/// Row-major allocation with bounding-box transfers.
#[derive(Clone, Debug)]
pub struct BoundingBox {
    tiling: Tiling,
    deps: DepPattern,
    /// Cached row-major strides of the space (fast-path addressing).
    st: Vec<u64>,
}

impl BoundingBox {
    pub fn new(tiling: Tiling, deps: DepPattern) -> BoundingBox {
        let st = crate::layout::strides(&tiling.space);
        BoundingBox { tiling, deps, st }
    }
}

impl Allocation for BoundingBox {
    fn name(&self) -> &str {
        "bbox"
    }

    fn tiling(&self) -> &Tiling {
        &self.tiling
    }

    fn footprint(&self) -> u64 {
        self.tiling.space_rect().volume()
    }

    fn num_arrays(&self) -> usize {
        1
    }

    fn holds(&self, array: usize, p: &[i64]) -> bool {
        array == 0 && self.tiling.in_space(p)
    }

    fn addr_of(&self, array: usize, p: &[i64]) -> u64 {
        assert!(self.holds(array, p));
        dot(p, &self.st)
    }

    fn plan(&self, coords: &[i64]) -> TilePlan {
        let fin = flow_in(&self.tiling, &self.deps, coords);
        let fout = write_set(&self.tiling, &self.deps, coords);
        let mut plan = TilePlan {
            read_useful: fin.volume(),
            write_useful: fout.volume(),
            ..TilePlan::default()
        };
        if let Some(bb) = fin.bbox() {
            plan.read_runs = runs_of_box(&bb, &self.tiling.space, 0);
            merge_runs(&mut plan.read_runs);
            // marshaling still moves only the useful points
            plan.read_pieces = fin
                .rects()
                .iter()
                .map(|r| Piece {
                    array: 0,
                    iter_box: r.clone(),
                })
                .collect();
        }
        if let Some(bb) = fout.bbox() {
            plan.write_runs = runs_of_box(&bb, &self.tiling.space, 0);
            merge_runs(&mut plan.write_runs);
            plan.write_pieces = fout
                .rects()
                .iter()
                .map(|r| Piece {
                    array: 0,
                    iter_box: r.clone(),
                })
                .collect();
        }
        plan
    }

    fn read_loc(&self, p: &[i64]) -> (usize, u64) {
        (0, self.addr_of(0, p))
    }

    fn write_locs(&self, p: &[i64]) -> Vec<(usize, u64)> {
        vec![(0, self.addr_of(0, p))]
    }

    fn for_each_write_loc(&self, p: &[i64], f: &mut dyn FnMut(usize, u64)) {
        f(0, self.addr_of(0, p));
    }

    fn for_each_run(&self, array: usize, bx: &Rect, f: &mut dyn FnMut(u64, u64)) {
        debug_assert_eq!(array, 0);
        row_major_runs(&self.st, bx, f);
    }

    fn rebase_plan(&self, plan: &TilePlan, from: &[i64], to: &[i64]) -> Option<TilePlan> {
        row_major_rebase(&self.tiling, &self.deps, &self.st, plan, from, to)
    }

    fn addrgen(&self) -> AddrGenProfile {
        // Same affine generator as the original layout, but fewer burst
        // starts (one box per direction).
        let mut prof = OriginalProfileHelper::profile(&self.tiling);
        let counts = self.tiling.tile_counts();
        let mid: Vec<i64> = counts.iter().map(|&c| (c - 1).min(1)).collect();
        prof.bursts_per_tile = self.plan(&mid).transactions() as f64;
        prof
    }
}

/// Shared affine-addressing cost for row-major baselines.
pub(crate) struct OriginalProfileHelper;

impl OriginalProfileHelper {
    pub(crate) fn profile(tiling: &Tiling) -> AddrGenProfile {
        let st = crate::layout::strides(&tiling.space);
        let mut prof = AddrGenProfile {
            arrays: 1,
            ..AddrGenProfile::default()
        };
        for &s in &st {
            if s > 1 {
                if s.is_power_of_two() {
                    prof.shift_ops += 1;
                } else {
                    prof.mul_ops += 1;
                }
                prof.add_ops += 1;
            }
        }
        prof.add_ops += tiling.dims();
        let fp: u64 = tiling.space_rect().volume();
        prof.counter_bits = 64 - fp.leading_zeros() as usize;
        prof
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::deps::DepPattern;

    fn setup() -> BoundingBox {
        let tiling = Tiling::new(vec![12, 12], vec![4, 4]);
        let deps = DepPattern::new(vec![vec![-1, 0], vec![0, -1], vec![-1, -1]]).unwrap();
        BoundingBox::new(tiling, deps)
    }

    #[test]
    fn redundancy_present_for_interior_tiles() {
        let b = setup();
        let plan = b.plan(&[1, 1]);
        // flow-in is an L-shaped halo; its bbox strictly contains it
        assert!(plan.read_raw() > plan.read_useful);
        assert!(plan.read_useful > 0);
    }

    #[test]
    fn fewer_bursts_than_original() {
        let b = setup();
        let o = crate::layout::original::OriginalLayout::new(
            b.tiling().clone(),
            DepPattern::new(vec![vec![-1, 0], vec![0, -1], vec![-1, -1]]).unwrap(),
        );
        use crate::layout::Allocation as _;
        let pb = b.plan(&[1, 1]);
        let po = o.plan(&[1, 1]);
        assert!(
            pb.read_runs.len() <= po.read_runs.len(),
            "bbox {} vs original {}",
            pb.read_runs.len(),
            po.read_runs.len()
        );
    }

    #[test]
    fn bbox_runs_cover_every_flow_in_address() {
        let b = setup();
        for tc in b.tiling().tiles() {
            let plan = b.plan(&tc);
            for pc in &plan.read_pieces {
                for p in pc.iter_box.points() {
                    let a = b.addr_of(0, &p);
                    assert!(plan.read_runs.iter().any(|r| a >= r.addr && a < r.end()));
                }
            }
        }
    }

    #[test]
    fn corner_tile_has_empty_plan() {
        let b = setup();
        let plan = b.plan(&[0, 0]);
        assert!(plan.read_runs.is_empty());
        assert_eq!(plan.read_useful, 0);
    }

    #[test]
    fn useful_never_exceeds_raw() {
        let b = setup();
        for tc in b.tiling().tiles() {
            let plan = b.plan(&tc);
            assert!(plan.read_raw() >= plan.read_useful);
            assert!(plan.write_raw() >= plan.write_useful);
        }
    }
}
