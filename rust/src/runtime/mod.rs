//! PJRT runtime: load AOT artifacts and execute tile programs from Rust.
//!
//! The build-time Python side (`python/compile/aot.py`) lowers each
//! (benchmark, tile size) L2 program to HLO **text** plus a
//! `manifest.json`; this module loads both, compiles each module once on
//! the PJRT CPU client, and exposes typed tile execution. Python is never
//! on this path — the binary is self-contained once `artifacts/` exists.
//!
//! The real client (the `xla` crate) is only compiled under the **`pjrt`
//! feature**, which is off by default so the tier-1 build needs neither
//! the crate nor `artifacts/`. Without the feature this module exposes an
//! API-compatible stub whose [`Runtime::open`] fails with a clear message,
//! so every driver (`experiment::e2e`, `main.rs`, the examples) compiles
//! unchanged on the default feature set. Enabling
//! `pjrt` additionally requires adding the vendored `xla` dependency to
//! `Cargo.toml` (see DESIGN.md §Runtime).

/// Parsed manifest entry for one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub kind: String,
    pub file: String,
    /// Tile sizes: stencil (tt, ti, tj) / sw3 (si, sj, sk).
    pub tile: Vec<i64>,
    /// Stencil halo radius r (0 for sw3).
    pub radius: i64,
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::ArtifactInfo;
    use crate::util::json::{self, Json};
    use anyhow::{anyhow, bail, Context, Result};
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};
    use std::rc::Rc;

    impl ArtifactInfo {
        fn from_json(name: &str, j: &Json) -> Result<ArtifactInfo> {
            let get_str = |k: &str| {
                j.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| anyhow!("manifest entry {name}: missing '{k}'"))
            };
            let tile = j
                .get("tile")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("manifest entry {name}: missing 'tile'"))?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0) as i64)
                .collect();
            Ok(ArtifactInfo {
                name: name.to_string(),
                kind: get_str("kind")?,
                file: get_str("file")?,
                tile,
                radius: j.get("radius").and_then(|v| v.as_f64()).unwrap_or(0.0) as i64,
            })
        }
    }

    /// A compiled tile program.
    pub struct TileExecutable {
        pub info: ArtifactInfo,
        exe: xla::PjRtLoadedExecutable,
    }

    impl TileExecutable {
        /// Execute with scalar i32 inputs followed by f32 tensor inputs.
        /// Returns the flattened f32 outputs in tuple order.
        pub fn execute(
            &self,
            scalars: &[i32],
            tensors: &[(&[f32], &[i64])],
        ) -> Result<Vec<Vec<f32>>> {
            let mut args: Vec<xla::Literal> = Vec::with_capacity(scalars.len() + tensors.len());
            for &s in scalars {
                args.push(xla::Literal::scalar(s));
            }
            for (data, shape) in tensors {
                let expect: i64 = shape.iter().product();
                if expect != data.len() as i64 {
                    bail!(
                        "tensor data length {} does not match shape {:?}",
                        data.len(),
                        shape
                    );
                }
                args.push(xla::Literal::vec1(data).reshape(shape)?);
            }
            let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(p.to_vec::<f32>()?);
            }
            Ok(out)
        }
    }

    /// PJRT CPU runtime holding compiled artifacts.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        manifest: BTreeMap<String, ArtifactInfo>,
        compiled: std::cell::RefCell<BTreeMap<String, Rc<TileExecutable>>>,
    }

    impl Runtime {
        /// Open an artifacts directory (reads `manifest.json`).
        pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
            let dir = dir.as_ref().to_path_buf();
            let mpath = dir.join("manifest.json");
            let text = std::fs::read_to_string(&mpath).with_context(|| {
                format!("reading {} (run `make artifacts` first)", mpath.display())
            })?;
            let parsed = json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
            let mut manifest = BTreeMap::new();
            if let Json::Obj(entries) = &parsed {
                for (name, j) in entries {
                    manifest.insert(name.clone(), ArtifactInfo::from_json(name, j)?);
                }
            } else {
                bail!("manifest.json: expected an object");
            }
            let client = xla::PjRtClient::cpu()?;
            Ok(Runtime {
                client,
                dir,
                manifest,
                compiled: Default::default(),
            })
        }

        /// Platform string (for diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Artifact names available.
        pub fn artifacts(&self) -> Vec<&str> {
            self.manifest.keys().map(|s| s.as_str()).collect()
        }

        pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
            self.manifest.get(name)
        }

        /// Load + compile an artifact (cached after the first call).
        pub fn load(&self, name: &str) -> Result<Rc<TileExecutable>> {
            if let Some(e) = self.compiled.borrow().get(name) {
                return Ok(e.clone());
            }
            let info = self
                .manifest
                .get(name)
                .ok_or_else(|| {
                    anyhow!("unknown artifact '{name}' (have: {:?})", self.artifacts())
                })?
                .clone();
            let path = self.dir.join(&info.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let te = Rc::new(TileExecutable { info, exe });
            self.compiled
                .borrow_mut()
                .insert(name.to_string(), te.clone());
            Ok(te)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{Runtime, TileExecutable};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::ArtifactInfo;
    use anyhow::{bail, Result};
    use std::path::Path;
    use std::rc::Rc;

    const DISABLED: &str = "the PJRT tile-compute runtime is disabled: rebuild with \
         `--features pjrt` (and the vendored `xla` crate wired into Cargo.toml)";

    /// Stub of the compiled tile program (`pjrt` feature disabled).
    pub struct TileExecutable {
        pub info: ArtifactInfo,
    }

    impl TileExecutable {
        /// Always fails: there is no compute backend in this build.
        pub fn execute(
            &self,
            _scalars: &[i32],
            _tensors: &[(&[f32], &[i64])],
        ) -> Result<Vec<Vec<f32>>> {
            bail!("{DISABLED}")
        }
    }

    /// Stub runtime (`pjrt` feature disabled): `open` fails with a clear
    /// message, so drivers compile unchanged and report the situation at
    /// run time instead of poisoning the offline build with `xla`. The
    /// private field keeps the type unconstructible outside this module,
    /// so the accessors below are genuinely unreachable.
    pub struct Runtime(());

    impl Runtime {
        pub fn open(_dir: impl AsRef<Path>) -> Result<Runtime> {
            bail!("{DISABLED}")
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }

        pub fn artifacts(&self) -> Vec<&str> {
            Vec::new()
        }

        pub fn info(&self, _name: &str) -> Option<&ArtifactInfo> {
            None
        }

        pub fn load(&self, _name: &str) -> Result<Rc<TileExecutable>> {
            bail!("{DISABLED}")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{Runtime, TileExecutable};

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if d.join("manifest.json").exists() {
            Some(d)
        } else {
            None
        }
    }

    #[test]
    fn manifest_parses() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(&dir).expect("open runtime");
        assert!(rt.artifacts().len() >= 5);
        let info = rt.info("jacobi2d5p_t4x16x16").expect("jacobi artifact");
        assert_eq!(info.kind, "stencil");
        assert_eq!(info.tile, vec![4, 16, 16]);
        assert_eq!(info.radius, 1);
    }

    #[test]
    fn stencil_tile_executes_and_matches_shape() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(&dir).unwrap();
        let exe = rt.load("jacobi2d5p_t4x16x16").unwrap();
        let (tt, ti, tj) = (4usize, 16usize, 16usize);
        let h = 2usize;
        let prev = vec![0.25f32; (ti + h) * (tj + h)];
        let halo_u = vec![0f32; (tt - 1) * h * (tj + h)];
        let halo_v = vec![0f32; (tt - 1) * ti * h];
        let out = exe
            .execute(
                &[0, 0, 0, 1_000_000, 1_000_000], // huge grid: no masking
                &[
                    (&prev, &[(ti + h) as i64, (tj + h) as i64]),
                    (&halo_u, &[(tt - 1) as i64, h as i64, (tj + h) as i64]),
                    (&halo_v, &[(tt - 1) as i64, ti as i64, h as i64]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].len(), ti * tj);
        assert_eq!(out[1].len(), tt * h * tj);
        assert_eq!(out[2].len(), tt * ti * h);
        // constant input, averaging stencil, interior far from halos:
        // first-step interior cells stay 0.25
        let facet_t = &out[0];
        // the facet_t center is influenced by the (zero) halos after 4
        // steps? halo reach = 2 per step * 4 = 8 < 16 - keep to the center
        let center = facet_t[(ti / 2) * tj + tj / 2];
        assert!((center - 0.25).abs() < 1e-5, "center {center}");
    }

    #[test]
    fn unknown_artifact_is_error() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(&dir).unwrap();
        assert!(rt.load("nope").is_err());
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn open_reports_disabled_feature() {
        let err = Runtime::open("artifacts").expect_err("stub must not open");
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
    }
}
