//! `cfa` — command-line front end for the Canonical Facet Allocation stack.
//!
//! Subcommands:
//!   list       Table I benchmark registry
//!   layouts    the open layout registry (canonical names + aliases)
//!   plan       show an allocation's layout + burst plan for a benchmark/tile
//!   run        end-to-end run (layout + memsim + PJRT compute + verify)
//!   bench      regenerate a figure sweep (fig15 | fig16 | fig17)
//!   tune       design-space exploration (tiling x layout x memory), resumable,
//!              shardable (--shard I/N) and early-abort prunable (--prune)
//!   merge      fold shard journals into one (fingerprint dedup)
//!   serve      persistent multi-tenant autotuning daemon (shared compiled-state caches)
//!   codegen    emit the HLS C the compiler pass produces (Fig 12/13)
//!
//! Every experiment-shaped subcommand goes through the `experiment`
//! session API: spec → session → report. Layouts are named through the
//! registry, so a newly registered layout is immediately reachable from
//! `--alloc` and enumerated by `--alloc all` / the bench sweeps.

use cfa::coordinator::reference::StencilKind;
use cfa::dse::{Exhaustive, Explorer, HillClimb, ModelGuided, RandomSearch, Space, Strategy};
use cfa::experiment::{ExperimentSpec, Mode, Session};
use cfa::harness::{figures, workloads};
use cfa::layout::cfa::Cfa;
use cfa::layout::registry;
use cfa::memsim::{MemConfig, Striping};
use cfa::poly::deps::DepPattern;
use cfa::poly::tiling::Tiling;
use cfa::runtime::Runtime;
use cfa::util::cli::{env_args, Command};
use cfa::util::table::{Align, Table};

fn main() {
    // deterministic fault injection (robustness tests / CI fault-smoke):
    // no-op unless CFA_FAULTS is set
    if let Err(e) = cfa::util::faults::arm_from_env() {
        eprintln!("error: CFA_FAULTS: {e:#}");
        std::process::exit(1);
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sub = args.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match sub {
        "list" => cmd_list(),
        "layouts" => cmd_layouts(),
        "plan" => cmd_plan(),
        "run" => cmd_run(),
        "bench" => cmd_bench(),
        "tune" => cmd_tune(),
        "merge" => cmd_merge(),
        "serve" => cmd_serve(),
        "codegen" => cmd_codegen(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "cfa — Canonical Facet Allocation (Ferry et al., 2022) reproduction\n\n\
         usage: cfa <subcommand> [options]\n\n\
         subcommands:\n\
         \x20 list                 print the Table I benchmark registry\n\
         \x20 layouts              print the layout registry (canonical names + aliases)\n\
         \x20 plan                 show layout + burst plan (--benchmark, --tile, --alloc)\n\
         \x20 run                  end-to-end verified run (--benchmark, --alloc, --channels N, --striping P, --parallel N,\n\
         \x20                      --timeline PATH --epoch-cycles N for a per-epoch bandwidth timeline, ...)\n\
         \x20 bench                figure sweeps (--figure fig15|fig16|fig17, --quick, --parallel N, --json PATH)\n\
         \x20 tune                 design-space exploration (--space, --strategy exhaustive|random|hill|model-guided,\n\
         \x20                      --budget, --parallel, --channels LIST, --striping LIST, --mem PRESETS,\n\
         \x20                      --out, --resume, --no-retry-failed, --deadline-secs N, --trace-cache,\n\
         \x20                      --prune for early-abort replay, --shard I/N, --warm-start JOURNAL,\n\
         \x20                      --profile PATH for a span trace)\n\
         \x20 merge                fold shard journals into one (cfa merge OUT IN...; --space for\n\
         \x20                      enumeration-order output; success records supersede failures)\n\
         \x20 serve                persistent autotuning daemon over line-delimited JSON\n\
         \x20                      (--addr HOST:PORT | --stdio, --workers N, --queue N);\n\
         \x20                      tenants share one session + trace cache across requests\n\
         \x20 codegen              emit HLS C (--benchmark, --tile)\n\n\
         layouts are named through the open registry (`cfa layouts`); every\n\
         --alloc option accepts a canonical name, an alias, or 'all'.\n"
    );
}

fn cmd_list() -> anyhow::Result<()> {
    let mut t = Table::new(&["benchmark", "deps", "tile sweep", "equivalent application"])
        .aligns(&[Align::Left, Align::Right, Align::Left, Align::Left]);
    for w in workloads::table1(false) {
        let first = &w.tile_sizes[0];
        let last = w.tile_sizes.last().unwrap();
        let fmt = |v: &Vec<i64>| {
            v.iter()
                .map(|x| x.to_string())
                .collect::<Vec<_>>()
                .join("x")
        };
        t.row(&[
            w.name.to_string(),
            w.n_deps().to_string(),
            format!("{} -> {}", fmt(first), fmt(last)),
            w.equivalent.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_layouts() -> anyhow::Result<()> {
    let reg = registry::global();
    let mut t = Table::new(&["layout", "aliases"]).aligns(&[Align::Left, Align::Left]);
    for e in reg.iter() {
        t.row(&[e.name().to_string(), e.aliases().join(", ")]);
    }
    println!("{}", t.render());
    println!("({} layouts registered)", reg.len());
    Ok(())
}

fn cmd_plan() -> anyhow::Result<()> {
    let cmd = Command::new("cfa plan", "show layout + burst plan")
        .opt("benchmark", "Table I benchmark name", Some("jacobi2d5p"))
        .opt("tile", "tile sizes, e.g. 16x16x16", Some("16x16x16"))
        .opt("alloc", "layout name (see `cfa layouts`)", Some("cfa"))
        .opt("tiles-per-dim", "tiles per dimension", Some("3"));
    let a = cmd.parse(&env_args(1)).map_err(anyhow::Error::msg)?;
    let bench = a.get_or("benchmark", "jacobi2d5p").to_string();
    let tile = a
        .get_sizes("tile")
        .map_err(anyhow::Error::msg)?
        .unwrap();
    let tpd = a.get_usize("tiles-per-dim", 3).map_err(anyhow::Error::msg)? as i64;
    let w = workloads::by_name(&bench)
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark '{bench}' (see `cfa list`)"))?;
    let deps = DepPattern::new(w.deps.clone())?;
    let tiling = Tiling::new(w.space_for(&tile, tpd), tile.clone());
    let reg = registry::global();
    let layout = a.get_or("alloc", "cfa");
    use cfa::layout::Allocation as _;
    // build the allocation exactly once; the CFA path goes through the
    // concrete type first so the facet arrays printed below are the ones
    // the plan two steps later actually uses
    let mut facet_lines: Vec<String> = Vec::new();
    let alloc: Box<dyn cfa::layout::Allocation> =
        if reg.canonical(layout) == Some(registry::names::CFA) {
            let built = Cfa::new(tiling.clone(), deps.clone())?;
            let axis_names: Vec<&str> = (0..tiling.dims())
                .map(|d| cfa::hlsgen::AXIS_NAMES[d])
                .collect();
            for fa in built.facet_arrays() {
                facet_lines.push(format!(
                    "  {}  ({} elems)",
                    fa.describe(&axis_names),
                    fa.size()
                ));
            }
            Box::new(built)
        } else {
            reg.build(layout, &tiling, &deps)?
        };
    println!("benchmark: {} ({})", w.name, w.equivalent);
    println!("deps: {deps}   widths: {:?}", deps.widths());
    println!("space: {:?}  tile: {:?}\n", tiling.space, tiling.tile);
    println!(
        "layout: {} ({} arrays, {} elements off-chip)",
        alloc.name(),
        alloc.num_arrays(),
        alloc.footprint()
    );
    if !facet_lines.is_empty() {
        println!("facet arrays:");
        for line in &facet_lines {
            println!("{line}");
        }
    }
    let counts = tiling.tile_counts();
    let mid: Vec<i64> = counts.iter().map(|&c| (c - 1).min(1)).collect();
    let plan = alloc.plan(&mid);
    println!("\ninterior tile {mid:?} plan:");
    println!(
        "  reads : {} bursts, {} elems raw / {} useful",
        plan.read_runs.len(),
        plan.read_raw(),
        plan.read_useful
    );
    for r in &plan.read_runs {
        println!("    @{:<10} len {}", r.addr, r.len);
    }
    println!(
        "  writes: {} bursts, {} elems raw / {} useful",
        plan.write_runs.len(),
        plan.write_raw(),
        plan.write_useful
    );
    for r in &plan.write_runs {
        println!("    @{:<10} len {}", r.addr, r.len);
    }
    Ok(())
}

/// Build the end-to-end session for one benchmark name + layout. Tile
/// shapes come from the loaded artifact (as the legacy drivers did), so
/// regenerated artifacts are picked up without touching this table;
/// `--n`/`--steps` override the grid, validated at compile.
/// Tile shape for an artifact: from the loaded artifact when a runtime
/// is open, else parsed from the `_t8x32x32` suffix every artifact name
/// carries (timing-only runs never touch the artifacts directory).
fn artifact_tile(rt: Option<&Runtime>, artifact: &str) -> anyhow::Result<Vec<i64>> {
    if let Some(rt) = rt {
        return Ok(rt.load(artifact)?.info.tile.clone());
    }
    artifact
        .rsplit_once("_t")
        .and_then(|(_, dims)| {
            let tile: Option<Vec<i64>> = dims.split('x').map(|d| d.parse().ok()).collect();
            tile.filter(|t| !t.is_empty() && t.iter().all(|&d| d > 0))
        })
        .ok_or_else(|| anyhow::anyhow!("artifact '{artifact}' has no _t<dims> tile suffix"))
}

fn run_session(
    rt: Option<&Runtime>,
    bench: &str,
    layout: &str,
    n_override: Option<i64>,
    steps_override: Option<i64>,
    parallel: usize,
    mem: &MemConfig,
    channels: usize,
    striping: &Striping,
) -> anyhow::Result<(Session, u64)> {
    let builder = ExperimentSpec::builder()
        .layout(layout)
        .threads(parallel)
        .pe_ops_per_cycle(64)
        .mem(mem.clone())
        .channels(channels)
        .striping(striping.clone());
    Ok(match bench {
        "sw3" | "smith-waterman-3seq" => {
            let artifact = "sw3_t16x16x16";
            let tile = artifact_tile(rt, artifact)?;
            let n = n_override.unwrap_or(48);
            let session = builder.sw3(artifact, tile, n, n, n).compile()?;
            (session, 7)
        }
        name => {
            let (artifact, kind) = match name {
                "jacobi2d5p" => ("jacobi2d5p_t8x32x32", StencilKind::Jacobi5p),
                "jacobi2d9p" => ("jacobi2d9p_t4x16x16", StencilKind::Jacobi9p),
                "gaussian" => ("gaussian_t4x16x16", StencilKind::Gaussian),
                _ => anyhow::bail!("unknown benchmark '{name}' (see `cfa list`)"),
            };
            let tile = artifact_tile(rt, artifact)?;
            // grid defaults sized for each artifact family
            let (mut n, mut steps) = if name == "jacobi2d5p" {
                (96, 32)
            } else {
                let r = kind.radius();
                (32 - r * 8, 8)
            };
            if let Some(x) = n_override {
                n = x;
            }
            if let Some(x) = steps_override {
                steps = x;
            }
            let session = builder.stencil(artifact, kind, tile, n, n, steps).compile()?;
            (session, 42)
        }
    })
}

fn cmd_run() -> anyhow::Result<()> {
    let cmd = Command::new("cfa run", "end-to-end verified run")
        .opt("benchmark", "jacobi2d5p | jacobi2d9p | gaussian | sw3", Some("jacobi2d5p"))
        .opt("alloc", "layout name (see `cfa layouts`) or 'all'", Some("all"))
        .opt("artifacts", "artifacts directory", Some("artifacts"))
        .opt("n", "grid rows (stencils) / seq len (sw3)", None)
        .opt("steps", "time steps (stencils)", None)
        .opt("parallel", "worker threads for burst planning", Some("1"))
        .opt("channels", "memory channels (>1 runs the timing model, no data verify)", Some("1"))
        .opt("striping", "channel striping: address[:BYTES] | facet | tile", Some("address:4096"))
        .opt("timeline", "write a per-epoch bandwidth timeline JSON to PATH (timing model: no data verify, no artifacts needed)", None)
        .opt("epoch-cycles", "timeline epoch length in bus cycles", Some("4096"));
    let a = cmd.parse(&env_args(1)).map_err(anyhow::Error::msg)?;
    let parallel = a.get_usize("parallel", 1).map_err(anyhow::Error::msg)?;
    // --timeline runs the timing model only: no compute backend and no
    // artifacts directory needed, so it works in offline (pjrt-disabled)
    // builds — the CI obs-smoke job relies on this
    let rt = if a.get("timeline").is_some() {
        None
    } else {
        Some(Runtime::open(a.get_or("artifacts", "artifacts"))?)
    };
    if let Some(rt) = &rt {
        println!("PJRT platform: {}", rt.platform());
    }
    let mem = MemConfig {
        elem_bytes: 4,
        ..MemConfig::default()
    };
    let reg = registry::global();
    let layouts: Vec<String> = match a.get_or("alloc", "all") {
        "all" => reg.names().iter().map(|s| s.to_string()).collect(),
        s => vec![reg
            .canonical(s)
            .ok_or_else(|| anyhow::anyhow!("unknown layout '{s}' (see `cfa layouts`)"))?
            .to_string()],
    };
    let n_override = match a.get("n") {
        Some(v) => Some(v.parse().map_err(|_| anyhow::anyhow!("bad --n"))?),
        None => None,
    };
    let steps_override = match a.get("steps") {
        Some(v) => Some(v.parse().map_err(|_| anyhow::anyhow!("bad --steps"))?),
        None => None,
    };
    let channels = a.get_usize("channels", 1).map_err(anyhow::Error::msg)?;
    let striping = Striping::parse(a.get_or("striping", "address:4096"))?;
    striping
        .validate(mem.elem_bytes)
        .map_err(|e| anyhow::anyhow!("--striping: {e}"))?;
    let timeline_path = a.get("timeline").map(str::to_string);
    let epoch_cycles = a.get_usize("epoch-cycles", 4096).map_err(anyhow::Error::msg)? as u64;
    if timeline_path.is_some() && layouts.len() > 1 {
        anyhow::bail!("--timeline writes one file; pick a single layout with --alloc");
    }
    let bench = a.get_or("benchmark", "jacobi2d5p").to_string();
    for layout in layouts {
        let (session, seed) = run_session(
            rt.as_ref(),
            &bench,
            layout.as_str(),
            n_override,
            steps_override,
            parallel,
            &mem,
            channels,
            &striping,
        )?;
        // the data path drives a single memory interface; multi-channel
        // sessions report the timing model instead of verifying data, as
        // do --timeline runs (the sampler rides the timing replay)
        let report = if let Some(path) = &timeline_path {
            let trace = session.compile_trace();
            let (report, tl) = session.run_trace_with_timeline(&trace, epoch_cycles)?;
            let useful_ratio = if report.raw_bytes == 0 {
                0.0
            } else {
                report.useful_bytes as f64 / report.raw_bytes as f64
            };
            cfa::util::fsx::write_atomic(path, tl.to_json(&mem, useful_ratio).to_string_pretty())?;
            let epochs: usize = tl.channels.iter().map(Vec::len).sum();
            // the "sum exactly" identity is asserted inside
            // run_trace_with_timeline; reaching this line proves it held
            println!(
                "timeline: wrote {path} ({} channel(s), {epochs} epochs of {} cycles; \
                 epoch sums match aggregate timing)",
                tl.channels.len(),
                tl.epoch_cycles
            );
            report
        } else if channels > 1 {
            session.run(Mode::Timing)?
        } else {
            let rt = rt.as_ref().expect("runtime is open unless --timeline");
            session.run_with_runtime(rt, Mode::Data { seed })?
        };
        println!("{}", report.summary());
        if report.max_abs_err.unwrap_or(0.0) > 1e-4 {
            anyhow::bail!(
                "verification FAILED: err {:.3e}",
                report.max_abs_err.unwrap_or(0.0)
            );
        }
    }
    if timeline_path.is_some() {
        println!("timing-only run (--timeline): data verify skipped");
    } else if channels > 1 {
        println!("timing-only run ({channels} channels, {striping} striping): data verify skipped");
    } else {
        println!("verification: OK");
    }
    Ok(())
}

fn cmd_bench() -> anyhow::Result<()> {
    let cmd = Command::new("cfa bench", "figure sweeps")
        .opt("figure", "fig15 | fig16 | fig17", Some("fig15"))
        .flag("quick", "restrict tile sweep")
        .opt("parallel", "worker threads for the sweep", Some("1"))
        .opt("out", "CSV output path", None)
        .opt("json", "machine-readable JSON output path", None);
    let a = cmd.parse(&env_args(1)).map_err(anyhow::Error::msg)?;
    let quick = a.flag("quick");
    let threads = a.get_usize("parallel", 1).map_err(anyhow::Error::msg)?;
    let wl = workloads::table1(quick);
    let mem = MemConfig::default();
    match a.get_or("figure", "fig15") {
        "fig15" => {
            let pts = figures::fig15_sweep_parallel(&wl, &mem, 3, threads);
            for w in &wl {
                print!("{}", figures::render_fig15(&pts, w.name, &mem));
            }
            if let Some(path) = a.get("out") {
                cfa::util::fsx::write_atomic(path, figures::fig15_csv(&pts))?;
                println!("wrote {path}");
            }
            if let Some(path) = a.get("json") {
                cfa::util::fsx::write_atomic(
                    path,
                    figures::fig15_json(&pts, &mem).to_string_pretty(),
                )?;
                println!("wrote {path}");
            }
        }
        "fig16" | "fig17" => {
            let pts = figures::area_sweep_parallel(&wl, mem.elem_bytes, 3, threads);
            if let Some(path) = a.get("out") {
                cfa::util::fsx::write_atomic(path, figures::area_csv(&pts))?;
                println!("wrote {path}");
            } else if a.get("json").is_none() {
                println!("{}", figures::area_csv(&pts));
            }
            if let Some(path) = a.get("json") {
                cfa::util::fsx::write_atomic(path, figures::area_json(&pts).to_string_pretty())?;
                println!("wrote {path}");
            }
        }
        f => anyhow::bail!("unknown figure '{f}'"),
    }
    Ok(())
}

/// `--space` resolution shared by `tune` and `merge`: a builtin name or a
/// JSON space file.
fn load_space(arg: &str) -> anyhow::Result<Space> {
    match Space::builtin(arg) {
        Some(s) => Ok(s),
        None => {
            let text = std::fs::read_to_string(arg).map_err(|e| {
                anyhow::anyhow!(
                    "--space '{arg}' is neither a builtin space nor a readable file: {e}"
                )
            })?;
            Space::parse(&text)
        }
    }
}

fn cmd_tune() -> anyhow::Result<()> {
    let cmd = Command::new("cfa tune", "design-space exploration")
        .opt(
            "space",
            "builtin (tiny | fig15 | fig15-quick | fig17 | fig17-quick) or a JSON file",
            Some("fig15-quick"),
        )
        .opt(
            "strategy",
            "exhaustive | random | hill | model-guided",
            Some("exhaustive"),
        )
        .opt("budget", "max new evaluations this run (0 = no cap)", Some("0"))
        .opt("parallel", "worker threads across points", Some("1"))
        .opt("seed", "seed for the random/hill strategies", Some("0"))
        .opt("out", "JSONL results journal path", Some("tune.jsonl"))
        .opt("resume", "journal to resume from (skips evaluated points)", None)
        .flag(
            "no-retry-failed",
            "skip journaled failures on resume instead of retrying them once",
        )
        .opt(
            "deadline-secs",
            "wall-clock deadline; the run stops cooperatively with a resumable journal (0 = none)",
            Some("0"),
        )
        .opt(
            "channels",
            "override the space's channel axis, comma-separated (e.g. 1,4)",
            None,
        )
        .opt(
            "striping",
            "override the space's striping axis, comma-separated (address[:BYTES] | facet | tile)",
            None,
        )
        .opt(
            "trace-cache",
            "reuse compiled txn traces across mem/PE variants (on | off; results identical)",
            Some("on"),
        )
        .opt(
            "mem",
            "override the space's memory axis with named geometry presets, comma-separated (zc706 | hbm | hbm-flat)",
            None,
        )
        .flag(
            "prune",
            "early-abort replays whose bandwidth upper bound the Pareto front already dominates (front is byte-identical; pruned points journal as resumable records)",
        )
        .opt(
            "shard",
            "own only shard I of N (I/N, 0-based): points are partitioned by fingerprint hash; fold shard journals with `cfa merge`",
            None,
        )
        .opt(
            "warm-start",
            "seed the model-guided strategy's training set from a prior tune journal (other strategies ignore it)",
            None,
        )
        .opt(
            "profile",
            "write a Chrome trace-event span profile (Perfetto-loadable) to PATH; journal bytes are unaffected",
            None,
        );
    let a = cmd.parse(&env_args(1)).map_err(anyhow::Error::msg)?;
    let mut space = load_space(a.get_or("space", "fig15-quick"))?;
    if let Some(list) = a.get("mem") {
        let mut mems = Vec::new();
        for part in list.split(',') {
            let name = part.trim();
            let cfg = MemConfig::preset(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "--mem: unknown preset '{name}' (known: {})",
                    MemConfig::preset_names().join(", ")
                )
            })?;
            mems.push(cfa::dse::MemVariant::new(name, cfg));
        }
        space.mems = mems;
    }
    if let Some(list) = a.get("channels") {
        let mut channels = Vec::new();
        for part in list.split(',') {
            let n: usize = part
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("--channels: '{part}' is not a channel count"))?;
            if n == 0 {
                anyhow::bail!("--channels entries must be >= 1");
            }
            channels.push(n);
        }
        space.channels = channels;
    }
    if let Some(list) = a.get("striping") {
        let mut stripings = Vec::new();
        for part in list.split(',') {
            stripings.push(Striping::parse(part.trim()).map_err(|e| anyhow::anyhow!("--striping: {e}"))?);
        }
        space.stripings = stripings;
    }
    // CLI front door: reject invalid striping x element-width combinations
    // here, with the flag named, rather than deep in enumeration
    for s in &space.stripings {
        for mv in &space.mems {
            s.validate(mv.cfg.elem_bytes).map_err(|e| {
                anyhow::anyhow!("--striping '{}' vs mem variant '{}': {e}", s.label(), mv.name)
            })?;
        }
    }
    let seed = a.get_usize("seed", 0).map_err(anyhow::Error::msg)? as u64;
    let strategy: Box<dyn Strategy> = match a.get_or("strategy", "exhaustive") {
        "exhaustive" => Box::new(Exhaustive::new()),
        "random" => Box::new(RandomSearch::new(seed)),
        "hill" | "hillclimb" => Box::new(HillClimb::new(seed)),
        "model-guided" | "model" => {
            let mut s = ModelGuided::new(seed);
            if let Some(path) = a.get("warm-start") {
                // salvage, not strict read: a warm-start journal is advice,
                // and a torn tail from a killed run must not block the tune
                let (records, _torn) =
                    cfa::dse::journal::read_salvage(std::path::Path::new(path))?;
                let rows: Vec<(cfa::dse::Point, f64)> = records
                    .iter()
                    .filter(|e| !e.is_failed() && !e.is_pruned())
                    .map(|e| (e.point().clone(), e.effective_mb_s()))
                    .collect();
                println!(
                    "warm-start: {} training rows from {path} ({} records)",
                    rows.len(),
                    records.len()
                );
                s = s.with_warm_start(rows);
            }
            Box::new(s)
        }
        s => anyhow::bail!("unknown strategy '{s}' (exhaustive | random | hill | model-guided)"),
    };
    let shard = match a.get("shard") {
        None => None,
        Some(spec) => {
            let (i, n) = spec
                .split_once('/')
                .and_then(|(i, n)| Some((i.parse::<usize>().ok()?, n.parse::<usize>().ok()?)))
                .ok_or_else(|| anyhow::anyhow!("--shard expects I/N (e.g. 0/4), got '{spec}'"))?;
            Some((i, n))
        }
    };
    let budget = a.get_usize("budget", 0).map_err(anyhow::Error::msg)?;
    let parallel = a.get_usize("parallel", 1).map_err(anyhow::Error::msg)?;
    let out = a.get_or("out", "tune.jsonl").to_string();
    let trace_cache = match a.get_or("trace-cache", "on") {
        "on" => true,
        "off" => false,
        s => anyhow::bail!("--trace-cache must be 'on' or 'off', got '{s}'"),
    };
    let deadline = a.get_usize("deadline-secs", 0).map_err(anyhow::Error::msg)?;
    // Ctrl-C / SIGTERM cancel cooperatively: the explorer stops at the
    // next point boundary, flushes the journal, and the summary carries
    // the `interrupted` marker instead of the process dying mid-append
    let token = cfa::dse::CancelToken::new();
    cfa::util::signals::watch(token.clone());
    let mut explorer = Explorer::new(space, strategy)
        .parallel(parallel)
        .journal(&out)
        .trace_cache(trace_cache)
        .retry_failed(!a.flag("no-retry-failed"))
        .prune(a.flag("prune"))
        .cancel_token(token);
    if let Some((i, n)) = shard {
        explorer = explorer.shard(i, n);
    }
    if budget > 0 {
        explorer = explorer.budget(budget);
    }
    if deadline > 0 {
        explorer = explorer.deadline_secs(deadline as u64);
    }
    if let Some(resume) = a.get("resume") {
        explorer = explorer.resume(resume);
    }
    // span capture encloses the whole exploration; wall time is advisory
    // and never feeds the journal (byte-identical with or without this)
    let profile = a.get("profile").map(str::to_string);
    let capture = profile.as_ref().map(|_| cfa::obs::begin_capture());
    let outcome = explorer.explore()?;
    if let (Some(cap), Some(path)) = (capture, &profile) {
        cap.export(path)?;
        println!("profile: wrote {path}");
    }
    print!("{}", outcome.summary());
    println!("journal: {out}");
    Ok(())
}

fn cmd_merge() -> anyhow::Result<()> {
    let cmd = Command::new("cfa merge", "fold shard journals into one")
        .opt(
            "space",
            "builtin name or JSON file: emit in-space records in enumeration order (byte-identical to an unsharded exhaustive journal)",
            None,
        );
    let a = cmd.parse(&env_args(1)).map_err(anyhow::Error::msg)?;
    if a.positional.len() < 2 {
        anyhow::bail!("usage: cfa merge OUT IN... [--space NAME|PATH]\n\n{}", cmd.usage());
    }
    let out = std::path::PathBuf::from(&a.positional[0]);
    let inputs: Vec<std::path::PathBuf> =
        a.positional[1..].iter().map(std::path::PathBuf::from).collect();
    let order = match a.get("space") {
        None => None,
        Some(arg) => Some(load_space(arg)?.enumerate(&registry::global())?),
    };
    let stats = cfa::dse::journal::merge(&out, &inputs, order.as_ref())?;
    println!(
        "merge: {} journals, {} records -> {} written to {} \
         ({} duplicates dropped, {} out-of-space, {} torn bytes ignored)",
        stats.inputs,
        stats.read,
        stats.written,
        out.display(),
        stats.duplicates,
        stats.out_of_space,
        stats.torn_bytes
    );
    Ok(())
}

fn cmd_serve() -> anyhow::Result<()> {
    let cmd = Command::new("cfa serve", "persistent multi-tenant autotuning service")
        .opt("addr", "TCP listen address", Some("127.0.0.1:7070"))
        .flag(
            "stdio",
            "serve one connection over stdin/stdout (tests/CI), then drain",
        )
        .opt(
            "workers",
            "worker threads for request execution (0 = one per core)",
            Some("0"),
        )
        .opt(
            "queue",
            "queued requests before backpressure ('rejected' replies)",
            Some("32"),
        );
    let a = cmd.parse(&env_args(1)).map_err(anyhow::Error::msg)?;
    let mut workers = a.get_usize("workers", 0).map_err(anyhow::Error::msg)?;
    if workers == 0 {
        workers = cfa::util::par::default_threads();
    }
    let depth = a.get_usize("queue", 32).map_err(anyhow::Error::msg)?;
    if depth == 0 {
        anyhow::bail!("--queue must be >= 1");
    }
    if a.flag("stdio") {
        cfa::serve::serve_stdio(workers, depth)
    } else {
        cfa::serve::serve_tcp(a.get_or("addr", "127.0.0.1:7070"), workers, depth)
    }
}

fn cmd_codegen() -> anyhow::Result<()> {
    let cmd = Command::new("cfa codegen", "emit HLS C")
        .opt("benchmark", "Table I benchmark name", Some("jacobi2d5p"))
        .opt("tile", "tile sizes", Some("16x16x16"))
        .opt("out", "output .c path", None);
    let a = cmd.parse(&env_args(1)).map_err(anyhow::Error::msg)?;
    let bench = a.get_or("benchmark", "jacobi2d5p").to_string();
    let tile = a.get_sizes("tile").map_err(anyhow::Error::msg)?.unwrap();
    let w = workloads::by_name(&bench)
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark '{bench}'"))?;
    let deps = DepPattern::new(w.deps.clone())?;
    let tiling = Tiling::new(w.space_for(&tile, 3), tile);
    let cfa = Cfa::new(tiling, deps)?;
    let code = cfa::hlsgen::generate_c(&cfa, &bench);
    match a.get("out") {
        Some(p) => {
            std::fs::write(p, code)?;
            println!("wrote {p}");
        }
        None => print!("{code}"),
    }
    Ok(())
}
