//! FPGA area model (§VI.B.3): logic slices, DSP blocks and block RAM for
//! the read/write engines of Fig 14.
//!
//! The paper reports *synthesized* area on a xc7z045ffg900-2. We model the
//! same quantities analytically from the address-generator structure each
//! allocation exposes ([`crate::layout::AddrGenProfile`]) plus the on-chip
//! buffer footprint:
//!
//! * **slices** — AXI read/write engine FSMs (fixed base per engine) plus
//!   adders, counters and comparators of the address generators; div/mod
//!   units synthesized to logic.
//! * **DSP** — wide multiplications by non-power-of-two strides ("CFA
//!   requires some DSP blocks … used to compute off-chip base addresses",
//!   never more than ~4%).
//! * **BRAM** — the on-chip buffers holding a tile's flow-in/flow-out data
//!   (double-buffered for the DATAFLOW pipeline); this is allocation-
//!   dependent only through the *transferred* footprint (bounding box /
//!   data tiling hold their redundant data on chip too, §VI.B.3.b).
//!
//! Constants are calibrated so the paper's configurations land in its
//! reported ranges (slices 2–5%, DSP 0–4%, BRAM up to ~95%); the claims we
//! reproduce are *relative* (CFA ≈ baselines on logic, ≈ original on BRAM).

use crate::accel::Scratchpad;
use crate::layout::{AddrGenProfile, Allocation};

/// xc7z045ffg900-2 resources.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub slices: u64,
    pub dsp: u64,
    pub bram36: u64,
}

impl Default for Device {
    fn default() -> Self {
        // Zynq-7045: 54,650 slices / 218,600 LUT, 900 DSP48E1, 545 BRAM36
        Device {
            slices: 54_650,
            dsp: 900,
            bram36: 545,
        }
    }
}

/// Synthesized-area estimate for one accelerator design.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AreaEstimate {
    pub slices: u64,
    pub dsp: u64,
    pub bram36: u64,
}

impl AreaEstimate {
    pub fn slice_pct(&self, dev: &Device) -> f64 {
        100.0 * self.slices as f64 / dev.slices as f64
    }

    pub fn dsp_pct(&self, dev: &Device) -> f64 {
        100.0 * self.dsp as f64 / dev.dsp as f64
    }

    pub fn bram_pct(&self, dev: &Device) -> f64 {
        100.0 * self.bram36 as f64 / dev.bram36 as f64
    }
}

/// Cost constants (slices / DSPs per primitive). Derived from typical
/// Vivado synthesis results for 32–40-bit datapath primitives.
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    /// One AXI master read or write engine (FSM, FIFOs, handshake).
    pub slices_per_engine: u64,
    /// 40-bit adder.
    pub slices_per_add: u64,
    /// Shift / power-of-two stride (wiring + mux).
    pub slices_per_shift: u64,
    /// LUT-synthesized divider/modulo (small constant divisors).
    pub slices_per_divmod: u64,
    /// Per counter bit (FF + carry).
    pub slices_per_counter_bit: u64,
    /// Burst-descriptor FSM state (per average transaction per tile).
    pub slices_per_burst: u64,
    /// DSP48 blocks per wide (≥18x25) multiplication.
    pub dsp_per_mul: u64,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            slices_per_engine: 620,
            slices_per_add: 14,
            slices_per_shift: 4,
            slices_per_divmod: 55,
            slices_per_counter_bit: 1,
            slices_per_burst: 9,
            dsp_per_mul: 4,
        }
    }
}

impl AreaModel {
    /// Logic + DSP of the read/write engines for an address generator.
    pub fn logic(&self, prof: &AddrGenProfile) -> (u64, u64) {
        // The burst FSM grows with the *structure* of the copy loops, not
        // their trip count: a loop issuing 500 bursts is the same hardware
        // as one issuing 5. Scale with log2 of the per-tile burst count.
        let burst_states = (prof.bursts_per_tile.max(1.0)).log2().ceil() as u64 + 1;
        let slices = 2 * self.slices_per_engine // read + write engine
            + prof.arrays as u64 * 90            // per-array AXI mux/ctrl
            + prof.add_ops as u64 * self.slices_per_add
            + prof.shift_ops as u64 * self.slices_per_shift
            + prof.div_mod_ops as u64 * self.slices_per_divmod
            + prof.counter_bits as u64 * self.slices_per_counter_bit
            + burst_states * self.slices_per_burst;
        let dsp = prof.mul_ops as u64 * self.dsp_per_mul;
        (slices, dsp)
    }

    /// Full estimate for an allocation: logic from its address generators,
    /// BRAM from the on-chip footprint of a representative interior tile
    /// (read buffer + write buffer, double-buffered for the dataflow
    /// pipeline). `elem_bytes` matches the memory config.
    pub fn estimate<A: Allocation + ?Sized>(&self, alloc: &A, elem_bytes: u64) -> AreaEstimate {
        let prof = alloc.addrgen();
        let (slices, dsp) = self.logic(&prof);
        let bram = self.bram_of(alloc, elem_bytes);
        AreaEstimate {
            slices,
            dsp,
            bram36: bram,
        }
    }

    /// BRAM blocks for the on-chip buffers implied by a tile plan: the raw
    /// transferred data must be held on chip (redundant data included —
    /// that is exactly the paper's bbox/data-tiling BRAM overhead).
    pub fn bram_of<A: Allocation + ?Sized>(&self, alloc: &A, elem_bytes: u64) -> u64 {
        let plan = representative_plan(alloc);
        let sp = Scratchpad::default();
        let read_buf = sp.bram36_for(plan.read_raw(), elem_bytes, true);
        let write_buf = sp.bram36_for(plan.write_raw(), elem_bytes, true);
        read_buf + write_buf
    }
}

/// Plan of a representative interior tile (same convention as addrgen()):
/// tile (1,1,…,1) clamped to the tile grid, which is interior whenever the
/// space has ≥3 tiles per axis and worst-case-ish otherwise.
pub fn representative_plan<A: Allocation + ?Sized>(alloc: &A) -> crate::layout::TilePlan {
    let counts = alloc.tiling().tile_counts();
    let mid: Vec<i64> = counts.iter().map(|&c| (c - 1).min(1)).collect();
    alloc.plan(&mid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::{Allocation, BoundingBox, Cfa, DataTiling, OriginalLayout};
    use crate::poly::deps::DepPattern;
    use crate::poly::tiling::Tiling;

    fn bench3d() -> (Tiling, DepPattern) {
        let tiling = Tiling::new(vec![64, 64, 64], vec![16, 16, 16]);
        let deps = DepPattern::new(vec![
            vec![-1, 0, 0],
            vec![-1, -1, 0],
            vec![-1, 0, -1],
            vec![-1, -2, -2],
        ])
        .unwrap();
        (tiling, deps)
    }

    #[test]
    fn all_allocations_land_in_paper_ranges() {
        let (tiling, deps) = bench3d();
        let dev = Device::default();
        let model = AreaModel::default();
        let allocs: Vec<Box<dyn Allocation>> = vec![
            Box::new(Cfa::new(tiling.clone(), deps.clone()).unwrap()),
            Box::new(OriginalLayout::new(tiling.clone(), deps.clone())),
            Box::new(BoundingBox::new(tiling.clone(), deps.clone())),
            Box::new(DataTiling::new(tiling.clone(), deps.clone(), vec![8, 8, 8])),
        ];
        for a in &allocs {
            let est = model.estimate(a.as_ref(), 8);
            let sp = est.slice_pct(&dev);
            let dp = est.dsp_pct(&dev);
            assert!(
                (1.0..=8.0).contains(&sp),
                "{}: slice {sp:.2}% out of expected band",
                a.name()
            );
            assert!(dp <= 6.0, "{}: dsp {dp:.2}%", a.name());
        }
    }

    #[test]
    fn cfa_logic_comparable_to_baselines() {
        // the paper's headline area claim: CFA "does not show a
        // significantly different slice occupancy than other baselines".
        let (tiling, deps) = bench3d();
        let model = AreaModel::default();
        let cfa = model.estimate(&Cfa::new(tiling.clone(), deps.clone()).unwrap(), 8);
        let orig = model.estimate(&OriginalLayout::new(tiling.clone(), deps.clone()), 8);
        let ratio = cfa.slices as f64 / orig.slices as f64;
        assert!(
            (0.5..2.5).contains(&ratio),
            "CFA/original slice ratio {ratio}"
        );
    }

    #[test]
    fn bbox_needs_more_bram_than_cfa() {
        // §VI.B.3.b: bounding box holds redundant data on chip.
        let (tiling, deps) = bench3d();
        let model = AreaModel::default();
        let cfa_bram = model.bram_of(&Cfa::new(tiling.clone(), deps.clone()).unwrap(), 8);
        let bbox_bram = model.bram_of(&BoundingBox::new(tiling.clone(), deps.clone()), 8);
        assert!(
            bbox_bram > cfa_bram,
            "bbox {bbox_bram} vs cfa {cfa_bram} BRAM"
        );
    }

    #[test]
    fn cfa_bram_close_to_original() {
        let (tiling, deps) = bench3d();
        let model = AreaModel::default();
        let cfa_bram = model.bram_of(&Cfa::new(tiling.clone(), deps.clone()).unwrap(), 8) as f64;
        let orig_bram = model.bram_of(&OriginalLayout::new(tiling, deps), 8) as f64;
        let ratio = cfa_bram / orig_bram;
        assert!((0.5..=2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn percentages() {
        let dev = Device::default();
        let est = AreaEstimate {
            slices: 5465,
            dsp: 90,
            bram36: 109,
        };
        assert!((est.slice_pct(&dev) - 10.0).abs() < 1e-9);
        assert!((est.dsp_pct(&dev) - 10.0).abs() < 1e-9);
        assert!((est.bram_pct(&dev) - 20.0).abs() < 1e-9);
    }
}
