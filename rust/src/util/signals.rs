//! Minimal SIGINT/SIGTERM observation without the `libc` crate.
//!
//! The crate has no signal-handling dependency, and the only thing the
//! CLI and the serve daemon need is a *flag*: "a termination signal has
//! arrived, drain and exit". So the handler is the smallest
//! async-signal-safe thing possible — it stores into a process-global
//! atomic — installed through a raw FFI declaration of POSIX `signal(2)`.
//! Consumers poll [`triggered`] at their own safe points (the daemon's
//! accept loop) or bridge it to a [`CancelToken`] with [`watch`] (plain
//! `cfa tune`), which turns Ctrl-C into the explorer's cooperative
//! cancellation: the journal is flushed mid-append-safe and the run exits
//! with the `interrupted` marker instead of dying on the default handler.
//!
//! Non-unix builds compile to no-ops: [`install`] does nothing and
//! [`triggered`] is always false, so callers need no cfg of their own.

use crate::util::par::CancelToken;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Set by the handler; read by everyone else.
static TRIGGERED: AtomicBool = AtomicBool::new(false);
/// Signals observed since [`install`] (a second Ctrl-C is visible here).
static COUNT: AtomicU64 = AtomicU64::new(0);

#[cfg(unix)]
mod imp {
    use super::{Ordering, COUNT, TRIGGERED};

    // POSIX signal(2). `sighandler_t` is a code pointer; `usize` has the
    // same representation on every supported unix, which keeps the
    // declaration free of the libc crate.
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    /// The handler itself: only atomic stores, which are async-signal-safe.
    extern "C" fn on_signal(_signum: i32) {
        TRIGGERED.store(true, Ordering::SeqCst);
        COUNT.fetch_add(1, Ordering::SeqCst);
    }

    pub(super) fn install() {
        let h = on_signal as extern "C" fn(i32) as usize;
        unsafe {
            signal(SIGINT, h);
            signal(SIGTERM, h);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub(super) fn install() {}
}

/// Install the SIGINT/SIGTERM handler (idempotent; no-op off unix).
pub fn install() {
    imp::install();
}

/// True once a SIGINT or SIGTERM has arrived after [`install`].
pub fn triggered() -> bool {
    TRIGGERED.load(Ordering::SeqCst)
}

/// Number of signals observed so far (callers that want "second Ctrl-C
/// exits hard" read this).
pub fn count() -> u64 {
    COUNT.load(Ordering::SeqCst)
}

/// Bridge signals to a [`CancelToken`]: a detached watcher thread polls
/// [`triggered`] every 50 ms and cancels `token` once it fires, then
/// exits. Installs the handler as a side effect. Intended for one-shot
/// CLI runs (`cfa tune`), where the watcher's lifetime is the process's.
pub fn watch(token: CancelToken) {
    install();
    std::thread::spawn(move || loop {
        if triggered() {
            token.cancel();
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_is_idempotent() {
        // no signal is raised in-process here (raising SIGINT would hit
        // sibling tests sharing the process), and no global is asserted
        // (the watch test pokes TRIGGERED concurrently) — this only pins
        // that repeated installs are safe
        install();
        install();
    }

    #[test]
    fn watch_cancels_after_trigger() {
        // simulate the handler's store directly: raise(2) would hit the
        // whole test process
        let token = CancelToken::new();
        watch(token.clone());
        assert!(!token.is_cancelled());
        TRIGGERED.store(true, Ordering::SeqCst);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while !token.is_cancelled() && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(token.is_cancelled());
        TRIGGERED.store(false, Ordering::SeqCst);
    }
}
