//! Tiny command-line argument parser (clap is not in the offline crate set).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options and
//! positional arguments, with generated usage text. Used by `main.rs`,
//! the examples and the bench binaries.

use std::collections::BTreeMap;

/// Declarative option specification.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Flags take no value.
    pub is_flag: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Parse a comma/x-separated size list like "8x32x32" or "8,32,32".
    pub fn get_sizes(&self, name: &str) -> Result<Option<Vec<i64>>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => {
                let parts: Result<Vec<i64>, _> = v
                    .split(|c| c == 'x' || c == ',')
                    .map(|p| p.trim().parse::<i64>())
                    .collect();
                parts
                    .map(Some)
                    .map_err(|_| format!("--{name} expects sizes like 8x32x32, got '{v}'"))
            }
        }
    }
}

/// A command parser: options + usage rendering.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: false,
            default,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: true,
            default: None,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <value>", o.name)
            };
            let dflt = o
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            out.push_str(&format!("{head:<28} {}{dflt}\n", o.help));
        }
        out
    }

    /// An error message that names the offending flag and carries the
    /// usage text — every parse failure goes through here, so inline
    /// (`--key=value`) and split (`--key value`) forms fail identically.
    fn err(&self, msg: impl std::fmt::Display) -> String {
        format!("{msg}\n\n{}", self.usage())
    }

    /// Parse a raw argument list. Unknown `--options`, malformed
    /// `--key=value` pairs and missing values are errors that name the
    /// offending flag and include the usage text.
    pub fn parse(&self, raw: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                args.options.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if key.is_empty() {
                    return Err(self.err(format_args!(
                        "malformed option '{a}': empty option name"
                    )));
                }
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| self.err(format_args!("unknown option --{key}")))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(self.err(format_args!(
                            "--{key} is a flag and takes no value (got '{a}')"
                        )));
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            let next = raw.get(i + 1);
                            match next {
                                None => {
                                    return Err(self.err(format_args!(
                                        "--{key} requires a value"
                                    )))
                                }
                                Some(v) if v.starts_with("--") => {
                                    return Err(self.err(format_args!(
                                        "--{key} requires a value, but the next \
                                         argument is an option ('{v}'); use \
                                         --{key}=VALUE if the value starts with '--'"
                                    )))
                                }
                                Some(v) => {
                                    i += 1;
                                    v.clone()
                                }
                            }
                        }
                    };
                    args.options.insert(key, val);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

/// Collect `std::env::args` after the program name (and an optional
/// subcommand which the caller has already consumed).
pub fn env_args(skip: usize) -> Vec<String> {
    std::env::args().skip(1 + skip).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("t", "test")
            .opt("size", "tile size", Some("32"))
            .opt("out", "output path", None)
            .flag("verbose", "chatty")
    }

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&v(&[])).unwrap();
        assert_eq!(a.get("size"), Some("32"));
        assert_eq!(a.get("out"), None);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cmd().parse(&v(&["--size", "64", "--out=x.json"])).unwrap();
        assert_eq!(a.get("size"), Some("64"));
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = cmd().parse(&v(&["--verbose", "pos1", "pos2"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn unknown_option_is_error() {
        let err = cmd().parse(&v(&["--nope"])).unwrap_err();
        assert!(err.contains("--nope"), "{err}");
        assert!(err.contains("options:"), "no usage in: {err}");
    }

    #[test]
    fn missing_value_is_error() {
        let err = cmd().parse(&v(&["--out"])).unwrap_err();
        assert!(err.contains("--out"), "{err}");
        assert!(err.contains("options:"), "no usage in: {err}");
    }

    #[test]
    fn flag_with_inline_value_names_flag_and_shows_usage() {
        let err = cmd().parse(&v(&["--verbose=yes"])).unwrap_err();
        assert!(err.contains("--verbose"), "{err}");
        assert!(err.contains("options:"), "no usage in: {err}");
    }

    #[test]
    fn empty_option_name_is_malformed() {
        for bad in ["--", "--=x"] {
            let err = cmd().parse(&v(&[bad])).unwrap_err();
            assert!(err.contains("malformed"), "{bad}: {err}");
            assert!(err.contains("options:"), "{bad}: no usage in: {err}");
        }
    }

    #[test]
    fn option_swallowing_an_option_is_error() {
        // `--out --verbose` used to silently take "--verbose" as the value
        let err = cmd().parse(&v(&["--out", "--verbose"])).unwrap_err();
        assert!(err.contains("--out"), "{err}");
        assert!(err.contains("--verbose"), "{err}");
        assert!(err.contains("options:"), "no usage in: {err}");
        // the inline form still accepts such values explicitly
        let a = cmd().parse(&v(&["--out=--verbose"])).unwrap();
        assert_eq!(a.get("out"), Some("--verbose"));
    }

    #[test]
    fn parse_round_trips_inline_and_split_forms() {
        // the same (key, value) pairs must round-trip identically through
        // both spellings, including '='-bearing and '-'-leading values
        let cases: &[(&str, &str)] = &[
            ("out", "x.json"),
            ("out", "a=b.json"),
            ("size", "-3"),
        ];
        for (key, val) in cases {
            let inline = cmd().parse(&[format!("--{key}={val}")]).unwrap();
            let split = cmd()
                .parse(&[format!("--{key}"), val.to_string()])
                .unwrap();
            assert_eq!(inline.get(key), Some(*val), "inline --{key}={val}");
            assert_eq!(split.get(key), Some(*val), "split --{key} {val}");
            assert_eq!(inline.options, split.options, "--{key}={val}");
        }
    }

    #[test]
    fn typed_getters() {
        let a = cmd().parse(&v(&["--size", "128"])).unwrap();
        assert_eq!(a.get_usize("size", 0).unwrap(), 128);
        let bad = cmd().parse(&v(&["--size", "xyz"])).unwrap();
        assert!(bad.get_usize("size", 0).is_err());
    }

    #[test]
    fn size_lists() {
        let c = Command::new("t", "t").opt("tile", "tile sizes", None);
        let a = c.parse(&v(&["--tile", "8x32x32"])).unwrap();
        assert_eq!(a.get_sizes("tile").unwrap(), Some(vec![8, 32, 32]));
        let a = c.parse(&v(&["--tile", "4,16"])).unwrap();
        assert_eq!(a.get_sizes("tile").unwrap(), Some(vec![4, 16]));
    }

    #[test]
    fn usage_mentions_options() {
        let u = cmd().usage();
        assert!(u.contains("--size"));
        assert!(u.contains("default: 32"));
    }
}
