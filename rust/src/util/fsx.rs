//! Crash-safe filesystem helpers.
//!
//! [`write_atomic`] is the project's one way to publish a result file
//! (bench `BENCH_*.json` schema seeds, figure CSVs): the bytes land in a
//! sibling `<name>.tmp` first and reach the destination via `rename`,
//! which POSIX makes atomic within a filesystem. A bench killed mid-write
//! therefore leaves either the old file or the new one — never a
//! truncated JSON that would poison downstream tooling. (Journals are
//! different: they are *append-only* logs with their own torn-line
//! salvage in `dse::journal`.)

use std::io::Write;
use std::path::Path;

/// Write `contents` to `path` atomically: temp sibling + `rename`.
/// On failure the destination is untouched and the temp file is cleaned
/// up best-effort. Fault site: `fsx::write_atomic`.
pub fn write_atomic(path: impl AsRef<Path>, contents: impl AsRef<[u8]>) -> std::io::Result<()> {
    let path = path.as_ref();
    crate::util::faults::check_io("fsx::write_atomic")?;
    let mut tmp_name = path.file_name().unwrap_or_default().to_os_string();
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    let result = (|| {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(contents.as_ref())?;
        // the rename publishes; sync first so a crash right after the
        // rename cannot surface a present-but-empty file
        f.sync_all()?;
        std::fs::rename(&tmp, path)
    })();
    if result.is_err() {
        std::fs::remove_file(&tmp).ok();
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(name);
        std::fs::remove_file(&p).ok();
        p
    }

    #[test]
    fn writes_and_overwrites_without_leftover_temp() {
        let p = tmp_path("cfa_fsx_atomic.json");
        write_atomic(&p, "{\"v\":1}").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"v\":1}");
        write_atomic(&p, "{\"v\":2}").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"v\":2}");
        let tmp = p.with_file_name("cfa_fsx_atomic.json.tmp");
        assert!(!tmp.exists(), "temp sibling must not survive");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn failed_write_leaves_destination_untouched() {
        let p = tmp_path("cfa_fsx_fail_dir/never.json");
        // parent directory does not exist: create of the temp file fails
        assert!(write_atomic(&p, "x").is_err());
        assert!(!p.exists());
    }
}
