//! Deterministic fork-join parallelism on `std::thread::scope`.
//!
//! The offline crate set ships no rayon, so this is the project's parallel
//! substrate: a fixed worker pool over an atomic work index, with results
//! returned **in input order** regardless of which worker ran which item.
//! Because the mapped function is pure (it only reads shared state), the
//! output of [`parallel_map`] is bit-identical to the sequential
//! `items.iter().map(f)` — the batched coordinator's determinism contract
//! rests on exactly this property.
//!
//! Work is claimed item-by-item (dynamic self-scheduling), so heavily
//! skewed workloads — one 128³ tile plan next to many tiny boundary tiles —
//! still balance across workers.
//!
//! **Fault isolation.** [`try_parallel_map`] is the panic-safe entry point:
//! every item runs under `catch_unwind`, so one panicking item costs exactly
//! one `Err` slot (carrying the payload and the item index) while sibling
//! items keep running to completion. [`parallel_map`] is its thin infallible
//! wrapper: on any panic it re-raises the *lowest-index* payload, which is
//! exactly the panic a sequential `items.iter().map(f)` would have surfaced
//! — serial and parallel failures report identically.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Sensible default worker count: the machine's available parallelism
/// (1 when it cannot be determined). [`parallel_map`] itself clamps the
/// worker count to the batch size, so oversubscription on small batches
/// is handled there, not here.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// A captured panic from one mapped item: the input index it was processing
/// plus the raw panic payload.
pub struct WorkerPanic {
    /// Index of the item whose closure panicked.
    pub index: usize,
    payload: Box<dyn Any + Send + 'static>,
}

impl WorkerPanic {
    /// Best-effort human rendering of the payload (`panic!` with a string
    /// literal or a formatted message covers essentially every panic in
    /// this codebase).
    pub fn message(&self) -> String {
        if let Some(s) = self.payload.downcast_ref::<&'static str>() {
            (*s).to_string()
        } else if let Some(s) = self.payload.downcast_ref::<String>() {
            s.clone()
        } else {
            "non-string panic payload".to_string()
        }
    }

    /// The raw payload, e.g. for [`std::panic::resume_unwind`].
    pub fn into_payload(self) -> Box<dyn Any + Send + 'static> {
        self.payload
    }
}

impl std::fmt::Debug for WorkerPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPanic")
            .field("index", &self.index)
            .field("message", &self.message())
            .finish()
    }
}

impl std::fmt::Display for WorkerPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "item {} panicked: {}", self.index, self.message())
    }
}

/// A cooperative cancellation token: cheap to clone, safe to share across
/// threads. Holders *observe* cancellation ([`CancelToken::is_cancelled`])
/// at their own safe points — nothing is interrupted preemptively, so a
/// cancelled explorer still finishes its in-flight items and flushes its
/// journal before returning.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation (idempotent, visible to every clone).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Map `f` over `items` with `threads` workers, returning per-item
/// `Result`s in input order: `Err(WorkerPanic)` for items whose closure
/// panicked, `Ok` for everything else. A panic costs exactly its own item —
/// sibling items (including later items claimed by the same worker) run to
/// completion. `threads <= 1` (or a single item) runs inline, with the same
/// per-item isolation.
pub fn try_parallel_map<I, T, F>(items: &[I], threads: usize, f: F) -> Vec<Result<T, WorkerPanic>>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> T + Sync,
{
    let run_one = |i: usize| -> Result<T, WorkerPanic> {
        catch_unwind(AssertUnwindSafe(|| f(&items[i])))
            .map_err(|payload| WorkerPanic { index: i, payload })
    };
    let threads = threads.clamp(1, items.len().max(1));
    if threads <= 1 {
        return (0..items.len()).map(run_one).collect();
    }
    let next = AtomicUsize::new(0);
    let parts: Vec<Vec<(usize, Result<T, WorkerPanic>)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, run_one(i)));
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            // run_one never unwinds (the item's panic was caught), so a
            // worker can only die to something unrecoverable like OOM
            .map(|h| h.join().expect("parallel_map worker died outside f"))
            .collect()
    });
    let mut out: Vec<Option<Result<T, WorkerPanic>>> = (0..items.len()).map(|_| None).collect();
    for part in parts {
        for (i, v) in part {
            debug_assert!(out[i].is_none(), "item {i} mapped twice");
            out[i] = Some(v);
        }
    }
    out.into_iter()
        .map(|v| v.expect("parallel_map missed an item"))
        .collect()
}

/// Map `f` over `items` with `threads` workers, returning the results in
/// input order. `threads <= 1` (or a single item) runs inline with no
/// thread spawned. If any item panics, the panic of the **lowest-index**
/// panicking item is re-raised with its original payload — deterministic,
/// and identical to what the sequential map would have raised.
pub fn parallel_map<I, T, F>(items: &[I], threads: usize, f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> T + Sync,
{
    let mut out = Vec::with_capacity(items.len());
    for r in try_parallel_map(items, threads, f) {
        match r {
            Ok(v) => out.push(v),
            Err(p) => std::panic::resume_unwind(p.into_payload()),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..257).collect();
        for threads in [1, 2, 3, 8] {
            let out = parallel_map(&items, threads, |&x| x * x);
            let expect: Vec<u64> = items.iter().map(|&x| x * x).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let none: Vec<i64> = Vec::new();
        assert!(parallel_map(&none, 4, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7i64], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn oversubscription_is_clamped() {
        // more threads than items must not deadlock or drop results
        let items: Vec<usize> = (0..3).collect();
        assert_eq!(parallel_map(&items, 64, |&x| x), items);
    }

    #[test]
    fn matches_sequential_on_shared_reads() {
        // workers only read shared state; result must equal the serial map
        let base: Vec<f32> = (0..100).map(|i| i as f32 * 0.5).collect();
        let idxs: Vec<usize> = (0..100).rev().collect();
        let par = parallel_map(&idxs, 4, |&i| base[i] + 1.0);
        let ser: Vec<f32> = idxs.iter().map(|&i| base[i] + 1.0).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }

    /// Silence the default panic-hook backtrace chatter while `f` runs.
    /// The hook is process-global, so tests that panic on purpose funnel
    /// through here (the mutex also keeps them from clobbering each other).
    fn with_quiet_panics<R>(f: impl FnOnce() -> R) -> R {
        use std::sync::Mutex;
        static HOOK: Mutex<()> = Mutex::new(());
        let _guard = HOOK.lock().unwrap_or_else(|p| p.into_inner());
        let saved = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let out = f();
        std::panic::set_hook(saved);
        out
    }

    #[test]
    fn try_map_isolates_panicking_items() {
        with_quiet_panics(|| {
            let items: Vec<u64> = (0..40).collect();
            for threads in [1, 4] {
                let out = try_parallel_map(&items, threads, |&x| {
                    if x % 10 == 3 {
                        panic!("boom {x}");
                    }
                    x * 2
                });
                assert_eq!(out.len(), items.len(), "threads={threads}");
                for (i, r) in out.iter().enumerate() {
                    if i % 10 == 3 {
                        let p = r.as_ref().unwrap_err();
                        assert_eq!(p.index, i);
                        assert_eq!(p.message(), format!("boom {i}"));
                    } else {
                        assert_eq!(*r.as_ref().unwrap(), i as u64 * 2);
                    }
                }
            }
        });
    }

    #[test]
    fn try_map_serial_and_parallel_agree() {
        with_quiet_panics(|| {
            let items: Vec<u64> = (0..64).collect();
            let flag = |r: &Result<u64, WorkerPanic>| match r {
                Ok(v) => format!("ok {v}"),
                Err(p) => format!("err {} {}", p.index, p.message()),
            };
            let ser: Vec<String> = try_parallel_map(&items, 1, |&x| {
                if x == 7 || x == 31 {
                    panic!("fail {x}")
                }
                x + 1
            })
            .iter()
            .map(flag)
            .collect();
            let par: Vec<String> = try_parallel_map(&items, 8, |&x| {
                if x == 7 || x == 31 {
                    panic!("fail {x}")
                }
                x + 1
            })
            .iter()
            .map(flag)
            .collect();
            assert_eq!(ser, par);
        });
    }

    #[test]
    fn wrapper_propagates_the_lowest_index_payload() {
        with_quiet_panics(|| {
            for threads in [1, 4] {
                let items: Vec<u64> = (0..32).collect();
                let err = catch_unwind(AssertUnwindSafe(|| {
                    parallel_map(&items, threads, |&x| {
                        if x >= 5 {
                            panic!("first failure at {x}");
                        }
                        x
                    })
                }))
                .unwrap_err();
                // the payload must be item 5's — the one the serial loop
                // would have raised — not whichever worker lost the race
                let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
                assert_eq!(msg, "first failure at 5", "threads={threads}");
            }
        });
    }

    #[test]
    fn cancel_token_is_shared_across_clones() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t.is_cancelled() && !t2.is_cancelled());
        t2.cancel();
        assert!(t.is_cancelled() && t2.is_cancelled());
        t.cancel(); // idempotent
        assert!(t.is_cancelled());
    }
}
