//! Deterministic fork-join parallelism on `std::thread::scope`.
//!
//! The offline crate set ships no rayon, so this is the project's parallel
//! substrate: a fixed worker pool over an atomic work index, with results
//! returned **in input order** regardless of which worker ran which item.
//! Because the mapped function is pure (it only reads shared state), the
//! output of [`parallel_map`] is bit-identical to the sequential
//! `items.iter().map(f)` — the batched coordinator's determinism contract
//! rests on exactly this property.
//!
//! Work is claimed item-by-item (dynamic self-scheduling), so heavily
//! skewed workloads — one 128³ tile plan next to many tiny boundary tiles —
//! still balance across workers.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Sensible default worker count: the machine's available parallelism
/// (1 when it cannot be determined). [`parallel_map`] itself clamps the
/// worker count to the batch size, so oversubscription on small batches
/// is handled there, not here.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Map `f` over `items` with `threads` workers, returning the results in
/// input order. `threads <= 1` (or a single item) runs inline with no
/// thread spawned. Panics in `f` propagate.
pub fn parallel_map<I, T, F>(items: &[I], threads: usize, f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> T + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if threads <= 1 {
        let mut out = Vec::with_capacity(items.len());
        for item in items {
            out.push(f(item));
        }
        return out;
    }
    let next = AtomicUsize::new(0);
    let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(&items[i])));
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("parallel_map worker panicked"))
            .collect()
    });
    let mut out: Vec<Option<T>> = (0..items.len()).map(|_| None).collect();
    for part in parts {
        for (i, v) in part {
            debug_assert!(out[i].is_none(), "item {i} mapped twice");
            out[i] = Some(v);
        }
    }
    out.into_iter()
        .map(|v| v.expect("parallel_map missed an item"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..257).collect();
        for threads in [1, 2, 3, 8] {
            let out = parallel_map(&items, threads, |&x| x * x);
            let expect: Vec<u64> = items.iter().map(|&x| x * x).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let none: Vec<i64> = Vec::new();
        assert!(parallel_map(&none, 4, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7i64], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn oversubscription_is_clamped() {
        // more threads than items must not deadlock or drop results
        let items: Vec<usize> = (0..3).collect();
        assert_eq!(parallel_map(&items, 64, |&x| x), items);
    }

    #[test]
    fn matches_sequential_on_shared_reads() {
        // workers only read shared state; result must equal the serial map
        let base: Vec<f32> = (0..100).map(|i| i as f32 * 0.5).collect();
        let idxs: Vec<usize> = (0..100).rev().collect();
        let par = parallel_map(&idxs, 4, |&i| base[i] + 1.0);
        let ser: Vec<f32> = idxs.iter().map(|&i| base[i] + 1.0).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }
}
