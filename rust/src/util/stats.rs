//! Small statistics toolkit for the benchmark harness.
//!
//! Criterion is not available offline; the benches use [`Bencher`] for
//! wall-clock measurement with warmup, outlier-robust summaries and a
//! plain-text report, and [`Summary`] for descriptive statistics of metric
//! series (bandwidths, cycle counts, areas).

use std::time::Instant;

/// Descriptive statistics over a sample of f64 values.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p05: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            max: s[n - 1],
            median: percentile_sorted(&s, 0.5),
            p05: percentile_sorted(&s, 0.05),
            p95: percentile_sorted(&s, 0.95),
        })
    }
}

/// Linear-interpolated percentile of an already-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean (for speedup tables). Ignores non-positive entries.
pub fn geomean(xs: &[f64]) -> f64 {
    let pos: Vec<f64> = xs.iter().copied().filter(|&x| x > 0.0).collect();
    if pos.is_empty() {
        return 0.0;
    }
    (pos.iter().map(|x| x.ln()).sum::<f64>() / pos.len() as f64).exp()
}

/// Work performed by one benchmark iteration, for throughput reporting:
/// wall time alone hides whether a speedup came from doing less work or
/// doing it faster, so bench lines carry elements/s and runs/s too.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Work {
    /// Elements touched (moved, planned or marshalled) per iteration.
    pub elems: u64,
    /// Burst runs emitted/processed per iteration.
    pub runs: u64,
}

/// Measurement of one benchmark target.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// per-iteration wall time, seconds
    pub times: Vec<f64>,
    pub summary: Summary,
    /// Per-iteration work, when the target reports it (throughput lines).
    pub work: Option<Work>,
}

impl Measurement {
    /// Attach per-iteration work counts for throughput reporting.
    pub fn with_work(mut self, elems: u64, runs: u64) -> Measurement {
        self.work = Some(Work { elems, runs });
        self
    }

    /// Elements per second at the median time (None without work counts).
    pub fn elems_per_sec(&self) -> Option<f64> {
        match self.work {
            Some(w) if self.summary.median > 0.0 => Some(w.elems as f64 / self.summary.median),
            _ => None,
        }
    }

    /// Runs per second at the median time (None without work counts).
    pub fn runs_per_sec(&self) -> Option<f64> {
        match self.work {
            Some(w) if self.summary.median > 0.0 => Some(w.runs as f64 / self.summary.median),
            _ => None,
        }
    }

    /// Nicely formatted one-line report (median ± robust spread, plus
    /// throughput when work counts are attached).
    pub fn line(&self) -> String {
        let s = &self.summary;
        let mut out = format!(
            "{:<44} {:>12} median  [{} .. {}]  n={}",
            self.name,
            fmt_duration(s.median),
            fmt_duration(s.p05),
            fmt_duration(s.p95),
            s.n
        );
        if let (Some(e), Some(r)) = (self.elems_per_sec(), self.runs_per_sec()) {
            out.push_str(&format!(
                "  {} elem/s  {} run/s",
                fmt_rate(e),
                fmt_rate(r)
            ));
        }
        out
    }
}

/// Format a per-second rate with an adaptive SI prefix.
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2}G", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2}M", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2}k", per_sec / 1e3)
    } else {
        format!("{per_sec:.1}")
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:.3} s", secs)
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Minimal criterion-like wall-clock bencher.
///
/// Runs `f` for a warmup period, then collects `samples` timed batches,
/// sizing each batch so one batch is ≥ `min_batch_time`.
pub struct Bencher {
    pub warmup_time: f64,
    pub samples: usize,
    pub min_batch_time: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_time: 0.3,
            samples: 20,
            min_batch_time: 0.01,
        }
    }
}

impl Bencher {
    /// Quick preset for slow end-to-end targets.
    pub fn quick() -> Self {
        Bencher {
            warmup_time: 0.05,
            samples: 5,
            min_batch_time: 0.0,
        }
    }

    /// Measure `f`, returning per-iteration times.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // Warmup + batch sizing.
        let start = Instant::now();
        let mut iters_in_warmup = 0u64;
        while start.elapsed().as_secs_f64() < self.warmup_time || iters_in_warmup == 0 {
            f();
            iters_in_warmup += 1;
            if iters_in_warmup > 1_000_000 {
                break;
            }
        }
        let per_iter = start.elapsed().as_secs_f64() / iters_in_warmup as f64;
        let batch = if per_iter > 0.0 {
            ((self.min_batch_time / per_iter).ceil() as u64).max(1)
        } else {
            1
        };
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            times.push(t0.elapsed().as_secs_f64() / batch as f64);
        }
        let summary = Summary::of(&times).unwrap();
        Measurement {
            name: name.to_string(),
            times,
            summary,
            work: None,
        }
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // `std::hint::black_box` is stable since 1.66.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn summary_singleton() {
        let s = Summary::of(&[7.5]).unwrap();
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.5);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert!((percentile_sorted(&s, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&s, 0.0), 0.0);
        assert_eq!(percentile_sorted(&s, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn bencher_measures_something() {
        let b = Bencher {
            warmup_time: 0.01,
            samples: 3,
            min_batch_time: 0.0,
        };
        let mut acc = 0u64;
        let m = b.bench("noop", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(m.times.len(), 3);
        assert!(m.summary.median >= 0.0);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" µs"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }

    #[test]
    fn throughput_reported_with_work_counts() {
        let m = Measurement {
            name: "x".into(),
            times: vec![0.5],
            summary: Summary::of(&[0.5]).unwrap(),
            work: None,
        };
        assert_eq!(m.elems_per_sec(), None);
        assert!(!m.line().contains("elem/s"));
        let m = m.with_work(1_000_000, 200);
        assert!((m.elems_per_sec().unwrap() - 2e6).abs() < 1e-6);
        assert!((m.runs_per_sec().unwrap() - 400.0).abs() < 1e-9);
        let line = m.line();
        assert!(line.contains("elem/s") && line.contains("run/s"), "{line}");
    }

    #[test]
    fn fmt_rate_prefixes() {
        assert_eq!(fmt_rate(2.5e9), "2.50G");
        assert_eq!(fmt_rate(3.0e6), "3.00M");
        assert_eq!(fmt_rate(4.5e3), "4.50k");
        assert_eq!(fmt_rate(12.0), "12.0");
    }
}
