//! Property-based testing mini-framework.
//!
//! `proptest` is not in the offline crate set, so the coordinator invariants
//! (facet coverage, single-assignment disjointness, address bijectivity,
//! simulator conservation laws, …) are exercised with this substrate: a
//! seeded case generator plus a greedy integer-shrinking loop.
//!
//! ```no_run
//! # // no_run: rustdoc test binaries lack the xla_extension rpath the
//! # // harness injects for regular targets; the snippet is compile-checked.
//! use cfa::util::prop::{Config, run};
//! run("add commutes", Config::default(), |g| {
//!     let a = g.i64(-100, 100);
//!     let b = g.i64(-100, 100);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;
use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Property-test configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (each case derives its own stream).
    pub seed: u64,
    /// Maximum shrink attempts after a failure.
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 128,
            seed: 0xCFA0_1234_5678_9ABC,
            max_shrink: 400,
        }
    }
}

impl Config {
    /// A lighter configuration for expensive properties.
    pub fn small(cases: usize) -> Self {
        Config {
            cases,
            ..Config::default()
        }
    }
}

/// Draw source handed to a property. Records every integer drawn so that the
/// framework can replay a failing case with shrunk values.
pub struct Gen {
    rng: RefCell<Rng>,
    /// When `Some`, draws are replayed from this tape (shrinking mode);
    /// a tape miss falls back to fresh randomness.
    tape: Option<Vec<i64>>,
    pos: RefCell<usize>,
    record: RefCell<Vec<i64>>,
}

impl Gen {
    fn new(seed: u64, tape: Option<Vec<i64>>) -> Self {
        Gen {
            rng: RefCell::new(Rng::new(seed)),
            tape,
            pos: RefCell::new(0),
            record: RefCell::new(Vec::new()),
        }
    }

    fn draw(&self, lo: i64, hi: i64) -> i64 {
        let v = if let Some(t) = &self.tape {
            let mut pos = self.pos.borrow_mut();
            if *pos < t.len() {
                let raw = t[*pos];
                *pos += 1;
                raw.clamp(lo, hi)
            } else {
                self.rng.borrow_mut().gen_i64(lo, hi)
            }
        } else {
            self.rng.borrow_mut().gen_i64(lo, hi)
        };
        self.record.borrow_mut().push(v);
        v
    }

    /// Integer in `[lo, hi]` inclusive.
    pub fn i64(&self, lo: i64, hi: i64) -> i64 {
        self.draw(lo, hi)
    }

    /// `usize` in `[lo, hi]` inclusive.
    pub fn usize(&self, lo: usize, hi: usize) -> usize {
        self.draw(lo as i64, hi as i64) as usize
    }

    /// Boolean with probability 1/2.
    pub fn bool(&self) -> bool {
        self.draw(0, 1) == 1
    }

    /// Pick an element of a non-empty slice.
    pub fn choose<'a, T>(&self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len() - 1)]
    }

    /// A vector of `len` integers in `[lo, hi]`.
    pub fn vec_i64(&self, len: usize, lo: i64, hi: i64) -> Vec<i64> {
        (0..len).map(|_| self.i64(lo, hi)).collect()
    }
}

/// Outcome of one execution of the property.
fn run_once(
    seed: u64,
    tape: Option<Vec<i64>>,
    prop: &dyn Fn(&Gen),
) -> Result<Vec<i64>, (Vec<i64>, String)> {
    let g = Gen::new(seed, tape);
    let result = catch_unwind(AssertUnwindSafe(|| prop(&g)));
    let tape_out = g.record.into_inner();
    match result {
        Ok(()) => Ok(tape_out),
        Err(e) => {
            let msg = if let Some(s) = e.downcast_ref::<String>() {
                s.clone()
            } else if let Some(s) = e.downcast_ref::<&str>() {
                s.to_string()
            } else {
                "<non-string panic>".to_string()
            };
            Err((tape_out, msg))
        }
    }
}

/// Run a property; panics with the shrunk counterexample on failure.
pub fn run<F: Fn(&Gen)>(name: &str, cfg: Config, prop: F) {
    let prop_ref: &dyn Fn(&Gen) = &prop;
    for case in 0..cfg.cases {
        let seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        if let Err((tape, first_msg)) = run_once(seed, None, prop_ref) {
            // Shrink: greedily try to move each drawn integer toward zero.
            let mut best = tape;
            let mut best_msg = first_msg;
            let mut budget = cfg.max_shrink;
            let mut progress = true;
            while progress && budget > 0 {
                progress = false;
                for i in 0..best.len() {
                    if budget == 0 {
                        break;
                    }
                    let orig = best[i];
                    for cand in shrink_candidates(orig) {
                        if budget == 0 {
                            break;
                        }
                        budget -= 1;
                        let mut t = best.clone();
                        t[i] = cand;
                        if let Err((tape2, msg2)) = run_once(seed, Some(t), prop_ref) {
                            best = tape2;
                            best_msg = msg2;
                            progress = true;
                            break;
                        }
                    }
                    let _ = orig;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x})\n  \
                 counterexample draws: {best:?}\n  failure: {best_msg}"
            );
        }
    }
}

fn shrink_candidates(v: i64) -> Vec<i64> {
    let mut out = Vec::new();
    if v != 0 {
        out.push(0);
    }
    if v > 1 {
        out.push(1);
        out.push(v / 2);
        out.push(v - 1);
    }
    if v < -1 {
        out.push(-1);
        out.push(v / 2);
        out.push(v + 1);
    }
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run("abs non-negative", Config::small(64), |g| {
            let x = g.i64(-1000, 1000);
            assert!(x.abs() >= 0);
        });
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        let r = std::panic::catch_unwind(|| {
            run("find big", Config::small(256), |g| {
                let x = g.i64(0, 1000);
                // fails for x >= 10; minimal counterexample is 10
                assert!(x < 10, "x too big: {x}");
            });
        });
        let err = r.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("failed"), "{msg}");
        // shrinker should reach the boundary value 10
        assert!(msg.contains("[10]"), "shrunk message: {msg}");
    }

    #[test]
    fn vectors_and_choices_work() {
        run("vec len", Config::small(32), |g| {
            let n = g.usize(0, 8);
            let v = g.vec_i64(n, -5, 5);
            assert_eq!(v.len(), n);
            assert!(v.iter().all(|x| (-5..=5).contains(x)));
            if !v.is_empty() {
                let c = *g.choose(&v);
                assert!(v.contains(&c));
            }
        });
    }

    #[test]
    fn deterministic_given_seed() {
        // Same config twice must draw identical sequences: encode draws into
        // a signature and compare.
        let sig = |cfg: &Config| {
            let mut all = Vec::new();
            // run collects nothing on success, so record manually
            let g = Gen::new(cfg.seed, None);
            for _ in 0..16 {
                all.push(g.i64(-100, 100));
            }
            all
        };
        let c = Config::default();
        assert_eq!(sig(&c), sig(&c));
    }
}
