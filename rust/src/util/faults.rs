//! Deterministic, seed-keyed fault injection for robustness tests.
//!
//! Production code plants named *sites* on its failure-relevant paths —
//! [`check`] for panic/delay faults, [`check_io`] where an injected
//! `io::Error` makes sense — and this module decides, from an armed plan,
//! whether the Nth arrival at a site fires. Everything is deterministic:
//! a plan names exact hit indices (or derives them from a seed via the
//! project RNG), and per-site counters restart from zero on every
//! [`arm`]. Disarmed (the default), a site costs one relaxed atomic load.
//!
//! Plans are comma-separated `KIND@SITE#HITS` entries:
//!
//! * `KIND` — `panic` | `io` | `delay<MS>` (e.g. `delay10`);
//! * `SITE` — the exact site label (`dse::evaluate`,
//!   `dse::journal::push`, `fsx::write_atomic`, `trace::compile`);
//! * `HITS` — `N` (the Nth arrival), `N+M+…` (each listed arrival), or
//!   `rand:K/N/SEED` (K distinct arrivals drawn from `1..=N` with
//!   [`Rng`](crate::util::rng::Rng) seeded by `SEED`).
//!
//! Example: `panic@dse::evaluate#rand:2/8/42` panics two seed-chosen
//! evaluations out of the first eight. The `cfa` binary arms from the
//! `CFA_FAULTS` environment variable at startup ([`arm_from_env`]), which
//! is what the CI `fault-smoke` job drives.
//!
//! The armed plan is process-global; tests that arm must serialize (see
//! `tests/fault_isolation.rs`) and [`disarm`] when done. A fired panic
//! never corrupts the harness itself: the action is decided under the
//! state lock but performed after the guard is dropped, and the state lock
//! recovers from poisoning by reading through.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};

/// What an armed site does when a hit fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` at the site (exercises unwind paths).
    Panic,
    /// Return an injected `io::Error` from [`check_io`] sites.
    Io,
    /// Sleep this many milliseconds (exercises timeout/deadline paths).
    DelayMs(u64),
}

#[derive(Clone, Debug)]
struct SiteFault {
    kind: FaultKind,
    hits: BTreeSet<u64>,
}

#[derive(Clone, Debug, Default)]
struct State {
    /// Armed faults per site label.
    sites: BTreeMap<String, Vec<SiteFault>>,
    /// Arrivals observed per site since the last [`arm`].
    counts: BTreeMap<String, u64>,
}

/// Fast-path gate: off means [`check`]/[`check_io`] return immediately.
static ARMED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<State>> = Mutex::new(None);

fn state_lock() -> std::sync::MutexGuard<'static, Option<State>> {
    // a panic fired *by* the harness unwinds with no guard held, but a
    // caller could still die between unrelated sites; read through poison
    STATE.lock().unwrap_or_else(|p| p.into_inner())
}

/// Parse one `HITS` spec into the set of firing arrival indices.
fn parse_hits(spec: &str) -> Result<BTreeSet<u64>> {
    if let Some(rest) = spec.strip_prefix("rand:") {
        let parts: Vec<&str> = rest.split('/').collect();
        let [k, n, seed] = parts.as_slice() else {
            bail!("rand hits must be 'rand:K/N/SEED', got 'rand:{rest}'");
        };
        let k: u64 = k.parse().map_err(|_| anyhow!("bad K in 'rand:{rest}'"))?;
        let n: u64 = n.parse().map_err(|_| anyhow!("bad N in 'rand:{rest}'"))?;
        let seed: u64 = seed
            .parse()
            .map_err(|_| anyhow!("bad SEED in 'rand:{rest}'"))?;
        if k > n {
            bail!("rand hits: K={k} exceeds N={n}");
        }
        let mut rng = Rng::new(seed);
        let mut hits = BTreeSet::new();
        while (hits.len() as u64) < k {
            hits.insert(rng.gen_range(n) + 1); // arrivals are 1-based
        }
        return Ok(hits);
    }
    let mut hits = BTreeSet::new();
    for part in spec.split('+') {
        let n: u64 = part
            .trim()
            .parse()
            .map_err(|_| anyhow!("bad hit index '{part}' in '{spec}'"))?;
        if n == 0 {
            bail!("hit indices are 1-based; 0 in '{spec}'");
        }
        hits.insert(n);
    }
    Ok(hits)
}

fn parse_entry(entry: &str) -> Result<(String, SiteFault)> {
    let (kind_str, rest) = entry
        .split_once('@')
        .ok_or_else(|| anyhow!("fault entry '{entry}' is missing '@' (KIND@SITE#HITS)"))?;
    let (site, hits_str) = rest
        .split_once('#')
        .ok_or_else(|| anyhow!("fault entry '{entry}' is missing '#' (KIND@SITE#HITS)"))?;
    if site.is_empty() {
        bail!("fault entry '{entry}' names an empty site");
    }
    let kind = match kind_str {
        "panic" => FaultKind::Panic,
        "io" => FaultKind::Io,
        s => match s.strip_prefix("delay") {
            Some(ms) => FaultKind::DelayMs(
                ms.parse()
                    .map_err(|_| anyhow!("bad delay milliseconds in '{entry}'"))?,
            ),
            None => bail!("unknown fault kind '{kind_str}' (panic | io | delay<MS>)"),
        },
    };
    Ok((
        site.to_string(),
        SiteFault {
            kind,
            hits: parse_hits(hits_str)?,
        },
    ))
}

/// Arm a fault plan (see the module docs for the grammar). Resets every
/// per-site arrival counter, so plans are reproducible back-to-back.
pub fn arm(spec: &str) -> Result<()> {
    let mut state = State::default();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (site, fault) = parse_entry(entry)?;
        state.sites.entry(site).or_default().push(fault);
    }
    let mut g = state_lock();
    if state.sites.is_empty() {
        *g = None;
        ARMED.store(false, Ordering::Relaxed);
    } else {
        *g = Some(state);
        ARMED.store(true, Ordering::Relaxed);
    }
    Ok(())
}

/// Arm from the `CFA_FAULTS` environment variable (no-op when unset or
/// empty). The `cfa` binary calls this once at startup.
pub fn arm_from_env() -> Result<()> {
    match std::env::var("CFA_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => arm(&spec),
        _ => Ok(()),
    }
}

/// Drop the armed plan; every site returns to the one-load fast path.
pub fn disarm() {
    let mut g = state_lock();
    *g = None;
    ARMED.store(false, Ordering::Relaxed);
}

/// True iff a plan is armed.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Arrivals observed at `site` since the last [`arm`] (testing aid).
pub fn arrivals(site: &str) -> u64 {
    state_lock()
        .as_ref()
        .and_then(|s| s.counts.get(site).copied())
        .unwrap_or(0)
}

/// Count one arrival at `site` and return the fault to perform, if any.
/// The lock is released before the caller acts, so a fired panic cannot
/// poison the harness state.
fn fire(site: &str) -> Option<(FaultKind, u64)> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut g = state_lock();
    let state = g.as_mut()?;
    let n = {
        let c = state.counts.entry(site.to_string()).or_insert(0);
        *c += 1;
        *c
    };
    state
        .sites
        .get(site)
        .and_then(|faults| faults.iter().find(|f| f.hits.contains(&n)))
        .map(|f| (f.kind, n))
}

/// A panic/delay fault site. Counts one arrival; fires the armed fault for
/// this arrival index, if any. An armed `io` fault at a plain site panics
/// (it marks a plan/site mismatch the test author must fix).
pub fn check(site: &str) {
    match fire(site) {
        None => {}
        Some((FaultKind::Panic, n)) => panic!("fault injected: panic at {site} (arrival {n})"),
        Some((FaultKind::DelayMs(ms), _)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms))
        }
        Some((FaultKind::Io, n)) => {
            panic!("fault plan error: io fault armed at non-io site {site} (arrival {n})")
        }
    }
}

/// An IO fault site. Like [`check`], but an armed `io` fault surfaces as
/// an injected [`std::io::Error`] for the caller's normal error path.
pub fn check_io(site: &str) -> std::io::Result<()> {
    match fire(site) {
        None => Ok(()),
        Some((FaultKind::Io, n)) => Err(std::io::Error::other(format!(
            "fault injected: io error at {site} (arrival {n})"
        ))),
        Some((FaultKind::Panic, n)) => panic!("fault injected: panic at {site} (arrival {n})"),
        Some((FaultKind::DelayMs(ms), _)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The plan is process-global: tests arming it take this lock.
    pub(crate) fn serialize() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|p| p.into_inner())
    }

    struct Disarm;
    impl Drop for Disarm {
        fn drop(&mut self) {
            disarm();
        }
    }

    #[test]
    fn disarmed_sites_are_inert() {
        let _gate = serialize();
        let _cleanup = Disarm;
        disarm();
        assert!(!armed());
        check("nowhere");
        assert!(check_io("nowhere").is_ok());
        assert_eq!(arrivals("nowhere"), 0);
    }

    #[test]
    fn nth_hit_fires_and_counters_reset_on_arm() {
        let _gate = serialize();
        let _cleanup = Disarm;
        arm("panic@site::a#2").unwrap();
        assert!(armed());
        check("site::a"); // arrival 1: quiet
        let err = std::panic::catch_unwind(|| check("site::a")).unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("site::a") && msg.contains("arrival 2"), "{msg}");
        check("site::a"); // arrival 3: quiet again
        assert_eq!(arrivals("site::a"), 3);
        // re-arming restarts the count, so the same plan replays exactly
        arm("panic@site::a#2").unwrap();
        assert_eq!(arrivals("site::a"), 0);
        check("site::a");
        assert!(std::panic::catch_unwind(|| check("site::a")).is_err());
    }

    #[test]
    fn hit_lists_and_io_and_delay_kinds() {
        let _gate = serialize();
        let _cleanup = Disarm;
        arm("io@site::w#1+3, delay0@site::d#1").unwrap();
        assert!(check_io("site::w").is_err());
        assert!(check_io("site::w").is_ok());
        let e = check_io("site::w").unwrap_err();
        assert!(e.to_string().contains("fault injected"), "{e}");
        check("site::d"); // a zero-ms delay is just a scheduling point
        check("other::site"); // unarmed sites count but never fire
        assert_eq!(arrivals("other::site"), 1);
    }

    #[test]
    fn rand_hits_are_seed_deterministic_and_in_range() {
        let _gate = serialize();
        let _cleanup = Disarm;
        let a = parse_hits("rand:3/16/7").unwrap();
        let b = parse_hits("rand:3/16/7").unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        assert!(a.iter().all(|&h| (1..=16).contains(&h)), "{a:?}");
        let c = parse_hits("rand:3/16/8").unwrap();
        assert_ne!(a, c, "different seeds should differ (16 choose 3)");
        // arming with a rand plan fires exactly K times over N arrivals
        arm("panic@site::r#rand:2/8/42").unwrap();
        let fired = (0..8)
            .filter(|_| std::panic::catch_unwind(|| check("site::r")).is_err())
            .count();
        assert_eq!(fired, 2);
    }

    #[test]
    fn bad_plans_are_rejected() {
        let _gate = serialize();
        assert!(arm("panic@site#0").is_err(), "0 is not a 1-based hit");
        assert!(arm("panic@site").is_err(), "missing hits");
        assert!(arm("panicsite#1").is_err(), "missing site separator");
        assert!(arm("zap@site#1").is_err(), "unknown kind");
        assert!(arm("delayx@site#1").is_err(), "bad delay ms");
        assert!(arm("panic@site#rand:9/4/1").is_err(), "K > N");
        assert!(arm("panic@#1").is_err(), "empty site");
        assert!(arm("").is_ok(), "empty plan disarms");
        assert!(!armed());
    }
}
