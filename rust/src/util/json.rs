//! Minimal JSON value type, parser and pretty-printer.
//!
//! serde/serde_json are not in the offline crate set; reports, experiment
//! records and machine-readable bench output go through this module instead.
//! Supports the full JSON grammar (RFC 8259) minus `\u` surrogate pairs
//! beyond the BMP (sufficient for our ASCII reports).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Indented rendering (2 spaces).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, m: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            message: m.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("bad utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-12", "3.5", "1e3"] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn roundtrip_structures() {
        let src = r#"{"a":[1,2,{"b":"x\ny","c":null}],"d":true}"#;
        let v = parse(src).unwrap();
        let back = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, back);
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn pretty_output_parses() {
        let v = Json::obj(vec![
            ("name", Json::str("fig15")),
            ("rows", Json::arr((0..3).map(|i| Json::num(i as f64)))),
        ]);
        let p = v.to_string_pretty();
        assert!(p.contains('\n'));
        assert_eq!(parse(&p).unwrap(), v);
    }

    #[test]
    fn errors_have_offsets() {
        let e = parse("[1, ").unwrap_err();
        assert!(e.offset >= 3);
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("[1] x").unwrap_err().message.contains("trailing"));
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn deep_nesting() {
        let src = "[".repeat(64) + &"]".repeat(64);
        assert!(parse(&src).is_ok());
    }
}
