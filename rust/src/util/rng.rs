//! Deterministic xorshift/splitmix PRNG.
//!
//! The vendored crate set has no `rand`; this is the project's randomness
//! substrate, used by the property-test framework ([`crate::util::prop`]),
//! workload generators and benchmark jitter. It is fully deterministic from
//! its seed, which keeps every test and benchmark reproducible.

/// A splitmix64-seeded xoshiro256** generator.
///
/// Passes the usual empirical smoke checks (see unit tests) and is more than
/// adequate for test-case generation; it is *not* a cryptographic RNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (splitmix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64 bits (xoshiro256**).
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Lemire's multiply-shift rejection method: unbiased.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform signed integer in `[lo, hi]` (inclusive).
    pub fn gen_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.gen_range(span) as i64)
    }

    /// Uniform `usize` in `[0, n)`.
    pub fn gen_usize(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Uniform float in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_usize(xs.len())]
    }

    /// Bernoulli draw with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child generator (for independent sub-streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.gen_range(13);
            assert!(x < 13);
        }
    }

    #[test]
    fn range_covers_all_values() {
        let mut r = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn i64_inclusive_bounds() {
        let mut r = Rng::new(9);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            let x = r.gen_i64(-3, 3);
            assert!((-3..=3).contains(&x));
            saw_lo |= x == -3;
            saw_hi |= x == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        // mean should be near 0.5
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
