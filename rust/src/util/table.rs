//! Plain-text tables and bar charts for benchmark reports.
//!
//! The harness regenerates the paper's figures as ASCII output (plus CSV);
//! this module is the renderer: aligned tables, horizontal bar charts with
//! stacked "effective / raw" segments (Fig 15 style), and min–max span rows
//! (Fig 16 style).

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple aligned text table.
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: vec![Align::Left; headers.len()],
            rows: Vec::new(),
        }
    }

    /// Set per-column alignment (panics if length mismatches).
    pub fn aligns(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for c in 0..ncol {
            w[c] = self.headers[c].chars().count();
            for r in &self.rows {
                w[c] = w[c].max(r[c].chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize], aligns: &[Align]| -> String {
            let mut line = String::new();
            for c in 0..ncol {
                if c > 0 {
                    line.push_str("  ");
                }
                let pad = w[c] - cells[c].chars().count();
                match aligns[c] {
                    Align::Left => {
                        line.push_str(&cells[c]);
                        line.push_str(&" ".repeat(pad));
                    }
                    Align::Right => {
                        line.push_str(&" ".repeat(pad));
                        line.push_str(&cells[c]);
                    }
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &w, &self.aligns));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w, &self.aligns));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (quoting cells containing commas/quotes).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// One bar in a stacked bar chart: `effective` is drawn solid (`#`),
/// the `raw − effective` remainder hatched (`:`), mirroring the paper's
/// colored-vs-grey Fig 15 encoding.
pub struct StackedBar {
    pub label: String,
    pub effective: f64,
    pub raw: f64,
}

/// Render a horizontal stacked bar chart with a common scale up to `max`
/// (e.g. the bus bandwidth roofline), `width` characters wide.
pub fn stacked_bars(title: &str, bars: &[StackedBar], max: f64, width: usize, unit: &str) -> String {
    let label_w = bars
        .iter()
        .map(|b| b.label.chars().count())
        .max()
        .unwrap_or(0);
    let mut out = format!("{title}\n");
    for b in bars {
        let eff_w = ((b.effective / max) * width as f64).round().clamp(0.0, width as f64) as usize;
        let raw_w = ((b.raw / max) * width as f64).round().clamp(0.0, width as f64) as usize;
        let raw_w = raw_w.max(eff_w);
        let mut bar = String::new();
        bar.push_str(&"#".repeat(eff_w));
        bar.push_str(&":".repeat(raw_w - eff_w));
        bar.push_str(&" ".repeat(width - raw_w));
        out.push_str(&format!(
            "  {:<label_w$} |{bar}| {:7.1}/{:7.1} {unit}\n",
            b.label, b.effective, b.raw,
        ));
    }
    out.push_str(&format!(
        "  {:<label_w$}  {}^ {max:.0} {unit} roofline  (# effective, : redundant)\n",
        "",
        " ".repeat(width.saturating_sub(1)),
    ));
    out
}

/// A min–max span row (Fig 16 style: vertical lines from min to max).
pub struct SpanRow {
    pub label: String,
    pub min: f64,
    pub max: f64,
    pub marker: Option<f64>,
}

/// Render span rows on a shared `[0, scale]` axis.
pub fn span_chart(title: &str, rows: &[SpanRow], scale: f64, width: usize, unit: &str) -> String {
    let label_w = rows
        .iter()
        .map(|r| r.label.chars().count())
        .max()
        .unwrap_or(0);
    let pos = |x: f64| ((x / scale) * width as f64).round().clamp(0.0, width as f64) as usize;
    let mut out = format!("{title}\n");
    for r in rows {
        let (a, b) = (pos(r.min), pos(r.max));
        let mut line: Vec<char> = vec![' '; width + 1];
        for c in line.iter_mut().take(b + 1).skip(a) {
            *c = '=';
        }
        line[a] = '|';
        line[b.min(width)] = '|';
        if let Some(m) = r.marker {
            line[pos(m).min(width)] = '*';
        }
        out.push_str(&format!(
            "  {:<label_w$} {}  [{:.2} .. {:.2}] {unit}\n",
            r.label,
            line.iter().collect::<String>(),
            r.min,
            r.max,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment_and_arity() {
        let mut t = Table::new(&["name", "value"]).aligns(&[Align::Left, Align::Right]);
        t.row_strs(&["a", "1"]);
        t.row_strs(&["longer", "23"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a"));
        // right-aligned numbers end at the same column
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn csv_quotes() {
        let mut t = Table::new(&["k", "v"]);
        t.row_strs(&["x,y", "has \"q\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"has \"\"q\"\"\""));
    }

    #[test]
    fn stacked_bar_geometry() {
        let s = stacked_bars(
            "bw",
            &[StackedBar {
                label: "cfa".into(),
                effective: 50.0,
                raw: 100.0,
            }],
            100.0,
            20,
            "MB/s",
        );
        // 10 chars solid, 10 hatched
        assert!(s.contains(&format!("|{}{}|", "#".repeat(10), ":".repeat(10))));
    }

    #[test]
    fn stacked_bar_clamps_overflow() {
        let s = stacked_bars(
            "bw",
            &[StackedBar {
                label: "x".into(),
                effective: 150.0,
                raw: 150.0,
            }],
            100.0,
            10,
            "u",
        );
        assert!(s.contains(&"#".repeat(10)));
    }

    #[test]
    fn span_chart_renders() {
        let s = span_chart(
            "area",
            &[SpanRow {
                label: "slices".into(),
                min: 2.0,
                max: 5.0,
                marker: Some(3.0),
            }],
            10.0,
            40,
            "%",
        );
        assert!(s.contains('|'));
        assert!(s.contains('*'));
    }
}
