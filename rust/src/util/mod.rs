//! Support substrates: randomness, statistics, property testing, JSON,
//! CLI parsing, text rendering, fork-join parallelism, fault injection
//! and crash-safe file writes.
//!
//! The offline crate set ships none of the usual ecosystem helpers
//! (rand / criterion / proptest / serde / clap / rayon), so this module
//! provides the project-local equivalents. Everything here is
//! deterministic and dependency-free.

pub mod cli;
pub mod faults;
pub mod fsx;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod signals;
pub mod stats;
pub mod table;
