//! End-to-end driver (the repo's full-stack proof): 2-D heat diffusion
//! (jacobi2d5p, Table I's "Laplace equation") executed tile by tile through
//! the complete system —
//!
//!   CFA / baseline layout  →  burst plans  →  AXI+DRAM timing model
//!         →  AOT-compiled Pallas/JAX tile kernels via PJRT
//!         →  facet writeback  →  numeric verification.
//!
//! The run is recorded in EXPERIMENTS.md. Requires `make artifacts`.
//!
//! Run with: `cargo run --release --example heat_diffusion [-- --steps 32]`

use cfa::coordinator::reference::StencilKind;
use cfa::experiment::{ExperimentSpec, Mode};
use cfa::layout::registry;
use cfa::memsim::MemConfig;
use cfa::runtime::Runtime;
use cfa::util::cli::{env_args, Command};
use cfa::util::table::{Align, Table};

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("heat_diffusion", "end-to-end heat equation")
        .opt("n", "grid size (n x n)", Some("96"))
        .opt("steps", "time steps", Some("32"))
        .opt("artifacts", "artifacts dir", Some("artifacts"));
    let a = cmd.parse(&env_args(0)).map_err(anyhow::Error::msg)?;
    let mut n: i64 = a.get_or("n", "96").parse()?;
    let mut steps: i64 = a.get_or("steps", "32").parse()?;
    // tile 8x32x32 must divide the skewed space (steps, n+steps, n+steps):
    // round up to the nearest legal configuration.
    let (tt, ts) = (8, 32);
    if steps % tt != 0 {
        steps += tt - steps % tt;
        println!("(steps rounded up to {steps} to fit the 8x32x32 tile)");
    }
    if (n + steps) % ts != 0 {
        n += ts - (n + steps) % ts;
        println!("(grid rounded up to {n} to fit the 8x32x32 tile)");
    }

    let rt = Runtime::open(a.get_or("artifacts", "artifacts"))?;
    println!("PJRT platform: {}", rt.platform());
    println!("heat equation: {n}x{n} grid, {steps} steps, tile 8x32x32\n");

    let mem = MemConfig {
        elem_bytes: 4, // f32 compute path
        ..MemConfig::default()
    };
    let mut table = Table::new(&[
        "allocation",
        "txns",
        "raw MB/s",
        "eff MB/s",
        "% of bus",
        "max |err|",
        "wall s",
    ])
    .aligns(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    let artifact = "jacobi2d5p_t8x32x32";
    let tile = rt.load(artifact)?.info.tile.clone();
    for name in registry::global().names() {
        let session = ExperimentSpec::builder()
            .stencil(artifact, StencilKind::Jacobi5p, tile.clone(), n, n, steps)
            .layout(name)
            .pe_ops_per_cycle(64)
            .mem(mem.clone())
            .compile()?;
        let rep = session.run_with_runtime(&rt, Mode::Data { seed: 42 })?;
        let err = rep.max_abs_err.unwrap_or(f64::INFINITY);
        anyhow::ensure!(err < 1e-4, "{name}: verification failed ({err:.3e})");
        table.row(&[
            rep.layout.clone(),
            rep.transactions.to_string(),
            format!("{:.1}", rep.raw_mb_s),
            format!("{:.1}", rep.effective_mb_s),
            format!("{:.1}", rep.bus_pct()),
            format!("{err:.2e}"),
            format!("{:.2}", rep.wall_secs),
        ]);
    }
    println!("{}", table.render());
    println!("all allocations verified against the native Rust reference — OK");
    Ok(())
}
