//! End-to-end driver (the repo's full-stack proof): 2-D heat diffusion
//! (jacobi2d5p, Table I's "Laplace equation") executed tile by tile through
//! the complete system —
//!
//!   CFA / baseline layout  →  burst plans  →  AXI+DRAM timing model
//!         →  AOT-compiled Pallas/JAX tile kernels via PJRT
//!         →  facet writeback  →  numeric verification.
//!
//! The run is recorded in EXPERIMENTS.md. Requires `make artifacts`.
//!
//! Run with: `cargo run --release --example heat_diffusion [-- --steps 32]`

use cfa::coordinator::stencil::{run_stencil, StencilRun};
use cfa::coordinator::AllocKind;
use cfa::memsim::MemConfig;
use cfa::runtime::Runtime;
use cfa::util::cli::{env_args, Command};
use cfa::util::table::{Align, Table};

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("heat_diffusion", "end-to-end heat equation")
        .opt("n", "grid size (n x n)", Some("96"))
        .opt("steps", "time steps", Some("32"))
        .opt("artifacts", "artifacts dir", Some("artifacts"));
    let a = cmd.parse(&env_args(0)).map_err(anyhow::Error::msg)?;
    let mut n: i64 = a.get_or("n", "96").parse()?;
    let mut steps: i64 = a.get_or("steps", "32").parse()?;
    // tile 8x32x32 must divide the skewed space (steps, n+steps, n+steps):
    // round up to the nearest legal configuration.
    let (tt, ts) = (8, 32);
    if steps % tt != 0 {
        steps += tt - steps % tt;
        println!("(steps rounded up to {steps} to fit the 8x32x32 tile)");
    }
    if (n + steps) % ts != 0 {
        n += ts - (n + steps) % ts;
        println!("(grid rounded up to {n} to fit the 8x32x32 tile)");
    }

    let rt = Runtime::open(a.get_or("artifacts", "artifacts"))?;
    println!("PJRT platform: {}", rt.platform());
    println!("heat equation: {n}x{n} grid, {steps} steps, tile 8x32x32\n");

    let mem = MemConfig {
        elem_bytes: 4, // f32 compute path
        ..MemConfig::default()
    };
    let mut table = Table::new(&[
        "allocation",
        "txns",
        "raw MB/s",
        "eff MB/s",
        "% of bus",
        "max |err|",
        "wall s",
    ])
    .aligns(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for alloc in AllocKind::ALL {
        let mut cfg = StencilRun::heat_default(alloc);
        cfg.n = n;
        cfg.m = n;
        cfg.steps = steps;
        let rep = run_stencil(&rt, &cfg, &mem)?;
        anyhow::ensure!(
            rep.max_abs_err < 1e-4,
            "{}: verification failed ({:.3e})",
            alloc.name(),
            rep.max_abs_err
        );
        table.row(&[
            rep.alloc.clone(),
            rep.transactions.to_string(),
            format!("{:.1}", rep.raw_mb_s(&mem)),
            format!("{:.1}", rep.effective_mb_s(&mem)),
            format!("{:.1}", 100.0 * rep.effective_mb_s(&mem) / mem.peak_mb_s()),
            format!("{:.2e}", rep.max_abs_err),
            format!("{:.2}", rep.wall_secs),
        ]);
    }
    println!("{}", table.render());
    println!("all allocations verified against the native Rust reference — OK");
    Ok(())
}
