//! Layout explorer: feed an arbitrary uniform dependence pattern (or a
//! Table I benchmark) and inspect what every allocation does with it —
//! facet shapes, burst plans, footprints, simulated bandwidth.
//!
//! Run with:
//!   cargo run --release --example layout_explorer -- --benchmark gaussian
//!   cargo run --release --example layout_explorer -- \
//!       --deps "-1,0,0;-1,-1,-1;0,0,-2" --tile 8x8x8
//!
//! Custom patterns must be backwards (all components <= 0); forward
//! patterns are skew-normalized automatically when possible.

use cfa::harness::figures::measure_bandwidth_named;
use cfa::harness::workloads::{self, Workload};
use cfa::layout::cfa::Cfa;
use cfa::layout::{registry, Allocation};
use cfa::memsim::MemConfig;
use cfa::poly::deps::{normalize, DepPattern};
use cfa::poly::tiling::Tiling;
use cfa::util::cli::{env_args, Command};

fn parse_deps(s: &str) -> anyhow::Result<Vec<Vec<i64>>> {
    s.split(';')
        .map(|v| {
            v.split(',')
                .map(|x| x.trim().parse::<i64>().map_err(|e| anyhow::anyhow!("{e}")))
                .collect()
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("layout_explorer", "inspect allocations")
        .opt("benchmark", "Table I name (overrides --deps)", None)
        .opt("deps", "custom pattern: \"-1,0;-1,-1\" (';'-separated)", None)
        .opt("tile", "tile sizes", Some("16x16x16"))
        .opt("tiles-per-dim", "tiles per dim", Some("3"));
    let a = cmd.parse(&env_args(0)).map_err(anyhow::Error::msg)?;
    let tile = a.get_sizes("tile").map_err(anyhow::Error::msg)?.unwrap();
    let tpd = a.get_usize("tiles-per-dim", 3).map_err(anyhow::Error::msg)? as i64;

    let w: Workload = if let Some(name) = a.get("benchmark") {
        workloads::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown benchmark '{name}'"))?
    } else if let Some(d) = a.get("deps") {
        let raw = parse_deps(d)?;
        let (skew, pat) = normalize(&raw)?;
        if !skew.is_identity() {
            println!("pattern skew-normalized with factors {:?}", skew.factors);
        }
        Workload {
            name: "custom",
            equivalent: "user pattern",
            dims: pat.dims(),
            deps: pat.vecs().to_vec(),
            tile_sizes: vec![tile.clone()],
        }
    } else {
        workloads::by_name("jacobi2d5p").unwrap()
    };
    anyhow::ensure!(tile.len() == w.dims, "tile dims must match pattern dims");

    let deps = DepPattern::new(w.deps.clone())?;
    println!("pattern: {deps}");
    println!("facet widths w_k: {:?}\n", deps.widths());
    let tiling = Tiling::new(w.space_for(&tile, tpd), tile.clone());

    // CFA internals
    let cfa = Cfa::new(tiling.clone(), deps.clone())?;
    let names: Vec<&str> = (0..w.dims).map(|d| cfa::hlsgen::AXIS_NAMES[d]).collect();
    println!("CFA facet arrays:");
    for fa in cfa.facet_arrays() {
        println!(
            "  {}  contiguity axis: {}",
            fa.describe(&names),
            fa.contig.map(|c| names[c]).unwrap_or("-")
        );
    }

    // every allocation side by side
    let mem = MemConfig::default();
    let reg = registry::global();
    println!("\n{:<10} {:>12} {:>8} {:>10} {:>10}", "alloc", "footprint", "txns", "raw MB/s", "eff MB/s");
    for name in reg.names() {
        let built = reg.build(name, &tiling, &deps)?;
        let p = measure_bandwidth_named(&w, &tile, name, &mem, tpd, 1, &reg)?;
        println!(
            "{:<10} {:>12} {:>8} {:>10.1} {:>10.1}",
            p.alloc,
            built.footprint(),
            p.transactions,
            p.raw_mb_s,
            p.effective_mb_s
        );
    }
    Ok(())
}
