//! Quickstart: build a Canonical Facet Allocation for a Jacobi stencil,
//! inspect the layout it constructs, and compare its simulated memory
//! bandwidth against the three baseline allocations of the paper.
//!
//! Run with: `cargo run --release --example quickstart`

use cfa::experiment::{ExperimentSpec, Mode};
use cfa::harness::figures::measure_bandwidth_named;
use cfa::harness::workloads;
use cfa::layout::cfa::Cfa;
use cfa::layout::{registry, Allocation};
use cfa::memsim::MemConfig;
use cfa::poly::deps::DepPattern;
use cfa::poly::tiling::Tiling;

fn main() -> anyhow::Result<()> {
    // 1. Pick a benchmark from Table I and a tile size.
    let w = workloads::by_name("jacobi2d5p").unwrap();
    let tile = vec![16, 16, 16];
    println!("benchmark: {} ({} deps)\n", w.name, w.n_deps());

    // 2. Build the CFA layout: one facet array per active axis, with the
    //    paper's data tiling and dimension permutations applied.
    let deps = DepPattern::new(w.deps.clone())?;
    let tiling = Tiling::new(w.space_for(&tile, 3), tile.clone());
    let cfa = Cfa::new(tiling, deps)?;
    println!("facet arrays (total {} elements off-chip):", cfa.footprint());
    for fa in cfa.facet_arrays() {
        println!("  {}", fa.describe(&["t", "u", "v"]));
    }

    // 3. Inspect an interior tile's transfer plan: a handful of long
    //    bursts (the paper's "4 transactions per 3-D tile").
    let plan = cfa.plan(&[1, 1, 1]);
    println!(
        "\ninterior tile: {} read bursts ({} elems), {} write bursts ({} elems)",
        plan.read_runs.len(),
        plan.read_raw(),
        plan.write_runs.len(),
        plan.write_raw()
    );

    // 4. Simulate the memory-bound rig (Fig 14) for all four allocations.
    let mem = MemConfig::default();
    println!(
        "\nbandwidth on the simulated ZC706 HP port (roofline {} MB/s):",
        mem.peak_mb_s()
    );
    let reg = registry::global();
    for name in reg.names() {
        let p = measure_bandwidth_named(&w, &tile, name, &mem, 3, 1, &reg)?;
        println!(
            "  {:<9} raw {:>6.1} MB/s   effective {:>6.1} MB/s   {} transactions",
            p.alloc, p.raw_mb_s, p.effective_mb_s, p.transactions
        );
    }

    // 5. The same measurement through the experiment session API (the
    //    crate's front door): spec -> session -> unified report. Layouts
    //    are named through the open registry, so a custom layout
    //    registered by name would be reachable here too.
    let report = ExperimentSpec::builder()
        .named(w.name, tile.clone(), 3)
        .layout("cfa")
        .mem(mem.clone())
        .compile()?
        .run(Mode::Sweep)?;
    println!("\nsession report:\n  {}", report.summary());
    Ok(())
}
