//! Smith-Waterman 3-sequence alignment through the full stack: the
//! wavefront DP benchmark of Table I, executed tile by tile with PJRT
//! kernels (max-plus associative-scan formulation) and verified against
//! the native DP reference.
//!
//! Run with: `cargo run --release --example sw_alignment [-- --n 48]`

use cfa::experiment::{ExperimentSpec, Mode};
use cfa::layout::registry;
use cfa::memsim::MemConfig;
use cfa::runtime::Runtime;
use cfa::util::cli::{env_args, Command};

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("sw_alignment", "3-seq alignment e2e")
        .opt("n", "sequence length (multiple of 16)", Some("48"))
        .opt("artifacts", "artifacts dir", Some("artifacts"));
    let a = cmd.parse(&env_args(0)).map_err(anyhow::Error::msg)?;
    let n: i64 = a.get_or("n", "48").parse()?;

    let rt = Runtime::open(a.get_or("artifacts", "artifacts"))?;
    let mem = MemConfig {
        elem_bytes: 4,
        ..MemConfig::default()
    };
    println!("aligning three random 4-letter sequences of length {n}\n");
    let artifact = "sw3_t16x16x16";
    let tile = rt.load(artifact)?.info.tile.clone();
    for name in registry::global().names() {
        let session = ExperimentSpec::builder()
            .sw3(artifact, tile.clone(), n, n, n)
            .layout(name)
            .pe_ops_per_cycle(64)
            .mem(mem.clone())
            .compile()?;
        let rep = session.run_with_runtime(&rt, Mode::Data { seed: 7 })?;
        let err = rep.max_abs_err.unwrap_or(f64::INFINITY);
        anyhow::ensure!(err < 1e-4, "{name}: verification failed ({err:.3e})");
        println!("{}", rep.summary());
    }
    println!("\nall facet values match the native DP reference — OK");
    Ok(())
}
