//! Smith-Waterman 3-sequence alignment through the full stack: the
//! wavefront DP benchmark of Table I, executed tile by tile with PJRT
//! kernels (max-plus associative-scan formulation) and verified against
//! the native DP reference.
//!
//! Run with: `cargo run --release --example sw_alignment [-- --n 48]`

use cfa::coordinator::sw::{run_sw, SwRun};
use cfa::coordinator::AllocKind;
use cfa::memsim::MemConfig;
use cfa::runtime::Runtime;
use cfa::util::cli::{env_args, Command};

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("sw_alignment", "3-seq alignment e2e")
        .opt("n", "sequence length (multiple of 16)", Some("48"))
        .opt("artifacts", "artifacts dir", Some("artifacts"));
    let a = cmd.parse(&env_args(0)).map_err(anyhow::Error::msg)?;
    let n: i64 = a.get_or("n", "48").parse()?;

    let rt = Runtime::open(a.get_or("artifacts", "artifacts"))?;
    let mem = MemConfig {
        elem_bytes: 4,
        ..MemConfig::default()
    };
    println!("aligning three random 4-letter sequences of length {n}\n");
    for alloc in AllocKind::ALL {
        let mut cfg = SwRun::default_run(alloc);
        cfg.ni = n;
        cfg.nj = n;
        cfg.nk = n;
        let rep = run_sw(&rt, &cfg, &mem)?;
        anyhow::ensure!(
            rep.max_abs_err < 1e-4,
            "{}: verification failed ({:.3e})",
            alloc.name(),
            rep.max_abs_err
        );
        println!("{}", rep.summary(&mem));
    }
    println!("\nall facet values match the native DP reference — OK");
    Ok(())
}
