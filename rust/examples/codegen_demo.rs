//! Codegen demo: run the CFA compiler pass end to end and print the HLS C
//! it generates (the paper's Fig 12 copy loops + Fig 13 DATAFLOW top).
//!
//! Run with: `cargo run --release --example codegen_demo [-- --benchmark gaussian]`

use cfa::harness::workloads;
use cfa::layout::cfa::Cfa;
use cfa::poly::deps::DepPattern;
use cfa::poly::tiling::Tiling;
use cfa::util::cli::{env_args, Command};

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("codegen_demo", "emit HLS C")
        .opt("benchmark", "Table I benchmark", Some("jacobi2d5p"))
        .opt("tile", "tile sizes", Some("16x16x16"));
    let a = cmd.parse(&env_args(0)).map_err(anyhow::Error::msg)?;
    let name = a.get_or("benchmark", "jacobi2d5p");
    let tile = a.get_sizes("tile").map_err(anyhow::Error::msg)?.unwrap();
    let w = workloads::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark '{name}'"))?;
    let deps = DepPattern::new(w.deps.clone())?;
    let tiling = Tiling::new(w.space_for(&tile, 3), tile);
    let cfa = Cfa::new(tiling, deps)?;
    print!("{}", cfa::hlsgen::generate_c(&cfa, name));
    Ok(())
}
