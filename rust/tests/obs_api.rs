//! Integration contracts for the observability layer (`cfa::obs`):
//!
//! * random span nestings always capture as balanced, per-thread LIFO
//!   event streams with monotone begin ids (property test);
//! * `Capture::export` writes Chrome trace-event JSON that round-trips
//!   through the project's own parser with the documented shape;
//! * timeline sampling is **passive**: `run_trace_with_timeline`
//!   reproduces `run_trace` bit for bit, and the epoch sums equal the
//!   aggregate `Timing` counters exactly, at any epoch granularity;
//! * multi-channel timelines are identical across serial and parallel
//!   replay, through the `Session` front door.
//!
//! The zero-allocation contract of the disabled span path lives in its
//! own binary (`tests/obs_alloc.rs`) because it needs a counting global
//! allocator and no concurrently-capturing neighbours.

use cfa::experiment::{ExperimentSpec, Mode, ScheduleKind, Session};
use cfa::obs::span::{current_tid, events_balanced};
use cfa::obs::{begin_capture, span, SpanEvent};
use cfa::util::json::{self, Json};
use cfa::util::prop::{run as prop_run, Config, Gen};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Tests that open a capture serialize on this lock: captures are
/// process-global (refcounted), so two concurrent capturing tests would
/// each see the union window. Filtering by tid makes that safe, but
/// serializing keeps the windows small and the assertions sharp.
fn serial() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Only this thread's events: other tests in this binary run
/// instrumented code (session replays) whose spans land in the same
/// process-global sink while our capture is open.
fn mine(events: Vec<SpanEvent>) -> Vec<SpanEvent> {
    let tid = current_tid();
    events.into_iter().filter(|e| e.tid == tid).collect()
}

const NAMES: [&str; 4] = ["prop::a", "prop::b", "prop::c", "prop::d"];

/// Open a random tree of nested spans; returns the number opened.
fn weave(g: &Gen, depth: usize) -> usize {
    let mut opened = 0;
    for _ in 0..g.usize(0, 3) {
        let _s = span(NAMES[g.usize(0, NAMES.len() - 1)]);
        opened += 1;
        if depth > 0 {
            opened += weave(g, depth - 1);
        }
        // _s drops here: strictly LIFO by construction
    }
    opened
}

#[test]
fn prop_random_span_nestings_capture_balanced_and_lifo() {
    let _g = serial();
    prop_run("span nesting balances", Config::small(32), |g| {
        let cap = begin_capture();
        let opened = weave(g, g.usize(0, 3));
        let events = mine(cap.finish());
        assert_eq!(events.len(), 2 * opened, "one B and one E per span");
        assert!(events_balanced(&events), "per-thread LIFO violated");
        // begin ids are monotone on one thread, and every id closes
        let begins: Vec<u64> = events.iter().filter(|e| e.begin).map(|e| e.id).collect();
        let mut sorted = begins.clone();
        sorted.sort_unstable();
        assert_eq!(begins, sorted, "begin order is id order");
        for id in begins {
            let n = events.iter().filter(|e| e.id == id).count();
            assert_eq!(n, 2, "span id {id} must appear exactly as a B/E pair");
        }
    });
}

#[test]
fn exported_profile_round_trips_through_the_project_json_parser() {
    let _g = serial();
    let path = std::env::temp_dir().join("cfa_obs_api_profile.json");
    std::fs::remove_file(&path).ok();

    let cap = begin_capture();
    {
        let _outer = span("export::outer");
        let _inner = span("export::inner");
    }
    cap.export(&path).expect("export writes the profile");

    let text = std::fs::read_to_string(&path).unwrap();
    let parsed = json::parse(&text).expect("Perfetto-loadable JSON");
    assert_eq!(
        parsed.get("displayTimeUnit").and_then(Json::as_str),
        Some("ms")
    );
    let all = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    let tid = current_tid() as f64;
    let ours: Vec<&Json> = all
        .iter()
        .filter(|e| e.get("tid").and_then(Json::as_f64) == Some(tid))
        .collect();
    assert_eq!(ours.len(), 4, "two spans, B+E each");
    let mut last_ts = 0.0;
    for e in &ours {
        assert_eq!(e.get("cat").and_then(Json::as_str), Some("cfa"));
        let ph = e.get("ph").and_then(Json::as_str).unwrap();
        assert!(ph == "B" || ph == "E", "duration events only, got {ph}");
        assert!(e.get("name").and_then(Json::as_str).is_some());
        assert!(
            e.get("args")
                .and_then(|a| a.get("span_id"))
                .and_then(Json::as_f64)
                .is_some(),
            "span_id rides in args"
        );
        let ts = e.get("ts").and_then(Json::as_f64).unwrap();
        assert!(ts >= last_ts, "timestamps are monotone within a thread");
        last_ts = ts;
    }
    let names: Vec<&str> = ours
        .iter()
        .map(|e| e.get("name").and_then(Json::as_str).unwrap())
        .collect();
    assert_eq!(
        names,
        ["export::outer", "export::inner", "export::inner", "export::outer"]
    );
    std::fs::remove_file(&path).ok();
}

fn tiny_session(channels: usize, threads: usize) -> Session {
    ExperimentSpec::builder()
        .named("jacobi2d5p", vec![8, 8, 8], 2)
        .schedule(ScheduleKind::Flat)
        .channels(channels)
        .threads(threads)
        .compile()
        .unwrap()
}

#[test]
fn timeline_sampling_is_passive_and_epoch_sums_equal_the_timing() {
    let session = tiny_session(1, 1);
    let trace = session.compile_trace();
    let plain = session.run_trace(&trace).unwrap();
    let (sampled, tl) = session.run_trace_with_timeline(&trace, 256).unwrap();

    // passive: the sampled report is bit-identical to the unsampled one
    assert_eq!(plain.timing, sampled.timing);
    assert_eq!(plain.makespan_cycles, sampled.makespan_cycles);
    assert_eq!(plain.raw_bytes, sampled.raw_bytes);
    assert_eq!(plain.useful_bytes, sampled.useful_bytes);
    assert_eq!(plain.transactions, sampled.transactions);
    assert_eq!(
        plain.effective_mb_s.to_bits(),
        sampled.effective_mb_s.to_bits()
    );

    // the headline identity: epochs sum exactly to the aggregate Timing
    let timing = sampled.timing.as_ref().expect("timing-mode report");
    assert!(tl.matches(timing), "epoch sums != aggregate counters");
    assert_eq!(tl.channels.len(), 1);
    assert!(!tl.channels[0].is_empty(), "a real run has traffic");

    // granularity invariance: any epoch size sums to the same totals
    for epoch_cycles in [1, 17, 4096, u64::MAX] {
        let (_, tl2) = session
            .run_trace_with_timeline(&trace, epoch_cycles)
            .unwrap();
        assert!(tl2.matches(timing), "epoch_cycles={epoch_cycles}");
        let (a, b) = (tl.totals(), tl2.totals());
        assert_eq!(a.data_cycles, b.data_cycles);
        assert_eq!(a.axi_bursts, b.axi_bursts);
        assert_eq!(a.row_hits, b.row_hits);
        assert_eq!(a.row_misses, b.row_misses);
    }
}

#[test]
fn multichannel_timelines_identical_across_serial_and_parallel_replay() {
    let serial_session = tiny_session(4, 1);
    let parallel_session = tiny_session(4, 4);
    let trace_s = serial_session.compile_trace();
    let trace_p = parallel_session.compile_trace();

    let (rep_s, tl_s) = serial_session.run_trace_with_timeline(&trace_s, 512).unwrap();
    let (rep_p, tl_p) = parallel_session
        .run_trace_with_timeline(&trace_p, 512)
        .unwrap();

    assert_eq!(rep_s.timing, rep_p.timing);
    assert_eq!(tl_s, tl_p, "timeline depends on thread count");
    assert_eq!(tl_s.channels.len(), 4, "one epoch list per channel");
    assert!(tl_s.matches(rep_s.timing.as_ref().unwrap()));
    assert!(tl_s.imbalance() >= 1.0);

    // the artifact itself is byte-deterministic
    let mem = cfa::memsim::MemConfig::default();
    assert_eq!(
        tl_s.to_json(&mem, 1.0).to_string_pretty(),
        tl_p.to_json(&mem, 1.0).to_string_pretty()
    );
}

#[test]
fn timing_mode_still_matches_trace_replay_with_observability_wired_in() {
    // regression guard: the spans and samplers added through the replay
    // path must not perturb the Mode::Timing ≡ trace-replay identity
    let session = tiny_session(1, 1);
    let direct = session.run(Mode::Timing).unwrap();
    let trace = session.compile_trace();
    let replayed = session.run_trace(&trace).unwrap();
    assert_eq!(replayed.timing, direct.timing);
    assert_eq!(replayed.makespan_cycles, direct.makespan_cycles);
}
