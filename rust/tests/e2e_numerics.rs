//! Full-stack integration: layout → host memory → PJRT tile compute →
//! verification, for every allocation. A wrong address function anywhere
//! breaks the stencil numerics, so this is the strongest correctness
//! signal in the repo.
//!
//! Requires `make artifacts` (skipped silently otherwise, like the runtime
//! unit tests) and the `pjrt` feature — the offline default build has no
//! compute backend, so the whole file is compiled out without it.

#![cfg(feature = "pjrt")]

use cfa::coordinator::reference::StencilKind;
use cfa::experiment::{ExperimentSpec, Mode, Report, Session};
use cfa::layout::registry;
use cfa::memsim::MemConfig;
use cfa::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Runtime::open(dir).expect("open artifacts"))
    } else {
        eprintln!("artifacts/ missing - skipping e2e tests");
        None
    }
}

fn f32_mem() -> MemConfig {
    MemConfig {
        elem_bytes: 4,
        ..MemConfig::default()
    }
}

/// Compile a stencil session against an artifact's own tile shape.
fn stencil_session(
    rt: &Runtime,
    artifact: &str,
    kind: StencilKind,
    n: i64,
    steps: i64,
    layout: &str,
    pe: u64,
) -> anyhow::Result<Session> {
    let tile = rt.load(artifact)?.info.tile.clone();
    ExperimentSpec::builder()
        .stencil(artifact, kind, tile, n, n, steps)
        .layout(layout)
        .pe_ops_per_cycle(pe)
        .mem(f32_mem())
        .compile()
}

fn run_data(session: &Session, rt: &Runtime, seed: u64) -> Report {
    session
        .run_with_runtime(rt, Mode::Data { seed })
        .expect("run")
}

#[test]
fn jacobi_heat_all_allocations_are_exact() {
    let Some(rt) = runtime() else { return };
    // jacobi2d5p_t4x16x16: r=1; steps=8, n=m=24 -> skewed (8, 32, 32)
    for name in registry::global().names() {
        let session =
            stencil_session(&rt, "jacobi2d5p_t4x16x16", StencilKind::Jacobi5p, 24, 8, name, 64)
                .expect("compile");
        let report = run_data(&session, &rt, 11);
        let err = report.max_abs_err.unwrap_or(f64::INFINITY);
        assert!(err < 1e-4, "{name}: numeric mismatch {err:.3e}");
        assert!(report.raw_bytes >= report.useful_bytes);
        assert!(report.makespan_cycles > 0);
    }
}

#[test]
fn gaussian_blur_cfa_is_exact() {
    let Some(rt) = runtime() else { return };
    // gaussian_t4x16x16: r=2; steps=8, n=m=16 -> skewed (8, 32, 32)
    let session = stencil_session(&rt, "gaussian_t4x16x16", StencilKind::Gaussian, 16, 8, "cfa", 64)
        .expect("compile");
    let report = run_data(&session, &rt, 3);
    let err = report.max_abs_err.unwrap_or(f64::INFINITY);
    assert!(err < 1e-4, "gaussian mismatch {err:.3e}");
}

#[test]
fn jacobi9p_cfa_is_exact() {
    let Some(rt) = runtime() else { return };
    let session =
        stencil_session(&rt, "jacobi2d9p_t4x16x16", StencilKind::Jacobi9p, 24, 8, "cfa", 64)
            .expect("compile");
    let report = run_data(&session, &rt, 5);
    let err = report.max_abs_err.unwrap_or(f64::INFINITY);
    assert!(err < 1e-4, "{err:.3e}");
}

#[test]
fn smith_waterman_all_allocations_are_exact() {
    let Some(rt) = runtime() else { return };
    let tile = rt.load("sw3_t16x16x16").expect("load").info.tile.clone();
    for name in registry::global().names() {
        let session = ExperimentSpec::builder()
            .sw3("sw3_t16x16x16", tile.clone(), 32, 32, 32)
            .layout(name)
            .pe_ops_per_cycle(64)
            .mem(f32_mem())
            .compile()
            .expect("compile");
        let report = run_data(&session, &rt, 9);
        let err = report.max_abs_err.unwrap_or(f64::INFINITY);
        assert!(err < 1e-4, "{name}: sw mismatch {err:.3e}");
    }
}

#[test]
fn cfa_beats_baselines_on_effective_bandwidth() {
    // The paper's headline: CFA's effective bandwidth tops every baseline
    // on the same workload.
    let Some(rt) = runtime() else { return };
    let mut eff = std::collections::BTreeMap::new();
    for name in registry::global().names() {
        // pe_ops_per_cycle high enough that the run is memory-bound (Fig 14)
        let session = stencil_session(
            &rt,
            "jacobi2d5p_t4x16x16",
            StencilKind::Jacobi5p,
            24,
            8,
            name,
            1_000_000,
        )
        .expect("compile");
        let report = run_data(&session, &rt, 1);
        eff.insert(name.to_string(), report.effective_mb_s);
    }
    let cfa = eff[cfa::layout::registry::names::CFA];
    for (name, &e) in &eff {
        if name != cfa::layout::registry::names::CFA {
            assert!(
                cfa >= e * 0.99,
                "cfa {cfa:.1} MB/s should beat {name} {e:.1} MB/s ({eff:?})"
            );
        }
    }
}

#[test]
fn tile_size_mismatch_is_reported() {
    let Some(rt) = runtime() else { return };
    // skewed space not divisible by the artifact tile: rejected at compile
    let bad = stencil_session(&rt, "jacobi2d5p_t4x16x16", StencilKind::Jacobi5p, 23, 8, "cfa", 64);
    assert!(bad.is_err());
}
