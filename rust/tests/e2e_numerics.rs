//! Full-stack integration: layout → host memory → PJRT tile compute →
//! verification, for every allocation. A wrong address function anywhere
//! breaks the stencil numerics, so this is the strongest correctness
//! signal in the repo.
//!
//! Requires `make artifacts` (skipped silently otherwise, like the runtime
//! unit tests) and the `pjrt` feature — the offline default build has no
//! compute backend, so the whole file is compiled out without it.

#![cfg(feature = "pjrt")]

use cfa::coordinator::reference::StencilKind;
use cfa::coordinator::stencil::{run_stencil, StencilRun};
use cfa::coordinator::sw::{run_sw, SwRun};
use cfa::coordinator::AllocKind;
use cfa::memsim::MemConfig;
use cfa::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Runtime::open(dir).expect("open artifacts"))
    } else {
        eprintln!("artifacts/ missing - skipping e2e tests");
        None
    }
}

fn f32_mem() -> MemConfig {
    MemConfig {
        elem_bytes: 4,
        ..MemConfig::default()
    }
}

#[test]
fn jacobi_heat_all_allocations_are_exact() {
    let Some(rt) = runtime() else { return };
    // jacobi2d5p_t4x16x16: r=1; steps=8, n=m=24 -> skewed (8, 32, 32)
    for alloc in AllocKind::ALL {
        let cfg = StencilRun {
            artifact: "jacobi2d5p_t4x16x16".into(),
            kind: StencilKind::Jacobi5p,
            n: 24,
            m: 24,
            steps: 8,
            alloc,
            pe_ops_per_cycle: 64,
            seed: 11,
            parallel: 1,
        };
        let report = run_stencil(&rt, &cfg, &f32_mem()).expect("run");
        assert!(
            report.max_abs_err < 1e-4,
            "{}: numeric mismatch {:.3e}",
            alloc.name(),
            report.max_abs_err
        );
        assert!(report.raw_bytes >= report.useful_bytes);
        assert!(report.makespan_cycles > 0);
    }
}

#[test]
fn gaussian_blur_cfa_is_exact() {
    let Some(rt) = runtime() else { return };
    // gaussian_t4x16x16: r=2; steps=8, n=m=16 -> skewed (8, 32, 32)
    let cfg = StencilRun {
        artifact: "gaussian_t4x16x16".into(),
        kind: StencilKind::Gaussian,
        n: 16,
        m: 16,
        steps: 8,
        alloc: AllocKind::Cfa,
        pe_ops_per_cycle: 64,
        seed: 3,
        parallel: 1,
    };
    let report = run_stencil(&rt, &cfg, &f32_mem()).expect("run");
    assert!(
        report.max_abs_err < 1e-4,
        "gaussian mismatch {:.3e}",
        report.max_abs_err
    );
}

#[test]
fn jacobi9p_cfa_is_exact() {
    let Some(rt) = runtime() else { return };
    let cfg = StencilRun {
        artifact: "jacobi2d9p_t4x16x16".into(),
        kind: StencilKind::Jacobi9p,
        n: 24,
        m: 24,
        steps: 8,
        alloc: AllocKind::Cfa,
        pe_ops_per_cycle: 64,
        seed: 5,
        parallel: 1,
    };
    let report = run_stencil(&rt, &cfg, &f32_mem()).expect("run");
    assert!(report.max_abs_err < 1e-4, "{:.3e}", report.max_abs_err);
}

#[test]
fn smith_waterman_all_allocations_are_exact() {
    let Some(rt) = runtime() else { return };
    for alloc in AllocKind::ALL {
        let cfg = SwRun {
            artifact: "sw3_t16x16x16".into(),
            ni: 32,
            nj: 32,
            nk: 32,
            alloc,
            pe_ops_per_cycle: 64,
            seed: 9,
            parallel: 1,
        };
        let report = run_sw(&rt, &cfg, &f32_mem()).expect("run");
        assert!(
            report.max_abs_err < 1e-4,
            "{}: sw mismatch {:.3e}",
            alloc.name(),
            report.max_abs_err
        );
    }
}

#[test]
fn cfa_beats_baselines_on_effective_bandwidth() {
    // The paper's headline: CFA's effective bandwidth tops every baseline
    // on the same workload.
    let Some(rt) = runtime() else { return };
    let mem = f32_mem();
    let mut eff = std::collections::BTreeMap::new();
    for alloc in AllocKind::ALL {
        let cfg = StencilRun {
            artifact: "jacobi2d5p_t4x16x16".into(),
            kind: StencilKind::Jacobi5p,
            n: 24,
            m: 24,
            steps: 8,
            alloc,
            pe_ops_per_cycle: 1_000_000, // memory-bound rig (paper Fig 14)
            seed: 1,
            parallel: 1,
        };
        let report = run_stencil(&rt, &cfg, &mem).expect("run");
        eff.insert(alloc.name(), report.effective_mb_s(&mem));
    }
    let cfa = eff[cfa::layout::registry::names::CFA];
    for (name, &e) in &eff {
        if *name != cfa::layout::registry::names::CFA {
            assert!(
                cfa >= e * 0.99,
                "cfa {cfa:.1} MB/s should beat {name} {e:.1} MB/s ({eff:?})"
            );
        }
    }
}

#[test]
fn tile_size_mismatch_is_reported() {
    let Some(rt) = runtime() else { return };
    let cfg = StencilRun {
        artifact: "jacobi2d5p_t4x16x16".into(),
        kind: StencilKind::Jacobi5p,
        n: 23, // skewed space not divisible
        m: 24,
        steps: 8,
        alloc: AllocKind::Cfa,
        pe_ops_per_cycle: 64,
        seed: 0,
        parallel: 1,
    };
    assert!(run_stencil(&rt, &cfg, &f32_mem()).is_err());
}
