//! Fault isolation & crash recovery, end to end:
//!
//! * the acceptance property — with K panics injected at seed-chosen
//!   evaluations, exploration completes, quarantines exactly K
//!   fingerprints, and after one resume (faults disarmed) the journal's
//!   successful records are byte-identical to a fault-free run;
//! * `--no-retry-failed` keeps quarantined points skipped;
//! * an injected IO error at the journal surfaces as a run error but
//!   leaves a salvageable journal behind;
//! * the `kill -9` property — truncating a journal at *every* byte offset
//!   salvages exactly the terminated prefix, and resuming re-evaluates
//!   only the lost points, reconverging byte-identically;
//! * cancellation ends a run with a resumable journal.
//!
//! The fault plan is process-global, so every test here serializes on one
//! gate (and they live in their own binary, away from the fault-free
//! explorer tests).

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use cfa::dse::{journal, CancelToken, Exhaustive, Explorer, Space};
use cfa::util::faults;

/// One gate for the whole binary: armed plans and the quieted panic hook
/// are process-global.
fn gate() -> std::sync::MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|p| p.into_inner())
}

/// Disarm + restore the panic hook when a test ends, pass or fail.
struct Cleanup;
impl Drop for Cleanup {
    fn drop(&mut self) {
        faults::disarm();
        let _ = std::panic::take_hook();
    }
}

/// Intentional panics are part of these tests; keep them off the console.
fn quiet_panics() -> Cleanup {
    std::panic::set_hook(Box::new(|_| {}));
    Cleanup
}

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(name);
    std::fs::remove_file(&p).ok();
    p
}

fn tiny() -> Space {
    Space::builtin("tiny").unwrap()
}

/// Journal lines split into (success, failure) record sets, as raw bytes.
fn journal_lines(path: &Path) -> (Vec<String>, Vec<String>) {
    let text = std::fs::read_to_string(path).unwrap();
    let (mut ok, mut failed) = (Vec::new(), Vec::new());
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let j = cfa::util::json::parse(line).unwrap();
        if j.get("error").is_some() {
            failed.push(line.to_string());
        } else {
            ok.push(line.to_string());
        }
    }
    (ok, failed)
}

fn sorted(mut v: Vec<String>) -> Vec<String> {
    v.sort();
    v
}

#[test]
fn injected_panics_are_quarantined_and_resume_reconverges() {
    let _gate = gate();
    let _cleanup = quiet_panics();

    // the fault-free reference journal
    let clean = tmp("cfa_fault_clean.jsonl");
    let reference = Explorer::new(tiny(), Box::new(Exhaustive::new()))
        .journal(&clean)
        .explore()
        .unwrap();
    assert_eq!(reference.evaluated, 8);

    // K=2 panics at seed-chosen evaluations: the run completes anyway
    let path = tmp("cfa_fault_quarantine.jsonl");
    faults::arm("panic@dse::evaluate#rand:2/8/42").unwrap();
    let faulted = Explorer::new(tiny(), Box::new(Exhaustive::new()))
        .journal(&path)
        .explore()
        .unwrap();
    faults::disarm();
    assert_eq!(faulted.failed, 2);
    assert_eq!(faulted.evaluated, 6);
    assert_eq!(faulted.quarantined.len(), 2);
    for q in &faulted.quarantined {
        assert!(q.error().unwrap().contains("panicked"), "{:?}", q.error());
    }
    assert!(faulted.summary().contains("quarantine: 2 new failures"));
    let (ok1, failed1) = journal_lines(&path);
    assert_eq!((ok1.len(), failed1.len()), (6, 2));
    // the journal round-trips, failures included
    assert_eq!(journal::read(&path).unwrap().len(), 8);

    // one resume with faults disarmed retries exactly the quarantined
    // fingerprints and reconverges
    let resumed = Explorer::new(tiny(), Box::new(Exhaustive::new()))
        .resume(&path)
        .journal(&path)
        .explore()
        .unwrap();
    assert_eq!(resumed.resumed, 6);
    assert_eq!(resumed.retried, 2);
    assert_eq!(resumed.evaluated, 2);
    assert_eq!(resumed.failed, 0);
    let fresh: Vec<String> = resumed.all[6..]
        .iter()
        .map(|e| e.fingerprint())
        .collect();
    let quarantined: Vec<String> = faulted
        .quarantined
        .iter()
        .map(|e| e.fingerprint())
        .collect();
    assert_eq!(sorted(fresh), sorted(quarantined));
    // acceptance: successful records byte-identical to the fault-free run
    let (ok2, failed2) = journal_lines(&path);
    assert_eq!(failed2, failed1, "old quarantine lines are kept, not rewritten");
    let (clean_ok, clean_failed) = journal_lines(&clean);
    assert!(clean_failed.is_empty());
    assert_eq!(sorted(ok2), sorted(clean_ok));

    // a further resume is a no-op: successes supersede the stale failures
    let done = Explorer::new(tiny(), Box::new(Exhaustive::new()))
        .resume(&path)
        .journal(&path)
        .explore()
        .unwrap();
    assert_eq!((done.resumed, done.retried, done.evaluated), (8, 0, 0));
    std::fs::remove_file(&clean).ok();
    std::fs::remove_file(&path).ok();
}

#[test]
fn no_retry_failed_keeps_quarantined_points_skipped() {
    let _gate = gate();
    let _cleanup = quiet_panics();
    let path = tmp("cfa_fault_noretry.jsonl");
    faults::arm("panic@dse::evaluate#2").unwrap();
    let faulted = Explorer::new(tiny(), Box::new(Exhaustive::new()))
        .journal(&path)
        .explore()
        .unwrap();
    faults::disarm();
    assert_eq!((faulted.evaluated, faulted.failed), (7, 1));

    // resume without retry: the failure counts as resumed, nothing runs
    let out = tmp("cfa_fault_noretry_out.jsonl");
    let resumed = Explorer::new(tiny(), Box::new(Exhaustive::new()))
        .resume(&path)
        .journal(&out)
        .retry_failed(false)
        .explore()
        .unwrap();
    assert_eq!((resumed.resumed, resumed.retried, resumed.evaluated), (8, 0, 0));
    // the rewritten journal stays complete: the kept failure is carried
    // over so a later (retrying) resume still knows about it
    let (ok, failed) = journal_lines(&out);
    assert_eq!((ok.len(), failed.len()), (7, 1));
    let retrying = Explorer::new(tiny(), Box::new(Exhaustive::new()))
        .resume(&out)
        .journal(&out)
        .explore()
        .unwrap();
    assert_eq!((retrying.resumed, retrying.retried, retrying.evaluated), (7, 1, 1));
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&out).ok();
}

#[test]
fn io_fault_at_journal_push_fails_the_run_but_salvages() {
    let _gate = gate();
    let _cleanup = quiet_panics();
    let path = tmp("cfa_fault_journal_io.jsonl");
    faults::arm("io@dse::journal::push#3").unwrap();
    let err = Explorer::new(tiny(), Box::new(Exhaustive::new()))
        .journal(&path)
        .explore()
        .unwrap_err();
    faults::disarm();
    assert!(format!("{err:#}").contains("fault injected"), "{err:#}");
    // the first two records were flushed before the fault — resumable
    let (records, torn) = journal::read_salvage(&path).unwrap();
    assert_eq!((records.len(), torn), (2, 0));
    let resumed = Explorer::new(tiny(), Box::new(Exhaustive::new()))
        .resume(&path)
        .journal(&path)
        .explore()
        .unwrap();
    assert_eq!((resumed.resumed, resumed.evaluated), (2, 6));
    assert_eq!(journal::read(&path).unwrap().len(), 8);
    std::fs::remove_file(&path).ok();
}

#[test]
fn kill9_truncation_at_every_byte_offset_resumes_losslessly() {
    let _gate = gate();
    let clean = tmp("cfa_fault_kill9.jsonl");
    Explorer::new(tiny(), Box::new(Exhaustive::new()))
        .journal(&clean)
        .explore()
        .unwrap();
    let bytes = std::fs::read(&clean).unwrap();
    let clean_text = String::from_utf8(bytes.clone()).unwrap();
    let line_ends: Vec<usize> = bytes
        .iter()
        .enumerate()
        .filter_map(|(i, &b)| (b == b'\n').then_some(i + 1))
        .collect();
    assert_eq!(line_ends.len(), 8);

    // cheap property at EVERY offset: salvage returns exactly the records
    // of the newline-terminated prefix, never an error
    let work = tmp("cfa_fault_kill9_cut.jsonl");
    for cut in 0..=bytes.len() {
        std::fs::write(&work, &bytes[..cut]).unwrap();
        let (records, torn) = journal::read_salvage(&work).unwrap();
        let complete = line_ends.iter().filter(|&&e| e <= cut).count();
        let clean_len = line_ends
            .iter()
            .rev()
            .find(|&&e| e <= cut)
            .copied()
            .unwrap_or(0);
        assert_eq!((records.len(), torn), (complete, cut - clean_len), "cut={cut}");
    }

    // full resume at a spread of offsets (line boundaries and torn cuts):
    // only the lost points re-evaluate, and the journal reconverges to the
    // clean bytes exactly (exhaustive order is the journal order)
    let mut cuts: Vec<usize> = line_ends.clone();
    cuts.extend([0, line_ends[0] / 2, line_ends[3] + 7, bytes.len() - 1]);
    for cut in cuts {
        std::fs::write(&work, &bytes[..cut]).unwrap();
        let complete = line_ends.iter().filter(|&&e| e <= cut).count();
        let resumed = Explorer::new(tiny(), Box::new(Exhaustive::new()))
            .resume(&work)
            .journal(&work)
            .explore()
            .unwrap();
        assert_eq!(resumed.resumed, complete, "cut={cut}");
        assert_eq!(resumed.evaluated, 8 - complete, "cut={cut}");
        assert_eq!(
            std::fs::read_to_string(&work).unwrap(),
            clean_text,
            "cut={cut}"
        );
    }
    std::fs::remove_file(&clean).ok();
    std::fs::remove_file(&work).ok();
}

#[test]
fn cancellation_leaves_a_flushed_resumable_journal() {
    let _gate = gate();
    let path = tmp("cfa_fault_cancel.jsonl");
    let token = CancelToken::new();
    token.cancel();
    let interrupted = Explorer::new(tiny(), Box::new(Exhaustive::new()))
        .cancel_token(token)
        .journal(&path)
        .explore()
        .unwrap();
    assert!(interrupted.interrupted);
    assert_eq!(interrupted.evaluated, 0);
    assert!(interrupted.summary().contains("interrupted"));
    // the journal exists (created, empty) and resumes to a full run
    let resumed = Explorer::new(tiny(), Box::new(Exhaustive::new()))
        .resume(&path)
        .journal(&path)
        .explore()
        .unwrap();
    assert!(!resumed.interrupted);
    assert_eq!((resumed.resumed, resumed.evaluated), (0, 8));
    assert_eq!(journal::read(&path).unwrap().len(), 8);
    std::fs::remove_file(&path).ok();
}
