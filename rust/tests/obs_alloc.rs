//! The disabled observability fast paths allocate **nothing**.
//!
//! This is the contract that lets hot loops (the memsim replay kernel,
//! the serve dispatch, the dse evaluator) keep their instrumentation
//! permanently: `span()` with no active capture is one relaxed atomic
//! load, and metric updates are single atomic RMWs on pre-registered
//! cells. A counting `#[global_allocator]` pins that to exactly zero
//! heap traffic.
//!
//! This lives in its own integration binary on purpose: the check is
//! only meaningful while no capture is active and no concurrent test is
//! allocating, so nothing else may run in this process.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn disabled_spans_and_metric_updates_never_touch_the_heap() {
    assert!(
        !cfa::obs::enabled(),
        "no capture may be active in this binary"
    );

    // handle creation allocates (registry entry + Arc) — do it up front
    let m = cfa::obs::registry();
    let counter = m.counter("cfa.test.alloc_counter");
    let gauge = m.gauge("cfa.test.alloc_gauge");
    let histogram = m.histogram("cfa.test.alloc_histogram");

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for i in 0..100_000u64 {
        let _s = cfa::obs::span("alloc::hot");
        counter.inc();
        counter.add(2);
        gauge.inc();
        gauge.dec();
        gauge.set(i);
        histogram.record(i);
    }
    let delta = ALLOC_CALLS.load(Ordering::SeqCst) - before;
    assert_eq!(
        delta, 0,
        "disabled span()/metric updates allocated {delta} time(s)"
    );
    assert_eq!(counter.get(), 300_000, "the loop really ran");
    assert_eq!(histogram.count(), 100_000);
}
