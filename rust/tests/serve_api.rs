//! Acceptance tests for `cfa serve`, the persistent multi-tenant
//! autotuning daemon:
//!
//! * protocol round-trip: malformed requests get `error` replies with
//!   the id preserved and the connection keeps serving;
//! * N concurrent tune tenants produce journals byte-identical to a
//!   standalone `cfa tune` run, and the shared single-flight trace
//!   cache proves the second (and third) same-geometry tenant performed
//!   **zero** trace compiles;
//! * an injected per-request fault (`CFA_FAULTS=panic@serve::enqueue#1`,
//!   in a spawned daemon process so the process-global fault plan cannot
//!   leak into sibling tests) errors exactly that request while the
//!   other tenant runs to a correct journal;
//! * kill -9 mid-tune, restart, resume: journaled evaluations are
//!   resumed, not re-evaluated.

use std::io::{BufRead, BufReader, Cursor, Write};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};

use cfa::dse::{Exhaustive, Explorer, Space};
use cfa::layout::registry;
use cfa::serve::Server;
use cfa::util::json::{self, Json};

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(name);
    std::fs::remove_file(&p).ok();
    p
}

fn sink() -> (Arc<Mutex<Vec<u8>>>, Arc<Mutex<dyn Write + Send>>) {
    let buf: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
    (buf.clone(), buf as Arc<Mutex<dyn Write + Send>>)
}

fn replies(buf: &Arc<Mutex<Vec<u8>>>) -> Vec<Json> {
    let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
    text.lines()
        .map(|l| json::parse(l).expect("reply lines parse as JSON"))
        .collect()
}

fn find<'a>(rs: &'a [Json], id: &str, event: &str) -> Option<&'a Json> {
    rs.iter().find(|j| {
        j.get("id").and_then(Json::as_str) == Some(id)
            && j.get("event").and_then(Json::as_str) == Some(event)
    })
}

/// The standalone-`cfa tune` reference journal for the tiny space.
fn reference_journal(path: &PathBuf) {
    Explorer::new(Space::builtin("tiny").unwrap(), Box::new(Exhaustive::new()))
        .registry(registry::global())
        .journal(path)
        .explore()
        .unwrap();
}

#[test]
fn protocol_round_trip_quarantines_bad_lines() {
    let server = Server::new(2, 8);
    let (buf, writer) = sink();
    let script = concat!(
        "{\"cmd\":\"tune\",\"id\":\"nospace\"}\n",
        "garbage that is not json\n",
        "{\"cmd\":\"stats\",\"id\":\"s\"}\n",
        "{\"cmd\":\"plan\",\"id\":\"p\",\"workload\":\"jacobi2d5p\",\"tile\":[8,8,8],\"layout\":\"cfa\"}\n",
        "{\"cmd\":\"run\",\"id\":\"r\",\"workload\":\"jacobi2d5p\",\"tile\":[8,8,8],\"tiles_per_dim\":2,\"channels\":2,\"striping\":\"facet\"}\n",
        "{\"cmd\":\"shutdown\",\"id\":\"z\"}\n",
    );
    server.serve_connection(Cursor::new(script), writer, false);
    server.shutdown_and_join();
    let rs = replies(&buf);
    // the two bad lines errored without killing anything after them
    let nospace = find(&rs, "nospace", "error").expect("tune without space errors");
    assert!(nospace
        .get("error")
        .and_then(Json::as_str)
        .unwrap()
        .contains("space"));
    assert!(find(&rs, "", "error").is_some(), "non-JSON line errors with empty id");
    assert!(find(&rs, "s", "done").is_some(), "stats still answered");
    assert!(find(&rs, "p", "done").is_some(), "plan still answered");
    let run = find(&rs, "r", "done").expect("multi-channel run still answered");
    let cycles = run
        .get("data")
        .and_then(|d| d.get("report"))
        .and_then(|r| r.get("makespan_cycles"))
        .and_then(Json::as_f64)
        .unwrap();
    assert!(cycles > 0.0);
    assert!(find(&rs, "z", "done").is_some(), "shutdown acknowledged");
    assert_eq!(server.state().errors(), 2);
}

#[test]
fn concurrent_tenants_share_compiles_and_match_tune_bytes() {
    let ref_path = tmp("cfa_serve_ref.jsonl");
    reference_journal(&ref_path);
    let ref_bytes = std::fs::read(&ref_path).unwrap();
    assert!(!ref_bytes.is_empty());

    let server = Arc::new(Server::new(4, 16));
    let out_a = tmp("cfa_serve_tenant_a.jsonl");
    let out_b = tmp("cfa_serve_tenant_b.jsonl");
    // two tenants, two connections, same geometry space, at the same time
    let mut handles = Vec::new();
    for (id, out) in [("a", &out_a), ("b", &out_b)] {
        let server = server.clone();
        let script = format!(
            "{{\"cmd\":\"tune\",\"id\":\"{id}\",\"space\":\"tiny\",\"out\":\"{}\"}}\n",
            out.display()
        );
        handles.push(std::thread::spawn(move || {
            let (buf, writer) = sink();
            server.serve_connection(Cursor::new(script), writer, false);
            buf
        }));
    }
    let bufs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // connections returned at EOF; the tunes drain through the pool
    server.shutdown_and_join();
    for (buf, id) in bufs.iter().zip(["a", "b"]) {
        let rs = replies(buf);
        assert!(find(&rs, id, "accepted").is_some(), "tenant {id} accepted");
        assert!(find(&rs, id, "done").is_some(), "tenant {id} finished");
    }
    // journals are byte-identical to standalone `cfa tune`
    assert_eq!(std::fs::read(&out_a).unwrap(), ref_bytes, "tenant a bytes");
    assert_eq!(std::fs::read(&out_b).unwrap(), ref_bytes, "tenant b bytes");
    // the tiny space is 8 geometries: 16 trace requests across the two
    // tenants must cost exactly 8 compiles — the single-flight batcher
    // turned every duplicate into a hit, even when they raced
    let traces = server.state().traces().stats();
    assert_eq!(traces.misses, 8, "misses == compiles == distinct geometries");
    assert_eq!(traces.hits + traces.misses, 16, "every request accounted");
    assert_eq!(traces.entries, 8);
    let sessions = server.state().sessions().stats();
    assert_eq!(sessions.misses, 8, "one compiled core per geometry");
    assert_eq!(sessions.hits, 8, "the other tenant reused every core");
}

#[test]
fn a_later_tenant_compiles_nothing_at_all() {
    let ref_path = tmp("cfa_serve_ref_warm.jsonl");
    reference_journal(&ref_path);
    let server = Server::new(2, 8);
    let out_warmup = tmp("cfa_serve_warmup.jsonl");
    let out_late = tmp("cfa_serve_late.jsonl");
    let (buf, writer) = sink();
    let script = format!(
        "{{\"cmd\":\"tune\",\"id\":\"w\",\"space\":\"tiny\",\"out\":\"{}\"}}\n",
        out_warmup.display()
    );
    server.serve_connection(Cursor::new(script), writer, false);
    // first tenant still draining is fine — the acceptance claim is about
    // totals after both finish; serve the second tenant now
    let (buf2, writer2) = sink();
    let script2 = format!(
        "{{\"cmd\":\"tune\",\"id\":\"l\",\"space\":\"tiny\",\"out\":\"{}\"}}\n",
        out_late.display()
    );
    server.serve_connection(Cursor::new(script2), writer2, false);
    server.shutdown_and_join();
    assert!(find(&replies(&buf), "w", "done").is_some());
    assert!(find(&replies(&buf2), "l", "done").is_some());
    let traces = server.state().traces().stats();
    assert_eq!(traces.misses, 8, "the warm tenant recompiled nothing");
    assert_eq!(server.state().sessions().misses(), 8);
    let ref_bytes = std::fs::read(&ref_path).unwrap();
    assert_eq!(std::fs::read(&out_late).unwrap(), ref_bytes);
}

#[test]
fn stats_reply_is_registry_backed_with_unchanged_schema() {
    // the counters moved onto the obs metrics registry; the `stats`
    // payload must keep the exact pre-migration key set, sorted-compact
    // shape, and values equal to the state accessors
    let server = Server::new(1, 4);
    let (buf, writer) = sink();
    let script = concat!(
        "not json\n",
        "{\"cmd\":\"run\",\"id\":\"r\",\"workload\":\"jacobi2d5p\",\"tile\":[8,8,8],\"tiles_per_dim\":2}\n",
        "{\"cmd\":\"shutdown\",\"id\":\"z\"}\n",
    );
    server.serve_connection(Cursor::new(script), writer, false);
    server.shutdown_and_join();
    assert!(find(&replies(&buf), "r", "done").is_some());
    let state = server.state();
    let s = state.stats_json().to_string_compact();
    assert!(
        s.starts_with(&format!(
            "{{\"active\":{},\"errors\":{},\"plans\":",
            state.active(),
            state.errors()
        )),
        "{s}"
    );
    assert!(s.contains(&format!("\"rejected\":{}", state.rejected())), "{s}");
    assert!(s.contains(&format!("\"requests\":{}", state.requests())), "{s}");
    assert!(s.contains("\"sessions\":{\"entries\":"), "{s}");
    assert!(s.contains("\"traces\":{\"entries\":"), "{s}");
    assert_eq!(state.errors(), 1, "the garbage line");
    assert_eq!(state.requests(), 3);
    // the per-instance handles feed the same process-wide registry the
    // snapshot sums, under the documented names
    // (`cfa.serve.queue_depth` lives on the worker pool, which
    // shutdown_and_join already dropped — its cell left the snapshot
    // with it; queue.rs covers it while a pool is alive)
    let snap = cfa::obs::registry().snapshot();
    assert!(snap.get("cfa.serve.requests").copied().unwrap_or(0) >= 3);
}

#[test]
fn profiled_tune_request_writes_a_span_trace_and_identical_journal() {
    let ref_path = tmp("cfa_serve_prof_ref.jsonl");
    reference_journal(&ref_path);
    let server = Server::new(2, 8);
    let out = tmp("cfa_serve_prof.jsonl");
    let prof = tmp("cfa_serve_prof_trace.json");
    let (buf, writer) = sink();
    let script = format!(
        "{{\"cmd\":\"tune\",\"id\":\"t\",\"space\":\"tiny\",\"out\":\"{}\",\"profile\":\"{}\"}}\n",
        out.display(),
        prof.display()
    );
    server.serve_connection(Cursor::new(script), writer, false);
    server.shutdown_and_join();
    assert!(find(&replies(&buf), "t", "done").is_some());
    // the profile is valid Chrome trace-event JSON with events in it
    // (balance is not asserted: concurrent capture windows may clip)
    let text = std::fs::read_to_string(&prof).unwrap();
    let j = json::parse(&text).expect("profile is valid JSON");
    let events = j.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!events.is_empty(), "the capture saw the tune's spans");
    assert!(events
        .iter()
        .any(|e| e.get("name").and_then(Json::as_str) == Some("dse::evaluate")));
    // ... and profiling never touches journal bytes
    assert_eq!(
        std::fs::read(&out).unwrap(),
        std::fs::read(&ref_path).unwrap(),
        "profiled tenant journal != cfa tune bytes"
    );
    std::fs::remove_file(&prof).ok();
}

// --- spawned-daemon tests (process isolation for faults and kill -9) ---

fn spawn_daemon(envs: &[(&str, &str)]) -> Child {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_cfa"));
    cmd.args(["serve", "--stdio", "--workers", "2"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null());
    for (k, v) in envs {
        cmd.env(k, v);
    }
    cmd.spawn().expect("spawn cfa serve --stdio")
}

/// Read daemon stdout until the terminal reply for `id` arrives; panics
/// (with the transcript) on EOF first.
fn read_until_terminal(reader: &mut impl BufRead, id: &str) -> (String, Vec<String>) {
    let mut seen = Vec::new();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap() == 0 {
            panic!("daemon EOF before terminal reply for {id}; transcript: {seen:#?}");
        }
        let l = line.trim().to_string();
        if l.is_empty() {
            continue;
        }
        let j = json::parse(&l).expect("daemon lines are JSON");
        let this_id = j.get("id").and_then(Json::as_str).unwrap_or("");
        let event = j.get("event").and_then(Json::as_str).unwrap_or("");
        seen.push(l);
        if this_id == id && (event == "done" || event == "error" || event == "rejected") {
            return (event.to_string(), seen);
        }
    }
}

#[test]
fn injected_fault_errors_one_request_and_spares_the_next() {
    let ref_path = tmp("cfa_serve_fault_ref.jsonl");
    reference_journal(&ref_path);
    let out_b = tmp("cfa_serve_fault_b.jsonl");
    // first arrival at the enqueue site panics: request "a" is the victim
    let mut child = spawn_daemon(&[("CFA_FAULTS", "panic@serve::enqueue#1")]);
    let mut stdin = child.stdin.take().unwrap();
    let mut stdout = BufReader::new(child.stdout.take().unwrap());
    writeln!(stdin, "{{\"cmd\":\"tune\",\"id\":\"a\",\"space\":\"tiny\"}}").unwrap();
    writeln!(
        stdin,
        "{{\"cmd\":\"tune\",\"id\":\"b\",\"space\":\"tiny\",\"out\":\"{}\"}}",
        out_b.display()
    )
    .unwrap();
    writeln!(stdin, "{{\"cmd\":\"shutdown\",\"id\":\"z\"}}").unwrap();
    drop(stdin);
    let (event_a, _) = read_until_terminal(&mut stdout, "a");
    assert_eq!(event_a, "error", "the faulted request errors");
    let (event_b, transcript) = read_until_terminal(&mut stdout, "b");
    assert_eq!(event_b, "done", "the sibling request is untouched: {transcript:#?}");
    let fault_line = transcript
        .iter()
        .find(|l| l.contains("\"id\":\"a\"") && l.contains("\"event\":\"error\""))
        .unwrap();
    assert!(
        fault_line.contains("fault injected"),
        "the error names the injected fault: {fault_line}"
    );
    let status = child.wait().unwrap();
    assert!(status.success(), "daemon exited cleanly after the fault");
    assert_eq!(
        std::fs::read(&out_b).unwrap(),
        std::fs::read(&ref_path).unwrap(),
        "the surviving tenant's journal is still byte-identical to cfa tune"
    );
}

#[test]
fn kill_nine_mid_run_resumes_without_reevaluating() {
    let journal = tmp("cfa_serve_kill9.jsonl");
    // phase 1: tune with a budget of 4 (of 8), then SIGKILL the daemon
    let mut child = spawn_daemon(&[]);
    let mut stdin = child.stdin.take().unwrap();
    let mut stdout = BufReader::new(child.stdout.take().unwrap());
    writeln!(
        stdin,
        "{{\"cmd\":\"tune\",\"id\":\"t1\",\"space\":\"tiny\",\"budget\":4,\"out\":\"{}\"}}",
        journal.display()
    )
    .unwrap();
    let (event, _) = read_until_terminal(&mut stdout, "t1");
    assert_eq!(event, "done");
    child.kill().unwrap(); // SIGKILL: no drain, no cleanup
    let _ = child.wait();
    // phase 2: a fresh daemon resumes the same journal with no budget
    let mut child = spawn_daemon(&[]);
    let mut stdin = child.stdin.take().unwrap();
    let mut stdout = BufReader::new(child.stdout.take().unwrap());
    writeln!(
        stdin,
        "{{\"cmd\":\"tune\",\"id\":\"t2\",\"space\":\"tiny\",\"out\":\"{p}\",\"resume\":\"{p}\"}}",
        p = journal.display()
    )
    .unwrap();
    writeln!(stdin, "{{\"cmd\":\"shutdown\",\"id\":\"z\"}}").unwrap();
    drop(stdin);
    let (event, transcript) = read_until_terminal(&mut stdout, "t2");
    assert_eq!(event, "done", "{transcript:#?}");
    let done = json::parse(transcript.last().unwrap()).unwrap();
    let summary = done
        .get("data")
        .and_then(|d| d.get("summary"))
        .and_then(Json::as_str)
        .unwrap();
    assert!(
        summary.contains("evaluated 4 new points (4 resumed"),
        "journaled work is resumed, not re-evaluated: {summary}"
    );
    let _ = child.wait();
}
